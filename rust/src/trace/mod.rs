//! Deterministic virtual-time trace journal.
//!
//! A [`TraceSink`] receives span/event records keyed by
//! `(epoch, virtual_time, worker, seq)` — never wall-clock, so journals obey
//! the same determinism contracts the lint xtask enforces on the simulator
//! (see `sim/README.md`, "Determinism contracts" and "Observability").
//! Emission sites: `sim::cluster` stage transitions, `net::contention` flow
//! enqueue/drain, the adaptive-cache resize controller, the recovery driver,
//! and per-(worker, epoch) report summaries from the worker pipeline.
//!
//! Records buffer per worker in bounded rings (drop-oldest, with a drop
//! counter) inside a [`TraceJournal`]; the cloneable [`TraceHandle`] is the
//! doorway the coordinator threads through `RunContext`. Export is JSONL —
//! one compact JSON object per record, merged across workers in the global
//! `(epoch, t, worker, seq)` order. Because `seq` is allocated per worker in
//! that worker's own deterministic emission order, the merged byte stream is
//! identical at any `RAPIDGNN_THREADS` setting: parallel trace-mode workers
//! each write only their own ring, and the cluster/contention paths emit from
//! the single-threaded event loop. Tracing is strictly observational — a
//! sink never feeds back into scheduling, pricing, or training state.

use crate::util::value::Value;
use crate::Result;
use anyhow::Context;
use std::collections::{BTreeMap, VecDeque};
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Default per-worker ring capacity (records). Generous for the simulated
/// scales in this repo; overflow drops the oldest records and counts them.
pub const DEFAULT_RING_CAPACITY: usize = 65_536;

/// One journal entry. `seq` is allocated per worker, monotone in that
/// worker's emission order, so `(epoch, t, worker, seq)` is a total order
/// over a run's records that is independent of thread count.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecord {
    /// Epoch the record belongs to (the virtual clock restarts per epoch).
    pub epoch: u32,
    /// Virtual time within the epoch (seconds on the simulated clock).
    pub t: f64,
    /// Worker the record is attributed to.
    pub worker: u32,
    /// Per-worker emission sequence number (ties on `(epoch, t)`).
    pub seq: u64,
    /// Record kind: `epoch`, `stage-done`, `consume-done`, `flow-enqueue`,
    /// `flow-drain`, `cache-resize`, `recovery`.
    pub kind: String,
    /// Kind-specific payload (always a table).
    pub fields: Value,
}

impl TraceRecord {
    /// Serialize to a [`Value`] table (keys emit alphabetically:
    /// `epoch, fields, kind, seq, t, worker`).
    pub fn to_value(&self) -> Value {
        let mut v = Value::table();
        v.set("epoch", self.epoch)
            .set("t", self.t)
            .set("worker", self.worker)
            .set("seq", self.seq)
            .set("kind", self.kind.as_str())
            .set("fields", self.fields.clone());
        v
    }

    /// Parse a table produced by [`Self::to_value`] (JSONL replay).
    pub fn from_value(v: &Value) -> Result<TraceRecord> {
        Ok(TraceRecord {
            epoch: v.req_u32("epoch")?,
            t: v.req_f64("t")?,
            worker: v.req_u32("worker")?,
            seq: v.req_u64("seq")?,
            kind: v.req_str("kind")?.to_string(),
            fields: v.req_table("fields")?.clone(),
        })
    }

    /// The global sort key (total order via `f64::total_cmp` on `t`).
    fn sort_key(&self) -> (u32, f64, u32, u64) {
        (self.epoch, self.t, self.worker, self.seq)
    }
}

/// Anything that can absorb trace records. The simulator emits through this
/// trait so tests can plug counting/filtering sinks without touching the
/// journal; all output must flow through a sink (the `trace-sink` lint rule
/// forbids direct console printing anywhere under `src/trace/`).
pub trait TraceSink: Send + Sync {
    /// Absorb one record. `t` is virtual time within `epoch`.
    fn record(&self, worker: u32, epoch: u32, t: f64, kind: &str, fields: Value);
}

/// One worker's bounded record ring.
#[derive(Debug, Default)]
struct WorkerRing {
    records: VecDeque<TraceRecord>,
    /// Next per-worker sequence number (never reset, so ordering survives
    /// drops).
    next_seq: u64,
    /// Records evicted by the capacity bound.
    dropped: u64,
}

/// The concrete journal: per-worker bounded rings behind one mutex. The
/// lock is held only for a push or a snapshot — emission sites are either
/// the single-threaded event loop or per-worker threads touching disjoint
/// rings, so contention is negligible and ordering never depends on lock
/// acquisition order.
#[derive(Debug)]
pub struct TraceJournal {
    capacity: usize,
    rings: Mutex<BTreeMap<u32, WorkerRing>>,
}

impl TraceJournal {
    /// Journal with the given per-worker ring capacity (min 1).
    pub fn with_capacity(capacity: usize) -> TraceJournal {
        TraceJournal { capacity: capacity.max(1), rings: Mutex::new(BTreeMap::new()) }
    }
}

impl TraceSink for TraceJournal {
    fn record(&self, worker: u32, epoch: u32, t: f64, kind: &str, fields: Value) {
        let mut rings = self.rings.lock().expect("trace journal lock");
        let ring = rings.entry(worker).or_default();
        let seq = ring.next_seq;
        ring.next_seq += 1;
        if ring.records.len() == self.capacity {
            ring.records.pop_front();
            ring.dropped += 1;
        }
        ring.records.push_back(TraceRecord {
            epoch,
            t,
            worker,
            seq,
            kind: kind.to_string(),
            fields,
        });
    }
}

/// Cloneable, shareable handle over a [`TraceJournal`]. This is what rides
/// in `RunContext.trace` and what `--trace-out` exports from.
#[derive(Debug, Clone)]
pub struct TraceHandle(Arc<TraceJournal>);

impl Default for TraceHandle {
    fn default() -> Self {
        TraceHandle::new()
    }
}

impl TraceHandle {
    /// Handle over a fresh journal with the default ring capacity.
    pub fn new() -> TraceHandle {
        TraceHandle(Arc::new(TraceJournal::with_capacity(DEFAULT_RING_CAPACITY)))
    }

    /// Handle over a fresh journal with an explicit per-worker capacity.
    pub fn with_capacity(capacity: usize) -> TraceHandle {
        TraceHandle(Arc::new(TraceJournal::with_capacity(capacity)))
    }

    /// Emit one record (delegates to [`TraceSink::record`]).
    pub fn event(&self, worker: u32, epoch: u32, t: f64, kind: &str, fields: Value) {
        self.0.record(worker, epoch, t, kind, fields);
    }

    /// Snapshot every buffered record, merged across workers into the global
    /// deterministic order `(epoch, t, worker, seq)`.
    pub fn records(&self) -> Vec<TraceRecord> {
        let rings = self.0.rings.lock().expect("trace journal lock");
        let mut out: Vec<TraceRecord> = Vec::new();
        for ring in rings.values() {
            out.extend(ring.records.iter().cloned());
        }
        out.sort_by(|a, b| {
            let (ae, at, aw, asq) = a.sort_key();
            let (be, bt, bw, bsq) = b.sort_key();
            ae.cmp(&be)
                .then(at.total_cmp(&bt))
                .then(aw.cmp(&bw))
                .then(asq.cmp(&bsq))
        });
        out
    }

    /// Total buffered records across all rings.
    pub fn len(&self) -> usize {
        let rings = self.0.rings.lock().expect("trace journal lock");
        rings.values().map(|r| r.records.len()).sum()
    }

    /// True when nothing has been recorded (or everything was dropped).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total records evicted by the per-worker capacity bound.
    pub fn dropped(&self) -> u64 {
        let rings = self.0.rings.lock().expect("trace journal lock");
        rings.values().map(|r| r.dropped).sum()
    }

    /// Render the journal as JSONL: one compact JSON object per record in
    /// the global order, each line terminated by `\n`. Byte-identical at
    /// any `RAPIDGNN_THREADS` setting for the same run.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for rec in self.records() {
            out.push_str(&rec.to_value().to_json());
            out.push('\n');
        }
        out
    }

    /// Write [`Self::to_jsonl`] to `path`.
    pub fn write_jsonl(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("create trace dir {}", parent.display()))?;
            }
        }
        std::fs::write(path, self.to_jsonl())
            .with_context(|| format!("write trace journal {}", path.display()))
    }
}

/// Parse a JSONL journal back into records (offline `top --trace` replay).
/// Blank lines are skipped; records are re-sorted into the global order so
/// hand-concatenated journals still replay deterministically.
pub fn parse_jsonl(text: &str) -> Result<Vec<TraceRecord>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let v = Value::from_json(line)
            .with_context(|| format!("trace line {}: invalid JSON", i + 1))?;
        out.push(
            TraceRecord::from_value(&v)
                .with_context(|| format!("trace line {}: invalid record", i + 1))?,
        );
    }
    out.sort_by(|a, b| {
        let (ae, at, aw, asq) = a.sort_key();
        let (be, bt, bw, bsq) = b.sort_key();
        ae.cmp(&be).then(at.total_cmp(&bt)).then(aw.cmp(&bw)).then(asq.cmp(&bsq))
    });
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fields(k: &str, v: u64) -> Value {
        let mut t = Value::table();
        t.set(k, v);
        t
    }

    #[test]
    fn records_merge_in_global_order() {
        let h = TraceHandle::new();
        // Emit out of worker order with equal times to exercise every key.
        h.event(1, 0, 2.0, "stage-done", Value::table());
        h.event(0, 0, 2.0, "stage-done", Value::table());
        h.event(0, 0, 1.0, "stage-done", Value::table());
        h.event(1, 1, 0.5, "stage-done", Value::table());
        h.event(0, 0, 2.0, "consume-done", Value::table());
        let keys: Vec<(u32, f64, u32, u64)> =
            h.records().iter().map(|r| (r.epoch, r.t, r.worker, r.seq)).collect();
        assert_eq!(
            keys,
            vec![
                (0, 1.0, 0, 1),
                (0, 2.0, 0, 0),
                (0, 2.0, 0, 2),
                (0, 2.0, 1, 0),
                (1, 0.5, 1, 1),
            ]
        );
    }

    #[test]
    fn bounded_ring_drops_oldest_and_counts() {
        let h = TraceHandle::with_capacity(2);
        for i in 0..5u64 {
            h.event(0, 0, i as f64, "stage-done", fields("i", i));
        }
        assert_eq!(h.len(), 2);
        assert_eq!(h.dropped(), 3);
        let recs = h.records();
        // Oldest dropped; seq numbering survives the eviction.
        assert_eq!(recs[0].seq, 3);
        assert_eq!(recs[1].seq, 4);
    }

    #[test]
    fn jsonl_round_trips_and_is_sorted() {
        let h = TraceHandle::new();
        h.event(1, 0, 0.25, "flow-drain", fields("bytes", 128));
        h.event(0, 0, 0.5, "epoch", fields("steps", 3));
        let text = h.to_jsonl();
        assert_eq!(text.lines().count(), 2);
        let parsed = parse_jsonl(&text).unwrap();
        assert_eq!(parsed, h.records());
        // Keys emit alphabetically from the Value table.
        let first = text.lines().next().unwrap();
        assert!(first.starts_with("{\"epoch\":"));
        assert!(first.contains("\"kind\":\"flow-drain\""));
    }

    #[test]
    fn parse_rejects_malformed_lines() {
        assert!(parse_jsonl("not json\n").is_err());
        assert!(parse_jsonl("{\"epoch\":0}\n").is_err());
        assert!(parse_jsonl("\n\n").unwrap().is_empty());
    }

    #[test]
    fn clones_share_one_journal() {
        let h = TraceHandle::new();
        let h2 = h.clone();
        h2.event(0, 0, 0.0, "epoch", Value::table());
        assert_eq!(h.len(), 1);
        assert!(!h.is_empty());
    }
}
