//! SSD streaming of precomputed metadata blocks (paper §4, component 3).
//!
//! The precompute pass can enumerate many epochs of schedules; holding them
//! all in CPU memory would defeat the paper's "no CPU-memory growth" claim
//! (Fig. 7b). Schedules are therefore written to disk as compact sequential
//! blocks during precomputation and streamed back one batch at a time during
//! training — a bounded-memory iterator is all the runtime holds.
//!
//! Format (little-endian, per epoch file):
//! ```text
//! magic "RGNB" | version u32 | worker u32 | epoch u32 | num_batches u32
//! per batch:
//!   batch u32 | num_seeds u32 | num_inputs u32 | num_remote u32
//!   seeds [u32; num_seeds] | input_nodes [u32; num_inputs]
//!   remote_mask [u64; ceil(num_inputs/64)]
//! ```

use crate::sampler::{BatchMeta, EpochSchedule};
use crate::{Result, WorkerId};
use anyhow::{bail, Context};
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 4] = b"RGNB";
const VERSION: u32 = 1;

/// Path of the metadata file for (worker, epoch) under `dir`.
pub fn block_path(dir: &Path, worker: WorkerId, epoch: u32) -> PathBuf {
    dir.join(format!("sched_w{worker}_e{epoch}.rgnb"))
}

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn write_u32_slice(w: &mut impl Write, v: &[u32]) -> Result<()> {
    // bulk byte copy — this is the hot path of the precompute writer
    let bytes: Vec<u8> = v.iter().flat_map(|x| x.to_le_bytes()).collect();
    w.write_all(&bytes)?;
    Ok(())
}

fn read_u32_vec(r: &mut impl Read, n: usize) -> Result<Vec<u32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Stream one epoch's schedule to disk.
pub fn write_epoch(dir: &Path, sched: &EpochSchedule) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = block_path(dir, sched.worker, sched.epoch);
    let mut w = BufWriter::new(File::create(&path).context("create metadata block")?);
    w.write_all(MAGIC)?;
    write_u32(&mut w, VERSION)?;
    write_u32(&mut w, sched.worker)?;
    write_u32(&mut w, sched.epoch)?;
    write_u32(&mut w, sched.batches.len() as u32)?;
    for b in &sched.batches {
        write_u32(&mut w, b.batch)?;
        write_u32(&mut w, b.seeds.len() as u32)?;
        write_u32(&mut w, b.input_nodes.len() as u32)?;
        write_u32(&mut w, b.num_remote)?;
        write_u32_slice(&mut w, &b.seeds)?;
        write_u32_slice(&mut w, &b.input_nodes)?;
        let mask_bytes: Vec<u8> = b.remote_mask.iter().flat_map(|x| x.to_le_bytes()).collect();
        w.write_all(&mask_bytes)?;
    }
    w.flush()?;
    Ok(path)
}

/// Streaming reader over one epoch's batches — holds one batch in memory.
pub struct EpochReader {
    r: BufReader<File>,
    /// Worker id recorded in the file header.
    pub worker: WorkerId,
    /// Epoch recorded in the file header.
    pub epoch: u32,
    /// Total batch count.
    pub num_batches: u32,
    next: u32,
}

impl EpochReader {
    /// Open the metadata file for (worker, epoch).
    pub fn open(dir: &Path, worker: WorkerId, epoch: u32) -> Result<Self> {
        let path = block_path(dir, worker, epoch);
        let mut r = BufReader::new(File::open(&path).with_context(|| format!("open {path:?}"))?);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad magic in {path:?}");
        }
        let version = read_u32(&mut r)?;
        if version != VERSION {
            bail!("unsupported block version {version}");
        }
        let fworker = read_u32(&mut r)?;
        let fepoch = read_u32(&mut r)?;
        if fworker != worker || fepoch != epoch {
            bail!("header mismatch: file says w{fworker}/e{fepoch}");
        }
        let num_batches = read_u32(&mut r)?;
        Ok(EpochReader { r, worker, epoch, num_batches, next: 0 })
    }

    /// Read the next batch; `None` once exhausted.
    pub fn next_batch(&mut self) -> Result<Option<BatchMeta>> {
        if self.next >= self.num_batches {
            return Ok(None);
        }
        self.next += 1;
        let batch = read_u32(&mut self.r)?;
        let num_seeds = read_u32(&mut self.r)? as usize;
        let num_inputs = read_u32(&mut self.r)? as usize;
        let num_remote = read_u32(&mut self.r)?;
        let seeds = read_u32_vec(&mut self.r, num_seeds)?;
        let input_nodes = read_u32_vec(&mut self.r, num_inputs)?;
        let mask_len = num_inputs.div_ceil(64);
        let mut mask_bytes = vec![0u8; mask_len * 8];
        self.r.read_exact(&mut mask_bytes)?;
        let remote_mask: Vec<u64> = mask_bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Some(BatchMeta { batch, seeds, input_nodes, remote_mask, num_remote }))
    }
}

/// Read an entire epoch back into memory (tests / cache builder over small
/// epochs; training streams with [`EpochReader`] instead).
pub fn read_epoch(dir: &Path, worker: WorkerId, epoch: u32) -> Result<EpochSchedule> {
    let mut r = EpochReader::open(dir, worker, epoch)?;
    let mut batches = Vec::with_capacity(r.num_batches as usize);
    while let Some(b) = r.next_batch()? {
        batches.push(b);
    }
    Ok(EpochSchedule { worker, epoch, batches })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetPreset};
    use crate::graph::build_dataset;
    use crate::partition::metis_like;
    use crate::sampler::{enumerate_epoch, Fanout};

    fn make_sched() -> EpochSchedule {
        let ds = build_dataset(&DatasetConfig::preset(DatasetPreset::Tiny, 1.0), false);
        let part = metis_like(&ds.graph, 2, 0);
        let shard: Vec<u32> = ds
            .train_nodes
            .iter()
            .copied()
            .filter(|&v| part.is_local(0, v))
            .collect();
        enumerate_epoch(
            &ds.graph,
            &part,
            &shard,
            &[Fanout::Sample(4), Fanout::Sample(3)],
            32,
            11,
            0,
            2,
        )
    }

    #[test]
    fn round_trip_exact() {
        let dir = crate::util::tempdir::TempDir::new("storage").unwrap();
        let sched = make_sched();
        write_epoch(dir.path(), &sched).unwrap();
        let back = read_epoch(dir.path(), 0, 2).unwrap();
        assert_eq!(sched, back);
    }

    #[test]
    fn streaming_reader_matches_bulk() {
        let dir = crate::util::tempdir::TempDir::new("storage").unwrap();
        let sched = make_sched();
        write_epoch(dir.path(), &sched).unwrap();
        let mut r = EpochReader::open(dir.path(), 0, 2).unwrap();
        assert_eq!(r.num_batches as usize, sched.batches.len());
        let mut i = 0;
        while let Some(b) = r.next_batch().unwrap() {
            assert_eq!(b, sched.batches[i]);
            i += 1;
        }
        assert_eq!(i, sched.batches.len());
    }

    #[test]
    fn open_missing_fails() {
        let dir = crate::util::tempdir::TempDir::new("storage").unwrap();
        assert!(EpochReader::open(dir.path(), 9, 9).is_err());
    }

    #[test]
    fn header_mismatch_detected() {
        let dir = crate::util::tempdir::TempDir::new("storage").unwrap();
        let sched = make_sched();
        let path = write_epoch(dir.path(), &sched).unwrap();
        // rename to a wrong (worker, epoch) slot
        let wrong = block_path(dir.path(), 3, 4);
        std::fs::rename(path, wrong).unwrap();
        assert!(EpochReader::open(dir.path(), 3, 4).is_err());
    }

    #[test]
    fn corrupt_magic_detected() {
        let dir = crate::util::tempdir::TempDir::new("storage").unwrap();
        let sched = make_sched();
        let path = write_epoch(dir.path(), &sched).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] = b'X';
        std::fs::write(&path, bytes).unwrap();
        assert!(EpochReader::open(dir.path(), 0, 2).is_err());
    }
}
