//! # RapidGNN
//!
//! A reproduction of *RapidGNN: Energy and Communication-Efficient Distributed
//! Training on Large-Scale Graph Neural Networks* (Niam, Kosar, Nine — SC2025
//! Sustainable Supercomputing Workshop).
//!
//! RapidGNN attacks the feature-communication bottleneck of sampling-based
//! distributed GNN training with three coordinated mechanisms:
//!
//! 1. **Deterministic precomputed sampling** — a seeded hash
//!    `s_{e,i}^{(w)} = H(s0, w, e, i)` drives the k-hop neighbor sampler so the
//!    full batch schedule (and therefore every remote feature access) is known
//!    before training starts ([`sampler`]).
//! 2. **Hot-set feature cache** — remote nodes are ranked by access frequency
//!    over the precomputed schedule; the top-`n_hot` are pulled in one
//!    vectorized RPC into a double-buffered steady cache ([`cache`]).
//! 3. **Rolling asynchronous prefetcher** — a background worker stages the next
//!    `Q` batches into a bounded queue, hiding residual misses off the critical
//!    path ([`prefetch`]).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//! rust coordination (this crate) → JAX GraphSAGE train step (AOT-lowered at
//! build time, `python/compile/`) → Pallas aggregation kernel. The compiled
//! HLO artifacts are executed from rust through PJRT ([`runtime`]).
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-reproduction results.

// Zero unsafe blocks exist in this tree (audited PR 8); keep it that way —
// determinism auditing (Miri/TSan jobs, rapidgnn-lint) assumes safe Rust.
#![forbid(unsafe_code)]

pub mod cache;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod graph;
pub mod kvstore;
pub mod metrics;
pub mod net;
pub mod partition;
pub mod prefetch;
pub mod runtime;
pub mod sampler;
pub mod sim;
pub mod storage;
pub mod trace;
pub mod trainer;
pub mod tui;
pub mod util;

/// Node identifier within a graph (global id space).
pub type NodeId = u32;
/// Worker / partition identifier.
pub type WorkerId = u32;
/// Epoch index (0-based internally; the paper's `e` is 1-based).
pub type EpochId = u32;
/// Batch index within an epoch.
pub type BatchId = u32;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
