//! RapidGNN CLI — the launcher for training runs, engine comparisons, and
//! diagnostics.
//!
//! ```text
//! rapidgnn train   [--config run.toml] [--dataset tiny] [--engine rapid] ...
//! rapidgnn compare [--dataset products-sim] [--batch-size 1000] ...
//! rapidgnn partition-stats [--dataset tiny] [--workers 4]
//! rapidgnn tune    [--dataset tiny]
//! rapidgnn top     [--report run.json | --trace trace.jsonl | <run flags>]
//! rapidgnn bench-diff [--results DIR] [--baselines DIR] [--tolerance F]
//! rapidgnn info
//! ```
//!
//! `--engine` accepts any id in the `EngineRegistry` (`rapidgnn help` lists
//! them); `compare` iterates the whole registry.
//!
//! Flag parsing is hand-rolled (this build environment has no clap); every
//! flag has the form `--name value`. The single source of truth for the
//! flag surface is [`FLAG_DOCS`]: `help` renders it, and `dispatch` rejects
//! any flag the invoked command's scopes don't list — a handler cannot read
//! a flag that isn't documented there.

#![forbid(unsafe_code)]

use anyhow::{bail, Context};
use rapidgnn::config::{
    load_run_config, save_run_config, DatasetConfig, DatasetPreset, Engine, RunConfig, Topology,
};
use rapidgnn::coordinator::{self, EngineRegistry};
use rapidgnn::graph::{build_dataset, degree_stats};
use rapidgnn::partition::{partition_quality, Partitioner};
use rapidgnn::util::bench::{fmt_bytes, fmt_secs, Table};
use rapidgnn::Result;
use std::collections::BTreeMap;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    let scopes: Option<&[&str]> = match cmd.as_str() {
        "train" => Some(&["common", "train"][..]),
        "compare" | "partition-stats" | "tune" => Some(&["common"][..]),
        "top" => Some(&["common", "top"][..]),
        "bench-diff" => Some(&["bench-diff"][..]),
        "info" => Some(&[][..]),
        _ => None, // help / unknown command — handled below
    };
    if let Some(scopes) = scopes {
        check_flags(scopes, &flags)?;
    }
    match cmd.as_str() {
        "train" => cmd_train(&flags),
        "compare" => cmd_compare(&flags),
        "partition-stats" => cmd_partition_stats(&flags),
        "tune" => cmd_tune(&flags),
        "top" => cmd_top(&flags),
        "bench-diff" => cmd_bench_diff(&flags),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!(
            "unknown command '{other}' \
             (train|compare|partition-stats|tune|top|bench-diff|info)"
        ),
    }
}

/// Bare flag key of a [`FLAG_DOCS`] syntax column (`"--codec C"` → `codec`).
fn flag_key(syntax: &str) -> &str {
    syntax.trim_start_matches("--").split(' ').next().unwrap_or("")
}

/// Reject any provided flag the invoked command's scopes don't document.
fn check_flags(scopes: &[&str], flags: &Flags) -> Result<()> {
    for key in flags.keys() {
        let known = FLAG_DOCS
            .iter()
            .any(|(scope, syntax, _)| scopes.contains(scope) && flag_key(syntax) == key);
        if !known {
            bail!("unknown flag --{key} for this command (see `rapidgnn help`)");
        }
    }
    Ok(())
}

/// Every `--flag` the CLI understands, one row per flag:
/// `(command scope, syntax, help)`. Embedded newlines in the help continue
/// on an aligned line. This table is the single source of truth for the
/// flag surface: `print_usage` renders it, `check_flags` rejects flags a
/// command's scopes don't list, and the `flag_docs_*` tests pin it against
/// the keys the handlers actually read.
const FLAG_DOCS: &[(&str, &str, &str)] = &[
    ("common", "--config PATH", "load a TOML run config (other flags override it)"),
    ("common", "--dataset NAME", "tiny | reddit-sim | products-sim | papers-sim"),
    ("common", "--scale F", "dataset node-count scale factor (default 1.0)"),
    ("common", "--engine NAME", "any registered engine id (see ENGINES above)"),
    ("common", "--workers P", "number of workers / partitions"),
    ("common", "--batch-size N", "seeds per mini-batch"),
    ("common", "--epochs E", "training epochs"),
    ("common", "--n-hot H", "hot-set cache size"),
    ("common", "--q Q", "prefetch window depth"),
    ("common", "--fanout A,B", "per-layer fan-outs (innermost first)"),
    ("common", "--exec MODE", "trace | full | wallclock"),
    ("common", "--backend B", "host | pjrt (full mode)"),
    ("common", "--seed S", "base seed s0"),
    ("common", "--topology T", "flat | two-tier | ring | star | fat-tree | dragonfly"),
    (
        "common",
        "--contention [B]",
        "shared-link queueing instead of the linear RPC price\n\
         (bare flag = true; emits per-link utilization telemetry)",
    ),
    ("common", "--racks N", "two-tier rack count (default 2)"),
    ("common", "--oversubscription F", "two-tier spine oversubscription (default 4)"),
    ("common", "--hub W", "star hub worker (default 0)"),
    ("common", "--fat-k K", "fat-tree pod count (default 4)"),
    ("common", "--groups G", "dragonfly group count (default 2)"),
    ("common", "--routers R", "dragonfly routers per group (default 2)"),
    (
        "common",
        "--resample-period K",
        "fast-sample: re-enumerate the schedule every K epochs",
    ),
    ("common", "--fetch-window W", "green-window: batches merged per windowed fetch"),
    (
        "common",
        "--resize-period K",
        "adaptive-cache: evaluate the resize controller every K\n\
         epoch boundaries (0 = never, which is exactly `rapid`)",
    ),
    ("common", "--min-hot N", "adaptive-cache n_hot lower clamp"),
    ("common", "--max-hot N", "adaptive-cache n_hot upper clamp"),
    ("common", "--target-hit-rate F", "adaptive-cache: grow below this hit rate"),
    (
        "common",
        "--tail-utility F",
        "adaptive-cache: shrink when the hot set's marginal\n\
         quarter serves under this fraction of remote accesses",
    ),
    ("common", "--hot-growth F", "adaptive-cache resize factor"),
    ("common", "--hysteresis N", "adaptive-cache flip-flop damping"),
    (
        "common",
        "--codec C",
        "default | none | f16 | int8 — feature wire codec\n\
         (quant-pull defaults to int8; every other engine to none;\n\
         an explicit f16/int8 composes with any engine)",
    ),
    ("common", "--codec-block N", "int8 quantization block size in elements (default 128)"),
    (
        "common",
        "--grad-k F",
        "grad-topk: fraction of gradient coordinates applied per\n\
         step, in (0,1]; 0 disables (exactly `rapid`)",
    ),
    ("common", "--grad-mode M", "topk | randk — gradient coordinate selector"),
    (
        "common",
        "--failures SPEC",
        "deterministic failure plan, comma-separated events at\n\
         epoch boundaries: leave:W@E | join:W@E | linkdown:A-B@E\n\
         | linkup:A-B@E | crash@E (e.g. \"leave:1@2,crash@3\")",
    ),
    ("common", "--checkpoint-every K", "write a checkpoint every K epoch boundaries"),
    ("common", "--checkpoint-dir P", "where checkpoints go (default: run metadata dir)"),
    ("train", "--save-config PATH", "write the effective config to a TOML file and exit"),
    (
        "train",
        "--restore PATH",
        "resume a run from a checkpoint file (ignores the other\n\
         config flags — the checkpoint carries the config)",
    ),
    (
        "train",
        "--trace-out PATH",
        "write the virtual-time trace journal as JSONL\n\
         (replayable offline via `rapidgnn top --trace PATH`)",
    ),
    ("train", "--json PATH", "write the run report as JSON"),
    ("top", "--report PATH", "render the dashboard from a RunReport JSON (offline)"),
    ("top", "--trace PATH", "replay the dashboard from a trace JSONL (offline)"),
    ("top", "--width N", "dashboard frame width in columns (default 100)"),
    (
        "bench-diff",
        "--results DIR",
        "fresh bench artifacts (fig4.json, table2.json;\n\
         default bench_results)",
    ),
    (
        "bench-diff",
        "--baselines DIR",
        "committed BENCH_fig4.json / BENCH_table2.json\n\
         (default: current directory)",
    ),
    ("bench-diff", "--tolerance F", "relative tolerance band (default 0.15)"),
    ("bench-diff", "--out PATH", "write the diff summary as JSON"),
];

fn print_usage() {
    let engines = EngineRegistry::global().ids().collect::<Vec<_>>().join(" | ");
    println!(
        "RapidGNN — communication-efficient distributed GNN training (paper reproduction)

USAGE: rapidgnn <command> [--flag value]...

COMMANDS
  train             run one engine and print the run report
  compare           run every registered engine, print Table-2-style speedups
  partition-stats   partition quality for a dataset (METIS-like vs random)
  tune              recommend n_hot from the access-frequency distribution
  top               dashboard for a run (live replay, --report, or --trace)
  bench-diff        gate fresh bench artifacts against committed baselines
  info              artifact + platform diagnostics

ENGINES
  {engines}"
    );
    for (scope, title) in [
        ("common", "COMMON FLAGS (train / compare / partition-stats / tune / top)"),
        ("train", "TRAIN FLAGS"),
        ("top", "TOP FLAGS"),
        ("bench-diff", "BENCH-DIFF FLAGS"),
    ] {
        println!("\n{title}");
        for (s, syntax, help) in FLAG_DOCS {
            if *s != scope {
                continue;
            }
            let mut lines = help.split('\n');
            println!("  {syntax:<21}{}", lines.next().unwrap_or(""));
            for cont in lines {
                println!("  {:<21}{}", "", cont.trim_start());
            }
        }
    }
}

type Flags = BTreeMap<String, String>;

/// Flags that may appear bare (no value ⇒ "true"), e.g. `--contention`.
const BOOL_FLAGS: [&str; 1] = ["contention"];

fn parse_flags(args: &[String]) -> Result<Flags> {
    let mut flags = Flags::new();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        let Some(name) = a.strip_prefix("--") else {
            bail!("expected --flag, got '{a}'");
        };
        let v = if BOOL_FLAGS.contains(&name)
            && it.peek().map_or(true, |next| next.starts_with("--"))
        {
            "true".to_string()
        } else {
            it.next()
                .with_context(|| format!("flag --{name} needs a value"))?
                .clone()
        };
        flags.insert(name.to_string(), v);
    }
    Ok(flags)
}

fn parse_bool(name: &str, v: &str) -> Result<bool> {
    match v {
        "true" | "on" | "1" | "yes" => Ok(true),
        "false" | "off" | "0" | "no" => Ok(false),
        other => bail!("flag --{name}: expected true|false, got '{other}'"),
    }
}

/// Build a RunConfig from `--config` + flag overrides.
fn config_from_flags(flags: &Flags) -> Result<RunConfig> {
    let mut cfg = match flags.get("config") {
        Some(p) => load_run_config(std::path::Path::new(p))?,
        None => RunConfig::default(),
    };
    if let Some(d) = flags.get("dataset") {
        let preset: DatasetPreset = d.parse()?;
        let scale: f64 = flags.get("scale").map_or(Ok(1.0), |s| s.parse())?;
        cfg.dataset = DatasetConfig::preset(preset, scale);
    } else if let Some(s) = flags.get("scale") {
        cfg.dataset = cfg.dataset.scaled(s.parse()?);
    }
    if let Some(v) = flags.get("engine") {
        cfg.engine = v.parse()?;
    }
    if let Some(v) = flags.get("workers") {
        cfg.num_workers = v.parse()?;
    }
    if let Some(v) = flags.get("batch-size") {
        cfg.batch_size = v.parse()?;
    }
    if let Some(v) = flags.get("epochs") {
        cfg.epochs = v.parse()?;
    }
    if let Some(v) = flags.get("n-hot") {
        cfg.n_hot = v.parse()?;
    }
    if let Some(v) = flags.get("q") {
        cfg.prefetch_q = v.parse()?;
    }
    if let Some(v) = flags.get("fanout") {
        cfg.fanout = v
            .split(',')
            .map(|x| x.trim().parse().context("fanout entry"))
            .collect::<Result<Vec<u32>>>()?;
    }
    if let Some(v) = flags.get("exec") {
        cfg.exec_mode = v.parse()?;
    }
    if let Some(v) = flags.get("backend") {
        cfg.backend = v.parse()?;
    }
    if let Some(v) = flags.get("seed") {
        cfg.base_seed = v.parse()?;
    }
    {
        let opt_u32 = |key: &str, default: u32| -> Result<u32> {
            flags.get(key).map_or(Ok(default), |s| s.parse().context("topology knob"))
        };
        let opt_f64 = |key: &str, default: f64| -> Result<f64> {
            flags.get(key).map_or(Ok(default), |s| s.parse().context("topology knob"))
        };
        // With --topology, build the named preset (knobs override its
        // defaults). Without it, knobs refine whatever topology the config
        // file (or the default) selected. Either way, a knob the final
        // topology cannot use errors rather than being silently dropped.
        cfg.fabric.topology = match flags.get("topology").map(String::as_str) {
            Some("flat") => Topology::Flat,
            Some("two-tier") => Topology::TwoTier {
                racks: opt_u32("racks", 2)?,
                oversubscription: opt_f64("oversubscription", 4.0)?,
            },
            Some("ring") => Topology::Ring,
            Some("star") => Topology::Star { hub: opt_u32("hub", 0)? },
            Some("fat-tree") => Topology::FatTree { k: opt_u32("fat-k", 4)? },
            Some("dragonfly") => Topology::Dragonfly {
                groups: opt_u32("groups", 2)?,
                routers: opt_u32("routers", 2)?,
            },
            Some(other) => bail!(
                "unknown topology '{other}' (flat|two-tier|ring|star|fat-tree|dragonfly)"
            ),
            None => match cfg.fabric.topology {
                Topology::TwoTier { racks, oversubscription } => Topology::TwoTier {
                    racks: opt_u32("racks", racks)?,
                    oversubscription: opt_f64("oversubscription", oversubscription)?,
                },
                Topology::Star { hub } => Topology::Star { hub: opt_u32("hub", hub)? },
                Topology::FatTree { k } => Topology::FatTree { k: opt_u32("fat-k", k)? },
                Topology::Dragonfly { groups, routers } => Topology::Dragonfly {
                    groups: opt_u32("groups", groups)?,
                    routers: opt_u32("routers", routers)?,
                },
                topo @ (Topology::Flat | Topology::Ring) => topo,
            },
        };
        let used: &[&str] = match cfg.fabric.topology {
            Topology::TwoTier { .. } => &["racks", "oversubscription"],
            Topology::Star { .. } => &["hub"],
            Topology::FatTree { .. } => &["fat-k"],
            Topology::Dragonfly { .. } => &["groups", "routers"],
            Topology::Flat | Topology::Ring => &[],
        };
        const KNOBS: [&str; 6] =
            ["racks", "oversubscription", "hub", "fat-k", "groups", "routers"];
        if let Some(k) = KNOBS
            .iter()
            .find(|k| flags.contains_key(**k) && !used.contains(*k))
        {
            bail!(
                "--{k} has no effect on the '{}' topology",
                cfg.fabric.topology.id()
            );
        }
    }
    if let Some(v) = flags.get("contention") {
        cfg.fabric.contention = parse_bool("contention", v)?;
    }
    if let Some(v) = flags.get("resample-period") {
        cfg.engine_params.resample_period = v.parse()?;
    }
    if let Some(v) = flags.get("fetch-window") {
        cfg.engine_params.fetch_window = v.parse()?;
    }
    if let Some(v) = flags.get("resize-period") {
        cfg.engine_params.resize_period = v.parse()?;
    }
    if let Some(v) = flags.get("min-hot") {
        cfg.engine_params.min_hot = v.parse()?;
    }
    if let Some(v) = flags.get("max-hot") {
        cfg.engine_params.max_hot = v.parse()?;
    }
    if let Some(v) = flags.get("target-hit-rate") {
        cfg.engine_params.target_hit_rate = v.parse()?;
    }
    if let Some(v) = flags.get("tail-utility") {
        cfg.engine_params.tail_utility = v.parse()?;
    }
    if let Some(v) = flags.get("hot-growth") {
        cfg.engine_params.hot_growth = v.parse()?;
    }
    if let Some(v) = flags.get("hysteresis") {
        cfg.engine_params.hysteresis = v.parse()?;
    }
    if let Some(v) = flags.get("codec") {
        cfg.engine_params.codec = v.parse()?;
    }
    if let Some(v) = flags.get("codec-block") {
        cfg.engine_params.codec_block = v.parse()?;
    }
    if let Some(v) = flags.get("grad-k") {
        cfg.engine_params.grad_k = v.parse()?;
    }
    if let Some(v) = flags.get("grad-mode") {
        cfg.engine_params.grad_mode = v.parse()?;
    }
    if let Some(v) = flags.get("failures") {
        cfg.failures = v.clone();
    }
    if let Some(v) = flags.get("checkpoint-every") {
        cfg.checkpoint_every = v.parse()?;
    }
    if let Some(v) = flags.get("checkpoint-dir") {
        cfg.checkpoint_dir = v.clone();
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_train(flags: &Flags) -> Result<()> {
    let report = if let Some(p) = flags.get("restore") {
        if flags.contains_key("trace-out") {
            bail!("--trace-out does not compose with --restore (resume replays without a sink)");
        }
        println!("restore: resuming from checkpoint {p}");
        coordinator::resume_run(std::path::Path::new(p))?
    } else {
        let cfg = config_from_flags(flags)?;
        if let Some(p) = flags.get("save-config") {
            save_run_config(&cfg, std::path::Path::new(p))?;
            println!("wrote {p}");
            return Ok(());
        }
        println!(
            "train: {} on {} | P={} batch={} epochs={} n_hot={} Q={} mode={:?}",
            cfg.engine.name(),
            cfg.dataset.name,
            cfg.num_workers,
            cfg.batch_size,
            cfg.epochs,
            cfg.n_hot,
            cfg.prefetch_q,
            cfg.exec_mode,
        );
        if let Some(tp) = flags.get("trace-out") {
            let trace = rapidgnn::trace::TraceHandle::new();
            let report = coordinator::RunBuilder::new(cfg).with_trace(trace.clone()).run()?;
            trace.write_jsonl(std::path::Path::new(tp))?;
            let dropped = trace.dropped();
            println!("trace journal written to {tp} ({} records)", trace.len());
            if dropped > 0 {
                println!("(ring capacity exceeded: {dropped} oldest records dropped)");
            }
            report
        } else {
            coordinator::run(&cfg)?
        }
    };
    let mut t = Table::new(
        &format!("{} / {}", report.engine, report.dataset),
        &["epoch", "time", "fetch", "compute", "MB moved", "hit rate", "loss", "acc"],
    );
    let mut by_epoch: BTreeMap<u32, Vec<&rapidgnn::metrics::EpochReport>> = BTreeMap::new();
    for e in &report.epochs {
        by_epoch.entry(e.epoch).or_default().push(e);
    }
    let epochs: Vec<u32> = by_epoch.keys().copied().collect();
    for &ep in &epochs {
        let group = &by_epoch[&ep];
        let n = group.len() as f64;
        let avg = |f: &dyn Fn(&rapidgnn::metrics::EpochReport) -> f64| -> f64 {
            group.iter().map(|e| f(e)).sum::<f64>() / n
        };
        let hits: u64 = group.iter().map(|e| e.cache.hits).sum();
        let lookups: u64 = group.iter().map(|e| e.cache.lookups).sum();
        t.row(&[
            ep.to_string(),
            fmt_secs(avg(&|e| e.epoch_time)),
            fmt_secs(avg(&|e| e.phases.fetch)),
            fmt_secs(avg(&|e| e.phases.compute)),
            fmt_bytes(avg(&|e| e.comm.bytes as f64)),
            if lookups > 0 {
                format!("{:.1}%", 100.0 * hits as f64 / lookups as f64)
            } else {
                "-".into()
            },
            format!("{:.3}", avg(&|e| e.mean_loss)),
            format!("{:.3}", avg(&|e| e.train_acc)),
        ]);
    }
    t.print();
    if report.epochs.iter().any(|e| e.cache_plan.is_some()) {
        let mut ct = Table::new(
            "Adaptive hot-cache (controller telemetry)",
            &["epoch", "n_hot", "hits", "misses", "hit rate", "resizes"],
        );
        for &ep in &epochs {
            let plans: Vec<_> = by_epoch[&ep].iter().filter_map(|e| e.cache_plan).collect();
            if plans.is_empty() {
                continue;
            }
            let hits: u64 = plans.iter().map(|p| p.hits).sum();
            let misses: u64 = plans.iter().map(|p| p.misses).sum();
            let lo = plans.iter().map(|p| p.n_hot).min().unwrap();
            let hi = plans.iter().map(|p| p.n_hot).max().unwrap();
            let resizes = plans.iter().map(|p| p.resize_events).max().unwrap();
            ct.row(&[
                ep.to_string(),
                if lo == hi {
                    lo.to_string()
                } else {
                    format!("{lo}-{hi}")
                },
                hits.to_string(),
                misses.to_string(),
                if hits + misses > 0 {
                    format!("{:.1}%", 100.0 * hits as f64 / (hits + misses) as f64)
                } else {
                    "-".into()
                },
                resizes.to_string(),
            ]);
        }
        ct.print();
    }
    println!(
        "total {} (+{} setup) | {:.0} J CPU, {:.0} J GPU | {} remote rows",
        fmt_secs(report.total_time),
        fmt_secs(report.setup_time),
        report.cpu_energy_j,
        report.gpu_energy_j,
        report.total_remote_rows(),
    );
    if !report.links.is_empty() {
        let mut links = report.links.clone();
        links.sort_by(|a, b| b.busy_sec.total_cmp(&a.busy_sec));
        let mut lt = Table::new(
            "Per-link utilization (contention mode, busiest first)",
            &["link", "busy", "served", "util", "peak flows", "peak backlog"],
        );
        for l in links.iter().take(12) {
            lt.row(&[
                l.link.clone(),
                fmt_secs(l.busy_sec),
                fmt_bytes(l.served_bytes),
                format!("{:.0}%", 100.0 * l.utilization()),
                l.peak_flows.to_string(),
                fmt_bytes(l.peak_backlog_bytes),
            ]);
        }
        lt.print();
        if links.len() > 12 {
            println!("({} more links in the JSON report)", links.len() - 12);
        }
    }
    if let Some(c) = &report.compression {
        println!(
            "compression: codec={} | {} -> {} ({:.2}x, {} saved) | quant MSE {:.3e} | grads {}/{} coords",
            c.codec,
            fmt_bytes(c.uncompressed_bytes as f64),
            fmt_bytes(c.compressed_bytes as f64),
            c.effective_compression_ratio,
            fmt_bytes(c.bytes_saved as f64),
            c.quant_mse,
            c.grad_elems_sent,
            c.grad_elems_total,
        );
    }
    if let Some(r) = &report.recovery {
        println!(
            "recovery: {} events ({} leave, {} join, {} down, {} up, {} crash) | {} checkpoints | {} rows / {} moved ({} detoured) | {} moving, {} lost to rollbacks",
            r.events,
            r.worker_leaves,
            r.worker_joins,
            r.link_downs,
            r.link_ups,
            r.crash_restarts,
            r.checkpoints_written,
            r.moved_rows,
            fmt_bytes(r.moved_bytes as f64),
            fmt_bytes(r.rerouted_bytes as f64),
            fmt_secs(r.recovery_time),
            fmt_secs(r.lost_work_time),
        );
    }
    if let Some(cal) = &report.calibration {
        let mut ct = Table::new(
            &format!("Calibration (backend {}, virtual vs wall-clock)", cal.backend),
            &["epoch", "modeled net", "measured wall", "measured bytes", "rpcs"],
        );
        for e in &cal.epochs {
            ct.row(&[
                e.epoch.to_string(),
                fmt_secs(e.modeled_net_sec),
                fmt_secs(e.measured_wall_sec),
                fmt_bytes(e.measured_bytes as f64),
                e.rpcs.to_string(),
            ]);
        }
        ct.print();
        println!(
            "calibration: {} links | {} payload moved in {} wall ({} modeled net)",
            cal.links.len(),
            fmt_bytes(cal.epochs.iter().map(|e| e.measured_bytes).sum::<u64>() as f64),
            fmt_secs(cal.run_wall_sec),
            fmt_secs(cal.epochs.iter().map(|e| e.modeled_net_sec).sum::<f64>()),
        );
    }
    if let Some(p) = flags.get("json") {
        std::fs::write(p, report.to_json())?;
        println!("report written to {p}");
    }
    Ok(())
}

fn cmd_compare(flags: &Flags) -> Result<()> {
    let base = config_from_flags(flags)?;
    let mut t = Table::new(
        &format!(
            "Engine comparison — {} (P={}, batch={})",
            base.dataset.name, base.num_workers, base.batch_size
        ),
        &["engine", "step time", "net/step", "MB/step", "step x", "net x", "CPU J"],
    );
    let mut rapid_step = 0.0;
    let mut rapid_net = 0.0;
    let mut rows = Vec::new();
    // The comparison set is the registry, not a hard-coded list: a newly
    // registered engine shows up here with no CLI changes.
    for engine in EngineRegistry::global().engines() {
        let mut cfg = base.clone();
        cfg.engine = engine;
        let report = coordinator::run(&cfg)?;
        if engine == Engine::Rapid {
            rapid_step = report.mean_step_time();
            rapid_net = report.mean_net_time_per_step();
        }
        rows.push((engine, report));
    }
    for (engine, report) in rows {
        let step = report.mean_step_time();
        let net = report.mean_net_time_per_step();
        t.row(&[
            engine.name().into(),
            fmt_secs(step),
            fmt_secs(net),
            fmt_bytes(report.mean_bytes_per_step()),
            format!("{:.2}", step / rapid_step),
            if rapid_net > 0.0 {
                format!("{:.2}", net / rapid_net)
            } else {
                "-".into()
            },
            format!("{:.0}", report.cpu_energy_j),
        ]);
    }
    t.print();
    println!("(x columns: this engine's cost relative to RapidGNN — the paper's speedup)");
    Ok(())
}

fn cmd_partition_stats(flags: &Flags) -> Result<()> {
    let cfg = config_from_flags(flags)?;
    let ds = build_dataset(&cfg.dataset, false);
    let stats = degree_stats(&ds.graph);
    println!(
        "{}: {} nodes, {} directed edges | degree mean {:.1} p50 {} p99 {} max {} | top-1% mass {:.1}%",
        cfg.dataset.name,
        ds.graph.num_nodes(),
        ds.graph.num_directed_edges(),
        stats.mean,
        stats.p50,
        stats.p99,
        stats.max,
        stats.top1pct_mass * 100.0
    );
    let mut t = Table::new(
        &format!("Partition quality (P={})", cfg.num_workers),
        &["algorithm", "edge cut", "balance", "remote nbr frac", "mean halo"],
    );
    for (name, which) in [("metis-like", Partitioner::MetisLike), ("random", Partitioner::Random)] {
        let p = rapidgnn::partition::partition(&ds.graph, cfg.num_workers, which, cfg.base_seed);
        let q = partition_quality(&ds.graph, &p);
        t.row(&[
            name.into(),
            format!("{:.1}%", q.edge_cut_fraction * 100.0),
            format!("{:.3}", q.balance),
            format!("{:.3}", q.remote_neighbor_fraction),
            format!("{:.0}", q.mean_halo),
        ]);
    }
    t.print();
    Ok(())
}

/// Recommend cache sizes from one precomputed epoch's frequency profile —
/// automates the paper's Fig-5 "practical cache-size selection".
fn cmd_tune(flags: &Flags) -> Result<()> {
    let mut cfg = config_from_flags(flags)?;
    cfg.engine = Engine::Rapid;
    let ctx = rapidgnn::coordinator::RunContext::build(&cfg)?;
    rapidgnn::coordinator::precompute(&ctx, 0)?;
    let freq = rapidgnn::coordinator::epoch_remote_frequency(&ctx, 0, 0)?;
    let total: u64 = freq.iter().map(|&(_, c)| c as u64).sum();
    println!(
        "{}: {} distinct remote nodes, {} accesses in epoch 0 (worker 0)",
        cfg.dataset.name,
        freq.len(),
        total
    );
    let mut t = Table::new(
        "Recommended n_hot by access-coverage target",
        &["coverage", "n_hot", "device MB (2 buffers)"],
    );
    let sched = rapidgnn::storage::read_epoch(&ctx.metadata_path, 0, 0)?;
    for coverage in [0.5f64, 0.7, 0.8, 0.9, 0.95] {
        let k = rapidgnn::cache::recommend_n_hot(&sched.batches, coverage);
        let mb = 2.0 * k as f64 * cfg.dataset.feature_dim as f64 * 4.0 / 1e6;
        t.row(&[
            format!("{:.0}%", coverage * 100.0),
            k.to_string(),
            format!("{mb:.1}"),
        ]);
    }
    t.print();
    Ok(())
}

/// `rapidgnn top` — render the observability dashboard. Three sources:
/// `--report run.json` (offline, from a RunReport), `--trace trace.jsonl`
/// (offline, from a `--trace-out` journal), or run flags (executes the run
/// on the virtual clock, then replays it frame by frame — workers share no
/// real-time epoch barrier, so "live" is replay-on-completion by design).
/// On a terminal the replay animates in place with ANSI styling; piped
/// output gets one plain final frame (what the CI smoke job asserts on).
fn cmd_top(flags: &Flags) -> Result<()> {
    use rapidgnn::metrics::RunReport;
    use rapidgnn::tui::App;
    use rapidgnn::util::value::Value;
    let width: usize = flags.get("width").map_or(Ok(100), |s| s.parse())?;
    let app = if let Some(p) = flags.get("report") {
        let v = Value::from_json(&std::fs::read_to_string(p)?)?;
        App::from_report(RunReport::from_value(&v)?)
    } else if let Some(p) = flags.get("trace") {
        let records = rapidgnn::trace::parse_jsonl(&std::fs::read_to_string(p)?)?;
        App::from_trace_records(&records)?
    } else {
        let cfg = config_from_flags(flags)?;
        App::from_report(coordinator::run(&cfg)?)
    };
    render_dashboard(&app, width)
}

/// Render an [`rapidgnn::tui::App`]: animated epoch-by-epoch ANSI replay on
/// a terminal, a single plain final frame otherwise.
fn render_dashboard(app: &rapidgnn::tui::App, width: usize) -> Result<()> {
    use std::io::{IsTerminal, Write};
    let stdout = std::io::stdout();
    if stdout.is_terminal() {
        if let Some(last) = app.last_epoch() {
            for epoch in 0..=last {
                let frame = app.through_epoch(epoch).render(width);
                // clear + home, then the styled frame
                let mut out = stdout.lock();
                write!(out, "\x1b[2J\x1b[H{}\r\n", frame.render_ansi())?;
                out.flush()?;
                std::thread::sleep(std::time::Duration::from_millis(120));
            }
            return Ok(());
        }
    }
    println!("{}", app.render(width).render_plain());
    Ok(())
}

/// `rapidgnn bench-diff` — gate fresh bench artifacts against the committed
/// `BENCH_*.json` baselines. Exit status: 0 within the tolerance band (or
/// bootstrap — no baseline committed yet), nonzero on any breach.
fn cmd_bench_diff(flags: &Flags) -> Result<()> {
    use rapidgnn::metrics::baseline::{diff_tables, DiffSummary, DEFAULT_TOLERANCE};
    use rapidgnn::util::value::Value;
    let results = flags.get("results").map_or("bench_results", String::as_str);
    let baselines = flags.get("baselines").map_or(".", String::as_str);
    let tolerance: f64 = flags.get("tolerance").map_or(Ok(DEFAULT_TOLERANCE), |s| s.parse())?;
    let mut summary = DiffSummary::new(tolerance);
    let mut compared = 0usize;
    for table in ["fig4", "table2"] {
        let base_path = std::path::Path::new(baselines).join(format!("BENCH_{table}.json"));
        let fresh_path = std::path::Path::new(results).join(format!("{table}.json"));
        if !base_path.is_file() {
            println!(
                "bench-diff: no baseline {} — skipping {table} (bootstrap)",
                base_path.display()
            );
            continue;
        }
        if !fresh_path.is_file() {
            bail!(
                "bench-diff: baseline {} exists but fresh artifact {} is missing",
                base_path.display(),
                fresh_path.display()
            );
        }
        let base = Value::from_json(&std::fs::read_to_string(&base_path)?)?;
        let fresh = Value::from_json(&std::fs::read_to_string(&fresh_path)?)?;
        diff_tables(&mut summary, table, &base, &fresh)?;
        compared += 1;
    }
    if compared == 0 {
        println!("bench-diff: nothing compared (no baselines committed yet)");
        return Ok(());
    }
    let mut t = Table::new(
        &format!("Bench baseline diff (tolerance ±{:.0}%)", tolerance * 100.0),
        &["table", "cell", "metric", "baseline", "fresh", "delta", "status"],
    );
    for e in &summary.entries {
        let sign = if e.fresh >= e.baseline { "+" } else { "-" };
        t.row(&[
            e.table.clone(),
            e.cell.clone(),
            e.metric.clone(),
            format!("{:.6}", e.baseline),
            format!("{:.6}", e.fresh),
            format!("{sign}{:.1}%", e.rel * 100.0),
            if e.breach { "BREACH" } else { "ok" }.into(),
        ]);
    }
    t.print();
    for c in &summary.missing_cells {
        println!("missing cell (regression): {c}");
    }
    for c in &summary.new_cells {
        println!("new cell (no baseline yet): {c}");
    }
    if let Some(p) = flags.get("out") {
        std::fs::write(p, summary.to_value().to_json_pretty())?;
        println!("diff summary written to {p}");
    }
    if summary.breached() {
        bail!(
            "bench-diff: {} breach(es) outside the ±{:.0}% band",
            summary.breaches().count() + summary.missing_cells.len(),
            tolerance * 100.0
        );
    }
    println!(
        "bench-diff: {} metric(s) within the ±{:.0}% band",
        summary.entries.len(),
        tolerance * 100.0
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("RapidGNN {} — three-layer rust+JAX+Pallas reproduction", env!("CARGO_PKG_VERSION"));
    let dir = rapidgnn::runtime::artifacts_dir();
    println!("artifacts dir: {dir:?}");
    let mut found = 0;
    if dir.is_dir() {
        for entry in std::fs::read_dir(&dir)? {
            let p = entry?.path();
            if p.to_string_lossy().ends_with(".meta.json") {
                let m = rapidgnn::runtime::ArtifactMeta::load(&p)?;
                println!(
                    "  {} — d={} h={} c={} fanout=[{},{}] caps=({},{},{})",
                    p.file_name().unwrap().to_string_lossy(),
                    m.d,
                    m.h,
                    m.c,
                    m.f1,
                    m.f2,
                    m.b_cap,
                    m.n1_cap,
                    m.n0_cap
                );
                found += 1;
            }
        }
    }
    if found == 0 {
        println!("  (none — run `make artifacts`)");
    }
    #[cfg(feature = "xla")]
    match xla::PjRtClient::cpu() {
        Ok(c) => println!("PJRT: {} ({} devices)", c.platform_name(), c.device_count()),
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    #[cfg(not(feature = "xla"))]
    println!("PJRT unavailable: built without the `xla` cargo feature");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags(pairs: &[(&str, &str)]) -> Flags {
        pairs.iter().map(|&(k, v)| (k.to_string(), v.to_string())).collect()
    }

    #[test]
    fn flag_docs_cover_every_handled_flag() {
        // Every flag key the command handlers read, by hand — update this
        // list and FLAG_DOCS together when adding a flag.
        const HANDLED: &[&str] = &[
            // config_from_flags
            "config", "dataset", "scale", "engine", "workers", "batch-size", "epochs",
            "n-hot", "q", "fanout", "exec", "backend", "seed", "topology", "racks",
            "oversubscription", "hub", "fat-k", "groups", "routers", "contention",
            "resample-period", "fetch-window", "resize-period", "min-hot", "max-hot",
            "target-hit-rate", "tail-utility", "hot-growth", "hysteresis", "codec",
            "codec-block", "grad-k", "grad-mode", "failures", "checkpoint-every",
            "checkpoint-dir",
            // cmd_train
            "save-config", "restore", "trace-out", "json",
            // cmd_top
            "report", "trace", "width",
            // cmd_bench_diff
            "results", "baselines", "tolerance", "out",
        ];
        let documented: std::collections::BTreeSet<&str> =
            FLAG_DOCS.iter().map(|(_, syntax, _)| flag_key(syntax)).collect();
        for key in HANDLED {
            assert!(documented.contains(key), "--{key} is handled but missing from FLAG_DOCS");
        }
        for key in &documented {
            assert!(HANDLED.contains(key), "--{key} is documented but no handler reads it");
        }
        assert_eq!(documented.len(), FLAG_DOCS.len(), "duplicate flag keys in FLAG_DOCS");
    }

    #[test]
    fn check_flags_rejects_out_of_scope_flags() {
        let bench = flags(&[("results", "bench_results")]);
        assert!(check_flags(&["common", "train"], &bench).is_err());
        assert!(check_flags(&["bench-diff"], &bench).is_ok());
        assert!(check_flags(&["common"], &flags(&[("epochs", "2")])).is_ok());
        assert!(check_flags(&[], &flags(&[("epochs", "2")])).is_err());
    }

    #[test]
    fn parse_flags_pairs() {
        let args: Vec<String> = ["--a", "1", "--b", "two"].iter().map(|s| s.to_string()).collect();
        let f = parse_flags(&args).unwrap();
        assert_eq!(f["a"], "1");
        assert_eq!(f["b"], "two");
    }

    #[test]
    fn parse_flags_rejects_bare_and_dangling() {
        assert!(parse_flags(&["bare".to_string()]).is_err());
        assert!(parse_flags(&["--x".to_string()]).is_err());
    }

    #[test]
    fn contention_flag_parses_bare_and_with_value() {
        let bare: Vec<String> =
            ["--contention", "--epochs", "2"].iter().map(|s| s.to_string()).collect();
        let f = parse_flags(&bare).unwrap();
        assert_eq!(f["contention"], "true");
        assert_eq!(f["epochs"], "2");
        let trailing: Vec<String> = ["--contention"].iter().map(|s| s.to_string()).collect();
        assert_eq!(parse_flags(&trailing).unwrap()["contention"], "true");
        let explicit: Vec<String> =
            ["--contention", "false"].iter().map(|s| s.to_string()).collect();
        assert_eq!(parse_flags(&explicit).unwrap()["contention"], "false");
        let cfg = config_from_flags(&flags(&[("contention", "true")])).unwrap();
        assert!(cfg.fabric.contention);
        assert!(config_from_flags(&flags(&[("contention", "maybe")])).is_err());
    }

    #[test]
    fn topology_flags_select_presets() {
        use rapidgnn::config::Topology;
        let cfg = config_from_flags(&flags(&[("topology", "fat-tree")])).unwrap();
        assert_eq!(cfg.fabric.topology, Topology::FatTree { k: 4 });
        let cfg = config_from_flags(&flags(&[("topology", "fat-tree"), ("fat-k", "8")])).unwrap();
        assert_eq!(cfg.fabric.topology, Topology::FatTree { k: 8 });
        let cfg = config_from_flags(&flags(&[
            ("topology", "dragonfly"),
            ("groups", "3"),
            ("routers", "2"),
        ]))
        .unwrap();
        assert_eq!(cfg.fabric.topology, Topology::Dragonfly { groups: 3, routers: 2 });
        let cfg = config_from_flags(&flags(&[
            ("topology", "two-tier"),
            ("oversubscription", "8"),
        ]))
        .unwrap();
        assert_eq!(
            cfg.fabric.topology,
            Topology::TwoTier { racks: 2, oversubscription: 8.0 }
        );
        let cfg = config_from_flags(&flags(&[("topology", "ring")])).unwrap();
        assert_eq!(cfg.fabric.topology, Topology::Ring);
        assert!(config_from_flags(&flags(&[("topology", "torus")])).is_err());
    }

    #[test]
    fn topology_knobs_refine_config_selected_topology_or_error() {
        use rapidgnn::config::Topology;
        // a knob without --topology on the default flat fabric must not be
        // silently dropped
        let err = config_from_flags(&flags(&[("oversubscription", "16")]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("oversubscription"), "{err}");
        // same for an explicit preset that lacks the knob
        assert!(config_from_flags(&flags(&[
            ("topology", "flat"),
            ("oversubscription", "8"),
        ]))
        .is_err());
        assert!(config_from_flags(&flags(&[("topology", "two-tier"), ("hub", "1")])).is_err());
        // but it refines a config file whose topology already uses it
        let dir = rapidgnn::util::tempdir::TempDir::new("cli-topo").unwrap();
        let path = dir.path().join("run.toml");
        let mut base = RunConfig::default();
        base.fabric.topology = Topology::TwoTier { racks: 2, oversubscription: 4.0 };
        save_run_config(&base, &path).unwrap();
        let cfg = config_from_flags(&flags(&[
            ("config", path.to_str().unwrap()),
            ("oversubscription", "16"),
        ]))
        .unwrap();
        assert_eq!(
            cfg.fabric.topology,
            Topology::TwoTier { racks: 2, oversubscription: 16.0 }
        );
    }

    #[test]
    fn config_from_flags_overrides() {
        let f = flags(&[
            ("dataset", "products-sim"),
            ("scale", "0.1"),
            ("engine", "dist-gcn"),
            ("workers", "3"),
            ("batch-size", "64"),
            ("epochs", "5"),
            ("n-hot", "123"),
            ("q", "7"),
            ("fanout", "4,9"),
            ("exec", "full"),
            ("backend", "host"),
            ("seed", "99"),
            ("resample-period", "6"),
            ("fetch-window", "3"),
            ("resize-period", "2"),
            ("min-hot", "16"),
            ("max-hot", "2048"),
            ("target-hit-rate", "0.9"),
            ("tail-utility", "0.02"),
            ("hot-growth", "1.5"),
            ("hysteresis", "3"),
            ("codec", "f16"),
            ("codec-block", "64"),
            ("grad-k", "0.25"),
            ("grad-mode", "randk"),
        ]);
        let cfg = config_from_flags(&f).unwrap();
        assert_eq!(cfg.dataset.name, "products-sim");
        assert_eq!(cfg.dataset.num_nodes, 12_000);
        assert_eq!(cfg.engine, Engine::DistGcn);
        assert_eq!(cfg.num_workers, 3);
        assert_eq!(cfg.batch_size, 64);
        assert_eq!(cfg.epochs, 5);
        assert_eq!(cfg.n_hot, 123);
        assert_eq!(cfg.prefetch_q, 7);
        assert_eq!(cfg.fanout, vec![4, 9]);
        assert_eq!(cfg.base_seed, 99);
        assert_eq!(cfg.engine_params.resample_period, 6);
        assert_eq!(cfg.engine_params.fetch_window, 3);
        assert_eq!(cfg.engine_params.resize_period, 2);
        assert_eq!(cfg.engine_params.min_hot, 16);
        assert_eq!(cfg.engine_params.max_hot, 2048);
        assert!((cfg.engine_params.target_hit_rate - 0.9).abs() < 1e-12);
        assert!((cfg.engine_params.tail_utility - 0.02).abs() < 1e-12);
        assert!((cfg.engine_params.hot_growth - 1.5).abs() < 1e-12);
        assert_eq!(cfg.engine_params.hysteresis, 3);
        assert_eq!(cfg.engine_params.codec, rapidgnn::compress::Codec::F16);
        assert_eq!(cfg.engine_params.codec_block, 64);
        assert!((cfg.engine_params.grad_k - 0.25).abs() < 1e-12);
        assert_eq!(cfg.engine_params.grad_mode, rapidgnn::compress::GradMode::RandK);
    }

    #[test]
    fn failure_flags_parse_and_validate() {
        let cfg = config_from_flags(&flags(&[
            ("failures", "leave:1@2,crash@3"),
            ("checkpoint-every", "2"),
            ("checkpoint-dir", "/tmp/ckpts"),
            ("epochs", "4"),
        ]))
        .unwrap();
        assert_eq!(cfg.failures, "leave:1@2,crash@3");
        assert_eq!(cfg.checkpoint_every, 2);
        assert_eq!(cfg.checkpoint_dir, "/tmp/ckpts");
        assert!(cfg.has_recovery());
        // a malformed or out-of-range plan is rejected at validate time
        assert!(config_from_flags(&flags(&[("failures", "explode@1")])).is_err());
        assert!(config_from_flags(&flags(&[("failures", "leave:1@99")])).is_err());
    }

    #[test]
    fn compression_flags_reject_bad_values() {
        assert!(config_from_flags(&flags(&[("codec", "gzip")])).is_err());
        assert!(config_from_flags(&flags(&[("codec-block", "0")])).is_err());
        assert!(config_from_flags(&flags(&[("grad-k", "1.5")])).is_err());
        assert!(config_from_flags(&flags(&[("grad-mode", "topj")])).is_err());
    }

    #[test]
    fn registry_engine_ids_parse_from_flags() {
        for id in EngineRegistry::global().ids() {
            let cfg = config_from_flags(&flags(&[("engine", id)])).unwrap();
            assert_eq!(cfg.engine.id(), id);
        }
    }

    #[test]
    fn config_from_flags_rejects_bad_values() {
        assert!(config_from_flags(&flags(&[("engine", "nope")])).is_err());
        assert!(config_from_flags(&flags(&[("workers", "0")])).is_err());
        assert!(config_from_flags(&flags(&[("fanout", "a,b")])).is_err());
    }

    #[test]
    fn config_file_plus_override_round_trip() {
        let dir = rapidgnn::util::tempdir::TempDir::new("cli").unwrap();
        let path = dir.path().join("run.toml");
        let mut base = RunConfig::default();
        base.batch_size = 77;
        save_run_config(&base, &path).unwrap();
        let f = flags(&[("config", path.to_str().unwrap()), ("epochs", "9")]);
        let cfg = config_from_flags(&f).unwrap();
        assert_eq!(cfg.batch_size, 77, "from file");
        assert_eq!(cfg.epochs, 9, "flag override");
    }
}
