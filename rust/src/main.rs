//! RapidGNN CLI — the launcher for training runs, engine comparisons, and
//! diagnostics.
//!
//! ```text
//! rapidgnn train   [--config run.toml] [--dataset tiny] [--engine rapid] ...
//! rapidgnn compare [--dataset products-sim] [--batch-size 1000] ...
//! rapidgnn partition-stats [--dataset tiny] [--workers 4]
//! rapidgnn tune    [--dataset tiny]
//! rapidgnn info
//! ```
//!
//! `--engine` accepts any id in the `EngineRegistry` (`rapidgnn help` lists
//! them); `compare` iterates the whole registry.
//!
//! Flag parsing is hand-rolled (this build environment has no clap); every
//! flag has the form `--name value`.

use anyhow::{bail, Context};
use rapidgnn::config::{
    load_run_config, save_run_config, DatasetConfig, DatasetPreset, Engine, RunConfig,
};
use rapidgnn::coordinator::{self, EngineRegistry};
use rapidgnn::graph::{build_dataset, degree_stats};
use rapidgnn::partition::{partition_quality, Partitioner};
use rapidgnn::util::bench::{fmt_bytes, fmt_secs, Table};
use rapidgnn::Result;
use std::collections::HashMap;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    match cmd.as_str() {
        "train" => cmd_train(&flags),
        "compare" => cmd_compare(&flags),
        "partition-stats" => cmd_partition_stats(&flags),
        "tune" => cmd_tune(&flags),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (train|compare|partition-stats|tune|info)"),
    }
}

fn print_usage() {
    let engines = EngineRegistry::global().ids().collect::<Vec<_>>().join(" | ");
    println!(
        "RapidGNN — communication-efficient distributed GNN training (paper reproduction)

USAGE: rapidgnn <command> [--flag value]...

COMMANDS
  train             run one engine and print the run report
  compare           run every registered engine, print Table-2-style speedups
  partition-stats   partition quality for a dataset (METIS-like vs random)
  tune              recommend n_hot from the access-frequency distribution
  info              artifact + platform diagnostics

COMMON FLAGS
  --config PATH     load a TOML run config (other flags override it)
  --save-config P   write the effective config to a TOML file and exit
  --dataset NAME    tiny | reddit-sim | products-sim | papers-sim
  --scale F         dataset node-count scale factor (default 1.0)
  --engine NAME     {engines}
  --workers P       number of workers / partitions
  --batch-size N    seeds per mini-batch
  --epochs E        training epochs
  --n-hot H         hot-set cache size
  --q Q             prefetch window depth
  --fanout A,B      per-layer fan-outs (innermost first)
  --exec MODE       trace | full
  --backend B       host | pjrt (full mode)
  --seed S          base seed s0
  --resample-period K   fast-sample: re-enumerate the schedule every K epochs
  --fetch-window W  green-window: batches merged per windowed fetch
  --json PATH       write the run report as JSON"
    );
}

type Flags = HashMap<String, String>;

fn parse_flags(args: &[String]) -> Result<Flags> {
    let mut flags = Flags::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let Some(name) = a.strip_prefix("--") else {
            bail!("expected --flag, got '{a}'");
        };
        let v = it
            .next()
            .with_context(|| format!("flag --{name} needs a value"))?;
        flags.insert(name.to_string(), v.clone());
    }
    Ok(flags)
}

/// Build a RunConfig from `--config` + flag overrides.
fn config_from_flags(flags: &Flags) -> Result<RunConfig> {
    let mut cfg = match flags.get("config") {
        Some(p) => load_run_config(std::path::Path::new(p))?,
        None => RunConfig::default(),
    };
    if let Some(d) = flags.get("dataset") {
        let preset: DatasetPreset = d.parse()?;
        let scale: f64 = flags.get("scale").map_or(Ok(1.0), |s| s.parse())?;
        cfg.dataset = DatasetConfig::preset(preset, scale);
    } else if let Some(s) = flags.get("scale") {
        cfg.dataset = cfg.dataset.scaled(s.parse()?);
    }
    if let Some(v) = flags.get("engine") {
        cfg.engine = v.parse()?;
    }
    if let Some(v) = flags.get("workers") {
        cfg.num_workers = v.parse()?;
    }
    if let Some(v) = flags.get("batch-size") {
        cfg.batch_size = v.parse()?;
    }
    if let Some(v) = flags.get("epochs") {
        cfg.epochs = v.parse()?;
    }
    if let Some(v) = flags.get("n-hot") {
        cfg.n_hot = v.parse()?;
    }
    if let Some(v) = flags.get("q") {
        cfg.prefetch_q = v.parse()?;
    }
    if let Some(v) = flags.get("fanout") {
        cfg.fanout = v
            .split(',')
            .map(|x| x.trim().parse().context("fanout entry"))
            .collect::<Result<Vec<u32>>>()?;
    }
    if let Some(v) = flags.get("exec") {
        cfg.exec_mode = v.parse()?;
    }
    if let Some(v) = flags.get("backend") {
        cfg.backend = v.parse()?;
    }
    if let Some(v) = flags.get("seed") {
        cfg.base_seed = v.parse()?;
    }
    if let Some(v) = flags.get("resample-period") {
        cfg.engine_params.resample_period = v.parse()?;
    }
    if let Some(v) = flags.get("fetch-window") {
        cfg.engine_params.fetch_window = v.parse()?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_train(flags: &Flags) -> Result<()> {
    let cfg = config_from_flags(flags)?;
    if let Some(p) = flags.get("save-config") {
        save_run_config(&cfg, std::path::Path::new(p))?;
        println!("wrote {p}");
        return Ok(());
    }
    println!(
        "train: {} on {} | P={} batch={} epochs={} n_hot={} Q={} mode={:?}",
        cfg.engine.name(),
        cfg.dataset.name,
        cfg.num_workers,
        cfg.batch_size,
        cfg.epochs,
        cfg.n_hot,
        cfg.prefetch_q,
        cfg.exec_mode,
    );
    let report = coordinator::run(&cfg)?;
    let mut t = Table::new(
        &format!("{} / {}", report.engine, report.dataset),
        &["epoch", "time", "fetch", "compute", "MB moved", "hit rate", "loss", "acc"],
    );
    let mut by_epoch: HashMap<u32, Vec<&rapidgnn::metrics::EpochReport>> = HashMap::new();
    for e in &report.epochs {
        by_epoch.entry(e.epoch).or_default().push(e);
    }
    let mut epochs: Vec<u32> = by_epoch.keys().copied().collect();
    epochs.sort_unstable();
    for ep in epochs {
        let group = &by_epoch[&ep];
        let n = group.len() as f64;
        let avg = |f: &dyn Fn(&rapidgnn::metrics::EpochReport) -> f64| -> f64 {
            group.iter().map(|e| f(e)).sum::<f64>() / n
        };
        let hits: u64 = group.iter().map(|e| e.cache.hits).sum();
        let lookups: u64 = group.iter().map(|e| e.cache.lookups).sum();
        t.row(&[
            ep.to_string(),
            fmt_secs(avg(&|e| e.epoch_time)),
            fmt_secs(avg(&|e| e.phases.fetch)),
            fmt_secs(avg(&|e| e.phases.compute)),
            fmt_bytes(avg(&|e| e.comm.bytes as f64)),
            if lookups > 0 {
                format!("{:.1}%", 100.0 * hits as f64 / lookups as f64)
            } else {
                "-".into()
            },
            format!("{:.3}", avg(&|e| e.mean_loss)),
            format!("{:.3}", avg(&|e| e.train_acc)),
        ]);
    }
    t.print();
    println!(
        "total {} (+{} setup) | {:.0} J CPU, {:.0} J GPU | {} remote rows",
        fmt_secs(report.total_time),
        fmt_secs(report.setup_time),
        report.cpu_energy_j,
        report.gpu_energy_j,
        report.total_remote_rows(),
    );
    if let Some(p) = flags.get("json") {
        std::fs::write(p, report.to_json())?;
        println!("report written to {p}");
    }
    Ok(())
}

fn cmd_compare(flags: &Flags) -> Result<()> {
    let base = config_from_flags(flags)?;
    let mut t = Table::new(
        &format!(
            "Engine comparison — {} (P={}, batch={})",
            base.dataset.name, base.num_workers, base.batch_size
        ),
        &["engine", "step time", "net/step", "MB/step", "step x", "net x", "CPU J"],
    );
    let mut rapid_step = 0.0;
    let mut rapid_net = 0.0;
    let mut rows = Vec::new();
    // The comparison set is the registry, not a hard-coded list: a newly
    // registered engine shows up here with no CLI changes.
    for engine in EngineRegistry::global().engines() {
        let mut cfg = base.clone();
        cfg.engine = engine;
        let report = coordinator::run(&cfg)?;
        if engine == Engine::Rapid {
            rapid_step = report.mean_step_time();
            rapid_net = report.mean_net_time_per_step();
        }
        rows.push((engine, report));
    }
    for (engine, report) in rows {
        let step = report.mean_step_time();
        let net = report.mean_net_time_per_step();
        t.row(&[
            engine.name().into(),
            fmt_secs(step),
            fmt_secs(net),
            fmt_bytes(report.mean_bytes_per_step()),
            format!("{:.2}", step / rapid_step),
            if rapid_net > 0.0 {
                format!("{:.2}", net / rapid_net)
            } else {
                "-".into()
            },
            format!("{:.0}", report.cpu_energy_j),
        ]);
    }
    t.print();
    println!("(x columns: this engine's cost relative to RapidGNN — the paper's speedup)");
    Ok(())
}

fn cmd_partition_stats(flags: &Flags) -> Result<()> {
    let cfg = config_from_flags(flags)?;
    let ds = build_dataset(&cfg.dataset, false);
    let stats = degree_stats(&ds.graph);
    println!(
        "{}: {} nodes, {} directed edges | degree mean {:.1} p50 {} p99 {} max {} | top-1% mass {:.1}%",
        cfg.dataset.name,
        ds.graph.num_nodes(),
        ds.graph.num_directed_edges(),
        stats.mean,
        stats.p50,
        stats.p99,
        stats.max,
        stats.top1pct_mass * 100.0
    );
    let mut t = Table::new(
        &format!("Partition quality (P={})", cfg.num_workers),
        &["algorithm", "edge cut", "balance", "remote nbr frac", "mean halo"],
    );
    for (name, which) in [("metis-like", Partitioner::MetisLike), ("random", Partitioner::Random)] {
        let p = rapidgnn::partition::partition(&ds.graph, cfg.num_workers, which, cfg.base_seed);
        let q = partition_quality(&ds.graph, &p);
        t.row(&[
            name.into(),
            format!("{:.1}%", q.edge_cut_fraction * 100.0),
            format!("{:.3}", q.balance),
            format!("{:.3}", q.remote_neighbor_fraction),
            format!("{:.0}", q.mean_halo),
        ]);
    }
    t.print();
    Ok(())
}

/// Recommend cache sizes from one precomputed epoch's frequency profile —
/// automates the paper's Fig-5 "practical cache-size selection".
fn cmd_tune(flags: &Flags) -> Result<()> {
    let mut cfg = config_from_flags(flags)?;
    cfg.engine = Engine::Rapid;
    let ctx = rapidgnn::coordinator::RunContext::build(&cfg)?;
    rapidgnn::coordinator::precompute(&ctx, 0)?;
    let freq = rapidgnn::coordinator::epoch_remote_frequency(&ctx, 0, 0)?;
    let total: u64 = freq.iter().map(|&(_, c)| c as u64).sum();
    println!(
        "{}: {} distinct remote nodes, {} accesses in epoch 0 (worker 0)",
        cfg.dataset.name,
        freq.len(),
        total
    );
    let mut t = Table::new(
        "Recommended n_hot by access-coverage target",
        &["coverage", "n_hot", "device MB (2 buffers)"],
    );
    let sched = rapidgnn::storage::read_epoch(&ctx.metadata_path, 0, 0)?;
    for coverage in [0.5f64, 0.7, 0.8, 0.9, 0.95] {
        let k = rapidgnn::cache::recommend_n_hot(&sched.batches, coverage);
        let mb = 2.0 * k as f64 * cfg.dataset.feature_dim as f64 * 4.0 / 1e6;
        t.row(&[
            format!("{:.0}%", coverage * 100.0),
            k.to_string(),
            format!("{mb:.1}"),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("RapidGNN {} — three-layer rust+JAX+Pallas reproduction", env!("CARGO_PKG_VERSION"));
    let dir = rapidgnn::runtime::artifacts_dir();
    println!("artifacts dir: {dir:?}");
    let mut found = 0;
    if dir.is_dir() {
        for entry in std::fs::read_dir(&dir)? {
            let p = entry?.path();
            if p.to_string_lossy().ends_with(".meta.json") {
                let m = rapidgnn::runtime::ArtifactMeta::load(&p)?;
                println!(
                    "  {} — d={} h={} c={} fanout=[{},{}] caps=({},{},{})",
                    p.file_name().unwrap().to_string_lossy(),
                    m.d,
                    m.h,
                    m.c,
                    m.f1,
                    m.f2,
                    m.b_cap,
                    m.n1_cap,
                    m.n0_cap
                );
                found += 1;
            }
        }
    }
    if found == 0 {
        println!("  (none — run `make artifacts`)");
    }
    #[cfg(feature = "xla")]
    match xla::PjRtClient::cpu() {
        Ok(c) => println!("PJRT: {} ({} devices)", c.platform_name(), c.device_count()),
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    #[cfg(not(feature = "xla"))]
    println!("PJRT unavailable: built without the `xla` cargo feature");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags(pairs: &[(&str, &str)]) -> Flags {
        pairs.iter().map(|&(k, v)| (k.to_string(), v.to_string())).collect()
    }

    #[test]
    fn parse_flags_pairs() {
        let args: Vec<String> = ["--a", "1", "--b", "two"].iter().map(|s| s.to_string()).collect();
        let f = parse_flags(&args).unwrap();
        assert_eq!(f["a"], "1");
        assert_eq!(f["b"], "two");
    }

    #[test]
    fn parse_flags_rejects_bare_and_dangling() {
        assert!(parse_flags(&["bare".to_string()]).is_err());
        assert!(parse_flags(&["--x".to_string()]).is_err());
    }

    #[test]
    fn config_from_flags_overrides() {
        let f = flags(&[
            ("dataset", "products-sim"),
            ("scale", "0.1"),
            ("engine", "dist-gcn"),
            ("workers", "3"),
            ("batch-size", "64"),
            ("epochs", "5"),
            ("n-hot", "123"),
            ("q", "7"),
            ("fanout", "4,9"),
            ("exec", "full"),
            ("backend", "host"),
            ("seed", "99"),
            ("resample-period", "6"),
            ("fetch-window", "3"),
        ]);
        let cfg = config_from_flags(&f).unwrap();
        assert_eq!(cfg.dataset.name, "products-sim");
        assert_eq!(cfg.dataset.num_nodes, 12_000);
        assert_eq!(cfg.engine, Engine::DistGcn);
        assert_eq!(cfg.num_workers, 3);
        assert_eq!(cfg.batch_size, 64);
        assert_eq!(cfg.epochs, 5);
        assert_eq!(cfg.n_hot, 123);
        assert_eq!(cfg.prefetch_q, 7);
        assert_eq!(cfg.fanout, vec![4, 9]);
        assert_eq!(cfg.base_seed, 99);
        assert_eq!(cfg.engine_params.resample_period, 6);
        assert_eq!(cfg.engine_params.fetch_window, 3);
    }

    #[test]
    fn registry_engine_ids_parse_from_flags() {
        for id in EngineRegistry::global().ids() {
            let cfg = config_from_flags(&flags(&[("engine", id)])).unwrap();
            assert_eq!(cfg.engine.id(), id);
        }
    }

    #[test]
    fn config_from_flags_rejects_bad_values() {
        assert!(config_from_flags(&flags(&[("engine", "nope")])).is_err());
        assert!(config_from_flags(&flags(&[("workers", "0")])).is_err());
        assert!(config_from_flags(&flags(&[("fanout", "a,b")])).is_err());
    }

    #[test]
    fn config_file_plus_override_round_trip() {
        let dir = rapidgnn::util::tempdir::TempDir::new("cli").unwrap();
        let path = dir.path().join("run.toml");
        let mut base = RunConfig::default();
        base.batch_size = 77;
        save_run_config(&base, &path).unwrap();
        let f = flags(&[("config", path.to_str().unwrap()), ("epochs", "9")]);
        let cfg = config_from_flags(&f).unwrap();
        assert_eq!(cfg.batch_size, 77, "from file");
        assert_eq!(cfg.epochs, 9, "flag override");
    }
}
