//! K-hop uniform neighbor sampling (GraphSAGE-style mini-batch blocks).
//!
//! Given seed nodes and per-layer fan-outs, expands outward layer by layer
//! exactly like DGL's `MultiLayerNeighborSampler`: layer `K` holds the seeds,
//! each outer layer holds the union of the previous layer's nodes and their
//! sampled neighbors, and layer `0` is the batch's input-node set `N_i^e`
//! whose features must be materialized.
//!
//! Both entry points have `*_scratch` variants threaded through a
//! [`SamplerScratch`] arena so the precompute pass ([`super::schedule`])
//! reuses the visited bitmap and frontier buffers across batches instead of
//! reallocating them per batch. The scratch variants walk the PRNG in
//! exactly the same order and produce byte-identical output (pinned by
//! `scratch_reuse_is_stateless`).

use super::seed::Rng;
use crate::graph::CsrGraph;
use crate::util::fasthash::IdHashMap;
use crate::NodeId;

/// Sentinel marking an absent neighbor slot (node had fewer neighbors than
/// the fan-out, or no neighbors at all). The trainer masks these out.
pub const NO_NEIGHBOR: u32 = u32::MAX;

/// Per-layer fan-out policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fanout {
    /// Sample up to `k` distinct neighbors uniformly (GraphSAGE).
    Sample(u32),
    /// Take the full neighborhood, capped at `cap` (Dist-GCN baseline).
    FullCapped(u32),
}

impl Fanout {
    /// Maximum neighbor slots this policy can produce.
    pub fn width(&self) -> u32 {
        match *self {
            Fanout::Sample(k) => k,
            Fanout::FullCapped(c) => c,
        }
    }
}

/// One message-passing layer of a sampled batch: maps a `src` node list
/// (outer layer) to a `dst` node list (inner layer).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerBlock {
    /// Neighbor slots per dst node.
    pub fanout: u32,
    /// Number of dst nodes.
    pub num_dst: u32,
    /// `self_idx[d]` = position of dst node `d` in the src node list.
    pub self_idx: Vec<u32>,
    /// `nbr_idx[d*fanout + j]` = position of the j-th sampled neighbor of dst
    /// node `d` in the src list, or [`NO_NEIGHBOR`].
    pub nbr_idx: Vec<u32>,
}

/// A fully sampled mini-batch: node lists per layer plus the blocks that
/// connect them. `node_layers[0]` is the input-node set; the last entry
/// holds the seeds.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SampledBatch {
    /// Node ids per layer, outermost (input) first.
    pub node_layers: Vec<Vec<NodeId>>,
    /// Blocks: `blocks[l]` maps `node_layers[l]` → `node_layers[l+1]`.
    pub blocks: Vec<LayerBlock>,
}

impl SampledBatch {
    /// The batch's input nodes `N_i^e` (features required).
    pub fn input_nodes(&self) -> &[NodeId] {
        &self.node_layers[0]
    }

    /// The seed nodes.
    pub fn seeds(&self) -> &[NodeId] {
        self.node_layers.last().unwrap()
    }
}

/// Reusable sampler state (§Perf): the per-batch allocations of
/// [`sample_input_nodes`] / [`sample_blocks`] — visited bitmap, frontier,
/// neighbor scratch, hub-path position picks — pooled so the steady-state
/// precompute path allocates nothing per batch beyond its output. One
/// scratch per thread: the parallel enumeration keeps one in a thread-local
/// pool (see `schedule::enumerate_epoch_threads`).
#[derive(Default)]
pub struct SamplerScratch {
    /// Visited bitmap over node ids, grown lazily to the graph and reset
    /// sparsely after each batch (only the words touched by the batch).
    seen: Vec<u64>,
    /// Frontier node list of the layer being expanded.
    current: Vec<NodeId>,
    /// Per-node sampled-neighbor scratch.
    nbrs: Vec<NodeId>,
    /// Position scratch for the hub sampling path.
    picked: Vec<u32>,
}

impl SamplerScratch {
    /// Fresh scratch; buffers grow on first use.
    pub fn new() -> SamplerScratch {
        SamplerScratch::default()
    }

    /// Grow the visited bitmap to cover node ids `0..n`.
    fn ensure(&mut self, n: u32) {
        let words = (n as usize).div_ceil(64);
        if self.seen.len() < words {
            self.seen.resize(words, 0);
        }
    }
}

/// Test-and-set of node `v` on a visited bitmap: returns true if `v` was
/// already present; marks it either way. (Bitmap dedup keeps the per-layer
/// sort over the much smaller unique set — see EXPERIMENTS.md §Perf.)
#[inline]
fn test_and_set(bits: &mut [u64], v: NodeId) -> bool {
    let (w, b) = ((v / 64) as usize, v % 64);
    let hit = (bits[w] >> b) & 1 == 1;
    bits[w] |= 1 << b;
    hit
}

/// Sparse bitmap reset: every node marked during a batch is in `uniq`
/// exactly once, so zeroing those nodes' words restores an all-clear map.
#[inline]
fn clear_seen(bits: &mut [u64], uniq: &[NodeId]) {
    for &v in uniq {
        bits[(v / 64) as usize] = 0;
    }
}

/// Sample up to `k` distinct neighbors of `v` uniformly into `out`.
#[inline]
fn sample_neighbors(
    g: &CsrGraph,
    v: NodeId,
    policy: Fanout,
    rng: &mut Rng,
    out: &mut Vec<NodeId>,
    picked: &mut Vec<u32>,
) {
    out.clear();
    let nbrs = g.neighbors(v);
    match policy {
        Fanout::FullCapped(cap) => {
            if nbrs.len() <= cap as usize {
                out.extend_from_slice(nbrs);
            } else {
                // Uniform without replacement via rejection on positions —
                // cap << deg in the regime this branch runs.
                sample_distinct_positions(nbrs, cap, rng, out, picked);
            }
        }
        Fanout::Sample(k) => {
            if nbrs.len() <= k as usize {
                out.extend_from_slice(nbrs);
            } else {
                sample_distinct_positions(nbrs, k, rng, out, picked);
            }
        }
    }
}

/// Draw `k` distinct positions from `nbrs` by rejection (k << |nbrs| here).
fn sample_distinct_positions(
    nbrs: &[NodeId],
    k: u32,
    rng: &mut Rng,
    out: &mut Vec<NodeId>,
    picked: &mut Vec<u32>,
) {
    debug_assert!((k as usize) < nbrs.len());
    let n = nbrs.len() as u32;
    if n <= 128 {
        // §Perf fast path: membership test as a u128 bitmask — covers the
        // vast majority of nodes in power-law graphs (only hubs exceed it).
        let mut mask: u128 = 0;
        let mut taken = 0;
        while taken < k {
            let pos = rng.below(n);
            let bit = 1u128 << pos;
            if mask & bit == 0 {
                mask |= bit;
                taken += 1;
                out.push(nbrs[pos as usize]);
            }
        }
        return;
    }
    // Hub path: k ≤ 64 ≪ n, collisions rare; linear scan of picks.
    picked.clear();
    while picked.len() < k as usize {
        let pos = rng.below(n);
        if !picked.contains(&pos) {
            picked.push(pos);
            out.push(nbrs[pos as usize]);
        }
    }
}

/// Fast path: enumerate only the batch's unique input-node set `N_i^e`.
///
/// This is what the precompute pass runs for every (epoch, batch) — it avoids
/// building index mappings. MUST visit the PRNG in exactly the same order as
/// [`sample_blocks`] so both produce identical node sets for the same seed
/// (verified by `blocks_and_ids_agree`).
pub fn sample_input_nodes(
    g: &CsrGraph,
    seeds: &[NodeId],
    fanouts: &[Fanout],
    rng_seed: u64,
) -> Vec<NodeId> {
    sample_input_nodes_scratch(g, seeds, fanouts, rng_seed, &mut SamplerScratch::new())
}

/// [`sample_input_nodes`] with caller-owned scratch: the only allocation in
/// the steady state is the returned node set itself.
pub fn sample_input_nodes_scratch(
    g: &CsrGraph,
    seeds: &[NodeId],
    fanouts: &[Fanout],
    rng_seed: u64,
    s: &mut SamplerScratch,
) -> Vec<NodeId> {
    if fanouts.is_empty() {
        // No expansion: historical contract returns the seeds as given.
        return seeds.to_vec();
    }
    let mut rng = Rng::new(rng_seed);
    s.ensure(g.num_nodes());
    let mut current = std::mem::take(&mut s.current);
    let mut scratch = std::mem::take(&mut s.nbrs);
    let mut picked = std::mem::take(&mut s.picked);
    current.clear();
    current.extend_from_slice(seeds);
    // Unique-id accumulator in first-seen order; sorted once at the end.
    let mut uniq: Vec<NodeId> = Vec::with_capacity(current.len() * 4);
    for &v in &current {
        if !test_and_set(&mut s.seen, v) {
            uniq.push(v);
        }
    }
    // Expand innermost (seed-adjacent, last fanout) first, like DGL.
    for (li, &policy) in fanouts.iter().rev().enumerate() {
        for &v in &current {
            sample_neighbors(g, v, policy, &mut rng, &mut scratch, &mut picked);
            for &u in &scratch {
                if !test_and_set(&mut s.seen, u) {
                    uniq.push(u);
                }
            }
        }
        if li + 1 == fanouts.len() {
            break;
        }
        // Next frontier: the unique set so far, in sorted id order (same
        // walk as the historical `uniq.clone()` + sort — `uniq` itself must
        // keep first-seen order while it accumulates).
        current.clear();
        current.extend_from_slice(&uniq);
        current.sort_unstable();
    }
    // final layer: sort in place, no clone (§Perf)
    uniq.sort_unstable();
    clear_seen(&mut s.seen, &uniq);
    s.current = current;
    s.nbrs = scratch;
    s.picked = picked;
    uniq
}

/// Full path: sample blocks with index mappings for the trainer.
pub fn sample_blocks(
    g: &CsrGraph,
    seeds: &[NodeId],
    fanouts: &[Fanout],
    rng_seed: u64,
) -> SampledBatch {
    sample_blocks_scratch(g, seeds, fanouts, rng_seed, &mut SamplerScratch::new())
}

/// [`sample_blocks`] with caller-owned scratch (visited bitmap + neighbor
/// buffers reused; the returned batch still owns all of its storage).
pub fn sample_blocks_scratch(
    g: &CsrGraph,
    seeds: &[NodeId],
    fanouts: &[Fanout],
    rng_seed: u64,
    s: &mut SamplerScratch,
) -> SampledBatch {
    let mut rng = Rng::new(rng_seed);
    s.ensure(g.num_nodes());
    let mut scratch = std::mem::take(&mut s.nbrs);
    let mut picked = std::mem::take(&mut s.picked);
    let mut node_layers: Vec<Vec<NodeId>> = vec![seeds.to_vec()];
    // Raw sampled neighbors per layer (dst-order), innermost first.
    let mut raw_nbrs: Vec<Vec<NodeId>> = Vec::new();
    // Same bitmap-dedup scheme as `sample_input_nodes` (identical PRNG walk).
    let mut uniq: Vec<NodeId> = Vec::with_capacity(seeds.len() * 4);
    for &v in seeds {
        if !test_and_set(&mut s.seen, v) {
            uniq.push(v);
        }
    }

    for &policy in fanouts.iter().rev() {
        let current = node_layers.last().unwrap();
        let mut flat: Vec<NodeId> = Vec::with_capacity(current.len() * policy.width() as usize);
        let mut counts: Vec<u32> = Vec::with_capacity(current.len());
        for &v in current {
            sample_neighbors(g, v, policy, &mut rng, &mut scratch, &mut picked);
            counts.push(scratch.len() as u32);
            flat.extend_from_slice(&scratch);
            for &u in &scratch {
                if !test_and_set(&mut s.seen, u) {
                    uniq.push(u);
                }
            }
        }
        let mut next = uniq.clone();
        next.sort_unstable();
        node_layers.push(next);
        // Stash (flat neighbor list + per-dst counts) for block assembly.
        raw_nbrs.push(flat);
        raw_nbrs.push(counts.into_iter().map(|c| c as NodeId).collect());
    }
    clear_seen(&mut s.seen, &uniq);
    s.nbrs = scratch;
    s.picked = picked;

    // node_layers currently: [seeds, layer K-1, ..., layer 0]; reverse so
    // index 0 = input nodes.
    node_layers.reverse();

    // Build blocks: blocks[l] maps node_layers[l] (src) → node_layers[l+1] (dst).
    let num_layers = fanouts.len();
    let mut blocks: Vec<LayerBlock> = Vec::with_capacity(num_layers);
    for l in 0..num_layers {
        let src = &node_layers[l];
        let dst = &node_layers[l + 1];
        let pos: IdHashMap<NodeId, u32> =
            src.iter().enumerate().map(|(i, &v)| (v, i as u32)).collect();
        // raw_nbrs entries were pushed innermost-first: fanouts.rev() order.
        // Layer l (outermost = 0) corresponds to rev index (num_layers-1-l).
        let ri = (num_layers - 1 - l) * 2;
        let flat = &raw_nbrs[ri];
        let counts = &raw_nbrs[ri + 1];
        let fanout = fanouts[l].width();
        let mut self_idx = Vec::with_capacity(dst.len());
        let mut nbr_idx = vec![NO_NEIGHBOR; dst.len() * fanout as usize];
        let mut offset = 0usize;
        for (d, &v) in dst.iter().enumerate() {
            self_idx.push(pos[&v]);
            let cnt = counts[d] as usize;
            for j in 0..cnt {
                nbr_idx[d * fanout as usize + j] = pos[&flat[offset + j]];
            }
            offset += cnt;
        }
        blocks.push(LayerBlock {
            fanout,
            num_dst: dst.len() as u32,
            self_idx,
            nbr_idx,
        });
    }

    SampledBatch { node_layers, blocks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetPreset};
    use crate::graph::build_dataset;
    use std::sync::Arc;

    fn graph() -> Arc<CsrGraph> {
        build_dataset(&DatasetConfig::preset(DatasetPreset::Tiny, 1.0), false).graph
    }

    const F: [Fanout; 2] = [Fanout::Sample(5), Fanout::Sample(3)];

    #[test]
    fn deterministic_in_seed() {
        let g = graph();
        let seeds = [1, 2, 3, 4, 5];
        assert_eq!(
            sample_input_nodes(&g, &seeds, &F, 42),
            sample_input_nodes(&g, &seeds, &F, 42)
        );
        assert_ne!(
            sample_input_nodes(&g, &seeds, &F, 42),
            sample_input_nodes(&g, &seeds, &F, 43)
        );
    }

    #[test]
    fn blocks_and_ids_agree() {
        // The trace path and the full path must sample identically.
        let g = graph();
        let seeds: Vec<NodeId> = (0..64).collect();
        for s in 0..5u64 {
            let ids = sample_input_nodes(&g, &seeds, &F, s);
            let batch = sample_blocks(&g, &seeds, &F, s);
            assert_eq!(ids, batch.node_layers[0], "seed {s}");
        }
    }

    #[test]
    fn scratch_reuse_is_stateless() {
        // One arena reused across many batches must behave exactly like a
        // fresh arena per batch — no state leaks through the bitmap reset.
        let g = graph();
        let mut s = SamplerScratch::new();
        for seed in 0..8u64 {
            let seeds: Vec<NodeId> = (seed as u32 * 3..seed as u32 * 3 + 40).collect();
            assert_eq!(
                sample_input_nodes_scratch(&g, &seeds, &F, seed, &mut s),
                sample_input_nodes(&g, &seeds, &F, seed),
                "input nodes, seed {seed}"
            );
            assert_eq!(
                sample_blocks_scratch(&g, &seeds, &F, seed, &mut s),
                sample_blocks(&g, &seeds, &F, seed),
                "blocks, seed {seed}"
            );
        }
    }

    #[test]
    fn input_nodes_contain_seeds() {
        let g = graph();
        let seeds = [7, 8, 9];
        let ids = sample_input_nodes(&g, &seeds, &F, 0);
        for s in seeds {
            assert!(ids.binary_search(&s).is_ok());
        }
    }

    #[test]
    fn input_nodes_sorted_unique() {
        let g = graph();
        let ids = sample_input_nodes(&g, &(0..100).collect::<Vec<_>>(), &F, 1);
        assert!(ids.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn block_indices_are_valid() {
        let g = graph();
        let seeds: Vec<NodeId> = (10..40).collect();
        let b = sample_blocks(&g, &seeds, &F, 3);
        assert_eq!(b.blocks.len(), 2);
        assert_eq!(b.seeds(), &seeds[..]);
        for l in 0..2 {
            let blk = &b.blocks[l];
            let src_len = b.node_layers[l].len() as u32;
            let dst = &b.node_layers[l + 1];
            assert_eq!(blk.num_dst as usize, dst.len());
            assert_eq!(blk.self_idx.len(), dst.len());
            for (d, &si) in blk.self_idx.iter().enumerate() {
                assert!(si < src_len);
                // self index really points at the same node id
                assert_eq!(b.node_layers[l][si as usize], dst[d]);
            }
            for &ni in &blk.nbr_idx {
                assert!(ni == NO_NEIGHBOR || ni < src_len);
            }
        }
    }

    #[test]
    fn sampled_neighbors_are_real_neighbors() {
        let g = graph();
        let seeds: Vec<NodeId> = (0..20).collect();
        let b = sample_blocks(&g, &seeds, &F, 9);
        for l in 0..b.blocks.len() {
            let blk = &b.blocks[l];
            for d in 0..blk.num_dst as usize {
                let v = b.node_layers[l + 1][d];
                for j in 0..blk.fanout as usize {
                    let ni = blk.nbr_idx[d * blk.fanout as usize + j];
                    if ni != NO_NEIGHBOR {
                        let u = b.node_layers[l][ni as usize];
                        assert!(g.neighbors(v).contains(&u), "{u} not a neighbor of {v}");
                    }
                }
            }
        }
    }

    #[test]
    fn fanout_respected() {
        let g = graph();
        let b = sample_blocks(&g, &[0, 1], &[Fanout::Sample(2)], 5);
        let blk = &b.blocks[0];
        for d in 0..blk.num_dst as usize {
            let v = b.node_layers[1][d];
            let valid = (0..2)
                .filter(|&j| blk.nbr_idx[d * 2 + j] != NO_NEIGHBOR)
                .count();
            assert!(valid as u32 <= 2.min(g.degree(v)));
            // distinct neighbors when sampling without replacement
            if valid == 2 {
                assert_ne!(blk.nbr_idx[d * 2], blk.nbr_idx[d * 2 + 1]);
            }
        }
    }

    #[test]
    fn full_capped_takes_all_small_neighborhoods() {
        let g = CsrGraph::from_edges(4, &[(0, 1), (0, 2), (0, 3)]);
        let b = sample_blocks(&g, &[0], &[Fanout::FullCapped(8)], 1);
        let blk = &b.blocks[0];
        let valid = blk.nbr_idx.iter().filter(|&&x| x != NO_NEIGHBOR).count();
        assert_eq!(valid, 3); // all of node 0's neighbors
        assert_eq!(b.node_layers[0].len(), 4);
    }

    #[test]
    fn zero_degree_seed_survives() {
        let g = CsrGraph::from_edges(3, &[(0, 1)]);
        let b = sample_blocks(&g, &[2], &[Fanout::Sample(4)], 1);
        assert_eq!(b.input_nodes(), &[2]);
        assert!(b.blocks[0].nbr_idx.iter().all(|&x| x == NO_NEIGHBOR));
    }
}
