//! Schedule precomputation: the paper's offline enumeration pass (§3).
//!
//! For worker `w`, epoch `e`: shuffle the worker's training-seed shard with a
//! derived seed, chunk into batches, and run the k-hop expansion for each
//! batch with its own derived seed `H(s0, w, e, i)`. The result — per-batch
//! input-node sets with locality flags — is everything the cache builder and
//! prefetcher need, computed before the first training step.
//!
//! # Parallel-determinism contract
//!
//! The enumeration is embarrassingly parallel *by construction*: batch `i`'s
//! PRNG seed depends only on `(s0, w, e, i)`, never on any other batch, and
//! the epoch shuffle is itself seeded. Batches can therefore be expanded in
//! any order on any number of threads and reassembled by index, and the
//! result is byte-identical to the serial walk — the serial path at
//! `threads = 1` is the reference the identity tests pin against
//! ([`enumerate_epoch_threads`], [`remote_frequency_threads`]). The same
//! holds for the frequency tally: hash-sharding node ids across threads
//! changes only *where* each id is counted; the final
//! (count desc, id asc) sort is a total order over the tallied pairs, so
//! shard and hashmap iteration order cannot leak into the output.
//!
//! Worker threads draw [`SamplerScratch`] arenas from a pool owned by the
//! coordinating thread (lent out per call, persisted across epochs), so the
//! steady-state enumeration allocates only each batch's output.

use super::khop::{sample_input_nodes_scratch, Fanout, SamplerScratch};
use super::seed::{derive_seed, Rng};
use crate::graph::CsrGraph;
use crate::partition::Partition;
use crate::util::fasthash::IdHashMap;
use crate::util::parallel::{available_threads, par_map_threads};
use crate::{NodeId, WorkerId};
use std::cell::RefCell;
use std::sync::Mutex;

thread_local! {
    /// Sampler-arena pool, owned by the *coordinating* thread (the one that
    /// calls [`enumerate_epoch`]). Worker threads are scoped per call, so a
    /// worker-side thread-local would die with them; instead each call lends
    /// the pool to its workers through a mutex and takes it back, so arenas
    /// persist across epochs and the steady-state enumeration allocates
    /// only each batch's output.
    static SCRATCH_POOL: RefCell<Vec<SamplerScratch>> = const { RefCell::new(Vec::new()) };
}

/// Precomputed metadata for one batch (paper §4 "metadata block"): node ids,
/// seed range, and a locality bitmask. No feature values.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchMeta {
    /// Batch index `i` within the epoch.
    pub batch: u32,
    /// Seed nodes of this batch (owned by this worker's partition).
    pub seeds: Vec<NodeId>,
    /// Input-node set `N_i^e`, sorted ascending.
    pub input_nodes: Vec<NodeId>,
    /// Bitmask over `input_nodes`: bit j set ⇒ `input_nodes[j]` is remote.
    pub remote_mask: Vec<u64>,
    /// Number of remote nodes (popcount of `remote_mask`).
    pub num_remote: u32,
}

impl BatchMeta {
    /// Whether input node at position `j` is remote.
    #[inline]
    pub fn is_remote(&self, j: usize) -> bool {
        (self.remote_mask[j / 64] >> (j % 64)) & 1 == 1
    }

    /// Iterate the remote node ids.
    pub fn remote_nodes(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.input_nodes
            .iter()
            .enumerate()
            .filter(|(j, _)| self.is_remote(*j))
            .map(|(_, &v)| v)
    }

    /// Approximate serialized size in bytes (for SSD-streaming accounting).
    pub fn byte_size(&self) -> u64 {
        16 + (self.seeds.len() * 4 + self.input_nodes.len() * 4 + self.remote_mask.len() * 8)
            as u64
    }
}

/// The full precomputed schedule of one (worker, epoch).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EpochSchedule {
    pub worker: WorkerId,
    pub epoch: u32,
    pub batches: Vec<BatchMeta>,
}

impl EpochSchedule {
    /// Max input-node count over batches (the paper's `m_max`).
    pub fn m_max(&self) -> u32 {
        self.batches
            .iter()
            .map(|b| b.input_nodes.len() as u32)
            .max()
            .unwrap_or(0)
    }

    /// Total remote accesses over the epoch.
    pub fn total_remote(&self) -> u64 {
        self.batches.iter().map(|b| b.num_remote as u64).sum()
    }
}

/// Deterministic per-epoch seed-node order for worker `w`: Fisher–Yates
/// shuffle of the worker's train shard, seeded by `H(s0, w, e, SHUFFLE)`.
pub fn epoch_seed_order(shard: &[NodeId], s0: u64, worker: WorkerId, epoch: u32) -> Vec<NodeId> {
    const SHUFFLE_TAG: u32 = u32::MAX;
    let mut order = shard.to_vec();
    let mut rng = Rng::new(derive_seed(s0, worker, epoch, SHUFFLE_TAG));
    for i in (1..order.len()).rev() {
        let j = rng.below(i as u32 + 1) as usize;
        order.swap(i, j);
    }
    order
}

/// Enumerate the full schedule for (worker, epoch): the paper's line 1–2 of
/// Algorithm 1, restricted to one epoch (epochs are enumerated independently
/// so the precompute pass can stream results to disk epoch by epoch).
/// Runs batches on all available cores — see the module docs for why the
/// output is nevertheless deterministic.
#[allow(clippy::too_many_arguments)]
pub fn enumerate_epoch(
    g: &CsrGraph,
    part: &Partition,
    shard: &[NodeId],
    fanouts: &[Fanout],
    batch_size: u32,
    s0: u64,
    worker: WorkerId,
    epoch: u32,
) -> EpochSchedule {
    enumerate_epoch_threads(
        available_threads(),
        g,
        part,
        shard,
        fanouts,
        batch_size,
        s0,
        worker,
        epoch,
    )
}

/// [`enumerate_epoch`] with an explicit thread count (`1` = the serial
/// reference). Output is byte-identical at any thread count: each batch's
/// expansion is seeded by `H(s0, w, e, i)` alone, so batches are
/// order-independent and reassembled in index order.
#[allow(clippy::too_many_arguments)]
pub fn enumerate_epoch_threads(
    threads: usize,
    g: &CsrGraph,
    part: &Partition,
    shard: &[NodeId],
    fanouts: &[Fanout],
    batch_size: u32,
    s0: u64,
    worker: WorkerId,
    epoch: u32,
) -> EpochSchedule {
    let order = epoch_seed_order(shard, s0, worker, epoch);
    let chunks: Vec<&[NodeId]> = order.chunks(batch_size as usize).collect();
    // Lend the caller's arena pool to the scoped workers for this call.
    let pool: Mutex<Vec<SamplerScratch>> =
        Mutex::new(SCRATCH_POOL.with(|p| std::mem::take(&mut *p.borrow_mut())));
    let batches: Vec<BatchMeta> = par_map_threads(threads, chunks.len(), |i| {
        let rng_seed = derive_seed(s0, worker, epoch, i as u32);
        let mut scratch = pool.lock().unwrap().pop().unwrap_or_default();
        let input_nodes =
            sample_input_nodes_scratch(g, chunks[i], fanouts, rng_seed, &mut scratch);
        pool.lock().unwrap().push(scratch);
        let mut remote_mask = vec![0u64; input_nodes.len().div_ceil(64)];
        let mut num_remote = 0u32;
        for (j, &v) in input_nodes.iter().enumerate() {
            if !part.is_local(worker, v) {
                remote_mask[j / 64] |= 1 << (j % 64);
                num_remote += 1;
            }
        }
        BatchMeta {
            batch: i as u32,
            seeds: chunks[i].to_vec(),
            input_nodes,
            remote_mask,
            num_remote,
        }
    });
    SCRATCH_POOL.with(|p| *p.borrow_mut() = pool.into_inner().unwrap());
    EpochSchedule { worker, epoch, batches }
}

/// Tally remote-node access frequency over a set of batches — the paper's
/// `freq(·)` ranking input for `TopHot` (Algorithm 1, line 3).
///
/// Returns `(node, count)` pairs sorted by descending count (ties by id for
/// determinism). The tally runs sharded across all available cores.
pub fn remote_frequency(batches: &[BatchMeta]) -> Vec<(NodeId, u32)> {
    remote_frequency_threads(available_threads(), batches)
}

/// [`remote_frequency`] with an explicit thread count (`1` = the serial
/// reference). The sorted output is byte-identical at any thread count.
pub fn remote_frequency_threads(threads: usize, batches: &[BatchMeta]) -> Vec<(NodeId, u32)> {
    let mut out = tally_remote_threads(threads, batches);
    out.sort_unstable_by(rank_order);
    out
}

/// The ranking order shared by [`remote_frequency`] and `cache::top_hot`:
/// frequency descending, ties broken by ascending node id — a total order
/// over tallied pairs (ids are unique), which is what makes the parallel
/// tally deterministic.
#[inline]
pub fn rank_order(a: &(NodeId, u32), b: &(NodeId, u32)) -> std::cmp::Ordering {
    b.1.cmp(&a.1).then(a.0.cmp(&b.0))
}

/// Unsorted `(node, count)` tally of remote accesses — the shared input of
/// [`remote_frequency`] and `cache::top_hot`'s partial selection.
///
/// The pair *set* is deterministic; pair *order* is not (it reflects shard
/// and hashmap iteration order), so callers must impose [`rank_order`].
/// Parallel scheme: threads tally disjoint batch ranges into hash-sharded
/// partial maps (`shard = id % threads`), then the per-shard maps are merged
/// in parallel — total work stays O(accesses + distinct ids).
pub fn tally_remote_threads(threads: usize, batches: &[BatchMeta]) -> Vec<(NodeId, u32)> {
    let shards = threads.clamp(1, 16);
    if shards == 1 || batches.len() < 2 * shards {
        let mut counts: IdHashMap<NodeId, u32> = Default::default();
        for b in batches {
            for v in b.remote_nodes() {
                *counts.entry(v).or_insert(0) += 1;
            }
        }
        return counts.into_iter().collect();
    }
    // Map phase: each thread tallies a contiguous slice of batches into
    // `shards` id-sharded partial maps.
    let chunk = batches.len().div_ceil(shards);
    let partials: Vec<Vec<IdHashMap<NodeId, u32>>> = par_map_threads(shards, shards, |t| {
        let lo = (t * chunk).min(batches.len());
        let hi = ((t + 1) * chunk).min(batches.len());
        let mut maps: Vec<IdHashMap<NodeId, u32>> =
            (0..shards).map(|_| Default::default()).collect();
        for b in &batches[lo..hi] {
            for v in b.remote_nodes() {
                *maps[v as usize % shards].entry(v).or_insert(0) += 1;
            }
        }
        maps
    });
    // Reduce phase: merge shard `s` across all partial maps, in parallel —
    // shards own disjoint id spaces, so no cross-thread contention.
    let merged: Vec<Vec<(NodeId, u32)>> = par_map_threads(shards, shards, |sdx| {
        let mut m: IdHashMap<NodeId, u32> = Default::default();
        for p in &partials {
            for (&v, &c) in &p[sdx] {
                *m.entry(v).or_insert(0) += c;
            }
        }
        m.into_iter().collect()
    });
    merged.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetPreset};
    use crate::graph::{build_dataset, Dataset};
    use crate::partition::{metis_like, Partition};

    fn setup() -> (Dataset, Partition) {
        let ds = build_dataset(&DatasetConfig::preset(DatasetPreset::Tiny, 1.0), false);
        let part = metis_like(&ds.graph, 2, 0);
        (ds, part)
    }

    fn shard(ds: &Dataset, part: &Partition, w: WorkerId) -> Vec<NodeId> {
        ds.train_nodes
            .iter()
            .copied()
            .filter(|&v| part.is_local(w, v))
            .collect()
    }

    const F: [Fanout; 2] = [Fanout::Sample(5), Fanout::Sample(3)];

    #[test]
    fn shuffle_is_permutation_and_epoch_dependent() {
        let (ds, part) = setup();
        let sh = shard(&ds, &part, 0);
        let o1 = epoch_seed_order(&sh, 42, 0, 0);
        let o2 = epoch_seed_order(&sh, 42, 0, 1);
        assert_ne!(o1, o2, "different epochs must shuffle differently");
        let mut s1 = o1.clone();
        s1.sort_unstable();
        let mut s0 = sh.clone();
        s0.sort_unstable();
        assert_eq!(s0, s1, "shuffle must be a permutation");
        assert_eq!(o1, epoch_seed_order(&sh, 42, 0, 0), "deterministic");
    }

    #[test]
    fn enumerate_epoch_covers_all_shard_seeds() {
        let (ds, part) = setup();
        let sh = shard(&ds, &part, 0);
        let sched = enumerate_epoch(&ds.graph, &part, &sh, &F, 64, 42, 0, 0);
        let total_seeds: usize = sched.batches.iter().map(|b| b.seeds.len()).sum();
        assert_eq!(total_seeds, sh.len());
        assert_eq!(sched.batches.len(), sh.len().div_ceil(64));
        // every batch except possibly the last is full
        for b in &sched.batches[..sched.batches.len() - 1] {
            assert_eq!(b.seeds.len(), 64);
        }
    }

    #[test]
    fn remote_mask_matches_partition() {
        let (ds, part) = setup();
        let sh = shard(&ds, &part, 1);
        let sched = enumerate_epoch(&ds.graph, &part, &sh, &F, 32, 7, 1, 0);
        for b in &sched.batches {
            let mut n = 0;
            for (j, &v) in b.input_nodes.iter().enumerate() {
                assert_eq!(b.is_remote(j), !part.is_local(1, v));
                if b.is_remote(j) {
                    n += 1;
                }
            }
            assert_eq!(n, b.num_remote);
            // seeds are always local (they come from the worker's shard)
            for &s in &b.seeds {
                assert!(part.is_local(1, s));
            }
        }
    }

    #[test]
    fn schedule_fully_deterministic() {
        let (ds, part) = setup();
        let sh = shard(&ds, &part, 0);
        let a = enumerate_epoch(&ds.graph, &part, &sh, &F, 32, 5, 0, 3);
        let b = enumerate_epoch(&ds.graph, &part, &sh, &F, 32, 5, 0, 3);
        assert_eq!(a, b);
    }

    #[test]
    fn parallel_enumeration_is_thread_count_invariant() {
        // The tentpole identity: the parallel path at any thread count must
        // reproduce the serial reference bit for bit.
        let (ds, part) = setup();
        let sh = shard(&ds, &part, 0);
        let serial = enumerate_epoch_threads(1, &ds.graph, &part, &sh, &F, 32, 5, 0, 2);
        for threads in [2, 4, 8] {
            let par = enumerate_epoch_threads(threads, &ds.graph, &part, &sh, &F, 32, 5, 0, 2);
            assert_eq!(serial, par, "threads {threads}");
        }
        assert_eq!(serial, enumerate_epoch(&ds.graph, &part, &sh, &F, 32, 5, 0, 2));
    }

    #[test]
    fn frequency_ranking_sorted_and_complete() {
        let (ds, part) = setup();
        let sh = shard(&ds, &part, 0);
        let sched = enumerate_epoch(&ds.graph, &part, &sh, &F, 32, 5, 0, 0);
        let freq = remote_frequency(&sched.batches);
        // descending counts
        assert!(freq.windows(2).all(|w| w[0].1 >= w[1].1));
        // total count equals total remote accesses
        let total: u64 = freq.iter().map(|&(_, c)| c as u64).sum();
        assert_eq!(total, sched.total_remote());
        // all ranked nodes are genuinely remote
        for &(v, _) in &freq {
            assert!(!part.is_local(0, v));
        }
    }

    #[test]
    fn sharded_frequency_matches_serial_reference() {
        let (ds, part) = setup();
        let sh = shard(&ds, &part, 0);
        // small batches so the sharded path actually engages
        let sched = enumerate_epoch(&ds.graph, &part, &sh, &F, 16, 5, 0, 0);
        let mut counts: IdHashMap<NodeId, u32> = Default::default();
        for b in &sched.batches {
            for v in b.remote_nodes() {
                *counts.entry(v).or_insert(0) += 1;
            }
        }
        let mut reference: Vec<(NodeId, u32)> = counts.into_iter().collect();
        reference.sort_unstable_by(rank_order);
        for threads in [1, 2, 8] {
            assert_eq!(
                remote_frequency_threads(threads, &sched.batches),
                reference,
                "threads {threads}"
            );
        }
        assert_eq!(remote_frequency(&sched.batches), reference);
    }

    #[test]
    fn frequency_ties_break_by_ascending_id_at_any_thread_count() {
        // Hand-built batches where every node has the same count: the output
        // order must be ascending node id, regardless of sharding.
        fn batch(remote: &[NodeId]) -> BatchMeta {
            let input_nodes = remote.to_vec();
            let mut mask = vec![0u64; input_nodes.len().div_ceil(64)];
            for j in 0..input_nodes.len() {
                mask[j / 64] |= 1 << (j % 64);
            }
            BatchMeta {
                batch: 0,
                seeds: vec![],
                num_remote: input_nodes.len() as u32,
                input_nodes,
                remote_mask: mask,
            }
        }
        let ids = [97u32, 5, 41, 13, 89, 2, 57, 33];
        // 16 batches so even threads = 8 clears the `len >= 2 * shards`
        // bar and genuinely exercises the sharded map/reduce path.
        let batches: Vec<BatchMeta> = (0..16).map(|_| batch(&ids)).collect();
        let mut expected: Vec<(NodeId, u32)> = ids.iter().map(|&v| (v, 16)).collect();
        expected.sort_unstable();
        for threads in [1, 2, 8] {
            assert_eq!(
                remote_frequency_threads(threads, &batches),
                expected,
                "threads {threads}"
            );
        }
    }

    #[test]
    fn m_max_is_max_batch_size() {
        let (ds, part) = setup();
        let sh = shard(&ds, &part, 0);
        let sched = enumerate_epoch(&ds.graph, &part, &sh, &F, 32, 5, 0, 0);
        let m = sched.batches.iter().map(|b| b.input_nodes.len()).max().unwrap();
        assert_eq!(sched.m_max() as usize, m);
    }
}
