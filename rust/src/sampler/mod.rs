//! Deterministic k-hop neighbor sampling and schedule precomputation.
//!
//! The paper's core trick: because every batch's PRNG seed is derived as
//! `H(s0, w, e, i)` ([`seed::derive_seed`]), the *entire* training schedule —
//! which seeds form batch `b_i` of epoch `e` on worker `w`, and which input
//! nodes the k-hop expansion touches — can be enumerated before training
//! starts ([`schedule`]). Every downstream mechanism (hot-set cache ranking,
//! prefetch staging) consumes that enumeration.

pub mod khop;
pub mod schedule;
pub mod seed;

pub use khop::{
    sample_blocks, sample_blocks_scratch, sample_input_nodes, sample_input_nodes_scratch,
    Fanout, LayerBlock, SampledBatch, SamplerScratch,
};
pub use schedule::{
    enumerate_epoch, enumerate_epoch_threads, epoch_seed_order, remote_frequency,
    remote_frequency_threads, tally_remote_threads, BatchMeta, EpochSchedule,
};
