//! Deterministic seeding: the paper's `s_{e,i}^{(w)} = H(s0, w, e, i)`.
//!
//! The paper uses a cryptographic hash to derive per-(worker, epoch, batch)
//! PRNG seeds with non-overlapping streams (Proposition 3.1). We use a strong
//! 64-bit mixing construction (SplitMix64 finalizer chained over the tuple
//! fields — the same finalizer family as MurmurHash3/xxHash) which passes the
//! collision and uniformity tests below; cryptographic strength is not
//! required for the proposition, only statistical independence of streams.

/// SplitMix64 finalizer: a bijective avalanche mix on 64 bits.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// The paper's seed derivation `H(s0, w, e, i)`.
///
/// Chains the SplitMix64 finalizer over the tuple fields, injecting each field
/// with a distinct odd constant so that permuted tuples hash differently.
#[inline]
pub fn derive_seed(s0: u64, worker: u32, epoch: u32, batch: u32) -> u64 {
    let mut h = mix64(s0 ^ 0xA0761D6478BD642F);
    h = mix64(h ^ (worker as u64).wrapping_mul(0xE7037ED1A0B428DB));
    h = mix64(h ^ (epoch as u64).wrapping_mul(0x8EBC6AF09C88C6E3));
    h = mix64(h ^ (batch as u64).wrapping_mul(0x589965CC75374CC3));
    h
}

/// xoshiro256++ PRNG — fast, high-quality, 256-bit state.
///
/// Used for neighbor sampling and synthetic-data generation. Seeded from a
/// single u64 via SplitMix64 expansion (the reference seeding procedure).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed from a 64-bit value (SplitMix64 state expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            mix64(sm)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform integer in `[0, bound)` (Lemire's nearly-divisionless method).
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        // 64-bit multiply-shift: bias < 2^-32, negligible for sampling.
        let x = self.next_u64() >> 32;
        ((x * bound as u64) >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box–Muller (one value per call; simple and exact
    /// enough for synthetic feature noise).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Sample `k` items uniformly *with replacement* from `0..n`.
    pub fn sample_with_replacement(&mut self, n: u32, k: usize, out: &mut Vec<u32>) {
        out.clear();
        for _ in 0..k {
            out.push(self.below(n));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    #[test]
    fn derive_seed_deterministic() {
        assert_eq!(derive_seed(42, 1, 2, 3), derive_seed(42, 1, 2, 3));
    }

    #[test]
    fn derive_seed_distinct_tuples_distinct_seeds() {
        // Proposition 3.1(b): distinct (w,e,i) tuples → distinct streams.
        let mut seen = BTreeSet::new();
        for w in 0..8 {
            for e in 0..32 {
                for i in 0..64 {
                    assert!(seen.insert(derive_seed(7, w, e, i)), "collision at {w},{e},{i}");
                }
            }
        }
        // field permutations must not collide either
        assert_ne!(derive_seed(7, 1, 2, 3), derive_seed(7, 3, 2, 1));
        assert_ne!(derive_seed(7, 1, 2, 3), derive_seed(7, 2, 1, 3));
    }

    #[test]
    fn derive_seed_sensitive_to_base_seed() {
        assert_ne!(derive_seed(1, 0, 0, 0), derive_seed(2, 0, 0, 0));
    }

    #[test]
    fn rng_reproducible() {
        let mut a = Rng::new(123);
        let mut b = Rng::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            let x = r.below(10);
            assert!(x < 10);
            counts[x as usize] += 1;
        }
        for &c in &counts {
            // each bucket expects 10k; allow ±6% (xoshiro passes far tighter)
            assert!((9_400..=10_600).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn f64_in_unit_interval_with_correct_mean() {
        let mut r = Rng::new(5);
        let mut sum = 0.0;
        for _ in 0..100_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn mix64_is_bijective_on_sample() {
        // injectivity spot-check over a dense range
        let mut seen = BTreeSet::new();
        for i in 0..100_000u64 {
            assert!(seen.insert(mix64(i)));
        }
    }
}
