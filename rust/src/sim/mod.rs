//! Simulated-time machinery: per-worker virtual clocks, the analytic compute
//! model used in trace mode, the bounded-queue pipeline recurrence that
//! converts per-step costs into end-to-end epoch times, and the
//! discrete-event cluster runtime ([`cluster`]) that schedules many worker
//! pipelines concurrently on one shared virtual clock (see `sim/README.md`
//! for the event model and topology presets).
//!
//! The pipeline model is the heart of the Table-2 reproduction: RapidGNN's
//! prefetcher and trainer form a two-stage pipeline coupled by a bounded
//! queue of depth `Q`. Stage costs come from real counters (bytes, rows,
//! cache misses) put through the fabric cost model; the recurrence then
//! yields exactly the overlap behaviour the paper describes — communication
//! hidden behind compute except where misses exceed the window.

pub mod cluster;
mod pipeline;

pub use cluster::{ClusterSim, ClusterWorker, ScriptedActor, WorkerActor, WorkerTimeline};
pub use pipeline::{pipeline_schedule, PipelineStep, PipelineTimes};

use crate::config::RunConfig;

/// Analytic compute model for one training step (trace mode).
///
/// Calibrated as an effective-FLOPs model of a 2-layer GraphSAGE
/// forward+backward on the paper's P100 (≈4.7 TF/s f32, ~20% MXU-equivalent
/// utilization on gather-bound GNN workloads → ~1 TF/s effective), plus a
/// per-node host-side assembly cost.
#[derive(Debug, Clone, Copy)]
pub struct ComputeModel {
    /// Effective device throughput in FLOP/s.
    pub effective_flops: f64,
    /// Host-side per-input-node assembly cost (gather + H2D), seconds.
    pub per_node_host_sec: f64,
    /// Fixed per-step launch/framework overhead, seconds.
    pub step_overhead_sec: f64,
}

impl Default for ComputeModel {
    fn default() -> Self {
        ComputeModel {
            effective_flops: 1.0e12,
            per_node_host_sec: 40e-9,
            step_overhead_sec: 300e-6,
        }
    }
}

impl ComputeModel {
    /// FLOPs of one fwd+bwd GraphSAGE step given batch composition.
    ///
    /// Layer 1 transforms every input node (`n_input`) from `d` to `h`;
    /// layer 2 transforms the seed set (`n_seeds`) from `h` to `c`.
    /// Backward ≈ 2× forward.
    pub fn step_flops(&self, n_input: u64, n_seeds: u64, d: u64, h: u64, c: u64) -> f64 {
        let fwd = (n_input * d * h * 2 + n_seeds * h * c * 2) as f64;
        3.0 * fwd
    }

    /// Simulated compute seconds for one step.
    pub fn step_time(&self, cfg: &RunConfig, n_input: u64, n_seeds: u64) -> f64 {
        let flops = self.step_flops(
            n_input,
            n_seeds,
            cfg.dataset.feature_dim as u64,
            cfg.hidden_dim as u64,
            cfg.dataset.num_classes as u64,
        );
        self.step_overhead_sec
            + flops / self.effective_flops
            + n_input as f64 * self.per_node_host_sec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_time_monotone_in_batch() {
        let m = ComputeModel::default();
        let cfg = RunConfig::default();
        assert!(m.step_time(&cfg, 20_000, 1_000) > m.step_time(&cfg, 10_000, 500));
    }

    #[test]
    fn step_time_has_overhead_floor() {
        let m = ComputeModel::default();
        let cfg = RunConfig::default();
        assert!(m.step_time(&cfg, 0, 0) >= m.step_overhead_sec);
    }

    #[test]
    fn flops_formula() {
        let m = ComputeModel::default();
        // 10 inputs, 2 seeds, d=4, h=3, c=2: fwd = 10*4*3*2 + 2*3*2*2 = 264
        assert_eq!(m.step_flops(10, 2, 4, 3, 2), 3.0 * 264.0);
    }
}
