//! Discrete-event cluster runtime: concurrently-scheduled worker pipelines on
//! a shared virtual clock.
//!
//! [`ClusterSim`] generalizes the closed-form bounded-queue recurrence in
//! [`super::pipeline`] to *many workers advancing together in virtual time*.
//! Each worker is an actor with two stages — a prefetcher that stages batches
//! (sampling/SSD stream + cache-first fetch) and a trainer that consumes them
//! — coupled by a bounded queue of depth `Q`. The simulator keeps one global
//! event heap; the earliest event fires next regardless of which worker owns
//! it, so cross-worker interleavings (shared-model SGD order in full mode,
//! straggler skew, topology-dependent stage costs) are resolved in exact
//! virtual-time order.
//!
//! # Determinism
//!
//! Everything is deterministic by construction: events are totally ordered by
//! `(time, worker, sequence number)` using `f64::total_cmp`, actors are
//! stepped single-threaded from the event loop, and all costs are produced by
//! the deterministic cost models. Two runs of the same configuration produce
//! bit-identical timelines — the golden-trace conformance suite pins this.
//!
//! # Agreement with the closed-form model
//!
//! For a single worker (or any set of workers that don't share state) the
//! event schedule satisfies exactly the recurrence of
//! [`super::pipeline_schedule`]:
//!
//! ```text
//! stage_done[i]   = max(stage_done[i-1], consume_done[i-Q]) + stage[i]
//! consume_done[i] = max(consume_done[i-1], stage_done[i]) + consume[i]
//! ```
//!
//! — a stage starts at the event that unblocks it (prefetcher idle *and* a
//! queue slot free), a consume starts when its batch is staged and the
//! trainer is idle. The per-worker makespan and trainer-wait therefore match
//! the closed-form schedule to the last bit on homogeneous inputs; the
//! property tests below pin the agreement at 1e-9 over random step costs.

use super::pipeline::PipelineStep;
use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// One worker's pipeline, driven by the event loop.
///
/// The simulator never inspects batches: actors perform the real side
/// effects (KV pulls, cache lookups, train steps) when called and return the
/// *virtual* seconds the work costs. `stage_next` is invoked when the
/// worker's prefetcher starts staging the next batch; `consume_next` when
/// its trainer starts consuming the oldest staged batch. Calls arrive in
/// exact virtual-time order across all workers.
pub trait WorkerActor {
    /// Stage the next batch (perform pulls, push onto the staged queue).
    /// Returns the staging cost in virtual seconds, or `None` when the
    /// schedule is exhausted.
    fn stage_next(&mut self) -> Option<f64>;

    /// Consume the oldest staged batch (run the train step in full mode).
    /// Returns the consume cost in virtual seconds. Called only when a
    /// staged batch is available.
    fn consume_next(&mut self) -> f64;
}

/// Per-worker virtual-time record produced by the simulation.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WorkerTimeline {
    /// Completion time of each staging, in batch order.
    pub stage_done: Vec<f64>,
    /// Completion time of each consume, in batch order.
    pub consume_done: Vec<f64>,
    /// Per-step trainer idle time waiting on staging (the residual-fetch
    /// stall — same quantity as [`super::PipelineTimes::trainer_wait`]).
    pub trainer_wait: Vec<f64>,
    /// This worker's epoch makespan (last consume completion; 0 if empty).
    pub makespan: f64,
    /// Sum of `trainer_wait`.
    pub total_wait: f64,
}

impl WorkerTimeline {
    /// Steps completed.
    pub fn steps(&self) -> usize {
        self.consume_done.len()
    }
}

/// A finished worker: its timeline plus the actor (with whatever state the
/// caller wants back — counters, accumulators, queues).
pub struct ClusterWorker<A> {
    pub timeline: WorkerTimeline,
    pub actor: A,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EventKind {
    StageDone,
    ConsumeDone,
}

#[derive(Debug, Clone, Copy)]
struct Event {
    time: f64,
    worker: u32,
    /// Global insertion sequence — the deterministic tie-break for events at
    /// identical (time, worker).
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time
            .total_cmp(&other.time)
            .then_with(|| self.worker.cmp(&other.worker))
            .then_with(|| self.seq.cmp(&other.seq))
    }
}

struct Slot<A> {
    actor: A,
    /// Prefetch window Q; 0 = fully serial (baseline mode, no overlap).
    q: u32,
    stages_started: u64,
    stages_done: u64,
    consumes_started: u64,
    consumes_done: u64,
    prefetcher_busy: bool,
    trainer_busy: bool,
    exhausted: bool,
    last_consume_done: f64,
    timeline: WorkerTimeline,
}

impl<A> Slot<A> {
    /// Queue-slot gate: stage `i` may start once batch `i − Q` has been
    /// consumed (`consume_done[i-Q]` in the closed-form recurrence). `Q = 0`
    /// and `Q = 1` coincide — with one slot the prefetcher can never run
    /// ahead of the trainer, exactly like the recurrence.
    fn may_stage(&self) -> bool {
        !self.exhausted
            && !self.prefetcher_busy
            && self.stages_started - self.consumes_done < u64::from(self.q.max(1))
    }

    fn may_consume(&self) -> bool {
        !self.trainer_busy && self.stages_done > self.consumes_started
    }
}

/// The event-driven cluster: a set of worker actors on one virtual clock.
pub struct ClusterSim<A: WorkerActor> {
    slots: Vec<Slot<A>>,
    heap: BinaryHeap<Reverse<Event>>,
    seq: u64,
}

impl<A: WorkerActor> Default for ClusterSim<A> {
    fn default() -> Self {
        Self::new()
    }
}

impl<A: WorkerActor> ClusterSim<A> {
    /// Empty cluster.
    pub fn new() -> Self {
        ClusterSim { slots: Vec::new(), heap: BinaryHeap::new(), seq: 0 }
    }

    /// Add one worker with prefetch window `q` (0 disables overlap).
    /// Workers are identified by insertion order.
    pub fn add_worker(&mut self, q: u32, actor: A) {
        self.slots.push(Slot {
            actor,
            q,
            stages_started: 0,
            stages_done: 0,
            consumes_started: 0,
            consumes_done: 0,
            prefetcher_busy: false,
            trainer_busy: false,
            exhausted: false,
            last_consume_done: 0.0,
            timeline: WorkerTimeline::default(),
        });
    }

    fn push_event(&mut self, time: f64, worker: usize, kind: EventKind) {
        self.seq += 1;
        self.heap.push(Reverse(Event { time, worker: worker as u32, seq: self.seq, kind }));
    }

    fn try_start_stage(&mut self, w: usize, now: f64) {
        if !self.slots[w].may_stage() {
            return;
        }
        match self.slots[w].actor.stage_next() {
            Some(cost) => {
                debug_assert!(cost >= 0.0, "negative stage cost");
                let slot = &mut self.slots[w];
                slot.stages_started += 1;
                slot.prefetcher_busy = true;
                self.push_event(now + cost, w, EventKind::StageDone);
            }
            None => self.slots[w].exhausted = true,
        }
    }

    fn try_start_consume(&mut self, w: usize, now: f64) {
        if !self.slots[w].may_consume() {
            return;
        }
        // Trainer idle since its last completion; anything between then and
        // now was spent waiting on staging.
        let wait = now - self.slots[w].last_consume_done;
        let cost = self.slots[w].actor.consume_next();
        debug_assert!(cost >= 0.0, "negative consume cost");
        let slot = &mut self.slots[w];
        slot.consumes_started += 1;
        slot.trainer_busy = true;
        slot.timeline.trainer_wait.push(wait.max(0.0));
        self.push_event(now + cost, w, EventKind::ConsumeDone);
    }

    /// Run to quiescence and hand back each worker's timeline + actor, in
    /// insertion order.
    pub fn run(mut self) -> Vec<ClusterWorker<A>> {
        for w in 0..self.slots.len() {
            self.try_start_stage(w, 0.0);
        }
        while let Some(Reverse(ev)) = self.heap.pop() {
            let w = ev.worker as usize;
            match ev.kind {
                EventKind::StageDone => {
                    let slot = &mut self.slots[w];
                    slot.prefetcher_busy = false;
                    slot.stages_done += 1;
                    slot.timeline.stage_done.push(ev.time);
                    self.try_start_consume(w, ev.time);
                    self.try_start_stage(w, ev.time);
                }
                EventKind::ConsumeDone => {
                    let slot = &mut self.slots[w];
                    slot.trainer_busy = false;
                    slot.consumes_done += 1;
                    slot.last_consume_done = ev.time;
                    slot.timeline.consume_done.push(ev.time);
                    // Consuming frees a queue slot, which may unblock the
                    // prefetcher; a newly staged batch may in turn feed the
                    // now-idle trainer.
                    self.try_start_stage(w, ev.time);
                    self.try_start_consume(w, ev.time);
                }
            }
        }
        self.slots
            .into_iter()
            .map(|mut slot| {
                debug_assert_eq!(
                    slot.stages_done, slot.consumes_done,
                    "every staged batch must be consumed"
                );
                slot.timeline.makespan = slot.timeline.consume_done.last().copied().unwrap_or(0.0);
                slot.timeline.total_wait = slot.timeline.trainer_wait.iter().sum();
                ClusterWorker { timeline: slot.timeline, actor: slot.actor }
            })
            .collect()
    }
}

/// Test/bench actor that replays a fixed list of per-step costs — the bridge
/// between the event simulator and the closed-form [`PipelineStep`] inputs.
pub struct ScriptedActor {
    steps: std::vec::IntoIter<PipelineStep>,
    /// Consume costs of staged-but-unconsumed batches (FIFO).
    staged: std::collections::VecDeque<f64>,
}

impl ScriptedActor {
    /// Replay `steps` in order.
    pub fn new(steps: &[PipelineStep]) -> Self {
        ScriptedActor {
            steps: steps.to_vec().into_iter(),
            staged: std::collections::VecDeque::new(),
        }
    }
}

impl WorkerActor for ScriptedActor {
    fn stage_next(&mut self) -> Option<f64> {
        let s = self.steps.next()?;
        self.staged.push_back(s.consume);
        Some(s.stage)
    }

    fn consume_next(&mut self) -> f64 {
        self.staged.pop_front().expect("consume without staged batch")
    }
}

#[cfg(test)]
mod tests {
    use super::super::pipeline::pipeline_schedule;
    use super::*;
    use crate::util::proptest_lite::{forall, gen};

    fn run_single(steps: &[PipelineStep], q: u32) -> WorkerTimeline {
        let mut sim = ClusterSim::new();
        sim.add_worker(q, ScriptedActor::new(steps));
        sim.run().pop().unwrap().timeline
    }

    fn assert_agrees(steps: &[PipelineStep], q: u32) {
        let closed = pipeline_schedule(steps, q);
        let event = run_single(steps, q);
        assert_eq!(event.steps(), steps.len());
        assert!(
            (event.makespan - closed.total).abs() < 1e-9,
            "q={q}: event {} vs closed {}",
            event.makespan,
            closed.total
        );
        assert!(
            (event.total_wait - closed.total_wait).abs() < 1e-9,
            "q={q}: wait {} vs {}",
            event.total_wait,
            closed.total_wait
        );
        for (i, (a, b)) in event.trainer_wait.iter().zip(&closed.trainer_wait).enumerate() {
            assert!((a - b).abs() < 1e-9, "q={q} step {i}: wait {a} vs {b}");
        }
    }

    fn uniform(n: usize, stage: f64, consume: f64) -> Vec<PipelineStep> {
        vec![PipelineStep { stage, consume }; n]
    }

    #[test]
    fn empty_worker_finishes_at_zero() {
        let t = run_single(&[], 4);
        assert_eq!(t.makespan, 0.0);
        assert_eq!(t.steps(), 0);
    }

    #[test]
    fn serial_q0_matches_closed_form() {
        assert_agrees(&uniform(10, 2.0, 3.0), 0);
    }

    #[test]
    fn agrees_with_closed_form_across_queue_depths() {
        let steps: Vec<PipelineStep> = (0..60)
            .map(|i| PipelineStep {
                stage: if i % 7 == 0 { 3.0 } else { 0.2 },
                consume: 1.0 + (i % 3) as f64 * 0.5,
            })
            .collect();
        for q in [0u32, 1, 2, 4, 8, 16] {
            assert_agrees(&steps, q);
        }
    }

    #[test]
    fn deep_queue_hides_cheap_staging() {
        let t = run_single(&uniform(100, 0.1, 1.0), 4);
        assert!((t.makespan - (0.1 + 100.0)).abs() < 1e-6, "{}", t.makespan);
        assert!(t.trainer_wait[0] > 0.0);
        assert!(t.trainer_wait[1..].iter().all(|&w| w < 1e-9));
    }

    #[test]
    fn event_vs_closed_form_property_over_random_costs() {
        // The conformance property the ISSUE pins: on homogeneous inputs the
        // event simulator and the closed-form recurrence agree within 1e-9,
        // for random step costs, lengths, and queue depths.
        forall(
            0xC10_57E9,
            60,
            |rng| {
                let n = gen::usize_in(rng, 0, 40);
                let q = gen::usize_in(rng, 0, 9) as u32;
                let steps = gen::vec_of(rng, n, |r| PipelineStep {
                    stage: gen::f64_in(r, 0.0, 4.0),
                    consume: gen::f64_in(r, 0.0, 4.0),
                });
                (steps, q)
            },
            |(steps, q)| {
                let closed = pipeline_schedule(steps, *q);
                let event = run_single(steps, *q);
                if (event.makespan - closed.total).abs() > 1e-9 {
                    return Err(format!(
                        "makespan {} != {}",
                        event.makespan, closed.total
                    ));
                }
                if (event.total_wait - closed.total_wait).abs() > 1e-9 {
                    return Err(format!(
                        "wait {} != {}",
                        event.total_wait, closed.total_wait
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn workers_advance_independently_on_shared_clock() {
        // Two unequal workers: each timeline matches its own closed-form
        // schedule; the cluster makespan is the max, not the sum.
        let fast = uniform(20, 0.1, 0.5);
        let slow = uniform(20, 0.4, 2.0);
        let mut sim = ClusterSim::new();
        sim.add_worker(4, ScriptedActor::new(&fast));
        sim.add_worker(4, ScriptedActor::new(&slow));
        let out = sim.run();
        let f = pipeline_schedule(&fast, 4);
        let s = pipeline_schedule(&slow, 4);
        assert!((out[0].timeline.makespan - f.total).abs() < 1e-9);
        assert!((out[1].timeline.makespan - s.total).abs() < 1e-9);
        assert!(out[1].timeline.makespan > out[0].timeline.makespan);
    }

    #[test]
    fn straggler_stretches_only_its_own_timeline() {
        let base = uniform(30, 0.2, 1.0);
        let slowed: Vec<PipelineStep> = base
            .iter()
            .map(|s| PipelineStep { stage: s.stage * 3.0, consume: s.consume * 3.0 })
            .collect();
        let mut sim = ClusterSim::new();
        sim.add_worker(4, ScriptedActor::new(&base));
        sim.add_worker(4, ScriptedActor::new(&slowed));
        sim.add_worker(4, ScriptedActor::new(&base));
        let out = sim.run();
        assert!((out[0].timeline.makespan - out[2].timeline.makespan).abs() < 1e-12);
        assert!(out[1].timeline.makespan > 2.5 * out[0].timeline.makespan);
    }

    #[test]
    fn deterministic_across_runs() {
        let steps: Vec<PipelineStep> = (0..50)
            .map(|i| PipelineStep {
                stage: (i % 5) as f64 * 0.3 + 0.01,
                consume: ((i + 2) % 3) as f64 * 0.5 + 0.1,
            })
            .collect();
        let run = || {
            let mut sim = ClusterSim::new();
            for _ in 0..4 {
                sim.add_worker(3, ScriptedActor::new(&steps));
            }
            sim.run()
                .into_iter()
                .map(|w| w.timeline)
                .collect::<Vec<_>>()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "timelines must be bit-identical across runs");
    }

    #[test]
    fn bounded_queue_gate_limits_runahead() {
        // Mirror of the pipeline test: a deep queue absorbs one slow fetch.
        let mut steps = uniform(20, 0.0, 1.0);
        steps[10].stage = 5.0;
        let t1 = run_single(&steps, 1);
        let t8 = run_single(&steps, 8);
        assert!(t8.makespan < t1.makespan, "deeper queue absorbs the spike");
    }
}
