//! Bounded-queue two-stage pipeline schedule.
//!
//! Models the Prefetcher → Trainer pipeline (paper §4): the prefetcher stages
//! batch `i` (cost `stage[i]` = cache lookup + residual SyncPull), the trainer
//! consumes it (cost `consume[i]` = assemble + compute). The queue holds at
//! most `Q` staged-but-unconsumed batches, so the prefetcher stalls when it
//! runs too far ahead ("stalls only when the Trainer lags" — §4). The
//! recurrence:
//!
//! ```text
//! stage_done[i]   = max(stage_done[i-1], consume_done[i-Q]) + stage[i]
//! consume_done[i] = max(consume_done[i-1], stage_done[i]) + consume[i]
//! ```
//!
//! For the on-demand baselines there is no overlap: pass `Q = 0` and the
//! schedule degenerates to `consume_done[i] = consume_done[i-1] + stage[i] +
//! consume[i]` (fetch fully on the critical path).

/// Per-step costs fed to the schedule.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PipelineStep {
    /// Prefetch/staging cost (network + cache lookup), seconds.
    pub stage: f64,
    /// Consumption cost (assemble + compute), seconds.
    pub consume: f64,
}

/// Output of the schedule: per-step completion and derived stall times.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PipelineTimes {
    /// Epoch makespan (seconds).
    pub total: f64,
    /// Per-step trainer wait time (time the trainer sat idle because the
    /// batch wasn't staged yet) — the paper's residual fetch stall.
    pub trainer_wait: Vec<f64>,
    /// Sum of trainer wait.
    pub total_wait: f64,
}

/// Compute the pipeline schedule. `q = 0` disables overlap (baseline mode).
pub fn pipeline_schedule(steps: &[PipelineStep], q: u32) -> PipelineTimes {
    let n = steps.len();
    let mut times = PipelineTimes {
        trainer_wait: Vec::with_capacity(n),
        ..Default::default()
    };
    if n == 0 {
        return times;
    }
    if q == 0 {
        // Fully serial: stage + consume on the critical path each step.
        let mut t = 0.0;
        for s in steps {
            times.trainer_wait.push(s.stage);
            t += s.stage + s.consume;
        }
        times.total_wait = times.trainer_wait.iter().sum();
        times.total = t;
        return times;
    }
    let q = q as usize;
    let mut stage_done = vec![0f64; n];
    let mut consume_done = vec![0f64; n];
    for i in 0..n {
        let prev_stage = if i > 0 { stage_done[i - 1] } else { 0.0 };
        let queue_free = if i >= q { consume_done[i - q] } else { 0.0 };
        stage_done[i] = prev_stage.max(queue_free) + steps[i].stage;
        let prev_consume = if i > 0 { consume_done[i - 1] } else { 0.0 };
        let wait = (stage_done[i] - prev_consume).max(0.0);
        times.trainer_wait.push(wait);
        consume_done[i] = prev_consume.max(stage_done[i]) + steps[i].consume;
    }
    times.total_wait = times.trainer_wait.iter().sum();
    times.total = consume_done[n - 1];
    times
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform(n: usize, stage: f64, consume: f64) -> Vec<PipelineStep> {
        vec![PipelineStep { stage, consume }; n]
    }

    #[test]
    fn empty_is_zero() {
        let t = pipeline_schedule(&[], 4);
        assert_eq!(t.total, 0.0);
    }

    #[test]
    fn q0_is_fully_serial() {
        let steps = uniform(10, 2.0, 3.0);
        let t = pipeline_schedule(&steps, 0);
        assert!((t.total - 50.0).abs() < 1e-9);
        assert!((t.total_wait - 20.0).abs() < 1e-9);
    }

    #[test]
    fn deep_queue_hides_cheap_staging() {
        // stage ≪ consume: total → stage[0] + Σ consume
        let steps = uniform(100, 0.1, 1.0);
        let t = pipeline_schedule(&steps, 4);
        assert!((t.total - (0.1 + 100.0)).abs() < 1e-6, "total {}", t.total);
        // only the first step waits
        assert!(t.trainer_wait[0] > 0.0);
        assert!(t.trainer_wait[1..].iter().all(|&w| w < 1e-9));
    }

    #[test]
    fn staging_bound_when_fetch_dominates() {
        // stage ≫ consume: total → Σ stage + consume[last]
        let steps = uniform(50, 1.0, 0.1);
        let t = pipeline_schedule(&steps, 4);
        assert!((t.total - (50.0 + 0.1)).abs() < 1e-6, "total {}", t.total);
    }

    #[test]
    fn monotone_improving_in_q() {
        let steps: Vec<PipelineStep> = (0..60)
            .map(|i| PipelineStep {
                stage: if i % 7 == 0 { 3.0 } else { 0.2 },
                consume: 1.0,
            })
            .collect();
        let mut prev = f64::INFINITY;
        for q in [0u32, 1, 2, 4, 8, 16] {
            let t = pipeline_schedule(&steps, q).total;
            assert!(t <= prev + 1e-9, "q={q}: {t} > {prev}");
            prev = t;
        }
    }

    #[test]
    fn never_faster_than_either_stage_sum() {
        let steps: Vec<PipelineStep> = (0..40)
            .map(|i| PipelineStep {
                stage: (i % 5) as f64 * 0.3,
                consume: ((i + 2) % 3) as f64 * 0.5 + 0.1,
            })
            .collect();
        let sum_consume: f64 = steps.iter().map(|s| s.consume).sum();
        let sum_stage: f64 = steps.iter().map(|s| s.stage).sum();
        for q in [1u32, 2, 8] {
            let t = pipeline_schedule(&steps, q).total;
            assert!(t >= sum_consume - 1e-9);
            assert!(t >= sum_stage.max(sum_consume) - 1e-9 || sum_stage < sum_consume);
        }
    }

    #[test]
    fn bounded_queue_limits_runahead() {
        // With Q=1 the prefetcher can't amortize a late spike; with Q=8 it can.
        let mut steps = uniform(20, 0.0, 1.0);
        steps[10].stage = 5.0; // one slow fetch
        let t1 = pipeline_schedule(&steps, 1);
        let t8 = pipeline_schedule(&steps, 8);
        assert!(t8.total < t1.total, "deeper queue absorbs the spike");
    }

    #[test]
    fn q1_matches_hand_computed() {
        // two steps, Q=1:
        // stage_done = [2, max(2, consume_done[0]=5)+2 = 7]
        // consume_done = [max(0,2)+3 = 5, max(5,7)+3 = 10]
        let steps = uniform(2, 2.0, 3.0);
        let t = pipeline_schedule(&steps, 1);
        assert!((t.total - 10.0).abs() < 1e-9);
        assert!((t.trainer_wait[0] - 2.0).abs() < 1e-9);
        assert!((t.trainer_wait[1] - 2.0).abs() < 1e-9);
    }
}
