//! Hot-set feature cache with double buffering (paper §3/§4, Fig. 2).
//!
//! A [`CacheBuffer`] holds the features of the `n_hot` most frequently
//! accessed remote nodes for one epoch, materialized with a single
//! `VectorPull`. Two buffers alternate: the steady cache `C_s` (Buffer 0)
//! serves the current epoch while the secondary `C_sec` (Buffer 1) is built
//! for the next epoch in the background; an atomic swap at the epoch
//! boundary promotes it (Algorithm 1, line 18).
//!
//! # Parallel-determinism contract
//!
//! [`top_hot`] runs on the sharded parallel tally
//! ([`crate::sampler::schedule::tally_remote_threads`]) and cuts the top
//! `n_hot` with `select_nth_unstable` — O(R) instead of the full O(R log R)
//! sort, which stays reserved for `remote_frequency`'s complete ranking.
//! The ranking order (count desc, ties by ascending id) is a *total* order
//! over the tallied pairs, so the selected set and its final order are
//! unique: the output is byte-identical to
//! `remote_frequency(batches).take(n_hot)` at any thread count (pinned by
//! `top_hot_matches_full_sort_reference`).

use crate::metrics::CacheStats;
use crate::sampler::schedule::{rank_order, remote_frequency, tally_remote_threads};
use crate::sampler::BatchMeta;
use crate::util::fasthash::IdHashMap;
use crate::util::parallel::available_threads;
use crate::NodeId;

/// Select the top-`n_hot` remote nodes by access frequency — the paper's
/// `TopHot(N_remote, n_hot, freq)` (Algorithm 1, line 3). Ties break by node
/// id so the selection is deterministic. Tally is sharded across cores and
/// the cut uses partial selection rather than a full sort (module docs).
pub fn top_hot(batches: &[BatchMeta], n_hot: u32) -> Vec<NodeId> {
    let n = n_hot as usize;
    if n == 0 {
        return Vec::new();
    }
    let mut ranked = tally_remote_threads(available_threads(), batches);
    if n < ranked.len() {
        // O(R) partial selection: everything before position n ranks at or
        // above everything after it; only the kept prefix gets sorted.
        ranked.select_nth_unstable_by(n - 1, rank_order);
        ranked.truncate(n);
    }
    ranked.sort_unstable_by(rank_order);
    ranked.into_iter().map(|(v, _)| v).collect()
}

/// One cache buffer: an id→row index plus (optionally) the feature rows.
#[derive(Debug, Default)]
pub struct CacheBuffer {
    index: IdHashMap<NodeId, u32>,
    /// Row-major feature rows; empty in trace mode.
    rows: Vec<f32>,
    feature_dim: usize,
}

impl CacheBuffer {
    /// Build from a hot-node list. `rows`, when provided, must be the
    /// features of `nodes` in order (as returned by a `VectorPull`).
    pub fn new(nodes: &[NodeId], rows: Vec<f32>, feature_dim: usize) -> Self {
        if !rows.is_empty() {
            assert_eq!(rows.len(), nodes.len() * feature_dim, "row block shape");
        }
        let index = nodes.iter().enumerate().map(|(i, &v)| (v, i as u32)).collect();
        CacheBuffer { index, rows, feature_dim }
    }

    /// Number of cached nodes.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Whether node `v` is cached.
    #[inline]
    pub fn contains(&self, v: NodeId) -> bool {
        self.index.contains_key(&v)
    }

    /// Cached feature row of `v`, if present and materialized.
    #[inline]
    pub fn row(&self, v: NodeId) -> Option<&[f32]> {
        let &i = self.index.get(&v)?;
        if self.rows.is_empty() {
            return None;
        }
        let d = self.feature_dim;
        Some(&self.rows[i as usize * d..(i as usize + 1) * d])
    }

    /// Device bytes held by this buffer (index ≈ 16 B/entry + rows).
    pub fn device_bytes(&self) -> u64 {
        (self.rows.len() * 4 + self.index.len() * 16) as u64
    }

    /// The cached node ids in row order — exactly the ranked hot-id list the
    /// buffer was built from ([`Self::new`] assigns row indices in input
    /// order). Checkpoints record this so a restore rebuilds the identical
    /// buffer, hash-map iteration order notwithstanding.
    pub fn ids_by_row(&self) -> Vec<NodeId> {
        let mut pairs: Vec<(u32, NodeId)> =
            self.index.iter().map(|(&v, &i)| (i, v)).collect();
        pairs.sort_unstable();
        pairs.into_iter().map(|(_, v)| v).collect()
    }
}

/// The double-buffered cache: steady `C_s` + secondary `C_sec`.
#[derive(Debug, Default)]
pub struct DoubleBufferCache {
    steady: CacheBuffer,
    secondary: Option<CacheBuffer>,
    stats: CacheStats,
    /// Number of epoch-boundary swaps performed.
    swaps: u32,
}

impl DoubleBufferCache {
    /// Install the initial steady cache (before epoch 1).
    pub fn install_steady(&mut self, buf: CacheBuffer) {
        self.steady = buf;
    }

    /// Stage the next epoch's cache (built in the background during training).
    pub fn stage_secondary(&mut self, buf: CacheBuffer) {
        self.secondary = Some(buf);
    }

    /// Epoch-boundary swap: promote `C_sec` to `C_s` if it's ready
    /// (Algorithm 1, line 18: "if C_sec ready then C_s ← C_sec").
    /// Returns true if a swap happened.
    pub fn swap_at_epoch_boundary(&mut self) -> bool {
        if let Some(next) = self.secondary.take() {
            self.steady = next;
            self.swaps += 1;
            true
        } else {
            false
        }
    }

    /// Current steady buffer.
    pub fn steady(&self) -> &CacheBuffer {
        &self.steady
    }

    /// Partition `ids` into cache hits and misses, updating hit statistics.
    /// `hits`/`misses` are cleared and refilled (allocation-free hot path).
    pub fn split_hits(&mut self, ids: &[NodeId], hits: &mut Vec<NodeId>, misses: &mut Vec<NodeId>) {
        hits.clear();
        misses.clear();
        for &v in ids {
            if self.steady.contains(v) {
                hits.push(v);
            } else {
                misses.push(v);
            }
        }
        self.stats.lookups += ids.len() as u64;
        self.stats.hits += hits.len() as u64;
    }

    /// Hit/miss statistics so far.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Reset statistics (per-epoch reporting).
    pub fn reset_stats(&mut self) {
        self.stats = CacheStats::default();
    }

    /// Swap count.
    pub fn swaps(&self) -> u32 {
        self.swaps
    }

    /// Total device bytes (both buffers — the paper's `2·n_hot·d` term).
    pub fn device_bytes(&self) -> u64 {
        self.steady.device_bytes()
            + self.secondary.as_ref().map_or(0, |b| b.device_bytes())
    }
}

/// Recommend a hot-set size from the frequency distribution: the smallest
/// `k` whose top-`k` nodes cover `coverage` (e.g. 0.8) of all remote
/// accesses. This automates the paper's Fig-5 "practical cache-size
/// selection without excessive memory overhead" (an extension beyond the
/// paper's manual sweep; exercised by `examples/cache_tuning` and the
/// ablation bench).
pub fn recommend_n_hot(batches: &[BatchMeta], coverage: f64) -> u32 {
    assert!((0.0..=1.0).contains(&coverage));
    let ranked = remote_frequency(batches);
    let total: u64 = ranked.iter().map(|&(_, c)| c as u64).sum();
    if total == 0 {
        return 0;
    }
    let target = (total as f64 * coverage).ceil() as u64;
    let mut acc = 0u64;
    for (k, &(_, c)) in ranked.iter().enumerate() {
        acc += c as u64;
        if acc >= target {
            return k as u32 + 1;
        }
    }
    ranked.len() as u32
}

/// Fraction of all remote accesses served by the *marginal quarter* of the
/// top-`n_hot` entries of a frequency ranking. This is the adaptive-cache
/// controller's shrink signal: when the lowest-ranked quarter of the hot set
/// serves almost no traffic, those entries are not earning their device
/// memory.
///
/// `top` is the count-descending prefix of the ranking (as produced by
/// [`remote_frequency`] or a `top_hot`-style partial selection), cut at
/// **no fewer than `n_hot` entries** when that many distinct nodes exist;
/// `total_accesses` is the count over the *whole* ranking, so a truncated
/// prefix still yields the exact global fraction.
///
/// Edge conventions: 1.0 when there is nothing to measure (no accesses or
/// `n_hot == 0`) so an empty epoch never triggers a shrink; 0.0 when the
/// cache is larger than the distinct remote set — the surplus capacity
/// serves nothing, the clearest shrink signal there is.
pub fn tail_mass_fraction(top: &[(NodeId, u32)], total_accesses: u64, n_hot: u32) -> f64 {
    if total_accesses == 0 {
        return 1.0;
    }
    if (n_hot as usize) > top.len() {
        return 0.0;
    }
    let k = n_hot as usize;
    if k == 0 {
        return 1.0;
    }
    let tail_w = (k / 4).max(1);
    let tail: u64 = top[k - tail_w..k].iter().map(|&(_, c)| c as u64).sum();
    tail as f64 / total_accesses as f64
}

/// The paper's per-worker device memory bound:
/// `Mem_device ≤ 2·n_hot·d + Q·m_max·d` (in f32 elements → bytes).
pub fn device_memory_bound(n_hot: u32, q: u32, m_max: u32, feature_dim: u32) -> u64 {
    (2 * n_hot as u64 + q as u64 * m_max as u64) * feature_dim as u64 * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::BatchMeta;

    /// Batch with the given remote nodes (all marked remote).
    fn batch(remote: &[NodeId]) -> BatchMeta {
        let input_nodes = remote.to_vec();
        let mut mask = vec![0u64; input_nodes.len().div_ceil(64)];
        for j in 0..input_nodes.len() {
            mask[j / 64] |= 1 << (j % 64);
        }
        BatchMeta {
            batch: 0,
            seeds: vec![],
            num_remote: input_nodes.len() as u32,
            input_nodes,
            remote_mask: mask,
        }
    }

    #[test]
    fn top_hot_ranks_by_frequency() {
        // node 5 appears 3×, node 7 2×, node 9 1×
        let batches = vec![batch(&[5, 7]), batch(&[5, 7, 9]), batch(&[5])];
        assert_eq!(top_hot(&batches, 2), vec![5, 7]);
        assert_eq!(top_hot(&batches, 10), vec![5, 7, 9]);
        assert_eq!(top_hot(&batches, 0), Vec::<NodeId>::new());
    }

    #[test]
    fn top_hot_matches_full_sort_reference() {
        // Partial selection must equal the full-sort prefix for every cut
        // size, including cuts landing inside a tie group (nodes 7/9/11 all
        // have count 2; node 3 has count 1).
        let batches = vec![
            batch(&[5, 7, 9, 11]),
            batch(&[5, 7, 9, 11]),
            batch(&[5, 3]),
        ];
        let ranked = remote_frequency(&batches);
        assert_eq!(ranked.len(), 5);
        for k in 0..=ranked.len() + 2 {
            let reference: Vec<NodeId> = ranked.iter().take(k).map(|&(v, _)| v).collect();
            assert_eq!(top_hot(&batches, k as u32), reference, "k = {k}");
        }
    }

    #[test]
    fn buffer_lookup_and_rows() {
        let nodes = [10u32, 20, 30];
        let rows: Vec<f32> = (0..9).map(|x| x as f32).collect();
        let buf = CacheBuffer::new(&nodes, rows, 3);
        assert_eq!(buf.len(), 3);
        assert!(buf.contains(20));
        assert!(!buf.contains(21));
        assert_eq!(buf.row(20).unwrap(), &[3.0, 4.0, 5.0]);
        assert!(buf.row(99).is_none());
    }

    #[test]
    fn trace_buffer_has_index_but_no_rows() {
        let buf = CacheBuffer::new(&[1, 2], Vec::new(), 128);
        assert!(buf.contains(1));
        assert!(buf.row(1).is_none());
    }

    #[test]
    #[should_panic]
    fn buffer_rejects_wrong_row_shape() {
        CacheBuffer::new(&[1, 2], vec![0.0; 5], 3);
    }

    #[test]
    fn ids_by_row_recovers_ranked_insertion_order() {
        // Deliberately non-sorted input: the accessor must return the exact
        // construction order, not id order or hash-iteration order.
        let nodes = [42u32, 7, 99, 3, 58];
        let buf = CacheBuffer::new(&nodes, Vec::new(), 16);
        assert_eq!(buf.ids_by_row(), nodes.to_vec());
        // Rebuilding from the recovered list yields identical row lookups.
        let rows: Vec<f32> = (0..nodes.len() * 2).map(|x| x as f32).collect();
        let full = CacheBuffer::new(&nodes, rows.clone(), 2);
        let rebuilt = CacheBuffer::new(&full.ids_by_row(), rows, 2);
        for &v in &nodes {
            assert_eq!(full.row(v), rebuilt.row(v));
        }
        assert!(CacheBuffer::default().ids_by_row().is_empty());
    }

    #[test]
    fn split_hits_partitions_and_counts() {
        let mut cache = DoubleBufferCache::default();
        cache.install_steady(CacheBuffer::new(&[1, 2, 3], Vec::new(), 4));
        let (mut h, mut m) = (Vec::new(), Vec::new());
        cache.split_hits(&[1, 5, 2, 9], &mut h, &mut m);
        assert_eq!(h, vec![1, 2]);
        assert_eq!(m, vec![5, 9]);
        let s = cache.stats();
        assert_eq!(s.lookups, 4);
        assert_eq!(s.hits, 2);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn swap_promotes_secondary() {
        let mut cache = DoubleBufferCache::default();
        cache.install_steady(CacheBuffer::new(&[1], Vec::new(), 4));
        assert!(!cache.swap_at_epoch_boundary(), "nothing staged yet");
        cache.stage_secondary(CacheBuffer::new(&[2], Vec::new(), 4));
        assert!(cache.swap_at_epoch_boundary());
        assert!(cache.steady().contains(2));
        assert!(!cache.steady().contains(1));
        assert_eq!(cache.swaps(), 1);
        // second swap without restaging is a no-op
        assert!(!cache.swap_at_epoch_boundary());
    }

    #[test]
    fn recommend_n_hot_covers_requested_mass() {
        // node 5: 3 accesses, node 7: 2, node 9: 1 → total 6
        let batches = vec![batch(&[5, 7]), batch(&[5, 7, 9]), batch(&[5])];
        assert_eq!(recommend_n_hot(&batches, 0.5), 1); // 3/6 ≥ 0.5
        assert_eq!(recommend_n_hot(&batches, 0.8), 2); // 5/6 ≥ 0.8
        assert_eq!(recommend_n_hot(&batches, 1.0), 3);
        assert_eq!(recommend_n_hot(&[], 0.8), 0);
    }

    #[test]
    fn tail_mass_fraction_measures_the_marginal_quarter() {
        let ranked: Vec<(NodeId, u32)> = vec![(1, 80), (2, 10), (3, 6), (4, 4)];
        // n_hot = 4 → tail quarter is the last entry: 4/100 of all accesses
        assert!((tail_mass_fraction(&ranked, 100, 4) - 0.04).abs() < 1e-12);
        // n_hot = 2 → tail quarter rounds up to the 2nd entry: 10/100
        assert!((tail_mass_fraction(&ranked, 100, 2) - 0.10).abs() < 1e-12);
        // a truncated prefix with the global total gives the same fraction
        assert!((tail_mass_fraction(&ranked[..2], 100, 2) - 0.10).abs() < 1e-12);
        // cache larger than the distinct remote set: pure surplus
        assert_eq!(tail_mass_fraction(&ranked, 100, 10), 0.0);
        // nothing to measure → never shrink on emptiness
        assert_eq!(tail_mass_fraction(&[], 0, 4), 1.0);
        assert_eq!(tail_mass_fraction(&ranked, 100, 0), 1.0);
    }

    #[test]
    fn memory_bound_formula() {
        // 2·n_hot·d + Q·m_max·d, d=100, f32
        assert_eq!(device_memory_bound(1000, 4, 25_000, 100), (2_000 + 100_000) * 100 * 4);
    }

    #[test]
    fn double_buffer_bytes_counts_both() {
        let mut cache = DoubleBufferCache::default();
        cache.install_steady(CacheBuffer::new(&[1, 2], vec![0.0; 8], 4));
        let one = cache.device_bytes();
        cache.stage_secondary(CacheBuffer::new(&[3, 4], vec![0.0; 8], 4));
        assert_eq!(cache.device_bytes(), 2 * one);
    }
}
