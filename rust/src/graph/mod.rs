//! Graph substrate: CSR storage, synthetic generators, and labeled datasets.
//!
//! The paper's datasets (Reddit, OGBN-Products, OGBN-Papers100M) are
//! substituted with Chung–Lu power-law graphs of matched shape — the long-tail
//! degree distribution that drives RapidGNN's hot-set cache (paper Fig. 3) is
//! a direct consequence of the power-law expected-degree sequence used here.

mod csr;
mod dataset;
mod generate;

pub use csr::CsrGraph;
pub use dataset::{Dataset, build_dataset};
pub use generate::{chung_lu, degree_stats, rmat, DegreeStats};
