//! Compressed-sparse-row graph storage.
//!
//! Undirected graphs are stored with both edge directions so `neighbors(v)`
//! is a single contiguous slice — the access pattern the k-hop sampler hits
//! millions of times per epoch.

use crate::NodeId;

/// A graph in CSR form. Node ids are dense `0..num_nodes`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CsrGraph {
    /// `indptr[v]..indptr[v+1]` indexes `indices` for node v's neighbors.
    indptr: Vec<u64>,
    /// Flattened adjacency lists.
    indices: Vec<NodeId>,
}

impl CsrGraph {
    /// Build a CSR graph from an (unsorted) edge list. Each `(u, v)` pair is
    /// inserted in both directions; self-loops are kept once per direction
    /// given; duplicate edges are preserved (multigraph semantics — the
    /// uniform sampler treats parallel edges as higher transition weight,
    /// matching DGL's behaviour on raw edge lists).
    pub fn from_edges(num_nodes: u32, edges: &[(NodeId, NodeId)]) -> Self {
        let n = num_nodes as usize;
        let mut degree = vec![0u64; n];
        for &(u, v) in edges {
            debug_assert!(u < num_nodes && v < num_nodes);
            degree[u as usize] += 1;
            degree[v as usize] += 1;
        }
        let mut indptr = vec![0u64; n + 1];
        for v in 0..n {
            indptr[v + 1] = indptr[v] + degree[v];
        }
        let mut cursor: Vec<u64> = indptr[..n].to_vec();
        let mut indices = vec![0 as NodeId; indptr[n] as usize];
        for &(u, v) in edges {
            indices[cursor[u as usize] as usize] = v;
            cursor[u as usize] += 1;
            indices[cursor[v as usize] as usize] = u;
            cursor[v as usize] += 1;
        }
        CsrGraph { indptr, indices }
    }

    /// Build directly from CSR arrays (used by the storage layer).
    pub fn from_raw(indptr: Vec<u64>, indices: Vec<NodeId>) -> Self {
        assert!(!indptr.is_empty(), "indptr must have n+1 entries");
        assert_eq!(*indptr.last().unwrap() as usize, indices.len());
        CsrGraph { indptr, indices }
    }

    /// Number of nodes.
    pub fn num_nodes(&self) -> u32 {
        (self.indptr.len() - 1) as u32
    }

    /// Number of directed edges (2× undirected edge count).
    pub fn num_directed_edges(&self) -> u64 {
        self.indices.len() as u64
    }

    /// Degree of node `v`.
    pub fn degree(&self, v: NodeId) -> u32 {
        (self.indptr[v as usize + 1] - self.indptr[v as usize]) as u32
    }

    /// Neighbor slice of node `v`.
    pub fn neighbors(&self, v: NodeId) -> &[NodeId] {
        let s = self.indptr[v as usize] as usize;
        let e = self.indptr[v as usize + 1] as usize;
        &self.indices[s..e]
    }

    /// Raw CSR arrays `(indptr, indices)`.
    pub fn raw(&self) -> (&[u64], &[NodeId]) {
        (&self.indptr, &self.indices)
    }

    /// Approximate heap size in bytes (for Fig-7 memory accounting).
    pub fn heap_bytes(&self) -> u64 {
        (self.indptr.len() * 8 + self.indices.len() * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path3() -> CsrGraph {
        // 0 - 1 - 2
        CsrGraph::from_edges(3, &[(0, 1), (1, 2)])
    }

    #[test]
    fn degrees_and_neighbors() {
        let g = path3();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_directed_edges(), 4);
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.degree(2), 1);
        assert_eq!(g.neighbors(0), &[1]);
        let mut n1 = g.neighbors(1).to_vec();
        n1.sort();
        assert_eq!(n1, vec![0, 2]);
    }

    #[test]
    fn isolated_nodes_allowed() {
        let g = CsrGraph::from_edges(4, &[(0, 1)]);
        assert_eq!(g.degree(2), 0);
        assert_eq!(g.degree(3), 0);
        assert!(g.neighbors(3).is_empty());
    }

    #[test]
    fn duplicate_edges_preserved() {
        let g = CsrGraph::from_edges(2, &[(0, 1), (0, 1)]);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.neighbors(0), &[1, 1]);
    }

    #[test]
    fn raw_round_trip() {
        let g = path3();
        let (p, i) = g.raw();
        let g2 = CsrGraph::from_raw(p.to_vec(), i.to_vec());
        assert_eq!(g, g2);
    }

    #[test]
    #[should_panic]
    fn from_raw_rejects_inconsistent() {
        CsrGraph::from_raw(vec![0, 5], vec![0]);
    }
}
