//! Synthetic power-law graph generation (Chung–Lu / ACL model).
//!
//! The generator draws a fixed number of edges with endpoint probability
//! proportional to a power-law expected-degree sequence — producing the
//! long-tail degree (and therefore feature-access) distribution that paper
//! Fig. 3 demonstrates and RapidGNN's hot-set cache exploits. A homophily
//! parameter biases endpoints toward same-class pairs so the planted labels
//! are learnable by a GNN (needed for the Fig-9 convergence experiment).

use crate::sampler::seed::Rng;
use crate::NodeId;

/// Walker alias table for O(1) weighted sampling.
#[derive(Debug, Clone)]
pub(crate) struct WeightedAlias {
    prob: Vec<f64>,
    alias: Vec<u32>,
    /// Items with nonzero weight (empty table is invalid).
    len: u32,
}

impl WeightedAlias {
    /// Build from non-negative weights. Panics if all weights are zero.
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        assert!(n > 0, "alias table needs at least one weight");
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "alias table needs positive total weight");
        let scale = n as f64 / total;
        let mut prob: Vec<f64> = weights.iter().map(|w| w * scale).collect();
        let mut alias = vec![0u32; n];
        let (mut small, mut large): (Vec<u32>, Vec<u32>) = (Vec::new(), Vec::new());
        for (i, &p) in prob.iter().enumerate() {
            if p < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            alias[s as usize] = l;
            prob[l as usize] -= 1.0 - prob[s as usize];
            if prob[l as usize] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        WeightedAlias { prob, alias, len: n as u32 }
    }

    /// Draw one index.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        let i = rng.below(self.len);
        if rng.f64() < self.prob[i as usize] {
            i
        } else {
            self.alias[i as usize]
        }
    }
}

/// Per-node expected weights: `w_v ∝ (v+1)^(-1/(γ-1))`, normalized to mean 1.
fn power_law_weights(n: u32, exponent: f64) -> Vec<f64> {
    let alpha = 1.0 / (exponent - 1.0);
    let mut w: Vec<f64> = (0..n).map(|v| ((v + 1) as f64).powf(-alpha)).collect();
    let mean = w.iter().sum::<f64>() / n as f64;
    for x in &mut w {
        *x /= mean;
    }
    w
}

/// Generate a Chung–Lu power-law graph with planted class communities.
///
/// `classes[v]` gives each node's class. With probability `homophily` an
/// edge's second endpoint is redrawn from the same class as the first, which
/// plants community structure aligned with the labels. Nodes are implicitly
/// ordered hub-first (node 0 has the highest expected degree); callers should
/// not rely on id order — the partitioners don't.
pub fn chung_lu(
    num_nodes: u32,
    avg_degree: f64,
    exponent: f64,
    classes: &[u16],
    num_classes: u32,
    homophily: f64,
    seed: u64,
) -> Vec<(NodeId, NodeId)> {
    assert_eq!(classes.len(), num_nodes as usize);
    let weights = power_law_weights(num_nodes, exponent);
    let global = WeightedAlias::new(&weights);

    // Per-class alias tables over that class's members.
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); num_classes as usize];
    for (v, &c) in classes.iter().enumerate() {
        members[c as usize].push(v as u32);
    }
    let per_class: Vec<Option<(WeightedAlias, &Vec<u32>)>> = members
        .iter()
        .map(|m| {
            if m.is_empty() {
                None
            } else {
                let w: Vec<f64> = m.iter().map(|&v| weights[v as usize]).collect();
                Some((WeightedAlias::new(&w), m))
            }
        })
        .collect();

    let num_edges = (num_nodes as f64 * avg_degree / 2.0) as u64;
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(num_edges as usize);
    while (edges.len() as u64) < num_edges {
        let u = global.sample(&mut rng);
        let v = if rng.f64() < homophily {
            match &per_class[classes[u as usize] as usize] {
                Some((alias, m)) => m[alias.sample(&mut rng) as usize],
                None => global.sample(&mut rng),
            }
        } else {
            global.sample(&mut rng)
        };
        if u != v {
            edges.push((u, v));
        }
    }
    edges
}

/// R-MAT graph generator (Chakrabarti et al.) — the alternative power-law
/// generator; used by ablation studies to check that RapidGNN's wins are not
/// an artifact of the Chung–Lu construction. Standard (a,b,c,d) recursive
/// quadrant descent; `scale` = log2(#nodes).
pub fn rmat(
    scale: u32,
    avg_degree: f64,
    (a, b, c): (f64, f64, f64),
    seed: u64,
) -> Vec<(NodeId, NodeId)> {
    assert!(scale >= 2 && scale <= 26);
    let d = 1.0 - a - b - c;
    assert!(a > 0.0 && b > 0.0 && c > 0.0 && d > 0.0, "quadrant probs must be positive");
    let n = 1u64 << scale;
    let num_edges = (n as f64 * avg_degree / 2.0) as u64;
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(num_edges as usize);
    while (edges.len() as u64) < num_edges {
        let (mut lo_u, mut lo_v) = (0u64, 0u64);
        let mut half = n >> 1;
        while half > 0 {
            let r = rng.f64();
            let (du, dv) = if r < a {
                (0, 0)
            } else if r < a + b {
                (0, 1)
            } else if r < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            lo_u += du * half;
            lo_v += dv * half;
            half >>= 1;
        }
        if lo_u != lo_v {
            edges.push((lo_u as NodeId, lo_v as NodeId));
        }
    }
    edges
}

/// Summary degree statistics for validating the generated distribution.
#[derive(Debug, Clone, PartialEq)]
pub struct DegreeStats {
    pub mean: f64,
    pub max: u32,
    pub p50: u32,
    pub p99: u32,
    /// Fraction of total degree mass held by the top 1% of nodes — the
    /// concentration metric behind the hot-set cache.
    pub top1pct_mass: f64,
}

/// Compute [`DegreeStats`] for a CSR graph.
pub fn degree_stats(g: &super::CsrGraph) -> DegreeStats {
    let n = g.num_nodes();
    let mut degs: Vec<u32> = (0..n).map(|v| g.degree(v)).collect();
    degs.sort_unstable();
    let total: u64 = degs.iter().map(|&d| d as u64).sum();
    let top_k = ((n as usize) / 100).max(1);
    let top_mass: u64 = degs[n as usize - top_k..].iter().map(|&d| d as u64).sum();
    DegreeStats {
        mean: total as f64 / n as f64,
        max: *degs.last().unwrap_or(&0),
        p50: degs[n as usize / 2],
        p99: degs[(n as usize * 99) / 100],
        top1pct_mass: top_mass as f64 / total.max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::CsrGraph;

    fn round_robin_classes(n: u32, c: u32) -> Vec<u16> {
        (0..n).map(|v| (v % c) as u16).collect()
    }

    #[test]
    fn alias_table_matches_weights() {
        let w = [1.0, 2.0, 7.0];
        let alias = WeightedAlias::new(&w);
        let mut rng = Rng::new(3);
        let mut counts = [0u32; 3];
        let n = 200_000;
        for _ in 0..n {
            counts[alias.sample(&mut rng) as usize] += 1;
        }
        let total: f64 = w.iter().sum();
        for i in 0..3 {
            let expected = w[i] / total;
            let got = counts[i] as f64 / n as f64;
            assert!((got - expected).abs() < 0.01, "weight {i}: {got} vs {expected}");
        }
    }

    #[test]
    #[should_panic]
    fn alias_rejects_zero_total() {
        WeightedAlias::new(&[0.0, 0.0]);
    }

    #[test]
    fn generator_deterministic() {
        let classes = round_robin_classes(500, 4);
        let a = chung_lu(500, 8.0, 2.2, &classes, 4, 0.5, 99);
        let b = chung_lu(500, 8.0, 2.2, &classes, 4, 0.5, 99);
        assert_eq!(a, b);
    }

    #[test]
    fn generator_hits_target_edge_count_and_degree() {
        let n = 5_000;
        let classes = round_robin_classes(n, 8);
        let edges = chung_lu(n, 10.0, 2.2, &classes, 8, 0.4, 1);
        let g = CsrGraph::from_edges(n, &edges);
        let stats = degree_stats(&g);
        assert!((stats.mean - 10.0).abs() < 0.5, "mean degree {}", stats.mean);
    }

    #[test]
    fn degree_distribution_is_long_tailed() {
        // The property paper Fig. 3 rests on: a small set of hub nodes holds a
        // disproportionate share of degree mass.
        let n = 20_000;
        let classes = round_robin_classes(n, 4);
        let edges = chung_lu(n, 15.0, 2.0, &classes, 4, 0.3, 5);
        let g = CsrGraph::from_edges(n, &edges);
        let stats = degree_stats(&g);
        assert!(stats.top1pct_mass > 0.15, "top-1% mass {}", stats.top1pct_mass);
        assert!(stats.max as f64 > 20.0 * stats.mean, "max {} mean {}", stats.max, stats.mean);
        assert!(stats.p50 <= stats.p99);
    }

    #[test]
    fn homophily_plants_communities() {
        let n = 4_000;
        let classes = round_robin_classes(n, 4);
        let hi = chung_lu(n, 10.0, 2.2, &classes, 4, 0.8, 2);
        let lo = chung_lu(n, 10.0, 2.2, &classes, 4, 0.0, 2);
        let frac_same = |edges: &[(u32, u32)]| {
            let same = edges
                .iter()
                .filter(|&&(u, v)| classes[u as usize] == classes[v as usize])
                .count();
            same as f64 / edges.len() as f64
        };
        assert!(frac_same(&hi) > frac_same(&lo) + 0.3);
    }

    #[test]
    fn rmat_is_deterministic_and_skewed() {
        let e1 = rmat(12, 8.0, (0.57, 0.19, 0.19), 3);
        let e2 = rmat(12, 8.0, (0.57, 0.19, 0.19), 3);
        assert_eq!(e1, e2);
        let g = CsrGraph::from_edges(1 << 12, &e1);
        let stats = degree_stats(&g);
        assert!((stats.mean - 8.0).abs() < 0.5, "mean {}", stats.mean);
        // the standard RMAT parameters produce a heavy tail
        assert!(stats.top1pct_mass > 0.10, "top-1% mass {}", stats.top1pct_mass);
        assert!(e1.iter().all(|&(u, v)| u != v));
    }

    #[test]
    #[should_panic]
    fn rmat_rejects_degenerate_probs() {
        rmat(10, 4.0, (0.5, 0.5, 0.1), 1); // a+b+c > 1
    }

    #[test]
    fn no_self_loops() {
        let classes = round_robin_classes(1_000, 2);
        let edges = chung_lu(1_000, 6.0, 2.2, &classes, 2, 0.5, 4);
        assert!(edges.iter().all(|&(u, v)| u != v));
    }
}
