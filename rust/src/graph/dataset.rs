//! Labeled dataset assembly: graph + features + labels + train split.

use super::{chung_lu, CsrGraph};
use crate::config::DatasetConfig;
use crate::sampler::seed::{mix64, Rng};
use crate::NodeId;
use std::sync::Arc;

/// A fully materialized synthetic dataset.
#[derive(Debug)]
pub struct Dataset {
    /// Dataset configuration this was generated from.
    pub config: DatasetConfig,
    /// Graph topology.
    pub graph: Arc<CsrGraph>,
    /// Node class labels.
    pub labels: Vec<u16>,
    /// Training-seed node ids (stable order).
    pub train_nodes: Vec<NodeId>,
    /// Row-major `[num_nodes, feature_dim]` feature matrix; empty if the
    /// dataset was built metadata-only (`with_features = false`).
    pub features: Vec<f32>,
}

impl Dataset {
    /// Feature row of node `v`. Panics if features were not materialized.
    pub fn feature_row(&self, v: NodeId) -> &[f32] {
        let d = self.config.feature_dim as usize;
        &self.features[v as usize * d..(v as usize + 1) * d]
    }

    /// Whether feature values are materialized.
    pub fn has_features(&self) -> bool {
        !self.features.is_empty()
    }

    /// Number of batches per epoch per worker given `batch_size` and P
    /// (train nodes are sharded across workers; DGL convention: each worker
    /// iterates its own shard).
    pub fn batches_per_epoch(&self, batch_size: u32, num_workers: u32) -> u32 {
        let per_worker = self.train_nodes.len() as u32 / num_workers.max(1);
        per_worker.div_ceil(batch_size).max(1)
    }
}

/// Generate the dataset described by `cfg`.
///
/// Fully deterministic in `cfg.gen_seed`. Labels are assigned by hash (so
/// classes are roughly balanced and uncorrelated with the hub-first id
/// order), edges are drawn with homophily toward same-class endpoints, and
/// features are `centroid(class) + noise`.
pub fn build_dataset(cfg: &DatasetConfig, with_features: bool) -> Dataset {
    let n = cfg.num_nodes;
    let c = cfg.num_classes;

    // Labels: hash-based, balanced in expectation.
    let labels: Vec<u16> = (0..n)
        .map(|v| (mix64(cfg.gen_seed ^ 0xC1A55 ^ v as u64) % c as u64) as u16)
        .collect();

    let edges = chung_lu(
        n,
        cfg.avg_degree,
        cfg.power_law_exponent,
        &labels,
        c,
        cfg.homophily,
        cfg.gen_seed ^ 0xED6E5,
    );
    let graph = Arc::new(CsrGraph::from_edges(n, &edges));

    // Train split: hash-selected subset, stable sorted order.
    let thresh = (cfg.train_fraction * u32::MAX as f64) as u64;
    let train_nodes: Vec<NodeId> = (0..n)
        .filter(|&v| mix64(cfg.gen_seed ^ 0x7EA1 ^ v as u64) % (u32::MAX as u64) < thresh)
        .collect();

    // Features: class centroid + Gaussian noise. Centroids are random unit-ish
    // directions so classes are linearly separable-ish before message passing;
    // homophily makes neighborhood aggregation strictly more informative.
    let d = cfg.feature_dim as usize;
    let features = if with_features {
        let mut centroids = vec![0f32; c as usize * d];
        for k in 0..c as usize {
            let mut rng = Rng::new(mix64(cfg.gen_seed ^ 0xCE17 ^ k as u64));
            for j in 0..d {
                centroids[k * d + j] = rng.normal() * 1.5;
            }
        }
        let mut feats = vec![0f32; n as usize * d];
        // Parallel per-node generation, seeded per node for determinism
        // independent of thread scheduling.
        crate::util::parallel::par_chunks_mut(&mut feats, d, |v, row| {
            let k = labels[v] as usize;
            let mut rng = Rng::new(mix64(cfg.gen_seed ^ 0xFEA7 ^ v as u64));
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = centroids[k * d + j] + cfg.feature_noise as f32 * rng.normal();
            }
        });
        feats
    } else {
        Vec::new()
    };

    Dataset {
        config: cfg.clone(),
        graph,
        labels,
        train_nodes,
        features,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetPreset};

    fn tiny() -> DatasetConfig {
        DatasetConfig::preset(DatasetPreset::Tiny, 1.0)
    }

    #[test]
    fn deterministic_build() {
        let a = build_dataset(&tiny(), true);
        let b = build_dataset(&tiny(), true);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.train_nodes, b.train_nodes);
        assert_eq!(a.features, b.features);
        assert_eq!(a.graph.raw().0, b.graph.raw().0);
    }

    #[test]
    fn train_fraction_respected() {
        let ds = build_dataset(&tiny(), false);
        let frac = ds.train_nodes.len() as f64 / ds.config.num_nodes as f64;
        assert!((frac - ds.config.train_fraction).abs() < 0.05, "frac {frac}");
        assert!(!ds.has_features());
    }

    #[test]
    fn labels_roughly_balanced() {
        let ds = build_dataset(&tiny(), false);
        let c = ds.config.num_classes as usize;
        let mut counts = vec![0usize; c];
        for &l in &ds.labels {
            counts[l as usize] += 1;
        }
        let expected = ds.config.num_nodes as usize / c;
        for &cnt in &counts {
            assert!(cnt > expected / 2 && cnt < expected * 2, "count {cnt} vs {expected}");
        }
    }

    #[test]
    fn features_cluster_by_class() {
        // mean intra-class feature distance < inter-class distance
        let ds = build_dataset(&tiny(), true);
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>()
        };
        let (mut intra, mut inter, mut ni, mut nx) = (0f64, 0f64, 0u64, 0u64);
        for v in 0..200u32 {
            for u in 200..400u32 {
                let dd = dist(ds.feature_row(v), ds.feature_row(u)) as f64;
                if ds.labels[v as usize] == ds.labels[u as usize] {
                    intra += dd;
                    ni += 1;
                } else {
                    inter += dd;
                    nx += 1;
                }
            }
        }
        assert!(ni > 0 && nx > 0);
        let (mean_intra, mean_inter) = (intra / ni as f64, inter / nx as f64);
        assert!(mean_intra < mean_inter, "intra {mean_intra} !< inter {mean_inter}");
    }

    #[test]
    fn batches_per_epoch_math() {
        let ds = build_dataset(&tiny(), false);
        let b = ds.batches_per_epoch(100, 2);
        let per_worker = ds.train_nodes.len() as u32 / 2;
        assert_eq!(b, per_worker.div_ceil(100));
        // never zero even with absurd batch size
        assert_eq!(ds.batches_per_epoch(10_000_000, 2), 1);
    }
}
