//! Typed configuration for datasets, training runs, and the simulated testbed.
//!
//! Everything a run needs is described by a [`RunConfig`]; dataset presets
//! mirroring the paper's three benchmarks (scaled down per DESIGN.md §3) are
//! provided by [`DatasetConfig::preset`]. Configs serialize to/from a TOML
//! subset (see [`crate::util::value`]) so runs are reproducible from a single
//! file (`rapidgnn train --config run.toml`).

mod dataset;
mod run;

pub use dataset::{DatasetConfig, DatasetPreset};
pub use run::{
    Engine, EngineParams, ExecMode, FabricConfig, FailureEvent, FailurePlan, LinkKey, LinkModel,
    PowerConfig, RouteHop, RunConfig, SpeedPhase, Topology, TrainerBackend,
};

use crate::util::value::Value;
use crate::Result;
use std::path::Path;

/// Load a [`RunConfig`] from a TOML file.
pub fn load_run_config(path: &Path) -> Result<RunConfig> {
    let text = std::fs::read_to_string(path)?;
    let v = Value::from_toml(&text)?;
    RunConfig::from_value(&v)
}

/// Save a [`RunConfig`] to a TOML file.
pub fn save_run_config(cfg: &RunConfig, path: &Path) -> Result<()> {
    let text = cfg.to_value().to_toml()?;
    std::fs::write(path, text)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toml_round_trip() {
        let cfg = RunConfig::default();
        let text = cfg.to_value().to_toml().unwrap();
        let back = RunConfig::from_value(&Value::from_toml(&text).unwrap()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn file_round_trip() {
        let dir = crate::util::tempdir::TempDir::new("cfg").unwrap();
        let path = dir.path().join("run.toml");
        let cfg = RunConfig::default();
        save_run_config(&cfg, &path).unwrap();
        let back = load_run_config(&path).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn load_missing_file_errors() {
        assert!(load_run_config(Path::new("/nonexistent/run.toml")).is_err());
    }
}
