//! Dataset descriptions and the three paper-benchmark presets.
//!
//! The paper evaluates on Reddit (233k nodes, d=602), OGBN-Products (2.45M,
//! d=100) and OGBN-Papers100M (111M, d=128). We cannot ship those datasets, so
//! each preset describes a *synthetic power-law graph with matched shape*:
//! matched feature dimensionality, class count, and average-degree ratio, with
//! node counts scaled down so the full matrix of experiments runs on one
//! machine (DESIGN.md §3). The long-tail degree distribution — the property
//! RapidGNN's hot-set cache exploits (paper Fig. 3) — is preserved by the
//! Chung–Lu generator in [`crate::graph`].

use crate::util::value::Value;
use crate::Result;
use anyhow::bail;

/// Named presets mirroring the paper's three benchmark datasets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DatasetPreset {
    /// Reddit-like: high feature dim (602), very dense, strongest skew.
    RedditSim,
    /// OGBN-Products-like: d=100, 47 classes, moderate density.
    ProductsSim,
    /// OGBN-Papers100M-like: d=128, 172 classes, largest node count.
    PapersSim,
    /// Tiny graph for unit tests and the quickstart example.
    Tiny,
}

impl DatasetPreset {
    /// All presets used in the paper's evaluation (excludes `Tiny`).
    pub const PAPER: [DatasetPreset; 3] = [
        DatasetPreset::RedditSim,
        DatasetPreset::ProductsSim,
        DatasetPreset::PapersSim,
    ];

    /// Short display name used in bench tables.
    pub fn name(&self) -> &'static str {
        match self {
            DatasetPreset::RedditSim => "reddit-sim",
            DatasetPreset::ProductsSim => "products-sim",
            DatasetPreset::PapersSim => "papers-sim",
            DatasetPreset::Tiny => "tiny",
        }
    }
}

impl std::str::FromStr for DatasetPreset {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "reddit-sim" | "reddit" => DatasetPreset::RedditSim,
            "products-sim" | "products" => DatasetPreset::ProductsSim,
            "papers-sim" | "papers" => DatasetPreset::PapersSim,
            "tiny" => DatasetPreset::Tiny,
            _ => bail!("unknown dataset preset '{s}' (reddit-sim|products-sim|papers-sim|tiny)"),
        })
    }
}

/// Full description of a synthetic dataset: graph shape + feature/label model.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetConfig {
    /// Human-readable dataset name.
    pub name: String,
    /// Number of nodes in the graph.
    pub num_nodes: u32,
    /// Target average degree (undirected edges are stored in both directions).
    pub avg_degree: f64,
    /// Power-law exponent for the Chung–Lu expected-degree sequence.
    /// Real social/product graphs sit around 2.0–2.5; lower = heavier tail.
    pub power_law_exponent: f64,
    /// Feature dimensionality `d` (matches the paper's datasets).
    pub feature_dim: u32,
    /// Number of node classes.
    pub num_classes: u32,
    /// Fraction of nodes in the training set (seeds are drawn from these).
    pub train_fraction: f64,
    /// Intra-class edge preference in [0,1]; >0 plants community structure so
    /// a GNN can actually learn (needed for the Fig-9 convergence experiment).
    pub homophily: f64,
    /// Feature noise scale: features are class centroid + noise*N(0,1).
    pub feature_noise: f64,
    /// Base RNG seed for graph/feature generation (fully deterministic).
    pub gen_seed: u64,
}

impl DatasetConfig {
    /// Construct the scaled preset for one of the paper's benchmarks.
    ///
    /// `scale` multiplies the node count (1.0 = the default scaled-down size;
    /// benches use smaller scales for sweeps, the e2e example uses 1.0).
    pub fn preset(p: DatasetPreset, scale: f64) -> Self {
        let base = match p {
            // Paper: 232,965 nodes, 114.8M edges (avg deg ~493 — we cap at a
            // still-dense 50 to keep CSR memory sane), d=602, 50 classes.
            DatasetPreset::RedditSim => DatasetConfig {
                name: "reddit-sim".into(),
                num_nodes: 60_000,
                avg_degree: 50.0,
                power_law_exponent: 1.9, // heaviest tail of the three
                feature_dim: 602,
                num_classes: 50,
                train_fraction: 0.66,
                homophily: 0.6,
                feature_noise: 1.0,
                gen_seed: 0x5EDD17,
            },
            // Paper: 2.45M nodes, 123.7M edges (avg deg ~50), d=100, 47 classes.
            DatasetPreset::ProductsSim => DatasetConfig {
                name: "products-sim".into(),
                num_nodes: 120_000,
                avg_degree: 25.0,
                power_law_exponent: 2.1,
                feature_dim: 100,
                num_classes: 47,
                train_fraction: 0.08, // OGBN-Products has a small train split
                homophily: 0.6,
                feature_noise: 1.0,
                gen_seed: 0x9A0D,
            },
            // Paper: 111M nodes, 1.62B edges (avg deg ~29), d=128, 172 classes.
            DatasetPreset::PapersSim => DatasetConfig {
                name: "papers-sim".into(),
                num_nodes: 250_000,
                avg_degree: 15.0,
                power_law_exponent: 2.3, // citation graphs: lighter tail
                feature_dim: 128,
                num_classes: 172,
                train_fraction: 0.01,
                homophily: 0.5,
                feature_noise: 1.0,
                gen_seed: 0x9A9E,
            },
            DatasetPreset::Tiny => DatasetConfig {
                name: "tiny".into(),
                num_nodes: 2_000,
                avg_degree: 8.0,
                power_law_exponent: 2.2,
                feature_dim: 16,
                num_classes: 4,
                train_fraction: 0.5,
                homophily: 0.7,
                feature_noise: 0.5,
                gen_seed: 7,
            },
        };
        base.scaled(scale)
    }

    /// Return a copy with the node count scaled by `scale` (min 1k nodes).
    pub fn scaled(mut self, scale: f64) -> Self {
        if (scale - 1.0).abs() > f64::EPSILON {
            self.num_nodes = ((self.num_nodes as f64 * scale) as u32).max(1_000);
        }
        self
    }

    /// Bytes per node feature row (f32 features).
    pub fn feature_row_bytes(&self) -> u64 {
        self.feature_dim as u64 * 4
    }

    /// Serialize to a [`Value`] table.
    pub fn to_value(&self) -> Value {
        let mut v = Value::table();
        v.set("name", self.name.as_str())
            .set("num_nodes", self.num_nodes)
            .set("avg_degree", self.avg_degree)
            .set("power_law_exponent", self.power_law_exponent)
            .set("feature_dim", self.feature_dim)
            .set("num_classes", self.num_classes)
            .set("train_fraction", self.train_fraction)
            .set("homophily", self.homophily)
            .set("feature_noise", self.feature_noise)
            .set("gen_seed", self.gen_seed);
        v
    }

    /// Deserialize from a [`Value`] table.
    pub fn from_value(v: &Value) -> Result<Self> {
        Ok(DatasetConfig {
            name: v.req_str("name")?.to_string(),
            num_nodes: v.req_u32("num_nodes")?,
            avg_degree: v.req_f64("avg_degree")?,
            power_law_exponent: v.req_f64("power_law_exponent")?,
            feature_dim: v.req_u32("feature_dim")?,
            num_classes: v.req_u32("num_classes")?,
            train_fraction: v.req_f64("train_fraction")?,
            homophily: v.req_f64("homophily")?,
            feature_noise: v.req_f64("feature_noise")?,
            gen_seed: v.req_u64("gen_seed")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_dims() {
        let r = DatasetConfig::preset(DatasetPreset::RedditSim, 1.0);
        assert_eq!(r.feature_dim, 602);
        assert_eq!(r.num_classes, 50);
        let p = DatasetConfig::preset(DatasetPreset::ProductsSim, 1.0);
        assert_eq!(p.feature_dim, 100);
        assert_eq!(p.num_classes, 47);
        let q = DatasetConfig::preset(DatasetPreset::PapersSim, 1.0);
        assert_eq!(q.feature_dim, 128);
        assert_eq!(q.num_classes, 172);
    }

    #[test]
    fn scaling_shrinks_nodes_only() {
        let full = DatasetConfig::preset(DatasetPreset::ProductsSim, 1.0);
        let half = DatasetConfig::preset(DatasetPreset::ProductsSim, 0.5);
        assert_eq!(half.num_nodes, full.num_nodes / 2);
        assert_eq!(half.feature_dim, full.feature_dim);
    }

    #[test]
    fn scaling_floors_at_1k() {
        let tiny = DatasetConfig::preset(DatasetPreset::ProductsSim, 1e-9);
        assert_eq!(tiny.num_nodes, 1_000);
    }

    #[test]
    fn feature_row_bytes_reddit() {
        let r = DatasetConfig::preset(DatasetPreset::RedditSim, 1.0);
        assert_eq!(r.feature_row_bytes(), 602 * 4);
    }

    #[test]
    fn value_round_trip() {
        let c = DatasetConfig::preset(DatasetPreset::RedditSim, 1.0);
        let back = DatasetConfig::from_value(&c.to_value()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn preset_from_str() {
        use std::str::FromStr;
        assert_eq!(DatasetPreset::from_str("reddit-sim").unwrap(), DatasetPreset::RedditSim);
        assert_eq!(DatasetPreset::from_str("papers").unwrap(), DatasetPreset::PapersSim);
        assert!(DatasetPreset::from_str("nope").is_err());
    }
}
