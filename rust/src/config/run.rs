//! Run configuration: which engine, dataset, testbed and trainer to use.

use super::dataset::DatasetConfig;
use crate::compress::{Codec, GradMode};
use crate::util::value::Value;
use crate::Result;
use anyhow::{bail, ensure};
use std::str::FromStr;

/// Training-engine id, resolved against the strategy registry
/// ([`crate::coordinator::EngineRegistry`]).
///
/// Thin by design: the config only *names* the engine — all behavior lives in
/// the [`crate::coordinator::TrainingStrategy`] the registry constructs for
/// this id (partitioner, fan-out policy, setup, staging, epoch bookkeeping).
/// Parsing validates against the registry, so every `Engine` obtained through
/// [`FromStr`] or the `Engine::Rapid`-style constants names a registered
/// strategy. Per-engine tuning knobs live in [`EngineParams`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Engine(&'static str);

#[allow(non_upper_case_globals)] // variant-style names predate the registry
impl Engine {
    /// The paper's system: deterministic schedule + hot-set cache + prefetcher.
    pub const Rapid: Engine = Engine("rapid");
    /// DistDGL-style GraphSAGE with METIS-like partitions, on-demand fetch.
    pub const DglMetis: Engine = Engine("dgl-metis");
    /// DistDGL-style GraphSAGE with random partitions, on-demand fetch.
    pub const DglRandom: Engine = Engine("dgl-random");
    /// Dist-GCN baseline: full-neighborhood k-hop expansion, on-demand fetch.
    pub const DistGcn: Engine = Engine("dist-gcn");
    /// FastSample-style periodic re-sampling: the schedule is re-enumerated
    /// every `EngineParams::resample_period` epochs and replayed in between.
    pub const FastSample: Engine = Engine("fast-sample");
    /// GreenGNN-style windowed communication: remote fetches of
    /// `EngineParams::fetch_window` consecutive batches merge into one pull.
    pub const GreenWindow: Engine = Engine("green-window");
    /// RapidGNN with a per-epoch hot-cache controller: `n_hot` is resized
    /// between epochs from observed hit rates and the ranking's marginal
    /// tail, clamped to `[min_hot, max_hot]` with hysteresis.
    pub const AdaptiveCache: Engine = Engine("adaptive-cache");
    /// RapidGNN shipping quantized feature rows: every remote pull charges
    /// the fabric the compressed payload of `EngineParams::codec` (int8 by
    /// default) instead of full-precision f32 rows; in full mode the trainer
    /// consumes the dequantized values, so accuracy effects are real.
    pub const QuantPull: Engine = Engine("quant-pull");
    /// RapidGNN with error-feedback gradient sparsification: each step only
    /// the top (or random) `EngineParams::grad_k` fraction of gradient
    /// coordinates is applied; the dropped mass carries forward as residual.
    pub const GradTopk: Engine = Engine("grad-topk");

    /// The engines compared in the paper's Table 2. The registry may hold
    /// more — `EngineRegistry::engines()` is the full open set.
    pub const ALL: [Engine; 4] = [
        Engine::Rapid,
        Engine::DglMetis,
        Engine::DglRandom,
        Engine::DistGcn,
    ];

    /// Display name used in bench tables (from the registry entry).
    pub fn name(&self) -> &'static str {
        crate::coordinator::EngineRegistry::global()
            .display_name(self.0)
            .unwrap_or(self.0)
    }

    /// Config-file identifier (the registry key).
    pub fn id(&self) -> &'static str {
        self.0
    }

    /// Registry-internal constructor: wrap a registry key as an `Engine`.
    /// Only the registry hands out ids, so the value always resolves.
    pub(crate) fn from_registry_id(id: &'static str) -> Engine {
        Engine(id)
    }
}

impl FromStr for Engine {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        // Historical aliases, kept for old config files and muscle memory.
        let wanted = match s {
            "rapidgnn" => "rapid",
            "gcn" => "dist-gcn",
            other => other,
        };
        let reg = crate::coordinator::EngineRegistry::global();
        match reg.canonical_id(wanted) {
            Some(id) => Ok(Engine(id)),
            None => bail!(
                "unknown engine '{s}' (registered: {})",
                reg.ids().collect::<Vec<_>>().join("|")
            ),
        }
    }
}

/// Per-engine tuning parameters.
///
/// One flat struct rather than a per-engine map so the TOML round-trip stays
/// trivial and typed; each strategy reads only its own fields and ignores the
/// rest. All fields have engine-neutral defaults, so configs written before
/// an engine existed still load.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EngineParams {
    /// `fast-sample`: re-enumerate the schedule every `k` epochs; epochs
    /// inside a period replay the period-start schedule, amortizing the
    /// precompute pass (and its cache rebuilds) over `k` epochs.
    pub resample_period: u32,
    /// `green-window`: number of consecutive batches whose remote fetches
    /// are merged into one windowed pull — fewer, larger RPCs at the price
    /// of first-step latency per window.
    pub fetch_window: u32,
    /// `adaptive-cache`: evaluate the resize controller at every k-th epoch
    /// boundary. 0 disables the controller entirely, which degenerates the
    /// engine to `rapid` bit-exactly (pinned by a test).
    pub resize_period: u32,
    /// `adaptive-cache`: lower clamp on the controller's `n_hot`.
    pub min_hot: u32,
    /// `adaptive-cache`: upper clamp on the controller's `n_hot` (the memory
    /// envelope the run may never exceed).
    pub max_hot: u32,
    /// `adaptive-cache`: grow `n_hot` while the observed hit rate is below
    /// this target (multiplicative increase by `hot_growth`).
    pub target_hit_rate: f64,
    /// `adaptive-cache`: shrink `n_hot` when the marginal tail (the lowest-
    /// ranked quarter of the hot set) serves less than this fraction of all
    /// remote accesses — those entries are not earning their memory.
    pub tail_utility: f64,
    /// `adaptive-cache`: multiplicative resize factor (> 1; grow multiplies,
    /// shrink divides).
    pub hot_growth: f64,
    /// `adaptive-cache`: after a resize, suppress opposite-direction resizes
    /// for this many controller evaluations (hysteresis against hit-rate
    /// flip-flop).
    pub hysteresis: u32,
    /// Feature wire codec for remote pulls. `Codec::Default` resolves
    /// per-strategy (`quant-pull` → int8, everything else → none); an
    /// explicit `none` disables compression on any engine — the bit-exact
    /// degeneration pin — while `f16`/`int8` enable it on any engine
    /// (notably composing with `green-window`'s merged pulls).
    pub codec: Codec,
    /// Elements per int8 quantization block (8-byte header per block). The
    /// default of 128 keeps header overhead ≤ 6% for d ≥ 100.
    pub codec_block: u32,
    /// `grad-topk`: coordinate selector for gradient sparsification.
    pub grad_mode: GradMode,
    /// `grad-topk`: fraction of gradient coordinates applied per step
    /// (per parameter group, ≥ 1 coordinate when non-zero). 0 disables
    /// sparsification entirely, degenerating the engine to `rapid`.
    pub grad_k: f64,
}

impl Default for EngineParams {
    fn default() -> Self {
        EngineParams {
            resample_period: 4,
            fetch_window: 4,
            resize_period: 1,
            min_hot: 64,
            max_hot: 65_536,
            target_hit_rate: 0.85,
            tail_utility: 0.01,
            hot_growth: 2.0,
            hysteresis: 2,
            codec: Codec::Default,
            codec_block: 128,
            grad_mode: GradMode::TopK,
            grad_k: 0.1,
        }
    }
}

impl EngineParams {
    /// Internal consistency checks (called from [`RunConfig::validate`]).
    pub fn validate(&self) -> Result<()> {
        ensure!(self.resample_period >= 1, "resample_period must be >= 1");
        ensure!(self.fetch_window >= 1, "fetch_window must be >= 1");
        ensure!(self.min_hot >= 1, "min_hot must be >= 1");
        ensure!(self.max_hot >= self.min_hot, "max_hot must be >= min_hot");
        ensure!(
            (0.0..=1.0).contains(&self.target_hit_rate),
            "target_hit_rate must be in [0,1]"
        );
        ensure!(
            (0.0..1.0).contains(&self.tail_utility),
            "tail_utility must be in [0,1)"
        );
        ensure!(
            self.hot_growth.is_finite() && self.hot_growth > 1.0,
            "hot_growth must be a finite factor > 1"
        );
        ensure!(self.codec_block >= 1, "codec_block must be >= 1");
        ensure!(
            self.grad_k.is_finite() && (0.0..=1.0).contains(&self.grad_k),
            "grad_k must be a fraction in [0,1]"
        );
        Ok(())
    }

    fn to_value(self) -> Value {
        let mut v = Value::table();
        v.set("resample_period", self.resample_period)
            .set("fetch_window", self.fetch_window)
            .set("resize_period", self.resize_period)
            .set("min_hot", self.min_hot)
            .set("max_hot", self.max_hot)
            .set("target_hit_rate", self.target_hit_rate)
            .set("tail_utility", self.tail_utility)
            .set("hot_growth", self.hot_growth)
            .set("hysteresis", self.hysteresis)
            .set("codec", self.codec.id())
            .set("codec_block", self.codec_block)
            .set("grad_mode", self.grad_mode.id())
            .set("grad_k", self.grad_k);
        v
    }

    fn from_value(v: &Value) -> Result<Self> {
        // Every key is optional so configs written before an engine existed
        // (or before its knobs grew) still load with that knob's default.
        let d = EngineParams::default();
        let opt_u32 = |key: &str, default: u32| -> Result<u32> {
            if v.get(key).is_some() {
                v.req_u32(key)
            } else {
                Ok(default)
            }
        };
        let opt_f64 = |key: &str, default: f64| -> Result<f64> {
            if v.get(key).is_some() {
                v.req_f64(key)
            } else {
                Ok(default)
            }
        };
        Ok(EngineParams {
            resample_period: opt_u32("resample_period", d.resample_period)?,
            fetch_window: opt_u32("fetch_window", d.fetch_window)?,
            resize_period: opt_u32("resize_period", d.resize_period)?,
            min_hot: opt_u32("min_hot", d.min_hot)?,
            max_hot: opt_u32("max_hot", d.max_hot)?,
            target_hit_rate: opt_f64("target_hit_rate", d.target_hit_rate)?,
            tail_utility: opt_f64("tail_utility", d.tail_utility)?,
            hot_growth: opt_f64("hot_growth", d.hot_growth)?,
            hysteresis: opt_u32("hysteresis", d.hysteresis)?,
            codec: match v.get("codec") {
                Some(_) => v.req_str("codec")?.parse()?,
                None => d.codec,
            },
            codec_block: opt_u32("codec_block", d.codec_block)?,
            grad_mode: match v.get("grad_mode") {
                Some(_) => v.req_str("grad_mode")?.parse()?,
                None => d.grad_mode,
            },
            grad_k: opt_f64("grad_k", d.grad_k)?,
        })
    }
}

/// How batch features are materialized and the model step executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// Metadata-only: hit/miss sets and byte counts are computed exactly, the
    /// model step is charged from the analytic compute model. Used by the
    /// parameter-sweep benches (fast, deterministic).
    #[default]
    Trace,
    /// Full execution: feature rows are actually staged/copied and the model
    /// step really runs (host-rust or PJRT backend).
    Full,
    /// Trace scheduling on the real shared-memory transport
    /// (`net::ShmRings`): every remote pull actually moves the serialized
    /// shard bytes between threads, measured in wall-clock, while the
    /// modeled report stays byte-identical to `Trace`. Adds a
    /// `CalibrationReport` (virtual-vs-wall-clock) to the run report.
    Wallclock,
}

impl ExecMode {
    /// Config-file identifier.
    pub fn id(&self) -> &'static str {
        match self {
            ExecMode::Trace => "trace",
            ExecMode::Full => "full",
            ExecMode::Wallclock => "wallclock",
        }
    }
}

impl FromStr for ExecMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "trace" => ExecMode::Trace,
            "full" => ExecMode::Full,
            "wallclock" => ExecMode::Wallclock,
            _ => bail!("unknown exec mode '{s}' (trace|full|wallclock)"),
        })
    }
}

/// Which implementation executes the GraphSAGE train step in `Full` mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TrainerBackend {
    /// Pure-rust reference implementation (no artifacts needed).
    #[default]
    Host,
    /// AOT-compiled JAX/Pallas artifact executed through PJRT.
    Pjrt,
}

impl TrainerBackend {
    /// Config-file identifier.
    pub fn id(&self) -> &'static str {
        match self {
            TrainerBackend::Host => "host",
            TrainerBackend::Pjrt => "pjrt",
        }
    }
}

impl FromStr for TrainerBackend {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "host" => TrainerBackend::Host,
            "pjrt" => TrainerBackend::Pjrt,
            _ => bail!("unknown backend '{s}' (host|pjrt)"),
        })
    }
}

/// Cluster interconnect topology: how worker-to-worker links are laid out.
///
/// The fabric cost model charges every RPC against the (latency, bandwidth)
/// of the specific `src → dst` link under the selected topology, so sweeps
/// over this axis expose locality effects the flat model cannot (Fig-6
/// topology × worker-count sweeps; see `sim/README.md`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Topology {
    /// Single non-blocking switch: every pair is one hop at full bandwidth
    /// (the paper testbed's 10 GbE — the previous implicit model).
    Flat,
    /// Two-tier rack/spine fabric: workers in the same rack (assigned
    /// round-robin, `rack = w % racks`) talk at full bandwidth; cross-rack
    /// traffic crosses the oversubscribed spine (2× latency, bandwidth
    /// divided by the oversubscription factor).
    TwoTier {
        /// Number of racks (≥ 1).
        racks: u32,
        /// Spine oversubscription ratio (≥ 1; 1 = non-blocking).
        oversubscription: f64,
    },
    /// Unidirectional-cable ring: cost scales with hop distance
    /// `min(|s−d|, P−|s−d|)` — latency × hops, bandwidth ÷ hops
    /// (store-and-forward through every intermediate link).
    Ring,
    /// Star / parameter-server: all traffic transits the hub worker. Links
    /// touching the hub are one hop; everything else pays 2× latency and
    /// half bandwidth (both spokes on the path).
    Star {
        /// Worker id acting as the hub.
        hub: u32,
    },
    /// Simplified k-ary fat tree: `k` pods (edge switches, workers assigned
    /// `pod = w % k`) joined by `max(k/2, 1)` core spines with full bisection
    /// bandwidth. Cross-pod traffic takes one deterministically ECMP-hashed
    /// spine (`(src + dst) % spines`); in the linear price that is 2× latency
    /// at full bandwidth — contention mode exposes the hash collisions.
    FatTree {
        /// Pod / edge-switch count (≥ 2); spines = `max(k/2, 1)`.
        k: u32,
    },
    /// Simplified dragonfly: `groups` groups of `routers` routers each
    /// (`group = w % groups`, `router = (w / groups) % routers`), all-to-all
    /// local links inside a group and one global link per ordered group pair
    /// (owned by gateway router `dst_group % routers`). Linear price grows
    /// with the hop count of the minimal route (local ≤ 1 hop each side +
    /// one long global hop).
    Dragonfly {
        /// Group count (≥ 1).
        groups: u32,
        /// Routers per group (≥ 1).
        routers: u32,
    },
}

impl Default for Topology {
    fn default() -> Self {
        Topology::Flat
    }
}

impl Topology {
    /// Config-file identifier.
    pub fn id(&self) -> &'static str {
        match self {
            Topology::Flat => "flat",
            Topology::TwoTier { .. } => "two-tier",
            Topology::Ring => "ring",
            Topology::Star { .. } => "star",
            Topology::FatTree { .. } => "fat-tree",
            Topology::Dragonfly { .. } => "dragonfly",
        }
    }
}

/// One shared physical link in the contention model. Workers see *routes* —
/// ordered hop lists over these links — and the `net::contention` simulator
/// shares each link's bandwidth processor-sharing-style among the transfers
/// in flight on it. Identity is structural so every RPC that crosses the
/// same cable lands on the same queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LinkKey {
    /// Worker `w`'s NIC egress (access link up into the fabric).
    HostUp(u32),
    /// Worker `w`'s NIC ingress (access link down from the fabric).
    HostDown(u32),
    /// Two-tier: rack `r`'s oversubscribed uplink into the spine.
    RackUp(u32),
    /// Two-tier: rack `r`'s oversubscribed downlink from the spine.
    RackDown(u32),
    /// Ring: the directed cable from worker `from` to its neighbour `to`.
    RingSeg { from: u32, to: u32 },
    /// Fat tree: pod `pod`'s uplink to core spine `spine`.
    EdgeUp { pod: u32, spine: u32 },
    /// Fat tree: pod `pod`'s downlink from core spine `spine`.
    EdgeDown { pod: u32, spine: u32 },
    /// Dragonfly: the local cable between routers `a < b` inside `group`.
    Local { group: u32, a: u32, b: u32 },
    /// Dragonfly: the long global cable from group `from` to group `to`.
    Global { from: u32, to: u32 },
}

impl LinkKey {
    /// Stable human-readable label (telemetry JSON, bench tables).
    pub fn label(&self) -> String {
        match self {
            LinkKey::HostUp(w) => format!("host-up:{w}"),
            LinkKey::HostDown(w) => format!("host-down:{w}"),
            LinkKey::RackUp(r) => format!("rack-up:{r}"),
            LinkKey::RackDown(r) => format!("rack-down:{r}"),
            LinkKey::RingSeg { from, to } => format!("ring:{from}>{to}"),
            LinkKey::EdgeUp { pod, spine } => format!("edge-up:p{pod}/s{spine}"),
            LinkKey::EdgeDown { pod, spine } => format!("edge-down:p{pod}/s{spine}"),
            LinkKey::Local { group, a, b } => format!("dfly-local:g{group}:{a}-{b}"),
            LinkKey::Global { from, to } => format!("dfly-global:{from}>{to}"),
        }
    }
}

/// One hop of a route: the shared link it crosses plus that link's
/// propagation latency and capacity.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RouteHop {
    pub link: LinkKey,
    /// Propagation/processing latency of this hop (seconds).
    pub latency_sec: f64,
    /// Capacity of the shared link (bytes/second).
    pub bandwidth_bytes_per_sec: f64,
}

/// Per-link effective parameters derived from a topology.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkModel {
    /// Effective one-RPC latency on this link (seconds).
    pub latency_sec: f64,
    /// Effective bandwidth on this link (bytes/second).
    pub bandwidth_bytes_per_sec: f64,
}

/// One transient-straggler phase: from `from_epoch` onward (until the next
/// phase takes over) worker `w` is additionally slowed by `speeds[w]`
/// (entries past the end default to 1.0). Layered multiplicatively over the
/// static [`FabricConfig::worker_speed`] vector, so a single phase starting
/// at epoch 0 over an empty static vector is bit-identical to configuring
/// `worker_speed` directly.
#[derive(Debug, Clone, PartialEq)]
pub struct SpeedPhase {
    /// First epoch this phase applies to.
    pub from_epoch: u32,
    /// Per-worker slowdown multipliers (≥ 1; missing entries = 1.0).
    pub speeds: Vec<f64>,
}

/// Simulated network fabric parameters (paper testbed: 10 Gbps Ethernet).
#[derive(Debug, Clone, PartialEq)]
pub struct FabricConfig {
    /// Link bandwidth in bytes/second (default 10 Gbps).
    pub bandwidth_bytes_per_sec: f64,
    /// Per-RPC latency in seconds (TCP/RPC software stack + switch).
    pub rpc_latency_sec: f64,
    /// Per-node serialization overhead (id lookup, tensor slicing) in seconds.
    pub per_node_overhead_sec: f64,
    /// Interconnect layout; per-link costs derive from it ([`Self::link_model`]).
    pub topology: Topology,
    /// Per-link loss rate in [0, 1): deterministically, every
    /// `round(1/loss_rate)`-th RPC *on each link* times out and is retried
    /// once at double latency. 0 disables injection.
    pub loss_rate: f64,
    /// Per-worker slowdown multipliers (heterogeneous cluster model): entry
    /// `w` scales worker `w`'s local work and every link touching it. Empty
    /// (the default) means all-ones; entries past the end default to 1.0.
    /// All entries must be ≥ 1 — slowdowns, not speedups, like
    /// `straggler_factor`. Resolved per worker by [`Self::slowdown_of`].
    pub worker_speed: Vec<f64>,
    /// Transient stragglers: epoch-indexed speed phases layered over the
    /// static `worker_speed` vector. Each entry switches the cluster's
    /// per-worker multipliers from its `from_epoch` onward; entries must be
    /// sorted by strictly increasing `from_epoch`. Empty = no phases.
    pub worker_speed_phases: Vec<SpeedPhase>,
    /// Single-straggler sugar: worker id whose links and local work run
    /// slow, or -1 for none. Combines multiplicatively with `worker_speed`.
    pub straggler_worker: i64,
    /// Slowdown multiplier for the straggler (≥ 1; 1 = no effect).
    pub straggler_factor: f64,
    /// Shared-link queueing: when true, RPCs contend for the physical links
    /// on their route (processor-sharing bandwidth, discrete-event drained on
    /// the cluster runtime's virtual clock — see `net::contention`) instead
    /// of the closed-form linear per-RPC price. Off by default, which keeps
    /// every existing trace byte-identical.
    pub contention: bool,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            bandwidth_bytes_per_sec: 10.0e9 / 8.0, // 10 Gbps
            rpc_latency_sec: 150e-6,               // ~150 µs RPC round trip
            per_node_overhead_sec: 0.3e-6,         // serialization cost per row
            topology: Topology::Flat,
            loss_rate: 0.0,
            worker_speed: Vec::new(),
            worker_speed_phases: Vec::new(),
            straggler_worker: -1,
            straggler_factor: 1.0,
            contention: false,
        }
    }
}

impl FabricConfig {
    /// Time to transfer one RPC carrying `bytes` for `nodes` feature rows
    /// over a flat one-hop link (topology-unaware; kept for cost-model
    /// calibration and the closed-form pipeline reference).
    pub fn rpc_time(&self, bytes: u64, nodes: u64) -> f64 {
        self.rpc_latency_sec
            + bytes as f64 / self.bandwidth_bytes_per_sec
            + nodes as f64 * self.per_node_overhead_sec
    }

    /// Effective per-link parameters for `src → dst` under the topology.
    /// `world` is the worker count (0 = unknown: ring distance degrades to
    /// the non-wrapped `|src − dst|`).
    pub fn link_model(&self, src: u32, dst: u32, world: u32) -> LinkModel {
        let l = self.rpc_latency_sec;
        let b = self.bandwidth_bytes_per_sec;
        let (lat, bw) = match self.topology {
            Topology::Flat => (l, b),
            Topology::TwoTier { racks, oversubscription } => {
                let r = racks.max(1);
                if src % r == dst % r {
                    (l, b)
                } else {
                    (2.0 * l, b / oversubscription.max(1.0))
                }
            }
            Topology::Ring => {
                let d = src.abs_diff(dst);
                let hops = if world > d { d.min(world - d) } else { d }.max(1);
                (hops as f64 * l, b / hops as f64)
            }
            Topology::Star { hub } => {
                if src == hub || dst == hub {
                    (l, b)
                } else {
                    (2.0 * l, b / 2.0)
                }
            }
            // The multi-hop presets derive their linear price from the same
            // route the contention model queues on: latency = sum of hop
            // latencies, bandwidth = the route's bottleneck capacity. This
            // keeps the two pricing modes consistent: an uncongested
            // contended transfer costs exactly the linear price.
            Topology::FatTree { .. } | Topology::Dragonfly { .. } => {
                let hops = self.route(src, dst, world);
                let lat: f64 = hops.iter().map(|h| h.latency_sec).sum();
                let bw = hops
                    .iter()
                    .map(|h| h.bandwidth_bytes_per_sec)
                    .fold(f64::INFINITY, f64::min);
                (lat, if bw.is_finite() { bw } else { b })
            }
        };
        LinkModel { latency_sec: lat, bandwidth_bytes_per_sec: bw }
    }

    /// The ordered shared-link route an RPC `src → dst` takes under the
    /// topology — the unit the contention model queues on. Invariants the
    /// `net::contention` tests pin: hop latencies sum to (at least) the
    /// linear [`Self::link_model`] latency, and for the switched presets
    /// (flat, two-tier, fat-tree, dragonfly) the bottleneck hop capacity
    /// equals the linear bandwidth, so an uncongested contended transfer
    /// costs exactly the linear price. Ring and star are *cheaper* per hop
    /// uncongested (cut-through vs the linear model's store-and-forward /
    /// half-duplex approximations) but share cables the linear model cannot.
    pub fn route(&self, src: u32, dst: u32, world: u32) -> Vec<RouteHop> {
        let l = self.rpc_latency_sec;
        let b = self.bandwidth_bytes_per_sec;
        let hop = |link: LinkKey, latency: f64, bw: f64| RouteHop {
            link,
            latency_sec: latency,
            bandwidth_bytes_per_sec: bw,
        };
        let nic_pair = |src: u32, dst: u32| {
            vec![
                hop(LinkKey::HostUp(src), 0.5 * l, b),
                hop(LinkKey::HostDown(dst), 0.5 * l, b),
            ]
        };
        // Self-transfers never cross the fabric: every topology prices them
        // as the NIC loopback pair (the ring walk below would otherwise
        // circle the whole ring for src == dst).
        if src == dst {
            return nic_pair(src, dst);
        }
        match self.topology {
            Topology::Flat => nic_pair(src, dst),
            Topology::TwoTier { racks, oversubscription } => {
                let r = racks.max(1);
                let o = oversubscription.max(1.0);
                if src % r == dst % r {
                    nic_pair(src, dst)
                } else {
                    vec![
                        hop(LinkKey::HostUp(src), 0.5 * l, b),
                        hop(LinkKey::RackUp(src % r), 0.5 * l, b / o),
                        hop(LinkKey::RackDown(dst % r), 0.5 * l, b / o),
                        hop(LinkKey::HostDown(dst), 0.5 * l, b),
                    ]
                }
            }
            Topology::Ring => {
                // Walk the shorter direction (forward on ties), one cable
                // per hop, each at full capacity and one hop latency.
                // Unknown world degrades to a ring just large enough.
                let p = world.max(src.max(dst) + 1).max(2);
                let d = src.abs_diff(dst);
                let fwd_dist = if dst >= src { d } else { p - d };
                let forward = fwd_dist <= p - fwd_dist;
                let mut hops = Vec::new();
                let mut cur = src;
                loop {
                    let next = if forward {
                        (cur + 1) % p
                    } else {
                        (cur + p - 1) % p
                    };
                    hops.push(hop(LinkKey::RingSeg { from: cur, to: next }, l, b));
                    cur = next;
                    if cur == dst || hops.len() as u32 >= p {
                        break;
                    }
                }
                hops
            }
            Topology::Star { hub } => {
                if src == hub || dst == hub {
                    nic_pair(src, dst)
                } else {
                    // Spoke-to-spoke transits the hub worker's NIC both ways
                    // — the shared cables every parameter-server pull queues
                    // on (the incast hotspot).
                    vec![
                        hop(LinkKey::HostUp(src), 0.5 * l, b),
                        hop(LinkKey::HostDown(hub), 0.5 * l, b),
                        hop(LinkKey::HostUp(hub), 0.5 * l, b),
                        hop(LinkKey::HostDown(dst), 0.5 * l, b),
                    ]
                }
            }
            Topology::FatTree { k } => {
                let pods = k.max(1);
                let spines = (k / 2).max(1);
                let (ps, pd) = (src % pods, dst % pods);
                if ps == pd {
                    nic_pair(src, dst)
                } else {
                    // Deterministic ECMP: the (src, dst) pair hashes to one
                    // spine, so repeat transfers collide repeatably.
                    let spine = (src + dst) % spines;
                    vec![
                        hop(LinkKey::HostUp(src), 0.5 * l, b),
                        hop(LinkKey::EdgeUp { pod: ps, spine }, 0.5 * l, b),
                        hop(LinkKey::EdgeDown { pod: pd, spine }, 0.5 * l, b),
                        hop(LinkKey::HostDown(dst), 0.5 * l, b),
                    ]
                }
            }
            Topology::Dragonfly { groups, routers } => {
                let g = groups.max(1);
                let r = routers.max(1);
                let (gs, gd) = (src % g, dst % g);
                let (rs, rd) = ((src / g) % r, (dst / g) % r);
                let local = |group: u32, x: u32, y: u32| {
                    hop(
                        LinkKey::Local { group, a: x.min(y), b: x.max(y) },
                        0.5 * l,
                        b,
                    )
                };
                if gs == gd {
                    if rs == rd {
                        nic_pair(src, dst)
                    } else {
                        vec![
                            hop(LinkKey::HostUp(src), 0.5 * l, b),
                            local(gs, rs, rd),
                            hop(LinkKey::HostDown(dst), 0.5 * l, b),
                        ]
                    }
                } else {
                    // Minimal route: local hop to the gateway router owning
                    // the global cable, the long global hop, local hop from
                    // the destination group's gateway.
                    let gw_src = gd % r; // router in gs with the link to gd
                    let gw_dst = gs % r; // router in gd with the link from gs
                    let mut hops = vec![hop(LinkKey::HostUp(src), 0.5 * l, b)];
                    if rs != gw_src {
                        hops.push(local(gs, rs, gw_src));
                    }
                    hops.push(hop(LinkKey::Global { from: gs, to: gd }, 2.0 * l, b));
                    if gw_dst != rd {
                        hops.push(local(gd, gw_dst, rd));
                    }
                    hops.push(hop(LinkKey::HostDown(dst), 0.5 * l, b));
                    hops
                }
            }
        }
    }

    /// Topology-aware RPC time for `src → dst`.
    pub fn rpc_time_on_link(&self, src: u32, dst: u32, world: u32, bytes: u64, nodes: u64) -> f64 {
        let link = self.link_model(src, dst, world);
        link.latency_sec
            + bytes as f64 / link.bandwidth_bytes_per_sec
            + nodes as f64 * self.per_node_overhead_sec
    }

    /// Deterministic per-link retry cadence implied by `loss_rate`
    /// (`None` when injection is disabled).
    pub fn loss_every(&self) -> Option<u64> {
        if self.loss_rate > 0.0 {
            Some(((1.0 / self.loss_rate).round() as u64).max(1))
        } else {
            None
        }
    }

    /// Configured straggler as `(worker, factor)`, if any.
    pub fn straggler(&self) -> Option<(u32, f64)> {
        if self.straggler_worker >= 0 && self.straggler_factor > 1.0 {
            Some((self.straggler_worker as u32, self.straggler_factor))
        } else {
            None
        }
    }

    /// Resolved slowdown multiplier for `worker`: its `worker_speed` entry
    /// (1.0 when absent) times the straggler sugar when it names this worker.
    /// ≥ 1 by validation; 1.0 for an unconfigured worker.
    pub fn slowdown_of(&self, worker: u32) -> f64 {
        let base = self.worker_speed.get(worker as usize).copied().unwrap_or(1.0);
        match self.straggler() {
            Some((w, factor)) if w == worker => base * factor,
            _ => base,
        }
    }

    /// Transient-phase multiplier for `worker` at `epoch`: the entry from
    /// the last phase whose `from_epoch` ≤ `epoch` (1.0 when no phase is
    /// active or the phase has no entry for this worker).
    pub fn phase_factor(&self, worker: u32, epoch: u32) -> f64 {
        let mut factor = 1.0;
        for phase in &self.worker_speed_phases {
            if phase.from_epoch <= epoch {
                factor = phase.speeds.get(worker as usize).copied().unwrap_or(1.0);
            } else {
                break;
            }
        }
        factor
    }

    /// Epoch-aware slowdown: the static [`Self::slowdown_of`] layered with
    /// the transient phase active at `epoch`. With no phases configured this
    /// is exactly `slowdown_of` (same float ops), so existing runs are
    /// bit-identical.
    pub fn slowdown_at(&self, worker: u32, epoch: u32) -> f64 {
        let base = self.slowdown_of(worker);
        if self.worker_speed_phases.is_empty() {
            base
        } else {
            base * self.phase_factor(worker, epoch)
        }
    }

    /// Internal consistency checks (called from [`RunConfig::validate`]).
    pub fn validate(&self) -> Result<()> {
        ensure!(self.bandwidth_bytes_per_sec > 0.0, "bandwidth must be positive");
        ensure!(self.rpc_latency_sec >= 0.0, "latency must be non-negative");
        ensure!(
            (0.0..1.0).contains(&self.loss_rate),
            "loss_rate must be in [0,1)"
        );
        ensure!(self.straggler_factor >= 1.0, "straggler_factor must be >= 1");
        ensure!(
            self.worker_speed.iter().all(|s| s.is_finite() && *s >= 1.0),
            "worker_speed entries must be finite slowdown factors >= 1"
        );
        let mut prev_from: Option<u32> = None;
        for phase in &self.worker_speed_phases {
            ensure!(
                phase.speeds.iter().all(|s| s.is_finite() && *s >= 1.0),
                "worker_speed_phases entries must be finite slowdown factors >= 1"
            );
            if let Some(p) = prev_from {
                ensure!(
                    phase.from_epoch > p,
                    "worker_speed_phases must have strictly increasing from_epoch"
                );
            }
            prev_from = Some(phase.from_epoch);
        }
        match self.topology {
            Topology::TwoTier { racks, oversubscription } => {
                ensure!(racks >= 1, "two-tier topology needs >= 1 rack");
                ensure!(oversubscription >= 1.0, "oversubscription must be >= 1");
            }
            Topology::FatTree { k } => {
                ensure!(k >= 2, "fat-tree needs k >= 2 pods");
            }
            Topology::Dragonfly { groups, routers } => {
                ensure!(groups >= 1, "dragonfly needs >= 1 group");
                ensure!(routers >= 1, "dragonfly needs >= 1 router per group");
            }
            Topology::Flat | Topology::Ring | Topology::Star { .. } => {}
        }
        Ok(())
    }

    fn to_value(&self) -> Value {
        let (racks, oversub, hub) = match self.topology {
            Topology::TwoTier { racks, oversubscription } => (racks, oversubscription, 0u32),
            Topology::Star { hub } => (0, 1.0, hub),
            _ => (0, 1.0, 0),
        };
        let (fat_k, groups, routers) = match self.topology {
            Topology::FatTree { k } => (k, 0, 0),
            Topology::Dragonfly { groups, routers } => (0, groups, routers),
            _ => (0, 0, 0),
        };
        let mut v = Value::table();
        v.set("bandwidth_bytes_per_sec", self.bandwidth_bytes_per_sec)
            .set("rpc_latency_sec", self.rpc_latency_sec)
            .set("per_node_overhead_sec", self.per_node_overhead_sec)
            .set("topology", self.topology.id())
            .set("topology_racks", racks)
            .set("topology_oversubscription", oversub)
            .set("topology_hub", hub)
            .set("topology_fat_k", fat_k)
            .set("topology_groups", groups)
            .set("topology_routers", routers)
            .set("loss_rate", self.loss_rate)
            .set("worker_speed", &self.worker_speed[..])
            .set("straggler_worker", self.straggler_worker)
            .set("straggler_factor", self.straggler_factor)
            .set("contention", self.contention);
        // Phases flatten to scalar arrays so the TOML subset (no arrays of
        // tables) round-trips them: one epoch list plus one speeds array per
        // phase, keyed by index.
        if !self.worker_speed_phases.is_empty() {
            let epochs: Vec<u32> =
                self.worker_speed_phases.iter().map(|p| p.from_epoch).collect();
            v.set("phase_from_epochs", &epochs[..]);
            for (i, phase) in self.worker_speed_phases.iter().enumerate() {
                v.set(&format!("phase_speeds_{i}"), &phase.speeds[..]);
            }
        }
        v
    }

    fn from_value(v: &Value) -> Result<Self> {
        // Topology keys are optional so pre-topology config files still load.
        let topology = match v.get("topology") {
            None => Topology::Flat,
            Some(Value::Str(s)) => match s.as_str() {
                "flat" => Topology::Flat,
                "two-tier" => Topology::TwoTier {
                    racks: v.req_u32("topology_racks")?,
                    oversubscription: v.req_f64("topology_oversubscription")?,
                },
                "ring" => Topology::Ring,
                "star" => Topology::Star { hub: v.req_u32("topology_hub")? },
                "fat-tree" => Topology::FatTree { k: v.req_u32("topology_fat_k")? },
                "dragonfly" => Topology::Dragonfly {
                    groups: v.req_u32("topology_groups")?,
                    routers: v.req_u32("topology_routers")?,
                },
                other => bail!(
                    "unknown topology '{other}' (flat|two-tier|ring|star|fat-tree|dragonfly)"
                ),
            },
            Some(other) => bail!("topology: expected string, got {other:?}"),
        };
        let mut worker_speed_phases = Vec::new();
        if v.get("phase_from_epochs").is_some() {
            for (i, from_epoch) in v.req_u32_array("phase_from_epochs")?.into_iter().enumerate() {
                worker_speed_phases.push(SpeedPhase {
                    from_epoch,
                    speeds: v.req_f64_array(&format!("phase_speeds_{i}"))?,
                });
            }
        }
        Ok(FabricConfig {
            bandwidth_bytes_per_sec: v.req_f64("bandwidth_bytes_per_sec")?,
            rpc_latency_sec: v.req_f64("rpc_latency_sec")?,
            per_node_overhead_sec: v.req_f64("per_node_overhead_sec")?,
            topology,
            loss_rate: if v.get("loss_rate").is_some() {
                v.req_f64("loss_rate")?
            } else {
                0.0
            },
            worker_speed: if v.get("worker_speed").is_some() {
                v.req_f64_array("worker_speed")?
            } else {
                Vec::new()
            },
            worker_speed_phases,
            straggler_worker: if v.get("straggler_worker").is_some() {
                v.req_i64("straggler_worker")?
            } else {
                -1
            },
            straggler_factor: if v.get("straggler_factor").is_some() {
                v.req_f64("straggler_factor")?
            } else {
                1.0
            },
            contention: if v.get("contention").is_some() {
                v.req_bool("contention")?
            } else {
                false
            },
        })
    }
}

/// Device power model used by [`crate::energy`] (paper Table 3 calibration).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerConfig {
    /// CPU package power when busy with compute/marshalling (W).
    pub cpu_busy_w: f64,
    /// CPU package power while stalled on network I/O (W). Polling RPC loops
    /// keep the CPU partially busy — this is why DGL's mean CPU power is
    /// *higher* than RapidGNN's in the paper (42.7 vs 36.7 W).
    pub cpu_net_wait_w: f64,
    /// CPU idle floor (W).
    pub cpu_idle_w: f64,
    /// GPU power when running the training step (W).
    pub gpu_busy_w: f64,
    /// GPU power while holding the feature cache but not computing (W).
    pub gpu_idle_w: f64,
}

impl Default for PowerConfig {
    fn default() -> Self {
        // Calibrated to paper Table 3: DGL-METIS mean CPU 42.7 W / GPU 29.5 W,
        // RapidGNN mean CPU 36.7 W / GPU 30.8 W (cache residency adds ~5%).
        PowerConfig {
            cpu_busy_w: 38.0,
            cpu_net_wait_w: 46.0,
            cpu_idle_w: 12.0,
            gpu_busy_w: 42.0,
            gpu_idle_w: 18.0,
        }
    }
}

impl PowerConfig {
    fn to_value(self) -> Value {
        let mut v = Value::table();
        v.set("cpu_busy_w", self.cpu_busy_w)
            .set("cpu_net_wait_w", self.cpu_net_wait_w)
            .set("cpu_idle_w", self.cpu_idle_w)
            .set("gpu_busy_w", self.gpu_busy_w)
            .set("gpu_idle_w", self.gpu_idle_w);
        v
    }

    fn from_value(v: &Value) -> Result<Self> {
        Ok(PowerConfig {
            cpu_busy_w: v.req_f64("cpu_busy_w")?,
            cpu_net_wait_w: v.req_f64("cpu_net_wait_w")?,
            cpu_idle_w: v.req_f64("cpu_idle_w")?,
            gpu_busy_w: v.req_f64("gpu_busy_w")?,
            gpu_idle_w: v.req_f64("gpu_idle_w")?,
        })
    }
}

/// One deterministic elasticity event, applied at an epoch boundary.
///
/// `at_epoch` names the boundary *entering* that epoch: the event is applied
/// after epoch `at_epoch - 1` finishes and before epoch `at_epoch` starts,
/// so valid boundaries are the interior ones, `1..epochs`. Events heal
/// entirely within the boundary (the recovery work is priced through the
/// fabric models and reported in `RunReport.recovery`), which is what makes
/// any failure schedule replay the failure-free training timeline bit-exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureEvent {
    /// The host serving `worker` departs; a performance-equivalent standby
    /// adopts the logical worker id, pulling the shard's feature rows and the
    /// warm hot-cache rows from a donor peer.
    WorkerLeave { worker: u32, at_epoch: u32 },
    /// A replacement host joins as `worker`; shard + cache state move to it
    /// (same movement price as a leave — joins model host replacement).
    WorkerJoin { worker: u32, at_epoch: u32 },
    /// The `a`↔`b` link is down at this boundary: recovery flows between the
    /// pair detour through a third alive worker (training traffic is assumed
    /// to ride redundant paths; see `sim/README.md`).
    LinkDown { a: u32, b: u32, at_epoch: u32 },
    /// The `a`↔`b` link is restored.
    LinkUp { a: u32, b: u32, at_epoch: u32 },
    /// Coordinator crash at this boundary; the run restarts from the last
    /// checkpoint at or before it and the re-executed span is charged as
    /// `lost_work_time` (deterministic replay — epochs are not duplicated).
    CrashRestart { at_epoch: u32 },
}

impl FailureEvent {
    /// The boundary this event fires at.
    pub fn at_epoch(&self) -> u32 {
        match *self {
            FailureEvent::WorkerLeave { at_epoch, .. }
            | FailureEvent::WorkerJoin { at_epoch, .. }
            | FailureEvent::LinkDown { at_epoch, .. }
            | FailureEvent::LinkUp { at_epoch, .. }
            | FailureEvent::CrashRestart { at_epoch } => at_epoch,
        }
    }

    /// Compact spec-string form (`leave:1@2`, `linkdown:0-1@3`, `crash@2`).
    pub fn encode(&self) -> String {
        match *self {
            FailureEvent::WorkerLeave { worker, at_epoch } => format!("leave:{worker}@{at_epoch}"),
            FailureEvent::WorkerJoin { worker, at_epoch } => format!("join:{worker}@{at_epoch}"),
            FailureEvent::LinkDown { a, b, at_epoch } => format!("linkdown:{a}-{b}@{at_epoch}"),
            FailureEvent::LinkUp { a, b, at_epoch } => format!("linkup:{a}-{b}@{at_epoch}"),
            FailureEvent::CrashRestart { at_epoch } => format!("crash@{at_epoch}"),
        }
    }
}

/// A deterministic failure schedule: an ordered list of [`FailureEvent`]s.
///
/// Serialized as one compact comma-separated spec string (the TOML subset has
/// no arrays of tables, the same reason `FabricConfig` flattens its speed
/// phases): `"leave:1@2,join:1@3,linkdown:0-1@1,linkup:0-1@2,crash@3"`.
/// The empty string is the empty plan — and the `failures` key is omitted
/// from serialized configs entirely, keeping pre-failure configs byte-stable.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FailurePlan {
    /// Events in spec order; a boundary's events apply in this order.
    pub events: Vec<FailureEvent>,
}

impl FailurePlan {
    /// Parse a spec string (see type docs). Whitespace around commas is
    /// tolerated; the empty string parses to the empty plan.
    pub fn parse(spec: &str) -> Result<FailurePlan> {
        let mut events = Vec::new();
        for raw in spec.split(',') {
            let tok = raw.trim();
            if tok.is_empty() {
                continue;
            }
            let Some((head, at)) = tok.rsplit_once('@') else {
                bail!("failure event '{tok}': missing '@epoch'");
            };
            let at_epoch: u32 = at
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("failure event '{tok}': bad epoch '{at}'"))?;
            let parse_worker = |s: &str| -> Result<u32> {
                s.trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("failure event '{tok}': bad worker '{s}'"))
            };
            let parse_pair = |s: &str| -> Result<(u32, u32)> {
                let Some((a, b)) = s.split_once('-') else {
                    bail!("failure event '{tok}': expected 'a-b' link endpoints");
                };
                Ok((parse_worker(a)?, parse_worker(b)?))
            };
            let ev = match head.trim().split_once(':') {
                None if head.trim() == "crash" => FailureEvent::CrashRestart { at_epoch },
                Some(("leave", w)) => {
                    FailureEvent::WorkerLeave { worker: parse_worker(w)?, at_epoch }
                }
                Some(("join", w)) => FailureEvent::WorkerJoin { worker: parse_worker(w)?, at_epoch },
                Some(("linkdown", p)) => {
                    let (a, b) = parse_pair(p)?;
                    FailureEvent::LinkDown { a, b, at_epoch }
                }
                Some(("linkup", p)) => {
                    let (a, b) = parse_pair(p)?;
                    FailureEvent::LinkUp { a, b, at_epoch }
                }
                _ => bail!(
                    "failure event '{tok}': unknown kind (leave:W@E | join:W@E | \
                     linkdown:A-B@E | linkup:A-B@E | crash@E)"
                ),
            };
            events.push(ev);
        }
        Ok(FailurePlan { events })
    }

    /// Re-encode to the canonical spec string.
    pub fn encode(&self) -> String {
        self.events.iter().map(FailureEvent::encode).collect::<Vec<_>>().join(",")
    }

    /// True when no events are scheduled.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events firing at the boundary entering `epoch`, in spec order.
    pub fn events_at(&self, epoch: u32) -> impl Iterator<Item = &FailureEvent> {
        self.events.iter().filter(move |e| e.at_epoch() == epoch)
    }

    /// Check the plan against the run shape.
    pub fn validate(&self, num_workers: u32, epochs: u32) -> Result<()> {
        for ev in &self.events {
            let at = ev.at_epoch();
            ensure!(
                at >= 1 && at < epochs,
                "failure event '{}' must land on an interior epoch boundary (1..{epochs})",
                ev.encode()
            );
            let check_worker = |w: u32| -> Result<()> {
                ensure!(
                    w < num_workers,
                    "failure event '{}' names worker {w} >= num_workers {num_workers}",
                    ev.encode()
                );
                Ok(())
            };
            match *ev {
                FailureEvent::WorkerLeave { worker, .. }
                | FailureEvent::WorkerJoin { worker, .. } => {
                    check_worker(worker)?;
                    ensure!(
                        num_workers >= 2,
                        "worker leave/join needs >= 2 workers (a donor must stay alive)"
                    );
                }
                FailureEvent::LinkDown { a, b, .. } | FailureEvent::LinkUp { a, b, .. } => {
                    check_worker(a)?;
                    check_worker(b)?;
                    ensure!(a != b, "failure event '{}' links a worker to itself", ev.encode());
                }
                FailureEvent::CrashRestart { .. } => {}
            }
        }
        Ok(())
    }
}

/// Everything needed to reproduce a training run.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// Dataset description (generated synthetically; see [`DatasetConfig`]).
    pub dataset: DatasetConfig,
    /// Engine under test.
    pub engine: Engine,
    /// Number of workers P (= number of partitions).
    pub num_workers: u32,
    /// Mini-batch size (seed nodes per batch).
    pub batch_size: u32,
    /// Neighbor-sampling fan-out per layer, innermost first (DGL convention:
    /// `[f1, f2]` samples `f2` 1-hop neighbors of each seed, then `f1`
    /// neighbors of each of those).
    pub fanout: Vec<u32>,
    /// Number of training epochs ε.
    pub epochs: u32,
    /// Hot-set cache size `n_hot` (remote nodes cached per worker).
    pub n_hot: u32,
    /// Prefetch window Q (batches staged ahead).
    pub prefetch_q: u32,
    /// Global base seed s0 for the deterministic sampler.
    pub base_seed: u64,
    /// GNN hidden width (GraphSAGE layer-1 output dim).
    pub hidden_dim: u32,
    /// SGD learning rate.
    pub learning_rate: f32,
    /// Execution mode (trace vs full).
    pub exec_mode: ExecMode,
    /// Train-step backend in full mode.
    pub backend: TrainerBackend,
    /// Simulated fabric parameters.
    pub fabric: FabricConfig,
    /// Power model for energy accounting.
    pub power: PowerConfig,
    /// Per-engine tuning parameters (each strategy reads only its own).
    pub engine_params: EngineParams,
    /// Cap on neighbors expanded per node for the Dist-GCN full-neighborhood
    /// baseline (prevents pathological hub blowup; paper's GCN uses the full
    /// neighborhood, which our generator's hubs would make degenerate).
    pub gcn_neighbor_cap: u32,
    /// Directory for precomputed metadata blocks (SSD streaming). Empty =
    /// a per-run temp dir.
    pub metadata_dir: String,
    /// Failure schedule as a compact spec string ([`FailurePlan::parse`]).
    /// Empty = no failures; the key is omitted from serialized configs.
    pub failures: String,
    /// Write a checkpoint every K epoch boundaries (0 = never; the key is
    /// omitted from serialized configs when 0).
    pub checkpoint_every: u32,
    /// Directory for checkpoints. Empty = a per-run temp dir; the key is
    /// omitted from serialized configs when empty.
    pub checkpoint_dir: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: DatasetConfig::preset(super::DatasetPreset::Tiny, 1.0),
            engine: Engine::Rapid,
            num_workers: 2,
            batch_size: 128,
            fanout: vec![10, 25],
            epochs: 2,
            n_hot: 1_000,
            prefetch_q: 4,
            base_seed: 42,
            hidden_dim: 64,
            learning_rate: 0.05,
            exec_mode: ExecMode::Trace,
            backend: TrainerBackend::Host,
            fabric: FabricConfig::default(),
            power: PowerConfig::default(),
            engine_params: EngineParams::default(),
            gcn_neighbor_cap: 64,
            metadata_dir: String::new(),
            failures: String::new(),
            checkpoint_every: 0,
            checkpoint_dir: String::new(),
        }
    }
}

impl RunConfig {
    /// Paper-style config for a given preset/engine/batch size.
    pub fn paper(preset: super::DatasetPreset, engine: Engine, batch_size: u32) -> Self {
        RunConfig {
            dataset: DatasetConfig::preset(preset, 1.0),
            engine,
            num_workers: 4,
            batch_size,
            epochs: 10,
            ..Default::default()
        }
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.num_workers >= 1, "need at least one worker");
        ensure!(self.batch_size >= 1, "batch size must be positive");
        ensure!(!self.fanout.is_empty(), "fanout must have >=1 layer");
        ensure!(self.fanout.iter().all(|&f| f >= 1), "fanout entries must be >=1");
        ensure!(self.epochs >= 1, "need at least one epoch");
        ensure!(self.prefetch_q >= 1, "prefetch window Q must be >=1");
        ensure!(self.dataset.num_nodes >= self.num_workers, "more workers than nodes");
        ensure!(
            self.dataset.train_fraction > 0.0 && self.dataset.train_fraction <= 1.0,
            "train_fraction must be in (0,1]"
        );
        self.fabric.validate()?;
        self.engine_params.validate()?;
        if let Topology::Star { hub } = self.fabric.topology {
            ensure!(hub < self.num_workers, "star hub {hub} >= num_workers");
        }
        ensure!(
            self.fabric.straggler_worker < self.num_workers as i64,
            "straggler worker out of range"
        );
        ensure!(
            self.fabric.worker_speed.len() <= self.num_workers as usize,
            "worker_speed has {} entries for {} workers",
            self.fabric.worker_speed.len(),
            self.num_workers
        );
        for phase in &self.fabric.worker_speed_phases {
            ensure!(
                phase.speeds.len() <= self.num_workers as usize,
                "speed phase at epoch {} has {} entries for {} workers",
                phase.from_epoch,
                phase.speeds.len(),
                self.num_workers
            );
        }
        self.failure_plan()?.validate(self.num_workers, self.epochs)?;
        Ok(())
    }

    /// The parsed failure schedule (empty plan when `failures` is empty).
    pub fn failure_plan(&self) -> Result<FailurePlan> {
        FailurePlan::parse(&self.failures)
    }

    /// True when this run needs the recovery layer (failure events scheduled
    /// or checkpoints requested) and must take the cluster execution path.
    pub fn has_recovery(&self) -> bool {
        !self.failures.is_empty() || self.checkpoint_every > 0
    }

    /// Number of GNN layers implied by the fanout.
    pub fn num_layers(&self) -> usize {
        self.fanout.len()
    }

    /// Serialize to a [`Value`] table (TOML/JSON emission).
    pub fn to_value(&self) -> Value {
        let mut v = Value::table();
        v.set("engine", self.engine.id())
            .set("num_workers", self.num_workers)
            .set("batch_size", self.batch_size)
            .set("fanout", &self.fanout[..])
            .set("epochs", self.epochs)
            .set("n_hot", self.n_hot)
            .set("prefetch_q", self.prefetch_q)
            .set("base_seed", self.base_seed)
            .set("hidden_dim", self.hidden_dim)
            .set("learning_rate", self.learning_rate)
            .set("exec_mode", self.exec_mode.id())
            .set("backend", self.backend.id())
            .set("gcn_neighbor_cap", self.gcn_neighbor_cap)
            .set("metadata_dir", self.metadata_dir.as_str())
            .set("dataset", self.dataset.to_value())
            .set("fabric", self.fabric.to_value())
            .set("power", self.power.to_value())
            .set("engine_params", self.engine_params.to_value());
        // Recovery knobs are emitted only when set, so configs written before
        // the failure layer existed serialize byte-identically.
        if !self.failures.is_empty() {
            v.set("failures", self.failures.as_str());
        }
        if self.checkpoint_every > 0 {
            v.set("checkpoint_every", self.checkpoint_every);
        }
        if !self.checkpoint_dir.is_empty() {
            v.set("checkpoint_dir", self.checkpoint_dir.as_str());
        }
        v
    }

    /// Deserialize from a [`Value`] table.
    pub fn from_value(v: &Value) -> Result<Self> {
        let cfg = RunConfig {
            dataset: DatasetConfig::from_value(v.req_table("dataset")?)?,
            engine: v.req_str("engine")?.parse()?,
            num_workers: v.req_u32("num_workers")?,
            batch_size: v.req_u32("batch_size")?,
            fanout: v.req_u32_array("fanout")?,
            epochs: v.req_u32("epochs")?,
            n_hot: v.req_u32("n_hot")?,
            prefetch_q: v.req_u32("prefetch_q")?,
            base_seed: v.req_u64("base_seed")?,
            hidden_dim: v.req_u32("hidden_dim")?,
            learning_rate: v.req_f64("learning_rate")? as f32,
            exec_mode: v.req_str("exec_mode")?.parse()?,
            backend: v.req_str("backend")?.parse()?,
            fabric: FabricConfig::from_value(v.req_table("fabric")?)?,
            power: PowerConfig::from_value(v.req_table("power")?)?,
            // Optional so pre-registry config files still load.
            engine_params: match v.get("engine_params") {
                Some(t) => EngineParams::from_value(t)?,
                None => EngineParams::default(),
            },
            gcn_neighbor_cap: v.req_u32("gcn_neighbor_cap")?,
            metadata_dir: v.req_str("metadata_dir")?.to_string(),
            // Optional so pre-failure-layer config files still load.
            failures: match v.get("failures") {
                Some(_) => v.req_str("failures")?.to_string(),
                None => String::new(),
            },
            checkpoint_every: match v.get("checkpoint_every") {
                Some(_) => v.req_u32("checkpoint_every")?,
                None => 0,
            },
            checkpoint_dir: match v.get("checkpoint_dir") {
                Some(_) => v.req_str("checkpoint_dir")?.to_string(),
                None => String::new(),
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetPreset;

    #[test]
    fn default_validates() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn rejects_zero_workers() {
        let mut c = RunConfig::default();
        c.num_workers = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_empty_fanout() {
        let mut c = RunConfig::default();
        c.fanout.clear();
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_zero_q() {
        let mut c = RunConfig::default();
        c.prefetch_q = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn fabric_rpc_time_monotone_in_bytes() {
        let f = FabricConfig::default();
        assert!(f.rpc_time(2_000_000, 100) > f.rpc_time(1_000_000, 100));
        // latency floor: even a zero-byte RPC costs the round trip
        assert!(f.rpc_time(0, 0) >= f.rpc_latency_sec);
    }

    #[test]
    fn flat_topology_matches_legacy_rpc_time() {
        let f = FabricConfig::default();
        for (src, dst) in [(0u32, 1u32), (3, 7), (15, 0)] {
            assert_eq!(
                f.rpc_time_on_link(src, dst, 16, 100_000, 250),
                f.rpc_time(100_000, 250),
                "flat link {src}->{dst} must equal the one-hop model"
            );
        }
    }

    #[test]
    fn two_tier_charges_cross_rack_traffic_more() {
        let mut f = FabricConfig::default();
        f.topology = Topology::TwoTier { racks: 2, oversubscription: 4.0 };
        // 0 and 2 share rack 0; 0 and 1 cross the spine.
        let intra = f.rpc_time_on_link(0, 2, 4, 1_000_000, 0);
        let inter = f.rpc_time_on_link(0, 1, 4, 1_000_000, 0);
        assert!(inter > intra, "spine path {inter} !> rack path {intra}");
        let intra_link = f.link_model(0, 2, 4);
        let inter_link = f.link_model(0, 1, 4);
        assert_eq!(intra_link.latency_sec, f.rpc_latency_sec);
        assert_eq!(inter_link.latency_sec, 2.0 * f.rpc_latency_sec);
        assert_eq!(
            inter_link.bandwidth_bytes_per_sec,
            f.bandwidth_bytes_per_sec / 4.0
        );
    }

    #[test]
    fn ring_cost_scales_with_wrapped_hop_distance() {
        let mut f = FabricConfig::default();
        f.topology = Topology::Ring;
        let one = f.link_model(0, 1, 8);
        let far = f.link_model(0, 4, 8);
        let wrap = f.link_model(0, 7, 8); // distance 1 the short way round
        assert_eq!(far.latency_sec, 4.0 * one.latency_sec);
        assert_eq!(wrap.latency_sec, one.latency_sec);
        assert_eq!(far.bandwidth_bytes_per_sec, one.bandwidth_bytes_per_sec / 4.0);
    }

    #[test]
    fn star_hub_links_are_cheaper_than_spoke_to_spoke() {
        let mut f = FabricConfig::default();
        f.topology = Topology::Star { hub: 0 };
        let to_hub = f.link_model(3, 0, 4);
        let spoke = f.link_model(1, 3, 4);
        assert_eq!(to_hub.latency_sec, f.rpc_latency_sec);
        assert_eq!(spoke.latency_sec, 2.0 * f.rpc_latency_sec);
        assert_eq!(spoke.bandwidth_bytes_per_sec, f.bandwidth_bytes_per_sec / 2.0);
    }

    #[test]
    fn loss_rate_maps_to_deterministic_cadence() {
        let mut f = FabricConfig::default();
        assert_eq!(f.loss_every(), None);
        f.loss_rate = 0.2;
        assert_eq!(f.loss_every(), Some(5));
        f.loss_rate = 0.5;
        assert_eq!(f.loss_every(), Some(2));
    }

    #[test]
    fn straggler_accessor_and_validation() {
        let mut c = RunConfig::default();
        assert_eq!(c.fabric.straggler(), None);
        c.fabric.straggler_worker = 1;
        c.fabric.straggler_factor = 3.0;
        assert_eq!(c.fabric.straggler(), Some((1, 3.0)));
        c.validate().unwrap();
        c.fabric.straggler_worker = 5; // only 2 workers
        assert!(c.validate().is_err());
        c.fabric.straggler_worker = 0;
        c.fabric.straggler_factor = 0.5; // speedups are not stragglers
        assert!(c.validate().is_err());
    }

    #[test]
    fn worker_speed_vector_resolves_per_worker() {
        let mut f = FabricConfig::default();
        assert_eq!(f.slowdown_of(0), 1.0);
        f.worker_speed = vec![1.0, 2.5];
        assert_eq!(f.slowdown_of(0), 1.0);
        assert_eq!(f.slowdown_of(1), 2.5);
        assert_eq!(f.slowdown_of(7), 1.0, "past-the-end workers run nominal");
        // straggler sugar composes multiplicatively with the vector
        f.straggler_worker = 1;
        f.straggler_factor = 2.0;
        assert_eq!(f.slowdown_of(1), 5.0);
        assert_eq!(f.slowdown_of(0), 1.0);
    }

    #[test]
    fn straggler_sugar_equals_equivalent_speed_vector() {
        let mut sugar = FabricConfig::default();
        sugar.straggler_worker = 1;
        sugar.straggler_factor = 3.0;
        let mut vector = FabricConfig::default();
        vector.worker_speed = vec![1.0, 3.0];
        for w in 0..4 {
            assert_eq!(sugar.slowdown_of(w), vector.slowdown_of(w), "worker {w}");
        }
    }

    #[test]
    fn worker_speed_validation() {
        let mut c = RunConfig::default();
        c.fabric.worker_speed = vec![1.0, 2.0];
        c.validate().unwrap();
        c.fabric.worker_speed = vec![1.0, 2.0, 3.0]; // 2 workers only
        assert!(c.validate().is_err());
        c.fabric.worker_speed = vec![0.5]; // speedups rejected like stragglers
        assert!(c.validate().is_err());
        c.fabric.worker_speed = vec![f64::NAN];
        assert!(c.validate().is_err());
    }

    #[test]
    fn worker_speed_survives_value_round_trip() {
        let mut c = RunConfig::default();
        c.fabric.worker_speed = vec![1.0, 4.5];
        let back = RunConfig::from_value(&c.to_value()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn rejects_bad_topologies() {
        let mut c = RunConfig::default();
        c.fabric.topology = Topology::TwoTier { racks: 0, oversubscription: 4.0 };
        assert!(c.validate().is_err());
        c.fabric.topology = Topology::TwoTier { racks: 2, oversubscription: 0.5 };
        assert!(c.validate().is_err());
        c.fabric.topology = Topology::Star { hub: 9 }; // 2 workers
        assert!(c.validate().is_err());
        c.fabric.topology = Topology::Star { hub: 1 };
        c.validate().unwrap();
    }

    #[test]
    fn topology_value_round_trip() {
        for topo in [
            Topology::Flat,
            Topology::TwoTier { racks: 2, oversubscription: 8.0 },
            Topology::Ring,
            Topology::Star { hub: 1 },
            Topology::FatTree { k: 4 },
            Topology::Dragonfly { groups: 2, routers: 2 },
        ] {
            let mut c = RunConfig::default();
            c.fabric.topology = topo;
            c.fabric.loss_rate = 0.125;
            c.fabric.straggler_worker = 1;
            c.fabric.straggler_factor = 2.5;
            let back = RunConfig::from_value(&c.to_value()).unwrap();
            assert_eq!(c, back, "{}", topo.id());
        }
    }

    #[test]
    fn fat_tree_routes_and_linear_price_agree() {
        let mut f = FabricConfig::default();
        f.topology = Topology::FatTree { k: 4 };
        // same pod (0 and 4 with k=4): one switch hop
        let intra = f.link_model(0, 4, 8);
        assert_eq!(intra.latency_sec, f.rpc_latency_sec);
        assert_eq!(intra.bandwidth_bytes_per_sec, f.bandwidth_bytes_per_sec);
        // cross-pod: 2× latency, full bisection bandwidth
        let inter = f.link_model(0, 1, 8);
        assert!((inter.latency_sec - 2.0 * f.rpc_latency_sec).abs() < 1e-15);
        assert_eq!(inter.bandwidth_bytes_per_sec, f.bandwidth_bytes_per_sec);
        // the route's hop latencies sum to the linear price and its
        // bottleneck equals the linear bandwidth
        let route = f.route(0, 1, 8);
        let lat: f64 = route.iter().map(|h| h.latency_sec).sum();
        assert!((lat - inter.latency_sec).abs() < 1e-18);
        assert_eq!(route.len(), 4, "host-up, edge-up, edge-down, host-down");
        // deterministic ECMP: same pair → same spine, different pair may
        // land elsewhere but stays in range
        assert_eq!(f.route(0, 1, 8), f.route(0, 1, 8));
        for h in f.route(2, 5, 8) {
            if let LinkKey::EdgeUp { spine, .. } = h.link {
                assert!(spine < 2);
            }
        }
    }

    #[test]
    fn dragonfly_routes_scale_with_hop_count() {
        let mut f = FabricConfig::default();
        f.topology = Topology::Dragonfly { groups: 2, routers: 2 };
        // workers: group = w % 2, router = (w / 2) % 2
        // 0 and 4: both group 0, routers 0 and 0 → same router
        let same_router = f.link_model(0, 4, 8);
        assert_eq!(same_router.latency_sec, f.rpc_latency_sec);
        // 0 and 2: group 0, routers 0 and 1 → one local hop
        let same_group = f.link_model(0, 2, 8);
        assert_eq!(same_group.latency_sec, 1.5 * f.rpc_latency_sec);
        // 0 and 1: different groups → global cable on the path
        let cross = f.link_model(0, 1, 8);
        assert!(cross.latency_sec >= 3.0 * f.rpc_latency_sec);
        let route = f.route(0, 1, 8);
        assert!(
            route.iter().any(|h| matches!(h.link, LinkKey::Global { .. })),
            "cross-group route must cross a global cable"
        );
        let lat: f64 = route.iter().map(|h| h.latency_sec).sum();
        assert!((lat - cross.latency_sec).abs() < 1e-18);
    }

    #[test]
    fn two_tier_route_bottleneck_matches_linear_bandwidth() {
        let mut f = FabricConfig::default();
        f.topology = Topology::TwoTier { racks: 2, oversubscription: 8.0 };
        let route = f.route(0, 1, 4); // cross-rack
        let min_bw = route
            .iter()
            .map(|h| h.bandwidth_bytes_per_sec)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(min_bw, f.bandwidth_bytes_per_sec / 8.0);
        let lat: f64 = route.iter().map(|h| h.latency_sec).sum();
        assert!((lat - f.link_model(0, 1, 4).latency_sec).abs() < 1e-18);
        // intra-rack stays off the spine
        assert!(f
            .route(0, 2, 4)
            .iter()
            .all(|h| matches!(h.link, LinkKey::HostUp(_) | LinkKey::HostDown(_))));
    }

    #[test]
    fn ring_route_walks_the_short_direction() {
        let mut f = FabricConfig::default();
        f.topology = Topology::Ring;
        assert_eq!(f.route(0, 1, 8).len(), 1);
        assert_eq!(f.route(0, 4, 8).len(), 4);
        let wrap = f.route(0, 7, 8);
        assert_eq!(wrap.len(), 1, "wraps the short way round");
        assert_eq!(wrap[0].link, LinkKey::RingSeg { from: 0, to: 7 });
        // self-transfers don't circle the ring — NIC loopback pair like
        // every other topology
        assert_eq!(f.route(3, 3, 8).len(), 2);
    }

    #[test]
    fn rejects_bad_new_topologies() {
        let mut c = RunConfig::default();
        c.fabric.topology = Topology::FatTree { k: 1 };
        assert!(c.validate().is_err());
        c.fabric.topology = Topology::Dragonfly { groups: 0, routers: 2 };
        assert!(c.validate().is_err());
        c.fabric.topology = Topology::Dragonfly { groups: 2, routers: 0 };
        assert!(c.validate().is_err());
        c.fabric.topology = Topology::FatTree { k: 4 };
        c.validate().unwrap();
    }

    #[test]
    fn contention_flag_round_trips_and_defaults_off() {
        let mut c = RunConfig::default();
        assert!(!c.fabric.contention);
        c.fabric.contention = true;
        let back = RunConfig::from_value(&c.to_value()).unwrap();
        assert!(back.fabric.contention);
        // pre-contention configs (no key) parse to off
        let mut v = Value::table();
        v.set("bandwidth_bytes_per_sec", 1.25e9)
            .set("rpc_latency_sec", 150e-6)
            .set("per_node_overhead_sec", 0.3e-6);
        assert!(!FabricConfig::from_value(&v).unwrap().contention);
    }

    #[test]
    fn speed_phases_resolve_by_epoch() {
        let mut f = FabricConfig::default();
        assert_eq!(f.slowdown_at(0, 5), 1.0);
        f.worker_speed_phases = vec![
            SpeedPhase { from_epoch: 2, speeds: vec![1.0, 3.0] },
            SpeedPhase { from_epoch: 4, speeds: vec![2.0] },
        ];
        assert_eq!(f.phase_factor(1, 0), 1.0, "before the first phase");
        assert_eq!(f.phase_factor(1, 2), 3.0);
        assert_eq!(f.phase_factor(1, 3), 3.0);
        assert_eq!(f.phase_factor(1, 4), 1.0, "later phase replaces, entry absent");
        assert_eq!(f.phase_factor(0, 4), 2.0);
        // layered multiplicatively over the static vector
        f.worker_speed = vec![1.0, 2.0];
        assert_eq!(f.slowdown_at(1, 2), 6.0);
        assert_eq!(f.slowdown_at(1, 0), 2.0);
    }

    #[test]
    fn single_phase_matches_static_vector_bit_exactly() {
        let mut phased = FabricConfig::default();
        phased.worker_speed_phases =
            vec![SpeedPhase { from_epoch: 0, speeds: vec![1.0, 3.5, 2.0] }];
        let mut fixed = FabricConfig::default();
        fixed.worker_speed = vec![1.0, 3.5, 2.0];
        for w in 0..5 {
            for e in 0..4 {
                assert_eq!(phased.slowdown_at(w, e), fixed.slowdown_at(w, e), "w{w} e{e}");
            }
        }
    }

    #[test]
    fn speed_phases_survive_value_round_trip() {
        let mut c = RunConfig::default();
        c.fabric.worker_speed_phases = vec![
            SpeedPhase { from_epoch: 0, speeds: vec![1.0, 2.0] },
            SpeedPhase { from_epoch: 1, speeds: vec![4.0] },
        ];
        let back = RunConfig::from_value(&c.to_value()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn speed_phase_validation() {
        let mut c = RunConfig::default();
        c.fabric.worker_speed_phases =
            vec![SpeedPhase { from_epoch: 0, speeds: vec![0.5] }];
        assert!(c.validate().is_err(), "speedups rejected");
        c.fabric.worker_speed_phases = vec![
            SpeedPhase { from_epoch: 1, speeds: vec![2.0] },
            SpeedPhase { from_epoch: 1, speeds: vec![3.0] },
        ];
        assert!(c.validate().is_err(), "from_epoch must strictly increase");
        c.fabric.worker_speed_phases =
            vec![SpeedPhase { from_epoch: 0, speeds: vec![1.0, 2.0, 3.0] }];
        assert!(c.validate().is_err(), "more entries than workers");
        c.fabric.worker_speed_phases =
            vec![SpeedPhase { from_epoch: 0, speeds: vec![1.0, 2.0] }];
        c.validate().unwrap();
    }

    #[test]
    fn pre_topology_fabric_values_still_parse() {
        // Config files written before the topology axis lack the new keys.
        let mut v = Value::table();
        v.set("bandwidth_bytes_per_sec", 1.25e9)
            .set("rpc_latency_sec", 150e-6)
            .set("per_node_overhead_sec", 0.3e-6);
        let f = FabricConfig::from_value(&v).unwrap();
        assert_eq!(f.topology, Topology::Flat);
        assert_eq!(f.loss_rate, 0.0);
        assert_eq!(f.straggler(), None);
    }

    #[test]
    fn paper_config_shape() {
        let c = RunConfig::paper(DatasetPreset::RedditSim, Engine::Rapid, 1000);
        assert_eq!(c.num_workers, 4);
        assert_eq!(c.epochs, 10);
        assert_eq!(c.batch_size, 1000);
        c.validate().unwrap();
    }

    #[test]
    fn engine_names_come_from_the_registry() {
        assert_eq!(Engine::Rapid.name(), "RapidGNN");
        assert_eq!(Engine::DglMetis.name(), "DGL-METIS");
        assert_eq!(Engine::FastSample.name(), "FastSample");
        assert_eq!(Engine::GreenWindow.name(), "GreenWindow");
    }

    #[test]
    fn engine_parse_round_trip_covers_every_registered_id() {
        for e in crate::coordinator::EngineRegistry::global().engines() {
            assert_eq!(e.id().parse::<Engine>().unwrap(), e);
        }
        // historical aliases still resolve
        assert_eq!("rapidgnn".parse::<Engine>().unwrap(), Engine::Rapid);
        assert_eq!("gcn".parse::<Engine>().unwrap(), Engine::DistGcn);
    }

    #[test]
    fn unknown_engine_error_lists_registered_ids() {
        let err = "bogus".parse::<Engine>().unwrap_err().to_string();
        for id in crate::coordinator::EngineRegistry::global().ids() {
            assert!(err.contains(id), "error '{err}' does not mention '{id}'");
        }
    }

    #[test]
    fn every_registered_engine_survives_value_round_trip() {
        // The registry-wide config contract: id + per-engine params survive
        // to_value → from_value → validate bit-identically.
        for e in crate::coordinator::EngineRegistry::global().engines() {
            let mut c = RunConfig::default();
            c.engine = e;
            c.engine_params.resample_period = 3;
            c.engine_params.fetch_window = 7;
            c.engine_params.resize_period = 2;
            c.engine_params.min_hot = 32;
            c.engine_params.max_hot = 4_096;
            c.engine_params.target_hit_rate = 0.75;
            c.engine_params.tail_utility = 0.05;
            c.engine_params.hot_growth = 1.5;
            c.engine_params.hysteresis = 3;
            c.engine_params.codec = Codec::Int8;
            c.engine_params.codec_block = 64;
            c.engine_params.grad_mode = GradMode::RandK;
            c.engine_params.grad_k = 0.25;
            let back = RunConfig::from_value(&c.to_value()).unwrap();
            assert_eq!(c, back, "{}", e.id());
            back.validate().unwrap();
        }
    }

    #[test]
    fn engine_params_validate() {
        let mut c = RunConfig::default();
        c.engine_params.resample_period = 0;
        assert!(c.validate().is_err());
        c.engine_params.resample_period = 1;
        c.engine_params.fetch_window = 0;
        assert!(c.validate().is_err());
        c.engine_params.fetch_window = 1;
        c.engine_params.min_hot = 0;
        assert!(c.validate().is_err(), "min_hot must be >= 1");
        c.engine_params.min_hot = 128;
        c.engine_params.max_hot = 64; // below min_hot
        assert!(c.validate().is_err(), "max_hot must be >= min_hot");
        c.engine_params.max_hot = 256;
        c.engine_params.target_hit_rate = 1.5;
        assert!(c.validate().is_err());
        c.engine_params.target_hit_rate = 0.9;
        c.engine_params.tail_utility = 1.0; // must stay strictly below 1
        assert!(c.validate().is_err());
        c.engine_params.tail_utility = 0.0;
        c.engine_params.hot_growth = 1.0; // a no-op factor cannot resize
        assert!(c.validate().is_err());
        c.engine_params.hot_growth = 2.0;
        c.engine_params.resize_period = 0; // 0 = controller disabled, legal
        c.validate().unwrap();
        c.engine_params.codec_block = 0;
        assert!(c.validate().is_err(), "codec_block must be >= 1");
        c.engine_params.codec_block = 128;
        c.engine_params.grad_k = 1.5;
        assert!(c.validate().is_err(), "grad_k must be a fraction");
        c.engine_params.grad_k = f64::NAN;
        assert!(c.validate().is_err(), "grad_k must be finite");
        c.engine_params.grad_k = 0.0; // 0 = sparsification off, legal
        c.validate().unwrap();
    }

    #[test]
    fn pre_compression_engine_params_still_parse() {
        // Configs written before the codec knobs existed load with the
        // compression defaults.
        let mut v = Value::table();
        v.set("resample_period", 5u32).set("fetch_window", 2u32);
        let p = EngineParams::from_value(&v).unwrap();
        let d = EngineParams::default();
        assert_eq!(p.codec, Codec::Default);
        assert_eq!(p.codec_block, d.codec_block);
        assert_eq!(p.grad_mode, GradMode::TopK);
        assert_eq!(p.grad_k, d.grad_k);
    }

    #[test]
    fn bad_codec_and_grad_mode_strings_are_rejected() {
        let mut v = EngineParams::default().to_value();
        v.set("codec", "gzip");
        assert!(EngineParams::from_value(&v).is_err());
        let mut v = EngineParams::default().to_value();
        v.set("grad_mode", "bottomk");
        assert!(EngineParams::from_value(&v).is_err());
    }

    #[test]
    fn pre_adaptive_engine_params_still_parse() {
        // Configs written when EngineParams had only the first two knobs
        // must load with controller defaults.
        let mut v = Value::table();
        v.set("resample_period", 5u32).set("fetch_window", 2u32);
        let p = EngineParams::from_value(&v).unwrap();
        assert_eq!(p.resample_period, 5);
        assert_eq!(p.fetch_window, 2);
        let d = EngineParams::default();
        assert_eq!(p.min_hot, d.min_hot);
        assert_eq!(p.max_hot, d.max_hot);
        assert_eq!(p.resize_period, d.resize_period);
        assert_eq!(p.hysteresis, d.hysteresis);
    }

    #[test]
    fn pre_registry_configs_without_engine_params_still_parse() {
        let mut v = RunConfig::default().to_value();
        if let Value::Table(m) = &mut v {
            m.remove("engine_params");
        }
        let cfg = RunConfig::from_value(&v).unwrap();
        assert_eq!(cfg.engine_params, EngineParams::default());
    }

    #[test]
    fn value_round_trip() {
        let mut c = RunConfig::default();
        c.engine = Engine::DistGcn;
        c.exec_mode = ExecMode::Full;
        c.backend = TrainerBackend::Pjrt;
        let back = RunConfig::from_value(&c.to_value()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn from_value_rejects_invalid() {
        let mut c = RunConfig::default();
        c.num_workers = 0; // invalid
        assert!(RunConfig::from_value(&c.to_value()).is_err());
    }

    #[test]
    fn failure_plan_spec_round_trip() {
        let spec = "leave:1@2,join:1@3,linkdown:0-1@1,linkup:0-1@2,crash@3";
        let plan = FailurePlan::parse(spec).unwrap();
        assert_eq!(plan.events.len(), 5);
        assert_eq!(plan.encode(), spec);
        assert_eq!(
            plan.events[0],
            FailureEvent::WorkerLeave { worker: 1, at_epoch: 2 }
        );
        assert_eq!(plan.events[2], FailureEvent::LinkDown { a: 0, b: 1, at_epoch: 1 });
        assert_eq!(plan.events[4], FailureEvent::CrashRestart { at_epoch: 3 });
        assert_eq!(plan.events_at(2).count(), 2);
        // whitespace tolerated, empty string is the empty plan
        let ws = FailurePlan::parse(" leave:0@1 , crash@1 ").unwrap();
        assert_eq!(ws.events.len(), 2);
        assert!(FailurePlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn failure_plan_rejects_malformed_specs() {
        for bad in [
            "leave:1",        // missing @epoch
            "leave@2",        // missing worker
            "leave:x@2",      // bad worker
            "linkdown:0@2",   // missing endpoint pair
            "explode:1@2",    // unknown kind
            "crash:1@2",      // crash takes no worker
            "leave:1@x",      // bad epoch
        ] {
            assert!(FailurePlan::parse(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn failure_plan_validates_against_run_shape() {
        let plan = FailurePlan::parse("leave:1@2").unwrap();
        plan.validate(2, 4).unwrap();
        assert!(plan.validate(1, 4).is_err(), "worker 1 out of range / no donor");
        assert!(plan.validate(2, 2).is_err(), "boundary 2 not interior for 2 epochs");
        let link = FailurePlan::parse("linkdown:0-0@1").unwrap();
        assert!(link.validate(4, 4).is_err(), "self-link rejected");
        let crash = FailurePlan::parse("crash@0").unwrap();
        assert!(crash.validate(4, 4).is_err(), "boundary 0 is not interior");
    }

    #[test]
    fn recovery_knobs_survive_value_round_trip() {
        let mut c = RunConfig::default();
        c.epochs = 4;
        c.failures = "leave:1@2,join:1@3".to_string();
        c.checkpoint_every = 1;
        c.checkpoint_dir = "/tmp/ckpt".to_string();
        let back = RunConfig::from_value(&c.to_value()).unwrap();
        assert_eq!(c, back);
        // TOML file form too
        let text = c.to_value().to_toml().unwrap();
        let again = RunConfig::from_value(&Value::from_toml(&text).unwrap()).unwrap();
        assert_eq!(c, again);
    }

    #[test]
    fn no_failures_config_serializes_byte_identically_to_pre_failure_layer() {
        // The three recovery keys must be absent at their defaults, so a
        // config written by a pre-failure-layer build is byte-identical.
        let c = RunConfig::default();
        let text = c.to_value().to_toml().unwrap();
        for key in ["failures", "checkpoint_every", "checkpoint_dir"] {
            assert!(!text.contains(key), "default config must not emit '{key}':\n{text}");
        }
        // And a hand-stripped table (what an old build would have written)
        // parses to exactly the defaults.
        let back = RunConfig::from_value(&Value::from_toml(&text).unwrap()).unwrap();
        assert_eq!(back.failures, "");
        assert_eq!(back.checkpoint_every, 0);
        assert_eq!(back.checkpoint_dir, "");
        assert_eq!(back, c);
    }

    #[test]
    fn validate_catches_bad_failure_plans_in_config() {
        let mut c = RunConfig::default(); // 2 workers, 2 epochs
        c.failures = "leave:5@1".to_string();
        assert!(c.validate().is_err(), "worker out of range");
        c.failures = "leave:1@1".to_string();
        c.validate().unwrap();
        c.failures = "leave:1@2".to_string();
        assert!(c.validate().is_err(), "boundary must be interior");
        c.failures = "not a plan".to_string();
        assert!(c.validate().is_err(), "unparseable spec");
    }
}
