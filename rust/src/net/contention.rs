//! Shared-link contention: the discrete-event queueing model behind
//! `fabric.contention = true`.
//!
//! The linear price charges every RPC independently — two concurrent pulls
//! through an oversubscribed spine never slow each other down, which is
//! exactly the effect RapidGNN's prefetch scheduling is designed to hide.
//! This module replaces that price with a fluid queueing model over the
//! *physical* links of the topology:
//!
//! - every RPC recorded by the charge path becomes a [`FlowSpec`] route
//!   claim: **enqueue** (registered at the virtual instant its stage starts)
//!   → **transmit** (after the route's fixed latency/serialization offset)
//!   → **drain** (when its service bytes finish at the shared rates);
//! - each hop of the claim's route ([`FabricConfig::route`]) is a shared
//!   link whose capacity is divided **processor-sharing** style: a
//!   transmitting flow's rate is the minimum over its route of
//!   `capacity / in-flight transfers` on that link;
//! - rates are piecewise constant between events, so the next event
//!   (an activation or the earliest drain) is exact; events are processed
//!   in virtual-time order with deterministic tie-breaking on
//!   `(time, src, dst, seq)` — `seq` is the fabric's global RPC counter.
//!
//! [`ContentionNet`] plugs into [`crate::sim::ClusterSim`]: stage events and
//! link events interleave on one virtual clock, so a worker's `StageDone`
//! fires when its *contended* flows drain (plus the stage's local residual
//! cost), not at the closed-form linear price. Uncongested, a flow costs
//! exactly the linear price on the switched topologies (flat, two-tier,
//! fat-tree, dragonfly) — the tests below pin this — so contention only ever
//! adds time there.
//!
//! Per-link telemetry (busy time, served bytes, peak in-flight transfers,
//! peak backlog) accumulates while flows drain and is committed to the
//! owning [`NetFabric`] by [`ContentionNet::finalize`], where it surfaces as
//! [`super::LinkUtilization`] in `RunReport.links` and the fig6 bench.

use super::{FlowSpec, LinkUtilization, NetFabric};
use crate::config::{FabricConfig, LinkKey};
use crate::util::value::Value;
use std::collections::BTreeMap;

/// Residual service (bytes) below which a flow counts as drained — absorbs
/// float drift from `rate · (remaining / rate)` round trips. Well below one
/// wire byte; far above f64 noise at realistic transfer sizes.
const EPS_BYTES: f64 = 1e-6;
/// Residual *time* (seconds) below which a flow counts as drained, scaled by
/// its current rate — the relative counterpart of [`EPS_BYTES`] for very
/// large transfers.
const EPS_SEC: f64 = 1e-9;

/// One shared physical link's live state.
struct LinkSlot {
    key: LinkKey,
    capacity: f64,
    /// Transmitting flows currently crossing this link.
    active: u32,
    busy_sec: f64,
    served_bytes: f64,
    flows: u64,
    peak_flows: u32,
    /// Outstanding service bytes of all flows (latent + transmitting)
    /// routed over this link.
    backlog_bytes: f64,
    peak_backlog_bytes: f64,
}

/// One in-flight transfer.
struct Flow {
    stage: usize,
    route: Vec<usize>,
    /// When the fixed latency/serialization offset elapses and bytes start
    /// flowing.
    activate_at: f64,
    transmitting: bool,
    /// Service bytes left to drain.
    remaining: f64,
    /// Current service rate (bytes/sec); valid while transmitting.
    rate: f64,
    src: u32,
    dst: u32,
    seq: u64,
    done: bool,
}

impl Flow {
    fn is_drained(&self, now: f64) -> bool {
        self.transmitting
            && (self.remaining <= EPS_BYTES
                || (self.rate > 0.0
                    // residual drains in under a nanosecond, or in less than
                    // one float ulp of the clock (no representable progress)
                    && (self.remaining <= self.rate * EPS_SEC
                        || now + self.remaining / self.rate <= now)))
    }
}

/// One staging call's pending network work: `outstanding` flows must drain
/// before the stage's `StageDone` (plus `local_cost`) may fire.
struct Stage {
    worker: u32,
    local_cost: f64,
    outstanding: u32,
}

/// The shared-link discrete-event network, driven by the cluster runtime's
/// virtual clock. One instance per simulated epoch; telemetry accumulates
/// into the owning fabric across epochs.
pub struct ContentionNet {
    fabric: NetFabric,
    cfg: FabricConfig,
    world: u32,
    links: Vec<LinkSlot>,
    index: BTreeMap<LinkKey, usize>,
    /// Resolved `(src, dst) → link indices` — routes are static per
    /// topology, so each pair derives its hop list once per epoch.
    routes: BTreeMap<(u32, u32), Vec<usize>>,
    flows: Vec<Flow>,
    stages: Vec<Stage>,
    now: f64,
    /// Optional trace sink + the epoch tag for its records. Strictly
    /// observational: with `None` the model takes the exact pre-trace paths.
    tracer: Option<(crate::trace::TraceHandle, u32)>,
}

impl ContentionNet {
    /// New network over the fabric's topology (telemetry commits back to it).
    pub fn new(fabric: &NetFabric) -> Self {
        ContentionNet {
            cfg: fabric.config().clone(),
            world: fabric.world_size(),
            fabric: fabric.clone(),
            links: Vec::new(),
            index: BTreeMap::new(),
            routes: BTreeMap::new(),
            flows: Vec::new(),
            stages: Vec::new(),
            now: 0.0,
            tracer: None,
        }
    }

    /// Attach a virtual-time trace sink; flow enqueues and drains journal as
    /// `flow-enqueue` / `flow-drain` records tagged with `epoch`.
    pub fn with_tracer(mut self, trace: crate::trace::TraceHandle, epoch: u32) -> Self {
        self.tracer = Some((trace, epoch));
        self
    }

    /// Link indices of the `(src, dst)` route, derived once per pair.
    fn route_of(&mut self, src: u32, dst: u32) -> Vec<usize> {
        if let Some(r) = self.routes.get(&(src, dst)) {
            return r.clone();
        }
        let hops = self.cfg.route(src, dst, self.world);
        debug_assert!(!hops.is_empty(), "every topology routes over >= 1 link");
        let mut route = Vec::with_capacity(hops.len());
        for h in hops {
            route.push(self.link_idx(h.link, h.bandwidth_bytes_per_sec));
        }
        self.routes.insert((src, dst), route.clone());
        route
    }

    fn link_idx(&mut self, key: LinkKey, capacity: f64) -> usize {
        if let Some(&i) = self.index.get(&key) {
            return i;
        }
        let i = self.links.len();
        self.links.push(LinkSlot {
            key,
            capacity,
            active: 0,
            busy_sec: 0.0,
            served_bytes: 0.0,
            flows: 0,
            peak_flows: 0,
            backlog_bytes: 0.0,
            peak_backlog_bytes: 0.0,
        });
        self.index.insert(key, i);
        i
    }

    /// Integrate transmissions at the current (piecewise-constant) rates
    /// from `self.now` to `t`.
    fn integrate_to(&mut self, t: f64) {
        let dt = t - self.now;
        debug_assert!(dt >= -1e-15, "virtual time went backwards: {} -> {t}", self.now);
        if dt > 0.0 {
            for l in &mut self.links {
                if l.active > 0 {
                    l.busy_sec += dt;
                }
            }
            for f in &mut self.flows {
                if f.done || !f.transmitting {
                    continue;
                }
                let delta = (f.rate * dt).min(f.remaining);
                f.remaining -= delta;
                for &li in &f.route {
                    let l = &mut self.links[li];
                    l.served_bytes += delta;
                    l.backlog_bytes = (l.backlog_bytes - delta).max(0.0);
                }
            }
        }
        self.now = t;
    }

    /// Latent flows whose fixed offset has elapsed start transmitting.
    fn activate_due(&mut self) {
        for f in &mut self.flows {
            if !f.done && !f.transmitting && f.activate_at <= self.now {
                f.transmitting = true;
            }
        }
    }

    /// Recompute every transmitting flow's processor-sharing rate and the
    /// per-link concurrency telemetry. Called whenever the flow set changes.
    fn recompute_rates(&mut self) {
        for l in &mut self.links {
            l.active = 0;
        }
        for f in &self.flows {
            if f.done || !f.transmitting {
                continue;
            }
            for &li in &f.route {
                self.links[li].active += 1;
            }
        }
        for l in &mut self.links {
            l.peak_flows = l.peak_flows.max(l.active);
        }
        for fi in 0..self.flows.len() {
            if self.flows[fi].done || !self.flows[fi].transmitting {
                continue;
            }
            let mut rate = f64::INFINITY;
            for &li in &self.flows[fi].route {
                let l = &self.links[li];
                rate = rate.min(l.capacity / l.active as f64);
            }
            self.flows[fi].rate = rate;
        }
    }

    /// Debug-build invariant sweep — the runtime half of the determinism
    /// contracts (see `src/sim/README.md` § Determinism contracts), and the
    /// tripwires the Miri/TSan CI jobs exercise. Compiled out of release
    /// builds; called after every state transition.
    ///
    /// Checks, per link: non-negative finite backlog bounded by its peak,
    /// and byte conservation `served ≤ capacity × busy (+ drain-residual
    /// slack)` — equivalently the ISSUE's `Σ busy ≥ bytes / bandwidth`.
    /// Per flow: non-negative remaining service, and a positive finite
    /// processor-sharing rate while transmitting. Globally: each link's
    /// cached `active` count equals a fresh recount over in-flight flows.
    #[cfg(debug_assertions)]
    fn debug_invariants(&self) {
        let mut active = vec![0u32; self.links.len()];
        for f in &self.flows {
            debug_assert!(
                f.remaining.is_finite() && f.remaining >= 0.0,
                "flow {}->{} seq {} has invalid remaining {}",
                f.src,
                f.dst,
                f.seq,
                f.remaining
            );
            if f.done || !f.transmitting {
                continue;
            }
            debug_assert!(
                f.rate.is_finite() && f.rate > 0.0,
                "transmitting flow {}->{} seq {} has rate {}",
                f.src,
                f.dst,
                f.seq,
                f.rate
            );
            for &li in &f.route {
                active[li] += 1;
            }
        }
        for (l, &a) in self.links.iter().zip(&active) {
            debug_assert_eq!(l.active, a, "link {:?}: active-count drift", l.key);
            debug_assert!(l.peak_flows >= a, "link {:?}: peak below current", l.key);
            debug_assert!(
                l.backlog_bytes.is_finite() && l.backlog_bytes >= 0.0,
                "link {:?}: negative/non-finite backlog {}",
                l.key,
                l.backlog_bytes
            );
            debug_assert!(
                l.peak_backlog_bytes + 1e-6 >= l.backlog_bytes,
                "link {:?}: backlog {} above recorded peak {}",
                l.key,
                l.backlog_bytes,
                l.peak_backlog_bytes
            );
            // Drain credits each flow's sub-epsilon residual as served
            // without busy time; bound that slack per historical flow.
            let slack = l.flows as f64 * (EPS_BYTES + 2.0 * l.capacity * EPS_SEC) + 1.0;
            debug_assert!(
                l.served_bytes <= l.capacity * l.busy_sec * (1.0 + 1e-9) + slack,
                "link {:?}: served {} exceeds capacity {} x busy {} + slack {}",
                l.key,
                l.served_bytes,
                l.capacity,
                l.busy_sec,
                slack
            );
        }
    }

    #[cfg(not(debug_assertions))]
    fn debug_invariants(&self) {}

    /// Register one stage's flows at virtual instant `now` (≥ the last event
    /// time). The stage completes — and is returned by [`Self::advance`] —
    /// once every flow drains.
    pub fn begin_stage(&mut self, now: f64, worker: u32, local_cost: f64, specs: Vec<FlowSpec>) {
        debug_assert!(!specs.is_empty(), "flow-less stages schedule directly");
        // Event-time monotonicity: the clamp below keeps release builds
        // safe, but a caller handing us the past is a scheduler bug.
        debug_assert!(
            now >= self.now - 1e-9 * self.now.abs().max(1.0),
            "stage registered in the past: {now} < {}",
            self.now
        );
        self.integrate_to(now.max(self.now));
        let stage = self.stages.len();
        self.stages.push(Stage { worker, local_cost, outstanding: specs.len() as u32 });
        for spec in specs {
            let route = self.route_of(spec.src, spec.dst);
            for &li in &route {
                let l = &mut self.links[li];
                l.flows += 1;
                l.backlog_bytes += spec.service_bytes;
                l.peak_backlog_bytes = l.peak_backlog_bytes.max(l.backlog_bytes);
            }
            if let Some((trace, epoch)) = &self.tracer {
                let mut fields = Value::table();
                fields.set("src", spec.src);
                fields.set("dst", spec.dst);
                fields.set("bytes", spec.bytes);
                fields.set("flow", spec.seq);
                trace.event(worker, *epoch, self.now, "flow-enqueue", fields);
            }
            self.flows.push(Flow {
                stage,
                route,
                activate_at: self.now + spec.fixed_sec,
                transmitting: false,
                remaining: spec.service_bytes,
                rate: 0.0,
                src: spec.src,
                dst: spec.dst,
                seq: spec.seq,
                done: false,
            });
        }
        self.activate_due();
        self.recompute_rates();
        self.debug_invariants();
    }

    /// Earliest pending network event: a latent flow's activation or the
    /// earliest drain at current rates. `None` when the network is idle.
    pub fn next_event_time(&self) -> Option<f64> {
        let mut t = f64::INFINITY;
        for f in &self.flows {
            if f.done {
                continue;
            }
            let c = if f.transmitting {
                if f.is_drained(self.now) {
                    self.now
                } else {
                    self.now + f.remaining / f.rate
                }
            } else {
                f.activate_at
            };
            t = t.min(c);
        }
        t.is_finite().then_some(t)
    }

    /// Advance the network to virtual time `t`: integrate transmissions,
    /// drain completed flows (tie-broken on `(src, dst, seq)` at equal
    /// times), start newly due ones, and re-share the links. Returns every
    /// stage whose last flow drained at `t` as `(worker, local_cost)`.
    pub fn advance(&mut self, t: f64) -> Vec<(u32, f64)> {
        debug_assert!(
            t >= self.now - 1e-9 * self.now.abs().max(1.0),
            "advance into the past: {t} < {}",
            self.now
        );
        self.integrate_to(t.max(self.now));
        let now = self.now;
        let mut drained: Vec<usize> = (0..self.flows.len())
            .filter(|&fi| !self.flows[fi].done && self.flows[fi].is_drained(now))
            .collect();
        drained.sort_by_key(|&fi| {
            let f = &self.flows[fi];
            (f.src, f.dst, f.seq)
        });
        let drained_any = !drained.is_empty();
        let mut finished = Vec::new();
        for fi in drained {
            let (stage_idx, residual, src, dst, fseq) = {
                let f = &mut self.flows[fi];
                f.done = true;
                f.transmitting = false;
                let r = f.remaining;
                f.remaining = 0.0;
                (f.stage, r, f.src, f.dst, f.seq)
            };
            for li_pos in 0..self.flows[fi].route.len() {
                let li = self.flows[fi].route[li_pos];
                let l = &mut self.links[li];
                l.backlog_bytes = (l.backlog_bytes - residual).max(0.0);
                // account the residual as served so per-link conservation
                // (served == Σ flow service) holds exactly
                l.served_bytes += residual;
            }
            let st = &mut self.stages[stage_idx];
            st.outstanding -= 1;
            let stage_worker = st.worker;
            if st.outstanding == 0 {
                finished.push((st.worker, st.local_cost));
            }
            if let Some((trace, epoch)) = &self.tracer {
                let mut fields = Value::table();
                fields.set("src", src);
                fields.set("dst", dst);
                fields.set("flow", fseq);
                trace.event(stage_worker, *epoch, now, "flow-drain", fields);
            }
        }
        // Prune drained flows (relative order preserved → deterministic):
        // every per-event scan stays proportional to the *in-flight* flow
        // count instead of all flows the epoch ever created.
        if drained_any {
            self.flows.retain(|f| !f.done);
        }
        self.activate_due();
        self.recompute_rates();
        self.debug_invariants();
        finished
    }

    /// Commit per-link telemetry to the owning fabric. Call when the epoch's
    /// simulation has quiesced; all flows must have drained.
    pub fn finalize(self) {
        self.debug_invariants();
        debug_assert!(self.flows.iter().all(|f| f.done), "undrained flows at finalize");
        debug_assert!(self.stages.iter().all(|s| s.outstanding == 0));
        let ContentionNet { fabric, links, .. } = self;
        let entries = links
            .into_iter()
            .map(|l| {
                (
                    l.key,
                    LinkUtilization {
                        capacity_bytes_per_sec: l.capacity,
                        busy_sec: l.busy_sec,
                        served_bytes: l.served_bytes,
                        flows: l.flows,
                        peak_flows: l.peak_flows,
                        peak_backlog_bytes: l.peak_backlog_bytes,
                    },
                )
            })
            .collect();
        fabric.record_link_utilization(entries);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Topology;

    fn two_tier_fabric(oversub: f64) -> NetFabric {
        let mut cfg = FabricConfig::default();
        cfg.topology = Topology::TwoTier { racks: 2, oversubscription: oversub };
        cfg.contention = true;
        NetFabric::new(cfg).with_world_size(4)
    }

    fn spec(src: u32, dst: u32, bytes: u64, fixed: f64, seq: u64) -> FlowSpec {
        FlowSpec { src, dst, bytes, fixed_sec: fixed, service_bytes: bytes as f64, seq }
    }

    /// Drive the network to quiescence; returns (time, worker, local).
    fn drain(net: &mut ContentionNet) -> Vec<(f64, u32, f64)> {
        let mut out = Vec::new();
        let mut guard = 0;
        while let Some(t) = net.next_event_time() {
            for (w, local) in net.advance(t) {
                out.push((t, w, local));
            }
            guard += 1;
            assert!(guard < 100_000, "network failed to quiesce");
        }
        out
    }

    #[test]
    fn uncongested_flow_costs_exactly_the_linear_price() {
        let f = two_tier_fabric(4.0);
        let cfg = f.config().clone();
        let bytes = 1_000_000u64;
        let linear = cfg.rpc_time_on_link(0, 1, 4, bytes, 0); // cross-rack
        let mut net = ContentionNet::new(&f);
        let lat = cfg.link_model(0, 1, 4).latency_sec;
        net.begin_stage(0.0, 0, 0.25, vec![spec(0, 1, bytes, lat, 1)]);
        let done = drain(&mut net);
        assert_eq!(done.len(), 1);
        let (t, w, local) = done[0];
        assert_eq!(w, 0);
        assert_eq!(local, 0.25);
        assert!(
            (t - linear).abs() < 1e-12 * linear.max(1.0),
            "uncongested {t} != linear {linear}"
        );
        net.finalize();
        let util = f.link_utilization();
        assert!(!util.is_empty());
        let spine_busy: f64 = util
            .iter()
            .filter(|(k, _)| matches!(k, LinkKey::RackUp(_) | LinkKey::RackDown(_)))
            .map(|(_, u)| u.busy_sec)
            .sum();
        assert!(spine_busy > 0.0, "cross-rack flow must occupy the spine");
    }

    #[test]
    fn two_flows_share_the_spine_half_rate_each() {
        let f = two_tier_fabric(4.0);
        let cfg = f.config().clone();
        let bytes = 2_000_000u64;
        let solo = cfg.rpc_time_on_link(0, 1, 4, bytes, 0);
        let lat = cfg.link_model(0, 1, 4).latency_sec;
        let mut net = ContentionNet::new(&f);
        // two cross-rack flows, distinct hosts, same spine uplink (rack 0)
        net.begin_stage(0.0, 0, 0.0, vec![spec(0, 1, bytes, lat, 1)]);
        net.begin_stage(0.0, 1, 0.0, vec![spec(2, 3, bytes, lat, 2)]);
        let done = drain(&mut net);
        assert_eq!(done.len(), 2);
        // each flow's service takes 2× solo service (half the spine each);
        // total = latency + 2 × (solo − latency)
        let expect = lat + 2.0 * (solo - lat);
        for &(t, _, _) in &done {
            assert!((t - expect).abs() < 1e-9, "shared spine: {t} vs {expect}");
        }
    }

    #[test]
    fn late_arrival_slows_the_flow_already_in_flight() {
        let f = two_tier_fabric(8.0);
        let cfg = f.config().clone();
        let bytes = 4_000_000u64;
        let solo = cfg.rpc_time_on_link(0, 1, 4, bytes, 0);
        let lat = cfg.link_model(0, 1, 4).latency_sec;
        let mut net = ContentionNet::new(&f);
        net.begin_stage(0.0, 0, 0.0, vec![spec(0, 1, bytes, lat, 1)]);
        // second flow enters halfway through the first's solo schedule
        let mid = solo / 2.0;
        // drive events up to `mid` first so time only moves forward
        while let Some(t) = net.next_event_time() {
            if t > mid {
                break;
            }
            net.advance(t);
        }
        net.begin_stage(mid, 1, 0.0, vec![spec(2, 3, bytes, lat, 2)]);
        let done = drain(&mut net);
        assert_eq!(done.len(), 2);
        let first = done.iter().find(|&&(_, w, _)| w == 0).unwrap().0;
        assert!(first > solo + 1e-12, "contended {first} !> solo {solo}");
        assert!(first < 2.0 * solo, "but better than fully serialized");
    }

    #[test]
    fn incast_on_flat_topology_shares_the_destination_nic() {
        let mut cfg = FabricConfig::default();
        cfg.contention = true;
        let f = NetFabric::new(cfg.clone()).with_world_size(4);
        let bytes = 1_000_000u64;
        let solo = cfg.rpc_time_on_link(1, 0, 4, bytes, 0);
        let lat = cfg.link_model(1, 0, 4).latency_sec;
        let mut net = ContentionNet::new(&f);
        // three workers pull from worker 0 simultaneously: the hotspot is
        // worker 0's NIC, which the linear price cannot see.
        for (i, src) in [1u32, 2, 3].iter().enumerate() {
            net.begin_stage(0.0, *src, 0.0, vec![spec(0, *src, bytes, lat, i as u64 + 1)]);
        }
        let done = drain(&mut net);
        assert_eq!(done.len(), 3);
        let expect = lat + 3.0 * (solo - lat);
        for &(t, _, _) in &done {
            assert!((t - expect).abs() < 1e-9, "incast: {t} vs {expect}");
        }
        net.finalize();
        let util = f.link_utilization();
        let hot = util
            .iter()
            .find(|(k, _)| *k == LinkKey::HostUp(0))
            .expect("worker 0 egress accounted")
            .1;
        assert_eq!(hot.flows, 3);
        assert_eq!(hot.peak_flows, 3);
        assert!(hot.peak_backlog_bytes >= 3.0 * bytes as f64);
    }

    #[test]
    fn served_bytes_and_busy_time_are_conserved() {
        let f = two_tier_fabric(4.0);
        let cfg = f.config().clone();
        let lat = cfg.link_model(0, 1, 4).latency_sec;
        let mut net = ContentionNet::new(&f);
        let mut total_bytes = 0u64;
        for (i, (s, d)) in [(0u32, 1u32), (2, 3), (1, 2), (3, 0)].iter().enumerate() {
            let bytes = 500_000 + 250_000 * i as u64;
            total_bytes += bytes;
            net.begin_stage(0.0, *s, 0.0, vec![spec(*s, *d, bytes, lat, i as u64 + 1)]);
        }
        drain(&mut net);
        net.finalize();
        let util = f.link_utilization();
        let b = cfg.bandwidth_bytes_per_sec;
        // per link: served bytes never exceed capacity × busy time
        for (k, u) in &util {
            assert!(
                u.served_bytes <= u.capacity_bytes_per_sec * u.busy_sec * (1.0 + 1e-9),
                "{k:?}: served {} > cap×busy {}",
                u.served_bytes,
                u.capacity_bytes_per_sec * u.busy_sec
            );
        }
        // the ISSUE's conservation bound: Σ busy ≥ Σ RPC bytes / bandwidth
        let busy: f64 = util.iter().map(|(_, u)| u.busy_sec).sum();
        assert!(
            busy >= total_bytes as f64 / b - 1e-9,
            "Σ busy {busy} < Σ bytes/bw {}",
            total_bytes as f64 / b
        );
        // every byte of every flow crossed each host egress exactly once
        let egress: f64 = util
            .iter()
            .filter(|(k, _)| matches!(k, LinkKey::HostUp(_)))
            .map(|(_, u)| u.served_bytes)
            .sum();
        assert!((egress - total_bytes as f64).abs() < 1e-3, "{egress} vs {total_bytes}");
    }

    #[test]
    fn event_order_is_deterministic() {
        let run = || {
            let f = two_tier_fabric(8.0);
            let cfg = f.config().clone();
            let lat = cfg.link_model(0, 1, 4).latency_sec;
            let mut net = ContentionNet::new(&f);
            for (i, (s, d)) in
                [(0u32, 1u32), (2, 3), (0, 3), (1, 2), (3, 0), (2, 1)].iter().enumerate()
            {
                net.begin_stage(
                    i as f64 * 1e-5,
                    *s,
                    0.1 * i as f64,
                    vec![spec(*s, *d, 700_000 + i as u64, lat, i as u64 + 1)],
                );
            }
            let events = drain(&mut net);
            net.finalize();
            (events, f.link_utilization())
        };
        let (e1, u1) = run();
        let (e2, u2) = run();
        assert_eq!(e1.len(), e2.len());
        for (a, b) in e1.iter().zip(&e2) {
            assert_eq!(a.1, b.1);
            assert_eq!(a.2, b.2);
            assert!((a.0 - b.0).abs() < 1e-18, "event times must be bit-stable");
        }
        assert_eq!(u1.len(), u2.len());
        for ((ka, ua), (kb, ub)) in u1.iter().zip(&u2) {
            assert_eq!(ka, kb);
            assert_eq!(ua, ub);
        }
    }

    #[test]
    fn lower_spine_capacity_never_speeds_a_flow_up() {
        let mut last = 0.0;
        for oversub in [1.0f64, 4.0, 16.0] {
            let f = two_tier_fabric(oversub);
            let cfg = f.config().clone();
            let lat = cfg.link_model(0, 1, 4).latency_sec;
            let mut net = ContentionNet::new(&f);
            net.begin_stage(0.0, 0, 0.0, vec![spec(0, 1, 1_000_000, lat, 1)]);
            net.begin_stage(0.0, 1, 0.0, vec![spec(2, 3, 1_000_000, lat, 2)]);
            let t = drain(&mut net).iter().map(|e| e.0).fold(0.0, f64::max);
            assert!(t >= last - 1e-12, "oversub {oversub}: {t} < {last}");
            last = t;
        }
    }
}
