//! Pluggable transport backends behind one [`ChargeSpec`] charging surface.
//!
//! The fabric's historical `charge_rpc` / `charge_fanout` wrapper ladder
//! collapsed into a single request type: every transfer the KV store (or any
//! future subsystem) issues is a [`ChargeSpec`], consumed by one
//! [`Transport::charge`] entry point. Two backends implement it:
//!
//! - [`Analytic`] — the default. A thin wrapper over [`NetFabric::charge`],
//!   i.e. exactly the closed-form linear pricing every run has always used.
//!   Byte-stable: the same float operations in the same order as the old
//!   ladder, so golden traces do not move.
//! - [`ShmRings`] — the first *real* backend. One server thread per worker
//!   shard (spawned through [`crate::util::parallel::spawn_worker`], the
//!   sanctioned doorway) serves serialized feature bytes over bounded
//!   [`crate::util::mpmc`] rings; every charge actually moves
//!   `payload_bytes` of shard data through the rings and measures the
//!   transfer with [`crate::util::wallclock::Stopwatch`]. Pricing and all
//!   deterministic counters still delegate to the *same* [`NetFabric`], so
//!   remote-row/byte counters are conformant with the simulated trace by
//!   construction; the wall-clock measurements are accumulated separately
//!   and surface only in the run's `CalibrationReport`.
//!
//! Determinism contract: a real backend may *describe* a run (measured
//! seconds, measured bytes) but must never *steer* one — nothing downstream
//! of [`Transport::charge`] reads the measured values back into scheduling,
//! pricing, or any serialized ordering decision.

use crate::net::{Charge, NetFabric};
use crate::util::mpmc;
use crate::util::parallel::spawn_worker;
use crate::util::wallclock::Stopwatch;
use crate::WorkerId;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::thread::JoinHandle;

/// One transfer request: everything a backend needs to price (and, for real
/// backends, perform) a single RPC-shaped movement of feature rows.
///
/// Replaces the `charge_rpc{,_at,_payload_at}` argument ladder; the
/// deprecated wrappers map onto it as:
///
/// | deprecated method                     | `ChargeSpec` equivalent            |
/// |---------------------------------------|------------------------------------|
/// | `charge_rpc(s,d,r,rb)`                | `ChargeSpec::rows(s,d,r,rb)`       |
/// | `charge_rpc_at(s,d,r,rb,e)`           | `ChargeSpec::rows(s,d,r,rb).at(e)` |
/// | `charge_rpc_payload_at(s,d,r,p,e)`    | `ChargeSpec::payload(s,d,r,p).at(e)` |
/// | `charge_fanout*` families             | a `Vec<ChargeSpec>` + `charge_many` |
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChargeSpec {
    /// Requesting worker (the side whose critical path pays the time).
    pub src: WorkerId,
    /// Owner worker the payload comes from.
    pub dst: WorkerId,
    /// Feature rows carried (prices the per-row serialization overhead and
    /// drives the row counters; codec-invariant).
    pub rows: u64,
    /// Wire payload in bytes, *excluding* the fixed 64-byte RPC envelope
    /// (compressed rows + codec block headers on the codec path, plain
    /// `rows × row_bytes` otherwise).
    pub payload_bytes: u64,
    /// Requester's training epoch — resolves transient speed phases.
    pub epoch: u32,
}

impl ChargeSpec {
    /// Uncompressed spec: `payload = rows × row_bytes`, epoch 0.
    pub fn rows(src: WorkerId, dst: WorkerId, rows: u64, row_bytes: u64) -> ChargeSpec {
        ChargeSpec { src, dst, rows, payload_bytes: rows * row_bytes, epoch: 0 }
    }

    /// Payload-granular spec (the codec path), epoch 0.
    pub fn payload(src: WorkerId, dst: WorkerId, rows: u64, payload_bytes: u64) -> ChargeSpec {
        ChargeSpec { src, dst, rows, payload_bytes, epoch: 0 }
    }

    /// Resolve transient speed phases against `epoch`.
    pub fn at(mut self, epoch: u32) -> ChargeSpec {
        self.epoch = epoch;
        self
    }
}

/// A transport backend: prices — and for real backends performs — transfers
/// described by [`ChargeSpec`]s. Implementations must be shareable across
/// worker threads (`Send + Sync`); the KV store holds one behind an `Arc`.
pub trait Transport: Send + Sync {
    /// Price (and, for real backends, perform) one transfer.
    fn charge(&self, spec: ChargeSpec) -> Charge;

    /// A fan-out issued in parallel: zero-row specs are skipped, the
    /// critical-path time is the max over specs, bytes sum — the same
    /// semantics as [`NetFabric::charge_many`].
    fn charge_many(&self, specs: &[ChargeSpec]) -> Charge {
        let mut max_time = 0f64;
        let mut total_bytes = 0u64;
        for &s in specs {
            if s.rows == 0 {
                continue;
            }
            let c = self.charge(s);
            max_time = max_time.max(c.time);
            total_bytes += c.bytes;
        }
        Charge { time: max_time, bytes: total_bytes }
    }

    /// Stable backend identifier (lands in the calibration report).
    fn backend_id(&self) -> &'static str;
}

/// The default backend: closed-form analytic pricing, i.e. exactly
/// [`NetFabric::charge`]. No bytes move; the virtual clock is the only
/// clock. All pre-transport behavior lives here unchanged.
#[derive(Clone)]
pub struct Analytic {
    fabric: NetFabric,
}

impl Analytic {
    /// Wrap a fabric handle (shared state: charges land on the same
    /// counters every other handle sees).
    pub fn new(fabric: NetFabric) -> Analytic {
        Analytic { fabric }
    }

    /// The underlying fabric handle.
    pub fn fabric(&self) -> &NetFabric {
        &self.fabric
    }
}

impl Transport for Analytic {
    fn charge(&self, spec: ChargeSpec) -> Charge {
        self.fabric.charge(spec)
    }

    fn charge_many(&self, specs: &[ChargeSpec]) -> Charge {
        self.fabric.charge_many(specs)
    }

    fn backend_id(&self) -> &'static str {
        "analytic"
    }
}

/// Measured wall-clock totals for one (src, dst) worker pair.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MeasuredLink {
    /// Payload bytes actually moved through the rings (envelopes are a
    /// virtual-pricing construct and are not materialized).
    pub payload_bytes: u64,
    /// Wall-clock seconds spent in transfers, request send → last chunk.
    pub wall_sec: f64,
    /// Transfers performed.
    pub rpcs: u64,
}

/// Measured wall-clock totals for one training epoch.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MeasuredEpoch {
    /// Payload bytes actually moved during the epoch's charges.
    pub payload_bytes: u64,
    /// Wall-clock seconds spent in the epoch's transfers.
    pub wall_sec: f64,
    /// Transfers performed.
    pub rpcs: u64,
}

#[derive(Default)]
struct MeasuredState {
    links: BTreeMap<(WorkerId, WorkerId), MeasuredLink>,
    epochs: BTreeMap<u32, MeasuredEpoch>,
}

/// One pull-shaped request to a shard server: serve `payload_bytes` of the
/// shard blob in chunks over `reply`, then hang up (drop the sender).
struct ShmRequest {
    payload_bytes: u64,
    reply: mpmc::Sender<Vec<u8>>,
}

/// Chunk granularity on the reply rings.
const CHUNK_BYTES: usize = 64 * 1024;
/// Outstanding requests a shard server will queue.
const REQUEST_DEPTH: usize = 64;
/// In-flight chunks per transfer before the server blocks on the ring.
const REPLY_DEPTH: usize = 8;

/// The in-process shared-memory backend: per-worker server threads moving
/// real feature bytes over bounded MPMC rings.
///
/// Pricing, retry cadence, and every deterministic counter delegate to the
/// wrapped [`NetFabric`] — a `ShmRings` run's *modeled* quantities are
/// bit-identical to an [`Analytic`] run of the same schedule. What it adds
/// is measurement: each charge serializes through a ring transfer of
/// exactly `payload_bytes` bytes of shard data, timed with [`Stopwatch`],
/// accumulated per link and per epoch for the calibration report.
pub struct ShmRings {
    fabric: NetFabric,
    /// Request ring senders, one per worker shard server.
    reqs: Vec<mpmc::Sender<ShmRequest>>,
    /// Server join handles, reaped on drop (after the senders close).
    servers: Vec<JoinHandle<()>>,
    measured: Mutex<MeasuredState>,
    /// Started at construction; [`Self::run_wall_sec`] reads it.
    started: Stopwatch,
}

impl ShmRings {
    /// Spawn one server thread per shard blob. `shard_blobs[w]` is worker
    /// `w`'s serialized feature bytes (the store's little-endian f32 rows);
    /// an empty blob is served as zeros so metadata-only stores still move
    /// real bytes.
    pub fn new(fabric: NetFabric, shard_blobs: Vec<Vec<u8>>) -> ShmRings {
        assert!(!shard_blobs.is_empty(), "ShmRings needs at least one shard server");
        let mut reqs = Vec::with_capacity(shard_blobs.len());
        let mut servers = Vec::with_capacity(shard_blobs.len());
        for (w, blob) in shard_blobs.into_iter().enumerate() {
            let (tx, rx) = mpmc::bounded::<ShmRequest>(REQUEST_DEPTH);
            reqs.push(tx);
            servers.push(spawn_worker(&format!("shm-server-{w}"), move || serve(blob, rx)));
        }
        ShmRings {
            fabric,
            reqs,
            servers,
            measured: Mutex::new(MeasuredState::default()),
            started: Stopwatch::start(),
        }
    }

    /// The fabric all pricing delegates to.
    pub fn fabric(&self) -> &NetFabric {
        &self.fabric
    }

    /// Wall-clock seconds since this backend was constructed.
    pub fn run_wall_sec(&self) -> f64 {
        self.started.elapsed_sec()
    }

    /// Measured per-link totals, sorted by (src, dst).
    pub fn measured_links(&self) -> Vec<((WorkerId, WorkerId), MeasuredLink)> {
        self.measured.lock().unwrap().links.iter().map(|(&k, &v)| (k, v)).collect()
    }

    /// Measured per-epoch totals, sorted by epoch.
    pub fn measured_epochs(&self) -> Vec<(u32, MeasuredEpoch)> {
        self.measured.lock().unwrap().epochs.iter().map(|(&k, &v)| (k, v)).collect()
    }

    /// Move `spec.payload_bytes` of the owner shard's bytes through the
    /// rings; returns (bytes received, wall seconds).
    fn transfer(&self, spec: ChargeSpec) -> (u64, f64) {
        let sw = Stopwatch::start();
        let owner = spec.dst as usize % self.reqs.len();
        let (tx, rx) = mpmc::bounded::<Vec<u8>>(REPLY_DEPTH);
        self.reqs[owner]
            .send(ShmRequest { payload_bytes: spec.payload_bytes, reply: tx })
            .expect("shm server hung up while the backend is alive");
        let mut got = 0u64;
        while let Ok(chunk) = rx.recv() {
            got += chunk.len() as u64;
        }
        (got, sw.elapsed_sec())
    }
}

impl Transport for ShmRings {
    fn charge(&self, spec: ChargeSpec) -> Charge {
        let (bytes, wall) = self.transfer(spec);
        debug_assert_eq!(bytes, spec.payload_bytes, "server must serve the exact payload");
        {
            let mut m = self.measured.lock().unwrap();
            let l = m.links.entry((spec.src, spec.dst)).or_default();
            l.payload_bytes += bytes;
            l.wall_sec += wall;
            l.rpcs += 1;
            let e = m.epochs.entry(spec.epoch).or_default();
            e.payload_bytes += bytes;
            e.wall_sec += wall;
            e.rpcs += 1;
        }
        // The measurement above is observational only: the charge returned —
        // and every counter mutated — comes from the same analytic fabric,
        // so modeled quantities are conformant with the trace by
        // construction.
        self.fabric.charge(spec)
    }

    fn backend_id(&self) -> &'static str {
        "shm-rings"
    }
}

impl Drop for ShmRings {
    fn drop(&mut self) {
        // Close the request rings so every server's recv() disconnects,
        // then reap the threads.
        self.reqs.clear();
        for h in self.servers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Shard server loop: serve each request's `payload_bytes` from the blob in
/// [`CHUNK_BYTES`] chunks (wrapping cyclically — a pull may ask for more
/// bytes than one shard holds when the fabric prices envelope-free payloads
/// across epochs), then drop the reply sender to end the stream.
fn serve(blob: Vec<u8>, rx: mpmc::Receiver<ShmRequest>) {
    let blob = if blob.is_empty() { vec![0u8; 4096] } else { blob };
    while let Ok(req) = rx.recv() {
        let mut remaining = req.payload_bytes as usize;
        let mut pos = 0usize;
        while remaining > 0 {
            let n = remaining.min(CHUNK_BYTES).min(blob.len() - pos);
            let chunk = blob[pos..pos + n].to_vec();
            if req.reply.send(chunk).is_err() {
                break; // requester hung up; abandon the transfer
            }
            pos = (pos + n) % blob.len();
            remaining -= n;
        }
        // req.reply drops here, disconnecting the requester's recv loop.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FabricConfig;

    fn fabric() -> NetFabric {
        NetFabric::new(FabricConfig::default()).with_world_size(4)
    }

    fn blobs(n: usize, bytes: usize) -> Vec<Vec<u8>> {
        (0..n).map(|w| vec![w as u8; bytes]).collect()
    }

    #[test]
    fn analytic_charge_matches_fabric_directly() {
        let f = fabric();
        let t = Analytic::new(f.clone());
        let spec = ChargeSpec::rows(0, 1, 100, 400).at(0);
        let via_transport = t.charge(spec);
        let direct = fabric().charge(spec);
        assert_eq!(via_transport, direct);
        assert_eq!(t.backend_id(), "analytic");
        // and the charge landed on the shared fabric's counters
        assert_eq!(f.total_rpcs(), 1);
    }

    #[test]
    fn charge_many_skips_zero_row_specs() {
        let t = Analytic::new(fabric());
        let specs = [
            ChargeSpec::rows(0, 1, 10, 400),
            ChargeSpec::rows(0, 2, 0, 400),
            ChargeSpec::rows(0, 3, 7, 400),
        ];
        let c = t.charge_many(&specs);
        assert_eq!(c.bytes, (10 * 400 + 64) + (7 * 400 + 64));
        assert_eq!(t.fabric().total_rpcs(), 2, "zero-row spec never reaches the fabric");
    }

    #[test]
    fn shm_moves_exactly_the_payload_bytes() {
        let shm = ShmRings::new(fabric(), blobs(2, 1000));
        let c = shm.charge(ChargeSpec::payload(0, 1, 25, 100_000).at(3));
        assert_eq!(c.bytes, 100_000 + 64, "pricing still includes the envelope");
        let links = shm.measured_links();
        assert_eq!(links.len(), 1);
        let ((s, d), l) = links[0];
        assert_eq!((s, d), (0, 1));
        assert_eq!(l.payload_bytes, 100_000, "payload (not envelope) actually moved");
        assert_eq!(l.rpcs, 1);
        assert!(l.wall_sec >= 0.0);
        let epochs = shm.measured_epochs();
        assert_eq!(epochs.len(), 1);
        assert_eq!(epochs[0].0, 3);
        assert_eq!(epochs[0].1.payload_bytes, 100_000);
        assert!(shm.run_wall_sec() >= 0.0);
    }

    #[test]
    fn shm_pricing_is_bit_identical_to_analytic() {
        // Same fabric config, same spec sequence: the real backend's charges
        // and counters must equal the analytic backend's exactly (the
        // conformance contract, at the unit level).
        let mut cfg = FabricConfig::default();
        cfg.loss_rate = 0.25;
        let fa = NetFabric::new(cfg.clone()).with_world_size(4);
        let fs = NetFabric::new(cfg).with_world_size(4);
        let analytic = Analytic::new(fa.clone());
        let shm = ShmRings::new(fs.clone(), blobs(4, 512));
        let specs: Vec<ChargeSpec> = (0..10u64)
            .map(|i| ChargeSpec::rows(0, 1 + (i % 3) as u32, 5 + i, 400).at((i % 2) as u32))
            .collect();
        for &s in &specs {
            assert_eq!(analytic.charge(s), shm.charge(s));
        }
        let many: Vec<ChargeSpec> =
            vec![ChargeSpec::rows(1, 2, 9, 400), ChargeSpec::rows(1, 3, 0, 400)];
        assert_eq!(analytic.charge_many(&many), shm.charge_many(&many));
        assert_eq!(fa.link_stats(), fs.link_stats());
        assert_eq!(fa.export_counters(), fs.export_counters());
    }

    #[test]
    fn shm_serves_empty_blobs_as_zeros() {
        let shm = ShmRings::new(fabric(), vec![Vec::new(), Vec::new()]);
        shm.charge(ChargeSpec::payload(0, 1, 3, 9000));
        assert_eq!(shm.measured_links()[0].1.payload_bytes, 9000);
    }

    #[test]
    fn shm_concurrent_charges_account_exactly() {
        // Worker threads hammer the backend concurrently (the wallclock
        // execution mode's shape); measured totals must come out exact.
        const THREADS: u64 = 4;
        const PER: u64 = 25;
        const PAYLOAD: u64 = 10_000;
        let shm = ShmRings::new(fabric(), blobs(4, 2048));
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let shm = &shm;
                s.spawn(move || {
                    for i in 0..PER {
                        let dst = 1 + ((t + i) % 3) as u32;
                        shm.charge(ChargeSpec::payload(t as u32, dst, 4, PAYLOAD).at(0));
                    }
                });
            }
        });
        let moved: u64 = shm.measured_links().iter().map(|(_, l)| l.payload_bytes).sum();
        let rpcs: u64 = shm.measured_links().iter().map(|(_, l)| l.rpcs).sum();
        assert_eq!(moved, THREADS * PER * PAYLOAD);
        assert_eq!(rpcs, THREADS * PER);
        assert_eq!(shm.fabric().total_rpcs(), THREADS * PER);
        let per_epoch: u64 = shm.measured_epochs().iter().map(|(_, e)| e.payload_bytes).sum();
        assert_eq!(per_epoch, moved, "epoch tallies cover every transfer");
    }

    #[test]
    fn shm_drop_reaps_servers() {
        // Dropping the backend must close the rings and join every server
        // (a hang here would wedge the whole test binary).
        let shm = ShmRings::new(fabric(), blobs(3, 64));
        shm.charge(ChargeSpec::payload(0, 1, 1, 128));
        drop(shm);
    }
}
