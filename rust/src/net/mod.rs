//! Simulated network fabric: the paper testbed's 10 GbE, as a cost model —
//! now with pluggable interconnect topologies.
//!
//! Every KV-store RPC is *charged* against a [`NetFabric`] which converts
//! (bytes, rows, rpc count) into simulated seconds using the linear model
//! `latency + bytes/bandwidth + rows·overhead`, where latency and bandwidth
//! are the *per-link* values derived from the configured
//! [`crate::config::Topology`] (flat switch, two-tier rack/spine, ring,
//! star/parameter-server — see [`crate::config::FabricConfig::link_model`]).
//! The paper's results are functions of exactly these quantities (remote rows
//! fetched, bytes moved, stall time on the critical path), so a charged model
//! reproduces the evaluation without a physical cluster (DESIGN.md §3).
//! Per-link counters feed Fig-4-style data-transfer reports.
//!
//! Failure injection is deterministic, so every run with the same config is
//! bit-reproducible:
//! - [`NetFabric::with_failures`] retries every global `n`-th RPC at double
//!   latency (the legacy whole-fabric knob);
//! - [`crate::config::FabricConfig::loss_rate`] promotes that to *per-link*
//!   cadence: every `round(1/loss_rate)`-th RPC **on each link** is retried.
//!
//! All counters live behind a single mutex ([`FabricState`]) so one lock
//! acquisition covers the retry decision and the link accounting — the old
//! split `links` / `rpc_counter` locks could interleave under concurrent
//! charges (counter ticks from two RPCs, then both account their links).

pub mod contention;
pub mod transport;

pub use contention::ContentionNet;
pub use transport::{Analytic, ChargeSpec, ShmRings, Transport};

use crate::config::{FabricConfig, LinkKey, LinkModel};
use crate::WorkerId;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::sync::Mutex;

/// One charged transfer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Charge {
    /// Simulated seconds this transfer takes.
    pub time: f64,
    /// Bytes on the wire.
    pub bytes: u64,
}

/// One RPC's claim on its route, recorded by the charge path when
/// [`FabricConfig::contention`] is on. The scalar [`Charge`] stays the
/// serialized linear estimate (counters are mode-invariant); the claim is
/// what the [`ContentionNet`] actually drains on the shared links — its
/// uncongested duration `fixed_sec + service_bytes / bottleneck` equals the
/// linear price on the switched topologies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlowSpec {
    /// Worker the payload leaves (a pull's *owner* side).
    pub src: WorkerId,
    /// Worker the payload lands on (the *requester*).
    pub dst: WorkerId,
    /// Wire bytes (what the counters record).
    pub bytes: u64,
    /// Fixed pre-transmission cost: route latency (doubled on an injected
    /// retry) plus per-row serialization, scaled by the endpoint slowdown.
    pub fixed_sec: f64,
    /// Service demand on the route in bytes (wire bytes × slowdown).
    pub service_bytes: f64,
    /// Global RPC sequence number — the deterministic tie-break.
    pub seq: u64,
}

/// Accumulated contention telemetry for one shared link.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LinkUtilization {
    /// Link capacity (bytes/second).
    pub capacity_bytes_per_sec: f64,
    /// Virtual seconds the link had at least one transfer in flight.
    pub busy_sec: f64,
    /// Bytes actually drained through the link.
    pub served_bytes: f64,
    /// Transfers that crossed the link.
    pub flows: u64,
    /// Peak concurrent in-flight transfers (queue depth).
    pub peak_flows: u32,
    /// Peak backlog: max total bytes queued on the link at any instant.
    pub peak_backlog_bytes: f64,
}

impl LinkUtilization {
    /// Merge another window of telemetry for the same link.
    pub fn merge(&mut self, o: &LinkUtilization) {
        self.capacity_bytes_per_sec = o.capacity_bytes_per_sec;
        self.busy_sec += o.busy_sec;
        self.served_bytes += o.served_bytes;
        self.flows += o.flows;
        self.peak_flows = self.peak_flows.max(o.peak_flows);
        self.peak_backlog_bytes = self.peak_backlog_bytes.max(o.peak_backlog_bytes);
    }
}

/// Per-link accounting entry.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LinkStats {
    pub rpcs: u64,
    pub bytes: u64,
    pub time: f64,
    /// RPCs that timed out and were retried (2× latency charged).
    pub retries: u64,
}

/// All mutable fabric state under one lock: the retry decision for an RPC and
/// its link accounting commit atomically.
#[derive(Debug, Default)]
struct FabricState {
    // BTreeMap (not a hash map): snapshots iterate these directly into
    // telemetry, and ordered iteration makes that deterministic by
    // construction rather than by sort-at-boundary discipline.
    links: BTreeMap<(WorkerId, WorkerId), LinkStats>,
    rpc_counter: u64,
    /// Route claims recorded since the last [`NetFabric::take_route_claims`]
    /// (only populated when `cfg.contention` is on).
    claims: Vec<FlowSpec>,
    /// Per-physical-link contention telemetry committed by [`ContentionNet`].
    util: BTreeMap<LinkKey, LinkUtilization>,
    /// Memoized per-pair link models: the multi-hop presets derive theirs
    /// from the full route, which would otherwise be rebuilt per RPC on the
    /// charge hot path. Valid for the fabric's lifetime (config-immutable),
    /// so `reset` keeps it.
    link_models: BTreeMap<(WorkerId, WorkerId), LinkModel>,
}

/// Shared simulated fabric. Cloneable handle; counters are global.
#[derive(Debug, Clone)]
pub struct NetFabric {
    cfg: FabricConfig,
    /// Worker count, used by topologies whose link costs depend on it
    /// (ring hop distance). 0 = unknown (degraded ring distances).
    world: u32,
    /// Optional failure injection: every global Nth RPC on any link "times
    /// out" and is retried once at double latency (tests the miss-handling
    /// paths). Per-link cadence comes from `cfg.loss_rate`.
    fail_every: Option<u64>,
    state: Arc<Mutex<FabricState>>,
}

impl NetFabric {
    /// New fabric with the given parameters.
    pub fn new(cfg: FabricConfig) -> Self {
        NetFabric {
            cfg,
            world: 0,
            fail_every: None,
            state: Arc::new(Mutex::new(FabricState::default())),
        }
    }

    /// Set the worker count (ring topologies need it for wrapped hop
    /// distances; harmless otherwise).
    pub fn with_world_size(mut self, world: u32) -> Self {
        self.world = world;
        self
    }

    /// Enable failure injection: every `n`-th RPC is retried at 2× latency.
    pub fn with_failures(mut self, n: u64) -> Self {
        assert!(n > 0);
        self.fail_every = Some(n);
        self
    }

    /// Fabric parameters.
    pub fn config(&self) -> &FabricConfig {
        &self.cfg
    }

    /// Configured worker count (0 = unknown).
    pub fn world_size(&self) -> u32 {
        self.world
    }

    /// Charge one transfer described by a [`ChargeSpec`] — the single real
    /// pricing entry point (the deprecated `charge_*` ladder and both
    /// [`transport::Transport`] backends all funnel through here).
    /// `spec.payload_bytes` is the wire payload (compressed rows + codec
    /// block headers on the codec path, `rows × row_bytes` otherwise),
    /// decoupled from the row count, which still prices the per-row
    /// serialization overhead; `spec.epoch` resolves transient speed phases
    /// ([`FabricConfig::worker_speed_phases`]) against the requester's
    /// current epoch.
    pub fn charge(&self, spec: ChargeSpec) -> Charge {
        let ChargeSpec { src, dst, rows, payload_bytes, epoch } = spec;
        let bytes = payload_bytes + 64; // 64B RPC envelope
        let mut st = self.state.lock().unwrap();
        let link = match st.link_models.get(&(src, dst)) {
            Some(&m) => m,
            None => {
                let m = self.cfg.link_model(src, dst, self.world);
                st.link_models.insert((src, dst), m);
                m
            }
        };
        // Same expression as `FabricConfig::rpc_time_on_link`, computed from
        // the memoized link model — that helper would re-derive it, which on
        // the multi-hop presets rebuilds the whole route per call.
        let mut time = link.latency_sec
            + bytes as f64 / link.bandwidth_bytes_per_sec
            + rows as f64 * self.cfg.per_node_overhead_sec;

        st.rpc_counter += 1;
        let seq = st.rpc_counter;
        let mut retried = match self.fail_every {
            Some(n) => st.rpc_counter % n == 0,
            None => false,
        };
        let e = st.links.entry((src, dst)).or_default();
        e.rpcs += 1;
        if let Some(per_link) = self.cfg.loss_every() {
            retried |= e.rpcs % per_link == 0;
        }
        if retried {
            // timeout + one retry: pay the (per-link) latency again
            time += link.latency_sec;
            e.retries += 1;
        }
        // Heterogeneous-speed injection: a link is as slow as its slowest
        // endpoint (worker_speed vector + straggler sugar + the transient
        // phase active at the requester's epoch, resolved by `slowdown_at`).
        // 1.0 for homogeneous clusters — no float op.
        let slow = self
            .cfg
            .slowdown_at(src, epoch)
            .max(self.cfg.slowdown_at(dst, epoch));
        if slow != 1.0 {
            time *= slow;
        }
        e.bytes += bytes;
        e.time += time;
        if self.cfg.contention {
            // Record the route claim the contention simulator will drain;
            // the scalar time above stays the serialized linear estimate.
            // The flow is oriented in the *data* direction: a pull's payload
            // leaves the owner (`dst` of the charge) and lands on the
            // requester, so incast on a hot owner queues on that owner's
            // egress NIC and a requester's fan-out shares its ingress. Route
            // costs are direction-symmetric, so only telemetry labels (and
            // any future asymmetric-capacity links) depend on this.
            let mut fixed = link.latency_sec * if retried { 2.0 } else { 1.0 }
                + rows as f64 * self.cfg.per_node_overhead_sec;
            let mut service = bytes as f64;
            if slow != 1.0 {
                fixed *= slow;
                service *= slow;
            }
            st.claims.push(FlowSpec {
                src: dst,
                dst: src,
                bytes,
                fixed_sec: fixed,
                service_bytes: service,
                seq,
            });
        }
        Charge { time, bytes }
    }

    /// Charge a vectorized pull that fans out to several owner shards at
    /// once: per-destination RPCs run in parallel, so the *critical-path*
    /// cost is the max over specs while counters record every link.
    /// Zero-row specs are skipped (an empty destination never reaches the
    /// wire).
    pub fn charge_many(&self, specs: &[ChargeSpec]) -> Charge {
        let mut max_time = 0f64;
        let mut total_bytes = 0u64;
        for &s in specs {
            if s.rows == 0 {
                continue;
            }
            let c = self.charge(s);
            max_time = max_time.max(c.time);
            total_bytes += c.bytes;
        }
        Charge { time: max_time, bytes: total_bytes }
    }

    /// Deprecated shim over [`Self::charge`] (one-PR migration window).
    #[deprecated(note = "build a ChargeSpec and call NetFabric::charge")]
    pub fn charge_rpc(&self, src: WorkerId, dst: WorkerId, rows: u64, row_bytes: u64) -> Charge {
        self.charge(ChargeSpec::rows(src, dst, rows, row_bytes))
    }

    /// Deprecated shim over [`Self::charge`] (one-PR migration window).
    #[deprecated(note = "build a ChargeSpec with .at(epoch) and call NetFabric::charge")]
    pub fn charge_rpc_at(
        &self,
        src: WorkerId,
        dst: WorkerId,
        rows: u64,
        row_bytes: u64,
        epoch: u32,
    ) -> Charge {
        self.charge(ChargeSpec::rows(src, dst, rows, row_bytes).at(epoch))
    }

    /// Deprecated shim over [`Self::charge`] (one-PR migration window).
    #[deprecated(note = "build a ChargeSpec::payload and call NetFabric::charge")]
    pub fn charge_rpc_payload_at(
        &self,
        src: WorkerId,
        dst: WorkerId,
        rows: u64,
        payload_bytes: u64,
        epoch: u32,
    ) -> Charge {
        self.charge(ChargeSpec::payload(src, dst, rows, payload_bytes).at(epoch))
    }

    /// Deprecated shim over [`Self::charge_many`] (one-PR migration window).
    #[deprecated(note = "build ChargeSpecs and call NetFabric::charge_many")]
    pub fn charge_fanout(
        &self,
        src: WorkerId,
        per_dst_rows: &[(WorkerId, u64)],
        row_bytes: u64,
    ) -> Charge {
        let specs: Vec<ChargeSpec> = per_dst_rows
            .iter()
            .map(|&(dst, rows)| ChargeSpec::rows(src, dst, rows, row_bytes))
            .collect();
        self.charge_many(&specs)
    }

    /// Deprecated shim over [`Self::charge_many`] (one-PR migration window).
    #[deprecated(note = "build ChargeSpecs with .at(epoch) and call NetFabric::charge_many")]
    pub fn charge_fanout_at(
        &self,
        src: WorkerId,
        per_dst_rows: &[(WorkerId, u64)],
        row_bytes: u64,
        epoch: u32,
    ) -> Charge {
        let specs: Vec<ChargeSpec> = per_dst_rows
            .iter()
            .map(|&(dst, rows)| ChargeSpec::rows(src, dst, rows, row_bytes).at(epoch))
            .collect();
        self.charge_many(&specs)
    }

    /// Deprecated shim over [`Self::charge_many`] (one-PR migration window).
    #[deprecated(note = "build ChargeSpec::payload specs and call NetFabric::charge_many")]
    pub fn charge_fanout_payload_at(
        &self,
        src: WorkerId,
        per_dst: &[(WorkerId, u64, u64)],
        epoch: u32,
    ) -> Charge {
        let specs: Vec<ChargeSpec> = per_dst
            .iter()
            .map(|&(dst, rows, payload)| ChargeSpec::payload(src, dst, rows, payload).at(epoch))
            .collect();
        self.charge_many(&specs)
    }

    /// Drain the route claims recorded since the last call (empty unless
    /// `cfg.contention` is on). The cluster runtime drains after every
    /// staging call so each stage's flows are attributed to it; offline
    /// phases (setup, background cache builds) drain-and-discard, keeping
    /// their linear pricing.
    pub fn take_route_claims(&self) -> Vec<FlowSpec> {
        std::mem::take(&mut self.state.lock().unwrap().claims)
    }

    /// Merge per-link contention telemetry (called by [`ContentionNet`] when
    /// an epoch's simulation finishes; accumulates across epochs).
    pub fn record_link_utilization(&self, entries: Vec<(LinkKey, LinkUtilization)>) {
        let mut st = self.state.lock().unwrap();
        for (key, u) in entries {
            st.util.entry(key).or_default().merge(&u);
        }
    }

    /// Snapshot of per-physical-link contention telemetry, sorted by link
    /// key. Empty unless a contended simulation ran on this fabric.
    pub fn link_utilization(&self) -> Vec<(LinkKey, LinkUtilization)> {
        self.state
            .lock()
            .unwrap()
            .util
            .iter()
            .map(|(&k, &u)| (k, u))
            .collect()
    }

    /// Snapshot of per-link stats.
    pub fn link_stats(&self) -> Vec<((WorkerId, WorkerId), LinkStats)> {
        let mut v: Vec<_> = self
            .state
            .lock()
            .unwrap()
            .links
            .iter()
            .map(|(&k, &s)| (k, s))
            .collect();
        v.sort_by_key(|&(k, _)| k);
        v
    }

    /// Total bytes across all links.
    pub fn total_bytes(&self) -> u64 {
        self.state.lock().unwrap().links.values().map(|s| s.bytes).sum()
    }

    /// Total RPCs across all links.
    pub fn total_rpcs(&self) -> u64 {
        self.state.lock().unwrap().links.values().map(|s| s.rpcs).sum()
    }

    /// Total injected retries across all links.
    pub fn total_retries(&self) -> u64 {
        self.state.lock().unwrap().links.values().map(|s| s.retries).sum()
    }

    /// Reset all counters (between bench configurations).
    pub fn reset(&self) {
        let mut st = self.state.lock().unwrap();
        st.links.clear();
        st.rpc_counter = 0;
        st.claims.clear();
        st.util.clear();
    }

    /// Export the deterministic RPC counters for a checkpoint: the global
    /// RPC sequence number plus every per-link entry, sorted by link key.
    /// These drive the loss-retry cadence (`rpcs % loss_every`,
    /// `rpc_counter % fail_every`), so a resumed run must start from the
    /// exact counts the interrupted run had — a fresh fabric's zeros would
    /// shift every subsequent retry decision.
    pub fn export_counters(&self) -> (u64, Vec<((WorkerId, WorkerId), LinkStats)>) {
        let st = self.state.lock().unwrap();
        let mut links: Vec<_> = st.links.iter().map(|(&k, &s)| (k, s)).collect();
        links.sort_by_key(|&(k, _)| k);
        (st.rpc_counter, links)
    }

    /// Restore counters exported by [`Self::export_counters`] into this
    /// (fresh) fabric. Claims/utilization telemetry start empty, as they do
    /// at every epoch boundary.
    pub fn import_counters(&self, rpc_counter: u64, links: &[((WorkerId, WorkerId), LinkStats)]) {
        let mut st = self.state.lock().unwrap();
        st.rpc_counter = rpc_counter;
        st.links = links.iter().copied().collect();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Topology;

    fn fabric() -> NetFabric {
        NetFabric::new(FabricConfig::default())
    }

    #[test]
    fn counter_export_import_preserves_retry_cadence() {
        // An uninterrupted lossy fabric vs. one that is snapshotted after 5
        // RPCs and resumed on a fresh fabric: the remaining RPCs must see
        // the identical per-RPC retry decisions and costs.
        let mut cfg = FabricConfig::default();
        cfg.loss_rate = 0.25;
        let uninterrupted = NetFabric::new(cfg.clone());
        let mut full = Vec::new();
        for _ in 0..12 {
            full.push(uninterrupted.charge(ChargeSpec::rows(0, 1, 10, 400)));
        }
        let first = NetFabric::new(cfg.clone());
        for i in 0..5 {
            let c = first.charge(ChargeSpec::rows(0, 1, 10, 400));
            assert_eq!(c, full[i], "prefix rpc {i}");
        }
        let (rpc_counter, links) = first.export_counters();
        let resumed = NetFabric::new(cfg);
        resumed.import_counters(rpc_counter, &links);
        for (i, expect) in full.iter().enumerate().skip(5) {
            let c = resumed.charge(ChargeSpec::rows(0, 1, 10, 400));
            assert_eq!(&c, expect, "resumed rpc {i}");
        }
        assert_eq!(resumed.total_retries(), uninterrupted.total_retries());
        assert_eq!(resumed.export_counters(), uninterrupted.export_counters());
    }

    #[test]
    fn charge_scales_with_rows() {
        let f = fabric();
        let a = f.charge(ChargeSpec::rows(0, 1, 100, 400));
        let b = f.charge(ChargeSpec::rows(0, 1, 1000, 400));
        assert!(b.time > a.time);
        assert_eq!(b.bytes, 1000 * 400 + 64);
    }

    #[test]
    fn latency_floor_applies() {
        let f = fabric();
        let c = f.charge(ChargeSpec::rows(0, 1, 0, 400));
        assert!(c.time >= f.config().rpc_latency_sec);
    }

    #[test]
    fn fanout_critical_path_is_max_not_sum() {
        let f = fabric();
        let big = f.charge(ChargeSpec::rows(0, 1, 10_000, 400)).time;
        f.reset();
        let c = f.charge_many(&[
            ChargeSpec::rows(0, 1, 10_000, 400),
            ChargeSpec::rows(0, 2, 10_000, 400),
            ChargeSpec::rows(0, 3, 10_000, 400),
        ]);
        assert!((c.time - big).abs() < 1e-12, "parallel fanout = max single");
        assert_eq!(c.bytes, 3 * (10_000 * 400 + 64));
        // but all three links were accounted
        assert_eq!(f.link_stats().len(), 3);
    }

    #[test]
    fn fanout_skips_empty_destinations() {
        let f = fabric();
        let c = f.charge_many(&[ChargeSpec::rows(0, 1, 0, 400), ChargeSpec::rows(0, 2, 5, 400)]);
        assert_eq!(f.link_stats().len(), 1);
        assert!(c.time > 0.0);
    }

    #[test]
    fn counters_accumulate_per_link() {
        let f = fabric();
        f.charge(ChargeSpec::rows(0, 1, 10, 4));
        f.charge(ChargeSpec::rows(0, 1, 10, 4));
        f.charge(ChargeSpec::rows(1, 0, 10, 4));
        let stats = f.link_stats();
        assert_eq!(stats.len(), 2);
        let l01 = stats.iter().find(|&&(k, _)| k == (0, 1)).unwrap().1;
        assert_eq!(l01.rpcs, 2);
    }

    #[test]
    fn failure_injection_adds_latency() {
        let clean = fabric();
        let faulty = NetFabric::new(FabricConfig::default()).with_failures(1);
        let a = clean.charge(ChargeSpec::rows(0, 1, 10, 4));
        let b = faulty.charge(ChargeSpec::rows(0, 1, 10, 4));
        assert!((b.time - a.time - FabricConfig::default().rpc_latency_sec).abs() < 1e-12);
    }

    #[test]
    fn retry_accounting_charges_exactly_one_extra_latency() {
        // Every 3rd RPC retried: time = n·base + floor(n/3)·latency, and the
        // rpc/bytes counters are unaffected by the retries.
        let lat = FabricConfig::default().rpc_latency_sec;
        let clean = fabric();
        let base = clean.charge(ChargeSpec::rows(0, 1, 10, 4)).time;
        let faulty = NetFabric::new(FabricConfig::default()).with_failures(3);
        let mut total = 0.0;
        for _ in 0..9 {
            total += faulty.charge(ChargeSpec::rows(0, 1, 10, 4)).time;
        }
        assert!((total - (9.0 * base + 3.0 * lat)).abs() < 1e-12, "{total}");
        let stats = faulty.link_stats();
        assert_eq!(stats.len(), 1);
        let l = stats[0].1;
        assert_eq!(l.rpcs, 9, "retries must not inflate the RPC count");
        assert_eq!(l.retries, 3);
        assert_eq!(l.bytes, 9 * (10 * 4 + 64), "retries must not inflate bytes");
        assert_eq!(faulty.total_retries(), 3);
        assert_eq!(faulty.total_rpcs(), 9);
    }

    #[test]
    fn per_link_loss_rate_is_counted_per_link_not_globally() {
        // loss_rate 0.5 → every 2nd RPC *per link* retried. Alternating
        // between two links, a global cadence would retry every other RPC on
        // the same link; per-link cadence retries the 2nd and 4th on each.
        let mut cfg = FabricConfig::default();
        cfg.loss_rate = 0.5;
        let f = NetFabric::new(cfg);
        for _ in 0..4 {
            f.charge(ChargeSpec::rows(0, 1, 10, 4));
            f.charge(ChargeSpec::rows(0, 2, 10, 4));
        }
        for (link, s) in f.link_stats() {
            assert_eq!(s.rpcs, 4, "{link:?}");
            assert_eq!(s.retries, 2, "{link:?}: 2nd and 4th RPC retried");
        }
        assert_eq!(f.total_retries(), 4);
    }

    #[test]
    fn loss_rate_charges_double_latency_on_retry_cadence() {
        let lat = FabricConfig::default().rpc_latency_sec;
        let clean = fabric();
        let base = clean.charge(ChargeSpec::rows(0, 1, 10, 4)).time;
        let mut cfg = FabricConfig::default();
        cfg.loss_rate = 0.25; // every 4th RPC on the link
        let f = NetFabric::new(cfg);
        let times: Vec<f64> = (0..4).map(|_| f.charge(ChargeSpec::rows(0, 1, 10, 4)).time).collect();
        for t in &times[..3] {
            assert!((t - base).abs() < 1e-12);
        }
        assert!((times[3] - base - lat).abs() < 1e-12, "4th pays the retry");
    }

    #[test]
    fn topology_changes_per_link_charges() {
        let mut cfg = FabricConfig::default();
        cfg.topology = Topology::TwoTier { racks: 2, oversubscription: 8.0 };
        let f = NetFabric::new(cfg).with_world_size(4);
        let intra = f.charge(ChargeSpec::rows(0, 2, 1000, 400)); // same rack (0%2 == 2%2)
        let inter = f.charge(ChargeSpec::rows(0, 1, 1000, 400)); // cross-rack
        assert!(inter.time > intra.time);
        assert_eq!(inter.bytes, intra.bytes, "topology changes time, not bytes");
    }

    #[test]
    fn straggler_slows_only_its_links() {
        let mut cfg = FabricConfig::default();
        cfg.straggler_worker = 1;
        cfg.straggler_factor = 4.0;
        let f = NetFabric::new(cfg).with_world_size(4);
        let clean = fabric();
        let base = clean.charge(ChargeSpec::rows(0, 2, 1000, 400)).time;
        let untouched = f.charge(ChargeSpec::rows(0, 2, 1000, 400)).time;
        let slow_dst = f.charge(ChargeSpec::rows(0, 1, 1000, 400)).time;
        let slow_src = f.charge(ChargeSpec::rows(1, 2, 1000, 400)).time;
        assert!((untouched - base).abs() < 1e-12);
        assert!((slow_dst - 4.0 * base).abs() < 1e-12);
        assert!((slow_src - 4.0 * base).abs() < 1e-12);
    }

    #[test]
    fn worker_speed_vector_slows_matching_links() {
        // The generalized straggler: every link touching a slowed worker is
        // scaled by that worker's factor; two slowed endpoints pay the max.
        let mut cfg = FabricConfig::default();
        cfg.worker_speed = vec![1.0, 2.0, 4.0];
        let f = NetFabric::new(cfg).with_world_size(4);
        let base = fabric().charge(ChargeSpec::rows(0, 3, 1000, 400)).time;
        assert!((f.charge(ChargeSpec::rows(0, 3, 1000, 400)).time - base).abs() < 1e-12);
        assert!((f.charge(ChargeSpec::rows(0, 1, 1000, 400)).time - 2.0 * base).abs() < 1e-12);
        assert!(
            (f.charge(ChargeSpec::rows(1, 2, 1000, 400)).time - 4.0 * base).abs() < 1e-12,
            "max endpoint wins"
        );
    }

    #[test]
    fn concurrent_charges_keep_counters_consistent() {
        // The merged-lock regression test: many threads hammer the same
        // fabric; rpc/bytes/retry totals must come out exact (the old split
        // rpc_counter/links locks could skew the retry cadence vs the link
        // counts under interleaving).
        const THREADS: u64 = 8;
        const PER: u64 = 500;
        let mut cfg = FabricConfig::default();
        cfg.loss_rate = 0.2; // every 5th per link
        let f = NetFabric::new(cfg).with_failures(7);
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let f = f.clone();
                s.spawn(move || {
                    for i in 0..PER {
                        // spread over a few links, deterministically per thread
                        let dst = 1 + ((t + i) % 3) as u32;
                        f.charge(ChargeSpec::rows(0, dst, 10, 4));
                    }
                });
            }
        });
        let total = THREADS * PER;
        assert_eq!(f.total_rpcs(), total);
        assert_eq!(f.total_bytes(), total * (10 * 4 + 64));
        // per-link loss retries: exactly floor(link_rpcs/5) on each link,
        // plus global every-7th retries — both derived from counters that
        // now commit atomically with the accounting.
        let per_link_expected: u64 = f.link_stats().iter().map(|(_, s)| s.rpcs / 5).sum();
        let global_expected = total / 7;
        let got = f.total_retries();
        // A single RPC can trip both cadences at once (counted once), so the
        // total lies between max(..) and the sum.
        assert!(
            got >= per_link_expected.max(global_expected)
                && got <= per_link_expected + global_expected,
            "retries {got} outside [{}, {}]",
            per_link_expected.max(global_expected),
            per_link_expected + global_expected
        );
    }

    #[test]
    fn payload_charge_with_full_payload_is_bit_identical() {
        // The row-granular entry point delegates to the payload one, so
        // charging rows×row_bytes explicitly must produce the same charge,
        // counters, and claims — the codec-off degeneration pin at the
        // fabric level.
        let mut cfg = FabricConfig::default();
        cfg.contention = true;
        cfg.loss_rate = 0.5;
        let a = NetFabric::new(cfg.clone()).with_world_size(4);
        let b = NetFabric::new(cfg).with_world_size(4);
        for i in 0..6u64 {
            let ca = a.charge(ChargeSpec::rows(0, 1, 10 + i, 400).at(0));
            let cb = b.charge(ChargeSpec::payload(0, 1, 10 + i, (10 + i) * 400).at(0));
            assert_eq!(ca, cb);
        }
        assert_eq!(a.link_stats(), b.link_stats());
        assert_eq!(a.take_route_claims(), b.take_route_claims());
    }

    #[test]
    fn payload_charge_prices_compressed_bytes_but_full_rows() {
        let f = fabric();
        let full = f.charge(ChargeSpec::payload(0, 1, 100, 100 * 400));
        let compressed = f.charge(ChargeSpec::payload(0, 1, 100, 100 * 108));
        assert_eq!(full.bytes, 100 * 400 + 64);
        assert_eq!(compressed.bytes, 100 * 108 + 64);
        // Same rows → same latency + per-row overhead; only the wire term
        // shrinks.
        let bw = f.config().bandwidth_bytes_per_sec;
        let expect = (full.bytes - compressed.bytes) as f64 / bw;
        assert!((full.time - compressed.time - expect).abs() < 1e-15);
    }

    #[test]
    fn fanout_payload_matches_per_rpc_payload_charges() {
        let f = fabric();
        let c = f.charge_many(&[
            ChargeSpec::payload(0, 1, 10, 1080),
            ChargeSpec::payload(0, 2, 0, 999),
            ChargeSpec::payload(0, 3, 7, 756),
        ]);
        assert_eq!(c.bytes, (1080 + 64) + (756 + 64), "zero-row dst skipped");
        assert_eq!(f.link_stats().len(), 2);
        let single = fabric().charge(ChargeSpec::payload(0, 1, 10, 1080));
        assert!((c.time - single.time).abs() < 1e-15, "max over dsts");
    }

    #[test]
    fn reset_clears() {
        let f = fabric();
        f.charge(ChargeSpec::rows(0, 1, 10, 4));
        assert!(f.total_bytes() > 0);
        f.reset();
        assert_eq!(f.total_bytes(), 0);
        assert_eq!(f.total_rpcs(), 0);
        assert_eq!(f.total_retries(), 0);
    }

    #[test]
    fn route_claims_recorded_only_in_contention_mode() {
        let off = fabric();
        off.charge(ChargeSpec::rows(0, 1, 10, 4));
        assert!(off.take_route_claims().is_empty(), "linear mode records no claims");

        let mut cfg = FabricConfig::default();
        cfg.contention = true;
        let on = NetFabric::new(cfg.clone()).with_world_size(4);
        let c = on.charge(ChargeSpec::rows(0, 1, 100, 4));
        on.charge_many(&[
            ChargeSpec::rows(0, 1, 5, 4),
            ChargeSpec::rows(0, 2, 0, 4),
            ChargeSpec::rows(0, 3, 7, 4),
        ]);
        let claims = on.take_route_claims();
        assert_eq!(claims.len(), 3, "one claim per non-empty RPC");
        assert_eq!(claims[0].bytes, c.bytes);
        assert_eq!(claims[0].service_bytes, c.bytes as f64);
        // flows are oriented in the data direction: the pull charge (0→1)
        // moves payload owner 1 → requester 0
        assert_eq!((claims[0].src, claims[0].dst), (1, 0));
        // uncongested flow duration equals the linear charge
        let dur = claims[0].fixed_sec
            + claims[0].service_bytes / cfg.link_model(0, 1, 4).bandwidth_bytes_per_sec;
        assert!((dur - c.time).abs() < 1e-15, "{dur} vs {c:?}");
        // seq strictly increases in charge order
        assert!(claims.windows(2).all(|w| w[0].seq < w[1].seq));
        // drained: a second take is empty
        assert!(on.take_route_claims().is_empty());
    }

    #[test]
    fn claims_scale_with_endpoint_slowdowns_and_retries() {
        let mut cfg = FabricConfig::default();
        cfg.contention = true;
        cfg.worker_speed = vec![1.0, 3.0];
        let f = NetFabric::new(cfg).with_failures(1); // every RPC retried
        let c = f.charge(ChargeSpec::rows(0, 1, 100, 4));
        let claim = f.take_route_claims().pop().unwrap();
        let lat = FabricConfig::default().rpc_latency_sec;
        let ovh = 100.0 * FabricConfig::default().per_node_overhead_sec;
        assert!((claim.fixed_sec - 3.0 * (2.0 * lat + ovh)).abs() < 1e-15);
        assert_eq!(claim.service_bytes, 3.0 * c.bytes as f64);
    }

    #[test]
    fn phase_epochs_resolve_on_the_charge_path() {
        // A phase switching at epoch 2 scales charges only from that epoch
        // on, and reproduces the static worker_speed semantics (max over
        // endpoints) within it.
        let mut cfg = FabricConfig::default();
        cfg.worker_speed_phases = vec![crate::config::SpeedPhase {
            from_epoch: 2,
            speeds: vec![1.0, 4.0],
        }];
        let f = NetFabric::new(cfg).with_world_size(4);
        let base = fabric().charge(ChargeSpec::rows(0, 1, 1000, 400)).time;
        assert!((f.charge(ChargeSpec::rows(0, 1, 1000, 400).at(0)).time - base).abs() < 1e-15);
        assert!((f.charge(ChargeSpec::rows(0, 1, 1000, 400).at(2)).time - 4.0 * base).abs() < 1e-12);
        assert!((f.charge(ChargeSpec::rows(1, 2, 1000, 400).at(3)).time - 4.0 * base).abs() < 1e-12);
        assert!((f.charge(ChargeSpec::rows(2, 3, 1000, 400).at(2)).time - base).abs() < 1e-15);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_charge_ladder_shims_delegate_to_charge_spec() {
        // One-PR migration window: every retired ladder entry point must be
        // a pure delegation — identical charge *and* identical counters, so
        // un-migrated external callers see bit-stable behavior.
        let mut cfg = FabricConfig::default();
        cfg.loss_rate = 0.5; // exercise the per-link retry cadence through both paths
        let old = NetFabric::new(cfg.clone()).with_world_size(4);
        let new = NetFabric::new(cfg).with_world_size(4);
        assert_eq!(old.charge_rpc(0, 1, 10, 400), new.charge(ChargeSpec::rows(0, 1, 10, 400)));
        assert_eq!(
            old.charge_rpc_at(0, 1, 10, 400, 3),
            new.charge(ChargeSpec::rows(0, 1, 10, 400).at(3))
        );
        assert_eq!(
            old.charge_rpc_payload_at(0, 1, 10, 1080, 3),
            new.charge(ChargeSpec::payload(0, 1, 10, 1080).at(3))
        );
        assert_eq!(
            old.charge_fanout(0, &[(1, 5), (2, 7)], 400),
            new.charge_many(&[ChargeSpec::rows(0, 1, 5, 400), ChargeSpec::rows(0, 2, 7, 400)])
        );
        assert_eq!(
            old.charge_fanout_at(0, &[(1, 5), (2, 7)], 400, 2),
            new.charge_many(&[
                ChargeSpec::rows(0, 1, 5, 400).at(2),
                ChargeSpec::rows(0, 2, 7, 400).at(2),
            ])
        );
        assert_eq!(
            old.charge_fanout_payload_at(0, &[(1, 5, 540), (2, 7, 756)], 2),
            new.charge_many(&[
                ChargeSpec::payload(0, 1, 5, 540).at(2),
                ChargeSpec::payload(0, 2, 7, 756).at(2),
            ])
        );
        assert_eq!(old.export_counters(), new.export_counters());
    }
}
