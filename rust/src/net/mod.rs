//! Simulated network fabric: the paper testbed's 10 GbE, as a cost model.
//!
//! Every KV-store RPC is *charged* against a [`NetFabric`] which converts
//! (bytes, rows, rpc count) into simulated seconds using the linear model
//! `latency + bytes/bandwidth + rows·overhead`. The paper's results are
//! functions of exactly these quantities (remote rows fetched, bytes moved,
//! stall time on the critical path), so a charged model reproduces the
//! evaluation without a physical cluster (DESIGN.md §3). Per-link counters
//! feed Fig-4-style data-transfer reports.

use crate::config::FabricConfig;
use crate::WorkerId;
use std::sync::Mutex;
use std::sync::Arc;

/// One charged transfer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Charge {
    /// Simulated seconds this transfer takes.
    pub time: f64,
    /// Bytes on the wire.
    pub bytes: u64,
}

/// Per-link accounting entry.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LinkStats {
    pub rpcs: u64,
    pub bytes: u64,
    pub time: f64,
}

/// Shared simulated fabric. Cloneable handle; counters are global.
#[derive(Debug, Clone)]
pub struct NetFabric {
    cfg: FabricConfig,
    links: Arc<Mutex<std::collections::HashMap<(WorkerId, WorkerId), LinkStats>>>,
    /// Optional failure injection: every Nth RPC on any link "times out" and
    /// is retried once at double latency (tests the miss-handling paths).
    fail_every: Option<u64>,
    rpc_counter: Arc<Mutex<u64>>,
}

impl NetFabric {
    /// New fabric with the given parameters.
    pub fn new(cfg: FabricConfig) -> Self {
        NetFabric {
            cfg,
            links: Arc::new(Mutex::new(std::collections::HashMap::new())),
            fail_every: None,
            rpc_counter: Arc::new(Mutex::new(0)),
        }
    }

    /// Enable failure injection: every `n`-th RPC is retried at 2× latency.
    pub fn with_failures(mut self, n: u64) -> Self {
        assert!(n > 0);
        self.fail_every = Some(n);
        self
    }

    /// Fabric parameters.
    pub fn config(&self) -> &FabricConfig {
        &self.cfg
    }

    /// Charge one RPC from `src` to `dst` carrying `rows` feature rows of
    /// `row_bytes` each. Returns the simulated cost.
    pub fn charge_rpc(&self, src: WorkerId, dst: WorkerId, rows: u64, row_bytes: u64) -> Charge {
        let bytes = rows * row_bytes + 64; // 64B header
        let mut time = self.cfg.rpc_time(bytes, rows);
        if let Some(n) = self.fail_every {
            let mut c = self.rpc_counter.lock().unwrap();
            *c += 1;
            if *c % n == 0 {
                // timeout + one retry: pay the latency again
                time += self.cfg.rpc_latency_sec;
            }
        }
        let mut links = self.links.lock().unwrap();
        let e = links.entry((src, dst)).or_default();
        e.rpcs += 1;
        e.bytes += bytes;
        e.time += time;
        Charge { time, bytes }
    }

    /// Charge a vectorized pull that fans out to several owner shards at
    /// once: per-destination RPCs run in parallel, so the *critical-path*
    /// cost is the max over destinations while counters record every link.
    pub fn charge_fanout(
        &self,
        src: WorkerId,
        per_dst_rows: &[(WorkerId, u64)],
        row_bytes: u64,
    ) -> Charge {
        let mut max_time = 0f64;
        let mut total_bytes = 0u64;
        for &(dst, rows) in per_dst_rows {
            if rows == 0 {
                continue;
            }
            let c = self.charge_rpc(src, dst, rows, row_bytes);
            max_time = max_time.max(c.time);
            total_bytes += c.bytes;
        }
        Charge { time: max_time, bytes: total_bytes }
    }

    /// Snapshot of per-link stats.
    pub fn link_stats(&self) -> Vec<((WorkerId, WorkerId), LinkStats)> {
        let mut v: Vec<_> = self.links.lock().unwrap().iter().map(|(&k, &s)| (k, s)).collect();
        v.sort_by_key(|&(k, _)| k);
        v
    }

    /// Total bytes across all links.
    pub fn total_bytes(&self) -> u64 {
        self.links.lock().unwrap().values().map(|s| s.bytes).sum()
    }

    /// Reset all counters (between bench configurations).
    pub fn reset(&self) {
        self.links.lock().unwrap().clear();
        *self.rpc_counter.lock().unwrap() = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric() -> NetFabric {
        NetFabric::new(FabricConfig::default())
    }

    #[test]
    fn charge_scales_with_rows() {
        let f = fabric();
        let a = f.charge_rpc(0, 1, 100, 400);
        let b = f.charge_rpc(0, 1, 1000, 400);
        assert!(b.time > a.time);
        assert_eq!(b.bytes, 1000 * 400 + 64);
    }

    #[test]
    fn latency_floor_applies() {
        let f = fabric();
        let c = f.charge_rpc(0, 1, 0, 400);
        assert!(c.time >= f.config().rpc_latency_sec);
    }

    #[test]
    fn fanout_critical_path_is_max_not_sum() {
        let f = fabric();
        let big = f.charge_rpc(0, 1, 10_000, 400).time;
        f.reset();
        let c = f.charge_fanout(0, &[(1, 10_000), (2, 10_000), (3, 10_000)], 400);
        assert!((c.time - big).abs() < 1e-12, "parallel fanout = max single");
        assert_eq!(c.bytes, 3 * (10_000 * 400 + 64));
        // but all three links were accounted
        assert_eq!(f.link_stats().len(), 3);
    }

    #[test]
    fn fanout_skips_empty_destinations() {
        let f = fabric();
        let c = f.charge_fanout(0, &[(1, 0), (2, 5)], 400);
        assert_eq!(f.link_stats().len(), 1);
        assert!(c.time > 0.0);
    }

    #[test]
    fn counters_accumulate_per_link() {
        let f = fabric();
        f.charge_rpc(0, 1, 10, 4);
        f.charge_rpc(0, 1, 10, 4);
        f.charge_rpc(1, 0, 10, 4);
        let stats = f.link_stats();
        assert_eq!(stats.len(), 2);
        let l01 = stats.iter().find(|&&(k, _)| k == (0, 1)).unwrap().1;
        assert_eq!(l01.rpcs, 2);
    }

    #[test]
    fn failure_injection_adds_latency() {
        let clean = fabric();
        let faulty = NetFabric::new(FabricConfig::default()).with_failures(1);
        let a = clean.charge_rpc(0, 1, 10, 4);
        let b = faulty.charge_rpc(0, 1, 10, 4);
        assert!((b.time - a.time - FabricConfig::default().rpc_latency_sec).abs() < 1e-12);
    }

    #[test]
    fn reset_clears() {
        let f = fabric();
        f.charge_rpc(0, 1, 10, 4);
        assert!(f.total_bytes() > 0);
        f.reset();
        assert_eq!(f.total_bytes(), 0);
    }
}
