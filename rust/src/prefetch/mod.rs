//! Rolling asynchronous prefetcher (paper §3 "Rolling prefetch and
//! execution", §4 component 4/7).
//!
//! The prefetcher walks the precomputed schedule ahead of the trainer,
//! staging each batch's features — cache-first, with residual misses fetched
//! via `SyncPull` — into a bounded queue of depth `Q`. The queue is a
//! crossbeam MPMC channel (the paper's "lock-free MPMC rings"): the
//! prefetcher blocks when `Q` batches are staged and unconsumed ("stalls
//! only when the Trainer lags") and resumes as the trainer drains it.
//!
//! Staging logic is shared between the threaded runtime path and the inline
//! path used by trace-mode benches (`stage_batch`), so both produce
//! bit-identical results — a property the integration tests pin down.

use crate::cache::DoubleBufferCache;
use crate::kvstore::{KvStore, PullRequest};
use crate::metrics::CommStats;
use crate::sampler::BatchMeta;
use crate::{NodeId, WorkerId};
use crate::util::mpmc::{bounded, Receiver};
use std::sync::{Arc, Mutex};

/// Per-node cache/queue bookkeeping cost charged at staging time (hash
/// lookups, offset bookkeeping). Calibrated to a ~100 ns hash-map probe.
pub const LOOKUP_COST_SEC: f64 = 100e-9;

/// A batch with features staged and ready for the trainer.
#[derive(Debug, Clone, PartialEq)]
pub struct StagedBatch {
    pub meta: BatchMeta,
    /// `[num_input_nodes, d]` row-major features; `None` in trace mode.
    pub features: Option<Vec<f32>>,
    /// Simulated staging time: cache lookups + residual SyncPull.
    pub stage_time: f64,
    /// Network portion of `stage_time` (the residual SyncPull). The cluster
    /// runtime splits it out so straggler slowdowns scale only the *local*
    /// staging work — the network side is already charged per-link by the
    /// topology-aware fabric.
    pub pull_time: f64,
    /// Remote nodes served from the steady cache.
    pub cache_hits: u32,
    /// Remote nodes that missed the cache (fetched via SyncPull).
    pub misses: u32,
}

impl StagedBatch {
    /// Device bytes this staged batch occupies while queued.
    pub fn staged_bytes(&self, feature_dim: u32) -> u64 {
        self.meta.input_nodes.len() as u64 * feature_dim as u64 * 4
    }
}

/// Stage one batch: split its remote nodes into cache hits/misses, SyncPull
/// the misses, and (in full mode) assemble the `[n, d]` feature block in
/// input-node order from the three sources (local shard, cache, pull).
/// Epoch 0 for the transient-straggler phase axis; the simulation paths use
/// [`stage_batch_at`] with the live training epoch.
pub fn stage_batch(
    kv: &KvStore,
    cache: &Mutex<DoubleBufferCache>,
    meta: BatchMeta,
    worker: WorkerId,
    materialize: bool,
    stats: &mut CommStats,
) -> StagedBatch {
    stage_batch_at(kv, cache, meta, worker, materialize, stats, 0)
}

/// Epoch-aware [`stage_batch`]: the residual `SyncPull` is charged under the
/// transient speed phase active at `epoch`.
pub fn stage_batch_at(
    kv: &KvStore,
    cache: &Mutex<DoubleBufferCache>,
    meta: BatchMeta,
    worker: WorkerId,
    materialize: bool,
    stats: &mut CommStats,
    epoch: u32,
) -> StagedBatch {
    let mut hits: Vec<NodeId> = Vec::new();
    let mut misses: Vec<NodeId> = Vec::new();
    let remote: Vec<NodeId> = meta.remote_nodes().collect();
    {
        let mut c = cache.lock().unwrap();
        c.split_hits(&remote, &mut hits, &mut misses);
    }
    let mut pulled: Vec<f32> = Vec::new();
    let pull = kv.pull(
        PullRequest::sync(worker, &misses).at(epoch),
        if materialize && kv.has_values() {
            Some(&mut pulled)
        } else {
            None
        },
        stats,
    );
    let stage_time = pull.time + meta.input_nodes.len() as f64 * LOOKUP_COST_SEC;

    let features = if materialize && kv.has_values() {
        let d = kv.feature_dim();
        let mut block = vec![0f32; meta.input_nodes.len() * d];
        // Position of each miss within `pulled` (misses order == pull order).
        let miss_pos: crate::util::fasthash::IdHashMap<NodeId, usize> =
            misses.iter().enumerate().map(|(i, &v)| (v, i)).collect();
        let c = cache.lock().unwrap();
        for (j, &v) in meta.input_nodes.iter().enumerate() {
            let dst = &mut block[j * d..(j + 1) * d];
            if !meta.is_remote(j) {
                dst.copy_from_slice(kv.row(v));
            } else if let Some(row) = c.steady().row(v) {
                dst.copy_from_slice(row);
            } else if let Some(&i) = miss_pos.get(&v) {
                dst.copy_from_slice(&pulled[i * d..(i + 1) * d]);
            } else {
                // Cache buffer without materialized rows (trace cache in a
                // full run) cannot happen: engines materialize consistently.
                unreachable!("remote node {v} neither cached nor pulled");
            }
        }
        Some(block)
    } else {
        None
    };

    StagedBatch {
        meta,
        features,
        stage_time,
        pull_time: pull.time,
        cache_hits: hits.len() as u32,
        misses: misses.len() as u32,
    }
}

/// Handle to a running background prefetcher.
pub struct Prefetcher {
    rx: Option<Receiver<StagedBatch>>,
    handle: Option<std::thread::JoinHandle<CommStats>>,
}

impl Prefetcher {
    /// Spawn a prefetcher over a batch-metadata source (typically a
    /// streaming [`crate::storage::EpochReader`] iterator). Stages into a
    /// bounded queue of depth `q`.
    #[allow(clippy::disallowed_methods)] // the paper's background prefetcher is this one thread
    pub fn spawn(
        kv: Arc<KvStore>,
        cache: Arc<Mutex<DoubleBufferCache>>,
        source: Box<dyn Iterator<Item = BatchMeta> + Send>,
        q: u32,
        worker: WorkerId,
        materialize: bool,
    ) -> Self {
        let (tx, rx) = bounded::<StagedBatch>(q.max(1) as usize);
        // The rolling prefetcher (paper §3.3) is the one sanctioned long-lived
        // worker thread outside util; `Prefetcher::join` drains it
        // deterministically before any telemetry is read.
        // lint:allow(thread-spawn): the paper-mandated background prefetcher thread
        let handle = std::thread::Builder::new()
            .name(format!("prefetcher-w{worker}"))
            .spawn(move || {
                let mut stats = CommStats::default();
                for meta in source {
                    let staged = stage_batch(&kv, &cache, meta, worker, materialize, &mut stats);
                    // send blocks when Q batches are staged → backpressure
                    if tx.send(staged).is_err() {
                        break; // trainer hung up (early stop)
                    }
                }
                stats
            })
            .expect("spawn prefetcher");
        Prefetcher { rx: Some(rx), handle: Some(handle) }
    }

    /// Receive the next staged batch; `None` when the schedule is exhausted.
    pub fn recv(&self) -> Option<StagedBatch> {
        self.rx.as_ref().and_then(|rx| rx.recv().ok())
    }

    /// Non-blocking probe used by the trainer's race-fallback path.
    pub fn try_recv(&self) -> Option<StagedBatch> {
        self.rx.as_ref().and_then(|rx| rx.try_recv())
    }

    /// Join the background thread and collect its communication stats.
    pub fn join(mut self) -> CommStats {
        // Drop the receiver first so a blocked `send` unblocks if the trainer
        // stopped early.
        self.rx = None;
        self.handle
            .take()
            .expect("join called once")
            .join()
            .expect("prefetcher panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{top_hot, CacheBuffer};
    use crate::config::{DatasetConfig, DatasetPreset, FabricConfig};
    use crate::graph::build_dataset;
    use crate::net::NetFabric;
    use crate::partition::metis_like;
    use crate::sampler::{enumerate_epoch, EpochSchedule, Fanout};

    fn setup(materialized: bool) -> (Arc<KvStore>, Arc<Mutex<DoubleBufferCache>>, EpochSchedule) {
        let ds = build_dataset(&DatasetConfig::preset(DatasetPreset::Tiny, 1.0), materialized);
        let part = Arc::new(metis_like(&ds.graph, 2, 0));
        let shard: Vec<u32> = ds
            .train_nodes
            .iter()
            .copied()
            .filter(|&v| part.is_local(0, v))
            .collect();
        let sched = enumerate_epoch(
            &ds.graph,
            &part,
            &shard,
            &[Fanout::Sample(4), Fanout::Sample(3)],
            64,
            3,
            0,
            0,
        );
        let fabric = NetFabric::new(FabricConfig::default());
        let kv = Arc::new(KvStore::new(&ds, part, fabric));

        // steady cache over the epoch's hottest remote nodes
        let hot = top_hot(&sched.batches, 200);
        let mut stats = CommStats::default();
        let mut rows = Vec::new();
        kv.pull(
            PullRequest::vector(0, &hot),
            if materialized { Some(&mut rows) } else { None },
            &mut stats,
        );
        let mut cache = DoubleBufferCache::default();
        cache.install_steady(CacheBuffer::new(&hot, rows, kv.feature_dim()));
        (kv, Arc::new(Mutex::new(cache)), sched)
    }

    #[test]
    fn staging_counts_hits_plus_misses_equals_remote() {
        let (kv, cache, sched) = setup(false);
        let mut stats = CommStats::default();
        for meta in sched.batches.clone() {
            let remote = meta.num_remote;
            let s = stage_batch(&kv, &cache, meta, 0, false, &mut stats);
            assert_eq!(s.cache_hits + s.misses, remote);
        }
    }

    #[test]
    fn cached_nodes_reduce_pull_volume() {
        let (kv, cache, sched) = setup(false);
        // with cache
        let mut with_stats = CommStats::default();
        for meta in sched.batches.clone() {
            stage_batch(&kv, &cache, meta, 0, false, &mut with_stats);
        }
        // without cache (empty steady buffer)
        let empty = Arc::new(Mutex::new(DoubleBufferCache::default()));
        let mut without_stats = CommStats::default();
        for meta in sched.batches.clone() {
            stage_batch(&kv, &empty, meta, 0, false, &mut without_stats);
        }
        assert!(with_stats.remote_rows < without_stats.remote_rows);
        assert!(with_stats.bytes < without_stats.bytes);
    }

    #[test]
    fn materialized_features_are_correct() {
        let (kv, cache, sched) = setup(true);
        let ds = build_dataset(&DatasetConfig::preset(DatasetPreset::Tiny, 1.0), true);
        let mut stats = CommStats::default();
        let d = kv.feature_dim();
        for meta in sched.batches.iter().take(3).cloned() {
            let s = stage_batch(&kv, &cache, meta, 0, true, &mut stats);
            let block = s.features.unwrap();
            for (j, &v) in s.meta.input_nodes.iter().enumerate() {
                assert_eq!(
                    &block[j * d..(j + 1) * d],
                    ds.feature_row(v),
                    "node {v} at position {j}"
                );
            }
        }
    }

    #[test]
    fn threaded_prefetcher_matches_inline() {
        let (kv, cache, sched) = setup(false);
        // inline reference
        let inline_cache = Arc::new(Mutex::new(DoubleBufferCache::default()));

        let mut inline_stats = CommStats::default();
        let inline: Vec<StagedBatch> = sched
            .batches
            .iter()
            .cloned()
            .map(|m| stage_batch(&kv, &cache, m, 0, false, &mut inline_stats))
            .collect();
        // Reset cache stats so the threaded pass sees the same state.
        cache.lock().unwrap().reset_stats();
        drop(inline_cache);

        let pf = Prefetcher::spawn(
            kv.clone(),
            cache.clone(),
            Box::new(sched.batches.clone().into_iter()),
            4,
            0,
            false,
        );
        let mut threaded = Vec::new();
        while let Some(b) = pf.recv() {
            threaded.push(b);
        }
        let _stats = pf.join();
        assert_eq!(inline.len(), threaded.len());
        for (a, b) in inline.iter().zip(&threaded) {
            assert_eq!(a.meta, b.meta);
            assert_eq!(a.cache_hits, b.cache_hits);
            assert_eq!(a.misses, b.misses);
        }
    }

    #[test]
    fn early_drop_unblocks_prefetcher() {
        let (kv, cache, sched) = setup(false);
        let pf = Prefetcher::spawn(
            kv,
            cache,
            Box::new(sched.batches.into_iter()),
            1, // tiny queue → prefetcher will block on send
            0,
            false,
        );
        let _first = pf.recv().unwrap();
        // drop without draining — join must not deadlock
        let _stats = pf.join();
    }

    #[test]
    fn bounded_queue_applies_backpressure() {
        let (kv, cache, sched) = setup(false);
        let n = sched.batches.len();
        assert!(n > 3, "need a few batches");
        let pf = Prefetcher::spawn(
            kv,
            cache,
            Box::new(sched.batches.into_iter()),
            2,
            0,
            false,
        );
        // Give the prefetcher time; it can stage at most q + 1 in flight
        // (queue capacity 2 plus one blocked in `send`).
        std::thread::sleep(std::time::Duration::from_millis(100));
        let mut got = 0;
        while pf.try_recv().is_some() {
            got += 1;
        }
        assert!(got <= 3, "queue leaked past its bound: got {got}");
        let _ = pf.join();
    }
}
