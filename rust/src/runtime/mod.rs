//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas train step.
//!
//! The build-time Python pass (`python/compile/aot.py`) lowers the 2-layer
//! GraphSAGE train step — whose neighbor aggregation is a Pallas kernel — to
//! **HLO text** (xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id protos, so
//! text is the interchange format; see /opt/xla-example/README.md) plus a
//! `.meta.json` manifest describing the fixed shapes. This module discovers
//! a matching artifact, compiles it once on the PJRT CPU client, and exposes
//! it as a [`crate::trainer::TrainStep`] backend. Python never runs here.

mod artifact;
#[cfg(feature = "xla")]
mod pjrt;
#[cfg(not(feature = "xla"))]
#[path = "pjrt_stub.rs"]
mod pjrt;

pub use artifact::{find_artifact, ArtifactMeta};
pub use pjrt::PjrtTrainer;

use crate::coordinator::RunContext;
use crate::trainer::TrainStep;
use crate::Result;

/// Default artifacts directory (overridable with `RAPIDGNN_ARTIFACTS`).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("RAPIDGNN_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

/// Build the PJRT-backed trainer for a run context, discovering the artifact
/// that matches the run's model shape.
pub fn build_pjrt_trainer(ctx: &RunContext) -> Result<Box<dyn TrainStep>> {
    let meta = find_artifact(&artifacts_dir(), ctx)?;
    let trainer = PjrtTrainer::load(meta, ctx.cfg.base_seed)?;
    Ok(Box::new(trainer))
}
