//! Stub PJRT backend for builds without the `xla` bindings crate — the
//! default in this offline tree (see Cargo.toml's `xla` feature). Mirrors
//! the real `pjrt` module's public surface so callers compile unchanged;
//! every entry point reports the runtime as unavailable, and the callers
//! that probe for artifacts first (`find_artifact`) skip gracefully.

use super::artifact::ArtifactMeta;
use crate::sampler::khop::SampledBatch;
use crate::trainer::{sage::StepOutput, Mat, TrainStep};
use crate::Result;
use anyhow::bail;

/// Placeholder for the PJRT executor; constructing it always fails.
pub struct PjrtTrainer {
    meta: ArtifactMeta,
    /// Number of train steps executed (always 0 in the stub).
    pub steps_run: u64,
}

impl PjrtTrainer {
    /// Always fails: the crate was built without the `xla` feature.
    pub fn load(_meta: ArtifactMeta, _seed: u64) -> Result<PjrtTrainer> {
        bail!("PJRT runtime unavailable: built without the `xla` cargo feature")
    }

    /// Artifact manifest.
    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Parameter snapshot (unreachable: `load` never succeeds).
    pub fn params_flat(&self) -> Result<Vec<Vec<f32>>> {
        Ok(Vec::new())
    }
}

impl TrainStep for PjrtTrainer {
    fn step(&mut self, _x0: &Mat, _batch: &SampledBatch, _labels: &[u16], _lr: f32) -> StepOutput {
        unreachable!("stub PjrtTrainer cannot be constructed")
    }

    fn eval(&mut self, _x0: &Mat, _batch: &SampledBatch, _labels: &[u16]) -> StepOutput {
        unreachable!("stub PjrtTrainer cannot be constructed")
    }
}
