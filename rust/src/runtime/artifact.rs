//! Artifact discovery: match `.meta.json` manifests against a run's shape.

use crate::coordinator::RunContext;
use crate::util::value::Value;
use crate::Result;
use anyhow::{bail, Context};
use std::path::{Path, PathBuf};

/// Manifest of one AOT-compiled train-step artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    /// Path of the HLO text file.
    pub hlo_path: PathBuf,
    /// Feature dim `d`, hidden width `h`, class count `c`.
    pub d: u32,
    pub h: u32,
    pub c: u32,
    /// Per-layer fan-outs (innermost first, length 2).
    pub f1: u32,
    pub f2: u32,
    /// Padded capacities: seeds, layer-1 nodes, input nodes.
    pub b_cap: u32,
    pub n1_cap: u32,
    pub n0_cap: u32,
}

impl ArtifactMeta {
    /// Parse a `.meta.json` file (paths resolved relative to its directory).
    pub fn load(path: &Path) -> Result<ArtifactMeta> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        let v = Value::from_json(&text)?;
        let dir = path.parent().unwrap_or(Path::new("."));
        Ok(ArtifactMeta {
            hlo_path: dir.join(v.req_str("hlo")?),
            d: v.req_u32("d")?,
            h: v.req_u32("h")?,
            c: v.req_u32("c")?,
            f1: v.req_u32("f1")?,
            f2: v.req_u32("f2")?,
            b_cap: v.req_u32("b_cap")?,
            n1_cap: v.req_u32("n1_cap")?,
            n0_cap: v.req_u32("n0_cap")?,
        })
    }

    /// Whether this artifact fits a run's model shape and batch capacities.
    pub fn matches(&self, ctx: &RunContext) -> bool {
        let cfg = &ctx.cfg;
        cfg.num_layers() == 2
            && self.d == cfg.dataset.feature_dim
            && self.h == cfg.hidden_dim
            && self.c == cfg.dataset.num_classes
            && self.f1 == cfg.fanout[0]
            && self.f2 == cfg.fanout[1]
            && self.b_cap >= cfg.batch_size
    }
}

/// Find the best artifact under `dir` matching the run context — among
/// matches, the one with the smallest `n0_cap` (least padding waste; §Perf).
pub fn find_artifact(dir: &Path, ctx: &RunContext) -> Result<ArtifactMeta> {
    let mut candidates: Vec<PathBuf> = Vec::new();
    if dir.is_dir() {
        for entry in std::fs::read_dir(dir)? {
            let p = entry?.path();
            if p.extension().is_some_and(|e| e == "json")
                && p.to_string_lossy().ends_with(".meta.json")
            {
                candidates.push(p);
            }
        }
    }
    candidates.sort();
    let mut best: Option<ArtifactMeta> = None;
    for p in &candidates {
        let meta = ArtifactMeta::load(p)?;
        if meta.matches(ctx) {
            if !meta.hlo_path.is_file() {
                bail!("manifest {p:?} points at missing HLO {:?}", meta.hlo_path);
            }
            if best.as_ref().is_none_or(|b| meta.n0_cap < b.n0_cap) {
                best = Some(meta);
            }
        }
    }
    if let Some(meta) = best {
        return Ok(meta);
    }
    bail!(
        "no artifact under {dir:?} matches d={} h={} c={} fanout={:?} batch={} — run `make artifacts`",
        ctx.cfg.dataset.feature_dim,
        ctx.cfg.hidden_dim,
        ctx.cfg.dataset.num_classes,
        ctx.cfg.fanout,
        ctx.cfg.batch_size
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetPreset, RunConfig};
    use crate::util::tempdir::TempDir;

    fn write_meta(dir: &Path, name: &str, d: u32, h: u32, c: u32, b_cap: u32) -> PathBuf {
        let mut v = Value::table();
        v.set("hlo", format!("{name}.hlo.txt"))
            .set("d", d)
            .set("h", h)
            .set("c", c)
            .set("f1", 10u32)
            .set("f2", 25u32)
            .set("b_cap", b_cap)
            .set("n1_cap", b_cap * 26)
            .set("n0_cap", b_cap * 26 * 11);
        let p = dir.join(format!("{name}.meta.json"));
        std::fs::write(&p, v.to_json_pretty()).unwrap();
        std::fs::write(dir.join(format!("{name}.hlo.txt")), "HloModule stub").unwrap();
        p
    }

    fn ctx() -> RunContext {
        let mut c = RunConfig::default();
        c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
        RunContext::build(&c).unwrap()
    }

    #[test]
    fn meta_round_trip() {
        let dir = TempDir::new("art").unwrap();
        let p = write_meta(dir.path(), "sage_test", 16, 64, 4, 128);
        let m = ArtifactMeta::load(&p).unwrap();
        assert_eq!(m.d, 16);
        assert_eq!(m.b_cap, 128);
        assert!(m.hlo_path.ends_with("sage_test.hlo.txt"));
    }

    #[test]
    fn find_matching_artifact() {
        let dir = TempDir::new("art").unwrap();
        write_meta(dir.path(), "sage_wrong", 999, 64, 4, 128);
        write_meta(dir.path(), "sage_right", 16, 64, 4, 128);
        let ctx = ctx();
        let m = find_artifact(dir.path(), &ctx).unwrap();
        assert_eq!(m.d, 16);
    }

    #[test]
    fn no_match_reports_shapes() {
        let dir = TempDir::new("art").unwrap();
        let err = find_artifact(dir.path(), &ctx()).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn too_small_batch_cap_rejected() {
        let dir = TempDir::new("art").unwrap();
        write_meta(dir.path(), "sage_small", 16, 64, 4, 8); // cap 8 < batch 128
        assert!(find_artifact(dir.path(), &ctx()).is_err());
    }
}
