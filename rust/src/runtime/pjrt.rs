//! The PJRT-backed train-step executor.
//!
//! Loads HLO text → `XlaComputation` → compiled executable, holds the model
//! parameters host-side as literals, and marshals each sampled batch into
//! the artifact's fixed shapes (index padding + masks). Operand order is the
//! contract with `python/compile/aot.py`:
//!
//! ```text
//! inputs:  w_self1 [d,h], w_nbr1 [d,h], b1 [h],
//!          w_self2 [h,c], w_nbr2 [h,c], b2 [c],
//!          lr [],
//!          x0 [n0_cap,d],
//!          self1 [n1_cap] i32, nbr1 [n1_cap,f1] i32, m1 [n1_cap,f1] f32,
//!          self2 [b_cap]  i32, nbr2 [b_cap,f2]  i32, m2 [b_cap,f2]  f32,
//!          labels [b_cap] i32, label_mask [b_cap] f32
//! outputs: (w_self1', w_nbr1', b1', w_self2', w_nbr2', b2', loss, correct)
//! ```

use super::artifact::ArtifactMeta;
use crate::sampler::khop::{LayerBlock, SampledBatch, NO_NEIGHBOR};
use crate::trainer::{sage::StepOutput, Mat, TrainStep};
use crate::Result;
use anyhow::{ensure, Context};

/// PJRT executor implementing [`TrainStep`].
pub struct PjrtTrainer {
    exe: xla::PjRtLoadedExecutable,
    meta: ArtifactMeta,
    /// Parameters, kept as literals between steps:
    /// `[w_self1, w_nbr1, b1, w_self2, w_nbr2, b2]`.
    params: Vec<xla::Literal>,
    /// Number of train steps executed (diagnostics).
    pub steps_run: u64,
}

impl PjrtTrainer {
    /// Compile the artifact and initialize parameters (same init as the host
    /// model so both backends are comparable).
    pub fn load(meta: ArtifactMeta, seed: u64) -> Result<PjrtTrainer> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            meta.hlo_path.to_str().context("hlo path utf8")?,
        )
        .with_context(|| format!("parse HLO text {:?}", meta.hlo_path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compile HLO")?;
        let params = init_params(&meta, seed)?;
        Ok(PjrtTrainer { exe, meta, params, steps_run: 0 })
    }

    /// Artifact manifest.
    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Current parameters as host matrices (for cross-checking vs the host
    /// backend): `[w_self1, w_nbr1, b1, w_self2, w_nbr2, b2]` flattened.
    pub fn params_flat(&self) -> Result<Vec<Vec<f32>>> {
        self.params.iter().map(|l| Ok(l.to_vec::<f32>()?)).collect()
    }

    /// Execute the artifact once. `lr = 0` makes the step a pure evaluation
    /// (SGD update with zero step size), `apply` controls whether the
    /// returned parameters replace the held ones.
    fn execute(
        &mut self,
        x0: &Mat,
        batch: &SampledBatch,
        labels: &[u16],
        lr: f32,
        apply: bool,
    ) -> Result<StepOutput> {
        let m = &self.meta;
        ensure!(batch.blocks.len() == 2, "artifact is a 2-layer model");
        let n0 = batch.node_layers[0].len();
        let n1 = batch.node_layers[1].len();
        let b = batch.node_layers[2].len();
        ensure!(
            n0 <= m.n0_cap as usize && n1 <= m.n1_cap as usize && b <= m.b_cap as usize,
            "batch ({n0},{n1},{b}) exceeds artifact caps ({},{},{})",
            m.n0_cap,
            m.n1_cap,
            m.b_cap
        );
        ensure!(x0.cols == m.d as usize, "feature dim");

        // ---- pad inputs ----
        let mut x0_pad = vec![0f32; m.n0_cap as usize * m.d as usize];
        x0_pad[..x0.data.len()].copy_from_slice(&x0.data);

        let (self1, nbr1, mask1) = pad_block(&batch.blocks[0], m.n1_cap as usize, m.f1 as usize);
        let (self2, nbr2, mask2) = pad_block(&batch.blocks[1], m.b_cap as usize, m.f2 as usize);

        let mut labels_pad = vec![0i32; m.b_cap as usize];
        let mut lmask = vec![0f32; m.b_cap as usize];
        for (i, &y) in labels.iter().enumerate() {
            if y != u16::MAX {
                labels_pad[i] = y as i32;
                lmask[i] = 1.0;
            }
        }

        let lit = |v: &[f32], dims: &[i64]| -> Result<xla::Literal> {
            Ok(xla::Literal::vec1(v).reshape(dims)?)
        };
        let ilit = |v: &[i32], dims: &[i64]| -> Result<xla::Literal> {
            Ok(xla::Literal::vec1(v).reshape(dims)?)
        };

        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(16);
        for p in &self.params {
            inputs.push(p.clone());
        }
        inputs.push(xla::Literal::scalar(lr));
        inputs.push(lit(&x0_pad, &[m.n0_cap as i64, m.d as i64])?);
        inputs.push(ilit(&self1, &[m.n1_cap as i64])?);
        inputs.push(ilit(&nbr1, &[m.n1_cap as i64, m.f1 as i64])?);
        inputs.push(lit(&mask1, &[m.n1_cap as i64, m.f1 as i64])?);
        inputs.push(ilit(&self2, &[m.b_cap as i64])?);
        inputs.push(ilit(&nbr2, &[m.b_cap as i64, m.f2 as i64])?);
        inputs.push(lit(&mask2, &[m.b_cap as i64, m.f2 as i64])?);
        inputs.push(ilit(&labels_pad, &[m.b_cap as i64])?);
        inputs.push(lit(&lmask, &[m.b_cap as i64])?);

        let result = self.exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let mut outs = result.to_tuple()?;
        ensure!(outs.len() == 8, "expected 8 outputs, got {}", outs.len());
        let correct = outs.pop().unwrap().to_vec::<f32>()?[0];
        let loss = outs.pop().unwrap().to_vec::<f32>()?[0];
        if apply {
            self.params = outs;
            self.steps_run += 1;
        }
        let total = labels.iter().filter(|&&y| y != u16::MAX).count() as u32;
        Ok(StepOutput { loss: loss as f64, correct: correct as u32, total })
    }
}

fn init_params(meta: &ArtifactMeta, seed: u64) -> Result<Vec<xla::Literal>> {
    // Mirror the host model's init exactly (same seeds → same matrices).
    let host = crate::trainer::SageModel::new(
        meta.d as usize,
        meta.h as usize,
        meta.c as usize,
        2,
        seed,
    );
    let mut out = Vec::with_capacity(6);
    for layer in &host.layers {
        out.push(xla::Literal::vec1(&layer.w_self.data).reshape(&[
            layer.w_self.rows as i64,
            layer.w_self.cols as i64,
        ])?);
        out.push(xla::Literal::vec1(&layer.w_nbr.data).reshape(&[
            layer.w_nbr.rows as i64,
            layer.w_nbr.cols as i64,
        ])?);
        out.push(xla::Literal::vec1(&layer.bias));
    }
    // order fix: host pushes [w_self1, w_nbr1, b1, w_self2, w_nbr2, b2] ✓
    Ok(out)
}

/// Pad a layer block's index arrays to `cap` destinations with `fanout`
/// slots: `NO_NEIGHBOR` → index 0 with mask 0; padded dst rows self-index 0.
fn pad_block(block: &LayerBlock, cap: usize, fanout: usize) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
    assert_eq!(block.fanout as usize, fanout, "artifact fanout vs batch fanout");
    let mut self_idx = vec![0i32; cap];
    let mut nbr = vec![0i32; cap * fanout];
    let mut mask = vec![0f32; cap * fanout];
    for d in 0..block.num_dst as usize {
        self_idx[d] = block.self_idx[d] as i32;
        for j in 0..fanout {
            let ni = block.nbr_idx[d * fanout + j];
            if ni != NO_NEIGHBOR {
                nbr[d * fanout + j] = ni as i32;
                mask[d * fanout + j] = 1.0;
            }
        }
    }
    (self_idx, nbr, mask)
}

impl TrainStep for PjrtTrainer {
    fn step(&mut self, x0: &Mat, batch: &SampledBatch, labels: &[u16], lr: f32) -> StepOutput {
        self.execute(x0, batch, labels, lr, true)
            .expect("PJRT step failed")
    }

    fn eval(&mut self, x0: &Mat, batch: &SampledBatch, labels: &[u16]) -> StepOutput {
        self.execute(x0, batch, labels, 0.0, false)
            .expect("PJRT eval failed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::khop::LayerBlock;

    #[test]
    fn pad_block_maps_sentinels_to_masked_zero() {
        let block = LayerBlock {
            fanout: 2,
            num_dst: 2,
            self_idx: vec![3, 1],
            nbr_idx: vec![5, NO_NEIGHBOR, 2, 4],
        };
        let (s, n, m) = pad_block(&block, 4, 2);
        assert_eq!(s, vec![3, 1, 0, 0]);
        assert_eq!(n, vec![5, 0, 2, 4, 0, 0, 0, 0]);
        assert_eq!(m, vec![1.0, 0.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0]);
    }
}
