//! Graph partitioning: random hash and METIS-like balanced edge-cut.
//!
//! The paper partitions with METIS (balanced edge-cut objective) for RapidGNN
//! and DGL-METIS, and with DGL's random partitioner for DGL-Random. METIS
//! itself is a quality knob, not a paper contribution, so we implement a
//! greedy BFS-grown balanced partitioner ([`metis_like`]) that produces the
//! same qualitative locality gap vs. [`random`] (DESIGN.md §3). One halo hop
//! of ghost-node *ids* is tracked per partition, mirroring DistDGL.

mod quality;

pub use quality::{partition_quality, PartitionQuality};

use crate::graph::CsrGraph;
use crate::sampler::seed::mix64;
use crate::{NodeId, WorkerId};

/// Partitioning algorithm selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partitioner {
    /// Hash-based random assignment (DGL "random" partitioner).
    Random,
    /// Greedy BFS-grown balanced edge-cut (METIS stand-in).
    MetisLike,
}

/// A P-way node partition with halo metadata.
#[derive(Debug, Clone)]
pub struct Partition {
    /// Number of parts P.
    pub num_parts: u32,
    /// `owner[v]` = partition owning node v.
    pub owner: Vec<WorkerId>,
    /// Local (owned) nodes per partition, ascending.
    pub local_nodes: Vec<Vec<NodeId>>,
    /// One-hop halo (ghost) node ids per partition: neighbors of owned nodes
    /// that live on other partitions. DistDGL caches these *ids* so sampling
    /// can run locally; features still live remotely.
    pub halo_nodes: Vec<Vec<NodeId>>,
}

impl Partition {
    /// Whether node `v` is owned by partition `p`.
    #[inline]
    pub fn is_local(&self, p: WorkerId, v: NodeId) -> bool {
        self.owner[v as usize] == p
    }

    /// Owner of node `v`.
    #[inline]
    pub fn owner_of(&self, v: NodeId) -> WorkerId {
        self.owner[v as usize]
    }

    /// Build halo sets from the graph (called by the constructors).
    fn compute_halos(&mut self, g: &CsrGraph) {
        let mut halos: Vec<Vec<NodeId>> = vec![Vec::new(); self.num_parts as usize];
        for p in 0..self.num_parts {
            let mut seen = vec![false; g.num_nodes() as usize];
            for &v in &self.local_nodes[p as usize] {
                for &u in g.neighbors(v) {
                    if self.owner[u as usize] != p && !seen[u as usize] {
                        seen[u as usize] = true;
                        halos[p as usize].push(u);
                    }
                }
            }
            halos[p as usize].sort_unstable();
        }
        self.halo_nodes = halos;
    }

    fn from_owner(g: &CsrGraph, num_parts: u32, owner: Vec<WorkerId>) -> Self {
        let mut local_nodes: Vec<Vec<NodeId>> = vec![Vec::new(); num_parts as usize];
        for (v, &p) in owner.iter().enumerate() {
            local_nodes[p as usize].push(v as NodeId);
        }
        let mut part = Partition {
            num_parts,
            owner,
            local_nodes,
            halo_nodes: Vec::new(),
        };
        part.compute_halos(g);
        part
    }
}

/// Partition `g` into `num_parts` parts with the selected algorithm.
pub fn partition(g: &CsrGraph, num_parts: u32, which: Partitioner, seed: u64) -> Partition {
    match which {
        Partitioner::Random => random(g, num_parts, seed),
        Partitioner::MetisLike => metis_like(g, num_parts, seed),
    }
}

/// Hash-based random partitioner (deterministic in `seed`).
pub fn random(g: &CsrGraph, num_parts: u32, seed: u64) -> Partition {
    assert!(num_parts >= 1);
    let owner: Vec<WorkerId> = (0..g.num_nodes())
        .map(|v| (mix64(seed ^ 0xBA17 ^ v as u64) % num_parts as u64) as WorkerId)
        .collect();
    Partition::from_owner(g, num_parts, owner)
}

/// Greedy BFS-grown balanced edge-cut partitioner (METIS stand-in).
///
/// Grows partitions one at a time from high-degree seed nodes using a BFS
/// frontier ordered by *gain* (number of already-assigned same-partition
/// neighbors), stopping each partition at the balance cap `⌈n/P⌉`. This is
/// the classic GGGP/greedy-graph-growing construction METIS uses for its
/// initial partitioning phase; it yields dramatically lower edge cut than
/// random on community-structured graphs, which is all the paper's
/// METIS-vs-Random comparison exercises.
pub fn metis_like(g: &CsrGraph, num_parts: u32, seed: u64) -> Partition {
    assert!(num_parts >= 1);
    let n = g.num_nodes() as usize;
    let cap = n.div_ceil(num_parts as usize);
    const UNASSIGNED: WorkerId = WorkerId::MAX;
    let mut owner = vec![UNASSIGNED; n];

    // Visit candidate seeds hub-first for stable growth.
    let mut by_degree: Vec<NodeId> = (0..g.num_nodes()).collect();
    by_degree.sort_unstable_by_key(|&v| std::cmp::Reverse(g.degree(v)));

    let mut seed_cursor = 0usize;
    for p in 0..num_parts {
        let mut size = 0usize;
        // Frontier as a simple max-gain scan over a bounded candidate list.
        // gain[v] counts v's neighbors already in partition p.
        let mut gain = vec![0u32; n];
        let mut frontier: Vec<NodeId> = Vec::new();
        while size < cap {
            // Pick next node: best-gain frontier node (first-max tie-break:
            // prefer earlier-discovered, i.e. topologically closer, nodes —
            // matters on small graphs where ties are common), else next
            // unassigned hub.
            let mut best: Option<NodeId> = None;
            for &u in &frontier {
                if owner[u as usize] == UNASSIGNED
                    && best.is_none_or(|b| gain[u as usize] > gain[b as usize])
                {
                    best = Some(u);
                }
            }
            let v = match best {
                Some(v) => v,
                None => {
                    while seed_cursor < n && owner[by_degree[seed_cursor] as usize] != UNASSIGNED
                    {
                        seed_cursor += 1;
                    }
                    if seed_cursor >= n {
                        break;
                    }
                    let _ = mix64(seed); // seed reserved for tie-breaking variants
                    by_degree[seed_cursor]
                }
            };
            owner[v as usize] = p;
            size += 1;
            // Retire assigned nodes from the frontier lazily; refresh gains.
            frontier.retain(|&u| owner[u as usize] == UNASSIGNED);
            for &u in g.neighbors(v) {
                if owner[u as usize] == UNASSIGNED {
                    if gain[u as usize] == 0 {
                        frontier.push(u);
                    }
                    gain[u as usize] += 1;
                }
            }
            // Bound the frontier scan cost on hub-heavy graphs.
            if frontier.len() > 4_096 {
                frontier.sort_unstable_by_key(|&u| std::cmp::Reverse(gain[u as usize]));
                frontier.truncate(2_048);
            }
        }
    }
    // Any stragglers (possible when P doesn't divide n) go to the smallest part.
    let mut sizes = vec![0usize; num_parts as usize];
    for &o in &owner {
        if o != UNASSIGNED {
            sizes[o as usize] += 1;
        }
    }
    for v in 0..n {
        if owner[v] == UNASSIGNED {
            let p = (0..num_parts as usize).min_by_key(|&p| sizes[p]).unwrap();
            owner[v] = p as WorkerId;
            sizes[p] += 1;
        }
    }
    Partition::from_owner(g, num_parts, owner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetPreset};
    use crate::graph::build_dataset;

    fn test_graph() -> std::sync::Arc<CsrGraph> {
        let cfg = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
        build_dataset(&cfg, false).graph
    }

    #[test]
    fn random_assigns_every_node() {
        let g = test_graph();
        let p = random(&g, 4, 1);
        assert_eq!(p.owner.len(), g.num_nodes() as usize);
        assert!(p.owner.iter().all(|&o| o < 4));
        let total: usize = p.local_nodes.iter().map(Vec::len).sum();
        assert_eq!(total, g.num_nodes() as usize);
    }

    #[test]
    fn metis_like_is_balanced() {
        let g = test_graph();
        let p = metis_like(&g, 4, 1);
        let cap = (g.num_nodes() as usize).div_ceil(4);
        for part in &p.local_nodes {
            assert!(part.len() <= cap + 1, "part size {} cap {}", part.len(), cap);
            assert!(part.len() >= cap / 2, "part size {} too small", part.len());
        }
    }

    #[test]
    fn metis_like_cuts_fewer_edges_than_random() {
        let g = test_graph();
        let pr = random(&g, 4, 1);
        let pm = metis_like(&g, 4, 1);
        let qr = partition_quality(&g, &pr);
        let qm = partition_quality(&g, &pm);
        assert!(
            qm.edge_cut_fraction < qr.edge_cut_fraction,
            "metis {} !< random {}",
            qm.edge_cut_fraction,
            qr.edge_cut_fraction
        );
    }

    #[test]
    fn halo_nodes_are_remote_neighbors() {
        let g = test_graph();
        let p = metis_like(&g, 2, 1);
        for part in 0..2u32 {
            for &h in &p.halo_nodes[part as usize] {
                assert_ne!(p.owner_of(h), part);
                // h must be adjacent to some owned node
                let touches = g.neighbors(h).iter().any(|&u| p.owner_of(u) == part);
                assert!(touches);
            }
        }
    }

    #[test]
    fn single_partition_owns_everything() {
        let g = test_graph();
        let p = metis_like(&g, 1, 0);
        assert!(p.owner.iter().all(|&o| o == 0));
        assert!(p.halo_nodes[0].is_empty());
    }

    #[test]
    fn partition_deterministic() {
        let g = test_graph();
        let a = metis_like(&g, 3, 7);
        let b = metis_like(&g, 3, 7);
        assert_eq!(a.owner, b.owner);
        let ar = random(&g, 3, 7);
        let br = random(&g, 3, 7);
        assert_eq!(ar.owner, br.owner);
    }
}
