//! Partition quality metrics: edge cut, balance, remote-neighbor fraction.

use super::Partition;
use crate::graph::CsrGraph;

/// Quality summary for a partition.
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionQuality {
    /// Fraction of directed edges crossing partitions (the METIS objective).
    pub edge_cut_fraction: f64,
    /// `max part size / mean part size` (1.0 = perfectly balanced).
    pub balance: f64,
    /// Mean over nodes of the fraction of their neighbors that are remote —
    /// the paper's `c` (remote-node fraction) governing per-worker
    /// communication `∝ c · |batch|` (§3 Scalability).
    pub remote_neighbor_fraction: f64,
    /// Mean halo size per partition.
    pub mean_halo: f64,
}

/// Compute [`PartitionQuality`] for `part` over `g`.
pub fn partition_quality(g: &CsrGraph, part: &Partition) -> PartitionQuality {
    let mut cut = 0u64;
    let mut remote_frac_sum = 0f64;
    let mut nodes_with_edges = 0u64;
    for v in 0..g.num_nodes() {
        let nbrs = g.neighbors(v);
        if nbrs.is_empty() {
            continue;
        }
        let remote = nbrs
            .iter()
            .filter(|&&u| part.owner_of(u) != part.owner_of(v))
            .count();
        cut += remote as u64;
        remote_frac_sum += remote as f64 / nbrs.len() as f64;
        nodes_with_edges += 1;
    }
    let sizes: Vec<usize> = part.local_nodes.iter().map(Vec::len).collect();
    let mean_size = sizes.iter().sum::<usize>() as f64 / sizes.len() as f64;
    let max_size = *sizes.iter().max().unwrap() as f64;
    let mean_halo =
        part.halo_nodes.iter().map(Vec::len).sum::<usize>() as f64 / part.num_parts as f64;
    PartitionQuality {
        edge_cut_fraction: cut as f64 / g.num_directed_edges().max(1) as f64,
        balance: if mean_size > 0.0 {
            max_size / mean_size
        } else {
            1.0
        },
        remote_neighbor_fraction: remote_frac_sum / nodes_with_edges.max(1) as f64,
        mean_halo,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::{metis_like, random};
    use crate::graph::CsrGraph;

    /// Two triangles joined by one edge: an obvious 2-way min cut.
    fn barbell() -> CsrGraph {
        CsrGraph::from_edges(
            6,
            &[(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3), (2, 3)],
        )
    }

    #[test]
    fn perfect_cut_on_barbell() {
        let g = barbell();
        let p = metis_like(&g, 2, 0);
        let q = partition_quality(&g, &p);
        // 2 of 14 directed edges cross in the ideal split
        assert!(q.edge_cut_fraction <= 2.0 / 14.0 + 1e-9, "cut {}", q.edge_cut_fraction);
        assert!((q.balance - 1.0).abs() < 1e-9);
    }

    #[test]
    fn random_cut_near_expected() {
        // Random P-way: expected cut fraction ≈ 1 - 1/P.
        let cfg = crate::config::DatasetConfig::preset(crate::config::DatasetPreset::Tiny, 1.0);
        let g = crate::graph::build_dataset(&cfg, false).graph;
        let p = random(&g, 4, 3);
        let q = partition_quality(&g, &p);
        assert!((q.edge_cut_fraction - 0.75).abs() < 0.05, "cut {}", q.edge_cut_fraction);
    }

    #[test]
    fn remote_fraction_zero_for_single_part() {
        let g = barbell();
        let p = metis_like(&g, 1, 0);
        let q = partition_quality(&g, &p);
        assert_eq!(q.edge_cut_fraction, 0.0);
        assert_eq!(q.remote_neighbor_fraction, 0.0);
        assert_eq!(q.mean_halo, 0.0);
    }
}
