//! `rapidgnn-lint` — the repo's determinism & contract linter (xtask).
//!
//! Every headline claim this reproduction makes (byte-stable golden traces,
//! bit-exact chaos/kill-restore replay, `RAPIDGNN_THREADS`-invariant
//! reports) rests on invariants that clippy cannot express. This binary
//! enforces them at lint time, before a test ever runs:
//!
//! | rule id                  | contract                                                      |
//! |--------------------------|---------------------------------------------------------------|
//! | `priced-recovery`        | `coordinator/recovery.rs` never calls a `charge_*` fabric     |
//! |                          | method — recovery is priced through the pure link model and   |
//! |                          | must not mutate RPC counters (retry cadence would shift).     |
//! | `unordered-collections`  | no std hash-map/-set identifiers outside `util/fasthash.rs`   |
//! |                          | (the sanctioned deterministic-hasher alias `IdHashMap`) —     |
//! |                          | hash iteration order must never feed serde/telemetry paths.   |
//! | `wall-clock`             | `Instant`/`SystemTime` only inside the allowlisted wall-clock |
//! |                          | modules (`util/wallclock.rs`, `util/bench.rs`,                |
//! |                          | `util/tempdir.rs`) — virtual time everywhere else.            |
//! | `thread-spawn`           | no direct `thread::spawn` / `thread::Builder` outside        |
//! |                          | `src/util/` — fan-out goes through `util::parallel`'s         |
//! |                          | deterministic helpers or `util::mpmc` actors.                 |
//! | `unordered-float-reduce` | no float `.sum()`/`.fold()` over a `par_*` result outside     |
//! |                          | `util/parallel.rs`, and no `rayon` — unordered float          |
//! |                          | reduction is thread-count-dependent.                          |
//! | `module-docs`            | every `src/**.rs` file starts with `//!` module docs.         |
//! | `trace-sink`             | no `println!`/`eprintln!` (or `print!`/`eprint!`) inside      |
//! |                          | `src/trace/` and `src/tui/` — observability code returns      |
//! |                          | strings/records; only the CLI layer owns stdout.              |
//! | `charge-ladder`          | no deprecated pre-`ChargeSpec` charge ladder (`charge_rpc*`,  |
//! |                          | `charge_fanout*`) outside `net/mod.rs`, and no deprecated     |
//! |                          | pull wrappers (`vector_pull*`, `sync_pull*`) outside          |
//! |                          | `kvstore/mod.rs` — callers build a `ChargeSpec` /             |
//! |                          | `PullRequest` and go through `Transport::charge` /            |
//! |                          | `KvStore::pull`.                                              |
//!
//! Approved exceptions carry an inline marker the linter recognizes:
//!
//! ```text
//! // lint:allow(<rule-id>): <justification>        -- this line + the next
//! // lint:allow-file(<rule-id>): <justification>   -- the whole file
//! ```
//!
//! A marker without a `: justification` tail is itself a violation
//! (`marker-justification`), as is a marker naming an unknown rule.
//!
//! Scanning is token/line-level over a comment- and string-stripped view of
//! each file (no `syn`; the container is offline), so identifiers inside
//! comments, doc examples, and string literals never trip a rule. Multi-line
//! evasion of the same-line `unordered-float-reduce` heuristic is possible;
//! review guards the gap — the rule exists to catch the common spelling.
//!
//! Usage: `cargo run --bin rapidgnn-lint -- lint [--root DIR]`. Without
//! `--root` the crate's own tree is scanned (`src/`, `tests/`, `benches/`
//! and the repo-level `examples/`); `--root` points at an alternate tree
//! with the same sub-layout (the seeded-violation fixtures under
//! `tests/fixtures/lint/` use this). Exit status: 0 clean, 1 violations,
//! 2 usage error. `tests/lint.rs` shells this binary, so contract drift
//! fails `cargo test` locally as well as in CI.

#![forbid(unsafe_code)]

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

/// Which scan root a file came from; rules scope themselves by kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RootKind {
    Src,
    Tests,
    Benches,
    Examples,
}

/// All rule identifiers, in report order. `marker-justification` is the
/// meta-rule for malformed allow markers.
const RULE_IDS: [&str; 9] = [
    "priced-recovery",
    "unordered-collections",
    "wall-clock",
    "thread-spawn",
    "unordered-float-reduce",
    "module-docs",
    "trace-sink",
    "charge-ladder",
    "marker-justification",
];

/// The deprecated pre-`ChargeSpec` fabric entry points, legal only inside
/// their shim home `net/mod.rs`.
const CHARGE_LADDER: [&str; 6] = [
    "charge_rpc",
    "charge_rpc_at",
    "charge_rpc_payload_at",
    "charge_fanout",
    "charge_fanout_at",
    "charge_fanout_payload_at",
];

/// The deprecated pre-`PullRequest` kvstore wrappers, legal only inside
/// their shim home `kvstore/mod.rs`.
const PULL_LADDER: [&str; 4] = ["vector_pull", "vector_pull_at", "sync_pull", "sync_pull_at"];

/// Files (paths relative to their scan root, `/`-separated) where the
/// wall-clock rule does not apply: these *are* the wall-clock modules.
const WALL_CLOCK_ALLOWED: [&str; 3] =
    ["util/wallclock.rs", "util/bench.rs", "util/tempdir.rs"];

/// The sanctioned home of the deterministic-hasher map alias.
const COLLECTIONS_ALLOWED: [&str; 1] = ["util/fasthash.rs"];

/// One reported violation.
struct Violation {
    path: PathBuf,
    line: usize,
    rule: &'static str,
    msg: String,
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut root: Option<PathBuf> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            // `lint` is the (only) subcommand; tolerate its absence.
            "lint" => {}
            "--root" => {
                i += 1;
                match args.get(i) {
                    Some(p) => root = Some(PathBuf::from(p)),
                    None => usage_error("--root requires a directory argument"),
                }
            }
            "--help" | "-h" => {
                println!(
                    "rapidgnn-lint: determinism & contract linter\n\
                     usage: rapidgnn-lint [lint] [--root DIR]\n\
                     rules: {}",
                    RULE_IDS.join(", ")
                );
                return;
            }
            other => usage_error(&format!("unknown argument '{other}'")),
        }
        i += 1;
    }

    let roots: Vec<(RootKind, PathBuf)> = match root {
        Some(r) => vec![
            (RootKind::Src, r.join("src")),
            (RootKind::Tests, r.join("tests")),
            (RootKind::Benches, r.join("benches")),
            (RootKind::Examples, r.join("examples")),
        ],
        None => {
            let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
            vec![
                (RootKind::Src, manifest.join("src")),
                (RootKind::Tests, manifest.join("tests")),
                (RootKind::Benches, manifest.join("benches")),
                (RootKind::Examples, manifest.join("../examples")),
            ]
        }
    };

    let mut violations: Vec<Violation> = Vec::new();
    let mut scanned = 0usize;
    for (kind, dir) in &roots {
        if !dir.is_dir() {
            continue;
        }
        let mut files = Vec::new();
        collect_rs_files(dir, &mut files);
        files.sort();
        for f in files {
            let rel = rel_slash_path(&f, dir);
            match std::fs::read_to_string(&f) {
                Ok(text) => {
                    scanned += 1;
                    lint_file(*kind, &f, &rel, &text, &mut violations);
                }
                Err(e) => violations.push(Violation {
                    path: f,
                    line: 0,
                    rule: "module-docs",
                    msg: format!("unreadable source file: {e}"),
                }),
            }
        }
    }

    for v in &violations {
        println!("{}:{}: [{}] {}", v.path.display(), v.line, v.rule, v.msg);
    }
    println!(
        "rapidgnn-lint: {} file(s) scanned, {} violation(s)",
        scanned,
        violations.len()
    );
    if !violations.is_empty() {
        std::process::exit(1);
    }
}

fn usage_error(msg: &str) -> ! {
    eprintln!("rapidgnn-lint: {msg} (try --help)");
    std::process::exit(2);
}

/// Recursively gather `.rs` files, skipping build output, vendored crates,
/// test fixtures (they contain seeded violations on purpose), and dotdirs.
fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return,
    };
    for entry in entries.flatten() {
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if path.is_dir() {
            if name == "target" || name == "vendor" || name == "fixtures" || name.starts_with('.')
            {
                continue;
            }
            collect_rs_files(&path, out);
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
}

/// `path` relative to `root`, `/`-separated (rule scoping is textual).
fn rel_slash_path(path: &Path, root: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/")
}

/// Per-file allow state parsed from `lint:allow` markers.
#[derive(Default)]
struct Allows {
    /// Rules allowed for the entire file.
    file: BTreeSet<&'static str>,
    /// (rule, line) pairs allowed by a line marker (the marker's own line
    /// and the one after it).
    lines: BTreeSet<(&'static str, usize)>,
}

impl Allows {
    fn permits(&self, rule: &'static str, line: usize) -> bool {
        self.file.contains(rule) || self.lines.contains(&(rule, line))
    }
}

/// Parse `lint:allow(...)` / `lint:allow-file(...)` markers from the raw
/// source. Malformed markers are violations, not silent no-ops.
fn parse_markers(path: &Path, raw_lines: &[&str], violations: &mut Vec<Violation>) -> Allows {
    let mut allows = Allows::default();
    for (idx, line) in raw_lines.iter().enumerate() {
        let lineno = idx + 1;
        for (needle, file_scope) in [("lint:allow-file(", true), ("lint:allow(", false)] {
            let Some(at) = line.find(needle) else { continue };
            let rest = &line[at + needle.len()..];
            let Some(close) = rest.find(')') else {
                violations.push(Violation {
                    path: path.to_path_buf(),
                    line: lineno,
                    rule: "marker-justification",
                    msg: "unterminated lint:allow marker (missing ')')".into(),
                });
                continue;
            };
            let rule_name = rest[..close].trim();
            // Only rule-shaped names ([a-z-]+) are marker candidates; other
            // spellings (e.g. the `<rule-id>` placeholder in docs) are prose.
            if rule_name.is_empty()
                || !rule_name.bytes().all(|b| b.is_ascii_lowercase() || b == b'-')
            {
                continue;
            }
            let Some(rule) = RULE_IDS.iter().find(|r| **r == rule_name).copied() else {
                violations.push(Violation {
                    path: path.to_path_buf(),
                    line: lineno,
                    rule: "marker-justification",
                    msg: format!(
                        "lint:allow names unknown rule '{rule_name}' (known: {})",
                        RULE_IDS.join(", ")
                    ),
                });
                continue;
            };
            // Justification: a `:` after the `)` with non-empty text.
            let tail = rest[close + 1..].trim_start();
            let justified =
                tail.strip_prefix(':').map(str::trim).is_some_and(|t| !t.is_empty());
            if !justified {
                violations.push(Violation {
                    path: path.to_path_buf(),
                    line: lineno,
                    rule: "marker-justification",
                    msg: format!(
                        "lint:allow({rule}) needs a justification: `lint:allow({rule}): why`"
                    ),
                });
                continue;
            }
            if file_scope {
                allows.file.insert(rule);
            } else {
                allows.lines.insert((rule, lineno));
                allows.lines.insert((rule, lineno + 1));
            }
        }
    }
    allows
}

/// Lint one file: build the comment/string-stripped code view, parse allow
/// markers, then apply every rule in scope.
fn lint_file(
    kind: RootKind,
    path: &Path,
    rel: &str,
    text: &str,
    violations: &mut Vec<Violation>,
) {
    let raw_lines: Vec<&str> = text.lines().collect();
    let allows = parse_markers(path, &raw_lines, violations);
    let code = strip_comments_and_strings(text);
    let code_lines: Vec<&str> = code.lines().collect();

    let mut report = |rule: &'static str, line: usize, msg: String| {
        if !allows.permits(rule, line) {
            violations.push(Violation { path: path.to_path_buf(), line, rule, msg });
        }
    };

    // -- module-docs: src files must open with `//!`. --------------------
    if kind == RootKind::Src {
        let first = raw_lines.iter().map(|l| l.trim()).find(|l| !l.is_empty());
        if !matches!(first, Some(l) if l.starts_with("//!")) {
            report(
                "module-docs",
                1,
                "source file must start with `//!` module documentation".into(),
            );
        }
    }

    // -- priced-recovery: no fabric charge calls in the recovery engine. --
    if kind == RootKind::Src && rel == "coordinator/recovery.rs" {
        for (idx, line) in code_lines.iter().enumerate() {
            for ident in idents(line) {
                if ident.starts_with("charge_") {
                    report(
                        "priced-recovery",
                        idx + 1,
                        format!(
                            "recovery must price via the pure link model \
                             (`rpc_time_on_link`), not `{ident}` — charging \
                             mutates the fabric's RPC/retry counters"
                        ),
                    );
                }
            }
        }
    }

    // -- unordered-collections ------------------------------------------
    if !COLLECTIONS_ALLOWED.contains(&rel) {
        for (idx, line) in code_lines.iter().enumerate() {
            for ident in idents(line) {
                if ident == "HashMap" || ident == "HashSet" {
                    report(
                        "unordered-collections",
                        idx + 1,
                        format!(
                            "`{ident}` iteration order is nondeterministic; use \
                             `BTreeMap`/`BTreeSet`, sort at the boundary, or the \
                             `IdHashMap` alias from util::fasthash (or annotate \
                             `// lint:allow(unordered-collections): why`)"
                        ),
                    );
                }
            }
        }
    }

    // -- wall-clock (src/tests/examples; benches measure by definition). --
    if matches!(kind, RootKind::Src | RootKind::Tests | RootKind::Examples)
        && !(kind == RootKind::Src && WALL_CLOCK_ALLOWED.contains(&rel))
    {
        for (idx, line) in code_lines.iter().enumerate() {
            for ident in idents(line) {
                if ident == "Instant" || ident == "SystemTime" {
                    report(
                        "wall-clock",
                        idx + 1,
                        format!(
                            "`{ident}` outside the wall-clock modules breaks \
                             virtual-time determinism; use \
                             `util::wallclock::Stopwatch` (full-mode timing) or \
                             the `util::bench` harness"
                        ),
                    );
                }
            }
        }
    }

    // -- thread-spawn (src outside util/, plus integration tests). -------
    let spawn_scoped = match kind {
        RootKind::Src => !rel.starts_with("util/"),
        RootKind::Tests => true,
        RootKind::Benches | RootKind::Examples => false,
    };
    if spawn_scoped {
        for (idx, line) in code_lines.iter().enumerate() {
            for needle in ["thread::spawn", "thread::Builder"] {
                if contains_token_seq(line, needle) {
                    report(
                        "thread-spawn",
                        idx + 1,
                        format!(
                            "direct `{needle}` outside `util/`; use \
                             `util::parallel`'s deterministic map/reduce or a \
                             `util::mpmc` actor (or annotate \
                             `// lint:allow(thread-spawn): why`)"
                        ),
                    );
                }
            }
        }
    }

    // -- trace-sink: observability modules never print. -------------------
    if kind == RootKind::Src && (rel.starts_with("trace/") || rel.starts_with("tui/")) {
        for (idx, line) in code_lines.iter().enumerate() {
            for ident in idents(line) {
                if matches!(ident, "println" | "eprintln" | "print" | "eprint") {
                    report(
                        "trace-sink",
                        idx + 1,
                        format!(
                            "`{ident}!` inside src/{rel}: trace and tui code \
                             returns strings/records and never owns stdout — \
                             print from the CLI layer (`main.rs`) instead"
                        ),
                    );
                }
            }
        }
    }

    // -- charge-ladder: deprecated charge/pull wrappers stay in their shim
    //    homes; everything else builds a ChargeSpec / PullRequest. ---------
    for (idx, line) in code_lines.iter().enumerate() {
        for ident in idents(line) {
            let (banned, home, new_api) = if CHARGE_LADDER.contains(&ident) {
                (true, "net/mod.rs", "`Transport::charge(ChargeSpec { .. })`")
            } else if PULL_LADDER.contains(&ident) {
                (true, "kvstore/mod.rs", "`KvStore::pull(PullRequest { .. })`")
            } else {
                (false, "", "")
            };
            if banned && !(kind == RootKind::Src && rel == home) {
                report(
                    "charge-ladder",
                    idx + 1,
                    format!(
                        "deprecated wrapper `{ident}` outside its shim home \
                         `src/{home}`; build the spec and call {new_api} instead"
                    ),
                );
            }
        }
    }

    // -- unordered-float-reduce (src outside util/parallel.rs). ----------
    if kind == RootKind::Src && rel != "util/parallel.rs" {
        for (idx, line) in code_lines.iter().enumerate() {
            let has_par = idents(line).iter().any(|i| i.starts_with("par_"));
            let has_reduce = line.contains(".sum(")
                || line.contains(".sum::")
                || line.contains(".fold(");
            if has_par && has_reduce {
                report(
                    "unordered-float-reduce",
                    idx + 1,
                    "reducing a parallel result in-line is order-sensitive for \
                     floats; reduce inside util::parallel's deterministic \
                     helpers or sort first"
                        .into(),
                );
            }
            if idents(line).iter().any(|i| i == "rayon") {
                report(
                    "unordered-float-reduce",
                    idx + 1,
                    "rayon's work-stealing reductions are \
                     nondeterministically ordered; use util::parallel"
                        .into(),
                );
            }
        }
    }
}

/// Identifiers ([A-Za-z_][A-Za-z0-9_]*) on one code-view line.
fn idents(line: &str) -> Vec<&str> {
    let bytes = line.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        if is_ident_start(bytes[i]) {
            let start = i;
            while i < bytes.len() && is_ident_char(bytes[i]) {
                i += 1;
            }
            out.push(&line[start..i]);
        } else {
            i += 1;
        }
    }
    out
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_char(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Whether `line` contains `needle` (an `ident::ident` sequence) at
/// identifier boundaries — `std::thread::spawn` matches `thread::spawn`,
/// `xthread::spawned` matches neither.
fn contains_token_seq(line: &str, needle: &str) -> bool {
    let mut from = 0;
    while let Some(at) = line[from..].find(needle) {
        let start = from + at;
        let end = start + needle.len();
        let pre_ok = start == 0 || !is_ident_char(line.as_bytes()[start - 1]);
        let post_ok = end >= line.len() || !is_ident_char(line.as_bytes()[end]);
        if pre_ok && post_ok {
            return true;
        }
        from = end;
    }
    false
}

/// Blank out comments, string literals, and char literals, preserving line
/// structure (stripped bytes become spaces). Handles nested block comments,
/// escapes, raw strings (`r"…"`, `r#"…"#`, `br#"…"#`), and distinguishes
/// lifetimes from char literals well enough for identifier scanning.
fn strip_comments_and_strings(text: &str) -> String {
    let b = text.as_bytes();
    let mut out = String::with_capacity(text.len());
    let mut i = 0;

    // Append a blanked byte (newlines survive so line numbers align).
    fn blank(out: &mut String, byte: u8) {
        out.push(if byte == b'\n' { '\n' } else { ' ' });
    }

    while i < b.len() {
        let c = b[i];
        // Line comment.
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            while i < b.len() && b[i] != b'\n' {
                blank(&mut out, b[i]);
                i += 1;
            }
            continue;
        }
        // Block comment (nested).
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
            let mut depth = 1;
            blank(&mut out, b[i]);
            blank(&mut out, b[i + 1]);
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    depth += 1;
                    blank(&mut out, b[i]);
                    blank(&mut out, b[i + 1]);
                    i += 2;
                } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    depth -= 1;
                    blank(&mut out, b[i]);
                    blank(&mut out, b[i + 1]);
                    i += 2;
                } else {
                    blank(&mut out, b[i]);
                    i += 1;
                }
            }
            continue;
        }
        // Raw string: r"…", r#"…"#, br"…", br#"…"# (only when `r`/`b` is not
        // the tail of a longer identifier).
        if (c == b'r' || c == b'b') && (i == 0 || !is_ident_char(b[i - 1])) {
            let mut j = i;
            if b[j] == b'b' && j + 1 < b.len() && b[j + 1] == b'r' {
                j += 1;
            }
            if b[j] == b'r' {
                let mut k = j + 1;
                let mut hashes = 0;
                while k < b.len() && b[k] == b'#' {
                    hashes += 1;
                    k += 1;
                }
                if k < b.len() && b[k] == b'"' {
                    // Blank the prefix and scan for `"` + `hashes` hashes.
                    while i <= k {
                        blank(&mut out, b[i]);
                        i += 1;
                    }
                    'raw: while i < b.len() {
                        if b[i] == b'"' {
                            let mut h = 0;
                            while h < hashes && i + 1 + h < b.len() && b[i + 1 + h] == b'#' {
                                h += 1;
                            }
                            if h == hashes {
                                for _ in 0..=hashes {
                                    blank(&mut out, b[i]);
                                    i += 1;
                                }
                                break 'raw;
                            }
                        }
                        blank(&mut out, b[i]);
                        i += 1;
                    }
                    continue;
                }
            }
        }
        // Plain string literal.
        if c == b'"' {
            blank(&mut out, c);
            i += 1;
            while i < b.len() {
                if b[i] == b'\\' && i + 1 < b.len() {
                    blank(&mut out, b[i]);
                    blank(&mut out, b[i + 1]);
                    i += 2;
                    continue;
                }
                let done = b[i] == b'"';
                blank(&mut out, b[i]);
                i += 1;
                if done {
                    break;
                }
            }
            continue;
        }
        // Char literal vs lifetime: `'x'` / `'\n'` are literals; `'static`
        // (no closing quote within the escape-free two-char window) is a
        // lifetime and passes through.
        if c == b'\'' {
            let is_char_lit = i + 1 < b.len()
                && (b[i + 1] == b'\\' || (i + 2 < b.len() && b[i + 2] == b'\''));
            if is_char_lit {
                blank(&mut out, c);
                i += 1;
                while i < b.len() {
                    if b[i] == b'\\' && i + 1 < b.len() {
                        blank(&mut out, b[i]);
                        blank(&mut out, b[i + 1]);
                        i += 2;
                        continue;
                    }
                    let done = b[i] == b'\'';
                    blank(&mut out, b[i]);
                    i += 1;
                    if done {
                        break;
                    }
                }
                continue;
            }
        }
        out.push(c as char);
        i += 1;
    }
    out
}
