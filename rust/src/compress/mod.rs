//! Communication-compression codecs and gradient sparsifiers.
//!
//! Two families, both deterministic and priced end-to-end by the fabric:
//!
//! 1. **Feature quantization** ([`BlockCodec`]) — per-block lossy codecs for
//!    the f32 feature rows shipped by [`crate::kvstore::KvStore`] pulls:
//!    - `f16`: IEEE binary16 with round-to-nearest-even, 2 bytes/element and
//!      no header; relative error ≤ 2⁻¹¹ for normal-range inputs.
//!    - `int8`: per-block affine quantization with an f32 `(min, scale)`
//!      header per block (8 bytes), 1 byte/element; absolute error ≤ scale/2
//!      where `scale = (max − min)/255` over the block. All-equal blocks
//!      (scale 0) round-trip exactly.
//!    Rows are quantized block-by-block *independently*, so the round-trip is
//!    invariant to how pulls are batched or windowed — a requirement for the
//!    bit-determinism contract across `RAPIDGNN_THREADS` and for composing
//!    the codec with `green-window` pull merging.
//!
//! 2. **Gradient sparsification** ([`top_k_indices`], [`rand_k_indices`],
//!    [`ErrorFeedback`]) — classic error-feedback compression (Stich et al.):
//!    each step the residual from previous steps is folded into the fresh
//!    gradient, the top-k (or a seeded random-k) coordinates are applied, and
//!    the dropped mass is carried forward. Ties in top-k break by lower index
//!    so selection is total-ordered and deterministic.
//!
//! The codec *byte model* lives here too ([`BlockCodec::row_payload_bytes`]):
//! the kvstore charges the fabric exactly these payload bytes (plus the
//! fabric's usual 64-byte per-RPC envelope), while `remote_rows` counters
//! stay codec-invariant.

use crate::sampler::seed::Rng;

/// Wire codec selector as it appears in `EngineParams` / TOML / CLI.
///
/// `Default` is a sentinel resolved per-strategy (rapid-family engines resolve
/// it to `None`; `quant-pull` resolves it to `Int8`), so an explicit
/// `codec = "none"` disables compression everywhere — the degeneration pin —
/// while plain configs pick each engine's natural default.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Codec {
    /// Strategy-resolved default.
    Default,
    /// Compression off: full-precision f32 rows, legacy charge path.
    None,
    /// IEEE binary16, 2 bytes/element, no header.
    F16,
    /// Per-block affine int8, 1 byte/element + 8-byte block header.
    Int8,
}

impl Codec {
    /// Every selectable codec (for usage strings and exhaustive tests).
    pub const ALL: [Codec; 4] = [Codec::Default, Codec::None, Codec::F16, Codec::Int8];

    /// Stable string id (TOML / CLI spelling).
    pub fn id(self) -> &'static str {
        match self {
            Codec::Default => "default",
            Codec::None => "none",
            Codec::F16 => "f16",
            Codec::Int8 => "int8",
        }
    }
}

impl Default for Codec {
    fn default() -> Self {
        Codec::Default
    }
}

impl std::str::FromStr for Codec {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Codec::ALL
            .into_iter()
            .find(|c| c.id() == s)
            .ok_or_else(|| anyhow::anyhow!("unknown codec {s:?} (default|none|f16|int8)"))
    }
}

/// Gradient-sparsification coordinate selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GradMode {
    /// Largest-|g| coordinates, ties to the lower index.
    TopK,
    /// Uniform random-k from a per-step seeded stream.
    RandK,
}

impl GradMode {
    pub const ALL: [GradMode; 2] = [GradMode::TopK, GradMode::RandK];

    /// Stable string id (TOML / CLI spelling).
    pub fn id(self) -> &'static str {
        match self {
            GradMode::TopK => "topk",
            GradMode::RandK => "randk",
        }
    }
}

impl Default for GradMode {
    fn default() -> Self {
        GradMode::TopK
    }
}

impl std::str::FromStr for GradMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        GradMode::ALL
            .into_iter()
            .find(|m| m.id() == s)
            .ok_or_else(|| anyhow::anyhow!("unknown grad mode {s:?} (topk|randk)"))
    }
}

/// A resolved wire codec (never `none`): what the kvstore actually runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireCodec {
    F16,
    Int8,
}

/// A wire codec plus its block size: the unit installed into the kvstore.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockCodec {
    pub kind: WireCodec,
    /// Elements per quantization block (int8 header granularity). ≥ 1.
    pub block: usize,
}

/// Per-block int8 header: `min: f32, scale: f32`.
pub const INT8_BLOCK_HEADER_BYTES: u64 = 8;

impl BlockCodec {
    pub fn new(kind: WireCodec, block: u32) -> Self {
        BlockCodec { kind, block: block.max(1) as usize }
    }

    /// Stable string id of the wire codec (telemetry label).
    pub fn id(&self) -> &'static str {
        match self.kind {
            WireCodec::F16 => Codec::F16.id(),
            WireCodec::Int8 => Codec::Int8.id(),
        }
    }

    /// Compressed payload bytes for one `d`-element f32 row, headers
    /// included. The uncompressed equivalent is `4 * d`.
    pub fn row_payload_bytes(&self, d: usize) -> u64 {
        match self.kind {
            WireCodec::F16 => 2 * d as u64,
            WireCodec::Int8 => {
                let blocks = d.div_ceil(self.block) as u64;
                d as u64 + INT8_BLOCK_HEADER_BYTES * blocks
            }
        }
    }

    /// Quantize→dequantize `row` in place; returns the summed squared error.
    ///
    /// This is exactly what the receiver would reconstruct from the wire
    /// format, so training on the round-tripped rows makes convergence
    /// effects real without materializing byte buffers.
    pub fn round_trip(&self, row: &mut [f32]) -> f64 {
        let mut se = 0.0f64;
        match self.kind {
            WireCodec::F16 => {
                for x in row.iter_mut() {
                    let y = f16_bits_to_f32(f32_to_f16_bits(*x));
                    se += (*x as f64 - y as f64).powi(2);
                    *x = y;
                }
            }
            WireCodec::Int8 => {
                for chunk in row.chunks_mut(self.block) {
                    se += int8_round_trip_block(chunk);
                }
            }
        }
        se
    }
}

/// Affine int8 round-trip of one block in place; returns summed squared error.
fn int8_round_trip_block(block: &mut [f32]) -> f64 {
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &x in block.iter() {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    let scale = (hi - lo) / 255.0;
    if !(scale > 0.0) {
        // All-equal (or empty) block: q ≡ 0, dequant ≡ min — exact.
        return 0.0;
    }
    let mut se = 0.0f64;
    for x in block.iter_mut() {
        let q = ((*x - lo) / scale).round().clamp(0.0, 255.0);
        let y = lo + q * scale;
        se += (*x as f64 - y as f64).powi(2);
        *x = y;
    }
    se
}

/// f32 → IEEE binary16 bits, round-to-nearest-even, saturating overflow to
/// the max finite half (±65504) so finite inputs never become Inf/NaN.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // Inf / NaN propagate (callers feed finite values).
        return sign | 0x7C00 | if man != 0 { 0x0200 } else { 0 };
    }
    let e16 = exp - 127 + 15;
    if e16 >= 0x1F {
        return sign | 0x7BFF; // saturate to max finite
    }
    if e16 <= 0 {
        // Subnormal half (or underflow to zero).
        if e16 < -10 {
            return sign;
        }
        let m = man | 0x0080_0000; // implicit leading 1
        let shift = (14 - e16) as u32; // in 14..=24
        let half = m >> shift;
        let rem = m & ((1u32 << shift) - 1);
        let mid = 1u32 << (shift - 1);
        let rounded = if rem > mid || (rem == mid && half & 1 == 1) { half + 1 } else { half };
        return sign | rounded as u16;
    }
    let half = ((e16 as u32) << 10) | (man >> 13);
    let rem = man & 0x1FFF;
    let rounded = if rem > 0x1000 || (rem == 0x1000 && half & 1 == 1) { half + 1 } else { half };
    if rounded >= 0x7C00 {
        return sign | 0x7BFF; // mantissa rounding carried into Inf: saturate
    }
    sign | rounded as u16
}

/// IEEE binary16 bits → f32 (exact: every half value is representable).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    if exp == 0 {
        // ±0 and subnormals: value = man · 2⁻²⁴ (exact in f32).
        let mag = man as f32 * f32::from_bits(0x3380_0000); // 2^-24
        return if sign != 0 { -mag } else { mag };
    }
    if exp == 0x1F {
        return if man != 0 {
            f32::NAN
        } else if sign != 0 {
            f32::NEG_INFINITY
        } else {
            f32::INFINITY
        };
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (man << 13))
}

/// Number of gradient coordinates kept for a `frac` target over `len`
/// elements: `ceil(len · frac)`, at least 1 for non-empty inputs.
pub fn keep_count(len: usize, frac: f64) -> usize {
    if len == 0 || frac <= 0.0 {
        return 0;
    }
    ((len as f64 * frac).ceil() as usize).clamp(1, len)
}

/// Indices of the `k` largest-magnitude entries, ascending-sorted.
///
/// Deterministic total order: |v| descending, then index ascending, so equal
/// magnitudes always resolve the same way regardless of thread count.
pub fn top_k_indices(values: &[f32], k: usize) -> Vec<u32> {
    let k = k.min(values.len());
    let mut idx: Vec<u32> = (0..values.len() as u32).collect();
    idx.sort_by(|&a, &b| {
        let (va, vb) = (values[a as usize].abs(), values[b as usize].abs());
        vb.partial_cmp(&va).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx.truncate(k);
    idx.sort_unstable();
    idx
}

/// `k` distinct uniform indices from `0..len`, ascending-sorted, via partial
/// Fisher–Yates on the supplied deterministic stream.
pub fn rand_k_indices(len: usize, k: usize, rng: &mut Rng) -> Vec<u32> {
    let k = k.min(len);
    let mut pool: Vec<u32> = (0..len as u32).collect();
    for i in 0..k {
        let j = i + rng.below((len - i) as u32) as usize;
        pool.swap(i, j);
    }
    pool.truncate(k);
    pool.sort_unstable();
    pool
}

/// Error-feedback residual accumulator for one parameter group.
///
/// Protocol per step: [`accumulate`](Self::accumulate) folds the carried
/// residual into the fresh gradient, the caller selects coordinates on the
/// *accumulated* values, then [`retain`](Self::retain) zeroes the dropped
/// coordinates out of the gradient and stores them back as the next
/// residual. With `keep = all`, the residual stays zero and the gradient is
/// untouched — the degeneration pin.
#[derive(Debug, Clone)]
pub struct ErrorFeedback {
    residual: Vec<f32>,
}

impl ErrorFeedback {
    pub fn new(len: usize) -> Self {
        ErrorFeedback { residual: vec![0.0; len] }
    }

    /// `grad += residual` (element-wise).
    pub fn accumulate(&mut self, grad: &mut [f32]) {
        debug_assert_eq!(grad.len(), self.residual.len());
        for (g, r) in grad.iter_mut().zip(self.residual.iter()) {
            *g += *r;
        }
    }

    /// Keep only `keep_sorted` coordinates of `grad`; dropped coordinates are
    /// zeroed and become the new residual. `keep_sorted` must be ascending.
    pub fn retain(&mut self, grad: &mut [f32], keep_sorted: &[u32]) {
        debug_assert_eq!(grad.len(), self.residual.len());
        let mut keep = keep_sorted.iter().copied().peekable();
        for (i, (g, r)) in grad.iter_mut().zip(self.residual.iter_mut()).enumerate() {
            if keep.peek() == Some(&(i as u32)) {
                keep.next();
                *r = 0.0;
            } else {
                *r = *g;
                *g = 0.0;
            }
        }
    }

    /// Squared norm of the carried residual (telemetry / tests).
    pub fn residual_norm_sq(&self) -> f64 {
        self.residual.iter().map(|&r| (r as f64).powi(2)).sum()
    }

    /// The carried residual (checkpoint export).
    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    /// Replace the carried residual (checkpoint restore). The length must
    /// match the group this accumulator was built for.
    pub fn set_residual(&mut self, r: &[f32]) {
        assert_eq!(r.len(), self.residual.len(), "residual length mismatch");
        self.residual.copy_from_slice(r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{forall, gen};

    #[test]
    fn codec_ids_round_trip_from_str() {
        for c in Codec::ALL {
            assert_eq!(c.id().parse::<Codec>().unwrap(), c);
        }
        for m in GradMode::ALL {
            assert_eq!(m.id().parse::<GradMode>().unwrap(), m);
        }
        assert!("gzip".parse::<Codec>().is_err());
        assert!("topj".parse::<GradMode>().is_err());
    }

    #[test]
    fn payload_bytes_match_the_wire_format() {
        let int8 = BlockCodec::new(WireCodec::Int8, 128);
        // d=100: one block → 100 + 8 header = 108 (3.70x under 400 raw).
        assert_eq!(int8.row_payload_bytes(100), 108);
        // d=602: 5 blocks → 602 + 40 = 642 (3.75x under 2408 raw).
        assert_eq!(int8.row_payload_bytes(602), 642);
        // Non-divisible tail still pays a full header.
        assert_eq!(int8.row_payload_bytes(129), 129 + 16);
        let f16 = BlockCodec::new(WireCodec::F16, 128);
        assert_eq!(f16.row_payload_bytes(100), 200);
        assert_eq!(f16.row_payload_bytes(0), 0);
    }

    #[test]
    fn f16_round_trip_is_exact_for_representable_values() {
        for x in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 6.103515625e-5] {
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            assert_eq!(x.to_bits(), y.to_bits(), "x={x}");
        }
    }

    #[test]
    fn f16_saturates_instead_of_overflowing() {
        for x in [1.0e5f32, -1.0e5, 7.0e4, f32::MAX, f32::MIN] {
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            assert!(y.is_finite(), "x={x} -> {y}");
            assert_eq!(y.abs(), 65504.0, "x={x} -> {y}");
            assert_eq!(y.is_sign_negative(), x.is_sign_negative());
        }
    }

    #[test]
    fn f16_matches_reference_bit_patterns() {
        // Spot-check against the IEEE 754 binary16 table.
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF);
        assert_eq!(f32_to_f16_bits(6.103515625e-5), 0x0400); // smallest normal
        assert_eq!(f32_to_f16_bits(5.960464477539063e-8), 0x0001); // smallest subnormal
        assert_eq!(f16_bits_to_f32(0x0001), 5.960464477539063e-8);
        // Round-to-nearest-even at a midpoint: 1 + 2^-11 is exactly between
        // 0x3C00 and 0x3C01 → even (0x3C00).
        assert_eq!(f32_to_f16_bits(1.0 + 2f32.powi(-11)), 0x3C00);
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 * 2f32.powi(-11)), 0x3C02);
    }

    #[test]
    fn prop_f16_relative_error_bounded() {
        // Normal-range magnitudes: relative error ≤ 2^-11 (half-ulp of a
        // 10-bit mantissa).
        forall(
            0xF16,
            500,
            |r| {
                let mag = gen::f64_in(r, -4.0, 4.0); // 1e-4 .. 1e4
                let sign = if r.below(2) == 0 { 1.0 } else { -1.0 };
                (sign * 10f64.powf(mag)) as f32
            },
            |&x| {
                let y = f16_bits_to_f32(f32_to_f16_bits(x));
                let rel = ((x as f64 - y as f64) / x as f64).abs();
                if rel <= 1.0 / 2048.0 {
                    Ok(())
                } else {
                    Err(format!("rel error {rel} for {x} -> {y}"))
                }
            },
        );
    }

    #[test]
    fn prop_f16_never_produces_nan_or_inf_from_finite() {
        forall(
            0xF17,
            500,
            |r| (gen::f64_in(r, -1.0, 1.0) * 1.0e6) as f32,
            |&x| {
                let y = f16_bits_to_f32(f32_to_f16_bits(x));
                if y.is_finite() { Ok(()) } else { Err(format!("{x} -> {y}")) }
            },
        );
    }

    #[test]
    fn prop_int8_error_bounded_by_half_scale() {
        // Random rows with random block sizes, including non-divisible
        // lengths: every element's round-trip error ≤ scale/2 of its block
        // (plus float-arithmetic slack).
        forall(
            0x1278,
            300,
            |r| {
                let len = gen::usize_in(r, 1, 300);
                let block = gen::usize_in(r, 1, 200);
                let lo = gen::f64_in(r, -100.0, 100.0);
                let span = gen::f64_in(r, 0.0, 50.0);
                let row =
                    gen::vec_of(r, len, |r| (lo + gen::f64_in(r, 0.0, 1.0) * span) as f32);
                (row, block)
            },
            |(row, block)| {
                let codec = BlockCodec::new(WireCodec::Int8, *block as u32);
                let mut rt = row.clone();
                codec.round_trip(&mut rt);
                for (chunk, rt_chunk) in row.chunks(*block).zip(rt.chunks(*block)) {
                    let lo = chunk.iter().copied().fold(f32::INFINITY, f32::min);
                    let hi = chunk.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                    let scale = ((hi - lo) / 255.0) as f64;
                    let bound = 0.5 * scale * 1.001 + 1e-4;
                    for (&x, &y) in chunk.iter().zip(rt_chunk) {
                        let err = (x as f64 - y as f64).abs();
                        if err > bound {
                            return Err(format!("err {err} > bound {bound} (scale {scale})"));
                        }
                        if !y.is_finite() {
                            return Err(format!("non-finite round-trip {y}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn int8_all_equal_block_is_exact() {
        let codec = BlockCodec::new(WireCodec::Int8, 64);
        let mut row = vec![3.25f32; 100];
        let se = codec.round_trip(&mut row);
        assert_eq!(se, 0.0);
        assert!(row.iter().all(|&x| x == 3.25));
    }

    #[test]
    fn prop_round_trip_is_deterministic_and_blockwise() {
        // Quantizing a long row equals quantizing its blocks separately —
        // the invariance that makes windowed pulls and thread splits agree.
        forall(
            0xB10C,
            200,
            |r| {
                let block = gen::usize_in(r, 1, 64);
                let len = gen::usize_in(r, 1, 256);
                let row = gen::vec_of(r, len, |r| (gen::f64_in(r, -10.0, 10.0)) as f32);
                (row, block)
            },
            |(row, block)| {
                let codec = BlockCodec::new(WireCodec::Int8, *block as u32);
                let mut a = row.clone();
                let mut b = row.clone();
                codec.round_trip(&mut a);
                codec.round_trip(&mut b);
                if a != b {
                    return Err("round trip not deterministic".into());
                }
                let mut piecewise = row.clone();
                let mut se = 0.0;
                for chunk in piecewise.chunks_mut(*block) {
                    se += codec.round_trip(chunk);
                }
                let _ = se;
                if piecewise != a {
                    return Err("blockwise split changed the result".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn top_k_picks_largest_with_index_tie_break() {
        let v = [1.0f32, -3.0, 2.0, 3.0, 0.5];
        assert_eq!(top_k_indices(&v, 2), vec![1, 3]); // |−3| ties |3| → lower index first
        assert_eq!(top_k_indices(&v, 3), vec![1, 2, 3]);
        assert_eq!(top_k_indices(&v, 0), Vec::<u32>::new());
        assert_eq!(top_k_indices(&v, 10), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn rand_k_is_distinct_sorted_and_seeded() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let ka = rand_k_indices(100, 10, &mut a);
        let kb = rand_k_indices(100, 10, &mut b);
        assert_eq!(ka, kb);
        assert_eq!(ka.len(), 10);
        assert!(ka.windows(2).all(|w| w[0] < w[1]), "sorted & distinct: {ka:?}");
        assert!(ka.iter().all(|&i| i < 100));
    }

    #[test]
    fn keep_count_rounds_up_and_clamps() {
        assert_eq!(keep_count(100, 0.1), 10);
        assert_eq!(keep_count(101, 0.1), 11);
        assert_eq!(keep_count(5, 0.0), 0);
        assert_eq!(keep_count(0, 0.5), 0);
        assert_eq!(keep_count(3, 1e-9), 1);
        assert_eq!(keep_count(3, 2.0), 3);
    }

    #[test]
    fn error_feedback_conserves_gradient_mass() {
        let mut fb = ErrorFeedback::new(4);
        let mut g = vec![1.0f32, -2.0, 0.5, 4.0];
        fb.accumulate(&mut g);
        let keep = top_k_indices(&g, 2); // keeps 1 and 3
        fb.retain(&mut g, &keep);
        assert_eq!(g, vec![0.0, -2.0, 0.0, 4.0]);
        assert_eq!(fb.residual_norm_sq(), 1.0 + 0.25);
        // Next step: residual folds back in.
        let mut g2 = vec![0.0f32; 4];
        fb.accumulate(&mut g2);
        assert_eq!(g2, vec![1.0, 0.0, 0.5, 0.0]);
        fb.retain(&mut g2, &[0, 1, 2, 3]);
        assert_eq!(fb.residual_norm_sq(), 0.0);
    }

    #[test]
    fn error_feedback_keep_all_is_identity() {
        let mut fb = ErrorFeedback::new(3);
        let mut g = vec![0.5f32, -1.5, 2.5];
        let orig = g.clone();
        fb.accumulate(&mut g);
        fb.retain(&mut g, &[0, 1, 2]);
        assert_eq!(g, orig);
        assert_eq!(fb.residual_norm_sq(), 0.0);
    }
}
