//! Self-cleaning temporary directories (tempfile stand-in).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A temporary directory removed on drop.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create a fresh unique directory under the system temp dir.
    #[allow(clippy::disallowed_methods)] // wall-clock uniqueness for leaked-dir hygiene only
    pub fn new(prefix: &str) -> std::io::Result<TempDir> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "rapidgnn-{prefix}-{}-{}-{n}",
            std::process::id(),
            // time-based component so leaked dirs from killed processes
            // don't collide across runs
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos() as u64)
                .unwrap_or(0)
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    /// Directory path.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let p;
        {
            let d = TempDir::new("t").unwrap();
            p = d.path().to_path_buf();
            assert!(p.is_dir());
            std::fs::write(p.join("f.txt"), "x").unwrap();
        }
        assert!(!p.exists(), "directory removed on drop");
    }

    #[test]
    fn unique_paths() {
        let a = TempDir::new("u").unwrap();
        let b = TempDir::new("u").unwrap();
        assert_ne!(a.path(), b.path());
    }
}
