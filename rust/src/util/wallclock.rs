//! The sanctioned wall-clock module (§Determinism contracts).
//!
//! Simulated runs live entirely on `sim::cluster`'s virtual clock; the only
//! places allowed to read the host's wall clock are this module, the bench
//! harness ([`crate::util::bench`]), and tempdir uniqueness
//! ([`crate::util::tempdir`]). Everything else is rejected by
//! `rapidgnn-lint`'s `wall-clock` rule and clippy's disallowed-methods
//! list, because a stray `Instant::now()` in a priced path silently turns
//! a byte-stable virtual-time report into a host-load-dependent one.
//!
//! [`Stopwatch`] is the narrow doorway: full (real-execution) mode uses it
//! to measure compute wall time that is *reported* but never fed back into
//! scheduling, pricing, or any serialized ordering decision. Keep it that
//! way — a measurement may describe a run, it must not steer one.

use std::time::Instant;

/// A started wall-clock timer; read with [`Stopwatch::elapsed_sec`].
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    t0: Instant,
}

impl Stopwatch {
    /// Start timing now.
    #[allow(clippy::disallowed_methods)] // this module IS the wall-clock allowlist
    pub fn start() -> Stopwatch {
        Stopwatch { t0: Instant::now() }
    }

    /// Seconds elapsed since [`Stopwatch::start`].
    pub fn elapsed_sec(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_is_monotone_nonnegative() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_sec();
        let b = sw.elapsed_sec();
        assert!(a >= 0.0);
        assert!(b >= a);
    }
}
