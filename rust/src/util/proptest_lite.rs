//! Minimal property-test driver (proptest stand-in).
//!
//! Runs a property over `n` randomly generated cases from a deterministic
//! seed; on failure, panics with the failing case's debug representation and
//! the case index so the exact input can be reproduced.

use crate::sampler::seed::Rng;

/// Run `prop(case)` for `n` cases drawn by `gen(rng)`.
///
/// Deterministic: case `i` for a given `seed` is always the same, so failures
/// are reproducible by seed alone.
pub fn forall<T: std::fmt::Debug, G, P>(seed: u64, n: u32, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for i in 0..n {
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            panic!("property failed at case {i} (seed {seed}): {msg}\ninput: {case:#?}");
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::sampler::seed::Rng;

    /// Uniform usize in `[lo, hi]`.
    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below((hi - lo + 1) as u32) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
        lo + rng.f64() * (hi - lo)
    }

    /// Vector of length `len` with elements from `f`.
    pub fn vec_of<T>(rng: &mut Rng, len: usize, mut f: impl FnMut(&mut Rng) -> T) -> Vec<T> {
        (0..len).map(|_| f(rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        forall(1, 100, |r| r.below(100), |&x| {
            if x < 100 {
                Ok(())
            } else {
                Err(format!("{x} >= 100"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_with_case_report() {
        forall(2, 100, |r| r.below(10), |&x| {
            if x != 7 {
                Ok(())
            } else {
                Err("hit 7".into())
            }
        });
    }

    #[test]
    fn generators_in_range() {
        let mut rng = crate::sampler::seed::Rng::new(3);
        for _ in 0..1000 {
            let u = gen::usize_in(&mut rng, 5, 9);
            assert!((5..=9).contains(&u));
            let f = gen::f64_in(&mut rng, -1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
        let v = gen::vec_of(&mut rng, 7, |r| r.below(3));
        assert_eq!(v.len(), 7);
    }
}
