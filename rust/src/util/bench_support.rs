//! Shared configuration for the paper-reproduction benches
//! (`rust/benches/*` regenerate every table and figure in the evaluation).
//!
//! The paper runs Reddit/OGBN-Products/OGBN-Papers100M on a 4-machine
//! cluster at batch sizes 1000–3000. Our synthetic datasets are scaled down
//! (DESIGN.md §3), so two knobs keep the paper's batch sizes meaningful:
//!
//! - `RAPIDGNN_BENCH_SCALE` (default 1.0) scales dataset node counts;
//! - the train fraction is raised on products/papers so each worker still
//!   runs ≥ a handful of batches per epoch at batch 1000–3000 (the real
//!   OGBN splits are tiny fractions of graphs 20–450× larger than ours).
//!
//! Both substitutions are recorded per-experiment in EXPERIMENTS.md.

use crate::config::{DatasetConfig, DatasetPreset, Engine, RunConfig};

/// Paper batch sizes (Table 2 / Figs 4–5).
pub const PAPER_BATCHES: [u32; 3] = [1000, 2000, 3000];

/// Dataset scale factor from the environment (default 1.0).
pub fn bench_scale() -> f64 {
    std::env::var("RAPIDGNN_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

/// Bench dataset config: preset scaled, with the train fraction raised so
/// paper-scale batch sizes produce multi-batch epochs per worker.
pub fn bench_dataset(preset: DatasetPreset) -> DatasetConfig {
    let mut ds = DatasetConfig::preset(preset, bench_scale());
    ds.train_fraction = match preset {
        DatasetPreset::RedditSim => 0.66, // paper-like: Reddit's split is large
        DatasetPreset::ProductsSim => 0.40,
        DatasetPreset::PapersSim => 0.25,
        DatasetPreset::Tiny => ds.train_fraction,
    };
    ds
}

/// The paper's Table-2 run configuration for (dataset, engine, batch).
pub fn paper_run(preset: DatasetPreset, engine: Engine, batch_size: u32) -> RunConfig {
    RunConfig {
        dataset: bench_dataset(preset),
        engine,
        num_workers: 4,
        batch_size,
        fanout: vec![10, 25],
        epochs: 4, // paper trains 10; 4 is past the cache-warm steady state
        // Cache sized at each dataset's Fig-5 diminishing-returns knee,
        // proportional to its per-epoch distinct remote set (the paper does
        // not state n_hot; its Fig-5 sweep flattens at the equivalent
        // point). Worst memory: 48k × d=128 × f32 × 2 buffers ≈ 49 MB.
        n_hot: match preset {
            DatasetPreset::RedditSim => 14_000,
            DatasetPreset::ProductsSim => 32_000,
            _ => 48_000,
        },
        prefetch_q: 4,
        ..Default::default()
    }
}

/// Hot-set sizes swept in Fig 5.
pub const FIG5_CACHE_SIZES: [u32; 8] = [1, 1_000, 2_000, 4_000, 8_000, 16_000, 32_000, 64_000];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_run_validates_for_all_cells() {
        for preset in DatasetPreset::PAPER {
            for engine in Engine::ALL {
                for b in PAPER_BATCHES {
                    paper_run(preset, engine, b).validate().unwrap();
                }
            }
        }
    }

    #[test]
    fn bench_datasets_have_multiple_batches_per_worker() {
        for preset in DatasetPreset::PAPER {
            let cfg = paper_run(preset, Engine::Rapid, 3000);
            let approx_train =
                (cfg.dataset.num_nodes as f64 * cfg.dataset.train_fraction) as u32;
            let per_worker = approx_train / cfg.num_workers;
            assert!(
                per_worker / 3000 >= 2,
                "{}: only {} seeds/worker at batch 3000",
                cfg.dataset.name,
                per_worker
            );
        }
    }
}
