//! Fast hashing for u32-keyed maps (§Perf).
//!
//! The coordinator's hot maps (cache index, frequency tallies, block
//! position maps) key on dense-ish `u32` node ids; std's SipHash costs more
//! than the probe itself. This single-multiply finalizer (a 64-bit
//! multiply-xor of the Fibonacci constant — the splitmix64 tail) keeps full
//! avalanche on 32-bit keys at ~1 ns/hash.

use std::hash::{BuildHasherDefault, Hasher};

/// Hasher specialized for one `write_u32`/`write_u64` call.
#[derive(Default)]
pub struct IdHasher {
    state: u64,
}

impl Hasher for IdHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    fn write(&mut self, bytes: &[u8]) {
        // generic fallback (unused on the hot paths)
        for &b in bytes {
            self.state = (self.state ^ b as u64).wrapping_mul(0x100000001B3);
        }
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.write_u64(v as u64);
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        let mut z = v.wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        self.state = z ^ (z >> 31);
    }
}

/// `HashMap` with the id hasher — the one sanctioned std-hash-map spelling
/// in the tree. Contract (enforced by `rapidgnn-lint` and clippy's
/// disallowed-types list): use it for lookup-only hot paths; its iteration
/// order is deterministic per-build but unsorted, so it must never feed a
/// serde/telemetry boundary without an intervening sort.
#[allow(clippy::disallowed_types)] // the deterministic-hasher alias lives here by contract
pub type IdHashMap<K, V> = std::collections::HashMap<K, V, BuildHasherDefault<IdHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works_like_std() {
        let mut m: IdHashMap<u32, u32> = IdHashMap::default();
        for i in 0..10_000u32 {
            m.insert(i * 3, i);
        }
        assert_eq!(m.len(), 10_000);
        for i in 0..10_000u32 {
            assert_eq!(m[&(i * 3)], i);
            assert!(!m.contains_key(&(i * 3 + 1)));
        }
    }

    #[test]
    fn hash_differs_across_keys() {
        use std::hash::Hash;
        let h = |v: u32| {
            let mut hh = IdHasher::default();
            v.hash(&mut hh);
            hh.finish()
        };
        let mut seen = std::collections::BTreeSet::new();
        for i in 0..100_000u32 {
            assert!(seen.insert(h(i)), "collision at {i}");
        }
    }
}
