//! Dependency-free infrastructure substrates.
//!
//! This build environment is fully offline, so the usual ecosystem crates
//! (serde, toml, crossbeam, rayon, criterion, tempfile…) are unavailable.
//! Everything the framework needs is implemented here, tested like any other
//! module:
//!
//! - [`value`] — a dynamic value tree with JSON and TOML-subset
//!   serialization/parsing (config files + bench artifacts).
//! - [`mpmc`] — bounded multi-producer multi-consumer ring (the paper's
//!   Sampler→Prefetcher→Trainer queues).
//! - [`parallel`] — scoped data-parallel helpers over std threads.
//! - [`tempdir`] — self-cleaning temporary directories for tests/benches.
//! - [`bench`] — timing + table formatting harness used by every
//!   `rust/benches/*` binary.
//! - [`proptest_lite`] — randomized property-test driver with failure-case
//!   reporting.
//! - [`wallclock`] — the sanctioned wall-clock doorway ([`wallclock::Stopwatch`]);
//!   every other module is virtual-time only (enforced by `rapidgnn-lint`).

pub mod bench;
pub mod bench_support;
pub mod fasthash;
pub mod mpmc;
pub mod parallel;
pub mod proptest_lite;
pub mod tempdir;
pub mod value;
pub mod wallclock;
