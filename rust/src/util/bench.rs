//! Benchmark harness: timing, repetition, and paper-style table output
//! (criterion stand-in, tuned for regenerating the paper's tables/figures).

use std::time::Instant;

/// Measure `f`'s wall time over `reps` repetitions; returns (mean, min) secs.
#[allow(clippy::disallowed_methods)] // bench harness: wall-clock reads are the point here
pub fn time_reps<F: FnMut()>(reps: u32, mut f: F) -> (f64, f64) {
    assert!(reps >= 1);
    let mut total = 0.0;
    let mut min = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        min = min.min(dt);
    }
    (total / reps as f64, min)
}

/// Throughput-style measurement: run `f` until `min_time` seconds elapse,
/// return (iterations, elapsed, per-iter seconds).
#[allow(clippy::disallowed_methods)] // bench harness: wall-clock reads are the point here
pub fn time_until<F: FnMut()>(min_time: f64, mut f: F) -> (u64, f64, f64) {
    let t0 = Instant::now();
    let mut iters = 0u64;
    loop {
        f();
        iters += 1;
        let dt = t0.elapsed().as_secs_f64();
        if dt >= min_time {
            return (iters, dt, dt / iters as f64);
        }
    }
}

/// A fixed-width text table, printed like the paper's result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells.to_vec());
        self
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, &w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds human-readably (µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// Format bytes human-readably (KB/MB/GB, decimal as the paper uses).
pub fn fmt_bytes(b: f64) -> String {
    if b < 1e3 {
        format!("{b:.0}B")
    } else if b < 1e6 {
        format!("{:.1}KB", b / 1e3)
    } else if b < 1e9 {
        format!("{:.1}MB", b / 1e6)
    } else {
        format!("{:.2}GB", b / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_reps_positive() {
        let (mean, min) = time_reps(3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(mean >= min);
        assert!(min >= 0.0);
    }

    #[test]
    fn time_until_runs_long_enough() {
        let (iters, elapsed, per) = time_until(0.01, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(elapsed >= 0.01);
        assert!(iters >= 1);
        assert!((per - elapsed / iters as f64).abs() < 1e-12);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["name", "x"]);
        t.row(&["a".into(), "1.00".into()]);
        t.row(&["longer".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("Demo"));
        assert!(r.contains("longer"));
        let lines: Vec<&str> = r.lines().filter(|l| !l.is_empty()).collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_secs(0.5e-4), "50.0µs");
        assert_eq!(fmt_secs(0.05), "50.00ms");
        assert_eq!(fmt_secs(2.0), "2.00s");
        assert_eq!(fmt_bytes(500.0), "500B");
        assert_eq!(fmt_bytes(34.45e6), "34.5MB");
        assert_eq!(fmt_bytes(5.18e9), "5.18GB");
    }
}
