//! Bounded multi-producer multi-consumer ring queue.
//!
//! The paper's Sampler→Prefetcher and Prefetcher→Trainer links are "lock-free
//! multi-producer, multi-consumer (MPMC) rings" (§4). This implementation is
//! a Mutex+Condvar ring — at the queue depths involved (Q ≤ 32, thousands of
//! ops/second) lock contention is unmeasurable, and the *semantics* the paper
//! relies on are fully reproduced: bounded capacity, producer blocking when
//! full (backpressure: "stalls only when the Trainer lags"), consumer
//! blocking when empty, and clean disconnect on either side.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

struct Inner<T> {
    q: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
}

struct State<T> {
    buf: VecDeque<T>,
    cap: usize,
    senders: usize,
    receivers: usize,
}

/// Sending half. Cloneable (multi-producer).
pub struct Sender<T>(Arc<Inner<T>>);

/// Receiving half. Cloneable (multi-consumer).
pub struct Receiver<T>(Arc<Inner<T>>);

/// Error returned when the other side has disconnected.
#[derive(Debug, PartialEq, Eq)]
pub struct Disconnected;

/// Create a bounded MPMC channel of capacity `cap` (≥ 1).
pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    assert!(cap >= 1, "capacity must be >= 1");
    let inner = Arc::new(Inner {
        q: Mutex::new(State {
            buf: VecDeque::with_capacity(cap),
            cap,
            senders: 1,
            receivers: 1,
        }),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
    });
    (Sender(inner.clone()), Receiver(inner))
}

impl<T> Sender<T> {
    /// Blocking send; returns `Err` if all receivers are gone.
    pub fn send(&self, v: T) -> Result<(), Disconnected> {
        let mut st = self.0.q.lock().unwrap();
        loop {
            if st.receivers == 0 {
                return Err(Disconnected);
            }
            if st.buf.len() < st.cap {
                st.buf.push_back(v);
                self.0.not_empty.notify_one();
                return Ok(());
            }
            st = self.0.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking send; `Err(Some(v))` when full, `Err(None)` when
    /// disconnected.
    pub fn try_send(&self, v: T) -> Result<(), Option<T>> {
        let mut st = self.0.q.lock().unwrap();
        if st.receivers == 0 {
            return Err(None);
        }
        if st.buf.len() < st.cap {
            st.buf.push_back(v);
            self.0.not_empty.notify_one();
            Ok(())
        } else {
            Err(Some(v))
        }
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `Err` once the queue is empty *and* all senders are
    /// gone.
    pub fn recv(&self) -> Result<T, Disconnected> {
        let mut st = self.0.q.lock().unwrap();
        loop {
            if let Some(v) = st.buf.pop_front() {
                self.0.not_full.notify_one();
                return Ok(v);
            }
            if st.senders == 0 {
                return Err(Disconnected);
            }
            st = self.0.not_empty.wait(st).unwrap();
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.0.q.lock().unwrap();
        let v = st.buf.pop_front();
        if v.is_some() {
            self.0.not_full.notify_one();
        }
        v
    }

    /// Current queue depth (diagnostics).
    pub fn len(&self) -> usize {
        self.0.q.lock().unwrap().buf.len()
    }

    /// Whether the queue is currently empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.0.q.lock().unwrap().senders += 1;
        Sender(self.0.clone())
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.0.q.lock().unwrap().receivers += 1;
        Receiver(self.0.clone())
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.0.q.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            self.0.not_empty.notify_all();
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut st = self.0.q.lock().unwrap();
        st.receivers -= 1;
        if st.receivers == 0 {
            self.0.not_full.notify_all();
        }
    }
}

#[cfg(test)]
// Tests exercise the ring with raw OS threads on purpose: the queue *is* the
// sanctioned concurrency primitive, so its own suite spawns directly.
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    /// xorshift64* — deterministic per-thread jitter source for the
    /// seeded-interleaving tests (loom is not vendorable offline, so we
    /// perturb real schedules reproducibly instead).
    struct XorShift(u64);

    impl XorShift {
        fn new(seed: u64) -> XorShift {
            XorShift(seed.max(1))
        }

        fn next(&mut self) -> u64 {
            let mut x = self.0;
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            self.0 = x;
            x.wrapping_mul(0x2545F4914F6CDD1D)
        }

        /// Yield the scheduler 0–3 times, seed-determined.
        fn jitter(&mut self) {
            for _ in 0..(self.next() % 4) {
                thread::yield_now();
            }
        }
    }

    #[test]
    fn fifo_order_single_thread() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(rx.recv().unwrap(), i);
        }
    }

    #[test]
    fn capacity_enforced_try_send() {
        let (tx, rx) = bounded(2);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        assert_eq!(tx.try_send(3), Err(Some(3)));
        rx.try_recv().unwrap();
        tx.try_send(3).unwrap();
    }

    #[test]
    fn recv_errors_after_all_senders_drop() {
        let (tx, rx) = bounded::<i32>(2);
        tx.send(1).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.recv(), Err(Disconnected));
    }

    #[test]
    fn send_errors_after_all_receivers_drop() {
        let (tx, rx) = bounded::<i32>(2);
        drop(rx);
        assert_eq!(tx.send(1), Err(Disconnected));
    }

    #[test]
    fn blocked_sender_unblocks_on_recv() {
        let (tx, rx) = bounded(1);
        tx.send(0).unwrap();
        let h = thread::spawn(move || tx.send(1));
        thread::sleep(Duration::from_millis(30));
        assert_eq!(rx.recv().unwrap(), 0);
        assert_eq!(rx.recv().unwrap(), 1);
        h.join().unwrap().unwrap();
    }

    #[test]
    fn blocked_sender_unblocks_on_receiver_drop() {
        let (tx, rx) = bounded(1);
        tx.send(0).unwrap();
        let h = thread::spawn(move || tx.send(1));
        thread::sleep(Duration::from_millis(30));
        drop(rx);
        assert_eq!(h.join().unwrap(), Err(Disconnected));
    }

    #[test]
    fn mpmc_all_items_delivered_exactly_once() {
        const PRODUCERS: usize = 4;
        const CONSUMERS: usize = 3;
        const PER: usize = 500;
        let (tx, rx) = bounded::<usize>(7);
        let mut handles = Vec::new();
        for p in 0..PRODUCERS {
            let tx = tx.clone();
            handles.push(thread::spawn(move || {
                for i in 0..PER {
                    tx.send(p * PER + i).unwrap();
                }
            }));
        }
        drop(tx);
        let mut consumers = Vec::new();
        for _ in 0..CONSUMERS {
            let rx = rx.clone();
            consumers.push(thread::spawn(move || {
                let mut got = Vec::new();
                while let Ok(v) = rx.recv() {
                    got.push(v);
                }
                got
            }));
        }
        drop(rx);
        for h in handles {
            h.join().unwrap();
        }
        let mut all: Vec<usize> = consumers
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..PRODUCERS * PER).collect::<Vec<_>>());
    }

    #[test]
    fn len_reports_depth() {
        let (tx, rx) = bounded(4);
        assert!(rx.is_empty());
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(rx.len(), 2);
    }

    #[test]
    fn empty_full_boundary_cycles() {
        // Walk the cap-1 ring across its empty↔full boundary many times;
        // every transition must be observable through try_send/try_recv.
        let (tx, rx) = bounded::<u32>(1);
        for i in 0..1_000 {
            assert!(rx.is_empty());
            assert_eq!(rx.try_recv(), None, "empty ring must not yield");
            tx.try_send(i).unwrap();
            assert_eq!(rx.len(), 1);
            assert_eq!(tx.try_send(i + 1), Err(Some(i + 1)), "full ring must refuse");
            assert_eq!(rx.try_recv(), Some(i));
        }
        assert!(rx.is_empty());
    }

    #[test]
    fn depth_never_exceeds_capacity_under_stress() {
        const CAP: usize = 3;
        let (tx, rx) = bounded::<u64>(CAP);
        let mut producers = Vec::new();
        for p in 0..4u64 {
            let tx = tx.clone();
            producers.push(thread::spawn(move || {
                let mut rng = XorShift::new(0xC0FFEE ^ p);
                for i in 0..400u64 {
                    rng.jitter();
                    tx.send(p * 400 + i).unwrap();
                }
            }));
        }
        drop(tx);
        // Sample the depth concurrently with the producers; the bound must
        // hold at every observation point, not just at quiescence.
        let mut received = 0usize;
        let mut rng = XorShift::new(0xDEAD);
        loop {
            assert!(rx.len() <= CAP, "depth {} exceeds capacity {CAP}", rx.len());
            rng.jitter();
            match rx.try_recv() {
                Some(_) => received += 1,
                None => match rx.recv() {
                    Ok(_) => received += 1,
                    Err(Disconnected) => break,
                },
            }
        }
        for h in producers {
            h.join().unwrap();
        }
        assert_eq!(received, 4 * 400);
    }

    #[test]
    fn per_producer_fifo_holds_across_consumers() {
        // Linearizability check: items are tagged (producer, seq). Whatever
        // the interleaving, each consumer's stream must contain any single
        // producer's items as a strictly increasing subsequence — the ring
        // may interleave producers but can never reorder one producer.
        for seed in [1u64, 7, 42, 0xFEED] {
            const PRODUCERS: u64 = 3;
            const CONSUMERS: usize = 3;
            const PER: u64 = 300;
            let (tx, rx) = bounded::<(u64, u64)>(4);
            let mut producers = Vec::new();
            for p in 0..PRODUCERS {
                let tx = tx.clone();
                producers.push(thread::spawn(move || {
                    let mut rng = XorShift::new(seed ^ (p << 32));
                    for i in 0..PER {
                        rng.jitter();
                        tx.send((p, i)).unwrap();
                    }
                }));
            }
            drop(tx);
            let mut consumers = Vec::new();
            for c in 0..CONSUMERS {
                let rx = rx.clone();
                consumers.push(thread::spawn(move || {
                    let mut rng = XorShift::new(seed ^ ((c as u64) << 16));
                    let mut got = Vec::new();
                    while let Ok(v) = rx.recv() {
                        rng.jitter();
                        got.push(v);
                    }
                    got
                }));
            }
            drop(rx);
            for h in producers {
                h.join().unwrap();
            }
            let streams: Vec<Vec<(u64, u64)>> =
                consumers.into_iter().map(|h| h.join().unwrap()).collect();
            let mut total = 0;
            for stream in &streams {
                total += stream.len();
                for p in 0..PRODUCERS {
                    let seqs: Vec<u64> =
                        stream.iter().filter(|&&(sp, _)| sp == p).map(|&(_, i)| i).collect();
                    assert!(
                        seqs.windows(2).all(|w| w[0] < w[1]),
                        "seed {seed}: producer {p} reordered within a consumer: {seqs:?}"
                    );
                }
            }
            assert_eq!(total, (PRODUCERS * PER) as usize, "seed {seed}: items lost or duplicated");
        }
    }

    #[test]
    fn transport_shaped_payload_stress() {
        // The `net::transport::ShmRings` wire shape: producers are pullers
        // shipping feature-row payloads (`Vec<u8>`, 400 B = 100 × f32 rows),
        // consumers are shard servers draining a small bounded ring. The
        // payload bytes encode (producer, seq) so corruption, loss,
        // duplication, and per-producer reordering are all distinguishable.
        // Runs under the tsan job alongside the transport suite.
        for seed in [5u64, 0xBEEF] {
            const PRODUCERS: u64 = 4;
            const CONSUMERS: usize = 2;
            const PER: u64 = 200;
            const ROW_BYTES: usize = 400;
            let (tx, rx) = bounded::<Vec<u8>>(4);
            let mut producers = Vec::new();
            for p in 0..PRODUCERS {
                let tx = tx.clone();
                producers.push(thread::spawn(move || {
                    let mut rng = XorShift::new(seed ^ (p << 40));
                    for i in 0..PER {
                        rng.jitter();
                        let mut payload = vec![0u8; ROW_BYTES];
                        payload[..8].copy_from_slice(&p.to_le_bytes());
                        payload[8..16].copy_from_slice(&i.to_le_bytes());
                        // Fill the body with a (p, i)-derived pattern so a
                        // torn or recycled buffer cannot masquerade as intact.
                        for (k, b) in payload[16..].iter_mut().enumerate() {
                            *b = (p as u8) ^ (i as u8) ^ (k as u8);
                        }
                        tx.send(payload).unwrap();
                    }
                }));
            }
            drop(tx);
            let mut consumers = Vec::new();
            for c in 0..CONSUMERS {
                let rx = rx.clone();
                consumers.push(thread::spawn(move || {
                    let mut rng = XorShift::new(seed ^ ((c as u64) << 24));
                    let mut got: Vec<(u64, u64)> = Vec::new();
                    while let Ok(payload) = rx.recv() {
                        rng.jitter();
                        assert_eq!(payload.len(), ROW_BYTES);
                        let p = u64::from_le_bytes(payload[..8].try_into().unwrap());
                        let i = u64::from_le_bytes(payload[8..16].try_into().unwrap());
                        for (k, &b) in payload[16..].iter().enumerate() {
                            assert_eq!(b, (p as u8) ^ (i as u8) ^ (k as u8), "torn payload");
                        }
                        got.push((p, i));
                    }
                    got
                }));
            }
            drop(rx);
            for h in producers {
                h.join().unwrap();
            }
            let streams: Vec<Vec<(u64, u64)>> =
                consumers.into_iter().map(|h| h.join().unwrap()).collect();
            let mut all: Vec<(u64, u64)> = Vec::new();
            for stream in &streams {
                for p in 0..PRODUCERS {
                    let seqs: Vec<u64> =
                        stream.iter().filter(|&&(sp, _)| sp == p).map(|&(_, i)| i).collect();
                    assert!(
                        seqs.windows(2).all(|w| w[0] < w[1]),
                        "seed {seed}: producer {p} reordered: {seqs:?}"
                    );
                }
                all.extend_from_slice(stream);
            }
            all.sort_unstable();
            let expect: Vec<(u64, u64)> =
                (0..PRODUCERS).flat_map(|p| (0..PER).map(move |i| (p, i))).collect();
            assert_eq!(all, expect, "seed {seed}: rows lost or duplicated");
        }
    }

    #[test]
    fn no_lost_wakeups_on_tiny_ring() {
        // The classic lost-wakeup shape: capacity 1 with 4 blocked producers
        // and 4 blocked consumers on each side of the boundary. If a wakeup
        // were ever dropped, a thread would block forever and the join below
        // would hang the test (caught by the harness timeout), so completing
        // at all *is* the assertion; exact delivery is checked on top.
        for seed in [3u64, 11, 0xB00E] {
            const SIDE: u64 = 4;
            const PER: u64 = 250;
            let (tx, rx) = bounded::<u64>(1);
            let mut producers = Vec::new();
            for p in 0..SIDE {
                let tx = tx.clone();
                producers.push(thread::spawn(move || {
                    let mut rng = XorShift::new(seed.wrapping_add(p));
                    for i in 0..PER {
                        rng.jitter();
                        tx.send(p * PER + i).unwrap();
                    }
                }));
            }
            drop(tx);
            let mut consumers = Vec::new();
            for c in 0..SIDE {
                let rx = rx.clone();
                consumers.push(thread::spawn(move || {
                    let mut rng = XorShift::new(seed.wrapping_mul(31).wrapping_add(c));
                    let mut got = Vec::new();
                    while let Ok(v) = rx.recv() {
                        rng.jitter();
                        got.push(v);
                    }
                    got
                }));
            }
            drop(rx);
            for h in producers {
                h.join().unwrap();
            }
            let mut all: Vec<u64> =
                consumers.into_iter().flat_map(|h| h.join().unwrap()).collect();
            all.sort_unstable();
            assert_eq!(all, (0..SIDE * PER).collect::<Vec<_>>(), "seed {seed}");
        }
    }
}
