//! Scoped data-parallel helpers over std threads (rayon stand-in).
//!
//! Every helper has a `*_threads` variant taking an explicit worker count —
//! the override hook the determinism identity tests use to compare the
//! serial reference (`threads = 1`, which runs inline on the caller) against
//! parallel execution at arbitrary thread counts. The unsuffixed forms
//! default to [`available_threads`].

/// Process disjoint mutable chunks of `data` on up to `threads` workers.
/// `f(chunk_index, chunk)` runs on a worker thread; chunking is by
/// `chunk_size` elements. `threads <= 1` (or a single chunk) runs inline on
/// the caller — the deterministic serial reference.
pub fn par_chunks_mut_threads<T: Send, F>(threads: usize, data: &mut [T], chunk_size: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Send + Sync,
{
    assert!(chunk_size > 0);
    if threads <= 1 || data.len() <= chunk_size {
        for (i, chunk) in data.chunks_mut(chunk_size).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let f = &f;
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk_size).enumerate().collect();
    let workers = threads.min(chunks.len());
    let work = std::sync::Mutex::new(chunks.into_iter());
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let next = work.lock().unwrap().next();
                match next {
                    Some((i, chunk)) => f(i, chunk),
                    None => break,
                }
            });
        }
    });
}

/// [`par_chunks_mut_threads`] at the machine's worker-thread count.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], chunk_size: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Send + Sync,
{
    par_chunks_mut_threads(available_threads(), data, chunk_size, f);
}

/// Map `f` over `0..n` on up to `threads` workers, returning results in
/// index order. The chunk size is computed once here; an element's index is
/// `chunk_index * chunk_size + offset`, with the chunk index taken from
/// [`par_chunks_mut_threads`] — never re-derived from the thread count.
/// Chunks are capped at 16 elements so the work queue can rebalance
/// variable-cost items (e.g. hub-heavy batches) instead of handing each
/// thread one monolithic chunk; the cap changes scheduling only, never
/// output, since indices derive from the chunk size alone.
pub fn par_map_threads<T: Send, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Send + Sync,
{
    let chunk_size = n.div_ceil(threads.max(1)).clamp(1, 16);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    par_chunks_mut_threads(threads, &mut out, chunk_size, |ci, chunk| {
        let base = ci * chunk_size;
        for (j, slot) in chunk.iter_mut().enumerate() {
            *slot = Some(f(base + j));
        }
    });
    out.into_iter().map(|o| o.expect("all slots filled")).collect()
}

/// [`par_map_threads`] at the machine's worker-thread count.
pub fn par_map<T: Send, F>(n: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Send + Sync,
{
    par_map_threads(available_threads(), n, f)
}

/// Spawn a named long-lived worker thread — the sanctioned doorway for the
/// few subsystems that need a resident thread rather than scoped fork/join
/// (today: the `net::transport::ShmRings` shard servers). Callers own the
/// returned handle and must join it; a worker that can outlive its owner
/// has no deterministic join order and belongs behind a `util::mpmc`
/// shutdown protocol instead.
pub fn spawn_worker<T, F>(name: &str, f: F) -> std::thread::JoinHandle<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    #[allow(clippy::disallowed_methods)] // the one sanctioned Builder::spawn: named, handle-owned
    std::thread::Builder::new()
        .name(name.to_string())
        .spawn(f)
        .unwrap_or_else(|e| panic!("spawn_worker({name}): {e}"))
}

/// Worker thread count (cores, capped at 16 — the workloads here are
/// memory-bound well before that). Overridable with `RAPIDGNN_THREADS`
/// (clamped to `1..=64`) for experiments and CI determinism sweeps.
pub fn available_threads() -> usize {
    if let Some(n) = std::env::var("RAPIDGNN_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
    {
        return n.clamp(1, 64);
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything() {
        let mut data = vec![0u32; 10_037];
        par_chunks_mut(&mut data, 64, |_, chunk| {
            for x in chunk {
                *x += 1;
            }
        });
        assert!(data.iter().all(|&x| x == 1));
    }

    #[test]
    fn chunk_index_is_correct() {
        let mut data = vec![0usize; 1000];
        par_chunks_mut(&mut data, 100, |i, chunk| {
            for x in chunk.iter_mut() {
                *x = i;
            }
        });
        for (j, &x) in data.iter().enumerate() {
            assert_eq!(x, j / 100);
        }
    }

    #[test]
    fn par_map_preserves_order() {
        let out = par_map(1000, |i| i * 3);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * 3);
        }
    }

    #[test]
    fn par_map_identical_at_any_thread_count() {
        let reference = par_map_threads(1, 1003, |i| i * 7 + 1);
        for threads in [2, 3, 8, 16] {
            let out = par_map_threads(threads, 1003, |i| i * 7 + 1);
            assert_eq!(out, reference, "threads {threads}");
        }
    }

    #[test]
    fn par_map_more_threads_than_items() {
        let out = par_map_threads(64, 5, |i| i);
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn par_map_empty() {
        let out: Vec<u8> = par_map(0, |_| 0);
        assert!(out.is_empty());
    }

    #[test]
    fn single_chunk_runs_inline() {
        let mut data = vec![5u8; 3];
        par_chunks_mut(&mut data, 100, |i, chunk| {
            assert_eq!(i, 0);
            chunk[0] = 9;
        });
        assert_eq!(data[0], 9);
    }

    #[test]
    fn spawn_worker_names_thread_and_returns_value() {
        let h = spawn_worker("test-worker", || {
            assert_eq!(std::thread::current().name(), Some("test-worker"));
            42u32
        });
        assert_eq!(h.join().unwrap(), 42);
    }

    #[test]
    fn serial_override_runs_inline_in_order() {
        // threads = 1 must process chunks sequentially on the caller thread.
        let tid = std::thread::current().id();
        let mut seen = std::sync::Mutex::new(Vec::new());
        let mut data = vec![0u8; 300];
        par_chunks_mut_threads(1, &mut data, 100, |i, _| {
            assert_eq!(std::thread::current().id(), tid);
            seen.lock().unwrap().push(i);
        });
        assert_eq!(*seen.get_mut().unwrap(), vec![0, 1, 2]);
    }
}
