//! Scoped data-parallel helpers over std threads (rayon stand-in).

/// Process disjoint mutable chunks of `data` in parallel. `f(chunk_index,
/// chunk)` runs on a worker thread; chunking is by `chunk_size` elements.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], chunk_size: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Send + Sync,
{
    assert!(chunk_size > 0);
    let threads = available_threads();
    if threads <= 1 || data.len() <= chunk_size {
        for (i, chunk) in data.chunks_mut(chunk_size).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let f = &f;
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk_size).enumerate().collect();
    let work = std::sync::Mutex::new(chunks.into_iter());
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let next = work.lock().unwrap().next();
                match next {
                    Some((i, chunk)) => f(i, chunk),
                    None => break,
                }
            });
        }
    });
}

/// Map `f` over `0..n` in parallel, returning results in index order.
pub fn par_map<T: Send, F>(n: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Send + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    par_chunks_mut(&mut out, n.div_ceil(available_threads().max(1)).max(1), |ci, chunk| {
        let base = ci * n.div_ceil(available_threads().max(1)).max(1);
        for (j, slot) in chunk.iter_mut().enumerate() {
            *slot = Some(f(base + j));
        }
    });
    out.into_iter().map(|o| o.expect("all slots filled")).collect()
}

/// Worker thread count (cores, capped at 16 — the workloads here are
/// memory-bound well before that).
pub fn available_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything() {
        let mut data = vec![0u32; 10_037];
        par_chunks_mut(&mut data, 64, |_, chunk| {
            for x in chunk {
                *x += 1;
            }
        });
        assert!(data.iter().all(|&x| x == 1));
    }

    #[test]
    fn chunk_index_is_correct() {
        let mut data = vec![0usize; 1000];
        par_chunks_mut(&mut data, 100, |i, chunk| {
            for x in chunk.iter_mut() {
                *x = i;
            }
        });
        for (j, &x) in data.iter().enumerate() {
            assert_eq!(x, j / 100);
        }
    }

    #[test]
    fn par_map_preserves_order() {
        let out = par_map(1000, |i| i * 3);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * 3);
        }
    }

    #[test]
    fn par_map_empty() {
        let out: Vec<u8> = par_map(0, |_| 0);
        assert!(out.is_empty());
    }

    #[test]
    fn single_chunk_runs_inline() {
        let mut data = vec![5u8; 3];
        par_chunks_mut(&mut data, 100, |i, chunk| {
            assert_eq!(i, 0);
            chunk[0] = 9;
        });
        assert_eq!(data[0], 9);
    }
}
