//! Dynamic value tree with JSON and TOML-subset round-tripping.
//!
//! Replaces serde/serde_json/toml in this offline environment. The TOML
//! subset covers what [`crate::config`] needs: top-level and nested
//! `[table.headers]`, `key = value` with strings, integers, floats, booleans,
//! and homogeneous arrays. JSON support is complete (emit + parse) and is
//! used for bench artifacts and report round-trips.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A dynamic value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
    Table(BTreeMap<String, Value>),
}

impl Value {
    /// Empty table.
    pub fn table() -> Value {
        Value::Table(BTreeMap::new())
    }

    /// Insert into a table value (panics on non-table — construction bug).
    pub fn set(&mut self, key: &str, v: impl Into<Value>) -> &mut Self {
        match self {
            Value::Table(m) => {
                m.insert(key.to_string(), v.into());
            }
            _ => panic!("set() on non-table"),
        }
        self
    }

    /// Get a table entry.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Table(m) => m.get(key),
            _ => None,
        }
    }

    /// Required typed accessors for config parsing.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        match self.get(key) {
            Some(Value::Str(s)) => Ok(s),
            other => bail!("key '{key}': expected string, got {other:?}"),
        }
    }

    pub fn req_i64(&self, key: &str) -> Result<i64> {
        match self.get(key) {
            Some(Value::Int(i)) => Ok(*i),
            Some(Value::Float(f)) if f.fract() == 0.0 => Ok(*f as i64),
            other => bail!("key '{key}': expected integer, got {other:?}"),
        }
    }

    pub fn req_u32(&self, key: &str) -> Result<u32> {
        let v = self.req_i64(key)?;
        u32::try_from(v).map_err(|_| anyhow!("key '{key}': {v} out of u32 range"))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64> {
        let v = self.req_i64(key)?;
        u64::try_from(v).map_err(|_| anyhow!("key '{key}': {v} out of u64 range"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        match self.get(key) {
            Some(Value::Float(f)) => Ok(*f),
            Some(Value::Int(i)) => Ok(*i as f64),
            other => bail!("key '{key}': expected float, got {other:?}"),
        }
    }

    pub fn req_bool(&self, key: &str) -> Result<bool> {
        match self.get(key) {
            Some(Value::Bool(b)) => Ok(*b),
            other => bail!("key '{key}': expected bool, got {other:?}"),
        }
    }

    pub fn req_table(&self, key: &str) -> Result<&Value> {
        match self.get(key) {
            Some(t @ Value::Table(_)) => Ok(t),
            other => bail!("key '{key}': expected table, got {other:?}"),
        }
    }

    pub fn req_f64_array(&self, key: &str) -> Result<Vec<f64>> {
        match self.get(key) {
            Some(Value::Arr(items)) => items
                .iter()
                .map(|v| match v {
                    Value::Float(f) => Ok(*f),
                    Value::Int(i) => Ok(*i as f64),
                    other => bail!("key '{key}': non-numeric array item {other:?}"),
                })
                .collect(),
            other => bail!("key '{key}': expected array, got {other:?}"),
        }
    }

    pub fn req_u32_array(&self, key: &str) -> Result<Vec<u32>> {
        match self.get(key) {
            Some(Value::Arr(items)) => items
                .iter()
                .map(|v| match v {
                    Value::Int(i) => {
                        u32::try_from(*i).map_err(|_| anyhow!("array item out of range"))
                    }
                    other => bail!("key '{key}': non-integer array item {other:?}"),
                })
                .collect(),
            other => bail!("key '{key}': expected array, got {other:?}"),
        }
    }

    // ---------------- JSON ----------------

    /// Serialize to compact JSON.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write_json(&mut s, None, 0);
        s
    }

    /// Serialize to pretty JSON (2-space indent).
    pub fn to_json_pretty(&self) -> String {
        let mut s = String::new();
        self.write_json(&mut s, Some(2), 0);
        s
    }

    fn write_json(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Value::Str(s) => {
                out.push('"');
                escape_json(s, out);
                out.push('"');
            }
            Value::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Value::Float(f) => {
                if f.is_finite() {
                    if f.fract() == 0.0 && f.abs() < 1e15 {
                        let _ = write!(out, "{f:.1}");
                    } else {
                        let _ = write!(out, "{f}");
                    }
                } else {
                    // JSON has no NaN/inf; emit null (reports use NaN for
                    // "not measured")
                    out.push_str("null");
                }
            }
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    v.write_json(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    out.push_str(nl);
                    out.push_str(&pad);
                }
                out.push(']');
            }
            Value::Table(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    out.push('"');
                    escape_json(k, out);
                    out.push_str("\":");
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write_json(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    out.push_str(nl);
                    out.push_str(&pad);
                }
                out.push('}');
            }
        }
    }

    /// Parse JSON text.
    pub fn from_json(text: &str) -> Result<Value> {
        let mut p = JsonParser { b: text.as_bytes(), i: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    // ---------------- TOML subset ----------------

    /// Serialize a table to TOML (nested tables become `[dotted.headers]`).
    pub fn to_toml(&self) -> Result<String> {
        let Value::Table(_) = self else {
            bail!("TOML root must be a table");
        };
        let mut out = String::new();
        self.write_toml_table(&mut out, "")?;
        Ok(out)
    }

    fn write_toml_table(&self, out: &mut String, prefix: &str) -> Result<()> {
        let Value::Table(m) = self else { unreachable!() };
        // scalars/arrays first, then sub-tables
        for (k, v) in m {
            match v {
                Value::Table(_) => {}
                _ => {
                    let _ = writeln!(out, "{k} = {}", toml_scalar(v)?);
                }
            }
        }
        for (k, v) in m {
            if let Value::Table(_) = v {
                let full = if prefix.is_empty() {
                    k.clone()
                } else {
                    format!("{prefix}.{k}")
                };
                let _ = writeln!(out, "\n[{full}]");
                v.write_toml_table(out, &full)?;
            }
        }
        Ok(())
    }

    /// Parse the TOML subset.
    pub fn from_toml(text: &str) -> Result<Value> {
        let mut root = Value::table();
        let mut path: Vec<String> = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_toml_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                let inner = line
                    .strip_prefix('[')
                    .and_then(|s| s.strip_suffix(']'))
                    .ok_or_else(|| anyhow!("line {}: bad table header", lineno + 1))?;
                path = inner.split('.').map(|s| s.trim().to_string()).collect();
                ensure_path(&mut root, &path);
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = k.trim().to_string();
            let val = parse_toml_value(v.trim())
                .map_err(|e| e.context(format!("line {}: value for '{key}'", lineno + 1)))?;
            let tbl = navigate(&mut root, &path);
            if let Value::Table(m) = tbl {
                m.insert(key, val);
            }
        }
        Ok(root)
    }
}

fn ensure_path(root: &mut Value, path: &[String]) {
    let mut cur = root;
    for p in path {
        let Value::Table(m) = cur else { return };
        cur = m.entry(p.clone()).or_insert_with(Value::table);
    }
}

fn navigate<'a>(root: &'a mut Value, path: &[String]) -> &'a mut Value {
    let mut cur = root;
    for p in path {
        let Value::Table(m) = cur else { unreachable!() };
        cur = m.entry(p.clone()).or_insert_with(Value::table);
    }
    cur
}

fn strip_toml_comment(line: &str) -> &str {
    // no '#' inside strings in our configs; safe simple strip
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn toml_scalar(v: &Value) -> Result<String> {
    Ok(match v {
        Value::Str(s) => format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
        Value::Int(i) => i.to_string(),
        Value::Float(f) => {
            if f.fract() == 0.0 && f.is_finite() && f.abs() < 1e15 {
                format!("{f:.1}")
            } else {
                format!("{f}")
            }
        }
        Value::Bool(b) => b.to_string(),
        Value::Arr(items) => {
            let inner: Result<Vec<String>> = items.iter().map(toml_scalar).collect();
            format!("[{}]", inner?.join(", "))
        }
        Value::Table(_) => bail!("inline tables unsupported"),
    })
}

fn parse_toml_value(s: &str) -> Result<Value> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array"))?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let items: Result<Vec<Value>> =
            inner.split(',').map(|p| parse_toml_value(p.trim())).collect();
        return Ok(Value::Arr(items?));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value: {s}")
}

fn escape_json(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
}

struct JsonParser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> JsonParser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected '{}' at offset {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => {
                self.literal("true")?;
                Ok(Value::Bool(true))
            }
            Some(b'f') => {
                self.literal("false")?;
                Ok(Value::Bool(false))
            }
            Some(b'n') => {
                self.literal("null")?;
                Ok(Value::Float(f64::NAN))
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at offset {}", other.map(|c| c as char), self.i),
        }
    }

    fn literal(&mut self, lit: &str) -> Result<()> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(())
        } else {
            bail!("bad literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Table(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Table(m));
                }
                _ => bail!("expected ',' or '}}' at offset {}", self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                _ => bail!("expected ',' or ']' at offset {}", self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        while let Some(c) = self.peek() {
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| anyhow!("dangling escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // reconstruct UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
        bail!("unterminated string")
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        let mut is_float = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => self.i += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    is_float = true;
                    self.i += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        if is_float {
            Ok(Value::Float(text.parse()?))
        } else {
            Ok(Value::Int(text.parse()?))
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Value {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Value {
        Value::Str(s)
    }
}
impl From<i64> for Value {
    fn from(v: i64) -> Value {
        Value::Int(v)
    }
}
impl From<u32> for Value {
    fn from(v: u32) -> Value {
        Value::Int(v as i64)
    }
}
impl From<u64> for Value {
    fn from(v: u64) -> Value {
        Value::Int(v as i64)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Value {
        Value::Float(v)
    }
}
impl From<f32> for Value {
    fn from(v: f32) -> Value {
        Value::Float(v as f64)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Value {
        Value::Bool(v)
    }
}
impl From<Vec<Value>> for Value {
    fn from(v: Vec<Value>) -> Value {
        Value::Arr(v)
    }
}
impl From<&[u32]> for Value {
    fn from(v: &[u32]) -> Value {
        Value::Arr(v.iter().map(|&x| Value::Int(x as i64)).collect())
    }
}
impl From<&[f64]> for Value {
    fn from(v: &[f64]) -> Value {
        Value::Arr(v.iter().map(|&x| Value::Float(x)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Value {
        let mut inner = Value::table();
        inner.set("bandwidth", 1.25e9).set("latency", 0.00015);
        let mut v = Value::table();
        v.set("name", "reddit-sim")
            .set("workers", 4u32)
            .set("lr", 0.05f64)
            .set("trace", true)
            .set("fanout", &[10u32, 25][..])
            .set("fabric", inner);
        v
    }

    #[test]
    fn json_round_trip() {
        let v = sample();
        for text in [v.to_json(), v.to_json_pretty()] {
            let back = Value::from_json(&text).unwrap();
            assert_eq!(v, back, "from: {text}");
        }
    }

    #[test]
    fn json_string_escapes() {
        let mut v = Value::table();
        v.set("s", "a\"b\\c\nd\te");
        let back = Value::from_json(&v.to_json()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn json_unicode() {
        let mut v = Value::table();
        v.set("s", "héllo ☃");
        let back = Value::from_json(&v.to_json()).unwrap();
        assert_eq!(back.req_str("s").unwrap(), "héllo ☃");
    }

    #[test]
    fn json_nan_becomes_null_and_back() {
        let mut v = Value::table();
        v.set("x", f64::NAN);
        let text = v.to_json();
        assert!(text.contains("null"));
        let back = Value::from_json(&text).unwrap();
        match back.get("x") {
            Some(Value::Float(f)) => assert!(f.is_nan()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn json_rejects_garbage() {
        assert!(Value::from_json("{\"a\":").is_err());
        assert!(Value::from_json("[1,2,]").is_err());
        assert!(Value::from_json("{\"a\":1} extra").is_err());
        assert!(Value::from_json("nul").is_err());
    }

    #[test]
    fn json_empty_containers() {
        assert_eq!(Value::from_json("{}").unwrap(), Value::table());
        assert_eq!(Value::from_json("[]").unwrap(), Value::Arr(vec![]));
    }

    #[test]
    fn json_negative_and_exponent_numbers() {
        let v = Value::from_json("[-3, -2.5, 1e3, 2E-2]").unwrap();
        assert_eq!(
            v,
            Value::Arr(vec![
                Value::Int(-3),
                Value::Float(-2.5),
                Value::Float(1000.0),
                Value::Float(0.02)
            ])
        );
    }

    #[test]
    fn toml_round_trip() {
        let v = sample();
        let text = v.to_toml().unwrap();
        let back = Value::from_toml(&text).unwrap();
        assert_eq!(v, back, "from:\n{text}");
    }

    #[test]
    fn toml_nested_headers() {
        let text = "a = 1\n[x]\nb = 2.5\n[x.y]\nc = \"z\"\n";
        let v = Value::from_toml(text).unwrap();
        assert_eq!(v.req_i64("a").unwrap(), 1);
        let x = v.req_table("x").unwrap();
        assert_eq!(x.req_f64("b").unwrap(), 2.5);
        assert_eq!(x.req_table("y").unwrap().req_str("c").unwrap(), "z");
    }

    #[test]
    fn toml_comments_and_blanks() {
        let text = "# header\na = 1 # trailing\n\nb = \"has # inside\"\n";
        let v = Value::from_toml(text).unwrap();
        assert_eq!(v.req_i64("a").unwrap(), 1);
        assert_eq!(v.req_str("b").unwrap(), "has # inside");
    }

    #[test]
    fn toml_arrays() {
        let v = Value::from_toml("f = [10, 25]\ng = []\n").unwrap();
        assert_eq!(v.req_u32_array("f").unwrap(), vec![10, 25]);
        assert_eq!(v.req_u32_array("g").unwrap(), Vec::<u32>::new());
    }

    #[test]
    fn toml_rejects_bad_lines() {
        assert!(Value::from_toml("just words\n").is_err());
        assert!(Value::from_toml("a = \"unterminated\n").is_err());
        assert!(Value::from_toml("[broken\na = 1\n").is_err());
    }

    #[test]
    fn typed_accessors_error_cleanly() {
        let v = sample();
        assert!(v.req_str("workers").is_err());
        assert!(v.req_i64("name").is_err());
        assert!(v.req_f64("missing").is_err());
        assert!(v.req_bool("lr").is_err());
        assert_eq!(v.req_u32("workers").unwrap(), 4);
        // float-typed whole numbers accepted as ints (TOML "1.0" case)
        let mut w = Value::table();
        w.set("n", 3.0f64);
        assert_eq!(w.req_i64("n").unwrap(), 3);
    }
}
