//! Energy accounting (paper §5.4, Table 3, Fig. 8).
//!
//! The paper measures CPU/GPU energy with psutil/NVML and attributes
//! RapidGNN's ~44%/32% savings almost entirely to shorter run time, with a
//! small CPU *power* reduction (no busy-wait RPC polling) and a small GPU
//! power increase (device-resident cache). We reproduce that causal chain
//! with a phase-based power model: energy = Σ phase_duration × phase_power,
//! where durations come from the (simulated or measured) run and powers from
//! [`crate::config::PowerConfig`].

use crate::config::PowerConfig;
use crate::metrics::{PhaseTimes, RunReport};

/// Energy report for one device class.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DeviceEnergy {
    /// Total joules.
    pub total_j: f64,
    /// Duration attributed (seconds).
    pub duration_s: f64,
}

impl DeviceEnergy {
    /// Mean power over the duration (W).
    pub fn mean_power_w(&self) -> f64 {
        if self.duration_s > 0.0 {
            self.total_j / self.duration_s
        } else {
            0.0
        }
    }
}

/// CPU + GPU energy for a run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyReport {
    pub cpu: DeviceEnergy,
    pub gpu: DeviceEnergy,
}

/// Integrate the power model over one epoch's phase times.
///
/// Phase → power mapping:
/// - `sample`/`assemble`: CPU busy, GPU idle (host-side work).
/// - `fetch`: CPU at *net-wait* power (RPC polling keeps cores spinning —
///   the reason DGL's mean CPU power exceeds RapidGNN's in Table 3),
///   GPU idle (stalled).
/// - `compute`: GPU busy; CPU near-idle feeding the device.
/// - `idle`: both at idle floor.
/// - `gpu_cache_bytes > 0` adds a small residency overhead to GPU idle power
///   (the paper's +4.7% GPU power for RapidGNN).
pub fn epoch_energy(p: &PhaseTimes, power: &PowerConfig, gpu_cache_bytes: u64) -> EnergyReport {
    // Cache residency: +1 W per GiB held, capped at +3 W — matches the
    // paper's observed ~5% GPU power delta at its cache sizes.
    let residency_w = ((gpu_cache_bytes as f64 / (1u64 << 30) as f64) * 1.0).min(3.0);
    let gpu_idle = power.gpu_idle_w + residency_w;
    let cpu_j = (p.sample + p.assemble) * power.cpu_busy_w
        + p.fetch * power.cpu_net_wait_w
        + p.compute * power.cpu_idle_w
        + p.idle * power.cpu_idle_w;
    let gpu_j = p.compute * (power.gpu_busy_w + residency_w)
        + (p.sample + p.assemble + p.fetch + p.idle) * gpu_idle;
    let dur = p.total();
    EnergyReport {
        cpu: DeviceEnergy { total_j: cpu_j, duration_s: dur },
        gpu: DeviceEnergy { total_j: gpu_j, duration_s: dur },
    }
}

/// Aggregate run energy from per-epoch reports (fills
/// `RunReport::{cpu,gpu}_energy_j`).
pub fn run_energy(report: &RunReport, power: &PowerConfig) -> EnergyReport {
    let mut total = EnergyReport::default();
    for e in &report.epochs {
        let er = epoch_energy(&e.phases, power, e.device_bytes);
        total.cpu.total_j += er.cpu.total_j;
        total.cpu.duration_s += er.cpu.duration_s;
        total.gpu.total_j += er.gpu.total_j;
        total.gpu.duration_s += er.gpu.duration_s;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    fn phases(sample: f64, fetch: f64, compute: f64) -> PhaseTimes {
        PhaseTimes { sample, fetch, assemble: 0.0, compute, idle: 0.0 }
    }

    #[test]
    fn energy_scales_with_duration() {
        let pw = PowerConfig::default();
        let e1 = epoch_energy(&phases(1.0, 1.0, 1.0), &pw, 0);
        let e2 = epoch_energy(&phases(2.0, 2.0, 2.0), &pw, 0);
        assert!((e2.cpu.total_j - 2.0 * e1.cpu.total_j).abs() < 1e-9);
        assert!((e2.gpu.total_j - 2.0 * e1.gpu.total_j).abs() < 1e-9);
    }

    #[test]
    fn fetch_heavy_run_draws_more_cpu_power() {
        // The Table-3 mechanism: network-stalled runs have HIGHER mean CPU
        // power than compute-balanced ones.
        let pw = PowerConfig::default();
        let stalled = epoch_energy(&phases(0.5, 3.0, 0.5), &pw, 0);
        let balanced = epoch_energy(&phases(0.5, 0.2, 3.3), &pw, 0);
        assert!(stalled.cpu.mean_power_w() > balanced.cpu.mean_power_w());
    }

    #[test]
    fn gpu_cache_residency_increases_gpu_power() {
        let pw = PowerConfig::default();
        let p = phases(1.0, 1.0, 1.0);
        let nocache = epoch_energy(&p, &pw, 0);
        let cache = epoch_energy(&p, &pw, 2 << 30);
        assert!(cache.gpu.mean_power_w() > nocache.gpu.mean_power_w());
        // but the delta is small (paper: +4.7%)
        let ratio = cache.gpu.mean_power_w() / nocache.gpu.mean_power_w();
        assert!(ratio < 1.15, "ratio {ratio}");
    }

    #[test]
    fn zero_duration_zero_power() {
        let e = DeviceEnergy::default();
        assert_eq!(e.mean_power_w(), 0.0);
    }

    #[test]
    fn shorter_run_saves_energy_even_at_equal_power() {
        // Energy ∝ duration: the paper's primary savings channel.
        let pw = PowerConfig::default();
        let slow = epoch_energy(&phases(1.0, 4.0, 2.0), &pw, 0);
        let fast = epoch_energy(&phases(1.0, 0.4, 2.0), &pw, 0);
        assert!(fast.cpu.total_j < slow.cpu.total_j);
        assert!(fast.gpu.total_j < slow.gpu.total_j);
    }
}
