//! The pluggable engine API: [`TrainingStrategy`] + [`EngineRegistry`].
//!
//! An *engine* is one data-movement policy for distributed GNN training —
//! the paper's RapidGNN, its DistDGL-style baselines, or any new scenario
//! from the literature. Engines used to be a closed `config::Engine` enum
//! matched in every coordinator path; they are now open trait objects that
//! one shared worker pipeline ([`super::pipeline`]) drives end to end, in
//! both trace and full mode, sequentially or on the event-driven cluster
//! runtime.
//!
//! # Strategy lifecycle
//!
//! ```text
//! EngineRegistry::create(cfg)            (once per run)
//!   └─ strategy.setup(ctx, w)            (once per worker → StrategySetup:
//!   │                                     setup_time + opaque worker state)
//!   └─ per epoch e:
//!        strategy.plan_epoch(...)        (→ BatchPlan: the batch source)
//!        loop: plan.next(...)            (stage one batch: pulls, costs)
//!              pipeline consumes it      (assemble + compute, shared code)
//!        strategy.finish_epoch(...)      (cache swaps, background work,
//!                                         epoch-time policy, memory report)
//! ```
//!
//! The pipeline owns everything engines have in common — the consume side
//! (feature assembly, the real or analytic train step), the bounded-queue
//! schedule, report assembly. A strategy owns only what distinguishes it:
//! which partitioner and fan-outs it wants, how a batch gets staged and what
//! that costs, and its epoch-boundary bookkeeping.
//!
//! # Registering a new engine
//!
//! 1. Implement [`TrainingStrategy`] (see `strategies/` for four worked
//!    examples; `fast_sample.rs` and `green_window.rs` are registry-only
//!    engines in < 200 lines each).
//! 2. Add an [`EngineEntry`] to [`EngineRegistry::builtin`] — id, display
//!    name, constructor. That is the *only* dispatch site: `--engine <id>`,
//!    `compare`, config round-trips, and the conformance tests all iterate
//!    the registry.
//! 3. Per-engine tuning knobs go in [`crate::config::EngineParams`] so they
//!    survive the TOML round-trip.

use super::common::RunContext;
use crate::compress::{BlockCodec, Codec, GradMode, WireCodec};
use crate::config::{Engine, EngineParams, RunConfig};
use crate::metrics::{CacheStats, CommStats, PhaseTimes};
use crate::partition::Partitioner;
use crate::prefetch::StagedBatch;
use crate::sampler::khop::Fanout;
use crate::util::value::Value;
use crate::{Result, WorkerId};
use anyhow::bail;
use std::any::Any;
use std::sync::OnceLock;

/// Opaque per-worker strategy state, created by [`TrainingStrategy::setup`]
/// and threaded back into every later hook. Strategies downcast to their own
/// concrete type.
pub type StrategyState = Box<dyn Any + Send>;

/// Products of a strategy's one-time per-worker setup.
pub struct StrategySetup {
    /// Simulated offline setup seconds (reported separately from training
    /// time, like the paper's precompute pass). 0 for on-demand engines.
    pub setup_time: f64,
    /// Per-worker mutable state handed back to `plan_epoch`/`finish_epoch`.
    pub state: StrategyState,
}

/// One staged batch plus its virtual staging cost.
pub struct StagedStep {
    /// The staged batch (metadata + features in full mode).
    pub staged: StagedBatch,
    /// Staging cost in virtual seconds, already slowdown-adjusted: the
    /// pipeline feeds it straight into the bounded-queue schedule (the
    /// `stage` slot), sequentially or on the cluster event loop.
    pub cost: f64,
}

/// The per-(worker, epoch) batch source a strategy plans: each `next` call
/// performs the real staging side effects (sampling charges, KV pulls, cache
/// lookups) and returns the staged batch with its cost.
pub trait BatchPlan {
    /// Stage the next batch; `Ok(None)` when the epoch is exhausted.
    fn next(&mut self, comm: &mut CommStats, phases: &mut PhaseTimes) -> Result<Option<StagedStep>>;
}

/// What the pipeline measured for one (worker, epoch), handed to
/// [`TrainingStrategy::finish_epoch`].
pub struct PipelineOutcome {
    /// Pipeline makespan: the closed-form [`crate::sim::pipeline_schedule`]
    /// total on the sequential path, the event-loop makespan on the cluster
    /// path (the two agree on homogeneous inputs — pinned by the
    /// conformance tests).
    pub total: f64,
    /// Trainer stall waiting on staging (residual-fetch time).
    pub total_wait: f64,
    /// True when produced by the event-driven cluster runtime. Lets a
    /// strategy keep the serial path's per-phase accounting bit-identical
    /// (the two accumulation orders differ only in float rounding).
    pub event_driven: bool,
}

/// Per-epoch consume-side totals the pipeline accumulated.
pub struct EpochTotals {
    /// Batches executed.
    pub steps: u32,
    /// Max input-node count over the epoch's batches (the paper's `m_max`).
    pub m_max: u64,
}

/// A strategy's resolved gradient-compression request (see
/// [`TrainingStrategy::grad_compression`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GradCompression {
    /// Coordinate selector.
    pub mode: GradMode,
    /// Fraction of gradient coordinates applied per step, in (0, 1].
    pub k: f64,
}

/// Resolve `params.codec` against a strategy's natural default: the
/// `Codec::Default` sentinel becomes `fallback`, then `none` maps to no
/// codec and `f16`/`int8` to a [`BlockCodec`] with `params.codec_block`.
pub fn resolve_codec(params: &EngineParams, fallback: Codec) -> Option<BlockCodec> {
    let kind = match params.codec {
        Codec::Default => fallback,
        explicit => explicit,
    };
    match kind {
        Codec::Default | Codec::None => None,
        Codec::F16 => Some(BlockCodec::new(WireCodec::F16, params.codec_block)),
        Codec::Int8 => Some(BlockCodec::new(WireCodec::Int8, params.codec_block)),
    }
}

/// A strategy's epoch-boundary verdict: the reported time and memory.
pub struct EpochFinish {
    /// Simulated epoch wall time `t_e`.
    pub epoch_time: f64,
    /// Cache counters for the report (default for cache-less engines).
    pub cache: CacheStats,
    /// Adaptive-cache controller telemetry (`None` for static caches;
    /// omitted from serialized reports so their traces stay byte-stable).
    pub cache_plan: Option<crate::metrics::CacheReport>,
    /// Peak device bytes attributable to this epoch.
    pub device_bytes: u64,
    /// Peak host bytes attributable to this epoch.
    pub host_bytes: u64,
}

/// One training engine: the open replacement for the old `Engine` match
/// arms. Object-safe; stateless (per-worker state lives in
/// [`StrategyState`]), so one instance serves all workers and threads.
pub trait TrainingStrategy: Send + Sync {
    /// Registry id (`--engine <id>`, config files).
    fn id(&self) -> &'static str;

    /// Display name for bench tables and reports.
    fn name(&self) -> &'static str;

    /// Which partitioner this engine trains against.
    fn partitioner(&self) -> Partitioner {
        Partitioner::MetisLike
    }

    /// Per-layer fan-out policy.
    fn fanouts(&self, cfg: &RunConfig) -> Vec<Fanout> {
        cfg.fanout.iter().map(|&f| Fanout::Sample(f)).collect()
    }

    /// Prefetch-queue depth `Q` for the bounded-queue pipeline (0 = fully
    /// serial, the reactive on-demand behaviour).
    fn queue_depth(&self, cfg: &RunConfig) -> u32;

    /// Feature wire codec for this run, installed into the kvstore once at
    /// context build. The default resolves the `Codec::Default` sentinel to
    /// *no* codec, so every pre-compression engine stays bit-exact; an
    /// explicit `f16`/`int8` in the config enables compression on any engine
    /// (notably composing with `green-window`'s merged pulls), and an
    /// explicit `none` always disables it. `quant-pull` overrides the
    /// fallback to int8.
    fn feature_codec(&self, params: &EngineParams) -> Option<BlockCodec> {
        resolve_codec(params, Codec::None)
    }

    /// Gradient-sparsification request for full-mode training; `None` (the
    /// default) keeps dense SGD. `grad-topk` overrides this to
    /// `params.grad_mode` at `params.grad_k` when `grad_k > 0`.
    fn grad_compression(&self, _params: &EngineParams) -> Option<GradCompression> {
        None
    }

    /// The epoch whose *schedule* training epoch `epoch` executes. Identity
    /// for every engine that samples fresh batches per epoch; a replaying
    /// engine (`fast-sample`) maps onto its period start. The pipeline uses
    /// this to derive per-batch train-step seeds, so the rebuilt blocks
    /// match the staged metadata in full mode.
    fn schedule_epoch(&self, _cfg: &RunConfig, epoch: u32) -> u32 {
        epoch
    }

    /// One-time per-worker setup (e.g. RapidGNN's offline precompute +
    /// initial cache build). Charged as setup time, not training time.
    fn setup(&self, ctx: &RunContext, worker: WorkerId) -> Result<StrategySetup>;

    /// Plan one epoch: reset per-epoch state and return the batch source.
    /// `comm` is the epoch's communication counter (merge setup traffic here
    /// if it should land on this epoch's report).
    fn plan_epoch<'a>(
        &self,
        ctx: &'a RunContext,
        state: &mut StrategyState,
        worker: WorkerId,
        epoch: u32,
        comm: &mut CommStats,
    ) -> Result<Box<dyn BatchPlan + 'a>>;

    /// Epoch-boundary bookkeeping: background work (cache rebuilds), the
    /// epoch-time policy, and the memory report.
    #[allow(clippy::too_many_arguments)]
    fn finish_epoch(
        &self,
        ctx: &RunContext,
        state: &mut StrategyState,
        worker: WorkerId,
        epoch: u32,
        outcome: &PipelineOutcome,
        totals: &EpochTotals,
        phases: &mut PhaseTimes,
        comm: &mut CommStats,
    ) -> Result<EpochFinish>;

    /// Serialize this worker's strategy state for a checkpoint. The default
    /// (an empty table) is correct for stateless on-demand engines whose
    /// per-epoch state is recomputed from the config and schedule position;
    /// cache-carrying engines override it to record their steady hot set
    /// (and any controller state) so a restore rebuilds the exact cache.
    fn checkpoint_state(
        &self,
        _ctx: &RunContext,
        _state: &StrategyState,
        _worker: WorkerId,
    ) -> Result<Value> {
        Ok(Value::table())
    }

    /// Rebuild per-worker state from a checkpoint written at the boundary
    /// entering `next_epoch`. The default delegates to [`Self::setup`],
    /// correct for stateless engines (their setup is free and chargeless);
    /// cache-carrying engines override to re-enumerate schedule metadata and
    /// rebuild the checkpointed steady cache *without* re-charging the
    /// fabric, so the resumed run's counters match the interrupted run's.
    fn restore_setup(
        &self,
        ctx: &RunContext,
        worker: WorkerId,
        _next_epoch: u32,
        _snapshot: &Value,
    ) -> Result<StrategySetup> {
        self.setup(ctx, worker)
    }

    /// Rows this worker's warm cache contributes to a membership-change data
    /// move (shard adoption ships the partition's feature rows plus the hot
    /// set, so recovery pricing needs the cache size). 0 for cache-less
    /// engines.
    fn cache_rows(&self, _state: &StrategyState, _worker: WorkerId) -> u64 {
        0
    }
}

/// Constructor for a registered engine. Takes the run config so an engine
/// can read its [`crate::config::EngineParams`] at construction.
pub type StrategyCtor = fn(&RunConfig) -> Box<dyn TrainingStrategy>;

/// One registry row: the id is the single source of truth an `Engine` value
/// resolves against.
pub struct EngineEntry {
    /// Registry key (config-file id, `--engine` value).
    pub id: &'static str,
    /// Display name for tables and reports.
    pub display_name: &'static str,
    /// Strategy constructor.
    pub ctor: StrategyCtor,
}

/// The open engine set: id → strategy constructor. [`Self::global`] is the
/// process-wide builtin registry every `Engine` resolves against; owned
/// registries (via [`Self::builtin`] + [`Self::register`]) exist for tests
/// and embedders that add engines without touching this file.
pub struct EngineRegistry {
    entries: Vec<EngineEntry>,
}

impl EngineRegistry {
    /// The built-in engines: the paper's four plus the scenario engines
    /// that prove the registry is open (`fast-sample`, `green-window`,
    /// `adaptive-cache`).
    pub fn builtin() -> EngineRegistry {
        let mut reg = EngineRegistry { entries: Vec::new() };
        for entry in [
            EngineEntry {
                id: "rapid",
                display_name: "RapidGNN",
                ctor: super::strategies::rapid::ctor,
            },
            EngineEntry {
                id: "dgl-metis",
                display_name: "DGL-METIS",
                ctor: super::strategies::baseline::dgl_metis_ctor,
            },
            EngineEntry {
                id: "dgl-random",
                display_name: "DGL-Random",
                ctor: super::strategies::baseline::dgl_random_ctor,
            },
            EngineEntry {
                id: "dist-gcn",
                display_name: "Dist-GCN",
                ctor: super::strategies::baseline::dist_gcn_ctor,
            },
            EngineEntry {
                id: "fast-sample",
                display_name: "FastSample",
                ctor: super::strategies::fast_sample::ctor,
            },
            EngineEntry {
                id: "green-window",
                display_name: "GreenWindow",
                ctor: super::strategies::green_window::ctor,
            },
            EngineEntry {
                id: "adaptive-cache",
                display_name: "AdaptiveCache",
                ctor: super::strategies::adaptive_cache::ctor,
            },
            EngineEntry {
                id: "quant-pull",
                display_name: "QuantPull",
                ctor: super::strategies::compress::quant_pull_ctor,
            },
            EngineEntry {
                id: "grad-topk",
                display_name: "GradTopK",
                ctor: super::strategies::compress::grad_topk_ctor,
            },
        ] {
            reg.register(entry).expect("builtin engine ids are unique");
        }
        reg
    }

    /// The process-wide registry (what `Engine` parsing resolves against).
    pub fn global() -> &'static EngineRegistry {
        static GLOBAL: OnceLock<EngineRegistry> = OnceLock::new();
        GLOBAL.get_or_init(EngineRegistry::builtin)
    }

    /// Register an engine; rejects duplicate ids.
    pub fn register(&mut self, entry: EngineEntry) -> Result<()> {
        if self.entries.iter().any(|e| e.id == entry.id) {
            bail!("engine id '{}' already registered", entry.id);
        }
        self.entries.push(entry);
        Ok(())
    }

    /// Registered ids, in registration order.
    pub fn ids(&self) -> impl Iterator<Item = &'static str> + '_ {
        self.entries.iter().map(|e| e.id)
    }

    /// Registered engines as resolved [`Engine`] values, in registration
    /// order (`compare` and the conformance tests iterate this).
    pub fn engines(&self) -> Vec<Engine> {
        self.entries.iter().map(|e| Engine::from_registry_id(e.id)).collect()
    }

    /// Canonicalize an id: the registry's own `&'static str` for it.
    pub fn canonical_id(&self, id: &str) -> Option<&'static str> {
        self.entries.iter().find(|e| e.id == id).map(|e| e.id)
    }

    /// Display name for an id.
    pub fn display_name(&self, id: &str) -> Option<&'static str> {
        self.entries.iter().find(|e| e.id == id).map(|e| e.display_name)
    }

    /// Construct the strategy for `cfg.engine`.
    pub fn create(&self, cfg: &RunConfig) -> Result<Box<dyn TrainingStrategy>> {
        self.create_by_id(cfg.engine.id(), cfg)
    }

    /// Construct the strategy for an explicit id.
    pub fn create_by_id(&self, id: &str, cfg: &RunConfig) -> Result<Box<dyn TrainingStrategy>> {
        match self.entries.iter().find(|e| e.id == id) {
            Some(e) => Ok((e.ctor)(cfg)),
            None => bail!(
                "unknown engine '{id}' (registered: {})",
                self.ids().collect::<Vec<_>>().join("|")
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_registry_holds_all_nine_engines() {
        let reg = EngineRegistry::global();
        let ids: Vec<_> = reg.ids().collect();
        assert_eq!(
            ids,
            [
                "rapid",
                "dgl-metis",
                "dgl-random",
                "dist-gcn",
                "fast-sample",
                "green-window",
                "adaptive-cache",
                "quant-pull",
                "grad-topk"
            ]
        );
        for id in ids {
            let s = reg.create_by_id(id, &RunConfig::default()).unwrap();
            assert_eq!(s.id(), id, "strategy id must match its registry key");
            assert_eq!(reg.display_name(id), Some(s.name()));
        }
    }

    #[test]
    fn unknown_id_lists_registered_engines() {
        let err = EngineRegistry::global()
            .create_by_id("bogus", &RunConfig::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("rapid") && err.contains("green-window"), "{err}");
    }

    #[test]
    fn owned_registry_accepts_new_engines_and_rejects_duplicates() {
        // The extensibility proof at the API level: a new engine is one
        // EngineEntry, no coordinator edits.
        struct Custom;
        impl TrainingStrategy for Custom {
            fn id(&self) -> &'static str {
                "custom"
            }
            fn name(&self) -> &'static str {
                "Custom"
            }
            fn queue_depth(&self, _cfg: &RunConfig) -> u32 {
                0
            }
            fn setup(&self, _ctx: &RunContext, _worker: WorkerId) -> Result<StrategySetup> {
                Ok(StrategySetup { setup_time: 0.0, state: Box::new(()) })
            }
            fn plan_epoch<'a>(
                &self,
                _ctx: &'a RunContext,
                _state: &mut StrategyState,
                _worker: WorkerId,
                _epoch: u32,
                _comm: &mut CommStats,
            ) -> Result<Box<dyn BatchPlan + 'a>> {
                struct Empty;
                impl BatchPlan for Empty {
                    fn next(
                        &mut self,
                        _comm: &mut CommStats,
                        _phases: &mut PhaseTimes,
                    ) -> Result<Option<StagedStep>> {
                        Ok(None)
                    }
                }
                Ok(Box::new(Empty))
            }
            fn finish_epoch(
                &self,
                _ctx: &RunContext,
                _state: &mut StrategyState,
                _worker: WorkerId,
                _epoch: u32,
                outcome: &PipelineOutcome,
                _totals: &EpochTotals,
                _phases: &mut PhaseTimes,
                _comm: &mut CommStats,
            ) -> Result<EpochFinish> {
                Ok(EpochFinish {
                    epoch_time: outcome.total,
                    cache: CacheStats::default(),
                    cache_plan: None,
                    device_bytes: 0,
                    host_bytes: 0,
                })
            }
        }
        fn custom_ctor(_cfg: &RunConfig) -> Box<dyn TrainingStrategy> {
            Box::new(Custom)
        }
        let mut reg = EngineRegistry::builtin();
        reg.register(EngineEntry { id: "custom", display_name: "Custom", ctor: custom_ctor })
            .unwrap();
        assert!(reg.canonical_id("custom").is_some());
        assert_eq!(reg.create_by_id("custom", &RunConfig::default()).unwrap().name(), "Custom");
        let dup = reg.register(EngineEntry { id: "rapid", display_name: "X", ctor: custom_ctor });
        assert!(dup.is_err(), "duplicate ids must be rejected");
    }
}
