//! Shared engine plumbing: run context, host-side cost models, and the
//! substrate (dataset, partition, KV store) every strategy trains against.

use super::strategy::{EngineRegistry, TrainingStrategy};
use crate::config::{ExecMode, RunConfig};
use crate::graph::{build_dataset, Dataset};
use crate::kvstore::KvStore;
use crate::net::{NetFabric, ShmRings};
use crate::partition::{partition, Partition};
use crate::sampler::khop::Fanout;
use crate::sim::ComputeModel;
use crate::util::tempdir::TempDir;
use crate::{NodeId, Result, WorkerId};
use std::path::PathBuf;
use std::sync::Arc;

/// Host-side cost model for phases the fabric doesn't cover (trace mode).
/// Calibrated to the paper testbed's Xeon E5-2670v3 + SATA/NVMe SSD.
#[derive(Debug, Clone, Copy)]
pub struct CostParams {
    /// Online sampling cost per enumerated input node (hash + CSR walk).
    pub sample_per_node_sec: f64,
    /// Fixed per-batch sampling overhead (python/dataloader dispatch in DGL).
    pub sample_per_batch_sec: f64,
    /// SSD streaming bandwidth for metadata blocks (bytes/sec).
    pub ssd_bytes_per_sec: f64,
    /// Fixed per-batch metadata streaming overhead.
    pub stream_per_batch_sec: f64,
    /// Host memory bandwidth for feature assembly + H2D copy (bytes/sec).
    pub host_bytes_per_sec: f64,
    /// Fixed per-batch assembly/launch overhead.
    pub assemble_per_batch_sec: f64,
    /// Frequency-ranking cost per counted remote access (cache builds).
    pub rank_per_access_sec: f64,
}

impl Default for CostParams {
    fn default() -> Self {
        CostParams {
            sample_per_node_sec: 60e-9,
            sample_per_batch_sec: 400e-6,
            ssd_bytes_per_sec: 2.0e9,
            stream_per_batch_sec: 20e-6,
            host_bytes_per_sec: 8.0e9,
            assemble_per_batch_sec: 50e-6,
            rank_per_access_sec: 15e-9,
        }
    }
}

impl CostParams {
    /// Online k-hop sampling cost for a batch with `n_input` enumerated nodes.
    pub fn sample_time(&self, n_input: usize) -> f64 {
        self.sample_per_batch_sec + n_input as f64 * self.sample_per_node_sec
    }

    /// Metadata-block streaming cost (RapidGNN's runtime sampling substitute).
    pub fn stream_time(&self, block_bytes: u64) -> f64 {
        self.stream_per_batch_sec + block_bytes as f64 / self.ssd_bytes_per_sec
    }

    /// Feature assembly + device copy cost for an `[n, d]` f32 block.
    pub fn assemble_time(&self, n_input: usize, feature_dim: u32) -> f64 {
        self.assemble_per_batch_sec
            + (n_input as u64 * feature_dim as u64 * 4) as f64 / self.host_bytes_per_sec
    }
}

/// Everything the engines share for one run, plus the resolved strategy
/// that drives it (the registry's answer for `cfg.engine`, or an explicit
/// override via [`crate::coordinator::RunBuilder::with_strategy`]).
pub struct RunContext {
    pub cfg: RunConfig,
    /// The engine under test. One stateless instance serves all workers;
    /// per-worker state lives in the pipeline.
    pub strategy: Arc<dyn TrainingStrategy>,
    pub ds: Arc<Dataset>,
    pub part: Arc<Partition>,
    pub kv: Arc<KvStore>,
    pub fabric: NetFabric,
    /// Train-seed shard per worker (seeds owned by that partition).
    pub shards: Vec<Vec<NodeId>>,
    pub compute: ComputeModel,
    pub costs: CostParams,
    /// Directory for streamed metadata blocks (the paper's SSD).
    pub metadata_path: PathBuf,
    /// Virtual-time trace sink (`--trace-out` / `RunBuilder::with_trace`).
    /// `None` by default — tracing is strictly observational, and with no
    /// sink installed the run takes the exact pre-trace code paths.
    pub trace: Option<crate::trace::TraceHandle>,
    /// Real shared-memory transport, installed on the KvStore only in
    /// [`ExecMode::Wallclock`]. Held here so the coordinator can read the
    /// measured (wall-clock) tallies into the calibration report after the
    /// run; pricing still goes through `fabric`, so it never steers a run.
    pub shm: Option<Arc<ShmRings>>,
    /// Owns the temp dir when the config didn't name one.
    _tmp: Option<Arc<TempDir>>,
}

impl RunContext {
    /// Build dataset, partition, and KV store for a config, resolving the
    /// strategy from the global [`EngineRegistry`].
    pub fn build(cfg: &RunConfig) -> Result<RunContext> {
        let strategy: Arc<dyn TrainingStrategy> =
            Arc::from(EngineRegistry::global().create(cfg)?);
        RunContext::build_with_strategy(cfg, strategy)
    }

    /// Build with an explicit strategy (bypasses the registry — the
    /// `RunBuilder::with_strategy` escape hatch for unregistered engines).
    pub fn build_with_strategy(
        cfg: &RunConfig,
        strategy: Arc<dyn TrainingStrategy>,
    ) -> Result<RunContext> {
        cfg.validate()?;
        // Wallclock materializes features too: the real transport serves the
        // serialized shard blobs, so there must be real bytes to move.
        let with_features = matches!(cfg.exec_mode, ExecMode::Full | ExecMode::Wallclock);
        let ds = Arc::new(build_dataset(&cfg.dataset, with_features));
        let which = strategy.partitioner();
        let part = Arc::new(partition(&ds.graph, cfg.num_workers, which, cfg.base_seed));
        let fabric = NetFabric::new(cfg.fabric.clone()).with_world_size(cfg.num_workers);
        // The strategy's resolved wire codec (None for every engine unless
        // compression is requested) — installed once, so every pull path
        // charges compressed payloads without engine-specific branches.
        let mut kv = KvStore::new(&ds, part.clone(), fabric.clone())
            .with_codec(strategy.feature_codec(&cfg.engine_params));
        let shm = if cfg.exec_mode == ExecMode::Wallclock {
            let rings = Arc::new(ShmRings::new(fabric.clone(), kv.serialized_shards()));
            kv = kv.with_transport(rings.clone());
            Some(rings)
        } else {
            None
        };
        let kv = Arc::new(kv);
        let shards: Vec<Vec<NodeId>> = (0..cfg.num_workers)
            .map(|w| {
                ds.train_nodes
                    .iter()
                    .copied()
                    .filter(|&v| part.is_local(w, v))
                    .collect()
            })
            .collect();
        let (metadata_path, tmp) = if cfg.metadata_dir.is_empty() {
            let t = Arc::new(TempDir::new("meta")?);
            (t.path().to_path_buf(), Some(t))
        } else {
            std::fs::create_dir_all(&cfg.metadata_dir)?;
            (PathBuf::from(&cfg.metadata_dir), None)
        };
        Ok(RunContext {
            cfg: cfg.clone(),
            strategy,
            ds,
            part,
            kv,
            fabric,
            shards,
            compute: ComputeModel::default(),
            costs: CostParams::default(),
            metadata_path,
            trace: None,
            shm,
            _tmp: tmp,
        })
    }

    /// Per-layer fan-out policy for this engine (strategy-defined).
    pub fn fanouts(&self) -> Vec<Fanout> {
        self.strategy.fanouts(&self.cfg)
    }

    /// Simulated compute time for a batch (trace mode).
    pub fn compute_time(&self, n_input: usize, n_seeds: usize) -> f64 {
        self.compute.step_time(&self.cfg, n_input as u64, n_seeds as u64)
    }

    /// Local-work slowdown multiplier for `worker` (heterogeneous speeds:
    /// the `FabricConfig::worker_speed` vector plus the single-straggler
    /// sugar; ≥ 1, and 1.0 for unconfigured workers). Scales the host-side
    /// costs on the training path — sampling, SSD streaming, cache lookups,
    /// assembly, compute, and the background `C_sec` stream+rank work; the
    /// worker's *network* slowdown is applied per-link by the fabric
    /// itself. The offline precompute pass is not scaled: it is one-time
    /// setup, reported separately from training time.
    pub fn slowdown(&self, worker: WorkerId) -> f64 {
        self.cfg.fabric.slowdown_of(worker)
    }

    /// Epoch-aware [`Self::slowdown`]: layers the transient speed phase
    /// active at `epoch` (`fabric.worker_speed_phases`) over the static
    /// per-worker factors. Identical to `slowdown` when no phases are
    /// configured.
    pub fn slowdown_at(&self, worker: WorkerId, epoch: u32) -> f64 {
        self.cfg.fabric.slowdown_at(worker, epoch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetPreset, Engine};
    use crate::WorkerId;

    fn cfg() -> RunConfig {
        let mut c = RunConfig::default();
        c.dataset = crate::config::DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
        c
    }

    #[test]
    fn context_builds_and_shards_partition_train_nodes() {
        let ctx = RunContext::build(&cfg()).unwrap();
        let total: usize = ctx.shards.iter().map(Vec::len).sum();
        assert_eq!(total, ctx.ds.train_nodes.len());
        for (w, shard) in ctx.shards.iter().enumerate() {
            for &v in shard {
                assert!(ctx.part.is_local(w as WorkerId, v));
            }
        }
    }

    #[test]
    fn trace_mode_skips_features() {
        let ctx = RunContext::build(&cfg()).unwrap();
        assert!(!ctx.ds.has_features());
        assert!(!ctx.kv.has_values());
    }

    #[test]
    fn gcn_engine_gets_full_fanouts() {
        let mut c = cfg();
        c.engine = Engine::DistGcn;
        let ctx = RunContext::build(&c).unwrap();
        assert!(matches!(ctx.fanouts()[0], Fanout::FullCapped(_)));
        let c2 = cfg();
        let ctx2 = RunContext::build(&c2).unwrap();
        assert!(matches!(ctx2.fanouts()[0], Fanout::Sample(10)));
    }

    #[test]
    fn cost_model_monotone() {
        let c = CostParams::default();
        assert!(c.sample_time(10_000) > c.sample_time(100));
        assert!(c.assemble_time(10_000, 602) > c.assemble_time(10_000, 100));
        assert!(c.stream_time(1 << 20) > c.stream_time(1 << 10));
    }
}
