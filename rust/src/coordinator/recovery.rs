//! Elastic fault tolerance: deterministic failure plans, epoch-boundary
//! checkpoints, and crash-restart resume.
//!
//! # Model
//!
//! Failures land on epoch *boundaries* and heal entirely within them
//! (`FailureEvent` docs in [`crate::config`]). The training timeline —
//! schedules, caches, RPC counters, SGD steps — replays the failure-free
//! run bit-exactly; the *only* observables are:
//!
//! - a [`RecoveryReport`] block on the run report (movement rows/bytes,
//!   detoured bytes, recovery seconds, lost-work seconds), and
//! - in contended runs, the recovery flows' per-link utilization.
//!
//! Recovery traffic is priced through the *pure* link models
//! ([`crate::config::FabricConfig::rpc_time_on_link`]), never through
//! `NetFabric::charge_rpc`: charging would advance the global RPC counter
//! and shift the deterministic loss/retry cadences, which would change the
//! training timeline — exactly what the model forbids.
//!
//! # Checkpoints
//!
//! With `checkpoint_every = k`, a [`Checkpoint`] is written at every
//! boundary `e` with `e % k == 0` (after that boundary's failure events
//! apply). It captures everything a fresh process needs to replay the
//! remaining epochs bit-exactly: the config, the epoch reports so far, each
//! worker's strategy snapshot, the trainer weights/optimizer state (full
//! mode), the fabric's RPC/link counters and utilization telemetry, the
//! codec tally, and the accumulated recovery telemetry. [`resume_run`]
//! rebuilds the run from one and produces a [`RunReport`] byte-identical
//! to the uninterrupted run's.

use crate::config::{ExecMode, FailureEvent, FailurePlan, LinkKey, RunConfig};
use crate::coordinator::common::RunContext;
use crate::coordinator::pipeline::{run_cluster_epoch, setup_cluster};
use crate::coordinator::strategy::StrategyState;
use crate::coordinator::{assemble_report, build_trainer, SharedTrainer};
use crate::kvstore::CompressTally;
use crate::metrics::{EpochReport, RecoveryReport, RunReport};
use crate::net::{LinkStats, LinkUtilization};
use crate::trainer::{GradStats, TrainStep};
use crate::util::value::Value;
use crate::{Result, WorkerId};
use anyhow::{anyhow, bail, ensure};
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Everything a fresh process needs to continue a run from an epoch
/// boundary. Serialized as JSON via [`Value`].
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// The full run config; resume rebuilds the context from it.
    pub config: RunConfig,
    /// First epoch the resumed run executes. The boundary *entering* it
    /// (failure events and this checkpoint's write) is already accounted.
    pub next_epoch: u32,
    /// One-time setup cost of the original run.
    pub setup_time: f64,
    /// Per-worker epoch reports for epochs `0..next_epoch`.
    pub epochs: Vec<EpochReport>,
    /// Per-worker strategy snapshots (`TrainingStrategy::checkpoint_state`),
    /// indexed by worker id.
    pub strategy: Vec<Value>,
    /// Trainer weights/optimizer state (`TrainStep::save_state`); `None` in
    /// trace mode or for backends that cannot checkpoint.
    pub trainer: Option<Value>,
    /// Global RPC sequence counter (drives loss/retry cadence).
    pub rpc_counter: u64,
    /// Per-pair RPC counters.
    pub links: Vec<((WorkerId, WorkerId), LinkStats)>,
    /// Per-physical-link utilization telemetry (contended runs; empty
    /// otherwise).
    pub util: Vec<(LinkKey, LinkUtilization)>,
    /// Codec compression tally.
    pub tally: CompressTally,
    /// Recovery telemetry accumulated so far (includes this checkpoint's
    /// own write).
    pub recovery: RecoveryReport,
}

fn link_key_to_value(k: &LinkKey) -> Value {
    let mut v = Value::table();
    match *k {
        LinkKey::HostUp(w) => v.set("kind", "host-up").set("w", w),
        LinkKey::HostDown(w) => v.set("kind", "host-down").set("w", w),
        LinkKey::RackUp(r) => v.set("kind", "rack-up").set("r", r),
        LinkKey::RackDown(r) => v.set("kind", "rack-down").set("r", r),
        LinkKey::RingSeg { from, to } => {
            v.set("kind", "ring").set("from", from).set("to", to)
        }
        LinkKey::EdgeUp { pod, spine } => {
            v.set("kind", "edge-up").set("pod", pod).set("spine", spine)
        }
        LinkKey::EdgeDown { pod, spine } => {
            v.set("kind", "edge-down").set("pod", pod).set("spine", spine)
        }
        LinkKey::Local { group, a, b } => {
            v.set("kind", "dfly-local").set("group", group).set("a", a).set("b", b)
        }
        LinkKey::Global { from, to } => {
            v.set("kind", "dfly-global").set("from", from).set("to", to)
        }
    };
    v
}

fn link_key_from_value(v: &Value) -> Result<LinkKey> {
    Ok(match v.req_str("kind")? {
        "host-up" => LinkKey::HostUp(v.req_u32("w")?),
        "host-down" => LinkKey::HostDown(v.req_u32("w")?),
        "rack-up" => LinkKey::RackUp(v.req_u32("r")?),
        "rack-down" => LinkKey::RackDown(v.req_u32("r")?),
        "ring" => LinkKey::RingSeg { from: v.req_u32("from")?, to: v.req_u32("to")? },
        "edge-up" => LinkKey::EdgeUp { pod: v.req_u32("pod")?, spine: v.req_u32("spine")? },
        "edge-down" => LinkKey::EdgeDown { pod: v.req_u32("pod")?, spine: v.req_u32("spine")? },
        "dfly-local" => LinkKey::Local {
            group: v.req_u32("group")?,
            a: v.req_u32("a")?,
            b: v.req_u32("b")?,
        },
        "dfly-global" => LinkKey::Global { from: v.req_u32("from")?, to: v.req_u32("to")? },
        other => bail!("checkpoint: unknown link kind '{other}'"),
    })
}

impl Checkpoint {
    /// Serialize to a [`Value`] table.
    pub fn to_value(&self) -> Value {
        let mut v = Value::table();
        v.set("config", self.config.to_value())
            .set("next_epoch", self.next_epoch)
            .set("setup_time", self.setup_time)
            .set("epochs", self.epochs.iter().map(EpochReport::to_value).collect::<Vec<_>>())
            .set("strategy", self.strategy.clone())
            .set("rpc_counter", self.rpc_counter)
            .set("recovery", self.recovery.to_value());
        if let Some(t) = &self.trainer {
            v.set("trainer", t.clone());
        }
        let links: Vec<Value> = self
            .links
            .iter()
            .map(|&((src, dst), s)| {
                let mut lv = Value::table();
                lv.set("src", src)
                    .set("dst", dst)
                    .set("rpcs", s.rpcs)
                    .set("bytes", s.bytes)
                    .set("time", s.time)
                    .set("retries", s.retries);
                lv
            })
            .collect();
        v.set("links", links);
        let util: Vec<Value> = self
            .util
            .iter()
            .map(|(k, u)| {
                let mut uv = link_key_to_value(k);
                uv.set("capacity_bytes_per_sec", u.capacity_bytes_per_sec)
                    .set("busy_sec", u.busy_sec)
                    .set("served_bytes", u.served_bytes)
                    .set("flows", u.flows)
                    .set("peak_flows", u.peak_flows)
                    .set("peak_backlog_bytes", u.peak_backlog_bytes);
                uv
            })
            .collect();
        v.set("util", util);
        let mut tv = Value::table();
        tv.set("raw_bytes", self.tally.raw_bytes)
            .set("wire_bytes", self.tally.wire_bytes)
            .set("sq_err", self.tally.sq_err)
            .set("elems", self.tally.elems);
        v.set("tally", tv);
        v
    }

    /// Parse back from [`to_value`](Self::to_value)'s table.
    pub fn from_value(v: &Value) -> Result<Checkpoint> {
        let arr = |key: &str| -> Result<&[Value]> {
            match v.get(key) {
                Some(Value::Arr(items)) => Ok(items),
                _ => bail!("checkpoint: missing array '{key}'"),
            }
        };
        let mut epochs = Vec::new();
        for e in arr("epochs")? {
            epochs.push(EpochReport::from_value(e)?);
        }
        let mut links = Vec::new();
        for l in arr("links")? {
            links.push((
                (l.req_u32("src")?, l.req_u32("dst")?),
                LinkStats {
                    rpcs: l.req_u64("rpcs")?,
                    bytes: l.req_u64("bytes")?,
                    time: l.req_f64("time")?,
                    retries: l.req_u64("retries")?,
                },
            ));
        }
        let mut util = Vec::new();
        for u in arr("util")? {
            util.push((
                link_key_from_value(u)?,
                LinkUtilization {
                    capacity_bytes_per_sec: u.req_f64("capacity_bytes_per_sec")?,
                    busy_sec: u.req_f64("busy_sec")?,
                    served_bytes: u.req_f64("served_bytes")?,
                    flows: u.req_u64("flows")?,
                    peak_flows: u32::try_from(u.req_u64("peak_flows")?)?,
                    peak_backlog_bytes: u.req_f64("peak_backlog_bytes")?,
                },
            ));
        }
        let t = v.req_table("tally")?;
        Ok(Checkpoint {
            config: RunConfig::from_value(v.req_table("config")?)?,
            next_epoch: v.req_u32("next_epoch")?,
            setup_time: v.req_f64("setup_time")?,
            epochs,
            strategy: arr("strategy")?.to_vec(),
            trainer: v.get("trainer").cloned(),
            rpc_counter: v.req_u64("rpc_counter")?,
            links,
            util,
            tally: CompressTally {
                raw_bytes: t.req_u64("raw_bytes")?,
                wire_bytes: t.req_u64("wire_bytes")?,
                sq_err: t.req_f64("sq_err")?,
                elems: t.req_u64("elems")?,
            },
            recovery: RecoveryReport::from_value(v.req_table("recovery")?)?,
        })
    }

    /// Write as pretty JSON, creating parent directories.
    pub fn write(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_value().to_json_pretty())?;
        Ok(())
    }

    /// Load from a JSON file written by [`write`](Self::write).
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("checkpoint '{}': {e}", path.display()))?;
        Checkpoint::from_value(&Value::from_json(&text)?)
    }
}

/// Where the driver writes the checkpoint for the boundary entering
/// `epoch`: `cfg.checkpoint_dir` when set, else a `checkpoints/` dir next
/// to the run's schedule metadata (ephemeral for temp-dir runs — enough
/// for crash-rollback pricing, set an explicit dir to actually resume).
pub fn checkpoint_path(ctx: &RunContext, epoch: u32) -> PathBuf {
    let dir = if ctx.cfg.checkpoint_dir.is_empty() {
        ctx.metadata_path.join("checkpoints")
    } else {
        PathBuf::from(&ctx.cfg.checkpoint_dir)
    };
    dir.join(format!("checkpoint-{epoch}.json"))
}

/// Normalized (undirected) link endpoints for the downed-link set.
fn norm(a: WorkerId, b: WorkerId) -> (WorkerId, WorkerId) {
    (a.min(b), a.max(b))
}

/// The stateful boundary driver shared by fresh failure runs and resumed
/// runs: executes epochs one at a time through the cluster runtime and
/// interleaves failure events and checkpoint writes at the boundaries.
struct Driver<'a> {
    ctx: &'a RunContext,
    plan: FailurePlan,
    trainer: Option<SharedTrainer>,
    setup_time: f64,
    reports: Vec<EpochReport>,
    rec: RecoveryReport,
    /// Currently-downed links, as normalized endpoint pairs.
    down: BTreeSet<(WorkerId, WorkerId)>,
}

impl Driver<'_> {
    /// Run epochs `start..epochs`, processing the boundary entering each
    /// epoch after `start` (the boundary entering `start` itself is either
    /// epoch 0 — no boundary — or was processed before the checkpoint this
    /// run resumed from was written).
    fn drive(&mut self, states: &mut [StrategyState], start: u32) -> Result<()> {
        for epoch in start..self.ctx.cfg.epochs {
            if epoch > start {
                self.boundary(states, epoch)?;
            }
            let reps = run_cluster_epoch(self.ctx, self.trainer.clone(), states, epoch)?;
            self.reports.extend(reps);
        }
        Ok(())
    }

    /// The boundary entering `epoch`: apply its failure events in spec
    /// order, then write the checkpoint if one is due (the snapshot counts
    /// its own write, so resumed runs reproduce the counter exactly).
    fn boundary(&mut self, states: &[StrategyState], epoch: u32) -> Result<()> {
        let events: Vec<FailureEvent> = self.plan.events_at(epoch).copied().collect();
        for ev in events {
            self.apply(states, ev, epoch);
        }
        let every = self.ctx.cfg.checkpoint_every;
        if every > 0 && epoch % every == 0 {
            self.rec.checkpoints_written += 1;
            let ckpt = self.snapshot(states, epoch)?;
            ckpt.write(&checkpoint_path(self.ctx, epoch))?;
        }
        Ok(())
    }

    /// Journal one boundary failure event as a `recovery` trace record.
    /// Boundary events carry no intra-epoch virtual time, so `t = 0.0`.
    fn trace_recovery(&self, worker: WorkerId, epoch: u32, event: &str, mut fields: Value) {
        if let Some(trace) = &self.ctx.trace {
            fields.set("event", event);
            trace.event(worker, epoch, 0.0, "recovery", fields);
        }
    }

    fn apply(&mut self, states: &[StrategyState], ev: FailureEvent, epoch: u32) {
        self.rec.events += 1;
        match ev {
            FailureEvent::WorkerLeave { worker, .. } => {
                self.rec.worker_leaves += 1;
                self.trace_recovery(worker, epoch, "worker-leave", Value::table());
                self.move_shard(states, worker);
            }
            FailureEvent::WorkerJoin { worker, .. } => {
                self.rec.worker_joins += 1;
                self.trace_recovery(worker, epoch, "worker-join", Value::table());
                self.move_shard(states, worker);
            }
            FailureEvent::LinkDown { a, b, .. } => {
                self.rec.link_downs += 1;
                self.down.insert(norm(a, b));
                let mut fields = Value::table();
                fields.set("peer", a.max(b));
                self.trace_recovery(a.min(b), epoch, "link-down", fields);
            }
            FailureEvent::LinkUp { a, b, .. } => {
                self.rec.link_ups += 1;
                self.down.remove(&norm(a, b));
                let mut fields = Value::table();
                fields.set("peer", a.max(b));
                self.trace_recovery(a.min(b), epoch, "link-up", fields);
            }
            FailureEvent::CrashRestart { .. } => {
                self.rec.crash_restarts += 1;
                // Roll back to the last checkpoint boundary strictly before
                // this one (a checkpoint due *at* this boundary is written
                // after its events, so it doesn't exist yet); with none, the
                // whole prefix restarts. Replay is deterministic, so the
                // epochs are not re-executed here — the re-done span is
                // charged as lost wall-clock: the max over workers of their
                // rolled-back epoch time.
                let every = self.ctx.cfg.checkpoint_every;
                let rollback = if every > 0 { (epoch - 1) / every * every } else { 0 };
                let mut lost = vec![0.0f64; self.ctx.cfg.num_workers as usize];
                for r in &self.reports {
                    if r.epoch >= rollback && r.epoch < epoch {
                        lost[r.worker as usize] += r.epoch_time;
                    }
                }
                let lost_max = lost.iter().cloned().fold(0.0, f64::max);
                self.rec.lost_work_time += lost_max;
                let mut fields = Value::table();
                fields.set("rollback_to", rollback);
                fields.set("lost_sec", lost_max);
                self.trace_recovery(0, epoch, "crash-restart", fields);
            }
        }
    }

    /// Price the shard + warm-cache move a membership change triggers: the
    /// adopting host pulls the departing worker's partition rows and its
    /// hot-cache rows from the smallest surviving peer.
    fn move_shard(&mut self, states: &[StrategyState], worker: WorkerId) {
        let ctx = self.ctx;
        let owned = ctx.part.owner.iter().filter(|&&o| o == worker).count() as u64;
        let cached = ctx.strategy.cache_rows(&states[worker as usize], worker);
        let rows = owned + cached;
        let bytes = rows * ctx.kv.feature_dim() as u64 * 4;
        let donor = (0..ctx.cfg.num_workers)
            .find(|&w| w != worker)
            .expect("plan validation requires >= 2 workers for leave/join");
        self.rec.moved_rows += rows;
        self.rec.moved_bytes += bytes;
        self.price_flow(donor, worker, bytes, rows);
    }

    /// Price a recovery flow through the pure link models (never through
    /// `charge_rpc` — see module docs). Flows between endpoints of a downed
    /// link detour through the smallest third worker, two hops.
    fn price_flow(&mut self, src: WorkerId, dst: WorkerId, bytes: u64, rows: u64) {
        let fc = self.ctx.fabric.config();
        let world = self.ctx.fabric.world_size();
        let wire = bytes + 64; // same 64B RPC envelope the fabric charges
        if self.down.contains(&norm(src, dst)) {
            let via = (0..world).find(|&w| w != src && w != dst).unwrap_or(src);
            self.rec.rerouted_bytes += bytes;
            self.rec.recovery_time += fc.rpc_time_on_link(src, via, world, wire, rows)
                + fc.rpc_time_on_link(via, dst, world, wire, rows);
            self.feed_links(src, via, bytes);
            self.feed_links(via, dst, bytes);
        } else {
            self.rec.recovery_time += fc.rpc_time_on_link(src, dst, world, wire, rows);
            self.feed_links(src, dst, bytes);
        }
    }

    /// Surface a recovery flow in the contended per-link telemetry so
    /// `RunReport.links` accounts for recovery traffic. One uncontended
    /// store-and-forward pass per hop; no-op outside contention mode.
    fn feed_links(&mut self, src: WorkerId, dst: WorkerId, bytes: u64) {
        if !self.ctx.cfg.fabric.contention {
            return;
        }
        let fc = self.ctx.fabric.config();
        let world = self.ctx.fabric.world_size();
        let entries: Vec<(LinkKey, LinkUtilization)> = fc
            .route(src, dst, world)
            .into_iter()
            .map(|hop| {
                (
                    hop.link,
                    LinkUtilization {
                        capacity_bytes_per_sec: hop.bandwidth_bytes_per_sec,
                        busy_sec: bytes as f64 / hop.bandwidth_bytes_per_sec,
                        served_bytes: bytes as f64,
                        flows: 1,
                        peak_flows: 1,
                        peak_backlog_bytes: bytes as f64,
                    },
                )
            })
            .collect();
        self.ctx.fabric.record_link_utilization(entries);
    }

    /// Snapshot the full run state at the boundary entering `next_epoch`.
    fn snapshot(&self, states: &[StrategyState], next_epoch: u32) -> Result<Checkpoint> {
        let ctx = self.ctx;
        let mut strategy = Vec::with_capacity(states.len());
        for (w, st) in states.iter().enumerate() {
            strategy.push(ctx.strategy.checkpoint_state(ctx, st, w as WorkerId)?);
        }
        let trainer = match &self.trainer {
            Some(t) => t.lock().unwrap().save_state(),
            None => None,
        };
        let (rpc_counter, links) = ctx.fabric.export_counters();
        Ok(Checkpoint {
            config: ctx.cfg.clone(),
            next_epoch,
            setup_time: self.setup_time,
            epochs: self.reports.clone(),
            strategy,
            trainer,
            rpc_counter,
            links,
            util: ctx.fabric.link_utilization(),
            tally: ctx.kv.compression_tally(),
            recovery: self.rec.clone(),
        })
    }
}

/// Execute a run with a failure plan and/or periodic checkpoints: the
/// cluster runtime driven one epoch at a time, boundaries interleaved.
/// Returns `(setup_time, epoch_reports, recovery, grad_stats)`.
pub(crate) fn run_with_failures(
    ctx: &RunContext,
    trainer_override: Option<Box<dyn TrainStep>>,
) -> Result<(f64, Vec<EpochReport>, RecoveryReport, Option<GradStats>)> {
    let cfg = &ctx.cfg;
    let plan = cfg.failure_plan()?;
    plan.validate(cfg.num_workers, cfg.epochs)?;
    let trainer: Option<SharedTrainer> = match cfg.exec_mode {
        ExecMode::Full => {
            let t = match trainer_override {
                Some(t) => t,
                None => build_trainer(ctx)?,
            };
            Some(Arc::new(Mutex::new(t)))
        }
        // Wallclock is trace scheduling on a real transport backend: no
        // trainer, and the recovery driver is backend-agnostic.
        ExecMode::Trace | ExecMode::Wallclock => None,
    };
    let (setup_time, mut states) = setup_cluster(ctx)?;
    let mut d = Driver {
        ctx,
        plan,
        trainer,
        setup_time,
        reports: Vec::new(),
        rec: RecoveryReport::default(),
        down: BTreeSet::new(),
    };
    d.drive(&mut states, 0)?;
    let grad = d.trainer.as_ref().and_then(|t| t.lock().unwrap().grad_stats());
    Ok((d.setup_time, d.reports, d.rec, grad))
}

/// Resume a run from a checkpoint file and run it to completion. The
/// resulting [`RunReport`] serializes byte-identically to the
/// uninterrupted run's.
pub fn resume_run(path: &Path) -> Result<RunReport> {
    resume_from(Checkpoint::load(path)?)
}

/// [`resume_run`] on an already-loaded checkpoint.
pub fn resume_from(ckpt: Checkpoint) -> Result<RunReport> {
    let cfg = ckpt.config.clone();
    ensure!(
        ckpt.next_epoch < cfg.epochs,
        "checkpoint resumes at epoch {} but the run has {} epochs",
        ckpt.next_epoch,
        cfg.epochs
    );
    ensure!(
        ckpt.strategy.len() == cfg.num_workers as usize,
        "checkpoint has {} worker snapshots for {} workers",
        ckpt.strategy.len(),
        cfg.num_workers
    );
    let ctx = RunContext::build(&cfg)?;
    // Restore the fabric's RPC/link counters (loss/retry cadence position)
    // and the codec tally so the resumed report matches bit-exactly.
    ctx.fabric.import_counters(ckpt.rpc_counter, &ckpt.links);
    ctx.fabric.record_link_utilization(ckpt.util.clone());
    ctx.kv.import_compression_tally(ckpt.tally);
    // Rebuild each worker's strategy state from its snapshot. Restoration
    // re-enumerates schedule metadata and re-materializes cache rows
    // without charging the fabric — the original run already paid.
    let mut states: Vec<StrategyState> = Vec::with_capacity(ckpt.strategy.len());
    for (w, snap) in ckpt.strategy.iter().enumerate() {
        let s = ctx.strategy.restore_setup(&ctx, w as WorkerId, ckpt.next_epoch, snap)?;
        states.push(s.state);
    }
    if cfg.fabric.contention {
        drop(ctx.fabric.take_route_claims());
    }
    let trainer: Option<SharedTrainer> = match cfg.exec_mode {
        ExecMode::Full => {
            let tv = ckpt.trainer.as_ref().ok_or_else(|| {
                anyhow!("checkpoint has no trainer state; cannot resume a full-mode run")
            })?;
            let mut t = build_trainer(&ctx)?;
            t.load_state(tv)?;
            Some(Arc::new(Mutex::new(t)))
        }
        // Wallclock is trace scheduling on a real transport backend: no
        // trainer, and the recovery driver is backend-agnostic.
        ExecMode::Trace | ExecMode::Wallclock => None,
    };
    let plan = cfg.failure_plan()?;
    // The downed-link set at checkpoint time is a pure fold of the plan
    // over boundaries up to and including the checkpoint's (its boundary's
    // events applied before the write), so it isn't stored.
    let mut down = BTreeSet::new();
    for b in 1..=ckpt.next_epoch {
        for ev in plan.events_at(b) {
            match *ev {
                FailureEvent::LinkDown { a, b: other, .. } => {
                    down.insert(norm(a, other));
                }
                FailureEvent::LinkUp { a, b: other, .. } => {
                    down.remove(&norm(a, other));
                }
                _ => {}
            }
        }
    }
    let start = ckpt.next_epoch;
    let mut d = Driver {
        ctx: &ctx,
        plan,
        trainer,
        setup_time: ckpt.setup_time,
        reports: ckpt.epochs,
        rec: ckpt.recovery,
        down,
    };
    d.drive(&mut states, start)?;
    let grad = d.trainer.as_ref().and_then(|t| t.lock().unwrap().grad_stats());
    let (setup_time, reports, rec) = (d.setup_time, d.reports, d.rec);
    assemble_report(&ctx, setup_time, reports, grad, Some(rec))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetPreset, Engine};
    use crate::util::tempdir::TempDir;

    fn cfg() -> RunConfig {
        let mut c = RunConfig::default();
        c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
        c.engine = Engine::Rapid;
        c.epochs = 3;
        c.n_hot = 300;
        c
    }

    #[test]
    fn checkpoint_json_round_trip_is_bit_exact() {
        let ckpt = Checkpoint {
            config: cfg(),
            next_epoch: 2,
            setup_time: 1.25,
            epochs: Vec::new(),
            strategy: vec![Value::table(), Value::table()],
            trainer: None,
            rpc_counter: 17,
            links: vec![((0, 1), LinkStats { rpcs: 3, bytes: 4096, time: 0.5, retries: 1 })],
            util: vec![(
                LinkKey::RingSeg { from: 0, to: 1 },
                LinkUtilization {
                    capacity_bytes_per_sec: 1e9,
                    busy_sec: 0.25,
                    served_bytes: 2048.0,
                    flows: 2,
                    peak_flows: 1,
                    peak_backlog_bytes: 1024.0,
                },
            )],
            tally: CompressTally { raw_bytes: 100, wire_bytes: 30, sq_err: 0.5, elems: 25 },
            recovery: RecoveryReport { events: 2, link_downs: 1, ..Default::default() },
        };
        let json = ckpt.to_value().to_json_pretty();
        let back = Checkpoint::from_value(&Value::from_json(&json).unwrap()).unwrap();
        assert_eq!(json, back.to_value().to_json_pretty());
        assert_eq!(back.next_epoch, 2);
        assert_eq!(back.links[0].1.bytes, 4096);
        assert_eq!(back.util[0].0, LinkKey::RingSeg { from: 0, to: 1 });
        assert_eq!(back.recovery.link_downs, 1);
    }

    #[test]
    fn failure_run_reports_recovery_and_replays_the_timeline() {
        let mut c = cfg();
        c.failures = "linkdown:0-1@1,leave:1@1,linkup:0-1@2,crash@2".into();
        c.checkpoint_every = 1;
        let report = crate::coordinator::run(&c).unwrap();
        let rec = report.recovery.as_ref().expect("failure run reports recovery");
        assert_eq!(rec.events, 4);
        assert_eq!(rec.worker_leaves, 1);
        assert_eq!(rec.link_downs, 1);
        assert_eq!(rec.link_ups, 1);
        assert_eq!(rec.crash_restarts, 1);
        assert_eq!(rec.checkpoints_written, 2, "boundaries 1 and 2");
        assert!(rec.moved_rows > 0 && rec.moved_bytes > 0);
        assert!(rec.rerouted_bytes > 0, "move at boundary 1 crosses the downed 0-1 link");
        assert!(rec.recovery_time > 0.0);
        assert!(rec.lost_work_time > 0.0, "crash at 2 rolls back to the boundary-1 checkpoint");

        // The training timeline is untouched: per-(worker, epoch) counters
        // equal the failure-free run's.
        let clean = crate::coordinator::run(&cfg()).unwrap();
        assert!(clean.recovery.is_none());
        let key = |e: &EpochReport| (e.worker, e.epoch);
        let mut a = report.epochs.clone();
        let mut b = clean.epochs.clone();
        a.sort_by_key(key);
        b.sort_by_key(key);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(key(x), key(y));
            assert_eq!(x.comm.remote_rows, y.comm.remote_rows);
            assert_eq!(x.cache.hits, y.cache.hits);
            assert_eq!(x.steps, y.steps);
        }
    }

    #[test]
    fn resume_reproduces_the_uninterrupted_report_bit_exactly() {
        let dir = TempDir::new("ckpt").unwrap();
        let mut c = cfg();
        c.checkpoint_every = 1;
        c.checkpoint_dir = dir.path().to_str().unwrap().to_string();
        let full = crate::coordinator::run(&c).unwrap();
        // Simulate a kill after the boundary-1 checkpoint landed: resume
        // from it in a fresh context and compare the serialized reports.
        let resumed = resume_run(&dir.path().join("checkpoint-1.json")).unwrap();
        assert_eq!(full.to_value().to_json_pretty(), resumed.to_value().to_json_pretty());
    }

    #[test]
    fn resume_past_the_last_epoch_is_rejected() {
        let ckpt = Checkpoint {
            config: cfg(),
            next_epoch: 3,
            setup_time: 0.0,
            epochs: Vec::new(),
            strategy: vec![Value::table(), Value::table()],
            trainer: None,
            rpc_counter: 0,
            links: Vec::new(),
            util: Vec::new(),
            tally: CompressTally::default(),
            recovery: RecoveryReport::default(),
        };
        assert!(resume_from(ckpt).is_err());
    }
}
