//! The RapidGNN engine: Algorithm 1 end to end.
//!
//! Per worker:
//! 1. **Precompute** (offline, once): enumerate every epoch's schedule with
//!    derived seeds and stream the metadata blocks to SSD (setup time, not
//!    training time — reported separately like the paper).
//! 2. **Initial cache build**: stream epoch 0's blocks back, rank remote
//!    accesses (`TopHot`), and materialize the steady cache `C_s` with one
//!    `VectorPull`.
//! 3. **Per epoch**: a prefetcher walks the streamed schedule, staging each
//!    batch cache-first with residual `SyncPull` misses into the bounded
//!    queue; the trainer consumes. In parallel (accounted as background
//!    time), `C_sec` for epoch e+1 is ranked, pulled, and swapped in at the
//!    boundary. Per-step times go through the bounded-queue pipeline model,
//!    which is what produces the paper's communication-hiding behaviour.

use super::common::RunContext;
use super::SharedTrainer;
use crate::cache::{top_hot, CacheBuffer, DoubleBufferCache};
use crate::config::ExecMode;
use crate::metrics::{CommStats, EpochReport, PhaseTimes};
use crate::prefetch::{stage_batch, Prefetcher, StagedBatch};
use crate::sampler::{enumerate_epoch, remote_frequency, BatchMeta};
use crate::sim::{pipeline_schedule, ClusterSim, PipelineStep, WorkerActor};
use crate::storage::{write_epoch, EpochReader};
use crate::trainer::TrainStep;
use crate::util::mpmc;
use crate::{NodeId, Result, WorkerId};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Setup products of the precompute pass.
pub struct RapidSetup {
    /// Simulated setup seconds (offline sampling + SSD writes + initial
    /// ranking + initial VectorPull).
    pub setup_time: f64,
    /// Comm stats of the initial cache build (merged into epoch 0's report).
    pub setup_comm: CommStats,
    /// The double-buffered cache with `C_s` installed for epoch 0.
    pub cache: Arc<Mutex<DoubleBufferCache>>,
}

/// Precompute all epochs to disk and build the initial steady cache.
///
/// The enumeration itself fans out over all cores (`enumerate_epoch`
/// parallelizes over batches — deterministic by the per-batch derived
/// seeds, see `sampler::schedule`). Epoch 0's `TopHot` ranking runs from
/// the in-memory schedule — the SSD read-back the old path paid is gone —
/// and is accounted as background work overlapping the later epochs' write
/// stream: only its overrun past that stream lands on setup time (the same
/// overrun idiom `run_worker` uses for the `C_sec` builds).
pub fn precompute(ctx: &RunContext, worker: WorkerId) -> Result<RapidSetup> {
    let cfg = &ctx.cfg;
    let fanouts = ctx.fanouts();
    let mut setup_time = 0.0;

    // Offline enumeration, streamed epoch by epoch (bounded CPU memory).
    let mut hot: Vec<NodeId> = Vec::new();
    let mut rank_time = 0.0;
    let mut later_stream_time = 0.0;
    for epoch in 0..cfg.epochs {
        let sched = enumerate_epoch(
            &ctx.ds.graph,
            &ctx.part,
            &ctx.shards[worker as usize],
            &fanouts,
            cfg.batch_size,
            cfg.base_seed,
            worker,
            epoch,
        );
        for b in &sched.batches {
            setup_time += ctx.costs.sample_time(b.input_nodes.len());
            let write = b.byte_size() as f64 / ctx.costs.ssd_bytes_per_sec;
            setup_time += write;
            if epoch > 0 {
                later_stream_time += write;
            }
        }
        write_epoch(&ctx.metadata_path, &sched)?;
        if epoch == 0 {
            rank_time = sched.total_remote() as f64 * ctx.costs.rank_per_access_sec;
            hot = top_hot(&sched.batches, cfg.n_hot);
        }
    }
    // Epoch 0's ranking runs in the background of the remaining epochs'
    // writes; only the overrun is serial setup time.
    setup_time += (rank_time - later_stream_time).max(0.0);

    // Initial cache: pull the top-n_hot features in one VectorPull.
    let mut setup_comm = CommStats::default();
    let mut rows: Vec<f32> = Vec::new();
    let materialize = cfg.exec_mode == ExecMode::Full;
    let pull = ctx.kv.vector_pull(
        worker,
        &hot,
        if materialize { Some(&mut rows) } else { None },
        &mut setup_comm,
    );
    setup_time += pull.time;
    let mut cache = DoubleBufferCache::default();
    cache.install_steady(CacheBuffer::new(&hot, rows, ctx.kv.feature_dim()));

    Ok(RapidSetup {
        setup_time,
        setup_comm,
        cache: Arc::new(Mutex::new(cache)),
    })
}

/// Stream one epoch's blocks from SSD and rank its remote accesses (the
/// background `C_sec` build for epoch e+1). Returns the top-`n_hot` node
/// list and the simulated background time (stream read + frequency tally —
/// the tally itself runs on the sharded parallel ranking in `top_hot`).
fn stream_top_hot(ctx: &RunContext, worker: WorkerId, epoch: u32) -> Result<(Vec<NodeId>, f64)> {
    let mut reader = EpochReader::open(&ctx.metadata_path, worker, epoch)?;
    let mut batches: Vec<BatchMeta> = Vec::with_capacity(reader.num_batches as usize);
    let mut time = 0.0;
    let mut accesses = 0u64;
    while let Some(b) = reader.next_batch()? {
        time += ctx.costs.stream_time(b.byte_size());
        accesses += b.num_remote as u64;
        batches.push(b);
    }
    time += accesses as f64 * ctx.costs.rank_per_access_sec;
    let hot = top_hot(&batches, ctx.cfg.n_hot);
    Ok((hot, time))
}

/// Run one worker's full RapidGNN training. `trainer` present in full mode.
pub fn run_worker(
    ctx: &RunContext,
    worker: WorkerId,
    mut trainer: Option<&mut (dyn TrainStep + 'static)>,
) -> Result<(f64, Vec<EpochReport>)> {
    let setup = precompute(ctx, worker)?;
    let cfg = &ctx.cfg;
    let full = cfg.exec_mode == ExecMode::Full;
    let d = cfg.dataset.feature_dim;
    let cache = setup.cache;
    let mut reports = Vec::with_capacity(cfg.epochs as usize);

    for epoch in 0..cfg.epochs {
        cache.lock().unwrap().reset_stats();
        let mut comm = CommStats::default();
        if epoch == 0 {
            comm.merge(&setup.setup_comm); // initial VectorPull bytes
        }
        let mut steps: Vec<PipelineStep> = Vec::new();
        let mut phases = PhaseTimes::default();
        let mut m_max = 0u64;
        let (mut loss_sum, mut correct, mut total) = (0.0f64, 0u64, 0u64);

        // --- consume staged batches (threaded prefetcher in full mode for
        // real overlap; inline staging in trace mode for sweep speed — both
        // produce identical staged content, see prefetch tests).
        let mut acc = EpochAcc::default();
        if full {
            let reader = EpochReader::open(&ctx.metadata_path, worker, epoch)?;
            let source = Box::new(ReaderIter { reader });
            let pf = Prefetcher::spawn(
                ctx.kv.clone(),
                cache.clone(),
                source,
                cfg.prefetch_q,
                worker,
                true,
            );
            let mut consumed = 0u32;
            while let Some(staged) = pf.recv() {
                consumed += 1;
                consume_staged(
                    ctx,
                    worker,
                    epoch,
                    staged,
                    &mut phases,
                    &mut steps,
                    &mut acc,
                    trainer.as_deref_mut(),
                );
            }
            comm.merge(&pf.join());
            // Trainer-side race fallback (Algorithm 1 / §3: "if a complete
            // batch is not found in the Prefetcher, the features of that
            // batch are fetched through the default path"). If the
            // prefetcher died or fell behind and never delivered the tail of
            // the schedule, re-open the stream and serve the remaining
            // batches on-demand so no training step is lost.
            let mut check = EpochReader::open(&ctx.metadata_path, worker, epoch)?;
            if consumed < check.num_batches {
                let mut skipped = consumed;
                while let Some(meta) = check.next_batch()? {
                    if skipped > 0 {
                        skipped -= 1;
                        continue;
                    }
                    let staged = stage_batch(&ctx.kv, &cache, meta, worker, true, &mut comm);
                    consume_staged(
                        ctx,
                        worker,
                        epoch,
                        staged,
                        &mut phases,
                        &mut steps,
                        &mut acc,
                        trainer.as_deref_mut(),
                    );
                }
            }
        } else {
            let mut reader = EpochReader::open(&ctx.metadata_path, worker, epoch)?;
            while let Some(meta) = reader.next_batch()? {
                let staged = stage_batch(&ctx.kv, &cache, meta, worker, false, &mut comm);
                consume_staged(ctx, worker, epoch, staged, &mut phases, &mut steps, &mut acc, None);
            }
        }
        m_max = m_max.max(acc.m_max);
        loss_sum += acc.loss_sum;
        correct += acc.correct;
        total += acc.total;

        // --- background C_sec build for the next epoch (accounted as
        // parallel work; only its *overrun* past the epoch stalls the swap).
        let mut bg_time = 0.0;
        if epoch + 1 < cfg.epochs {
            let (hot, rank_time) = stream_top_hot(ctx, worker, epoch + 1)?;
            // local work (stream read + ranking) carries the straggler
            // slowdown; the VectorPull below is priced per-link by the fabric
            bg_time += ctx.slowdown(worker) * rank_time;
            let mut rows: Vec<f32> = Vec::new();
            let pull = ctx.kv.vector_pull(
                worker,
                &hot,
                if full { Some(&mut rows) } else { None },
                &mut comm,
            );
            bg_time += pull.time;
            cache
                .lock()
                .unwrap()
                .stage_secondary(CacheBuffer::new(&hot, rows, ctx.kv.feature_dim()));
        }

        // --- pipeline schedule → epoch time
        let times = pipeline_schedule(&steps, cfg.prefetch_q);
        let overrun = (bg_time - times.total).max(0.0);
        phases.fetch = times.total_wait; // residual stalls visible to trainer
        phases.idle = overrun;
        let epoch_time = times.total + overrun;

        let (cache_stats, device_cache_bytes) = {
            let mut c = cache.lock().unwrap();
            let s = c.stats();
            let bytes = c.device_bytes();
            c.swap_at_epoch_boundary();
            (s, bytes)
        };

        let steps_n = steps.len() as u32;
        reports.push(EpochReport {
            epoch,
            worker,
            steps: steps_n,
            epoch_time,
            phases,
            comm,
            cache: cache_stats,
            mean_loss: if full { loss_sum / steps_n.max(1) as f64 } else { f64::NAN },
            train_acc: if full && total > 0 {
                correct as f64 / total as f64
            } else {
                f64::NAN
            },
            // Paper bound: 2·n_hot·d + Q·m_max·d (both cache buffers + the
            // staged queue). Trace mode reports the bound-equivalent since
            // rows aren't materialized.
            device_bytes: device_cache_bytes.max(2 * cfg.n_hot as u64 * d as u64 * 4)
                + cfg.prefetch_q as u64 * m_max * d as u64 * 4,
            // Streaming keeps host memory at one batch + the ranking tally.
            host_bytes: m_max * 8 + cfg.n_hot as u64 * 12,
        });
    }
    Ok((setup.setup_time, reports))
}

/// Per-epoch accumulators for the consume loop.
#[derive(Default)]
struct EpochAcc {
    m_max: u64,
    loss_sum: f64,
    correct: u64,
    total: u64,
}

/// Consume one staged batch: charge assemble+compute (measured in full mode),
/// record the pipeline step, and run the real train step when present.
#[allow(clippy::too_many_arguments)]
fn consume_staged(
    ctx: &RunContext,
    worker: WorkerId,
    epoch: u32,
    staged: StagedBatch,
    phases: &mut PhaseTimes,
    steps: &mut Vec<PipelineStep>,
    acc: &mut EpochAcc,
    trainer: Option<&mut (dyn TrainStep + 'static)>,
) {
    let full = ctx.cfg.exec_mode == ExecMode::Full;
    let d = ctx.cfg.dataset.feature_dim;
    let slow = ctx.slowdown(worker);
    let n_input = staged.meta.input_nodes.len();
    acc.m_max = acc.m_max.max(n_input as u64);
    // Straggler slowdown scales only the local staging work (SSD stream +
    // cache lookups); the SyncPull part is already charged per-link by the
    // topology-aware fabric.
    let stage_time = staged.pull_time
        + slow * (staged.stage_time - staged.pull_time + ctx.costs.stream_time(staged.meta.byte_size()));
    let assemble = slow * ctx.costs.assemble_time(n_input, d);
    let compute = if full {
        let t0 = Instant::now();
        let out = super::baseline::full_train_step(
            ctx,
            worker,
            epoch,
            &staged.meta,
            staged.features.unwrap_or_default(),
            trainer,
        );
        acc.loss_sum += out.0;
        acc.correct += out.1 as u64;
        acc.total += out.2 as u64;
        t0.elapsed().as_secs_f64()
    } else {
        slow * ctx.compute_time(n_input, staged.meta.seeds.len())
    };
    phases.assemble += assemble;
    phases.compute += compute;
    steps.push(PipelineStep { stage: stage_time, consume: assemble + compute });
}

/// Adapter: streaming [`EpochReader`] as an iterator for the prefetcher.
struct ReaderIter {
    reader: EpochReader,
}

impl Iterator for ReaderIter {
    type Item = BatchMeta;
    fn next(&mut self) -> Option<BatchMeta> {
        self.reader.next_batch().ok().flatten()
    }
}

/// One worker's sampler → prefetcher → trainer pipeline for one epoch, as a
/// [`WorkerActor`] driven by the [`ClusterSim`] event loop.
///
/// The prefetcher stage streams the precomputed schedule from SSD and stages
/// each batch cache-first (residual `SyncPull` misses charged against the
/// topology-aware fabric); staged batches flow to the trainer stage over a
/// bounded [`mpmc`] ring of depth `Q` — the same queue semantics the
/// threaded [`Prefetcher`] uses, here popped in exact virtual-time order. In
/// full mode the trainer stage runs the real shared-model train step when it
/// fires, so cross-worker SGD interleaving is resolved by the virtual clock
/// (deterministically — all virtual costs come from the analytic models).
struct RapidEpochActor<'a> {
    ctx: &'a RunContext,
    worker: WorkerId,
    epoch: u32,
    reader: EpochReader,
    cache: Arc<Mutex<DoubleBufferCache>>,
    trainer: Option<SharedTrainer>,
    /// Local-work slowdown (straggler injection); 1.0 normally.
    slow: f64,
    full: bool,
    queue_tx: mpmc::Sender<StagedBatch>,
    queue_rx: mpmc::Receiver<StagedBatch>,
    comm: CommStats,
    phases: PhaseTimes,
    acc: EpochAcc,
    /// Set when the metadata stream failed mid-read; surfaced as an error by
    /// `run_cluster` after the simulation drains (the actor interface can't
    /// propagate it, and silently truncating the epoch would lose steps).
    read_error: Option<anyhow::Error>,
}

impl<'a> RapidEpochActor<'a> {
    fn new(
        ctx: &'a RunContext,
        worker: WorkerId,
        epoch: u32,
        reader: EpochReader,
        cache: Arc<Mutex<DoubleBufferCache>>,
        trainer: Option<SharedTrainer>,
        comm: CommStats,
    ) -> Self {
        let (queue_tx, queue_rx) = mpmc::bounded(ctx.cfg.prefetch_q.max(1) as usize);
        RapidEpochActor {
            worker,
            epoch,
            reader,
            cache,
            trainer,
            slow: ctx.slowdown(worker),
            full: ctx.cfg.exec_mode == ExecMode::Full,
            queue_tx,
            queue_rx,
            comm,
            phases: PhaseTimes::default(),
            acc: EpochAcc::default(),
            read_error: None,
            ctx,
        }
    }
}

impl WorkerActor for RapidEpochActor<'_> {
    fn stage_next(&mut self) -> Option<f64> {
        let meta = match self.reader.next_batch() {
            Ok(Some(m)) => m,
            Ok(None) => return None,
            Err(e) => {
                self.read_error = Some(e);
                return None;
            }
        };
        let stream = self.ctx.costs.stream_time(meta.byte_size());
        let staged =
            stage_batch(&self.ctx.kv, &self.cache, meta, self.worker, self.full, &mut self.comm);
        // Network part at the fabric's per-link price; local part (stream +
        // cache lookups) scaled by the straggler slowdown — the same split
        // `consume_staged` applies on the trace path.
        let cost = staged.pull_time + self.slow * (staged.stage_time - staged.pull_time + stream);
        if self.queue_tx.try_send(staged).is_err() {
            panic!("cluster scheduler overflowed the bounded staging queue");
        }
        Some(cost)
    }

    fn consume_next(&mut self) -> f64 {
        let staged = self
            .queue_rx
            .try_recv()
            .expect("scheduler consumes only staged batches");
        let n_input = staged.meta.input_nodes.len();
        self.acc.m_max = self.acc.m_max.max(n_input as u64);
        let d = self.ctx.cfg.dataset.feature_dim;
        let assemble = self.slow * self.ctx.costs.assemble_time(n_input, d);
        let compute = self.slow * self.ctx.compute_time(n_input, staged.meta.seeds.len());
        if self.full {
            // Virtual time uses the analytic model (deterministic event
            // order + reproducible epoch times); the real step still runs.
            let out = match &self.trainer {
                Some(tr) => {
                    let mut t = tr.lock().unwrap();
                    super::baseline::full_train_step(
                        self.ctx,
                        self.worker,
                        self.epoch,
                        &staged.meta,
                        staged.features.unwrap_or_default(),
                        Some(&mut **t),
                    )
                }
                None => (f64::NAN, 0, 0),
            };
            self.acc.loss_sum += out.0;
            self.acc.correct += out.1 as u64;
            self.acc.total += out.2 as u64;
        }
        self.phases.assemble += assemble;
        self.phases.compute += compute;
        assemble + compute
    }
}

/// Run all workers' RapidGNN training concurrently on the shared virtual
/// clock — the event-driven replacement for the old sequential full-mode
/// loop. Per epoch, every worker's pipeline advances together in one
/// [`ClusterSim`]; between epochs each worker does its background `C_sec`
/// build and cache swap exactly as [`run_worker`] does, so the two paths
/// report identical communication counters (pinned by the conformance
/// tests). Returns (max setup time, per-(worker, epoch) reports).
pub fn run_cluster(
    ctx: &RunContext,
    trainer: Option<SharedTrainer>,
) -> Result<(f64, Vec<EpochReport>)> {
    let cfg = &ctx.cfg;
    let full = cfg.exec_mode == ExecMode::Full;
    let d = cfg.dataset.feature_dim;

    // Offline precompute per worker (setup time, reported separately).
    let mut setup_time = 0.0f64;
    let mut caches: Vec<Arc<Mutex<DoubleBufferCache>>> = Vec::new();
    let mut setup_comms: Vec<CommStats> = Vec::new();
    for w in 0..cfg.num_workers {
        let s = precompute(ctx, w)?;
        setup_time = setup_time.max(s.setup_time);
        caches.push(s.cache);
        setup_comms.push(s.setup_comm);
    }

    let mut reports = Vec::with_capacity((cfg.num_workers * cfg.epochs) as usize);
    for epoch in 0..cfg.epochs {
        let mut sim = ClusterSim::new();
        for w in 0..cfg.num_workers {
            caches[w as usize].lock().unwrap().reset_stats();
            let mut comm = CommStats::default();
            if epoch == 0 {
                comm.merge(&setup_comms[w as usize]); // initial VectorPull bytes
            }
            let reader = EpochReader::open(&ctx.metadata_path, w, epoch)?;
            sim.add_worker(
                cfg.prefetch_q,
                RapidEpochActor::new(ctx, w, epoch, reader, caches[w as usize].clone(), trainer.clone(), comm),
            );
        }
        for (w, done) in sim.run().into_iter().enumerate() {
            let worker = w as WorkerId;
            let timeline = done.timeline;
            let mut actor = done.actor;
            if let Some(e) = actor.read_error.take() {
                return Err(e.context(format!(
                    "metadata stream for worker {worker} epoch {epoch} failed mid-read"
                )));
            }
            let cache = &caches[w];

            // Background C_sec build for the next epoch (overrun accounting
            // identical to run_worker).
            let mut bg_time = 0.0;
            if epoch + 1 < cfg.epochs {
                let (hot, rank_time) = stream_top_hot(ctx, worker, epoch + 1)?;
                // same slowdown split as run_worker: local stream+rank work
                // scaled, VectorPull priced per-link by the fabric
                bg_time += ctx.slowdown(worker) * rank_time;
                let mut rows: Vec<f32> = Vec::new();
                let pull = ctx.kv.vector_pull(
                    worker,
                    &hot,
                    if full { Some(&mut rows) } else { None },
                    &mut actor.comm,
                );
                bg_time += pull.time;
                cache
                    .lock()
                    .unwrap()
                    .stage_secondary(CacheBuffer::new(&hot, rows, ctx.kv.feature_dim()));
            }

            let overrun = (bg_time - timeline.makespan).max(0.0);
            let mut phases = actor.phases;
            phases.fetch = timeline.total_wait; // residual stalls visible to trainer
            phases.idle = overrun;
            let epoch_time = timeline.makespan + overrun;

            let (cache_stats, device_cache_bytes) = {
                let mut c = cache.lock().unwrap();
                let s = c.stats();
                let bytes = c.device_bytes();
                c.swap_at_epoch_boundary();
                (s, bytes)
            };

            let steps_n = timeline.steps() as u32;
            let m_max = actor.acc.m_max;
            reports.push(EpochReport {
                epoch,
                worker,
                steps: steps_n,
                epoch_time,
                phases,
                comm: actor.comm,
                cache: cache_stats,
                mean_loss: if full {
                    actor.acc.loss_sum / steps_n.max(1) as f64
                } else {
                    f64::NAN
                },
                train_acc: if full && actor.acc.total > 0 {
                    actor.acc.correct as f64 / actor.acc.total as f64
                } else {
                    f64::NAN
                },
                device_bytes: device_cache_bytes.max(2 * cfg.n_hot as u64 * d as u64 * 4)
                    + cfg.prefetch_q as u64 * m_max * d as u64 * 4,
                host_bytes: m_max * 8 + cfg.n_hot as u64 * 12,
            });
        }
    }
    Ok((setup_time, reports))
}

/// Streamed frequency ranking is also exposed for the Fig-3 bench.
pub fn epoch_remote_frequency(ctx: &RunContext, worker: WorkerId, epoch: u32) -> Result<Vec<(NodeId, u32)>> {
    let mut reader = EpochReader::open(&ctx.metadata_path, worker, epoch)?;
    let mut batches = Vec::new();
    while let Some(b) = reader.next_batch()? {
        batches.push(b);
    }
    Ok(remote_frequency(&batches))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetPreset, Engine, RunConfig};

    fn ctx() -> RunContext {
        let mut c = RunConfig::default();
        c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
        c.engine = Engine::Rapid;
        c.epochs = 3;
        c.n_hot = 300;
        RunContext::build(&c).unwrap()
    }

    #[test]
    fn precompute_writes_all_epochs() {
        let ctx = ctx();
        let setup = precompute(&ctx, 0).unwrap();
        assert!(setup.setup_time > 0.0);
        assert!(setup.setup_comm.vector_pulls > 0, "initial VectorPull issued");
        for e in 0..3 {
            assert!(EpochReader::open(&ctx.metadata_path, 0, e).is_ok(), "epoch {e} on disk");
        }
        assert!(!setup.cache.lock().unwrap().steady().is_empty());
    }

    #[test]
    fn rapid_runs_and_hits_cache() {
        let ctx = ctx();
        let (setup_time, reports) = run_worker(&ctx, 0, None).unwrap();
        assert!(setup_time > 0.0);
        assert_eq!(reports.len(), 3);
        for r in &reports {
            assert!(r.steps >= 1);
            assert!(r.cache.lookups > 0);
            assert!(r.cache.hit_rate() > 0.2, "hit rate {}", r.cache.hit_rate());
        }
    }

    #[test]
    fn rapid_moves_fewer_remote_rows_than_baseline() {
        // The paper's headline mechanism, on the tiny graph.
        let rctx = ctx();
        let (_, rapid) = run_worker(&rctx, 0, None).unwrap();
        let mut bcfg = rctx.cfg.clone();
        bcfg.engine = Engine::DglMetis;
        let bctx = RunContext::build(&bcfg).unwrap();
        let base = super::super::baseline::run_worker(&bctx, 0, None);
        let rows = |rs: &[EpochReport]| -> u64 { rs.iter().map(|r| r.comm.remote_rows).sum() };
        // exclude epoch 0's vector pull? keep it — still far fewer
        assert!(
            rows(&rapid) < rows(&base),
            "rapid {} !< baseline {}",
            rows(&rapid),
            rows(&base)
        );
    }

    #[test]
    fn rapid_is_faster_per_epoch_than_baseline() {
        let rctx = ctx();
        let (_, rapid) = run_worker(&rctx, 0, None).unwrap();
        let mut bcfg = rctx.cfg.clone();
        bcfg.engine = Engine::DglMetis;
        let bctx = RunContext::build(&bcfg).unwrap();
        let base = super::super::baseline::run_worker(&bctx, 0, None);
        let t = |rs: &[EpochReport]| -> f64 { rs.iter().map(|r| r.epoch_time).sum() };
        assert!(t(&rapid) < t(&base), "rapid {} !< baseline {}", t(&rapid), t(&base));
    }

    #[test]
    fn deterministic_reports() {
        let c1 = ctx();
        let (s1, a) = run_worker(&c1, 0, None).unwrap();
        let c2 = ctx();
        let (s2, b) = run_worker(&c2, 0, None).unwrap();
        assert_eq!(s1, s2);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.comm.remote_rows, y.comm.remote_rows);
            assert_eq!(x.cache.hits, y.cache.hits);
            assert!((x.epoch_time - y.epoch_time).abs() < 1e-12);
        }
    }

    #[test]
    fn memory_respects_paper_bound() {
        let ctx = ctx();
        let (_, reports) = run_worker(&ctx, 0, None).unwrap();
        let d = ctx.cfg.dataset.feature_dim;
        for r in &reports {
            // bound with index overhead allowance (+16B/entry)
            let m_max = 2_000u64; // tiny graph: generous m_max envelope
            let bound = crate::cache::device_memory_bound(ctx.cfg.n_hot, ctx.cfg.prefetch_q, m_max as u32, d);
            let slack = 2 * ctx.cfg.n_hot as u64 * 16;
            assert!(
                r.device_bytes <= bound + slack,
                "device {} > bound {}",
                r.device_bytes,
                bound + slack
            );
        }
    }

    #[test]
    fn cluster_runtime_matches_sequential_worker_path() {
        // The event-driven cluster runtime and the per-worker sequential
        // path must agree exactly: same communication counters, same cache
        // behaviour, same simulated epoch times (the event schedule
        // reproduces the closed-form pipeline recurrence bit-for-bit on a
        // homogeneous fabric).
        let seq_ctx = ctx();
        let mut seq = Vec::new();
        let mut seq_setup = 0.0f64;
        for w in 0..seq_ctx.cfg.num_workers {
            let (st, reps) = run_worker(&seq_ctx, w, None).unwrap();
            seq_setup = seq_setup.max(st);
            seq.extend(reps);
        }
        let clu_ctx = ctx();
        let (clu_setup, clu) = run_cluster(&clu_ctx, None).unwrap();
        assert_eq!(seq_setup, clu_setup);
        assert_eq!(seq.len(), clu.len());
        for c in &clu {
            let s = seq
                .iter()
                .find(|r| r.worker == c.worker && r.epoch == c.epoch)
                .expect("matching report");
            assert_eq!(s.comm.remote_rows, c.comm.remote_rows, "w{} e{}", c.worker, c.epoch);
            assert_eq!(s.comm.bytes, c.comm.bytes);
            assert_eq!(s.comm.sync_pulls, c.comm.sync_pulls);
            assert_eq!(s.cache.hits, c.cache.hits);
            assert_eq!(s.cache.lookups, c.cache.lookups);
            assert_eq!(s.steps, c.steps);
            assert!(
                (s.epoch_time - c.epoch_time).abs() < 1e-12,
                "w{} e{}: {} vs {}",
                c.worker,
                c.epoch,
                s.epoch_time,
                c.epoch_time
            );
            assert_eq!(s.device_bytes, c.device_bytes);
        }
    }

    #[test]
    fn cluster_runtime_matches_threaded_worker_path_in_full_mode() {
        // run_worker's full-mode branch (threaded Prefetcher + race
        // fallback) stays in-tree as the reference implementation; pin its
        // communication/cache accounting against the cluster runtime so the
        // two full-mode paths cannot drift apart silently.
        let full_cfg = || {
            let mut c = ctx().cfg.clone();
            c.exec_mode = crate::config::ExecMode::Full;
            c.batch_size = 64;
            c
        };
        let seq_ctx = RunContext::build(&full_cfg()).unwrap();
        let mut seq = Vec::new();
        for w in 0..seq_ctx.cfg.num_workers {
            let (_, reps) = run_worker(&seq_ctx, w, None).unwrap();
            seq.extend(reps);
        }
        let clu_ctx = RunContext::build(&full_cfg()).unwrap();
        let (_, clu) = run_cluster(&clu_ctx, None).unwrap();
        assert_eq!(seq.len(), clu.len());
        for c in &clu {
            let s = seq
                .iter()
                .find(|r| r.worker == c.worker && r.epoch == c.epoch)
                .expect("matching report");
            assert_eq!(s.comm.remote_rows, c.comm.remote_rows, "w{} e{}", c.worker, c.epoch);
            assert_eq!(s.comm.bytes, c.comm.bytes);
            assert_eq!(s.cache.hits, c.cache.hits);
            assert_eq!(s.cache.lookups, c.cache.lookups);
            assert_eq!(s.steps, c.steps);
        }
    }

    #[test]
    fn cluster_runtime_is_deterministic() {
        let (s1, a) = run_cluster(&ctx(), None).unwrap();
        let (s2, b) = run_cluster(&ctx(), None).unwrap();
        assert_eq!(s1, s2);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.comm.remote_rows, y.comm.remote_rows);
            assert_eq!(x.cache.hits, y.cache.hits);
            assert!((x.epoch_time - y.epoch_time).abs() < 1e-15);
        }
    }

    #[test]
    fn straggler_slows_its_own_worker_most() {
        let mut cfg = ctx().cfg.clone();
        cfg.fabric.straggler_worker = 0;
        cfg.fabric.straggler_factor = 5.0;
        let slow_ctx = RunContext::build(&cfg).unwrap();
        let (_, slow) = run_cluster(&slow_ctx, None).unwrap();
        let (_, clean) = run_cluster(&ctx(), None).unwrap();
        let total = |rs: &[EpochReport], w: u32| -> f64 {
            rs.iter().filter(|r| r.worker == w).map(|r| r.epoch_time).sum()
        };
        // Straggler injection must not change data movement, only time.
        let rows = |rs: &[EpochReport]| -> u64 { rs.iter().map(|r| r.comm.remote_rows).sum() };
        assert_eq!(rows(&slow), rows(&clean));
        assert!(
            total(&slow, 0) > 2.0 * total(&clean, 0),
            "straggler {} !> 2x clean {}",
            total(&slow, 0),
            total(&clean, 0)
        );
        // the other worker pays at most the straggler's *link* penalty, so
        // it must inflate far less than the straggler itself
        let inflation_w0 = total(&slow, 0) / total(&clean, 0);
        let inflation_w1 = total(&slow, 1) / total(&clean, 1);
        assert!(
            inflation_w0 > inflation_w1,
            "w0 {inflation_w0} !> w1 {inflation_w1}"
        );
    }

    #[test]
    fn later_epochs_swap_cache() {
        let ctx = ctx();
        let setup = precompute(&ctx, 0).unwrap();
        let cache = setup.cache;
        // stage + swap manually to verify the boundary logic end to end
        let (hot, _) = super::stream_top_hot(&ctx, 0, 1).unwrap();
        cache
            .lock()
            .unwrap()
            .stage_secondary(CacheBuffer::new(&hot, Vec::new(), ctx.kv.feature_dim()));
        assert!(cache.lock().unwrap().swap_at_epoch_boundary());
        assert_eq!(cache.lock().unwrap().swaps(), 1);
    }
}
