//! The one worker pipeline that drives every [`TrainingStrategy`].
//!
//! Two entry points, both strategy-agnostic:
//!
//! - [`run_worker`] — one worker, sequentially: stage each batch through the
//!   strategy's [`BatchPlan`], consume it (assemble + compute, the real
//!   train step in full mode), then convert the per-step costs into the
//!   epoch time via the closed-form bounded-queue recurrence
//!   ([`pipeline_schedule`]).
//! - [`run_cluster`] — all workers concurrently on the shared virtual clock:
//!   the same plans wrapped in a [`StrategyEpochActor`] and scheduled by the
//!   event-driven [`ClusterSim`]; cross-worker SGD interleaving on the
//!   shared model is resolved in deterministic virtual-time order.
//!
//! The consume side is identical for every engine, so it lives here:
//! assembly and compute costs from the shared cost models (slowdown-scaled),
//! and in full mode the real GraphSAGE step rebuilt from the batch's
//! deterministic seed. What a batch *costs to stage* — and everything else
//! that distinguishes an engine — comes from the strategy hooks.
//!
//! These functions replaced the per-engine `rapid::run_worker` /
//! `baseline::run_worker` (and `run_cluster`) pairs; the conformance tests
//! below pin that the sequential and event-driven paths still agree exactly.

use super::common::RunContext;
use super::strategy::{EpochTotals, PipelineOutcome, StrategyState, TrainingStrategy};
use super::SharedTrainer;
use crate::config::ExecMode;
use crate::metrics::{CommStats, EpochReport, PhaseTimes};
use crate::prefetch::StagedBatch;
use crate::sampler::khop::sample_blocks;
use crate::sampler::seed::derive_seed;
use crate::sampler::BatchMeta;
use crate::sim::{pipeline_schedule, ClusterSim, PipelineStep, WorkerActor};
use crate::trainer::{batch_labels, feature_mat, TrainStep};
use crate::util::mpmc;
use crate::util::wallclock::Stopwatch;
use crate::{Result, WorkerId};

/// Per-epoch consume-side accumulators.
#[derive(Default)]
struct EpochAcc {
    m_max: u64,
    loss_sum: f64,
    correct: u64,
    total: u64,
}

/// Execute a real training step (full mode): rebuild the batch's blocks from
/// its deterministic seed, wrap the fetched features, and step the model.
pub(super) fn full_train_step(
    ctx: &RunContext,
    worker: WorkerId,
    epoch: u32,
    meta: &BatchMeta,
    features: Vec<f32>,
    trainer: Option<&mut (dyn TrainStep + 'static)>,
) -> (f64, u32, u32) {
    let Some(trainer) = trainer else {
        return (f64::NAN, 0, 0);
    };
    let fanouts = ctx.fanouts();
    let rng_seed = derive_seed(ctx.cfg.base_seed, worker, epoch, meta.batch);
    let batch = sample_blocks(&ctx.ds.graph, &meta.seeds, &fanouts, rng_seed);
    debug_assert_eq!(batch.input_nodes(), &meta.input_nodes[..], "determinism");
    let x0 = feature_mat(features, meta.input_nodes.len(), ctx.cfg.dataset.feature_dim as usize);
    let labels = batch_labels(&ctx.ds, &batch);
    let out = trainer.step(&x0, &batch, &labels, ctx.cfg.learning_rate);
    (out.loss, out.correct, out.total)
}

/// Consume one staged batch on the sequential path: charge assemble+compute
/// (wall-clock-measured in full mode), run the real train step when present,
/// and return the consume cost for the pipeline schedule. `seed_epoch` is
/// the *schedule* epoch ([`TrainingStrategy::schedule_epoch`]) — the one the
/// staged metadata was enumerated under, which a replaying engine maps away
/// from the training epoch. `slow` is the worker's local slowdown for the
/// *training* epoch (transient phases resolve per epoch).
#[allow(clippy::too_many_arguments)]
fn consume_staged(
    ctx: &RunContext,
    worker: WorkerId,
    seed_epoch: u32,
    slow: f64,
    staged: StagedBatch,
    phases: &mut PhaseTimes,
    acc: &mut EpochAcc,
    trainer: Option<&mut (dyn TrainStep + 'static)>,
) -> f64 {
    let full = ctx.cfg.exec_mode == ExecMode::Full;
    let d = ctx.cfg.dataset.feature_dim;
    let n_input = staged.meta.input_nodes.len();
    acc.m_max = acc.m_max.max(n_input as u64);
    let assemble = slow * ctx.costs.assemble_time(n_input, d);
    let compute = if full {
        let sw = Stopwatch::start();
        let out = full_train_step(
            ctx,
            worker,
            seed_epoch,
            &staged.meta,
            staged.features.unwrap_or_default(),
            trainer,
        );
        acc.loss_sum += out.0;
        acc.correct += out.1 as u64;
        acc.total += out.2 as u64;
        sw.elapsed_sec()
    } else {
        slow * ctx.compute_time(n_input, staged.meta.seeds.len())
    };
    phases.assemble += assemble;
    phases.compute += compute;
    assemble + compute
}

/// Assemble one (worker, epoch) report from the pipeline's measurements and
/// the strategy's epoch verdict.
#[allow(clippy::too_many_arguments)]
fn make_report(
    epoch: u32,
    worker: WorkerId,
    full: bool,
    totals: &EpochTotals,
    acc: &EpochAcc,
    finish: super::strategy::EpochFinish,
    phases: PhaseTimes,
    comm: CommStats,
) -> EpochReport {
    EpochReport {
        epoch,
        worker,
        steps: totals.steps,
        epoch_time: finish.epoch_time,
        phases,
        comm,
        cache: finish.cache,
        cache_plan: finish.cache_plan,
        mean_loss: if full {
            acc.loss_sum / totals.steps.max(1) as f64
        } else {
            f64::NAN
        },
        train_acc: if full && acc.total > 0 {
            acc.correct as f64 / acc.total as f64
        } else {
            f64::NAN
        },
        device_bytes: finish.device_bytes,
        host_bytes: finish.host_bytes,
    }
}

/// Run one worker's full training for the context's strategy, sequentially.
/// `trainer` present in full mode. Returns (setup time, per-epoch reports).
pub fn run_worker(
    ctx: &RunContext,
    worker: WorkerId,
    mut trainer: Option<&mut (dyn TrainStep + 'static)>,
) -> Result<(f64, Vec<EpochReport>)> {
    let strategy = &*ctx.strategy;
    let setup = strategy.setup(ctx, worker)?;
    let mut state = setup.state;
    let cfg = &ctx.cfg;
    let full = cfg.exec_mode == ExecMode::Full;
    let q = strategy.queue_depth(cfg);
    let mut reports = Vec::with_capacity(cfg.epochs as usize);

    for epoch in 0..cfg.epochs {
        let seed_epoch = strategy.schedule_epoch(cfg, epoch);
        let slow = ctx.slowdown_at(worker, epoch);
        let mut comm = CommStats::default();
        let mut phases = PhaseTimes::default();
        let mut steps: Vec<PipelineStep> = Vec::new();
        let mut acc = EpochAcc::default();
        {
            let mut plan = strategy.plan_epoch(ctx, &mut state, worker, epoch, &mut comm)?;
            while let Some(step) = plan.next(&mut comm, &mut phases)? {
                let consume = consume_staged(
                    ctx,
                    worker,
                    seed_epoch,
                    slow,
                    step.staged,
                    &mut phases,
                    &mut acc,
                    trainer.as_deref_mut(),
                );
                steps.push(PipelineStep { stage: step.cost, consume });
            }
        }
        let times = pipeline_schedule(&steps, q);
        let outcome = PipelineOutcome {
            total: times.total,
            total_wait: times.total_wait,
            event_driven: false,
        };
        let totals = EpochTotals { steps: steps.len() as u32, m_max: acc.m_max };
        let finish = strategy.finish_epoch(
            ctx, &mut state, worker, epoch, &outcome, &totals, &mut phases, &mut comm,
        )?;
        reports.push(make_report(epoch, worker, full, &totals, &acc, finish, phases, comm));
        emit_epoch_trace(ctx, worker, epoch, reports.last().expect("just pushed"));
    }
    Ok((setup.setup_time, reports))
}

/// Journal one finished (worker, epoch) as an `epoch` trace record, stamped
/// at the epoch's closing virtual time. The fields embed the full
/// [`EpochReport`] so `top --trace` can replay a dashboard without the JSON
/// report. No-op without an installed sink — and strictly observational with
/// one (nothing reads the journal back during the run).
fn emit_epoch_trace(ctx: &RunContext, worker: WorkerId, epoch: u32, report: &EpochReport) {
    if let Some(trace) = &ctx.trace {
        trace.event(worker, epoch, report.epoch_time, "epoch", report.to_value());
    }
}

/// One worker's (epoch, plan) as a [`WorkerActor`] for the event-driven
/// cluster runtime: the strategy's plan feeds the stage slot, the shared
/// consume logic the consume slot, coupled by a bounded [`mpmc`] ring of
/// depth `Q` — popped in exact virtual-time order. In full mode the real
/// shared-model train step runs at the virtual instant the consume fires
/// (virtual cost still from the analytic models, so event order and epoch
/// times stay deterministic).
struct StrategyEpochActor<'a> {
    ctx: &'a RunContext,
    worker: WorkerId,
    /// The schedule epoch the staged metadata was enumerated under
    /// ([`TrainingStrategy::schedule_epoch`]) — seeds train-step rebuilds.
    seed_epoch: u32,
    plan: Box<dyn super::strategy::BatchPlan + 'a>,
    queue_tx: mpmc::Sender<StagedBatch>,
    queue_rx: mpmc::Receiver<StagedBatch>,
    trainer: Option<SharedTrainer>,
    slow: f64,
    full: bool,
    /// Shared-link queueing mode: each stage's pulls become route claims
    /// drained by the simulation's [`crate::net::ContentionNet`]; the stage
    /// cost handed to the scheduler is the local residual only.
    contention: bool,
    /// Route claims of the last `stage_next` (drained by `take_flows`).
    pending_flows: Vec<crate::net::FlowSpec>,
    comm: CommStats,
    phases: PhaseTimes,
    acc: EpochAcc,
    /// Set when the plan failed mid-epoch (e.g. a truncated metadata
    /// stream); surfaced as an error by [`run_cluster`] after the simulation
    /// drains — the actor interface can't propagate it, and silently
    /// truncating the epoch would lose steps.
    error: Option<anyhow::Error>,
}

impl WorkerActor for StrategyEpochActor<'_> {
    fn stage_next(&mut self) -> Option<f64> {
        match self.plan.next(&mut self.comm, &mut self.phases) {
            Ok(Some(step)) => {
                let cost = if self.contention {
                    // The staging pulls just recorded their route claims on
                    // the fabric; hand them to the link network (via
                    // `take_flows`) and keep only the local residual — the
                    // scalar `pull_time` was the linear network estimate.
                    self.pending_flows = self.ctx.fabric.take_route_claims();
                    (step.cost - step.staged.pull_time).max(0.0)
                } else {
                    step.cost
                };
                if self.queue_tx.try_send(step.staged).is_err() {
                    panic!("cluster scheduler overflowed the bounded staging queue");
                }
                Some(cost)
            }
            Ok(None) => None,
            Err(e) => {
                self.error = Some(e);
                None
            }
        }
    }

    fn take_flows(&mut self) -> Vec<crate::net::FlowSpec> {
        std::mem::take(&mut self.pending_flows)
    }

    fn consume_next(&mut self) -> f64 {
        let staged = self
            .queue_rx
            .try_recv()
            .expect("scheduler consumes only staged batches");
        let n_input = staged.meta.input_nodes.len();
        self.acc.m_max = self.acc.m_max.max(n_input as u64);
        let d = self.ctx.cfg.dataset.feature_dim;
        let assemble = self.slow * self.ctx.costs.assemble_time(n_input, d);
        let compute = self.slow * self.ctx.compute_time(n_input, staged.meta.seeds.len());
        if self.full {
            // Virtual time uses the analytic model (deterministic event
            // order + reproducible epoch times); the real step still runs.
            let out = match &self.trainer {
                Some(tr) => {
                    let mut t = tr.lock().unwrap();
                    full_train_step(
                        self.ctx,
                        self.worker,
                        self.seed_epoch,
                        &staged.meta,
                        staged.features.unwrap_or_default(),
                        Some(&mut **t),
                    )
                }
                None => (f64::NAN, 0, 0),
            };
            self.acc.loss_sum += out.0;
            self.acc.correct += out.1 as u64;
            self.acc.total += out.2 as u64;
        }
        self.phases.assemble += assemble;
        self.phases.compute += compute;
        assemble + compute
    }
}

/// Run all workers concurrently on the shared virtual clock for the
/// context's strategy — the event-driven counterpart of [`run_worker`]. Per
/// epoch every worker's pipeline advances together in one [`ClusterSim`];
/// between epochs each worker runs its strategy's `finish_epoch` exactly as
/// the sequential path does, so the two paths report identical communication
/// counters (pinned by the conformance tests). Returns (max setup time,
/// per-(worker, epoch) reports).
pub fn run_cluster(
    ctx: &RunContext,
    trainer: Option<SharedTrainer>,
) -> Result<(f64, Vec<EpochReport>)> {
    let (setup_time, mut states) = setup_cluster(ctx)?;
    let cfg = &ctx.cfg;
    let mut reports = Vec::with_capacity((cfg.num_workers * cfg.epochs) as usize);
    for epoch in 0..cfg.epochs {
        reports.extend(run_cluster_epoch(ctx, trainer.clone(), &mut states, epoch)?);
    }
    Ok((setup_time, reports))
}

/// One-time per-worker strategy setup for the cluster path. Returns the max
/// setup time and the per-worker states. Split out of [`run_cluster`] so the
/// recovery driver can substitute checkpoint-restored states.
pub(super) fn setup_cluster(ctx: &RunContext) -> Result<(f64, Vec<StrategyState>)> {
    let strategy = &*ctx.strategy;
    let cfg = &ctx.cfg;
    let mut setup_time = 0.0f64;
    let mut states: Vec<StrategyState> = Vec::with_capacity(cfg.num_workers as usize);
    for w in 0..cfg.num_workers {
        let s = strategy.setup(ctx, w)?;
        setup_time = setup_time.max(s.setup_time);
        states.push(s.state);
    }
    if cfg.fabric.contention {
        // Setup pulls (offline precompute, initial cache builds) keep their
        // linear pricing — they are one-time background work, not epoch
        // traffic. Discard any claims they recorded.
        drop(ctx.fabric.take_route_claims());
    }
    Ok((setup_time, states))
}

/// Run one epoch for all workers on the shared virtual clock — the body of
/// [`run_cluster`]'s epoch loop. A fresh [`ClusterSim`] per epoch means the
/// within-epoch virtual timeline is independent of earlier epochs, which is
/// what lets a checkpoint-resumed run replay the remaining epochs
/// bit-exactly. Exposed to the recovery driver, which interleaves
/// failure-plan boundaries and checkpoint writes between calls.
pub(super) fn run_cluster_epoch(
    ctx: &RunContext,
    trainer: Option<SharedTrainer>,
    states: &mut [StrategyState],
    epoch: u32,
) -> Result<Vec<EpochReport>> {
    let strategy = &*ctx.strategy;
    let cfg = &ctx.cfg;
    let full = cfg.exec_mode == ExecMode::Full;
    let contention = cfg.fabric.contention;
    let q = strategy.queue_depth(cfg);
    let mut reports = Vec::with_capacity(cfg.num_workers as usize);
    {
        let mut sim = ClusterSim::new();
        if contention {
            let mut net = crate::net::ContentionNet::new(&ctx.fabric);
            if let Some(trace) = &ctx.trace {
                net = net.with_tracer(trace.clone(), epoch);
            }
            sim = sim.with_network(net);
        }
        if let Some(trace) = &ctx.trace {
            sim = sim.with_tracer(trace.clone(), epoch);
        }
        for w in 0..cfg.num_workers {
            let mut comm = CommStats::default();
            let plan =
                strategy.plan_epoch(ctx, &mut states[w as usize], w, epoch, &mut comm)?;
            let (queue_tx, queue_rx) = mpmc::bounded(q.max(1) as usize);
            sim.add_worker(
                q,
                StrategyEpochActor {
                    ctx,
                    worker: w,
                    seed_epoch: strategy.schedule_epoch(cfg, epoch),
                    plan,
                    queue_tx,
                    queue_rx,
                    trainer: trainer.clone(),
                    slow: ctx.slowdown_at(w, epoch),
                    full,
                    contention,
                    pending_flows: Vec::new(),
                    comm,
                    phases: PhaseTimes::default(),
                    acc: EpochAcc::default(),
                    error: None,
                },
            );
        }
        for (w, done) in sim.run().into_iter().enumerate() {
            let worker = w as WorkerId;
            let timeline = done.timeline;
            let mut actor = done.actor;
            if let Some(e) = actor.error.take() {
                return Err(e.context(format!(
                    "batch plan for worker {worker} epoch {epoch} failed mid-epoch"
                )));
            }
            let outcome = PipelineOutcome {
                total: timeline.makespan,
                total_wait: timeline.total_wait,
                event_driven: true,
            };
            let totals = EpochTotals { steps: timeline.steps() as u32, m_max: actor.acc.m_max };
            let mut phases = actor.phases;
            let mut comm = actor.comm;
            let finish = strategy.finish_epoch(
                ctx,
                &mut states[w],
                worker,
                epoch,
                &outcome,
                &totals,
                &mut phases,
                &mut comm,
            )?;
            reports
                .push(make_report(epoch, worker, full, &totals, &actor.acc, finish, phases, comm));
            emit_epoch_trace(ctx, worker, epoch, reports.last().expect("just pushed"));
        }
        if contention {
            // `finish_epoch` background pulls (C_sec rebuilds) are priced
            // linearly as overlap work; discard their claims.
            drop(ctx.fabric.take_route_claims());
        }
    }
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetPreset, Engine, RunConfig};

    fn ctx(engine: Engine) -> RunContext {
        let mut c = RunConfig::default();
        c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
        c.engine = engine;
        c.epochs = 3;
        c.n_hot = 300;
        RunContext::build(&c).unwrap()
    }

    fn assert_cluster_matches_sequential(engine: Engine, time_tol: f64) {
        // The conformance contract every registered strategy inherits: the
        // event-driven cluster runtime and the per-worker sequential path
        // agree — identical counters, epoch times within `time_tol` (exact
        // for pipeline-scheduled engines; float-accumulation noise for the
        // serial per-phase accounting of the on-demand ones).
        let seq_ctx = ctx(engine);
        let mut seq = Vec::new();
        let mut seq_setup = 0.0f64;
        for w in 0..seq_ctx.cfg.num_workers {
            let (st, reps) = run_worker(&seq_ctx, w, None).unwrap();
            seq_setup = seq_setup.max(st);
            seq.extend(reps);
        }
        let clu_ctx = ctx(engine);
        let (clu_setup, clu) = run_cluster(&clu_ctx, None).unwrap();
        assert_eq!(seq_setup, clu_setup, "{}", engine.id());
        assert_eq!(seq.len(), clu.len());
        for c in &clu {
            let s = seq
                .iter()
                .find(|r| r.worker == c.worker && r.epoch == c.epoch)
                .expect("matching report");
            let tag = format!("{} w{} e{}", engine.id(), c.worker, c.epoch);
            assert_eq!(s.comm.remote_rows, c.comm.remote_rows, "{tag}");
            assert_eq!(s.comm.bytes, c.comm.bytes, "{tag}");
            assert_eq!(s.comm.sync_pulls, c.comm.sync_pulls, "{tag}");
            assert_eq!(s.comm.vector_pulls, c.comm.vector_pulls, "{tag}");
            assert_eq!(s.cache.hits, c.cache.hits, "{tag}");
            assert_eq!(s.cache.lookups, c.cache.lookups, "{tag}");
            assert_eq!(s.steps, c.steps, "{tag}");
            assert_eq!(s.device_bytes, c.device_bytes, "{tag}");
            assert_eq!(s.host_bytes, c.host_bytes, "{tag}");
            assert!(
                (s.epoch_time - c.epoch_time).abs() < time_tol,
                "{tag}: {} vs {}",
                s.epoch_time,
                c.epoch_time
            );
        }
    }

    #[test]
    fn cluster_matches_sequential_for_rapid() {
        // The event schedule reproduces the closed-form pipeline recurrence
        // bit-for-bit on a homogeneous fabric.
        assert_cluster_matches_sequential(Engine::Rapid, 1e-12);
    }

    #[test]
    fn cluster_matches_sequential_for_baselines() {
        // Q = 0 actors: the event path sums per-batch, the serial path
        // per-phase — equal within float-accumulation noise.
        assert_cluster_matches_sequential(Engine::DglMetis, 1e-9);
        assert_cluster_matches_sequential(Engine::DistGcn, 1e-9);
    }

    #[test]
    fn cluster_matches_sequential_for_registry_only_engines() {
        assert_cluster_matches_sequential(Engine::FastSample, 1e-12);
        assert_cluster_matches_sequential(Engine::GreenWindow, 1e-9);
        assert_cluster_matches_sequential(Engine::AdaptiveCache, 1e-12);
        // The compression engines ride rapid's pipeline-scheduled path; the
        // compressed payload charge is identical on both runtimes.
        assert_cluster_matches_sequential(Engine::QuantPull, 1e-12);
        assert_cluster_matches_sequential(Engine::GradTopk, 1e-12);
    }

    #[test]
    fn cluster_matches_sequential_adaptive_telemetry() {
        // The adaptive controller runs per worker on both paths; its
        // telemetry (n_hot trajectory, resize counts) must agree exactly.
        let seq_ctx = ctx(Engine::AdaptiveCache);
        let mut seq = Vec::new();
        for w in 0..seq_ctx.cfg.num_workers {
            let (_, reps) = run_worker(&seq_ctx, w, None).unwrap();
            seq.extend(reps);
        }
        let clu_ctx = ctx(Engine::AdaptiveCache);
        let (_, clu) = run_cluster(&clu_ctx, None).unwrap();
        for c in &clu {
            let s = seq
                .iter()
                .find(|r| r.worker == c.worker && r.epoch == c.epoch)
                .expect("matching report");
            assert_eq!(s.cache_plan, c.cache_plan, "w{} e{}", c.worker, c.epoch);
            assert!(c.cache_plan.is_some(), "adaptive always reports telemetry");
        }
    }

    #[test]
    fn cluster_full_mode_matches_sequential_counters() {
        // The sequential full-mode path (inline staging + real SGD) and the
        // cluster path must count identical communication and cache traffic
        // — only SGD interleaving across workers differs.
        let full_cfg = || {
            let mut c = ctx(Engine::Rapid).cfg.clone();
            c.exec_mode = crate::config::ExecMode::Full;
            c.batch_size = 64;
            c
        };
        let seq_ctx = RunContext::build(&full_cfg()).unwrap();
        let mut seq = Vec::new();
        for w in 0..seq_ctx.cfg.num_workers {
            let (_, reps) = run_worker(&seq_ctx, w, None).unwrap();
            seq.extend(reps);
        }
        let clu_ctx = RunContext::build(&full_cfg()).unwrap();
        let (_, clu) = run_cluster(&clu_ctx, None).unwrap();
        assert_eq!(seq.len(), clu.len());
        for c in &clu {
            let s = seq
                .iter()
                .find(|r| r.worker == c.worker && r.epoch == c.epoch)
                .expect("matching report");
            assert_eq!(s.comm.remote_rows, c.comm.remote_rows, "w{} e{}", c.worker, c.epoch);
            assert_eq!(s.comm.bytes, c.comm.bytes);
            assert_eq!(s.cache.hits, c.cache.hits);
            assert_eq!(s.cache.lookups, c.cache.lookups);
            assert_eq!(s.steps, c.steps);
        }
    }

    #[test]
    fn cluster_runtime_is_deterministic() {
        let (s1, a) = run_cluster(&ctx(Engine::Rapid), None).unwrap();
        let (s2, b) = run_cluster(&ctx(Engine::Rapid), None).unwrap();
        assert_eq!(s1, s2);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.comm.remote_rows, y.comm.remote_rows);
            assert_eq!(x.cache.hits, y.cache.hits);
            assert!((x.epoch_time - y.epoch_time).abs() < 1e-15);
        }
    }

    #[test]
    fn straggler_slows_its_own_worker_most() {
        let mut cfg = ctx(Engine::Rapid).cfg.clone();
        cfg.fabric.straggler_worker = 0;
        cfg.fabric.straggler_factor = 5.0;
        let slow_ctx = RunContext::build(&cfg).unwrap();
        let (_, slow) = run_cluster(&slow_ctx, None).unwrap();
        let (_, clean) = run_cluster(&ctx(Engine::Rapid), None).unwrap();
        let total = |rs: &[EpochReport], w: u32| -> f64 {
            rs.iter().filter(|r| r.worker == w).map(|r| r.epoch_time).sum()
        };
        // Straggler injection must not change data movement, only time.
        let rows = |rs: &[EpochReport]| -> u64 { rs.iter().map(|r| r.comm.remote_rows).sum() };
        assert_eq!(rows(&slow), rows(&clean));
        assert!(
            total(&slow, 0) > 2.0 * total(&clean, 0),
            "straggler {} !> 2x clean {}",
            total(&slow, 0),
            total(&clean, 0)
        );
        // the other worker pays at most the straggler's *link* penalty, so
        // it must inflate far less than the straggler itself
        let inflation_w0 = total(&slow, 0) / total(&clean, 0);
        let inflation_w1 = total(&slow, 1) / total(&clean, 1);
        assert!(inflation_w0 > inflation_w1, "w0 {inflation_w0} !> w1 {inflation_w1}");
    }

    #[test]
    fn worker_speed_vector_reproduces_straggler_sugar() {
        // The generalized per-worker speed model: an explicit vector must
        // produce the same run as the equivalent straggler sugar.
        let mut sugar_cfg = ctx(Engine::Rapid).cfg.clone();
        sugar_cfg.fabric.straggler_worker = 1;
        sugar_cfg.fabric.straggler_factor = 3.0;
        let mut vec_cfg = ctx(Engine::Rapid).cfg.clone();
        vec_cfg.fabric.worker_speed = vec![1.0, 3.0];
        let (_, sugar) = run_cluster(&RunContext::build(&sugar_cfg).unwrap(), None).unwrap();
        let (_, vector) = run_cluster(&RunContext::build(&vec_cfg).unwrap(), None).unwrap();
        assert_eq!(sugar.len(), vector.len());
        for (a, b) in sugar.iter().zip(&vector) {
            assert_eq!(a.comm.remote_rows, b.comm.remote_rows);
            assert!((a.epoch_time - b.epoch_time).abs() < 1e-12, "w{} e{}", a.worker, a.epoch);
        }
    }

    #[test]
    fn heterogeneous_speeds_order_worker_times() {
        // Three distinct speeds → three distinct per-worker epoch times, in
        // speed order; traffic unchanged.
        let mut cfg = ctx(Engine::DglMetis).cfg.clone();
        cfg.num_workers = 3;
        cfg.fabric.worker_speed = vec![1.0, 2.0, 4.0];
        let (_, het) = run_cluster(&RunContext::build(&cfg).unwrap(), None).unwrap();
        let mut clean_cfg = cfg.clone();
        clean_cfg.fabric.worker_speed.clear();
        let (_, clean) = run_cluster(&RunContext::build(&clean_cfg).unwrap(), None).unwrap();
        let total = |rs: &[EpochReport], w: u32| -> f64 {
            rs.iter().filter(|r| r.worker == w).map(|r| r.epoch_time).sum()
        };
        let rows = |rs: &[EpochReport]| -> u64 { rs.iter().map(|r| r.comm.remote_rows).sum() };
        assert_eq!(rows(&het), rows(&clean), "speeds change time, not traffic");
        let inflation = |w: u32| total(&het, w) / total(&clean, w);
        assert!(inflation(1) > 1.5, "w1 {}", inflation(1));
        assert!(inflation(2) > inflation(1), "{} !> {}", inflation(2), inflation(1));
        assert!(inflation(0) < inflation(1), "{} !< {}", inflation(0), inflation(1));
    }
}
