//! `fast-sample` — FastSample-style periodic re-sampling (arXiv 2311.17847)
//! as a registry-only engine.
//!
//! RapidGNN precomputes *every* epoch's schedule offline; FastSample
//! re-enumerates only every `k = EngineParams::resample_period` epochs and
//! replays the period-start schedule in between. That amortizes the
//! precompute pass (and the per-epoch `C_sec` cache rebuilds, which are
//! pointless while the schedule is frozen) over `k` epochs:
//!
//! - setup enumerates `ceil(epochs / k)` schedules instead of `epochs`;
//! - the hot-set cache is rebuilt only at period boundaries, so the steady
//!   cache is always ranked on *exactly* the schedule being replayed —
//!   fewer `VectorPull` rebuild rows than `rapid`, at the price of stale
//!   sampling randomness within a period (the FastSample trade).
//!
//! At `k = 1` this engine degenerates to `rapid` exactly (every epoch
//! enumerated, rebuilt, and swapped) — pinned by a test below.
//!
//! Everything else — staging, costs, memory accounting — is shared with the
//! `rapid` strategy through `plan_cached_epoch`/`finish_cached_epoch`; this
//! file only maps epochs onto period-start schedules.

use super::rapid::{
    checkpoint_rapid_state, finish_cached_epoch, plan_cached_epoch, precompute_epochs,
    restore_rapid_state, RapidState,
};
use crate::config::RunConfig;
use crate::coordinator::common::RunContext;
use crate::coordinator::strategy::{
    BatchPlan, EpochFinish, EpochTotals, PipelineOutcome, StrategySetup, StrategyState,
    TrainingStrategy,
};
use crate::metrics::{CommStats, PhaseTimes};
use crate::util::value::Value;
use crate::{Result, WorkerId};

/// Periodic re-sampling engine.
pub struct FastSampleStrategy {
    /// Re-enumerate every `period` epochs (≥ 1, from `EngineParams`).
    period: u32,
}

/// Registry constructor.
pub fn ctor(cfg: &RunConfig) -> Box<dyn TrainingStrategy> {
    Box::new(FastSampleStrategy { period: cfg.engine_params.resample_period.max(1) })
}

impl FastSampleStrategy {
    /// The period-start epoch whose on-disk schedule epoch `e` replays.
    fn sched_epoch(&self, epoch: u32) -> u32 {
        epoch - epoch % self.period
    }
}

impl TrainingStrategy for FastSampleStrategy {
    fn id(&self) -> &'static str {
        "fast-sample"
    }

    fn name(&self) -> &'static str {
        "FastSample"
    }

    fn queue_depth(&self, cfg: &RunConfig) -> u32 {
        cfg.prefetch_q
    }

    fn schedule_epoch(&self, _cfg: &RunConfig, epoch: u32) -> u32 {
        self.sched_epoch(epoch)
    }

    fn setup(&self, ctx: &RunContext, worker: WorkerId) -> Result<StrategySetup> {
        let starts: Vec<u32> = (0..ctx.cfg.epochs).step_by(self.period as usize).collect();
        let s = precompute_epochs(ctx, worker, &starts)?;
        Ok(StrategySetup {
            setup_time: s.setup_time,
            state: Box::new(RapidState { cache: s.cache, setup_comm: s.setup_comm }),
        })
    }

    fn plan_epoch<'a>(
        &self,
        ctx: &'a RunContext,
        state: &mut StrategyState,
        worker: WorkerId,
        epoch: u32,
        comm: &mut CommStats,
    ) -> Result<Box<dyn BatchPlan + 'a>> {
        plan_cached_epoch(ctx, state, worker, epoch, self.sched_epoch(epoch), comm)
    }

    fn finish_epoch(
        &self,
        ctx: &RunContext,
        state: &mut StrategyState,
        worker: WorkerId,
        epoch: u32,
        outcome: &PipelineOutcome,
        totals: &EpochTotals,
        phases: &mut PhaseTimes,
        comm: &mut CommStats,
    ) -> Result<EpochFinish> {
        // Rebuild C_sec only when the next epoch starts a new period — the
        // steady cache already matches the schedule being replayed otherwise.
        let next = epoch + 1;
        let rebuild = if next < ctx.cfg.epochs && next % self.period == 0 {
            Some(next) // a period start: its schedule is on disk
        } else {
            None
        };
        finish_cached_epoch(ctx, state, worker, epoch, rebuild, outcome, totals, phases, comm)
    }

    fn checkpoint_state(
        &self,
        _ctx: &RunContext,
        state: &StrategyState,
        _worker: WorkerId,
    ) -> Result<Value> {
        let st = state.downcast_ref::<RapidState>().expect("rapid-family worker state");
        Ok(checkpoint_rapid_state(st))
    }

    fn restore_setup(
        &self,
        ctx: &RunContext,
        worker: WorkerId,
        next_epoch: u32,
        snapshot: &Value,
    ) -> Result<StrategySetup> {
        let hot = snapshot.req_u32_array("hot")?;
        // Resumed epochs replay period-start schedules, so only those files
        // need re-enumerating: the start of next_epoch's period plus every
        // later period start.
        let mut starts: Vec<u32> = vec![self.sched_epoch(next_epoch)];
        starts.extend(
            (0..ctx.cfg.epochs)
                .step_by(self.period as usize)
                .filter(|&s| s > next_epoch),
        );
        let st = restore_rapid_state(ctx, worker, &starts, &hot)?;
        Ok(StrategySetup { setup_time: 0.0, state: Box::new(st) })
    }

    fn cache_rows(&self, state: &StrategyState, _worker: WorkerId) -> u64 {
        state
            .downcast_ref::<RapidState>()
            .expect("rapid-family worker state")
            .cache_rows()
    }
}

#[cfg(test)]
mod tests {
    use crate::config::{DatasetConfig, DatasetPreset, Engine, RunConfig};
    use crate::coordinator::common::RunContext;
    use crate::coordinator::pipeline::run_worker;
    use crate::metrics::EpochReport;

    fn cfg(period: u32, epochs: u32) -> RunConfig {
        let mut c = RunConfig::default();
        c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
        c.engine = Engine::FastSample;
        c.engine_params.resample_period = period;
        c.epochs = epochs;
        c.n_hot = 300;
        c
    }

    #[test]
    fn period_one_degenerates_to_rapid_exactly() {
        let fs_ctx = RunContext::build(&cfg(1, 3)).unwrap();
        let (fs_setup, fs) = run_worker(&fs_ctx, 0, None).unwrap();
        let mut rcfg = cfg(1, 3);
        rcfg.engine = Engine::Rapid;
        let r_ctx = RunContext::build(&rcfg).unwrap();
        let (r_setup, rapid) = run_worker(&r_ctx, 0, None).unwrap();
        assert_eq!(fs_setup, r_setup);
        assert_eq!(fs.len(), rapid.len());
        for (a, b) in fs.iter().zip(&rapid) {
            assert_eq!(a.comm.remote_rows, b.comm.remote_rows, "epoch {}", a.epoch);
            assert_eq!(a.comm.vector_rows, b.comm.vector_rows);
            assert_eq!(a.cache.hits, b.cache.hits);
            assert!((a.epoch_time - b.epoch_time).abs() < 1e-12);
        }
    }

    #[test]
    fn replayed_epochs_repeat_the_period_start_schedule() {
        // Within one period every epoch replays the same schedule against
        // the same cache → identical per-epoch counters.
        let ctx = RunContext::build(&cfg(3, 3)).unwrap();
        let (_, reports) = run_worker(&ctx, 0, None).unwrap();
        assert_eq!(reports.len(), 3);
        for r in &reports[1..] {
            assert_eq!(r.comm.remote_rows - r.comm.vector_rows,
                reports[0].comm.remote_rows - reports[0].comm.vector_rows,
                "epoch {} must replay epoch 0's miss set", r.epoch);
            assert_eq!(r.steps, reports[0].steps);
            assert_eq!(r.cache.lookups, reports[0].cache.lookups);
            assert_eq!(r.cache.hits, reports[0].cache.hits);
        }
    }

    #[test]
    fn amortizes_precompute_and_cache_rebuilds_vs_rapid() {
        let fs_ctx = RunContext::build(&cfg(4, 4)).unwrap();
        let (fs_setup, fs) = run_worker(&fs_ctx, 0, None).unwrap();
        let mut rcfg = cfg(4, 4);
        rcfg.engine = Engine::Rapid;
        let r_ctx = RunContext::build(&rcfg).unwrap();
        let (r_setup, rapid) = run_worker(&r_ctx, 0, None).unwrap();
        assert!(
            fs_setup < 0.5 * r_setup,
            "one enumerated epoch vs four: setup {fs_setup} !< half of {r_setup}"
        );
        let vector_rows = |rs: &[EpochReport]| -> u64 {
            rs.iter().map(|r| r.comm.vector_rows).sum()
        };
        assert!(
            vector_rows(&fs) < vector_rows(&rapid),
            "frozen periods skip C_sec rebuilds: {} !< {}",
            vector_rows(&fs),
            vector_rows(&rapid)
        );
    }

    #[test]
    fn full_mode_trains_on_replayed_schedules() {
        // The seed-epoch mapping: a replayed epoch must rebuild its blocks
        // from the *period-start* schedule's seeds, or the staged features
        // misalign with the rebuilt batch (full_train_step's determinism
        // debug_assert pins this).
        let mut c = cfg(3, 3);
        c.exec_mode = crate::config::ExecMode::Full;
        c.batch_size = 64;
        let report = crate::coordinator::run(&c).unwrap();
        assert_eq!(report.loss_curve().len(), 3);
        assert!(report.loss_curve().iter().all(|&(_, l)| l.is_finite()));
    }

    #[test]
    fn restore_reenumerates_exactly_the_replayed_period_starts() {
        use crate::coordinator::strategy::TrainingStrategy;
        use crate::storage::EpochReader;
        // period 2 over 6 epochs → schedules at 0, 2, 4. Resuming at epoch 3
        // replays epoch 2's schedule and later needs epoch 4's.
        let c = cfg(2, 6);
        let ctx = RunContext::build(&c).unwrap();
        let strat = super::ctor(&c);
        let setup = strat.setup(&ctx, 0).unwrap();
        let snap = strat.checkpoint_state(&ctx, &setup.state, 0).unwrap();
        let snap = crate::util::value::Value::from_json(&snap.to_json()).unwrap();

        let ctx2 = RunContext::build(&c).unwrap();
        let restored = strat.restore_setup(&ctx2, 0, 3, &snap).unwrap();
        assert_eq!(restored.setup_time, 0.0);
        for s in [2u32, 4] {
            assert!(EpochReader::open(&ctx2.metadata_path, 0, s).is_ok(), "period start {s}");
        }
        assert!(EpochReader::open(&ctx2.metadata_path, 0, 0).is_err(), "epoch 0 not replayed");
        let orig = setup.state.downcast_ref::<super::RapidState>().unwrap();
        let re = restored.state.downcast_ref::<super::RapidState>().unwrap();
        assert_eq!(
            re.cache.lock().unwrap().steady().ids_by_row(),
            orig.cache.lock().unwrap().steady().ids_by_row()
        );
        assert_eq!(strat.cache_rows(&restored.state, 0), orig.cache_rows());
    }

    #[test]
    fn deterministic_across_runs() {
        let a_ctx = RunContext::build(&cfg(2, 4)).unwrap();
        let (sa, a) = run_worker(&a_ctx, 0, None).unwrap();
        let b_ctx = RunContext::build(&cfg(2, 4)).unwrap();
        let (sb, b) = run_worker(&b_ctx, 0, None).unwrap();
        assert_eq!(sa, sb);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.comm.remote_rows, y.comm.remote_rows);
            assert!((x.epoch_time - y.epoch_time).abs() < 1e-12);
        }
    }
}
