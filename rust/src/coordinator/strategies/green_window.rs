//! `green-window` — GreenGNN-style energy-aware windowed communication
//! (arXiv 2606.02916) as a registry-only engine.
//!
//! Like the on-demand baselines it samples online and fetches every remote
//! feature synchronously — but instead of one pull per batch it merges the
//! fetches of `W = EngineParams::fetch_window` consecutive batches into one
//! windowed pull. Same rows on the wire, far fewer RPCs: per window each
//! touched owner shard is paid one RPC latency instead of `W`. The trade is
//! step latency — the first batch of each window stalls for the whole
//! window's sampling + fetch (its staging cost), while the remaining `W−1`
//! batches stage for free. Fewer, larger RPCs also mean less time stalled in
//! polling RPC loops, which is where the CPU burns `cpu_net_wait_w` — the
//! GreenGNN energy argument.
//!
//! At `W = 1` this engine is exactly `dgl-metis` (pinned by a test below).

use crate::config::{ExecMode, RunConfig};
use crate::coordinator::common::RunContext;
use crate::coordinator::strategies::baseline::{
    enumerate_on_demand, finish_on_demand_epoch, on_demand_setup,
};
use crate::coordinator::strategy::{
    BatchPlan, EpochFinish, EpochTotals, PipelineOutcome, StagedStep, StrategySetup,
    StrategyState, TrainingStrategy,
};
use crate::kvstore::PullRequest;
use crate::metrics::{CommStats, PhaseTimes};
use crate::prefetch::StagedBatch;
use crate::sampler::BatchMeta;
use crate::{NodeId, Result, WorkerId};
use std::collections::VecDeque;

/// Windowed-communication engine.
pub struct GreenWindowStrategy {
    /// Batches per fetch window (≥ 1, from `EngineParams`).
    window: u32,
}

/// Registry constructor.
pub fn ctor(cfg: &RunConfig) -> Box<dyn TrainingStrategy> {
    Box::new(GreenWindowStrategy { window: cfg.engine_params.fetch_window.max(1) })
}

/// The windowed batch plan: buffers one window of staged batches; the first
/// `next` of a window performs all of its sampling and the single merged
/// pull, later `next`s drain the buffer at zero staging cost.
struct WindowedPlan<'a> {
    ctx: &'a RunContext,
    worker: WorkerId,
    batches: std::vec::IntoIter<BatchMeta>,
    window: usize,
    ready: VecDeque<StagedStep>,
    slow: f64,
    full: bool,
    /// Training epoch this plan stages (transient-phase resolution).
    epoch: u32,
}

impl BatchPlan for WindowedPlan<'_> {
    fn next(
        &mut self,
        comm: &mut CommStats,
        phases: &mut PhaseTimes,
    ) -> Result<Option<StagedStep>> {
        if let Some(step) = self.ready.pop_front() {
            return Ok(Some(step));
        }
        let metas: Vec<BatchMeta> = self.batches.by_ref().take(self.window).collect();
        if metas.is_empty() {
            return Ok(None);
        }

        // Online sampling is still per batch (the windowing only merges the
        // network side); local work carries the worker slowdown.
        let mut sample_total = 0.0;
        for meta in &metas {
            let s = self.slow * self.ctx.costs.sample_time(meta.input_nodes.len());
            phases.sample += s;
            sample_total += s;
        }

        // One merged pull over the window's concatenated input sets: the
        // fabric charges one RPC per touched owner shard per *window*. No
        // dedup across batches — every row a per-batch engine would move
        // still moves, so remote rows match `dgl-metis` exactly; only the
        // RPC count shrinks (and with it the per-RPC latency charges and
        // 64-byte header bytes).
        let all_ids: Vec<NodeId> = metas
            .iter()
            .flat_map(|m| m.input_nodes.iter().copied())
            .collect();
        let mut rows: Vec<f32> = Vec::new();
        let materialize = self.full && self.ctx.kv.has_values();
        let pull = self.ctx.kv.pull(
            PullRequest::sync(self.worker, &all_ids).at(self.epoch),
            if materialize { Some(&mut rows) } else { None },
            comm,
        );
        phases.fetch += pull.time;

        // Split the gathered block back per batch (request order == the
        // concatenation order), and attribute the whole window's cost to its
        // first batch — that is the step-latency trade.
        let d = self.ctx.kv.feature_dim();
        let mut offset = 0usize;
        for (i, meta) in metas.into_iter().enumerate() {
            let n = meta.input_nodes.len();
            let features = if materialize {
                let block = rows[offset * d..(offset + n) * d].to_vec();
                Some(block)
            } else {
                None
            };
            offset += n;
            let num_remote = meta.num_remote;
            let cost = if i == 0 {
                sample_total + pull.time
            } else {
                0.0
            };
            self.ready.push_back(StagedStep {
                staged: StagedBatch {
                    meta,
                    features,
                    stage_time: cost,
                    pull_time: if i == 0 { pull.time } else { 0.0 },
                    cache_hits: 0,
                    misses: num_remote,
                },
                cost,
            });
        }
        Ok(self.ready.pop_front())
    }
}

impl TrainingStrategy for GreenWindowStrategy {
    fn id(&self) -> &'static str {
        "green-window"
    }

    fn name(&self) -> &'static str {
        "GreenWindow"
    }

    fn queue_depth(&self, _cfg: &RunConfig) -> u32 {
        0
    }

    fn setup(&self, _ctx: &RunContext, _worker: WorkerId) -> Result<StrategySetup> {
        Ok(on_demand_setup())
    }

    fn plan_epoch<'a>(
        &self,
        ctx: &'a RunContext,
        state: &mut StrategyState,
        worker: WorkerId,
        epoch: u32,
        _comm: &mut CommStats,
    ) -> Result<Box<dyn BatchPlan + 'a>> {
        let batches = enumerate_on_demand(ctx, state, worker, epoch);
        Ok(Box::new(WindowedPlan {
            ctx,
            worker,
            batches: batches.into_iter(),
            window: self.window as usize,
            ready: VecDeque::new(),
            slow: ctx.slowdown_at(worker, epoch),
            full: ctx.cfg.exec_mode == ExecMode::Full,
            epoch,
        }))
    }

    fn finish_epoch(
        &self,
        ctx: &RunContext,
        state: &mut StrategyState,
        _worker: WorkerId,
        _epoch: u32,
        outcome: &PipelineOutcome,
        totals: &EpochTotals,
        phases: &mut PhaseTimes,
        _comm: &mut CommStats,
    ) -> Result<EpochFinish> {
        finish_on_demand_epoch(ctx, state, outcome, totals, phases)
    }
}

#[cfg(test)]
mod tests {
    use crate::config::{DatasetConfig, DatasetPreset, Engine, RunConfig};
    use crate::coordinator::common::RunContext;
    use crate::coordinator::pipeline::run_worker;
    use crate::metrics::EpochReport;

    fn cfg(engine: Engine, window: u32) -> RunConfig {
        let mut c = RunConfig::default();
        c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
        c.engine = engine;
        c.engine_params.fetch_window = window;
        c.epochs = 2;
        c
    }

    fn rows(rs: &[EpochReport]) -> u64 {
        rs.iter().map(|r| r.comm.remote_rows).sum()
    }

    fn rpcs(rs: &[EpochReport]) -> u64 {
        rs.iter().map(|r| r.comm.sync_pulls).sum()
    }

    #[test]
    fn window_one_is_exactly_dgl_metis() {
        let g_ctx = RunContext::build(&cfg(Engine::GreenWindow, 1)).unwrap();
        let (_, green) = run_worker(&g_ctx, 0, None).unwrap();
        let m_ctx = RunContext::build(&cfg(Engine::DglMetis, 1)).unwrap();
        let (_, metis) = run_worker(&m_ctx, 0, None).unwrap();
        assert_eq!(green.len(), metis.len());
        for (a, b) in green.iter().zip(&metis) {
            assert_eq!(a.comm.remote_rows, b.comm.remote_rows);
            assert_eq!(a.comm.sync_pulls, b.comm.sync_pulls);
            assert_eq!(a.comm.bytes, b.comm.bytes);
            assert_eq!(a.steps, b.steps);
            assert!((a.epoch_time - b.epoch_time).abs() < 1e-12);
        }
    }

    #[test]
    fn windowing_cuts_rpcs_not_rows() {
        let g_ctx = RunContext::build(&cfg(Engine::GreenWindow, 4)).unwrap();
        let (_, green) = run_worker(&g_ctx, 0, None).unwrap();
        let m_ctx = RunContext::build(&cfg(Engine::DglMetis, 4)).unwrap();
        let (_, metis) = run_worker(&m_ctx, 0, None).unwrap();
        assert_eq!(rows(&green), rows(&metis), "windowing must not change data movement");
        assert!(
            rpcs(&green) < rpcs(&metis),
            "merged windows must issue fewer RPCs: {} !< {}",
            rpcs(&green),
            rpcs(&metis)
        );
    }

    #[test]
    fn fewer_rpcs_means_less_network_time() {
        // The latency amortization the energy argument rests on.
        let g_ctx = RunContext::build(&cfg(Engine::GreenWindow, 4)).unwrap();
        let (_, green) = run_worker(&g_ctx, 0, None).unwrap();
        let m_ctx = RunContext::build(&cfg(Engine::DglMetis, 4)).unwrap();
        let (_, metis) = run_worker(&m_ctx, 0, None).unwrap();
        let net = |rs: &[EpochReport]| -> f64 { rs.iter().map(|r| r.comm.net_time).sum() };
        assert!(net(&green) < net(&metis), "{} !< {}", net(&green), net(&metis));
    }

    #[test]
    fn deterministic_across_runs() {
        let a_ctx = RunContext::build(&cfg(Engine::GreenWindow, 4)).unwrap();
        let (_, a) = run_worker(&a_ctx, 0, None).unwrap();
        let b_ctx = RunContext::build(&cfg(Engine::GreenWindow, 4)).unwrap();
        let (_, b) = run_worker(&b_ctx, 0, None).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.comm.remote_rows, y.comm.remote_rows);
            assert!((x.epoch_time - y.epoch_time).abs() < 1e-12);
        }
    }
}
