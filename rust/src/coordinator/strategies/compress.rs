//! The communication-compression engine family: RapidGNN's data movement
//! with compressed payloads.
//!
//! Both engines delegate every scheduling decision (precompute, hot-set
//! cache, prefetch window, epoch bookkeeping) to [`RapidStrategy`] and
//! override only the compression hooks:
//!
//! - **`quant-pull`** resolves the `Codec::Default` sentinel to **int8**, so
//!   every remote feature row is charged at its quantized wire size (1
//!   byte/element + an 8-byte header per `codec_block` elements) and, in
//!   full mode, the trainer consumes the dequantized reconstruction. With an
//!   explicit `codec = "none"` the engine is bit-exact `rapid` — the same
//!   degeneration pin as `adaptive-cache`'s `resize_period = 0`.
//! - **`grad-topk`** requests error-feedback gradient sparsification: each
//!   step only the top (or seeded-random) `grad_k` fraction of gradient
//!   coordinates per parameter group is applied; the dropped mass carries
//!   forward as residual. `grad_k = 0` degenerates to `rapid`.
//!
//! Because the codec hook is resolved by the *trait default* for every other
//! engine, an explicit `codec = "f16"`/`"int8"` also composes with
//! `green-window`'s merged pulls — the windowed RPC simply charges the
//! compressed payload for its row total.

use super::rapid::RapidStrategy;
use crate::compress::{BlockCodec, Codec};
use crate::config::{EngineParams, RunConfig};
use crate::coordinator::common::RunContext;
use crate::coordinator::strategy::{
    resolve_codec, BatchPlan, EpochFinish, EpochTotals, GradCompression, PipelineOutcome,
    StrategySetup, StrategyState, TrainingStrategy,
};
use crate::metrics::{CommStats, PhaseTimes};
use crate::partition::Partitioner;
use crate::sampler::khop::Fanout;
use crate::util::value::Value;
use crate::{Result, WorkerId};

/// RapidGNN shipping quantized feature rows (int8 by default).
pub struct QuantPullStrategy {
    inner: RapidStrategy,
}

/// Registry constructor for `quant-pull`.
pub fn quant_pull_ctor(_cfg: &RunConfig) -> Box<dyn TrainingStrategy> {
    Box::new(QuantPullStrategy { inner: RapidStrategy })
}

impl TrainingStrategy for QuantPullStrategy {
    fn id(&self) -> &'static str {
        "quant-pull"
    }

    fn name(&self) -> &'static str {
        "QuantPull"
    }

    fn feature_codec(&self, params: &EngineParams) -> Option<BlockCodec> {
        resolve_codec(params, Codec::Int8)
    }

    fn partitioner(&self) -> Partitioner {
        self.inner.partitioner()
    }

    fn fanouts(&self, cfg: &RunConfig) -> Vec<Fanout> {
        self.inner.fanouts(cfg)
    }

    fn queue_depth(&self, cfg: &RunConfig) -> u32 {
        self.inner.queue_depth(cfg)
    }

    fn schedule_epoch(&self, cfg: &RunConfig, epoch: u32) -> u32 {
        self.inner.schedule_epoch(cfg, epoch)
    }

    fn setup(&self, ctx: &RunContext, worker: WorkerId) -> Result<StrategySetup> {
        self.inner.setup(ctx, worker)
    }

    fn plan_epoch<'a>(
        &self,
        ctx: &'a RunContext,
        state: &mut StrategyState,
        worker: WorkerId,
        epoch: u32,
        comm: &mut CommStats,
    ) -> Result<Box<dyn BatchPlan + 'a>> {
        self.inner.plan_epoch(ctx, state, worker, epoch, comm)
    }

    fn finish_epoch(
        &self,
        ctx: &RunContext,
        state: &mut StrategyState,
        worker: WorkerId,
        epoch: u32,
        outcome: &PipelineOutcome,
        totals: &EpochTotals,
        phases: &mut PhaseTimes,
        comm: &mut CommStats,
    ) -> Result<EpochFinish> {
        self.inner
            .finish_epoch(ctx, state, worker, epoch, outcome, totals, phases, comm)
    }

    fn checkpoint_state(
        &self,
        ctx: &RunContext,
        state: &StrategyState,
        worker: WorkerId,
    ) -> Result<Value> {
        self.inner.checkpoint_state(ctx, state, worker)
    }

    fn restore_setup(
        &self,
        ctx: &RunContext,
        worker: WorkerId,
        next_epoch: u32,
        snapshot: &Value,
    ) -> Result<StrategySetup> {
        self.inner.restore_setup(ctx, worker, next_epoch, snapshot)
    }

    fn cache_rows(&self, state: &StrategyState, worker: WorkerId) -> u64 {
        self.inner.cache_rows(state, worker)
    }
}

/// RapidGNN with error-feedback gradient sparsification.
pub struct GradTopkStrategy {
    inner: RapidStrategy,
}

/// Registry constructor for `grad-topk`.
pub fn grad_topk_ctor(_cfg: &RunConfig) -> Box<dyn TrainingStrategy> {
    Box::new(GradTopkStrategy { inner: RapidStrategy })
}

impl TrainingStrategy for GradTopkStrategy {
    fn id(&self) -> &'static str {
        "grad-topk"
    }

    fn name(&self) -> &'static str {
        "GradTopK"
    }

    fn grad_compression(&self, params: &EngineParams) -> Option<GradCompression> {
        if params.grad_k > 0.0 {
            Some(GradCompression { mode: params.grad_mode, k: params.grad_k })
        } else {
            None
        }
    }

    fn partitioner(&self) -> Partitioner {
        self.inner.partitioner()
    }

    fn fanouts(&self, cfg: &RunConfig) -> Vec<Fanout> {
        self.inner.fanouts(cfg)
    }

    fn queue_depth(&self, cfg: &RunConfig) -> u32 {
        self.inner.queue_depth(cfg)
    }

    fn schedule_epoch(&self, cfg: &RunConfig, epoch: u32) -> u32 {
        self.inner.schedule_epoch(cfg, epoch)
    }

    fn setup(&self, ctx: &RunContext, worker: WorkerId) -> Result<StrategySetup> {
        self.inner.setup(ctx, worker)
    }

    fn plan_epoch<'a>(
        &self,
        ctx: &'a RunContext,
        state: &mut StrategyState,
        worker: WorkerId,
        epoch: u32,
        comm: &mut CommStats,
    ) -> Result<Box<dyn BatchPlan + 'a>> {
        self.inner.plan_epoch(ctx, state, worker, epoch, comm)
    }

    fn finish_epoch(
        &self,
        ctx: &RunContext,
        state: &mut StrategyState,
        worker: WorkerId,
        epoch: u32,
        outcome: &PipelineOutcome,
        totals: &EpochTotals,
        phases: &mut PhaseTimes,
        comm: &mut CommStats,
    ) -> Result<EpochFinish> {
        self.inner
            .finish_epoch(ctx, state, worker, epoch, outcome, totals, phases, comm)
    }

    fn checkpoint_state(
        &self,
        ctx: &RunContext,
        state: &StrategyState,
        worker: WorkerId,
    ) -> Result<Value> {
        self.inner.checkpoint_state(ctx, state, worker)
    }

    fn restore_setup(
        &self,
        ctx: &RunContext,
        worker: WorkerId,
        next_epoch: u32,
        snapshot: &Value,
    ) -> Result<StrategySetup> {
        self.inner.restore_setup(ctx, worker, next_epoch, snapshot)
    }

    fn cache_rows(&self, state: &StrategyState, worker: WorkerId) -> u64 {
        self.inner.cache_rows(state, worker)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{GradMode, WireCodec};

    #[test]
    fn quant_pull_resolves_default_codec_to_int8() {
        let s = quant_pull_ctor(&RunConfig::default());
        let mut p = EngineParams::default();
        let codec = s.feature_codec(&p).expect("default codec is int8");
        assert_eq!(codec.kind, WireCodec::Int8);
        assert_eq!(codec.block, p.codec_block as usize);
        // explicit none disables — the degeneration pin
        p.codec = Codec::None;
        assert!(s.feature_codec(&p).is_none());
        // explicit f16 overrides the engine default
        p.codec = Codec::F16;
        assert_eq!(s.feature_codec(&p).unwrap().kind, WireCodec::F16);
    }

    #[test]
    fn other_engines_resolve_default_codec_to_none() {
        let reg = crate::coordinator::EngineRegistry::global();
        let p = EngineParams::default();
        for id in ["rapid", "dgl-metis", "green-window", "grad-topk"] {
            let s = reg.create_by_id(id, &RunConfig::default()).unwrap();
            assert!(s.feature_codec(&p).is_none(), "{id} must default to uncompressed");
        }
        // ...but an explicit codec composes with any engine
        let mut p = EngineParams::default();
        p.codec = Codec::Int8;
        p.codec_block = 64;
        let gw = reg.create_by_id("green-window", &RunConfig::default()).unwrap();
        let codec = gw.feature_codec(&p).unwrap();
        assert_eq!(codec.kind, WireCodec::Int8);
        assert_eq!(codec.block, 64);
    }

    #[test]
    fn grad_topk_requests_sparsification_unless_disabled() {
        let s = grad_topk_ctor(&RunConfig::default());
        let mut p = EngineParams::default();
        let spec = s.grad_compression(&p).expect("default grad_k is 0.1");
        assert_eq!(spec.mode, GradMode::TopK);
        assert_eq!(spec.k, 0.1);
        p.grad_mode = GradMode::RandK;
        p.grad_k = 0.5;
        let spec = s.grad_compression(&p).unwrap();
        assert_eq!(spec.mode, GradMode::RandK);
        assert_eq!(spec.k, 0.5);
        p.grad_k = 0.0;
        assert!(s.grad_compression(&p).is_none(), "grad_k = 0 degenerates to rapid");
        // quant-pull and rapid never request it
        let q = quant_pull_ctor(&RunConfig::default());
        assert!(q.grad_compression(&EngineParams::default()).is_none());
    }
}
