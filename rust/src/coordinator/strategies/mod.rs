//! Concrete [`crate::coordinator::TrainingStrategy`] implementations.
//!
//! - [`rapid`] — the paper's engine: precomputed schedules on SSD, hot-set
//!   double-buffered cache, prefetch window `Q`.
//! - [`baseline`] — the on-demand DistDGL-style baselines (`dgl-metis`,
//!   `dgl-random`, `dist-gcn`): online sampling, every remote feature
//!   fetched synchronously, `Q = 0`.
//! - [`fast_sample`] — FastSample-style periodic re-sampling (arXiv
//!   2311.17847): re-enumerate every `k` epochs, replay in between.
//! - [`green_window`] — GreenGNN-style windowed communication (arXiv
//!   2606.02916): merge `W` consecutive batches' fetches into one pull.
//! - [`adaptive_cache`] — RapidGNN with a per-epoch hot-cache controller:
//!   `n_hot` resized between epochs from observed hit rates, clamped with
//!   hysteresis.
//! - [`compress`] — the communication-compression family (`quant-pull`,
//!   `grad-topk`): RapidGNN's schedule and cache, shipping quantized feature
//!   rows and/or error-fed sparse gradients.
//!
//! All but the first two are registry-only engines: no coordinator file
//! outside this directory knows they exist.

pub mod adaptive_cache;
pub mod baseline;
pub mod compress;
pub mod fast_sample;
pub mod green_window;
pub mod rapid;
