//! `adaptive-cache` — RapidGNN with a per-epoch hot-cache controller.
//!
//! The paper's Fig-5 sweep shows hit rate and remote-fetch reduction are
//! sharply sensitive to `n_hot`, yet the right size depends on the access
//! distribution — a static knob is either undersized (misses on the
//! critical path) or oversized (device memory spent on entries that never
//! hit). This engine closes the loop: between epochs a deterministic
//! controller reads the epoch's observed hit/miss tally (from
//! `cache::split_hits`) plus the *next* epoch's precomputed remote-frequency
//! ranking, and resizes `n_hot` before the background `C_sec` build runs:
//!
//! - **grow** (multiplicative, × `hot_growth`) while the observed hit rate
//!   is below `target_hit_rate`;
//! - **shrink** (÷ `hot_growth`) when the marginal tail — the lowest-ranked
//!   quarter of the hot set — serves less than `tail_utility` of all remote
//!   accesses (those entries are not earning their memory);
//! - clamped to `[min_hot, max_hot]`, with **hysteresis**: after a resize,
//!   opposite-direction resizes are suppressed for `hysteresis` controller
//!   evaluations, so alternating hit rates cannot make the size flip-flop.
//!
//! Everything is a pure function of simulated quantities — no wall-clock
//! input — so runs stay bit-reproducible across thread counts and the
//! cluster/sequential conformance contract holds like every other engine.
//!
//! With `resize_period = 0` the controller never fires and the engine is
//! the static `rapid` strategy *bit-exactly* (same schedules, same cache
//! builds, same simulated times) — pinned by a test below. The only
//! reporting difference is the per-epoch [`CacheReport`] telemetry, which
//! static engines omit.
//!
//! Lifecycle (where the resize sits):
//!
//! ```text
//! setup            precompute all epochs; C_s sized clamp(n_hot, min, max)
//! plan_epoch(e)    stream epoch e's schedule against the current C_s
//! finish_epoch(e)  1. read epoch e's hit/miss stats
//!                  2. rank epoch e+1's schedule (stream_ranked_top: O(R)
//!                     partial selection, cut at the largest size this
//!                     boundary could need)
//!                  3. controller: maybe resize n_hot        ← the new step
//!                  4. build C_sec = top-n_hot of that ranking, swap
//! ```

use super::rapid::{
    checkpoint_rapid_state, finish_cached_epoch_with, plan_rapid_epoch, precompute_epochs_n,
    restore_rapid_state, stream_ranked_top, CacheRebuild, RapidState,
};
use crate::cache::tail_mass_fraction;
use crate::config::{EngineParams, RunConfig};
use crate::coordinator::common::RunContext;
use crate::coordinator::strategy::{
    BatchPlan, EpochFinish, EpochTotals, PipelineOutcome, StrategySetup, StrategyState,
    TrainingStrategy,
};
use crate::metrics::{CacheReport, CommStats, PhaseTimes};
use crate::util::value::Value;
use crate::{NodeId, Result, WorkerId};

/// The deterministic resize policy: thresholds and clamps, copied out of
/// [`EngineParams`] at construction.
#[derive(Debug, Clone, Copy)]
pub(crate) struct Controller {
    pub(crate) min_hot: u32,
    pub(crate) max_hot: u32,
    pub(crate) target_hit_rate: f64,
    pub(crate) tail_utility: f64,
    pub(crate) growth: f64,
    pub(crate) hysteresis: u32,
}

/// Per-worker controller state, evolved at each evaluated epoch boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct CtrlState {
    /// Current steady-cache capacity.
    pub(crate) n_hot: u32,
    /// Direction of the last applied resize (+1 grow, −1 shrink, 0 none).
    pub(crate) last_dir: i8,
    /// Evaluations left during which opposite-direction resizes are
    /// suppressed.
    pub(crate) cooldown: u32,
    /// Resizes applied so far (the report's `resize_events`).
    pub(crate) resizes: u32,
}

impl CtrlState {
    fn new(n_hot: u32) -> CtrlState {
        CtrlState { n_hot, last_dir: 0, cooldown: 0, resizes: 0 }
    }
}

impl Controller {
    fn from_params(p: &EngineParams) -> Controller {
        Controller {
            min_hot: p.min_hot,
            max_hot: p.max_hot,
            target_hit_rate: p.target_hit_rate,
            tail_utility: p.tail_utility,
            growth: p.hot_growth,
            hysteresis: p.hysteresis,
        }
    }

    /// One controller evaluation at an epoch boundary. `hit_rate` is the
    /// finished epoch's observed rate; `tail_mass` the fraction of all
    /// remote accesses served by the hot set's marginal quarter under the
    /// next epoch's ranking. Returns the (possibly unchanged) capacity.
    pub(crate) fn decide(&self, st: &mut CtrlState, hit_rate: f64, tail_mass: f64) -> u32 {
        // Shrink precedence: when the marginal entries are useless, growing
        // would only add entries ranked even lower.
        let dir: i8 = if tail_mass < self.tail_utility && st.n_hot > self.min_hot {
            -1
        } else if hit_rate < self.target_hit_rate && st.n_hot < self.max_hot {
            1
        } else {
            0
        };
        // Suppression is checked *before* this evaluation consumes a
        // cooldown tick, so a resize at evaluation t suppresses opposite
        // directions at evaluations t+1 … t+hysteresis — exactly the
        // documented count (hysteresis = 1 damps one evaluation).
        let suppressed = st.cooldown > 0 && dir != st.last_dir;
        st.cooldown = st.cooldown.saturating_sub(1);
        if dir != 0 && !suppressed {
            let next = if dir > 0 {
                ((st.n_hot as f64 * self.growth).ceil() as u32).min(self.max_hot)
            } else {
                ((st.n_hot as f64 / self.growth).floor() as u32).max(self.min_hot)
            };
            if next != st.n_hot {
                st.n_hot = next;
                st.last_dir = dir;
                st.cooldown = self.hysteresis;
                st.resizes += 1;
            }
        }
        st.n_hot
    }
}

/// Per-worker state: the rapid-family cache state plus the controller.
struct AdaptiveState {
    inner: RapidState,
    ctrl: CtrlState,
}

/// The adaptive engine.
pub struct AdaptiveCacheStrategy {
    controller: Controller,
    /// Evaluate the controller at every `resize_period`-th boundary;
    /// 0 = never (static degeneration).
    resize_period: u32,
}

/// Registry constructor.
pub fn ctor(cfg: &RunConfig) -> Box<dyn TrainingStrategy> {
    Box::new(AdaptiveCacheStrategy {
        controller: Controller::from_params(&cfg.engine_params),
        resize_period: cfg.engine_params.resize_period,
    })
}

impl AdaptiveCacheStrategy {
    /// Whether the controller evaluates at the boundary *into* `epoch`.
    fn fires_at(&self, boundary: u32) -> bool {
        self.resize_period > 0 && boundary % self.resize_period == 0
    }

    fn initial_n_hot(&self, cfg: &RunConfig) -> u32 {
        if self.resize_period == 0 {
            // Controller disabled: static rapid semantics, clamps included —
            // anything else would break the bit-exact degeneration.
            cfg.n_hot
        } else {
            cfg.n_hot.clamp(self.controller.min_hot, self.controller.max_hot)
        }
    }
}

impl TrainingStrategy for AdaptiveCacheStrategy {
    fn id(&self) -> &'static str {
        "adaptive-cache"
    }

    fn name(&self) -> &'static str {
        "AdaptiveCache"
    }

    fn queue_depth(&self, cfg: &RunConfig) -> u32 {
        cfg.prefetch_q
    }

    fn setup(&self, ctx: &RunContext, worker: WorkerId) -> Result<StrategySetup> {
        let initial = self.initial_n_hot(&ctx.cfg);
        let epochs: Vec<u32> = (0..ctx.cfg.epochs).collect();
        let s = precompute_epochs_n(ctx, worker, &epochs, initial)?;
        Ok(StrategySetup {
            setup_time: s.setup_time,
            state: Box::new(AdaptiveState {
                inner: RapidState { cache: s.cache, setup_comm: s.setup_comm },
                ctrl: CtrlState::new(initial),
            }),
        })
    }

    fn plan_epoch<'a>(
        &self,
        ctx: &'a RunContext,
        state: &mut StrategyState,
        worker: WorkerId,
        epoch: u32,
        comm: &mut CommStats,
    ) -> Result<Box<dyn BatchPlan + 'a>> {
        let st = state.downcast_mut::<AdaptiveState>().expect("adaptive-cache worker state");
        plan_rapid_epoch(ctx, &mut st.inner, worker, epoch, epoch, comm)
    }

    fn finish_epoch(
        &self,
        ctx: &RunContext,
        state: &mut StrategyState,
        worker: WorkerId,
        epoch: u32,
        outcome: &PipelineOutcome,
        totals: &EpochTotals,
        phases: &mut PhaseTimes,
        comm: &mut CommStats,
    ) -> Result<EpochFinish> {
        let st = state.downcast_mut::<AdaptiveState>().expect("adaptive-cache worker state");
        // The capacity that served this epoch, and what it observed.
        let serving_n = st.ctrl.n_hot;
        let stats = st.inner.cache.lock().unwrap().stats();

        let next = epoch + 1;
        let rebuild = if next < ctx.cfg.epochs {
            // One stream pass yields both the controller's tail signal and
            // the C_sec hot list; the simulated cost is identical to the
            // static engine's stream_top_hot pass. An epoch with no cache
            // lookups carries no hit-rate signal (hit_rate() reads 0.0),
            // so the controller holds rather than growing on silence —
            // mirroring tail_mass_fraction's never-shrink-on-empty rule.
            let fires = self.fires_at(next) && stats.lookups > 0;
            // Cut the ranking at the largest size this boundary could need
            // (the grown capacity if the controller fires) — an O(R)
            // partial selection instead of sorting the full ranking.
            let k_max = if fires {
                let grown = ((st.ctrl.n_hot as f64 * self.controller.growth).ceil() as u32)
                    .min(self.controller.max_hot);
                st.ctrl.n_hot.max(grown)
            } else {
                st.ctrl.n_hot
            };
            let (top, total, rank_time) = stream_ranked_top(ctx, worker, next, k_max)?;
            if fires {
                let before = st.ctrl.n_hot;
                let tail = tail_mass_fraction(&top, total, st.ctrl.n_hot);
                self.controller.decide(&mut st.ctrl, stats.hit_rate(), tail);
                if st.ctrl.n_hot != before {
                    if let Some(trace) = &ctx.trace {
                        let mut fields = crate::util::value::Value::table();
                        fields.set("from", before);
                        fields.set("to", st.ctrl.n_hot);
                        fields.set("hit_rate", stats.hit_rate());
                        fields.set("tail", tail);
                        trace.event(worker, next, 0.0, "cache-resize", fields);
                    }
                }
            }
            let k = (st.ctrl.n_hot as usize).min(top.len());
            let hot: Vec<NodeId> = top[..k].iter().map(|&(v, _)| v).collect();
            Some(CacheRebuild { hot, local_time: ctx.slowdown_at(worker, epoch) * rank_time })
        } else {
            None
        };
        // Capacity of the C_sec just staged — differs from serving_n on a
        // resize epoch, and the device-memory bound must cover both buffers.
        let staged_n = if rebuild.is_some() {
            st.ctrl.n_hot
        } else {
            serving_n
        };

        let mut finish = finish_cached_epoch_with(
            ctx, &mut st.inner, worker, epoch, rebuild, serving_n, staged_n, outcome, totals,
            phases, comm,
        )?;
        finish.cache_plan = Some(CacheReport {
            n_hot: serving_n,
            hits: stats.hits,
            misses: stats.misses(),
            hit_rate: stats.hit_rate(),
            resize_events: st.ctrl.resizes,
        });
        Ok(finish)
    }

    fn checkpoint_state(
        &self,
        _ctx: &RunContext,
        state: &StrategyState,
        _worker: WorkerId,
    ) -> Result<Value> {
        let st = state.downcast_ref::<AdaptiveState>().expect("adaptive-cache worker state");
        // The rapid-family snapshot (steady hot list) plus the controller's
        // full evolution state — resumed runs must make the same resize
        // decisions the uninterrupted run would, hysteresis included.
        let mut v = checkpoint_rapid_state(&st.inner);
        let mut ctrl = Value::table();
        ctrl.set("n_hot", st.ctrl.n_hot);
        ctrl.set("last_dir", st.ctrl.last_dir as i64);
        ctrl.set("cooldown", st.ctrl.cooldown);
        ctrl.set("resizes", st.ctrl.resizes);
        v.set("ctrl", ctrl);
        Ok(v)
    }

    fn restore_setup(
        &self,
        ctx: &RunContext,
        worker: WorkerId,
        next_epoch: u32,
        snapshot: &Value,
    ) -> Result<StrategySetup> {
        let hot = snapshot.req_u32_array("hot")?;
        let epochs: Vec<u32> = (next_epoch..ctx.cfg.epochs).collect();
        let inner = restore_rapid_state(ctx, worker, &epochs, &hot)?;
        let c = snapshot.req_table("ctrl")?;
        let ctrl = CtrlState {
            n_hot: u32::try_from(c.req_u64("n_hot")?)?,
            last_dir: i8::try_from(c.req_i64("last_dir")?)?,
            cooldown: u32::try_from(c.req_u64("cooldown")?)?,
            resizes: u32::try_from(c.req_u64("resizes")?)?,
        };
        Ok(StrategySetup {
            setup_time: 0.0,
            state: Box::new(AdaptiveState { inner, ctrl }),
        })
    }

    fn cache_rows(&self, state: &StrategyState, _worker: WorkerId) -> u64 {
        state
            .downcast_ref::<AdaptiveState>()
            .expect("adaptive-cache worker state")
            .inner
            .cache_rows()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetPreset, Engine, RunConfig};
    use crate::coordinator::pipeline::run_worker;

    fn cfg(n_hot: u32, epochs: u32) -> RunConfig {
        let mut c = RunConfig::default();
        c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
        c.engine = Engine::AdaptiveCache;
        c.epochs = epochs;
        c.n_hot = n_hot;
        c
    }

    fn controller() -> Controller {
        Controller {
            min_hot: 100,
            max_hot: 1_600,
            target_hit_rate: 0.9,
            tail_utility: 0.01,
            growth: 2.0,
            hysteresis: 2,
        }
    }

    #[test]
    fn controller_grows_on_low_hit_rate_and_clamps_at_max() {
        let c = controller();
        let mut st = CtrlState::new(400);
        assert_eq!(c.decide(&mut st, 0.5, 0.5), 800);
        assert_eq!(c.decide(&mut st, 0.5, 0.5), 1_600);
        assert_eq!(c.decide(&mut st, 0.5, 0.5), 1_600, "clamped at max_hot");
        assert_eq!(st.resizes, 2, "clamped evaluations are not resize events");
    }

    #[test]
    fn controller_shrinks_on_useless_tail_and_clamps_at_min() {
        let c = controller();
        let mut st = CtrlState::new(400);
        assert_eq!(c.decide(&mut st, 0.99, 0.001), 200);
        assert_eq!(c.decide(&mut st, 0.99, 0.001), 100);
        assert_eq!(c.decide(&mut st, 0.99, 0.001), 100, "clamped at min_hot");
        assert_eq!(st.resizes, 2);
    }

    #[test]
    fn controller_holds_inside_the_deadband() {
        let c = controller();
        let mut st = CtrlState::new(400);
        // hit rate at target, tail earning its keep: no movement, ever
        for _ in 0..5 {
            assert_eq!(c.decide(&mut st, 0.95, 0.2), 400);
        }
        assert_eq!(st.resizes, 0);
    }

    #[test]
    fn hysteresis_prevents_flip_flop_on_alternating_signals() {
        // Alternate a grow signal with a shrink signal at every evaluation.
        let alternating = |c: &Controller| -> Vec<u32> {
            let mut st = CtrlState::new(400);
            (0..6)
                .map(|i| {
                    let (hit, tail) =
                        if i % 2 == 0 { (0.5, 0.5) } else { (0.99, 0.001) };
                    c.decide(&mut st, hit, tail)
                })
                .collect()
        };
        // Without hysteresis the size bounces A→B→A immediately.
        let bare = alternating(&Controller { hysteresis: 0, ..controller() });
        assert!(
            bare.windows(3).any(|w| w[0] == w[2] && w[1] != w[0]),
            "expected oscillation without hysteresis: {bare:?}"
        );
        // With hysteresis, no A→B→A bounce anywhere in the trajectory: the
        // opposite-direction request right after a resize is suppressed.
        let damped = alternating(&controller());
        for w in damped.windows(3) {
            assert!(w[0] != w[2] || w[1] == w[0], "flip-flop {:?} in {:?}", w, damped);
        }
    }

    #[test]
    fn hysteresis_one_damps_exactly_one_evaluation() {
        // The documented count: hysteresis = 1 suppresses the opposite
        // direction for exactly the one evaluation after a resize.
        let c = Controller { hysteresis: 1, ..controller() };
        let mut st = CtrlState::new(400);
        assert_eq!(c.decide(&mut st, 0.5, 0.5), 800, "grow applies");
        assert_eq!(c.decide(&mut st, 0.99, 0.001), 800, "opposite suppressed once");
        assert_eq!(c.decide(&mut st, 0.99, 0.001), 400, "then allowed");
    }

    #[test]
    fn resize_period_zero_degenerates_to_rapid_bit_exactly() {
        // Controller disabled → the engine must be the static rapid path,
        // operation for operation: identical setup time, counters, and
        // simulated epoch times (exact f64 equality, not tolerance). The
        // n_hot = 32 case sits below the default min_hot clamp: a disabled
        // controller must not clamp either.
        for n_hot in [300u32, 32] {
            let mut a_cfg = cfg(n_hot, 3);
            a_cfg.engine_params.resize_period = 0;
            let a_ctx = crate::coordinator::common::RunContext::build(&a_cfg).unwrap();
            let (a_setup, adaptive) = run_worker(&a_ctx, 0, None).unwrap();
            let mut r_cfg = cfg(n_hot, 3);
            r_cfg.engine = Engine::Rapid;
            let r_ctx = crate::coordinator::common::RunContext::build(&r_cfg).unwrap();
            let (r_setup, rapid) = run_worker(&r_ctx, 0, None).unwrap();
            assert_eq!(a_setup, r_setup, "n_hot {n_hot}");
            assert_eq!(adaptive.len(), rapid.len());
            for (a, r) in adaptive.iter().zip(&rapid) {
                let tag = format!("n_hot {n_hot} epoch {}", a.epoch);
                assert_eq!(a.comm, r.comm, "{tag}");
                assert_eq!(a.cache, r.cache, "{tag}");
                assert_eq!(a.steps, r.steps, "{tag}");
                assert_eq!(a.device_bytes, r.device_bytes, "{tag}");
                assert_eq!(a.host_bytes, r.host_bytes, "{tag}");
                assert_eq!(a.epoch_time, r.epoch_time, "{tag}: bit-exact epoch time");
                // the only divergence: adaptive reports its telemetry
                let cp = a.cache_plan.expect("adaptive telemetry present");
                assert_eq!(cp.n_hot, n_hot, "{tag}: no clamp with the controller off");
                assert_eq!(cp.resize_events, 0);
                assert!(r.cache_plan.is_none(), "rapid stays telemetry-free");
            }
        }
    }

    #[test]
    fn undersized_cache_grows_and_improves_hit_rate() {
        let mut c = cfg(8, 6);
        c.engine_params.min_hot = 8;
        c.engine_params.max_hot = 800;
        c.engine_params.target_hit_rate = 0.99; // keep growing
        c.engine_params.tail_utility = 0.0; // never shrink
        let ctx = crate::coordinator::common::RunContext::build(&c).unwrap();
        let (_, reports) = run_worker(&ctx, 0, None).unwrap();
        let plans: Vec<_> = reports.iter().map(|r| r.cache_plan.unwrap()).collect();
        assert_eq!(plans[0].n_hot, 8, "starts at the configured size");
        for w in plans.windows(2) {
            assert!(w[1].n_hot >= w[0].n_hot, "growth-only run must be monotone");
        }
        assert!(
            plans.last().unwrap().n_hot > plans[0].n_hot,
            "undersized cache must have grown"
        );
        assert!(plans.iter().all(|p| p.n_hot <= 800), "never exceeds max_hot");
        assert!(
            plans.last().unwrap().hit_rate > plans[0].hit_rate,
            "hit rate {} !> {}",
            plans.last().unwrap().hit_rate,
            plans[0].hit_rate
        );
        assert!(plans.last().unwrap().resize_events >= 1);
    }

    #[test]
    fn oversized_cache_shrinks_toward_the_useful_set() {
        let mut c = cfg(2_000, 6);
        c.engine_params.min_hot = 50;
        c.engine_params.max_hot = 4_000;
        c.engine_params.target_hit_rate = 0.0; // never grow
        c.engine_params.tail_utility = 0.9; // shrink while the tail is thin
        let ctx = crate::coordinator::common::RunContext::build(&c).unwrap();
        let (_, reports) = run_worker(&ctx, 0, None).unwrap();
        let plans: Vec<_> = reports.iter().map(|r| r.cache_plan.unwrap()).collect();
        assert!(
            plans.last().unwrap().n_hot < plans[0].n_hot,
            "oversized cache must shrink: {:?}",
            plans.iter().map(|p| p.n_hot).collect::<Vec<_>>()
        );
        assert!(plans.iter().all(|p| p.n_hot >= 50), "never undercuts min_hot");
    }

    #[test]
    fn deterministic_across_worker_thread_counts() {
        // The controller must not observe thread count: identical serialized
        // reports at RAPIDGNN_THREADS ∈ {1, 2, 8}. (Results are thread-count
        // invariant by the parallel-determinism contract, so concurrently
        // running tests are unaffected by this env churn.)
        let run = || {
            let mut c = cfg(64, 4);
            c.engine_params.target_hit_rate = 0.95;
            crate::coordinator::run(&c).unwrap().to_json()
        };
        let prev = std::env::var("RAPIDGNN_THREADS").ok();
        std::env::set_var("RAPIDGNN_THREADS", "1");
        let serial = run();
        for threads in ["2", "8"] {
            std::env::set_var("RAPIDGNN_THREADS", threads);
            assert_eq!(serial, run(), "threads={threads} changed the adaptive report");
        }
        match prev {
            Some(v) => std::env::set_var("RAPIDGNN_THREADS", v),
            None => std::env::remove_var("RAPIDGNN_THREADS"),
        }
    }

    #[test]
    fn checkpoint_round_trips_controller_and_cache_state() {
        let c = cfg(64, 4);
        let ctx = crate::coordinator::common::RunContext::build(&c).unwrap();
        let strat = ctor(&c);
        let mut setup = strat.setup(&ctx, 0).unwrap();
        // Evolve the controller so the snapshot carries non-trivial state —
        // a resumed run must replay hysteresis, not restart it.
        let evolved = CtrlState { n_hot: 128, last_dir: 1, cooldown: 2, resizes: 3 };
        setup.state.downcast_mut::<AdaptiveState>().unwrap().ctrl = evolved;
        let snap = strat.checkpoint_state(&ctx, &setup.state, 0).unwrap();
        let snap = crate::util::value::Value::from_json(&snap.to_json()).unwrap();

        let ctx2 = crate::coordinator::common::RunContext::build(&c).unwrap();
        let restored = strat.restore_setup(&ctx2, 0, 1, &snap).unwrap();
        assert_eq!(restored.setup_time, 0.0);
        let orig = setup.state.downcast_ref::<AdaptiveState>().unwrap();
        let re = restored.state.downcast_ref::<AdaptiveState>().unwrap();
        assert_eq!(re.ctrl, evolved);
        assert_eq!(
            re.inner.cache.lock().unwrap().steady().ids_by_row(),
            orig.inner.cache.lock().unwrap().steady().ids_by_row()
        );
        assert_eq!(strat.cache_rows(&restored.state, 0), orig.inner.cache_rows());
    }

    #[test]
    fn resize_period_gates_controller_evaluations() {
        let mut c = cfg(8, 6);
        c.engine_params.min_hot = 8;
        c.engine_params.max_hot = 800;
        c.engine_params.target_hit_rate = 0.99;
        c.engine_params.tail_utility = 0.0;
        c.engine_params.resize_period = 2; // boundaries 2 and 4 only
        let ctx = crate::coordinator::common::RunContext::build(&c).unwrap();
        let (_, reports) = run_worker(&ctx, 0, None).unwrap();
        let plans: Vec<_> = reports.iter().map(|r| r.cache_plan.unwrap()).collect();
        // Epoch 1 runs before the first evaluated boundary → still initial.
        assert_eq!(plans[1].n_hot, plans[0].n_hot);
        assert!(plans.last().unwrap().resize_events <= 2, "at most one per evaluation");
        assert!(plans.last().unwrap().n_hot > plans[0].n_hot);
    }
}
