//! The RapidGNN engine as a [`TrainingStrategy`]: Algorithm 1 end to end.
//!
//! Per worker:
//! 1. **Precompute** (offline, once): enumerate every epoch's schedule with
//!    derived seeds and stream the metadata blocks to SSD (setup time, not
//!    training time — reported separately like the paper).
//! 2. **Initial cache build**: rank epoch 0's remote accesses (`TopHot`) and
//!    materialize the steady cache `C_s` with one `VectorPull`.
//! 3. **Per epoch**: the plan streams the schedule from SSD, staging each
//!    batch cache-first with residual `SyncPull` misses; `finish_epoch`
//!    builds `C_sec` for epoch e+1 in the background (only its *overrun*
//!    past the epoch stalls the swap) and swaps at the boundary.
//!
//! The precompute pass is shared with the `fast-sample` engine through
//! [`precompute_epochs`], which enumerates an explicit epoch list.

use crate::cache::{top_hot, CacheBuffer, DoubleBufferCache};
use crate::config::{ExecMode, RunConfig};
use crate::coordinator::common::RunContext;
use crate::coordinator::strategy::{
    BatchPlan, EpochFinish, EpochTotals, PipelineOutcome, StagedStep, StrategySetup,
    StrategyState, TrainingStrategy,
};
use crate::kvstore::PullRequest;
use crate::metrics::CommStats;
use crate::prefetch::stage_batch_at;
use crate::sampler::schedule::{rank_order, tally_remote_threads};
use crate::sampler::{enumerate_epoch, remote_frequency, BatchMeta};
use crate::storage::{write_epoch, EpochReader};
use crate::util::parallel::available_threads;
use crate::util::value::Value;
use crate::{NodeId, Result, WorkerId};
use std::sync::{Arc, Mutex};

/// Setup products of the precompute pass.
pub struct RapidSetup {
    /// Simulated setup seconds (offline sampling + SSD writes + initial
    /// ranking + initial VectorPull).
    pub setup_time: f64,
    /// Comm stats of the initial cache build (merged into epoch 0's report).
    pub setup_comm: CommStats,
    /// The double-buffered cache with `C_s` installed for the first epoch.
    pub cache: Arc<Mutex<DoubleBufferCache>>,
}

/// Per-worker state: the cache plus the initial-build traffic to merge into
/// the first epoch's report. Shared by the `fast-sample` strategy.
pub(crate) struct RapidState {
    pub(crate) cache: Arc<Mutex<DoubleBufferCache>>,
    pub(crate) setup_comm: CommStats,
}

impl RapidState {
    /// Rows held by the steady cache — the warm state a membership change
    /// would have to ship alongside the shard.
    pub(crate) fn cache_rows(&self) -> u64 {
        self.cache.lock().unwrap().steady().len() as u64
    }
}

/// Serialize a rapid-family worker state for a checkpoint: the steady
/// cache's ranked hot-id list. `C_sec` is not recorded — checkpoints are
/// written after the boundary swap, so the steady buffer *is* the cache that
/// serves the next epoch, and the resumed run's own `finish_epoch` stages
/// the following rebuild exactly as the uninterrupted run would. The setup
/// pull isn't recorded either: it only merges into epoch 0's report, which a
/// restore never replays.
pub(crate) fn checkpoint_rapid_state(st: &RapidState) -> Value {
    let mut v = Value::table();
    v.set("hot", &st.cache.lock().unwrap().steady().ids_by_row()[..]);
    v
}

/// Rebuild a rapid-family worker state from a checkpoint without charging
/// the fabric: re-enumerate the listed epochs' schedule metadata to disk
/// (derived-seed deterministic, so the files match the originals byte for
/// byte) and re-install the checkpointed steady cache via a non-charging
/// [`crate::kvstore::KvStore::peek_rows`] gather. Fabric counters and the
/// compression tally are restored from the checkpoint directly, so the
/// resumed run's telemetry lines up with the uninterrupted run's.
pub(crate) fn restore_rapid_state(
    ctx: &RunContext,
    worker: WorkerId,
    reenumerate: &[u32],
    hot: &[NodeId],
) -> Result<RapidState> {
    let cfg = &ctx.cfg;
    let fanouts = ctx.fanouts();
    for &epoch in reenumerate {
        let sched = enumerate_epoch(
            &ctx.ds.graph,
            &ctx.part,
            &ctx.shards[worker as usize],
            &fanouts,
            cfg.batch_size,
            cfg.base_seed,
            worker,
            epoch,
        );
        write_epoch(&ctx.metadata_path, &sched)?;
    }
    let rows = if cfg.exec_mode == ExecMode::Full {
        ctx.kv.peek_rows(worker, hot)
    } else {
        Vec::new()
    };
    let mut cache = DoubleBufferCache::default();
    cache.install_steady(CacheBuffer::new(hot, rows, ctx.kv.feature_dim()));
    Ok(RapidState {
        cache: Arc::new(Mutex::new(cache)),
        // The initial VectorPull only merges into epoch 0, which a resumed
        // run never replays; zero keeps the restored state chargeless.
        setup_comm: CommStats::default(),
    })
}

/// Precompute all epochs to disk and build the initial steady cache (the
/// classic RapidGNN setup — `rapidgnn tune` and the Fig-3 bench call this).
pub fn precompute(ctx: &RunContext, worker: WorkerId) -> Result<RapidSetup> {
    let epochs: Vec<u32> = (0..ctx.cfg.epochs).collect();
    precompute_epochs(ctx, worker, &epochs)
}

/// Precompute an explicit list of epochs to disk and build the initial
/// steady cache from the first listed epoch's schedule, sized by the run
/// config's static `n_hot`.
pub(crate) fn precompute_epochs(
    ctx: &RunContext,
    worker: WorkerId,
    epochs: &[u32],
) -> Result<RapidSetup> {
    precompute_epochs_n(ctx, worker, epochs, ctx.cfg.n_hot)
}

/// [`precompute_epochs`] with an explicit initial cache capacity — the
/// `adaptive-cache` engine seeds its controller with a clamped `n_hot`.
///
/// The enumeration fans out over all cores (`enumerate_epoch` parallelizes
/// over batches — deterministic by the per-batch derived seeds). The first
/// epoch's `TopHot` ranking runs from the in-memory schedule and is
/// accounted as background work overlapping the later epochs' write stream:
/// only its overrun past that stream lands on setup time (the same overrun
/// idiom `finish_epoch` uses for the `C_sec` builds).
pub(crate) fn precompute_epochs_n(
    ctx: &RunContext,
    worker: WorkerId,
    epochs: &[u32],
    n_hot: u32,
) -> Result<RapidSetup> {
    let cfg = &ctx.cfg;
    let fanouts = ctx.fanouts();
    let mut setup_time = 0.0;

    // Offline enumeration, streamed epoch by epoch (bounded CPU memory).
    let mut hot: Vec<NodeId> = Vec::new();
    let mut rank_time = 0.0;
    let mut later_stream_time = 0.0;
    for (k, &epoch) in epochs.iter().enumerate() {
        let sched = enumerate_epoch(
            &ctx.ds.graph,
            &ctx.part,
            &ctx.shards[worker as usize],
            &fanouts,
            cfg.batch_size,
            cfg.base_seed,
            worker,
            epoch,
        );
        for b in &sched.batches {
            setup_time += ctx.costs.sample_time(b.input_nodes.len());
            let write = b.byte_size() as f64 / ctx.costs.ssd_bytes_per_sec;
            setup_time += write;
            if k > 0 {
                later_stream_time += write;
            }
        }
        write_epoch(&ctx.metadata_path, &sched)?;
        if k == 0 {
            rank_time = sched.total_remote() as f64 * ctx.costs.rank_per_access_sec;
            hot = top_hot(&sched.batches, n_hot);
        }
    }
    // The first epoch's ranking runs in the background of the remaining
    // epochs' writes; only the overrun is serial setup time.
    setup_time += (rank_time - later_stream_time).max(0.0);

    // Initial cache: pull the top-n_hot features in one VectorPull.
    let mut setup_comm = CommStats::default();
    let mut rows: Vec<f32> = Vec::new();
    let materialize = cfg.exec_mode == ExecMode::Full;
    let pull = ctx.kv.pull(
        PullRequest::vector(worker, &hot),
        if materialize { Some(&mut rows) } else { None },
        &mut setup_comm,
    );
    setup_time += pull.time;
    let mut cache = DoubleBufferCache::default();
    cache.install_steady(CacheBuffer::new(&hot, rows, ctx.kv.feature_dim()));

    Ok(RapidSetup {
        setup_time,
        setup_comm,
        cache: Arc::new(Mutex::new(cache)),
    })
}

/// Stream one epoch's blocks from SSD, charging the read + ranking time
/// shared by every consumer of the on-disk schedule.
fn stream_epoch_batches(
    ctx: &RunContext,
    worker: WorkerId,
    epoch: u32,
) -> Result<(Vec<BatchMeta>, f64)> {
    let mut reader = EpochReader::open(&ctx.metadata_path, worker, epoch)?;
    let mut batches: Vec<BatchMeta> = Vec::with_capacity(reader.num_batches as usize);
    let mut time = 0.0;
    let mut accesses = 0u64;
    while let Some(b) = reader.next_batch()? {
        time += ctx.costs.stream_time(b.byte_size());
        accesses += b.num_remote as u64;
        batches.push(b);
    }
    time += accesses as f64 * ctx.costs.rank_per_access_sec;
    Ok((batches, time))
}

/// Stream one epoch's blocks from SSD and rank its remote accesses (the
/// background `C_sec` build). Returns the top-`n_hot` node list and the
/// simulated background time (stream read + frequency tally).
pub(crate) fn stream_top_hot(
    ctx: &RunContext,
    worker: WorkerId,
    epoch: u32,
) -> Result<(Vec<NodeId>, f64)> {
    let (batches, time) = stream_epoch_batches(ctx, worker, epoch)?;
    Ok((top_hot(&batches, ctx.cfg.n_hot), time))
}

/// Stream one epoch's blocks and return the sorted top-`k` of its
/// remote-frequency ranking (with counts) plus the total access count — the
/// adaptive controller's inputs. Partial selection keeps this O(R) like
/// [`top_hot`] rather than the full ranking's O(R log R) sort; the sorted
/// prefix equals `remote_frequency(..)[..k]` for any cut (pinned by the
/// cache module's partial-selection tests). Same simulated time as
/// [`stream_top_hot`] — identical read and tally charges, only the cut
/// differs.
pub(crate) fn stream_ranked_top(
    ctx: &RunContext,
    worker: WorkerId,
    epoch: u32,
    k: u32,
) -> Result<(Vec<(NodeId, u32)>, u64, f64)> {
    let (batches, time) = stream_epoch_batches(ctx, worker, epoch)?;
    let mut ranked = tally_remote_threads(available_threads(), &batches);
    let total: u64 = ranked.iter().map(|&(_, c)| c as u64).sum();
    let n = k as usize;
    if n == 0 {
        ranked.clear();
    } else if n < ranked.len() {
        ranked.select_nth_unstable_by(n - 1, rank_order);
        ranked.truncate(n);
    }
    ranked.sort_unstable_by(rank_order);
    Ok((ranked, total, time))
}

/// The scheduled batch plan: stream precomputed metadata from SSD and stage
/// each batch cache-first. Shared by `rapid` and `fast-sample` (the latter
/// opens a period-start epoch's file).
pub(crate) struct ScheduledPlan<'a> {
    pub(crate) ctx: &'a RunContext,
    pub(crate) worker: WorkerId,
    pub(crate) reader: EpochReader,
    pub(crate) cache: Arc<Mutex<DoubleBufferCache>>,
    /// Local-work slowdown (heterogeneous speeds); 1.0 normally.
    pub(crate) slow: f64,
    pub(crate) full: bool,
    /// Training epoch this plan stages (transient-phase resolution).
    pub(crate) epoch: u32,
}

impl BatchPlan for ScheduledPlan<'_> {
    fn next(
        &mut self,
        comm: &mut CommStats,
        _phases: &mut crate::metrics::PhaseTimes,
    ) -> Result<Option<StagedStep>> {
        let Some(meta) = self.reader.next_batch()? else {
            return Ok(None);
        };
        let stream = self.ctx.costs.stream_time(meta.byte_size());
        let staged = stage_batch_at(
            &self.ctx.kv,
            &self.cache,
            meta,
            self.worker,
            self.full,
            comm,
            self.epoch,
        );
        // Network part at the fabric's per-link price; local part (SSD
        // stream + cache lookups) scaled by the worker's slowdown.
        let cost =
            staged.pull_time + self.slow * (staged.stage_time - staged.pull_time + stream);
        Ok(Some(StagedStep { staged, cost }))
    }
}

/// The paper's engine.
pub struct RapidStrategy;

/// Registry constructor.
pub fn ctor(_cfg: &RunConfig) -> Box<dyn TrainingStrategy> {
    Box::new(RapidStrategy)
}

/// A prepared `C_sec` rebuild: the hot-id list to pull plus the local
/// background time (stream read + ranking, already slowdown-scaled) it cost
/// to produce.
pub(crate) struct CacheRebuild {
    pub(crate) hot: Vec<NodeId>,
    pub(crate) local_time: f64,
}

/// Shared epoch-boundary bookkeeping for schedule-driven cached engines:
/// optionally build `C_sec` from `rebuild_from` (an on-disk epoch), account
/// the overrun, and swap at the boundary.
#[allow(clippy::too_many_arguments)]
pub(crate) fn finish_cached_epoch(
    ctx: &RunContext,
    state: &mut StrategyState,
    worker: WorkerId,
    epoch: u32,
    rebuild_from: Option<u32>,
    outcome: &PipelineOutcome,
    totals: &EpochTotals,
    phases: &mut crate::metrics::PhaseTimes,
    comm: &mut CommStats,
) -> Result<EpochFinish> {
    let st = state.downcast_mut::<RapidState>().expect("rapid-family worker state");
    let rebuild = match rebuild_from {
        Some(source_epoch) => {
            let (hot, rank_time) = stream_top_hot(ctx, worker, source_epoch)?;
            // Local work (stream read + ranking) carries the worker
            // slowdown; the VectorPull is priced per-link by the fabric.
            // Both run during `epoch`, so that epoch's transient phase
            // applies.
            Some(CacheRebuild { hot, local_time: ctx.slowdown_at(worker, epoch) * rank_time })
        }
        None => None,
    };
    let n_hot = ctx.cfg.n_hot;
    finish_cached_epoch_with(
        ctx, st, worker, epoch, rebuild, n_hot, n_hot, outcome, totals, phases, comm,
    )
}

/// [`finish_cached_epoch`] with a pre-built rebuild and explicit cache
/// capacities for the memory report — the adaptive engine decides all three
/// (its controller may have resized `n_hot` away from the static config).
/// `steady_n_hot` is the capacity that served this epoch, `staged_n_hot` the
/// capacity of the `C_sec` being built (they differ on a resize epoch, and
/// the device bound covers both buffers). With both equal to `cfg.n_hot`
/// and a rebuild from [`stream_top_hot`] this is operation-for-operation
/// the static path (the degeneration pin relies on it).
#[allow(clippy::too_many_arguments)]
pub(crate) fn finish_cached_epoch_with(
    ctx: &RunContext,
    st: &mut RapidState,
    worker: WorkerId,
    epoch: u32,
    rebuild: Option<CacheRebuild>,
    steady_n_hot: u32,
    staged_n_hot: u32,
    outcome: &PipelineOutcome,
    totals: &EpochTotals,
    phases: &mut crate::metrics::PhaseTimes,
    comm: &mut CommStats,
) -> Result<EpochFinish> {
    let cfg = &ctx.cfg;
    let full = cfg.exec_mode == ExecMode::Full;

    // Background C_sec build for the next epoch (accounted as parallel work;
    // only its *overrun* past the epoch stalls the swap).
    let mut bg_time = 0.0;
    if let Some(rb) = rebuild {
        bg_time += rb.local_time;
        let mut rows: Vec<f32> = Vec::new();
        let pull = ctx.kv.pull(
            PullRequest::vector(worker, &rb.hot).at(epoch),
            if full { Some(&mut rows) } else { None },
            comm,
        );
        bg_time += pull.time;
        st.cache
            .lock()
            .unwrap()
            .stage_secondary(CacheBuffer::new(&rb.hot, rows, ctx.kv.feature_dim()));
    }

    let overrun = (bg_time - outcome.total).max(0.0);
    phases.fetch = outcome.total_wait; // residual stalls visible to trainer
    phases.idle = overrun;
    let epoch_time = outcome.total + overrun;

    let (cache_stats, device_cache_bytes) = {
        let mut c = st.cache.lock().unwrap();
        let s = c.stats();
        let bytes = c.device_bytes();
        c.swap_at_epoch_boundary();
        (s, bytes)
    };

    let d = cfg.dataset.feature_dim;
    Ok(EpochFinish {
        epoch_time,
        cache: cache_stats,
        cache_plan: None,
        // Paper bound: 2·n_hot·d + Q·m_max·d (both cache buffers + the
        // staged queue; on an adaptive resize epoch the buffers differ, so
        // the bound sums their capacities). Trace mode reports the
        // bound-equivalent since rows aren't materialized.
        device_bytes: device_cache_bytes
            .max((steady_n_hot as u64 + staged_n_hot as u64) * d as u64 * 4)
            + cfg.prefetch_q as u64 * totals.m_max * d as u64 * 4,
        // Streaming keeps host memory at one batch + the ranking tally.
        host_bytes: totals.m_max * 8 + steady_n_hot as u64 * 12,
    })
}

/// Shared plan construction for schedule-driven cached engines: reset cache
/// stats, merge the setup pull into epoch 0, stream `sched_epoch` from SSD.
pub(crate) fn plan_cached_epoch<'a>(
    ctx: &'a RunContext,
    state: &mut StrategyState,
    worker: WorkerId,
    epoch: u32,
    sched_epoch: u32,
    comm: &mut CommStats,
) -> Result<Box<dyn BatchPlan + 'a>> {
    let st = state.downcast_mut::<RapidState>().expect("rapid-family worker state");
    plan_rapid_epoch(ctx, st, worker, epoch, sched_epoch, comm)
}

/// [`plan_cached_epoch`] on an already-downcast [`RapidState`] (the adaptive
/// engine nests one inside its own state).
pub(crate) fn plan_rapid_epoch<'a>(
    ctx: &'a RunContext,
    st: &mut RapidState,
    worker: WorkerId,
    epoch: u32,
    sched_epoch: u32,
    comm: &mut CommStats,
) -> Result<Box<dyn BatchPlan + 'a>> {
    st.cache.lock().unwrap().reset_stats();
    if epoch == 0 {
        comm.merge(&st.setup_comm); // initial VectorPull bytes
    }
    let reader = EpochReader::open(&ctx.metadata_path, worker, sched_epoch)?;
    Ok(Box::new(ScheduledPlan {
        ctx,
        worker,
        reader,
        cache: st.cache.clone(),
        slow: ctx.slowdown_at(worker, epoch),
        full: ctx.cfg.exec_mode == ExecMode::Full,
        epoch,
    }))
}

impl TrainingStrategy for RapidStrategy {
    fn id(&self) -> &'static str {
        "rapid"
    }

    fn name(&self) -> &'static str {
        "RapidGNN"
    }

    fn queue_depth(&self, cfg: &RunConfig) -> u32 {
        cfg.prefetch_q
    }

    fn setup(&self, ctx: &RunContext, worker: WorkerId) -> Result<StrategySetup> {
        let s = precompute(ctx, worker)?;
        Ok(StrategySetup {
            setup_time: s.setup_time,
            state: Box::new(RapidState { cache: s.cache, setup_comm: s.setup_comm }),
        })
    }

    fn plan_epoch<'a>(
        &self,
        ctx: &'a RunContext,
        state: &mut StrategyState,
        worker: WorkerId,
        epoch: u32,
        comm: &mut CommStats,
    ) -> Result<Box<dyn BatchPlan + 'a>> {
        plan_cached_epoch(ctx, state, worker, epoch, epoch, comm)
    }

    fn finish_epoch(
        &self,
        ctx: &RunContext,
        state: &mut StrategyState,
        worker: WorkerId,
        epoch: u32,
        outcome: &PipelineOutcome,
        totals: &EpochTotals,
        phases: &mut crate::metrics::PhaseTimes,
        comm: &mut CommStats,
    ) -> Result<EpochFinish> {
        let rebuild = if epoch + 1 < ctx.cfg.epochs {
            Some(epoch + 1)
        } else {
            None
        };
        finish_cached_epoch(ctx, state, worker, epoch, rebuild, outcome, totals, phases, comm)
    }

    fn checkpoint_state(
        &self,
        _ctx: &RunContext,
        state: &StrategyState,
        _worker: WorkerId,
    ) -> Result<Value> {
        let st = state.downcast_ref::<RapidState>().expect("rapid-family worker state");
        Ok(checkpoint_rapid_state(st))
    }

    fn restore_setup(
        &self,
        ctx: &RunContext,
        worker: WorkerId,
        next_epoch: u32,
        snapshot: &Value,
    ) -> Result<StrategySetup> {
        let hot = snapshot.req_u32_array("hot")?;
        // The resumed epochs stream their own schedule files, and each
        // finish_epoch streams the next epoch's for the C_sec rebuild — the
        // resumed range covers both.
        let epochs: Vec<u32> = (next_epoch..ctx.cfg.epochs).collect();
        let st = restore_rapid_state(ctx, worker, &epochs, &hot)?;
        // Setup time was paid (and reported) by the interrupted run; the
        // orchestrator carries it over from the checkpoint.
        Ok(StrategySetup { setup_time: 0.0, state: Box::new(st) })
    }

    fn cache_rows(&self, state: &StrategyState, _worker: WorkerId) -> u64 {
        state
            .downcast_ref::<RapidState>()
            .expect("rapid-family worker state")
            .cache_rows()
    }
}

/// Streamed frequency ranking, exposed for the Fig-3 bench and `tune`.
pub fn epoch_remote_frequency(
    ctx: &RunContext,
    worker: WorkerId,
    epoch: u32,
) -> Result<Vec<(NodeId, u32)>> {
    let mut reader = EpochReader::open(&ctx.metadata_path, worker, epoch)?;
    let mut batches = Vec::new();
    while let Some(b) = reader.next_batch()? {
        batches.push(b);
    }
    Ok(remote_frequency(&batches))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetPreset, Engine, RunConfig};
    use crate::coordinator::pipeline::run_worker;
    use crate::metrics::EpochReport;

    fn ctx() -> RunContext {
        let mut c = RunConfig::default();
        c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
        c.engine = Engine::Rapid;
        c.epochs = 3;
        c.n_hot = 300;
        RunContext::build(&c).unwrap()
    }

    #[test]
    fn precompute_writes_all_epochs() {
        let ctx = ctx();
        let setup = precompute(&ctx, 0).unwrap();
        assert!(setup.setup_time > 0.0);
        assert!(setup.setup_comm.vector_pulls > 0, "initial VectorPull issued");
        for e in 0..3 {
            assert!(EpochReader::open(&ctx.metadata_path, 0, e).is_ok(), "epoch {e} on disk");
        }
        assert!(!setup.cache.lock().unwrap().steady().is_empty());
    }

    #[test]
    fn rapid_runs_and_hits_cache() {
        let ctx = ctx();
        let (setup_time, reports) = run_worker(&ctx, 0, None).unwrap();
        assert!(setup_time > 0.0);
        assert_eq!(reports.len(), 3);
        for r in &reports {
            assert!(r.steps >= 1);
            assert!(r.cache.lookups > 0);
            assert!(r.cache.hit_rate() > 0.2, "hit rate {}", r.cache.hit_rate());
        }
    }

    #[test]
    fn rapid_moves_fewer_remote_rows_than_baseline() {
        // The paper's headline mechanism, on the tiny graph.
        let rctx = ctx();
        let (_, rapid) = run_worker(&rctx, 0, None).unwrap();
        let mut bcfg = rctx.cfg.clone();
        bcfg.engine = Engine::DglMetis;
        let bctx = RunContext::build(&bcfg).unwrap();
        let (_, base) = run_worker(&bctx, 0, None).unwrap();
        let rows = |rs: &[EpochReport]| -> u64 { rs.iter().map(|r| r.comm.remote_rows).sum() };
        assert!(
            rows(&rapid) < rows(&base),
            "rapid {} !< baseline {}",
            rows(&rapid),
            rows(&base)
        );
    }

    #[test]
    fn rapid_is_faster_per_epoch_than_baseline() {
        let rctx = ctx();
        let (_, rapid) = run_worker(&rctx, 0, None).unwrap();
        let mut bcfg = rctx.cfg.clone();
        bcfg.engine = Engine::DglMetis;
        let bctx = RunContext::build(&bcfg).unwrap();
        let (_, base) = run_worker(&bctx, 0, None).unwrap();
        let t = |rs: &[EpochReport]| -> f64 { rs.iter().map(|r| r.epoch_time).sum() };
        assert!(t(&rapid) < t(&base), "rapid {} !< baseline {}", t(&rapid), t(&base));
    }

    #[test]
    fn deterministic_reports() {
        let c1 = ctx();
        let (s1, a) = run_worker(&c1, 0, None).unwrap();
        let c2 = ctx();
        let (s2, b) = run_worker(&c2, 0, None).unwrap();
        assert_eq!(s1, s2);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.comm.remote_rows, y.comm.remote_rows);
            assert_eq!(x.cache.hits, y.cache.hits);
            assert!((x.epoch_time - y.epoch_time).abs() < 1e-12);
        }
    }

    #[test]
    fn memory_respects_paper_bound() {
        let ctx = ctx();
        let (_, reports) = run_worker(&ctx, 0, None).unwrap();
        for r in &reports {
            // bound with index overhead allowance (+16B/entry)
            let m_max = 2_000u64; // tiny graph: generous m_max envelope
            let bound = crate::cache::device_memory_bound(
                ctx.cfg.n_hot,
                ctx.cfg.prefetch_q,
                m_max as u32,
                ctx.cfg.dataset.feature_dim,
            );
            let slack = 2 * ctx.cfg.n_hot as u64 * 16;
            assert!(
                r.device_bytes <= bound + slack,
                "device {} > bound {}",
                r.device_bytes,
                bound + slack
            );
        }
    }

    #[test]
    fn checkpoint_restore_rebuilds_the_exact_steady_cache() {
        let mut c = RunConfig::default();
        c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
        c.engine = Engine::Rapid;
        c.epochs = 3;
        c.n_hot = 300;
        c.exec_mode = ExecMode::Full;
        let ctx = RunContext::build(&c).unwrap();
        let strat = RapidStrategy;
        let setup = strat.setup(&ctx, 0).unwrap();
        let snap = strat.checkpoint_state(&ctx, &setup.state, 0).unwrap();
        // Round-trip through JSON like the on-disk checkpoint does.
        let snap = Value::from_json(&snap.to_json()).unwrap();

        // Fresh context: new tmp metadata dir, fresh kv shards.
        let ctx2 = RunContext::build(&c).unwrap();
        let restored = strat.restore_setup(&ctx2, 0, 1, &snap).unwrap();
        assert_eq!(restored.setup_time, 0.0, "restore charges no setup time");

        let orig = setup.state.downcast_ref::<RapidState>().unwrap();
        let re = restored.state.downcast_ref::<RapidState>().unwrap();
        let orig_ids = orig.cache.lock().unwrap().steady().ids_by_row();
        assert!(!orig_ids.is_empty());
        assert_eq!(re.cache.lock().unwrap().steady().ids_by_row(), orig_ids);
        for &v in orig_ids.iter().take(16) {
            assert_eq!(
                orig.cache.lock().unwrap().steady().row(v).map(<[f32]>::to_vec),
                re.cache.lock().unwrap().steady().row(v).map(<[f32]>::to_vec),
                "row {v}"
            );
        }
        assert_eq!(strat.cache_rows(&restored.state, 0), orig.cache_rows());
        assert_eq!(re.setup_comm, CommStats::default(), "no setup traffic on restore");
        // Re-enumerated metadata serves every resumed epoch (and the C_sec
        // rebuild reads).
        for e in 1..3 {
            assert!(EpochReader::open(&ctx2.metadata_path, 0, e).is_ok(), "epoch {e}");
        }
    }

    #[test]
    fn later_epochs_swap_cache() {
        let ctx = ctx();
        let setup = precompute(&ctx, 0).unwrap();
        let cache = setup.cache;
        // stage + swap manually to verify the boundary logic end to end
        let (hot, _) = stream_top_hot(&ctx, 0, 1).unwrap();
        cache
            .lock()
            .unwrap()
            .stage_secondary(CacheBuffer::new(&hot, Vec::new(), ctx.kv.feature_dim()));
        assert!(cache.lock().unwrap().swap_at_epoch_boundary());
        assert_eq!(cache.lock().unwrap().swaps(), 1);
    }
}
