//! On-demand baselines as [`TrainingStrategy`] impls: DGL-METIS, DGL-Random,
//! and Dist-GCN (paper §2.3).
//!
//! These engines reproduce DistDGL's data path: each batch is sampled online
//! on the critical path, then *all* of its remote input-node features are
//! fetched synchronously from the KV store before the training step runs.
//! There is no cache and no prefetch overlap (`Q = 0` in the pipeline model)
//! — exactly the reactive behaviour RapidGNN's scheduled data path replaces.
//! Dist-GCN differs only in its fan-out policy (capped full neighborhoods →
//! much larger input sets, the paper's worst communicator); DGL-Random only
//! in its partitioner.
//!
//! Wall-clock note: `enumerate_epoch` runs on the multi-threaded sampler
//! with per-thread scratch arenas (like DGL's parallel dataloader workers),
//! which only accelerates *our* harness — the simulated per-batch
//! `sample_time` charged on the critical path models the baseline's online
//! sampling cost, not ours.

use crate::config::{ExecMode, RunConfig};
use crate::coordinator::common::RunContext;
use crate::coordinator::strategy::{
    BatchPlan, EpochFinish, EpochTotals, PipelineOutcome, StagedStep, StrategySetup,
    StrategyState, TrainingStrategy,
};
use crate::kvstore::PullRequest;
use crate::metrics::{CacheStats, CommStats, PhaseTimes};
use crate::partition::Partitioner;
use crate::prefetch::StagedBatch;
use crate::sampler::khop::Fanout;
use crate::sampler::{enumerate_epoch, BatchMeta};
use crate::{Result, WorkerId};

/// Per-worker state: the current epoch's host-memory footprint (the DGL
/// dataloader materializes indices per epoch).
pub(crate) struct OnDemandState {
    pub(crate) host_bytes: u64,
}

/// The on-demand batch plan: online per-batch sampling charge, then one
/// synchronous pull of the whole input set on the critical path.
pub(crate) struct OnDemandPlan<'a> {
    pub(crate) ctx: &'a RunContext,
    pub(crate) worker: WorkerId,
    pub(crate) batches: std::vec::IntoIter<BatchMeta>,
    pub(crate) slow: f64,
    pub(crate) full: bool,
    /// Training epoch this plan stages (transient-phase resolution).
    pub(crate) epoch: u32,
}

impl BatchPlan for OnDemandPlan<'_> {
    fn next(
        &mut self,
        comm: &mut CommStats,
        phases: &mut PhaseTimes,
    ) -> Result<Option<StagedStep>> {
        let Some(meta) = self.batches.next() else {
            return Ok(None);
        };
        let n_input = meta.input_nodes.len();
        let num_remote = meta.num_remote;
        // Local work (sampling) carries the worker slowdown; the fetch is
        // charged per-link by the fabric, which applies its own per-worker
        // factors to links touching slowed workers.
        let sample = self.slow * self.ctx.costs.sample_time(n_input);
        phases.sample += sample;

        // On-demand fetch of every remote input feature, synchronously on
        // the critical path (local rows gather free of network).
        let mut features: Vec<f32> = Vec::new();
        let materialize = self.full && self.ctx.kv.has_values();
        let pull = self.ctx.kv.pull(
            PullRequest::sync(self.worker, &meta.input_nodes).at(self.epoch),
            if materialize {
                Some(&mut features)
            } else {
                None
            },
            comm,
        );
        phases.fetch += pull.time;

        let staged = StagedBatch {
            meta,
            features: materialize.then_some(features),
            stage_time: sample + pull.time,
            pull_time: pull.time,
            cache_hits: 0,
            misses: num_remote,
        };
        Ok(Some(StagedStep { staged, cost: sample + pull.time }))
    }
}

/// Enumerate the epoch schedule at run time (the DGL dataloader pattern)
/// and record its host footprint in the worker state. Shared by every
/// on-demand engine, including `green-window`.
pub(crate) fn enumerate_on_demand(
    ctx: &RunContext,
    state: &mut StrategyState,
    worker: WorkerId,
    epoch: u32,
) -> Vec<BatchMeta> {
    let cfg = &ctx.cfg;
    let sched = enumerate_epoch(
        &ctx.ds.graph,
        &ctx.part,
        &ctx.shards[worker as usize],
        &ctx.fanouts(),
        cfg.batch_size,
        cfg.base_seed,
        worker,
        epoch,
    );
    let st = state.downcast_mut::<OnDemandState>().expect("on-demand worker state");
    st.host_bytes = sched.batches.iter().map(|b| b.byte_size()).sum();
    sched.batches
}

/// Shared `plan_epoch` for the per-batch on-demand engines.
pub(crate) fn plan_on_demand_epoch<'a>(
    ctx: &'a RunContext,
    state: &mut StrategyState,
    worker: WorkerId,
    epoch: u32,
) -> Result<Box<dyn BatchPlan + 'a>> {
    let batches = enumerate_on_demand(ctx, state, worker, epoch);
    Ok(Box::new(OnDemandPlan {
        ctx,
        worker,
        batches: batches.into_iter(),
        slow: ctx.slowdown_at(worker, epoch),
        full: ctx.cfg.exec_mode == ExecMode::Full,
        epoch,
    }))
}

/// Shared `finish_epoch` for on-demand engines: no cache, no background
/// work. The serial path reports the per-phase sum (bit-identical to the
/// historical accounting); the event path reports the makespan — the two
/// agree within float-accumulation noise (pinned by the conformance tests).
pub(crate) fn finish_on_demand_epoch(
    ctx: &RunContext,
    state: &mut StrategyState,
    outcome: &PipelineOutcome,
    totals: &EpochTotals,
    phases: &mut PhaseTimes,
) -> Result<EpochFinish> {
    let st = state.downcast_mut::<OnDemandState>().expect("on-demand worker state");
    let epoch_time = if outcome.event_driven {
        outcome.total
    } else {
        phases.total()
    };
    Ok(EpochFinish {
        epoch_time,
        cache: CacheStats::default(),
        cache_plan: None,
        // One batch in flight on device + model activations.
        device_bytes: totals.m_max * ctx.cfg.dataset.feature_dim as u64 * 4,
        host_bytes: st.host_bytes,
    })
}

/// Empty setup shared by the on-demand engines.
pub(crate) fn on_demand_setup() -> StrategySetup {
    StrategySetup { setup_time: 0.0, state: Box::new(OnDemandState { host_bytes: 0 }) }
}

/// DistDGL-style GraphSAGE baseline; `random_partition` distinguishes
/// `dgl-random` from `dgl-metis`.
pub struct DglStrategy {
    pub random_partition: bool,
}

/// Registry constructor for `dgl-metis`.
pub fn dgl_metis_ctor(_cfg: &RunConfig) -> Box<dyn TrainingStrategy> {
    Box::new(DglStrategy { random_partition: false })
}

/// Registry constructor for `dgl-random`.
pub fn dgl_random_ctor(_cfg: &RunConfig) -> Box<dyn TrainingStrategy> {
    Box::new(DglStrategy { random_partition: true })
}

impl TrainingStrategy for DglStrategy {
    fn id(&self) -> &'static str {
        if self.random_partition {
            "dgl-random"
        } else {
            "dgl-metis"
        }
    }

    fn name(&self) -> &'static str {
        if self.random_partition {
            "DGL-Random"
        } else {
            "DGL-METIS"
        }
    }

    fn partitioner(&self) -> Partitioner {
        if self.random_partition {
            Partitioner::Random
        } else {
            Partitioner::MetisLike
        }
    }

    fn queue_depth(&self, _cfg: &RunConfig) -> u32 {
        0
    }

    fn setup(&self, _ctx: &RunContext, _worker: WorkerId) -> Result<StrategySetup> {
        Ok(on_demand_setup())
    }

    fn plan_epoch<'a>(
        &self,
        ctx: &'a RunContext,
        state: &mut StrategyState,
        worker: WorkerId,
        epoch: u32,
        _comm: &mut CommStats,
    ) -> Result<Box<dyn BatchPlan + 'a>> {
        plan_on_demand_epoch(ctx, state, worker, epoch)
    }

    fn finish_epoch(
        &self,
        ctx: &RunContext,
        state: &mut StrategyState,
        _worker: WorkerId,
        _epoch: u32,
        outcome: &PipelineOutcome,
        totals: &EpochTotals,
        phases: &mut PhaseTimes,
        _comm: &mut CommStats,
    ) -> Result<EpochFinish> {
        finish_on_demand_epoch(ctx, state, outcome, totals, phases)
    }
}

/// Dist-GCN baseline: capped full-neighborhood expansion, on-demand fetch.
pub struct DistGcnStrategy;

/// Registry constructor for `dist-gcn`.
pub fn dist_gcn_ctor(_cfg: &RunConfig) -> Box<dyn TrainingStrategy> {
    Box::new(DistGcnStrategy)
}

impl TrainingStrategy for DistGcnStrategy {
    fn id(&self) -> &'static str {
        "dist-gcn"
    }

    fn name(&self) -> &'static str {
        "Dist-GCN"
    }

    fn fanouts(&self, cfg: &RunConfig) -> Vec<Fanout> {
        cfg.fanout.iter().map(|_| Fanout::FullCapped(cfg.gcn_neighbor_cap)).collect()
    }

    fn queue_depth(&self, _cfg: &RunConfig) -> u32 {
        0
    }

    fn setup(&self, _ctx: &RunContext, _worker: WorkerId) -> Result<StrategySetup> {
        Ok(on_demand_setup())
    }

    fn plan_epoch<'a>(
        &self,
        ctx: &'a RunContext,
        state: &mut StrategyState,
        worker: WorkerId,
        epoch: u32,
        _comm: &mut CommStats,
    ) -> Result<Box<dyn BatchPlan + 'a>> {
        plan_on_demand_epoch(ctx, state, worker, epoch)
    }

    fn finish_epoch(
        &self,
        ctx: &RunContext,
        state: &mut StrategyState,
        _worker: WorkerId,
        _epoch: u32,
        outcome: &PipelineOutcome,
        totals: &EpochTotals,
        phases: &mut PhaseTimes,
        _comm: &mut CommStats,
    ) -> Result<EpochFinish> {
        finish_on_demand_epoch(ctx, state, outcome, totals, phases)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetPreset, Engine, RunConfig};
    use crate::coordinator::pipeline::run_worker;
    use crate::metrics::EpochReport;

    fn ctx(engine: Engine) -> RunContext {
        let mut c = RunConfig::default();
        c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
        c.engine = engine;
        c.epochs = 2;
        RunContext::build(&c).unwrap()
    }

    #[test]
    fn baseline_reports_all_epochs_and_steps() {
        let ctx = ctx(Engine::DglMetis);
        let (setup, reports) = run_worker(&ctx, 0, None).unwrap();
        assert_eq!(setup, 0.0, "on-demand engines have no setup pass");
        assert_eq!(reports.len(), 2);
        for r in &reports {
            assert!(r.steps >= 1);
            assert!(r.epoch_time > 0.0);
            assert!(r.phases.fetch > 0.0, "on-demand fetch must cost time");
            assert_eq!(r.cache.lookups, 0, "baselines have no cache");
            assert!(r.mean_loss.is_nan(), "trace mode has no loss");
        }
    }

    #[test]
    fn epoch_time_is_sum_of_phases() {
        let ctx = ctx(Engine::DglMetis);
        let (_, reports) = run_worker(&ctx, 0, None).unwrap();
        let r = &reports[0];
        assert!((r.epoch_time - r.phases.total()).abs() < 1e-12);
        assert_eq!(r.phases.idle, 0.0, "serial baseline never idles");
    }

    #[test]
    fn gcn_fetches_more_than_sage() {
        let (_, sage) = run_worker(&ctx(Engine::DglMetis), 0, None).unwrap();
        let (_, gcn) = run_worker(&ctx(Engine::DistGcn), 0, None).unwrap();
        let rows = |rs: &[EpochReport]| -> u64 { rs.iter().map(|r| r.comm.remote_rows).sum() };
        assert!(
            rows(&gcn) > rows(&sage),
            "full-neighborhood GCN must move more rows: {} vs {}",
            rows(&gcn),
            rows(&sage)
        );
    }

    #[test]
    fn random_partition_fetches_more_than_metis() {
        let (_, metis) = run_worker(&ctx(Engine::DglMetis), 0, None).unwrap();
        let (_, random) = run_worker(&ctx(Engine::DglRandom), 0, None).unwrap();
        let rows = |rs: &[EpochReport]| -> u64 { rs.iter().map(|r| r.comm.remote_rows).sum() };
        assert!(rows(&random) > rows(&metis), "{} !> {}", rows(&random), rows(&metis));
    }

    #[test]
    fn deterministic_across_runs() {
        let c = ctx(Engine::DglMetis);
        let (_, a) = run_worker(&c, 0, None).unwrap();
        let c2 = ctx(Engine::DglMetis);
        let (_, b) = run_worker(&c2, 0, None).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.comm.remote_rows, y.comm.remote_rows);
            assert!((x.epoch_time - y.epoch_time).abs() < 1e-12);
        }
    }
}
