//! The Layer-3 coordinator: engine dispatch, worker orchestration, and run
//! reporting — the paper's system contribution wired together.
//!
//! [`run`] executes a full distributed-training simulation for any
//! [`Engine`]: it builds the dataset/partition/KV substrate, runs every
//! worker (parallel threads in trace mode; the event-driven cluster runtime
//! in full mode, where all workers' pipelines advance concurrently on one
//! shared virtual clock and train-step order on the shared model is resolved
//! deterministically in virtual time — [`crate::sim::cluster`]), and
//! aggregates per-epoch reports plus energy into a [`RunReport`].

mod baseline;
mod common;
mod rapid;

pub use common::{CostParams, RunContext};
pub use rapid::{epoch_remote_frequency, precompute, run_cluster, RapidSetup};

use crate::config::{Engine, ExecMode, RunConfig, TrainerBackend};
use crate::energy::run_energy;
use crate::metrics::{EpochReport, RunReport};
use crate::trainer::{SageModel, TrainStep};
use crate::Result;
use std::sync::{Arc, Mutex};

/// The full-mode model, shared across all worker actors on the virtual
/// clock. The cluster event loop is single-threaded, so the mutex is
/// uncontended — it exists to hand `&mut` access to whichever worker's
/// train step fires next.
pub type SharedTrainer = Arc<Mutex<Box<dyn TrainStep>>>;

/// Execute a full run for `cfg` and aggregate the report.
pub fn run(cfg: &RunConfig) -> Result<RunReport> {
    let ctx = RunContext::build(cfg)?;
    run_with_context(&ctx)
}

/// Execute with a pre-built context (benches reuse datasets across configs).
pub fn run_with_context(ctx: &RunContext) -> Result<RunReport> {
    let cfg = &ctx.cfg;
    let mut setup_time = 0.0f64;
    let mut epochs: Vec<EpochReport> = Vec::new();

    match cfg.exec_mode {
        ExecMode::Trace => {
            // Workers are independent in trace mode — run them in parallel.
            let results: Vec<Result<(f64, Vec<EpochReport>)>> = std::thread::scope(|s| {
                let handles: Vec<_> = (0..cfg.num_workers)
                    .map(|w| s.spawn(move || run_one_worker(ctx, w, None)))
                    .collect();
                handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
            });
            for r in results {
                let (st, reps) = r?;
                setup_time = setup_time.max(st);
                epochs.extend(reps);
            }
        }
        ExecMode::Full => {
            // Shared model across workers, stepped by the event-driven
            // cluster runtime: every worker's sampler→prefetcher→trainer
            // pipeline advances concurrently on one virtual clock, and SGD
            // steps interleave across workers in deterministic virtual-time
            // order (replaces the old strictly-sequential worker loop).
            let model: SharedTrainer = Arc::new(Mutex::new(build_trainer(ctx)?));
            let (st, reps) = match cfg.engine {
                Engine::Rapid => rapid::run_cluster(ctx, Some(model))?,
                Engine::DglMetis | Engine::DglRandom | Engine::DistGcn => {
                    (0.0, baseline::run_cluster(ctx, Some(model)))
                }
            };
            setup_time = st;
            epochs = reps;
        }
    }

    // End-to-end time: workers run concurrently, so the run takes the max
    // over workers of their summed epoch time.
    let mut per_worker_total = vec![0.0f64; cfg.num_workers as usize];
    for e in &epochs {
        per_worker_total[e.worker as usize] += e.epoch_time;
    }
    let total_time = per_worker_total.iter().cloned().fold(0.0, f64::max);

    let mut report = RunReport {
        engine: cfg.engine.name().to_string(),
        dataset: cfg.dataset.name.clone(),
        num_workers: cfg.num_workers,
        batch_size: cfg.batch_size,
        epochs,
        total_time,
        setup_time,
        cpu_energy_j: 0.0,
        gpu_energy_j: 0.0,
    };
    let energy = run_energy(&report, &cfg.power);
    report.cpu_energy_j = energy.cpu.total_j;
    report.gpu_energy_j = energy.gpu.total_j;
    Ok(report)
}

fn run_one_worker(
    ctx: &RunContext,
    worker: u32,
    trainer: Option<&mut (dyn TrainStep + 'static)>,
) -> Result<(f64, Vec<EpochReport>)> {
    match ctx.cfg.engine {
        Engine::Rapid => rapid::run_worker(ctx, worker, trainer),
        Engine::DglMetis | Engine::DglRandom | Engine::DistGcn => {
            Ok((0.0, baseline::run_worker(ctx, worker, trainer)))
        }
    }
}

/// Instantiate the configured train-step backend.
pub fn build_trainer(ctx: &RunContext) -> Result<Box<dyn TrainStep>> {
    let cfg = &ctx.cfg;
    match cfg.backend {
        TrainerBackend::Host => Ok(Box::new(SageModel::new(
            cfg.dataset.feature_dim as usize,
            cfg.hidden_dim as usize,
            cfg.dataset.num_classes as usize,
            cfg.num_layers(),
            cfg.base_seed,
        ))),
        TrainerBackend::Pjrt => crate::runtime::build_pjrt_trainer(ctx),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetPreset};

    fn cfg(engine: Engine) -> RunConfig {
        let mut c = RunConfig::default();
        c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
        c.engine = engine;
        c.epochs = 2;
        c.n_hot = 300;
        c
    }

    #[test]
    fn trace_run_all_engines() {
        for engine in Engine::ALL {
            let report = run(&cfg(engine)).unwrap();
            assert_eq!(report.engine, engine.name());
            assert_eq!(report.epochs.len(), 2 * 2, "2 workers × 2 epochs");
            assert!(report.total_time > 0.0);
            assert!(report.cpu_energy_j > 0.0);
            assert!(report.gpu_energy_j > 0.0);
        }
    }

    #[test]
    fn rapid_beats_baselines_end_to_end() {
        let rapid = run(&cfg(Engine::Rapid)).unwrap();
        for baseline in [Engine::DglMetis, Engine::DglRandom, Engine::DistGcn] {
            let base = run(&cfg(baseline)).unwrap();
            assert!(
                rapid.mean_step_time() < base.mean_step_time(),
                "{}: rapid {} !< {}",
                baseline.name(),
                rapid.mean_step_time(),
                base.mean_step_time()
            );
            assert!(rapid.total_remote_rows() < base.total_remote_rows());
        }
    }

    #[test]
    fn rapid_uses_less_energy() {
        let rapid = run(&cfg(Engine::Rapid)).unwrap();
        let base = run(&cfg(Engine::DglMetis)).unwrap();
        assert!(rapid.cpu_energy_j < base.cpu_energy_j);
        assert!(rapid.gpu_energy_j < base.gpu_energy_j);
    }

    #[test]
    fn full_mode_trains_host_model() {
        let mut c = cfg(Engine::Rapid);
        c.exec_mode = ExecMode::Full;
        c.batch_size = 64;
        c.epochs = 3;
        let report = run(&c).unwrap();
        let curve = report.accuracy_curve();
        assert_eq!(curve.len(), 3);
        // accuracy improves from epoch 0 to the last epoch
        assert!(
            curve.last().unwrap().1 > curve[0].1,
            "accuracy {:?}",
            curve
        );
        assert!(report.loss_curve().last().unwrap().1 < report.loss_curve()[0].1);
    }

    #[test]
    fn full_mode_baseline_also_trains() {
        let mut c = cfg(Engine::DglMetis);
        c.exec_mode = ExecMode::Full;
        c.batch_size = 64;
        c.epochs = 2;
        let report = run(&c).unwrap();
        assert!(report.loss_curve().iter().all(|&(_, l)| l.is_finite()));
    }

    #[test]
    fn total_time_is_max_worker_not_sum() {
        let report = run(&cfg(Engine::DglMetis)).unwrap();
        let sum: f64 = report.epochs.iter().map(|e| e.epoch_time).sum();
        assert!(report.total_time < sum, "workers run concurrently");
        assert!(report.total_time > 0.0);
    }
}
