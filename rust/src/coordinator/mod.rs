//! The Layer-3 coordinator: the pluggable [`TrainingStrategy`] engine API,
//! the one worker pipeline that drives any strategy, and run reporting —
//! the paper's system contribution wired together as an *open* set of
//! engines.
//!
//! # Architecture
//!
//! ```text
//! config::Engine (thin id) ──► EngineRegistry ──► Box<dyn TrainingStrategy>
//!                                                     │
//!            RunContext (dataset, partition, KV, fabric, strategy)
//!                                                     │
//!       pipeline::run_worker (sequential)   pipeline::run_cluster (event-
//!            trace mode, parallel threads     driven virtual clock, full
//!                                             mode, shared-model SGD)
//! ```
//!
//! A strategy's lifecycle per worker: `setup` (one-time, e.g. RapidGNN's
//! offline precompute) → per epoch `plan_epoch` (the batch source: staging
//! side effects + costs) → the shared pipeline consumes each staged batch
//! (assembly + the real or analytic train step) → `finish_epoch` (cache
//! swaps, background work, the epoch-time policy). See [`strategy`] for the
//! trait contract and how to register a new engine — registration is one
//! [`EngineEntry`] in [`EngineRegistry::builtin`]; nothing else dispatches
//! on the engine.
//!
//! # Entry points
//!
//! [`RunBuilder`] is the composable entry:
//!
//! ```ignore
//! let report = RunBuilder::new(cfg)
//!     .with_strategy(Box::new(MyStrategy))   // optional: bypass the registry
//!     .with_trainer(Box::new(my_backend))    // optional: custom TrainStep
//!     .run()?;
//! ```
//!
//! [`run`] and [`run_with_context`] remain as thin shims over it (every
//! bench and test uses them).
//!
//! # Migration note (pre-registry API)
//!
//! The per-engine `rapid::run_worker` / `rapid::run_cluster` and
//! `baseline::run_worker` / `baseline::run_cluster` exports are gone —
//! engine choice is no longer an enum match, so there is nothing
//! engine-specific left to export. Use [`run_worker`] / [`run_cluster`]
//! (strategy-agnostic; the context carries the strategy) or the [`run`] /
//! [`RunBuilder`] front door. The threaded prefetcher with the paper's
//! trainer-side race fallback lives on in [`crate::prefetch::Prefetcher`]
//! (exercised directly by the integration tests); the simulation paths
//! stage inline, which produces bit-identical staging (pinned by the
//! prefetch tests).

mod common;
mod pipeline;
pub mod recovery;
pub mod strategies;
pub mod strategy;

pub use common::{CostParams, RunContext};
pub use pipeline::{run_cluster, run_worker};
pub use recovery::{resume_run, Checkpoint};
pub use strategies::adaptive_cache::AdaptiveCacheStrategy;
pub use strategies::baseline::{DglStrategy, DistGcnStrategy};
pub use strategies::fast_sample::FastSampleStrategy;
pub use strategies::green_window::GreenWindowStrategy;
pub use strategies::rapid::{epoch_remote_frequency, precompute, RapidSetup, RapidStrategy};
pub use strategy::{
    BatchPlan, EngineEntry, EngineRegistry, EpochFinish, EpochTotals, PipelineOutcome,
    StagedStep, StrategyCtor, StrategySetup, StrategyState, TrainingStrategy,
};

use crate::config::{ExecMode, RunConfig, TrainerBackend};
use crate::energy::run_energy;
use crate::metrics::{
    CalibrationEpoch, CalibrationLink, CalibrationReport, CompressionReport, EpochReport,
    RecoveryReport, RunReport,
};
use crate::net::Transport;
use crate::trainer::{GradCompressedSage, GradStats, SageModel, TrainStep};
use crate::Result;
use anyhow::bail;
use std::sync::{Arc, Mutex};

/// The full-mode model, shared across all worker actors on the virtual
/// clock. The cluster event loop is single-threaded, so the mutex is
/// uncontended — it exists to hand `&mut` access to whichever worker's
/// train step fires next.
pub type SharedTrainer = Arc<Mutex<Box<dyn TrainStep>>>;

/// Builder-style run entry: configure, optionally override the strategy or
/// the trainer backend, and execute.
pub struct RunBuilder {
    cfg: RunConfig,
    strategy: Option<Box<dyn TrainingStrategy>>,
    trainer: Option<Box<dyn TrainStep>>,
    trace: Option<crate::trace::TraceHandle>,
}

impl RunBuilder {
    /// Start from a run config (the strategy resolves from the registry via
    /// `cfg.engine` unless overridden).
    pub fn new(cfg: RunConfig) -> RunBuilder {
        RunBuilder { cfg, strategy: None, trainer: None, trace: None }
    }

    /// Drive the run with an explicit strategy instead of the registry's
    /// answer for `cfg.engine` (unregistered/experimental engines).
    pub fn with_strategy(mut self, strategy: Box<dyn TrainingStrategy>) -> RunBuilder {
        self.strategy = Some(strategy);
        self
    }

    /// Use an explicit train-step backend in full mode instead of the one
    /// `cfg.backend` selects. Ignored in trace mode (no model runs).
    pub fn with_trainer(mut self, trainer: Box<dyn TrainStep>) -> RunBuilder {
        self.trainer = Some(trainer);
        self
    }

    /// Install a virtual-time trace sink (`--trace-out`). Strictly
    /// observational: the run's report is byte-identical with or without it.
    pub fn with_trace(mut self, trace: crate::trace::TraceHandle) -> RunBuilder {
        self.trace = Some(trace);
        self
    }

    /// Execute the run and aggregate the report.
    pub fn run(self) -> Result<RunReport> {
        let mut ctx = match self.strategy {
            Some(s) => RunContext::build_with_strategy(&self.cfg, Arc::from(s))?,
            None => RunContext::build(&self.cfg)?,
        };
        ctx.trace = self.trace;
        run_with_overrides(&ctx, self.trainer)
    }
}

/// Execute a full run for `cfg` and aggregate the report.
pub fn run(cfg: &RunConfig) -> Result<RunReport> {
    RunBuilder::new(cfg.clone()).run()
}

/// Execute with a pre-built context (benches reuse datasets across configs).
pub fn run_with_context(ctx: &RunContext) -> Result<RunReport> {
    run_with_overrides(ctx, None)
}

fn run_with_overrides(
    ctx: &RunContext,
    trainer_override: Option<Box<dyn TrainStep>>,
) -> Result<RunReport> {
    let cfg = &ctx.cfg;
    if cfg.has_recovery() {
        // Failure plans and checkpoint writes need epoch boundaries driven
        // one at a time — the recovery driver interleaves them with the
        // cluster runtime and reports the extra work separately.
        let (setup_time, epochs, rec, grad_stats) =
            recovery::run_with_failures(ctx, trainer_override)?;
        return assemble_report(ctx, setup_time, epochs, grad_stats, Some(rec));
    }
    let mut setup_time = 0.0f64;
    let mut epochs: Vec<EpochReport> = Vec::new();
    let mut grad_stats: Option<GradStats> = None;

    match cfg.exec_mode {
        // Wallclock is trace scheduling on the real transport: same code
        // paths, same modeled report; only the KvStore's transport backend
        // (installed by RunContext::build) and the calibration section differ.
        ExecMode::Trace | ExecMode::Wallclock if cfg.fabric.contention => {
            // Shared-link queueing needs every worker's transfers on one
            // virtual clock — contended trace runs go through the same
            // event-driven cluster runtime as full mode (no trainer).
            let (st, reps) = pipeline::run_cluster(ctx, None)?;
            setup_time = st;
            epochs = reps;
        }
        ExecMode::Trace | ExecMode::Wallclock => {
            // Workers are independent in trace mode — run them in parallel.
            let results: Vec<Result<(f64, Vec<EpochReport>)>> = std::thread::scope(|s| {
                let handles: Vec<_> = (0..cfg.num_workers)
                    .map(|w| s.spawn(move || pipeline::run_worker(ctx, w, None)))
                    .collect();
                handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
            });
            for r in results {
                let (st, reps) = r?;
                setup_time = setup_time.max(st);
                epochs.extend(reps);
            }
        }
        ExecMode::Full => {
            // Shared model across workers, stepped by the event-driven
            // cluster runtime: every worker's pipeline advances concurrently
            // on one virtual clock and SGD steps interleave across workers
            // in deterministic virtual-time order.
            let trainer = match trainer_override {
                Some(t) => t,
                None => build_trainer(ctx)?,
            };
            let model: SharedTrainer = Arc::new(Mutex::new(trainer));
            let (st, reps) = pipeline::run_cluster(ctx, Some(model.clone()))?;
            setup_time = st;
            epochs = reps;
            grad_stats = model.lock().unwrap().grad_stats();
        }
    }

    assemble_report(ctx, setup_time, epochs, grad_stats, None)
}

/// Aggregate epoch reports plus the fabric/compression/energy telemetry
/// into the final [`RunReport`]. Shared by the normal path, the failure
/// driver, and checkpoint resume so all three serialize identically.
pub(crate) fn assemble_report(
    ctx: &RunContext,
    setup_time: f64,
    epochs: Vec<EpochReport>,
    grad_stats: Option<GradStats>,
    recovery: Option<RecoveryReport>,
) -> Result<RunReport> {
    let cfg = &ctx.cfg;
    // End-to-end time: workers run concurrently, so the run takes the max
    // over workers of their summed epoch time.
    let mut per_worker_total = vec![0.0f64; cfg.num_workers as usize];
    for e in &epochs {
        per_worker_total[e.worker as usize] += e.epoch_time;
    }
    let total_time = per_worker_total.iter().cloned().fold(0.0, f64::max);

    let mut report = RunReport {
        engine: ctx.strategy.name().to_string(),
        dataset: cfg.dataset.name.clone(),
        num_workers: cfg.num_workers,
        batch_size: cfg.batch_size,
        epochs,
        total_time,
        setup_time,
        cpu_energy_j: 0.0,
        gpu_energy_j: 0.0,
        links: Vec::new(),
        compression: None,
        recovery,
        calibration: None,
    };
    // Contended runs surface per-physical-link telemetry (accumulated over
    // the run's epochs by the link network); empty otherwise, which keeps
    // the serialized report — and the golden trace — byte-identical.
    report.links = ctx
        .fabric
        .link_utilization()
        .into_iter()
        .map(|(key, u)| crate::metrics::LinkReport {
            link: key.label(),
            capacity_bytes_per_sec: u.capacity_bytes_per_sec,
            busy_sec: u.busy_sec,
            served_bytes: u.served_bytes,
            flows: u.flows,
            peak_flows: u.peak_flows,
            peak_backlog_bytes: u.peak_backlog_bytes,
        })
        .collect();
    // Compression telemetry: present only when a wire codec is installed or a
    // gradient sparsifier ran, so uncompressed reports — and the committed
    // golden trace — serialize byte-identically.
    if ctx.kv.codec().is_some() || grad_stats.is_some() {
        let tally = ctx.kv.compression_tally();
        report.compression = Some(CompressionReport {
            codec: ctx.kv.codec().map_or("none", |c| c.id()).to_string(),
            uncompressed_bytes: tally.raw_bytes,
            compressed_bytes: tally.wire_bytes,
            bytes_saved: tally.raw_bytes.saturating_sub(tally.wire_bytes),
            effective_compression_ratio: if tally.wire_bytes > 0 {
                tally.raw_bytes as f64 / tally.wire_bytes as f64
            } else {
                1.0
            },
            quant_mse: if tally.elems > 0 {
                tally.sq_err / tally.elems as f64
            } else {
                0.0
            },
            grad_elems_total: grad_stats.map_or(0, |g| g.elems_total),
            grad_elems_sent: grad_stats.map_or(0, |g| g.elems_sent),
        });
    }
    // Wallclock runs attach the virtual-vs-wall-clock calibration measured
    // by the real transport. Strictly additive: everything above (and the
    // energy model below) is computed from the same modeled quantities a
    // trace run reports.
    if let Some(shm) = &ctx.shm {
        use std::collections::BTreeMap;
        let mut modeled_by_epoch: BTreeMap<u32, f64> = BTreeMap::new();
        for e in &report.epochs {
            *modeled_by_epoch.entry(e.epoch).or_insert(0.0) += e.comm.net_time;
        }
        let measured_by_epoch: BTreeMap<_, _> = shm.measured_epochs().into_iter().collect();
        // Union of both key sets: setup-phase pulls are measured under
        // epoch 0 even when no epoch-0 report row exists, and vice versa.
        let mut epoch_keys: Vec<u32> =
            modeled_by_epoch.keys().chain(measured_by_epoch.keys()).copied().collect();
        epoch_keys.sort_unstable();
        epoch_keys.dedup();
        let cal_epochs: Vec<CalibrationEpoch> = epoch_keys
            .into_iter()
            .map(|epoch| {
                let m = measured_by_epoch.get(&epoch).copied().unwrap_or_default();
                CalibrationEpoch {
                    epoch,
                    modeled_net_sec: modeled_by_epoch.get(&epoch).copied().unwrap_or(0.0),
                    measured_wall_sec: m.wall_sec,
                    measured_bytes: m.payload_bytes,
                    rpcs: m.rpcs,
                }
            })
            .collect();
        let measured_links: BTreeMap<_, _> = shm.measured_links().into_iter().collect();
        let cal_links: Vec<CalibrationLink> = ctx
            .fabric
            .link_stats()
            .into_iter()
            .map(|((src, dst), s)| {
                let m = measured_links.get(&(src, dst)).copied().unwrap_or_default();
                CalibrationLink {
                    link: format!("{src}->{dst}"),
                    modeled_bytes: s.bytes,
                    modeled_sec: s.time,
                    measured_bytes: m.payload_bytes,
                    measured_wall_sec: m.wall_sec,
                    rpcs: m.rpcs,
                }
            })
            .collect();
        report.calibration = Some(CalibrationReport {
            backend: shm.backend_id().to_string(),
            run_wall_sec: shm.run_wall_sec(),
            epochs: cal_epochs,
            links: cal_links,
        });
    }
    let energy = run_energy(&report, &cfg.power);
    report.cpu_energy_j = energy.cpu.total_j;
    report.gpu_energy_j = energy.gpu.total_j;
    Ok(report)
}

/// Instantiate the configured train-step backend, honoring the strategy's
/// gradient-compression request (`grad-topk`'s error-feedback sparsifier).
pub fn build_trainer(ctx: &RunContext) -> Result<Box<dyn TrainStep>> {
    let cfg = &ctx.cfg;
    let spec = ctx.strategy.grad_compression(&cfg.engine_params);
    match cfg.backend {
        TrainerBackend::Host => {
            let model = SageModel::new(
                cfg.dataset.feature_dim as usize,
                cfg.hidden_dim as usize,
                cfg.dataset.num_classes as usize,
                cfg.num_layers(),
                cfg.base_seed,
            );
            Ok(match spec {
                Some(gc) => {
                    Box::new(GradCompressedSage::new(model, gc.mode, gc.k, cfg.base_seed))
                }
                None => Box::new(model),
            })
        }
        TrainerBackend::Pjrt => {
            if spec.is_some() {
                bail!(
                    "gradient compression (grad_k > 0) requires the host backend: \
                     the AOT-compiled PJRT artifact applies dense updates"
                );
            }
            crate::runtime::build_pjrt_trainer(ctx)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetPreset, Engine};

    fn cfg(engine: Engine) -> RunConfig {
        let mut c = RunConfig::default();
        c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
        c.engine = engine;
        c.epochs = 2;
        c.n_hot = 300;
        c
    }

    #[test]
    fn trace_run_all_registered_engines() {
        // Every registry id runs end to end through the shared pipeline —
        // no per-engine dispatch anywhere on this path.
        for engine in EngineRegistry::global().engines() {
            let report = run(&cfg(engine)).unwrap();
            assert_eq!(report.engine, engine.name());
            assert_eq!(report.epochs.len(), 2 * 2, "2 workers × 2 epochs");
            assert!(report.total_time > 0.0, "{}", engine.id());
            assert!(report.cpu_energy_j > 0.0);
            assert!(report.gpu_energy_j > 0.0);
        }
    }

    #[test]
    fn rapid_beats_baselines_end_to_end() {
        let rapid = run(&cfg(Engine::Rapid)).unwrap();
        for baseline in [Engine::DglMetis, Engine::DglRandom, Engine::DistGcn] {
            let base = run(&cfg(baseline)).unwrap();
            assert!(
                rapid.mean_step_time() < base.mean_step_time(),
                "{}: rapid {} !< {}",
                baseline.name(),
                rapid.mean_step_time(),
                base.mean_step_time()
            );
            assert!(rapid.total_remote_rows() < base.total_remote_rows());
        }
    }

    #[test]
    fn rapid_uses_less_energy() {
        let rapid = run(&cfg(Engine::Rapid)).unwrap();
        let base = run(&cfg(Engine::DglMetis)).unwrap();
        assert!(rapid.cpu_energy_j < base.cpu_energy_j);
        assert!(rapid.gpu_energy_j < base.gpu_energy_j);
    }

    #[test]
    fn full_mode_trains_host_model() {
        let mut c = cfg(Engine::Rapid);
        c.exec_mode = ExecMode::Full;
        c.batch_size = 64;
        c.epochs = 3;
        let report = run(&c).unwrap();
        let curve = report.accuracy_curve();
        assert_eq!(curve.len(), 3);
        // accuracy improves from epoch 0 to the last epoch
        assert!(curve.last().unwrap().1 > curve[0].1, "accuracy {:?}", curve);
        assert!(report.loss_curve().last().unwrap().1 < report.loss_curve()[0].1);
    }

    #[test]
    fn full_mode_baseline_also_trains() {
        let mut c = cfg(Engine::DglMetis);
        c.exec_mode = ExecMode::Full;
        c.batch_size = 64;
        c.epochs = 2;
        let report = run(&c).unwrap();
        assert!(report.loss_curve().iter().all(|&(_, l)| l.is_finite()));
    }

    #[test]
    fn wallclock_mode_reports_calibration_and_matches_trace_counters() {
        let trace = run(&cfg(Engine::Rapid)).unwrap();
        assert!(trace.calibration.is_none(), "trace runs stay calibration-free");
        let mut c = cfg(Engine::Rapid);
        c.exec_mode = ExecMode::Wallclock;
        let wall = run(&c).unwrap();
        let cal = wall.calibration.as_ref().expect("wallclock attaches calibration");
        assert_eq!(cal.backend, "shm-rings");
        assert!(cal.run_wall_sec > 0.0);
        assert!(!cal.epochs.is_empty() && !cal.links.is_empty());
        assert!(
            cal.epochs.iter().map(|e| e.measured_bytes).sum::<u64>() > 0,
            "the real transport moved bytes"
        );
        // Conformance: the real backend prices through the same fabric, so
        // the modeled counters equal the simulated trace exactly.
        assert_eq!(wall.total_remote_rows(), trace.total_remote_rows());
        assert_eq!(wall.sync_remote_rows(), trace.sync_remote_rows());
    }

    #[test]
    fn total_time_is_max_worker_not_sum() {
        let report = run(&cfg(Engine::DglMetis)).unwrap();
        let sum: f64 = report.epochs.iter().map(|e| e.epoch_time).sum();
        assert!(report.total_time < sum, "workers run concurrently");
        assert!(report.total_time > 0.0);
    }

    #[test]
    fn run_builder_with_custom_strategy_bypasses_registry() {
        // The RunBuilder escape hatch: an unregistered strategy drives the
        // same pipeline end to end.
        let report = RunBuilder::new(cfg(Engine::DglMetis))
            .with_strategy(Box::new(DglStrategy { random_partition: false }))
            .run()
            .unwrap();
        let registry_report = run(&cfg(Engine::DglMetis)).unwrap();
        assert_eq!(report.total_remote_rows(), registry_report.total_remote_rows());
        assert_eq!(report.engine, registry_report.engine);
    }

    #[test]
    fn run_builder_with_custom_trainer_runs_full_mode() {
        let mut c = cfg(Engine::DglMetis);
        c.exec_mode = ExecMode::Full;
        c.batch_size = 64;
        let ctx = RunContext::build(&c).unwrap();
        let trainer = build_trainer(&ctx).unwrap();
        let report = RunBuilder::new(c).with_trainer(trainer).run().unwrap();
        assert!(report.loss_curve().iter().all(|&(_, l)| l.is_finite()));
    }
}
