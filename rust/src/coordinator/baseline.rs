//! On-demand baselines: DGL-METIS, DGL-Random, and Dist-GCN (paper §2.3).
//!
//! These engines reproduce DistDGL's data path: each batch is sampled online
//! on the critical path, then *all* of its remote input-node features are
//! fetched synchronously from the KV store before the training step runs.
//! There is no cache and no prefetch overlap (`Q = 0` in the pipeline model)
//! — exactly the reactive behaviour RapidGNN's scheduled data path replaces.
//! Dist-GCN differs only in its fan-out policy (capped full neighborhoods →
//! much larger input sets, the paper's worst communicator).
//!
//! Wall-clock note: `enumerate_epoch` runs on the multi-threaded sampler
//! with per-thread scratch arenas (like DGL's parallel dataloader workers),
//! which only accelerates *our* harness — the simulated per-batch
//! `sample_time` charged on the critical path below is unchanged, since it
//! models the baseline's online sampling cost, not ours.

use super::common::RunContext;
use super::SharedTrainer;
use crate::config::ExecMode;
use crate::metrics::{CommStats, EpochReport, PhaseTimes};
use crate::sampler::khop::sample_blocks;
use crate::sampler::seed::derive_seed;
use crate::sampler::{enumerate_epoch, BatchMeta};
use crate::sim::{ClusterSim, WorkerActor};
use crate::trainer::{batch_labels, feature_mat, TrainStep};
use crate::util::mpmc;
use crate::WorkerId;
use std::time::Instant;

/// Run one worker's full training for a baseline engine.
///
/// `trainer` is `Some` in full-exec mode (workers sequentially share the
/// model — sequential SGD over the shard union, see DESIGN.md §4).
pub fn run_worker(
    ctx: &RunContext,
    worker: WorkerId,
    mut trainer: Option<&mut (dyn TrainStep + 'static)>,
) -> Vec<EpochReport> {
    let cfg = &ctx.cfg;
    let fanouts = ctx.fanouts();
    let full = cfg.exec_mode == ExecMode::Full;
    let d = cfg.dataset.feature_dim;
    let mut reports = Vec::with_capacity(cfg.epochs as usize);

    for epoch in 0..cfg.epochs {
        // Online sampling: the schedule is enumerated batch by batch at run
        // time. We enumerate the epoch here and charge the per-batch
        // sampling cost on the critical path — the DGL dataloader pattern.
        let sched = enumerate_epoch(
            &ctx.ds.graph,
            &ctx.part,
            &ctx.shards[worker as usize],
            &fanouts,
            cfg.batch_size,
            cfg.base_seed,
            worker,
            epoch,
        );

        let mut phases = PhaseTimes::default();
        let mut comm = CommStats::default();
        let mut m_max = 0u64;
        let (mut loss_sum, mut correct, mut total) = (0.0f64, 0u64, 0u64);

        let slow = ctx.slowdown(worker);
        for meta in &sched.batches {
            let n_input = meta.input_nodes.len();
            m_max = m_max.max(n_input as u64);
            // Local work (sampling, assembly, compute) carries the straggler
            // slowdown; the fetch is charged per-link by the fabric, which
            // applies its own straggler factor to links touching the worker.
            phases.sample += slow * ctx.costs.sample_time(n_input);

            // On-demand fetch of every remote input feature, synchronously on
            // the critical path (local rows gather free of network).
            let mut features: Vec<f32> = Vec::new();
            let pull = ctx.kv.sync_pull(
                worker,
                &meta.input_nodes,
                if full { Some(&mut features) } else { None },
                &mut comm,
            );
            phases.fetch += pull.time;
            phases.assemble += slow * ctx.costs.assemble_time(n_input, d);

            if full {
                let t0 = Instant::now();
                let out = full_train_step(ctx, worker, epoch, meta, features, trainer.as_deref_mut());
                phases.compute += t0.elapsed().as_secs_f64();
                loss_sum += out.0;
                correct += out.1 as u64;
                total += out.2 as u64;
            } else {
                phases.compute += slow * ctx.compute_time(n_input, meta.seeds.len());
            }
        }

        let steps = sched.batches.len() as u32;
        reports.push(EpochReport {
            epoch,
            worker,
            steps,
            epoch_time: phases.total(),
            phases,
            comm,
            cache: Default::default(),
            mean_loss: if full { loss_sum / steps.max(1) as f64 } else { f64::NAN },
            train_acc: if full && total > 0 {
                correct as f64 / total as f64
            } else {
                f64::NAN
            },
            // One batch in flight on device + model activations.
            device_bytes: m_max * d as u64 * 4,
            // Online sampling holds one epoch schedule in host memory — the
            // DGL dataloader materializes indices per epoch.
            host_bytes: sched.batches.iter().map(|b| b.byte_size()).sum(),
        });
    }
    reports
}

/// Execute a real training step (full mode): rebuild the batch's blocks from
/// its deterministic seed, wrap the fetched features, and step the model.
pub(super) fn full_train_step(
    ctx: &RunContext,
    worker: WorkerId,
    epoch: u32,
    meta: &BatchMeta,
    features: Vec<f32>,
    trainer: Option<&mut (dyn TrainStep + 'static)>,
) -> (f64, u32, u32) {
    let Some(trainer) = trainer else {
        return (f64::NAN, 0, 0);
    };
    let fanouts = ctx.fanouts();
    let rng_seed = derive_seed(ctx.cfg.base_seed, worker, epoch, meta.batch);
    let batch = sample_blocks(&ctx.ds.graph, &meta.seeds, &fanouts, rng_seed);
    debug_assert_eq!(batch.input_nodes(), &meta.input_nodes[..], "determinism");
    let x0 = feature_mat(features, meta.input_nodes.len(), ctx.cfg.dataset.feature_dim as usize);
    let labels = batch_labels(&ctx.ds, &batch);
    let out = trainer.step(&x0, &batch, &labels, ctx.cfg.learning_rate);
    (out.loss, out.correct, out.total)
}

/// One baseline worker's epoch as a [`WorkerActor`]: online sampling + the
/// full on-demand fetch in the stage slot, assemble + train in the consume
/// slot, with `Q = 0` (no overlap — the reactive DistDGL behaviour). The
/// single-slot [`mpmc`] ring carries the fetched batch to the trainer.
struct BaselineEpochActor<'a> {
    ctx: &'a RunContext,
    worker: WorkerId,
    epoch: u32,
    slow: f64,
    full: bool,
    batches: std::vec::IntoIter<BatchMeta>,
    queue_tx: mpmc::Sender<(BatchMeta, Vec<f32>)>,
    queue_rx: mpmc::Receiver<(BatchMeta, Vec<f32>)>,
    trainer: Option<SharedTrainer>,
    comm: CommStats,
    phases: PhaseTimes,
    m_max: u64,
    loss_sum: f64,
    correct: u64,
    total: u64,
}

impl<'a> BaselineEpochActor<'a> {
    fn new(
        ctx: &'a RunContext,
        worker: WorkerId,
        epoch: u32,
        batches: Vec<BatchMeta>,
        trainer: Option<SharedTrainer>,
    ) -> Self {
        let (queue_tx, queue_rx) = mpmc::bounded(1);
        BaselineEpochActor {
            worker,
            epoch,
            slow: ctx.slowdown(worker),
            full: ctx.cfg.exec_mode == ExecMode::Full,
            batches: batches.into_iter(),
            queue_tx,
            queue_rx,
            trainer,
            comm: CommStats::default(),
            phases: PhaseTimes::default(),
            m_max: 0,
            loss_sum: 0.0,
            correct: 0,
            total: 0,
            ctx,
        }
    }
}

impl WorkerActor for BaselineEpochActor<'_> {
    fn stage_next(&mut self) -> Option<f64> {
        let meta = self.batches.next()?;
        let n_input = meta.input_nodes.len();
        self.m_max = self.m_max.max(n_input as u64);
        let sample = self.slow * self.ctx.costs.sample_time(n_input);
        self.phases.sample += sample;
        let mut features: Vec<f32> = Vec::new();
        let pull = self.ctx.kv.sync_pull(
            self.worker,
            &meta.input_nodes,
            if self.full { Some(&mut features) } else { None },
            &mut self.comm,
        );
        self.phases.fetch += pull.time;
        if self.queue_tx.try_send((meta, features)).is_err() {
            panic!("cluster scheduler overflowed the serial staging slot");
        }
        Some(sample + pull.time)
    }

    fn consume_next(&mut self) -> f64 {
        let (meta, features) = self
            .queue_rx
            .try_recv()
            .expect("scheduler consumes only staged batches");
        let n_input = meta.input_nodes.len();
        let d = self.ctx.cfg.dataset.feature_dim;
        let assemble = self.slow * self.ctx.costs.assemble_time(n_input, d);
        let compute = self.slow * self.ctx.compute_time(n_input, meta.seeds.len());
        if self.full {
            let out = match &self.trainer {
                Some(tr) => {
                    let mut t = tr.lock().unwrap();
                    full_train_step(self.ctx, self.worker, self.epoch, &meta, features, Some(&mut **t))
                }
                None => (f64::NAN, 0, 0),
            };
            self.loss_sum += out.0;
            self.correct += out.1 as u64;
            self.total += out.2 as u64;
        }
        self.phases.assemble += assemble;
        self.phases.compute += compute;
        assemble + compute
    }
}

/// Run every baseline worker concurrently on the shared virtual clock — the
/// event-driven replacement for the old sequential full-mode loop. Each
/// worker is still internally serial (`Q = 0`), but cross-worker train steps
/// interleave in deterministic virtual-time order on the shared model.
pub fn run_cluster(ctx: &RunContext, trainer: Option<SharedTrainer>) -> Vec<EpochReport> {
    let cfg = &ctx.cfg;
    let fanouts = ctx.fanouts();
    let full = cfg.exec_mode == ExecMode::Full;
    let d = cfg.dataset.feature_dim;
    let mut reports = Vec::with_capacity((cfg.num_workers * cfg.epochs) as usize);

    for epoch in 0..cfg.epochs {
        let mut sim = ClusterSim::new();
        let mut sched_bytes: Vec<u64> = Vec::with_capacity(cfg.num_workers as usize);
        for w in 0..cfg.num_workers {
            let sched = enumerate_epoch(
                &ctx.ds.graph,
                &ctx.part,
                &ctx.shards[w as usize],
                &fanouts,
                cfg.batch_size,
                cfg.base_seed,
                w,
                epoch,
            );
            sched_bytes.push(sched.batches.iter().map(|b| b.byte_size()).sum());
            sim.add_worker(0, BaselineEpochActor::new(ctx, w, epoch, sched.batches, trainer.clone()));
        }
        for (w, done) in sim.run().into_iter().enumerate() {
            let timeline = done.timeline;
            let actor = done.actor;
            let steps = timeline.steps() as u32;
            reports.push(EpochReport {
                epoch,
                worker: w as WorkerId,
                steps,
                epoch_time: timeline.makespan,
                phases: actor.phases,
                comm: actor.comm,
                cache: Default::default(),
                mean_loss: if full { actor.loss_sum / steps.max(1) as f64 } else { f64::NAN },
                train_acc: if full && actor.total > 0 {
                    actor.correct as f64 / actor.total as f64
                } else {
                    f64::NAN
                },
                device_bytes: actor.m_max * d as u64 * 4,
                host_bytes: sched_bytes[w],
            });
        }
    }
    reports
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetPreset, Engine, RunConfig};

    fn ctx(engine: Engine) -> RunContext {
        let mut c = RunConfig::default();
        c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
        c.engine = engine;
        c.epochs = 2;
        RunContext::build(&c).unwrap()
    }

    #[test]
    fn baseline_reports_all_epochs_and_steps() {
        let ctx = ctx(Engine::DglMetis);
        let reports = run_worker(&ctx, 0, None);
        assert_eq!(reports.len(), 2);
        for r in &reports {
            assert!(r.steps >= 1);
            assert!(r.epoch_time > 0.0);
            assert!(r.phases.fetch > 0.0, "on-demand fetch must cost time");
            assert_eq!(r.cache.lookups, 0, "baselines have no cache");
            assert!(r.mean_loss.is_nan(), "trace mode has no loss");
        }
    }

    #[test]
    fn epoch_time_is_sum_of_phases() {
        let ctx = ctx(Engine::DglMetis);
        let r = &run_worker(&ctx, 0, None)[0];
        assert!((r.epoch_time - r.phases.total()).abs() < 1e-12);
        assert_eq!(r.phases.idle, 0.0, "serial baseline never idles");
    }

    #[test]
    fn gcn_fetches_more_than_sage() {
        let sage = run_worker(&ctx(Engine::DglMetis), 0, None);
        let gcn = run_worker(&ctx(Engine::DistGcn), 0, None);
        let rows = |rs: &[EpochReport]| -> u64 { rs.iter().map(|r| r.comm.remote_rows).sum() };
        assert!(
            rows(&gcn) > rows(&sage),
            "full-neighborhood GCN must move more rows: {} vs {}",
            rows(&gcn),
            rows(&sage)
        );
    }

    #[test]
    fn random_partition_fetches_more_than_metis() {
        let metis = run_worker(&ctx(Engine::DglMetis), 0, None);
        let random = run_worker(&ctx(Engine::DglRandom), 0, None);
        let rows = |rs: &[EpochReport]| -> u64 { rs.iter().map(|r| r.comm.remote_rows).sum() };
        assert!(rows(&random) > rows(&metis), "{} !> {}", rows(&random), rows(&metis));
    }

    #[test]
    fn deterministic_across_runs() {
        let c = ctx(Engine::DglMetis);
        let a = run_worker(&c, 0, None);
        let c2 = ctx(Engine::DglMetis);
        let b = run_worker(&c2, 0, None);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.comm.remote_rows, y.comm.remote_rows);
            assert!((x.epoch_time - y.epoch_time).abs() < 1e-12);
        }
    }

    #[test]
    fn cluster_runtime_matches_sequential_worker_path() {
        // Q = 0 actors on the shared virtual clock must reproduce the serial
        // per-worker accounting: identical counters, epoch times within
        // float-accumulation noise (the event path sums per-batch, the
        // serial path per-phase).
        let seq_ctx = ctx(Engine::DglMetis);
        let mut seq = Vec::new();
        for w in 0..seq_ctx.cfg.num_workers {
            seq.extend(run_worker(&seq_ctx, w, None));
        }
        let clu_ctx = ctx(Engine::DglMetis);
        let clu = run_cluster(&clu_ctx, None);
        assert_eq!(seq.len(), clu.len());
        for c in &clu {
            let s = seq
                .iter()
                .find(|r| r.worker == c.worker && r.epoch == c.epoch)
                .expect("matching report");
            assert_eq!(s.comm.remote_rows, c.comm.remote_rows);
            assert_eq!(s.comm.bytes, c.comm.bytes);
            assert_eq!(s.comm.sync_pulls, c.comm.sync_pulls);
            assert_eq!(s.steps, c.steps);
            assert_eq!(s.host_bytes, c.host_bytes);
            assert_eq!(s.device_bytes, c.device_bytes);
            assert!(
                (s.epoch_time - c.epoch_time).abs() < 1e-9,
                "w{} e{}: {} vs {}",
                c.worker,
                c.epoch,
                s.epoch_time,
                c.epoch_time
            );
        }
    }
}
