//! On-demand baselines: DGL-METIS, DGL-Random, and Dist-GCN (paper §2.3).
//!
//! These engines reproduce DistDGL's data path: each batch is sampled online
//! on the critical path, then *all* of its remote input-node features are
//! fetched synchronously from the KV store before the training step runs.
//! There is no cache and no prefetch overlap (`Q = 0` in the pipeline model)
//! — exactly the reactive behaviour RapidGNN's scheduled data path replaces.
//! Dist-GCN differs only in its fan-out policy (capped full neighborhoods →
//! much larger input sets, the paper's worst communicator).
//!
//! Wall-clock note: `enumerate_epoch` runs on the multi-threaded sampler
//! with per-thread scratch arenas (like DGL's parallel dataloader workers),
//! which only accelerates *our* harness — the simulated per-batch
//! `sample_time` charged on the critical path below is unchanged, since it
//! models the baseline's online sampling cost, not ours.

use super::common::RunContext;
use crate::config::ExecMode;
use crate::metrics::{CommStats, EpochReport, PhaseTimes};
use crate::sampler::khop::sample_blocks;
use crate::sampler::seed::derive_seed;
use crate::sampler::{enumerate_epoch, BatchMeta};
use crate::trainer::{batch_labels, feature_mat, TrainStep};
use crate::WorkerId;
use std::time::Instant;

/// Run one worker's full training for a baseline engine.
///
/// `trainer` is `Some` in full-exec mode (workers sequentially share the
/// model — sequential SGD over the shard union, see DESIGN.md §4).
pub fn run_worker(
    ctx: &RunContext,
    worker: WorkerId,
    mut trainer: Option<&mut (dyn TrainStep + 'static)>,
) -> Vec<EpochReport> {
    let cfg = &ctx.cfg;
    let fanouts = ctx.fanouts();
    let full = cfg.exec_mode == ExecMode::Full;
    let d = cfg.dataset.feature_dim;
    let mut reports = Vec::with_capacity(cfg.epochs as usize);

    for epoch in 0..cfg.epochs {
        // Online sampling: the schedule is enumerated batch by batch at run
        // time. We enumerate the epoch here and charge the per-batch
        // sampling cost on the critical path — the DGL dataloader pattern.
        let sched = enumerate_epoch(
            &ctx.ds.graph,
            &ctx.part,
            &ctx.shards[worker as usize],
            &fanouts,
            cfg.batch_size,
            cfg.base_seed,
            worker,
            epoch,
        );

        let mut phases = PhaseTimes::default();
        let mut comm = CommStats::default();
        let mut m_max = 0u64;
        let (mut loss_sum, mut correct, mut total) = (0.0f64, 0u64, 0u64);

        for meta in &sched.batches {
            let n_input = meta.input_nodes.len();
            m_max = m_max.max(n_input as u64);
            phases.sample += ctx.costs.sample_time(n_input);

            // On-demand fetch of every remote input feature, synchronously on
            // the critical path (local rows gather free of network).
            let mut features: Vec<f32> = Vec::new();
            let pull = ctx.kv.sync_pull(
                worker,
                &meta.input_nodes,
                if full { Some(&mut features) } else { None },
                &mut comm,
            );
            phases.fetch += pull.time;
            phases.assemble += ctx.costs.assemble_time(n_input, d);

            if full {
                let t0 = Instant::now();
                let out = full_train_step(ctx, worker, epoch, meta, features, trainer.as_deref_mut());
                phases.compute += t0.elapsed().as_secs_f64();
                loss_sum += out.0;
                correct += out.1 as u64;
                total += out.2 as u64;
            } else {
                phases.compute += ctx.compute_time(n_input, meta.seeds.len());
            }
        }

        let steps = sched.batches.len() as u32;
        reports.push(EpochReport {
            epoch,
            worker,
            steps,
            epoch_time: phases.total(),
            phases,
            comm,
            cache: Default::default(),
            mean_loss: if full { loss_sum / steps.max(1) as f64 } else { f64::NAN },
            train_acc: if full && total > 0 {
                correct as f64 / total as f64
            } else {
                f64::NAN
            },
            // One batch in flight on device + model activations.
            device_bytes: m_max * d as u64 * 4,
            // Online sampling holds one epoch schedule in host memory — the
            // DGL dataloader materializes indices per epoch.
            host_bytes: sched.batches.iter().map(|b| b.byte_size()).sum(),
        });
    }
    reports
}

/// Execute a real training step (full mode): rebuild the batch's blocks from
/// its deterministic seed, wrap the fetched features, and step the model.
pub(super) fn full_train_step(
    ctx: &RunContext,
    worker: WorkerId,
    epoch: u32,
    meta: &BatchMeta,
    features: Vec<f32>,
    trainer: Option<&mut (dyn TrainStep + 'static)>,
) -> (f64, u32, u32) {
    let Some(trainer) = trainer else {
        return (f64::NAN, 0, 0);
    };
    let fanouts = ctx.fanouts();
    let rng_seed = derive_seed(ctx.cfg.base_seed, worker, epoch, meta.batch);
    let batch = sample_blocks(&ctx.ds.graph, &meta.seeds, &fanouts, rng_seed);
    debug_assert_eq!(batch.input_nodes(), &meta.input_nodes[..], "determinism");
    let x0 = feature_mat(features, meta.input_nodes.len(), ctx.cfg.dataset.feature_dim as usize);
    let labels = batch_labels(&ctx.ds, &batch);
    let out = trainer.step(&x0, &batch, &labels, ctx.cfg.learning_rate);
    (out.loss, out.correct, out.total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetPreset, Engine, RunConfig};

    fn ctx(engine: Engine) -> RunContext {
        let mut c = RunConfig::default();
        c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
        c.engine = engine;
        c.epochs = 2;
        RunContext::build(&c).unwrap()
    }

    #[test]
    fn baseline_reports_all_epochs_and_steps() {
        let ctx = ctx(Engine::DglMetis);
        let reports = run_worker(&ctx, 0, None);
        assert_eq!(reports.len(), 2);
        for r in &reports {
            assert!(r.steps >= 1);
            assert!(r.epoch_time > 0.0);
            assert!(r.phases.fetch > 0.0, "on-demand fetch must cost time");
            assert_eq!(r.cache.lookups, 0, "baselines have no cache");
            assert!(r.mean_loss.is_nan(), "trace mode has no loss");
        }
    }

    #[test]
    fn epoch_time_is_sum_of_phases() {
        let ctx = ctx(Engine::DglMetis);
        let r = &run_worker(&ctx, 0, None)[0];
        assert!((r.epoch_time - r.phases.total()).abs() < 1e-12);
        assert_eq!(r.phases.idle, 0.0, "serial baseline never idles");
    }

    #[test]
    fn gcn_fetches_more_than_sage() {
        let sage = run_worker(&ctx(Engine::DglMetis), 0, None);
        let gcn = run_worker(&ctx(Engine::DistGcn), 0, None);
        let rows = |rs: &[EpochReport]| -> u64 { rs.iter().map(|r| r.comm.remote_rows).sum() };
        assert!(
            rows(&gcn) > rows(&sage),
            "full-neighborhood GCN must move more rows: {} vs {}",
            rows(&gcn),
            rows(&sage)
        );
    }

    #[test]
    fn random_partition_fetches_more_than_metis() {
        let metis = run_worker(&ctx(Engine::DglMetis), 0, None);
        let random = run_worker(&ctx(Engine::DglRandom), 0, None);
        let rows = |rs: &[EpochReport]| -> u64 { rs.iter().map(|r| r.comm.remote_rows).sum() };
        assert!(rows(&random) > rows(&metis), "{} !> {}", rows(&random), rows(&metis));
    }

    #[test]
    fn deterministic_across_runs() {
        let c = ctx(Engine::DglMetis);
        let a = run_worker(&c, 0, None);
        let c2 = ctx(Engine::DglMetis);
        let b = run_worker(&c2, 0, None);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.comm.remote_rows, y.comm.remote_rows);
            assert!((x.epoch_time - y.epoch_time).abs() < 1e-12);
        }
    }
}
