//! Fixed-size character frame buffer with a tiny ANSI style palette.
//!
//! The dashboard never prints directly (the `trace-sink` lint rule forbids
//! console output anywhere under `src/tui/`): widgets draw styled cells into
//! a [`Frame`], and the frame renders to a `String` — [`Frame::render_plain`]
//! for snapshot tests and piped output, [`Frame::render_ansi`] for live
//! terminals. The caller (the CLI layer) owns the one place bytes reach
//! stdout.

/// Cell style. Maps to one ANSI SGR sequence in [`Frame::render_ansi`] and
/// is invisible in [`Frame::render_plain`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Style {
    /// Default foreground.
    Plain,
    /// Section titles (bold).
    Title,
    /// Gauge/bar fill (cyan).
    Bar,
    /// Saturated / straggler highlight (red).
    Hot,
    /// Caution highlight (yellow).
    Warn,
}

impl Style {
    /// The SGR escape that selects this style.
    fn sgr(self) -> &'static str {
        match self {
            Style::Plain => "\x1b[0m",
            Style::Title => "\x1b[1m",
            Style::Bar => "\x1b[36m",
            Style::Hot => "\x1b[31m",
            Style::Warn => "\x1b[33m",
        }
    }
}

/// A `width × height` grid of styled characters. Writes outside the bounds
/// are clipped, so widgets never need their own range checks.
#[derive(Debug, Clone)]
pub struct Frame {
    /// Columns.
    pub width: usize,
    /// Rows.
    pub height: usize,
    cells: Vec<(char, Style)>,
}

impl Frame {
    /// Blank frame (spaces, [`Style::Plain`]).
    pub fn new(width: usize, height: usize) -> Frame {
        Frame { width, height, cells: vec![(' ', Style::Plain); width * height] }
    }

    /// Write one cell; out-of-bounds writes are ignored.
    pub fn put(&mut self, x: usize, y: usize, ch: char, style: Style) {
        if x < self.width && y < self.height {
            self.cells[y * self.width + x] = (ch, style);
        }
    }

    /// Write a string starting at `(x, y)`, clipped at the right edge.
    pub fn text(&mut self, x: usize, y: usize, s: &str, style: Style) {
        for (i, ch) in s.chars().enumerate() {
            self.put(x + i, y, ch, style);
        }
    }

    /// Repeat `ch` horizontally for `len` cells.
    pub fn hline(&mut self, x: usize, y: usize, len: usize, ch: char, style: Style) {
        for i in 0..len {
            self.put(x + i, y, ch, style);
        }
    }

    /// Render without styling: rows joined by `\n`, trailing spaces trimmed
    /// per row (stable bytes for snapshot tests), no trailing newline.
    pub fn render_plain(&self) -> String {
        let mut out = String::new();
        for y in 0..self.height {
            if y > 0 {
                out.push('\n');
            }
            let row: String =
                self.cells[y * self.width..(y + 1) * self.width].iter().map(|c| c.0).collect();
            out.push_str(row.trim_end());
        }
        out
    }

    /// Render with ANSI styling. Escape sequences are emitted only on style
    /// changes, each row ends with a reset, rows join with `\r\n` (the live
    /// loop redraws with the cursor parked at home).
    pub fn render_ansi(&self) -> String {
        let mut out = String::new();
        for y in 0..self.height {
            if y > 0 {
                out.push_str("\r\n");
            }
            let mut current = Style::Plain;
            for (ch, style) in &self.cells[y * self.width..(y + 1) * self.width] {
                if *style != current {
                    out.push_str(style.sgr());
                    current = *style;
                }
                out.push(*ch);
            }
            if current != Style::Plain {
                out.push_str(Style::Plain.sgr());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_and_text_clip_at_bounds() {
        let mut f = Frame::new(4, 2);
        f.text(2, 0, "abcd", Style::Plain); // clips to "ab"
        f.put(0, 5, 'x', Style::Plain); // ignored
        f.put(9, 0, 'x', Style::Plain); // ignored
        assert_eq!(f.render_plain(), "  ab\n");
    }

    #[test]
    fn plain_render_trims_trailing_spaces() {
        let mut f = Frame::new(6, 2);
        f.text(0, 0, "hi", Style::Title);
        f.hline(0, 1, 3, '-', Style::Bar);
        assert_eq!(f.render_plain(), "hi\n---");
    }

    #[test]
    fn ansi_render_switches_styles_minimally() {
        let mut f = Frame::new(3, 1);
        f.put(0, 0, 'a', Style::Hot);
        f.put(1, 0, 'b', Style::Hot);
        f.put(2, 0, 'c', Style::Plain);
        assert_eq!(f.render_ansi(), "\x1b[31mab\x1b[0mc");
    }

    #[test]
    fn ansi_render_resets_at_row_end() {
        let mut f = Frame::new(1, 2);
        f.put(0, 0, 'a', Style::Bar);
        f.put(0, 1, 'b', Style::Plain);
        assert_eq!(f.render_ansi(), "\x1b[36ma\x1b[0m\r\nb");
    }
}
