//! Dashboard application state and layout.
//!
//! [`App`] owns the report being displayed and knows how to lay the widgets
//! out into one content-sized [`Frame`]. It is constructed either from a
//! finished [`RunReport`] (`top --report`, or live mode after the run
//! completes) or replayed from a trace journal's per-(worker, epoch)
//! `epoch` records (`top --trace`). Rendering is a pure function of the
//! report — the CLI layer owns the terminal, the render loop never reads a
//! clock, and nothing here prints.

use crate::metrics::{EpochReport, RunReport};
use crate::trace::TraceRecord;
use crate::tui::frame::{Frame, Style};
use crate::tui::widgets::{cache, counters, links, timeline};
use crate::Result;

/// Dashboard state: the report under display.
#[derive(Debug, Clone)]
pub struct App {
    /// The run being rendered.
    pub report: RunReport,
}

impl App {
    /// Dashboard over a finished (or partially assembled) report.
    pub fn from_report(report: RunReport) -> App {
        App { report }
    }

    /// Rebuild a replay report from a journal's `epoch` records (other record
    /// kinds are ignored here — they exist for machine analysis). Run-level
    /// identity is not in the journal, so replay labels it as such.
    pub fn from_trace_records(records: &[TraceRecord]) -> Result<App> {
        let mut report = RunReport {
            engine: "(trace replay)".to_string(),
            dataset: "(trace replay)".to_string(),
            ..Default::default()
        };
        for rec in records.iter().filter(|r| r.kind == "epoch") {
            report.epochs.push(EpochReport::from_value(&rec.fields)?);
        }
        report.num_workers = report.epochs.iter().map(|e| e.worker + 1).max().unwrap_or(0);
        // Total time = max over workers of their summed epoch times, the same
        // convention the coordinator uses.
        let mut per_worker = vec![0.0f64; report.num_workers as usize];
        for e in &report.epochs {
            per_worker[e.worker as usize] += e.epoch_time;
        }
        report.total_time = per_worker.iter().cloned().fold(0.0, f64::max);
        Ok(App { report })
    }

    /// A copy restricted to epochs `<= upto` — the replay loop renders one
    /// frame per epoch by truncating the full report.
    pub fn through_epoch(&self, upto: u32) -> App {
        let mut report = self.report.clone();
        report.epochs.retain(|e| e.epoch <= upto);
        App { report }
    }

    /// Highest epoch index present (None on an empty report).
    pub fn last_epoch(&self) -> Option<u32> {
        self.report.epochs.iter().map(|e| e.epoch).max()
    }

    /// Rows the full layout needs at the moment (content-sized).
    fn height(&self) -> usize {
        let r = &self.report;
        let links_rows = if r.links.is_empty() { 2 } else { 1 + r.links.len() };
        let workers = timeline::worker_totals(r).len();
        let timeline_rows = if workers == 0 { 2 } else { 1 + workers };
        // title + summary + blank, then panels separated by blank rows.
        3 + links_rows + 1 + 2 + 1 + timeline_rows + 1 + 2
    }

    /// Render the full dashboard into a content-sized frame of `width`
    /// columns.
    pub fn render(&self, width: usize) -> Frame {
        let r = &self.report;
        let mut f = Frame::new(width, self.height());
        let epochs = r.epochs.iter().map(|e| e.epoch).max().map_or(0, |e| e + 1);
        f.text(
            0,
            0,
            &format!(
                "rapidgnn top — {} on {} ({} workers, {} epochs)",
                r.engine, r.dataset, r.num_workers, epochs
            ),
            Style::Title,
        );
        f.text(
            0,
            1,
            &format!(
                "total {:.3}s  setup {:.3}s  cpu {:.1}J  gpu {:.1}J",
                r.total_time, r.setup_time, r.cpu_energy_j, r.gpu_energy_j
            ),
            Style::Plain,
        );
        let mut y = 3;
        y += links::render(&mut f, 0, y, &r.links) + 1;
        y += cache::render(&mut f, 0, y, width, r) + 1;
        y += timeline::render(&mut f, 0, y, r) + 1;
        counters::render(&mut f, 0, y, r);
        f
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{CacheStats, CompressionReport, LinkReport, RecoveryReport};
    use crate::util::value::Value;

    fn epoch(epoch: u32, worker: u32, time: f64, lookups: u64, hits: u64) -> EpochReport {
        EpochReport {
            epoch,
            worker,
            epoch_time: time,
            cache: CacheStats { lookups, hits },
            ..Default::default()
        }
    }

    /// Fixture with every optional section present.
    fn full_report() -> RunReport {
        RunReport {
            engine: "rapid".to_string(),
            dataset: "tiny".to_string(),
            num_workers: 2,
            batch_size: 32,
            epochs: vec![epoch(0, 0, 1.0, 10, 5), epoch(0, 1, 2.0, 10, 10)],
            total_time: 2.0,
            setup_time: 0.5,
            cpu_energy_j: 1.0,
            gpu_energy_j: 2.0,
            links: vec![LinkReport {
                link: "host-up:0".to_string(),
                capacity_bytes_per_sec: 1000.0,
                busy_sec: 2.0,
                served_bytes: 1000.0,
                flows: 4,
                peak_flows: 2,
                peak_backlog_bytes: 64.0,
            }],
            compression: Some(CompressionReport {
                codec: "int8".to_string(),
                effective_compression_ratio: 4.0,
                ..Default::default()
            }),
            recovery: Some(RecoveryReport { events: 1, ..Default::default() }),
        }
    }

    #[test]
    fn snapshot_all_sections_absent() {
        let report = RunReport {
            engine: "rapid".to_string(),
            dataset: "tiny".to_string(),
            num_workers: 1,
            epochs: vec![epoch(0, 0, 2.0, 0, 0)],
            total_time: 2.0,
            setup_time: 0.5,
            ..Default::default()
        };
        let frame = App::from_report(report).render(60);
        let expected = format!(
            "rapidgnn top — rapid on tiny (1 workers, 1 epochs)\n\
             total 2.000s  setup 0.500s  cpu 0.0J  gpu 0.0J\n\
             \n\
             links\n\
             \x20 (no contention telemetry)\n\
             \n\
             cache hit-rate\n\
             \x20 (no cache lookups)\n\
             \n\
             worker timelines\n\
             \x20 w0   {}     2.000s\n\
             \n\
             compression: —\n\
             recovery: —",
            "=".repeat(24)
        );
        assert_eq!(frame.render_plain(), expected);
    }

    #[test]
    fn full_report_renders_every_widget() {
        let frame = App::from_report(full_report()).render(70);
        let plain = frame.render_plain();
        for needle in [
            "rapidgnn top — rapid on tiny (2 workers, 1 epochs)",
            "host-up:0",
            "cache hit-rate",
            "worker timelines",
            "STRAGGLER",
            "compression: int8 4.00x",
            "recovery: 1 events",
        ] {
            assert!(plain.contains(needle), "missing {needle:?} in:\n{plain}");
        }
    }

    #[test]
    fn trace_replay_rebuilds_epochs() {
        let full = full_report();
        let records: Vec<TraceRecord> = full
            .epochs
            .iter()
            .enumerate()
            .map(|(i, e)| TraceRecord {
                epoch: e.epoch,
                t: e.epoch_time,
                worker: e.worker,
                seq: i as u64,
                kind: "epoch".to_string(),
                fields: e.to_value(),
            })
            .collect();
        let app = App::from_trace_records(&records).unwrap();
        assert_eq!(app.report.epochs, full.epochs);
        assert_eq!(app.report.num_workers, 2);
        assert!((app.report.total_time - 2.0).abs() < 1e-12);
        assert_eq!(app.last_epoch(), Some(0));
    }

    #[test]
    fn non_epoch_records_are_ignored() {
        let rec = TraceRecord {
            epoch: 0,
            t: 0.0,
            worker: 0,
            seq: 0,
            kind: "stage-done".to_string(),
            fields: Value::table(),
        };
        let app = App::from_trace_records(&[rec]).unwrap();
        assert!(app.report.epochs.is_empty());
        assert_eq!(app.last_epoch(), None);
    }

    #[test]
    fn through_epoch_truncates_for_replay() {
        let mut report = full_report();
        report.epochs.push(epoch(1, 0, 1.0, 5, 5));
        let app = App::from_report(report);
        let first = app.through_epoch(0);
        assert!(first.report.epochs.iter().all(|e| e.epoch == 0));
        assert_eq!(first.report.epochs.len(), 2);
    }
}
