//! `rapidgnn top` dashboard: a std-only ANSI terminal UI over run telemetry.
//!
//! No TUI crate exists in this offline environment, so the stack is
//! homegrown and deliberately small, split the way a ratatui app would be:
//! [`frame`] is the character buffer + style palette, [`widgets`] are pure
//! data→cells panels (each with fixed-size frame snapshot tests), and
//! [`app`] owns the state and layout. Nothing in this module touches the
//! wall clock or prints — the render loop is driven by the CLI layer off
//! *virtual-time* epoch boundaries (live mode replays the finished run's
//! journal; the simulator's workers share no real-time epoch barrier to
//! animate against), and the `trace-sink` lint rule keeps console output
//! confined to that caller.

pub mod app;
pub mod frame;
pub mod widgets;

pub use app::App;
pub use frame::{Frame, Style};
