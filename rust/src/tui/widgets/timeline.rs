//! Per-worker epoch timelines with straggler highlighting.
//!
//! One row per worker: total simulated training time as a bar scaled to the
//! slowest worker, the time itself, and a `STRAGGLER` tag (rendered
//! [`Style::Hot`]) when a worker's total exceeds 1.2× the median — the same
//! heuristic the paper uses to call out imbalance in its timeline plots.

use crate::metrics::RunReport;
use crate::tui::frame::{Frame, Style};

/// Bar width in cells.
pub const BAR_WIDTH: usize = 24;
/// Straggler threshold as a multiple of the median worker total.
pub const STRAGGLER_FACTOR: f64 = 1.2;

/// Per-worker total epoch time, indexed by worker id (missing workers 0.0).
pub fn worker_totals(report: &RunReport) -> Vec<f64> {
    let workers = report.num_workers.max(
        report.epochs.iter().map(|e| e.worker + 1).max().unwrap_or(0),
    ) as usize;
    let mut totals = vec![0.0f64; workers];
    for e in &report.epochs {
        totals[e.worker as usize] += e.epoch_time;
    }
    totals
}

/// Median of a non-empty slice (mean of the middle pair on even lengths).
fn median(values: &[f64]) -> f64 {
    let mut sorted = values.to_vec();
    sorted.sort_by(f64::total_cmp);
    let n = sorted.len();
    if n == 0 {
        0.0
    } else if n % 2 == 1 {
        sorted[n / 2]
    } else {
        0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
    }
}

/// Draw the widget at `(x, y)`; returns rows used.
pub fn render(f: &mut Frame, x: usize, y: usize, report: &RunReport) -> usize {
    f.text(x, y, "worker timelines", Style::Title);
    let totals = worker_totals(report);
    if totals.is_empty() {
        f.text(x, y + 1, "  (no epochs reported)", Style::Plain);
        return 2;
    }
    let max = totals.iter().cloned().fold(0.0, f64::max);
    let med = median(&totals);
    for (w, total) in totals.iter().enumerate() {
        let row = y + 1 + w;
        let fill = if max > 0.0 {
            ((total / max) * BAR_WIDTH as f64).round() as usize
        } else {
            0
        };
        let straggler = med > 0.0 && *total > STRAGGLER_FACTOR * med;
        let style = if straggler { Style::Hot } else { Style::Bar };
        f.text(x + 2, row, &format!("w{w:<3}"), Style::Plain);
        f.hline(x + 7, row, fill.min(BAR_WIDTH), '=', style);
        f.text(x + 7 + BAR_WIDTH + 1, row, &format!("{total:>9.3}s"), Style::Plain);
        if straggler {
            f.text(x + 7 + BAR_WIDTH + 12, row, "STRAGGLER", Style::Hot);
        }
    }
    1 + totals.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::EpochReport;

    fn epoch(epoch: u32, worker: u32, time: f64) -> EpochReport {
        EpochReport { epoch, worker, epoch_time: time, ..Default::default() }
    }

    fn report(num_workers: u32, epochs: Vec<EpochReport>) -> RunReport {
        RunReport { num_workers, epochs, ..Default::default() }
    }

    #[test]
    fn totals_accumulate_per_worker() {
        let r = report(3, vec![epoch(0, 0, 1.0), epoch(1, 0, 1.0), epoch(0, 2, 4.0)]);
        assert_eq!(worker_totals(&r), vec![2.0, 0.0, 4.0]);
    }

    #[test]
    fn snapshot_balanced_and_straggler() {
        // Workers 0/1 at 1.0s, worker 2 at 2.0s: median 1.0, straggler fires.
        let r = report(
            3,
            vec![epoch(0, 0, 1.0), epoch(0, 1, 1.0), epoch(0, 2, 2.0)],
        );
        let mut f = Frame::new(60, 4);
        let rows = render(&mut f, 0, 0, &r);
        assert_eq!(rows, 4);
        assert_eq!(
            f.render_plain(),
            "worker timelines\n\
             \x20 w0   ============                 1.000s\n\
             \x20 w1   ============                 1.000s\n\
             \x20 w2   ========================     2.000s STRAGGLER"
        );
    }

    #[test]
    fn snapshot_empty_report() {
        let r = report(0, vec![]);
        let mut f = Frame::new(40, 2);
        assert_eq!(render(&mut f, 0, 0, &r), 2);
        assert_eq!(f.render_plain(), "worker timelines\n  (no epochs reported)");
    }

    #[test]
    fn all_equal_times_have_no_straggler() {
        let r = report(2, vec![epoch(0, 0, 3.0), epoch(0, 1, 3.0)]);
        let mut f = Frame::new(60, 3);
        render(&mut f, 0, 0, &r);
        assert!(!f.render_plain().contains("STRAGGLER"));
    }
}
