//! Compression and recovery counter footer.
//!
//! Two rows summarizing the run-level optional telemetry sections: the
//! compression codec with its effective ratio and saved bytes, and the
//! recovery event/movement/lost-work counters. Sections a run never produced
//! render as an em-dash placeholder, mirroring how the serialized report
//! omits them entirely.

use crate::metrics::RunReport;
use crate::tui::frame::{Frame, Style};

/// Draw the widget at `(x, y)`; returns rows used (always 2).
pub fn render(f: &mut Frame, x: usize, y: usize, report: &RunReport) -> usize {
    let comp = match &report.compression {
        None => "compression: —".to_string(),
        Some(c) => format!(
            "compression: {} {:.2}x, saved {} B, grad {}/{}",
            c.codec,
            c.effective_compression_ratio,
            c.bytes_saved,
            c.grad_elems_sent,
            c.grad_elems_total
        ),
    };
    let rec = match &report.recovery {
        None => "recovery: —".to_string(),
        Some(r) => format!(
            "recovery: {} events, {} ckpts, {} rows moved, lost {:.3}s",
            r.events, r.checkpoints_written, r.moved_rows, r.lost_work_time
        ),
    };
    let comp_style = if report.compression.is_some() { Style::Bar } else { Style::Plain };
    let rec_style = if report.recovery.is_some() { Style::Warn } else { Style::Plain };
    f.text(x, y, &comp, comp_style);
    f.text(x, y + 1, &rec, rec_style);
    2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{CompressionReport, RecoveryReport};

    #[test]
    fn snapshot_both_absent() {
        let r = RunReport::default();
        let mut f = Frame::new(40, 2);
        assert_eq!(render(&mut f, 0, 0, &r), 2);
        assert_eq!(f.render_plain(), "compression: —\nrecovery: —");
    }

    #[test]
    fn snapshot_both_present() {
        let r = RunReport {
            compression: Some(CompressionReport {
                codec: "int8".to_string(),
                uncompressed_bytes: 4000,
                compressed_bytes: 1000,
                bytes_saved: 3000,
                effective_compression_ratio: 4.0,
                quant_mse: 0.0,
                grad_elems_total: 100,
                grad_elems_sent: 10,
            }),
            recovery: Some(RecoveryReport {
                events: 3,
                checkpoints_written: 2,
                moved_rows: 42,
                lost_work_time: 1.5,
                ..Default::default()
            }),
            ..Default::default()
        };
        let mut f = Frame::new(60, 2);
        render(&mut f, 0, 0, &r);
        assert_eq!(
            f.render_plain(),
            "compression: int8 4.00x, saved 3000 B, grad 10/100\n\
             recovery: 3 events, 2 ckpts, 42 rows moved, lost 1.500s"
        );
    }
}
