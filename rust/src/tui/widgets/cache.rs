//! Cache hit-rate sparkline.
//!
//! Aggregates every worker's per-epoch cache counters into one hit-rate
//! series and renders it as an ASCII sparkline (density ramp, one cell per
//! epoch), annotated with the final epoch's rate and — when an adaptive
//! controller reported capacities — the peak `n_hot`. Runs with no cache
//! lookups say so instead of drawing a flat line of zeros.

use crate::metrics::RunReport;
use crate::tui::frame::{Frame, Style};

/// Density ramp indexed by `round(rate * 9)`.
const RAMP: [char; 10] = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];

/// Per-epoch aggregate hit rate, ordered by epoch. `None` entries mean the
/// epoch had no lookups.
pub fn hit_rate_series(report: &RunReport) -> Vec<Option<f64>> {
    let mut by_epoch: std::collections::BTreeMap<u32, (u64, u64)> =
        std::collections::BTreeMap::new();
    for e in &report.epochs {
        let slot = by_epoch.entry(e.epoch).or_insert((0, 0));
        slot.0 += e.cache.lookups;
        slot.1 += e.cache.hits;
    }
    by_epoch
        .into_values()
        .map(|(lookups, hits)| {
            if lookups == 0 {
                None
            } else {
                Some(hits as f64 / lookups as f64)
            }
        })
        .collect()
}

/// Draw the widget at `(x, y)` with at most `w` columns; returns rows used.
pub fn render(f: &mut Frame, x: usize, y: usize, w: usize, report: &RunReport) -> usize {
    f.text(x, y, "cache hit-rate", Style::Title);
    let series = hit_rate_series(report);
    if series.iter().all(Option::is_none) {
        f.text(x, y + 1, "  (no cache lookups)", Style::Plain);
        return 2;
    }
    let budget = w.saturating_sub(12).max(1);
    let start = series.len().saturating_sub(budget);
    for (i, slot) in series[start..].iter().enumerate() {
        let (ch, style) = match slot {
            None => ('_', Style::Plain),
            Some(rate) => {
                let idx = (rate.clamp(0.0, 1.0) * 9.0).round() as usize;
                (RAMP[idx], if *rate < 0.5 { Style::Warn } else { Style::Bar })
            }
        };
        f.put(x + 2 + i, y + 1, ch, style);
    }
    let last = series.iter().rev().find_map(|s| *s).unwrap_or(0.0);
    let pct = (last * 100.0).round() as i64;
    let mut tail = format!("last {pct}%");
    let peak = report.peak_n_hot();
    if peak > 0 {
        tail.push_str(&format!("  peak n_hot {peak}"));
    }
    f.text(x + 2 + series.len().min(budget) + 2, y + 1, &tail, Style::Plain);
    2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{CacheStats, EpochReport};

    fn epoch(epoch: u32, worker: u32, lookups: u64, hits: u64) -> EpochReport {
        EpochReport {
            epoch,
            worker,
            cache: CacheStats { lookups, hits },
            ..Default::default()
        }
    }

    fn report(epochs: Vec<EpochReport>) -> RunReport {
        RunReport { epochs, ..Default::default() }
    }

    #[test]
    fn series_merges_workers_per_epoch() {
        let r = report(vec![epoch(0, 0, 10, 5), epoch(0, 1, 10, 10), epoch(1, 0, 0, 0)]);
        assert_eq!(hit_rate_series(&r), vec![Some(0.75), None]);
    }

    #[test]
    fn snapshot_sparkline() {
        // Rates 0.0, 0.5, 1.0 -> ramp chars ' ', '+', '@'; gap epoch -> '_'.
        let r = report(vec![
            epoch(0, 0, 10, 0),
            epoch(1, 0, 10, 5),
            epoch(2, 0, 0, 0),
            epoch(3, 0, 10, 10),
        ]);
        let mut f = Frame::new(40, 2);
        let rows = render(&mut f, 0, 0, 40, &r);
        assert_eq!(rows, 2);
        assert_eq!(f.render_plain(), "cache hit-rate\n   +_@  last 100%");
    }

    #[test]
    fn snapshot_no_lookups() {
        let r = report(vec![epoch(0, 0, 0, 0)]);
        let mut f = Frame::new(40, 2);
        assert_eq!(render(&mut f, 0, 0, 40, &r), 2);
        assert_eq!(f.render_plain(), "cache hit-rate\n  (no cache lookups)");
    }

    #[test]
    fn long_series_keeps_the_tail() {
        let epochs: Vec<EpochReport> =
            (0..50).map(|e| epoch(e, 0, 10, u64::from(e % 11))).collect();
        let r = report(epochs);
        let mut f = Frame::new(30, 2);
        render(&mut f, 0, 0, 30, &r);
        // Budget = 30 - 12 = 18 cells; the frame still renders something and
        // the tail annotation survives.
        assert!(f.render_plain().contains("last"));
    }
}
