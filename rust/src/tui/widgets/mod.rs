//! Dashboard widgets: each module draws one telemetry panel into a
//! [`crate::tui::frame::Frame`] region and returns the rows it used, so the
//! app layer can stack panels without hard-coded offsets. Widgets are pure
//! functions of report data — no I/O, no wall-clock, no console output (the
//! `trace-sink` lint rule enforces the last one).

pub mod cache;
pub mod counters;
pub mod links;
pub mod timeline;
