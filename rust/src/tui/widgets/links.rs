//! Per-link utilization heat bars.
//!
//! One row per [`LinkReport`]: the stable link label, a fixed-width bar
//! filled proportionally to mean utilization, and the percentage. Bars at or
//! above 90% render [`Style::Hot`], above 60% [`Style::Warn`], otherwise
//! [`Style::Bar`]. With no contention telemetry the widget says so instead
//! of rendering an empty table.

use crate::metrics::LinkReport;
use crate::tui::frame::{Frame, Style};

/// Bar width in cells.
pub const BAR_WIDTH: usize = 20;
/// Label column width (longer labels are clipped).
pub const LABEL_WIDTH: usize = 16;

/// Draw the widget at `(x, y)`; returns the number of rows used.
pub fn render(f: &mut Frame, x: usize, y: usize, links: &[LinkReport]) -> usize {
    f.text(x, y, "links", Style::Title);
    if links.is_empty() {
        f.text(x, y + 1, "  (no contention telemetry)", Style::Plain);
        return 2;
    }
    for (i, link) in links.iter().enumerate() {
        let row = y + 1 + i;
        let util = link.utilization().clamp(0.0, 1.0);
        let fill = (util * BAR_WIDTH as f64).round() as usize;
        let pct = (util * 100.0).round() as i64;
        let style = if pct >= 90 {
            Style::Hot
        } else if pct > 60 {
            Style::Warn
        } else {
            Style::Bar
        };
        let label: String = link.link.chars().take(LABEL_WIDTH).collect();
        f.text(x + 2, row, &label, Style::Plain);
        f.put(x + 2 + LABEL_WIDTH + 1, row, '[', Style::Plain);
        f.hline(x + 2 + LABEL_WIDTH + 2, row, fill, '#', style);
        f.hline(x + 2 + LABEL_WIDTH + 2 + fill, row, BAR_WIDTH - fill, '-', Style::Plain);
        f.put(x + 2 + LABEL_WIDTH + 2 + BAR_WIDTH, row, ']', Style::Plain);
        f.text(x + 2 + LABEL_WIDTH + 2 + BAR_WIDTH + 2, row, &format!("{pct:>3}%"), style);
    }
    1 + links.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link(label: &str, capacity: f64, busy: f64, served: f64) -> LinkReport {
        LinkReport {
            link: label.to_string(),
            capacity_bytes_per_sec: capacity,
            busy_sec: busy,
            served_bytes: served,
            flows: 1,
            peak_flows: 1,
            peak_backlog_bytes: 0.0,
        }
    }

    #[test]
    fn snapshot_half_and_full_utilization() {
        let links = vec![
            link("host-up:0", 1000.0, 2.0, 1000.0), // 50%
            link("host-up:1", 1000.0, 1.0, 1000.0), // 100%
        ];
        let mut f = Frame::new(50, 3);
        let rows = render(&mut f, 0, 0, &links);
        assert_eq!(rows, 3);
        assert_eq!(
            f.render_plain(),
            "links\n  host-up:0        [##########----------]  50%\n  \
             host-up:1        [####################] 100%"
        );
    }

    #[test]
    fn snapshot_absent_telemetry() {
        let mut f = Frame::new(40, 2);
        let rows = render(&mut f, 0, 0, &[]);
        assert_eq!(rows, 2);
        assert_eq!(f.render_plain(), "links\n  (no contention telemetry)");
    }

    #[test]
    fn long_labels_clip_and_idle_links_read_zero() {
        let links = vec![link("a-very-long-link-label-indeed", 1000.0, 0.0, 0.0)];
        let mut f = Frame::new(50, 2);
        render(&mut f, 0, 0, &links);
        let plain = f.render_plain();
        assert!(plain.contains("a-very-long-link"), "clipped label missing:\n{plain}");
        assert!(!plain.contains("label-indeed"), "label not clipped:\n{plain}");
        assert!(plain.contains("[--------------------]   0%"), "idle bar wrong:\n{plain}");
    }
}
