//! Instrumentation: counters, phase timers, and per-epoch reports.
//!
//! Every bench and example consumes these structures; they mirror the
//! quantities the paper reports — step time, network fetch time, RPC counts,
//! bytes moved, cache hit rates, memory, and energy.

use crate::util::value::Value;
use crate::Result;
use std::collections::BTreeMap;

pub mod baseline;

/// Communication counters (monotonic over a run).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommStats {
    /// Vectorized bulk-pull RPCs (cache builds).
    pub vector_pulls: u64,
    /// Synchronous miss-set pulls.
    pub sync_pulls: u64,
    /// Remote feature rows fetched (the paper's `rpc_e` counts rows).
    pub remote_rows: u64,
    /// Subset of `remote_rows` moved by bulk VectorPulls (cache builds);
    /// `remote_rows - vector_rows` = critical-path SyncPull misses (Fig 5).
    pub vector_rows: u64,
    /// Bytes moved over the fabric.
    pub bytes: u64,
    /// Simulated network time charged (seconds).
    pub net_time: f64,
}

impl CommStats {
    /// Accumulate another counter set.
    pub fn merge(&mut self, o: &CommStats) {
        self.vector_pulls += o.vector_pulls;
        self.sync_pulls += o.sync_pulls;
        self.remote_rows += o.remote_rows;
        self.vector_rows += o.vector_rows;
        self.bytes += o.bytes;
        self.net_time += o.net_time;
    }
}

/// Cache statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    pub lookups: u64,
    pub hits: u64,
}

impl CacheStats {
    /// Hit rate in [0,1]; 0 when no lookups.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }

    /// Lookups that missed the cache.
    pub fn misses(&self) -> u64 {
        self.lookups - self.hits
    }

    pub fn merge(&mut self, o: &CacheStats) {
        self.lookups += o.lookups;
        self.hits += o.hits;
    }
}

/// Per-epoch hot-cache controller telemetry, reported by engines whose cache
/// capacity is a live quantity (the `adaptive-cache` strategy). Static-cache
/// engines leave it `None`, and serialization omits it entirely, so existing
/// reports — including the golden trace fixture — stay byte-identical.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheReport {
    /// Steady-cache capacity (`n_hot`) that served this epoch.
    pub n_hot: u32,
    /// Cache hits observed this epoch.
    pub hits: u64,
    /// Cache misses observed this epoch.
    pub misses: u64,
    /// Hit rate in [0,1] for this epoch.
    pub hit_rate: f64,
    /// Cumulative controller resizes applied through this epoch's boundary.
    pub resize_events: u32,
}

impl CacheReport {
    /// Serialize to a [`Value`] table.
    pub fn to_value(&self) -> Value {
        let mut v = Value::table();
        v.set("n_hot", self.n_hot)
            .set("hits", self.hits)
            .set("misses", self.misses)
            .set("hit_rate", self.hit_rate)
            .set("resize_events", self.resize_events);
        v
    }

    /// Parse a table produced by [`Self::to_value`].
    pub fn from_value(v: &Value) -> Result<CacheReport> {
        Ok(CacheReport {
            n_hot: u32::try_from(v.req_u64("n_hot")?)?,
            hits: v.req_u64("hits")?,
            misses: v.req_u64("misses")?,
            hit_rate: v.req_f64("hit_rate")?,
            resize_events: u32::try_from(v.req_u64("resize_events")?)?,
        })
    }
}

/// Wall/simulated time spent per pipeline phase (seconds).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseTimes {
    /// Mini-batch sampling / schedule streaming.
    pub sample: f64,
    /// Feature fetch waiting on the critical path.
    pub fetch: f64,
    /// Host-side feature assembly / device copy.
    pub assemble: f64,
    /// Model forward/backward/update.
    pub compute: f64,
    /// Trainer idle (waiting on prefetcher that is itself waiting).
    pub idle: f64,
}

impl PhaseTimes {
    /// Total step-attributable time.
    pub fn total(&self) -> f64 {
        self.sample + self.fetch + self.assemble + self.compute + self.idle
    }

    pub fn merge(&mut self, o: &PhaseTimes) {
        self.sample += o.sample;
        self.fetch += o.fetch;
        self.assemble += o.assemble;
        self.compute += o.compute;
        self.idle += o.idle;
    }
}

/// Per-epoch report, one per (worker, epoch).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EpochReport {
    pub epoch: u32,
    pub worker: u32,
    /// Batches executed.
    pub steps: u32,
    /// Simulated epoch wall time `t_e` (seconds).
    pub epoch_time: f64,
    pub phases: PhaseTimes,
    pub comm: CommStats,
    pub cache: CacheStats,
    /// Adaptive-cache controller telemetry (`None` for static-cache engines;
    /// omitted from serialization so their traces stay byte-identical).
    pub cache_plan: Option<CacheReport>,
    /// Mean training loss over the epoch (NaN in trace mode).
    pub mean_loss: f64,
    /// Training accuracy over the epoch's seeds (NaN in trace mode).
    pub train_acc: f64,
    /// Peak device-cache bytes (cache + staged prefetch buffers).
    pub device_bytes: u64,
    /// Peak host bytes attributable to the run (schedule buffers etc.).
    pub host_bytes: u64,
}

impl EpochReport {
    /// Serialize to a [`Value`] table.
    pub fn to_value(&self) -> Value {
        let mut v = Value::table();
        v.set("epoch", self.epoch)
            .set("worker", self.worker)
            .set("steps", self.steps)
            .set("epoch_time", self.epoch_time)
            .set("mean_loss", self.mean_loss)
            .set("train_acc", self.train_acc)
            .set("device_bytes", self.device_bytes)
            .set("host_bytes", self.host_bytes)
            .set("sample_s", self.phases.sample)
            .set("fetch_s", self.phases.fetch)
            .set("assemble_s", self.phases.assemble)
            .set("compute_s", self.phases.compute)
            .set("idle_s", self.phases.idle)
            .set("vector_pulls", self.comm.vector_pulls)
            .set("sync_pulls", self.comm.sync_pulls)
            .set("remote_rows", self.comm.remote_rows)
            .set("vector_rows", self.comm.vector_rows)
            .set("bytes", self.comm.bytes)
            .set("net_time", self.comm.net_time)
            .set("cache_lookups", self.cache.lookups)
            .set("cache_hits", self.cache.hits);
        if let Some(cp) = &self.cache_plan {
            v.set("cache_plan", cp.to_value());
        }
        v
    }

    /// Parse a table produced by [`Self::to_value`] — checkpoints store the
    /// already-reported epoch prefix this way so a resumed run's final
    /// report equals the uninterrupted run's.
    pub fn from_value(v: &Value) -> Result<EpochReport> {
        Ok(EpochReport {
            epoch: u32::try_from(v.req_u64("epoch")?)?,
            worker: u32::try_from(v.req_u64("worker")?)?,
            steps: u32::try_from(v.req_u64("steps")?)?,
            epoch_time: v.req_f64("epoch_time")?,
            phases: PhaseTimes {
                sample: v.req_f64("sample_s")?,
                fetch: v.req_f64("fetch_s")?,
                assemble: v.req_f64("assemble_s")?,
                compute: v.req_f64("compute_s")?,
                idle: v.req_f64("idle_s")?,
            },
            comm: CommStats {
                vector_pulls: v.req_u64("vector_pulls")?,
                sync_pulls: v.req_u64("sync_pulls")?,
                remote_rows: v.req_u64("remote_rows")?,
                vector_rows: v.req_u64("vector_rows")?,
                bytes: v.req_u64("bytes")?,
                net_time: v.req_f64("net_time")?,
            },
            cache: CacheStats {
                lookups: v.req_u64("cache_lookups")?,
                hits: v.req_u64("cache_hits")?,
            },
            cache_plan: match v.get("cache_plan") {
                Some(cp) => Some(CacheReport::from_value(cp)?),
                None => None,
            },
            mean_loss: v.req_f64("mean_loss")?,
            train_acc: v.req_f64("train_acc")?,
            device_bytes: v.req_u64("device_bytes")?,
            host_bytes: v.req_u64("host_bytes")?,
        })
    }
}

/// Per-physical-link utilization telemetry from a contended run
/// (`fabric.contention = true`); mirrors `net::LinkUtilization` with the
/// link identity flattened to its stable label.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LinkReport {
    /// Stable link label (e.g. `host-up:0`, `rack-up:1`, `dfly-global:0>1`).
    pub link: String,
    /// Link capacity (bytes/second).
    pub capacity_bytes_per_sec: f64,
    /// Virtual seconds with at least one transfer in flight.
    pub busy_sec: f64,
    /// Bytes drained through the link.
    pub served_bytes: f64,
    /// Transfers that crossed the link.
    pub flows: u64,
    /// Peak concurrent in-flight transfers (queue depth).
    pub peak_flows: u32,
    /// Peak queued bytes at any instant.
    pub peak_backlog_bytes: f64,
}

impl LinkReport {
    /// Mean utilization in [0,1] over the link's busy time.
    pub fn utilization(&self) -> f64 {
        if self.busy_sec <= 0.0 {
            0.0
        } else {
            self.served_bytes / (self.capacity_bytes_per_sec * self.busy_sec)
        }
    }

    /// Serialize to a [`Value`] table.
    pub fn to_value(&self) -> Value {
        let mut v = Value::table();
        v.set("link", self.link.as_str())
            .set("capacity_bytes_per_sec", self.capacity_bytes_per_sec)
            .set("busy_sec", self.busy_sec)
            .set("served_bytes", self.served_bytes)
            .set("flows", self.flows)
            .set("peak_flows", u64::from(self.peak_flows))
            .set("peak_backlog_bytes", self.peak_backlog_bytes)
            .set("utilization", self.utilization());
        v
    }

    /// Parse a table produced by [`Self::to_value`]. The derived
    /// `utilization` key is ignored — it is recomputed from the stored
    /// counters, so a report round-trip cannot drift it.
    pub fn from_value(v: &Value) -> Result<LinkReport> {
        Ok(LinkReport {
            link: v.req_str("link")?.to_string(),
            capacity_bytes_per_sec: v.req_f64("capacity_bytes_per_sec")?,
            busy_sec: v.req_f64("busy_sec")?,
            served_bytes: v.req_f64("served_bytes")?,
            flows: v.req_u64("flows")?,
            peak_flows: u32::try_from(v.req_u64("peak_flows")?)?,
            peak_backlog_bytes: v.req_f64("peak_backlog_bytes")?,
        })
    }
}

/// Whole-run communication-compression telemetry. Present only when a wire
/// codec or gradient sparsifier actually ran; omitted from serialization
/// otherwise, so uncompressed reports — including the golden trace fixture —
/// stay byte-identical.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CompressionReport {
    /// Wire codec label (`f16` / `int8`; `none` when only gradients compress).
    pub codec: String,
    /// Raw f32 bytes the compressed remote rows would have moved.
    pub uncompressed_bytes: u64,
    /// Payload bytes actually charged for those rows (block headers included).
    pub compressed_bytes: u64,
    /// `uncompressed_bytes - compressed_bytes` (saturating at 0).
    pub bytes_saved: u64,
    /// `uncompressed_bytes / compressed_bytes`; 1.0 when nothing compressed.
    pub effective_compression_ratio: f64,
    /// Mean squared quantization error per feature element (0 in trace mode,
    /// where rows are never materialized).
    pub quant_mse: f64,
    /// Gradient coordinates produced by backward passes (full mode).
    pub grad_elems_total: u64,
    /// Gradient coordinates applied after sparsification.
    pub grad_elems_sent: u64,
}

impl CompressionReport {
    /// Serialize to a [`Value`] table.
    pub fn to_value(&self) -> Value {
        let mut v = Value::table();
        v.set("codec", self.codec.as_str())
            .set("uncompressed_bytes", self.uncompressed_bytes)
            .set("compressed_bytes", self.compressed_bytes)
            .set("bytes_saved", self.bytes_saved)
            .set("effective_compression_ratio", self.effective_compression_ratio)
            .set("quant_mse", self.quant_mse)
            .set("grad_elems_total", self.grad_elems_total)
            .set("grad_elems_sent", self.grad_elems_sent);
        v
    }

    /// Parse a table produced by [`Self::to_value`].
    pub fn from_value(v: &Value) -> Result<CompressionReport> {
        Ok(CompressionReport {
            codec: v.req_str("codec")?.to_string(),
            uncompressed_bytes: v.req_u64("uncompressed_bytes")?,
            compressed_bytes: v.req_u64("compressed_bytes")?,
            bytes_saved: v.req_u64("bytes_saved")?,
            effective_compression_ratio: v.req_f64("effective_compression_ratio")?,
            quant_mse: v.req_f64("quant_mse")?,
            grad_elems_total: v.req_u64("grad_elems_total")?,
            grad_elems_sent: v.req_u64("grad_elems_sent")?,
        })
    }
}

/// Whole-run elasticity/fault-recovery telemetry. Present only when the run
/// executed a failure plan or wrote checkpoints; omitted from serialization
/// otherwise, so failure-free reports — including the golden trace fixture —
/// stay byte-identical.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RecoveryReport {
    /// Failure-plan events applied over the run.
    pub events: u32,
    /// Worker departures (shard handed to a standby).
    pub worker_leaves: u32,
    /// Worker (re)joins.
    pub worker_joins: u32,
    /// Links taken down.
    pub link_downs: u32,
    /// Links restored.
    pub link_ups: u32,
    /// Crash-restart events (rollback to the last checkpoint).
    pub crash_restarts: u32,
    /// Checkpoints written at epoch boundaries.
    pub checkpoints_written: u32,
    /// Feature rows shipped by membership-change data moves (shard + warm
    /// cache of the departing/adopting worker).
    pub moved_rows: u64,
    /// Bytes shipped by those moves.
    pub moved_bytes: u64,
    /// Recovery-flow bytes that took a detour around a downed link.
    pub rerouted_bytes: u64,
    /// Simulated seconds spent moving recovery data (priced through the
    /// fabric's link models; kept out of `total_time`, which stays
    /// epoch-only).
    pub recovery_time: f64,
    /// Simulated training seconds re-executed after crash rollbacks (max
    /// over workers of the rolled-back epochs' times).
    pub lost_work_time: f64,
}

impl RecoveryReport {
    /// Serialize to a [`Value`] table.
    pub fn to_value(&self) -> Value {
        let mut v = Value::table();
        v.set("events", self.events)
            .set("worker_leaves", self.worker_leaves)
            .set("worker_joins", self.worker_joins)
            .set("link_downs", self.link_downs)
            .set("link_ups", self.link_ups)
            .set("crash_restarts", self.crash_restarts)
            .set("checkpoints_written", self.checkpoints_written)
            .set("moved_rows", self.moved_rows)
            .set("moved_bytes", self.moved_bytes)
            .set("rerouted_bytes", self.rerouted_bytes)
            .set("recovery_time", self.recovery_time)
            .set("lost_work_time", self.lost_work_time);
        v
    }

    /// Parse back from [`to_value`](Self::to_value)'s table (checkpoint load).
    pub fn from_value(v: &Value) -> Result<RecoveryReport> {
        Ok(RecoveryReport {
            events: v.req_u32("events")?,
            worker_leaves: v.req_u32("worker_leaves")?,
            worker_joins: v.req_u32("worker_joins")?,
            link_downs: v.req_u32("link_downs")?,
            link_ups: v.req_u32("link_ups")?,
            crash_restarts: v.req_u32("crash_restarts")?,
            checkpoints_written: v.req_u32("checkpoints_written")?,
            moved_rows: v.req_u64("moved_rows")?,
            moved_bytes: v.req_u64("moved_bytes")?,
            rerouted_bytes: v.req_u64("rerouted_bytes")?,
            recovery_time: v.req_f64("recovery_time")?,
            lost_work_time: v.req_f64("lost_work_time")?,
        })
    }
}

/// One epoch's virtual-vs-wall-clock comparison from a wallclock run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CalibrationEpoch {
    pub epoch: u32,
    /// Simulated network seconds the analytic model charged this epoch
    /// (summed across workers).
    pub modeled_net_sec: f64,
    /// Wall-clock seconds the real transport spent moving this epoch's
    /// payload (summed across transfers; overlapping transfers from
    /// concurrent workers each count their own duration).
    pub measured_wall_sec: f64,
    /// Payload bytes the real transport actually moved (RPC envelopes
    /// excluded — the modeled byte counters include a 64 B envelope per RPC).
    pub measured_bytes: u64,
    /// Transfers the real transport served.
    pub rpcs: u64,
}

impl CalibrationEpoch {
    /// Serialize to a [`Value`] table.
    pub fn to_value(&self) -> Value {
        let mut v = Value::table();
        v.set("epoch", self.epoch)
            .set("modeled_net_sec", self.modeled_net_sec)
            .set("measured_wall_sec", self.measured_wall_sec)
            .set("measured_bytes", self.measured_bytes)
            .set("rpcs", self.rpcs);
        v
    }

    /// Parse a table produced by [`Self::to_value`].
    pub fn from_value(v: &Value) -> Result<CalibrationEpoch> {
        Ok(CalibrationEpoch {
            epoch: v.req_u32("epoch")?,
            modeled_net_sec: v.req_f64("modeled_net_sec")?,
            measured_wall_sec: v.req_f64("measured_wall_sec")?,
            measured_bytes: v.req_u64("measured_bytes")?,
            rpcs: v.req_u64("rpcs")?,
        })
    }
}

/// One worker-pair link's modeled-vs-measured comparison from a wallclock
/// run. `link` is the directed `src->dst` pair as charged (requester →
/// owner); modeled quantities come from the fabric's per-link counters,
/// measured ones from the real transport's tallies.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CalibrationLink {
    /// Directed pair label, `"src->dst"`.
    pub link: String,
    /// Wire bytes the analytic model charged (payload + 64 B RPC envelopes).
    pub modeled_bytes: u64,
    /// Simulated seconds the analytic model charged.
    pub modeled_sec: f64,
    /// Payload bytes the real transport moved (no envelopes).
    pub measured_bytes: u64,
    /// Wall-clock seconds spent moving them.
    pub measured_wall_sec: f64,
    /// Transfers served on this pair.
    pub rpcs: u64,
}

impl CalibrationLink {
    /// Serialize to a [`Value`] table.
    pub fn to_value(&self) -> Value {
        let mut v = Value::table();
        v.set("link", self.link.as_str())
            .set("modeled_bytes", self.modeled_bytes)
            .set("modeled_sec", self.modeled_sec)
            .set("measured_bytes", self.measured_bytes)
            .set("measured_wall_sec", self.measured_wall_sec)
            .set("rpcs", self.rpcs);
        v
    }

    /// Parse a table produced by [`Self::to_value`].
    pub fn from_value(v: &Value) -> Result<CalibrationLink> {
        Ok(CalibrationLink {
            link: v.req_str("link")?.to_string(),
            modeled_bytes: v.req_u64("modeled_bytes")?,
            modeled_sec: v.req_f64("modeled_sec")?,
            measured_bytes: v.req_u64("measured_bytes")?,
            measured_wall_sec: v.req_f64("measured_wall_sec")?,
            rpcs: v.req_u64("rpcs")?,
        })
    }
}

/// Virtual-vs-wall-clock calibration from a `--exec wallclock` run, where
/// the real shared-memory transport moves every remote pull's payload while
/// the analytic model prices it. Present only on wallclock runs; omitted
/// from serialization otherwise, so trace/full reports — including the
/// golden trace fixture — stay byte-identical.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CalibrationReport {
    /// Transport backend that produced the measurements (e.g. `shm-rings`).
    pub backend: String,
    /// Wall-clock seconds from transport construction to report assembly.
    pub run_wall_sec: f64,
    /// Per-epoch virtual-vs-wall-clock comparison.
    pub epochs: Vec<CalibrationEpoch>,
    /// Per-(requester→owner)-pair modeled-vs-measured comparison.
    pub links: Vec<CalibrationLink>,
}

impl CalibrationReport {
    /// Serialize to a [`Value`] table.
    pub fn to_value(&self) -> Value {
        let mut v = Value::table();
        v.set("backend", self.backend.as_str()).set("run_wall_sec", self.run_wall_sec);
        let epochs: Vec<Value> = self.epochs.iter().map(CalibrationEpoch::to_value).collect();
        v.set("epochs", epochs);
        let links: Vec<Value> = self.links.iter().map(CalibrationLink::to_value).collect();
        v.set("links", links);
        v
    }

    /// Parse a table produced by [`Self::to_value`].
    pub fn from_value(v: &Value) -> Result<CalibrationReport> {
        let epochs = match v.get("epochs") {
            Some(Value::Arr(items)) => items
                .iter()
                .map(CalibrationEpoch::from_value)
                .collect::<Result<Vec<_>>>()?,
            other => anyhow::bail!("key 'epochs': expected array, got {other:?}"),
        };
        let links = match v.get("links") {
            Some(Value::Arr(items)) => items
                .iter()
                .map(CalibrationLink::from_value)
                .collect::<Result<Vec<_>>>()?,
            other => anyhow::bail!("key 'links': expected array, got {other:?}"),
        };
        Ok(CalibrationReport {
            backend: v.req_str("backend")?.to_string(),
            run_wall_sec: v.req_f64("run_wall_sec")?,
            epochs,
            links,
        })
    }
}

/// Whole-run summary aggregated across workers and epochs.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunReport {
    /// Engine display name.
    pub engine: String,
    pub dataset: String,
    pub num_workers: u32,
    pub batch_size: u32,
    pub epochs: Vec<EpochReport>,
    /// End-to-end simulated time (max over workers of their total time).
    pub total_time: f64,
    /// One-time setup cost (RapidGNN precompute + initial cache build),
    /// reported separately from per-epoch training time like the paper.
    pub setup_time: f64,
    /// CPU / GPU energy in joules (from [`crate::energy`]).
    pub cpu_energy_j: f64,
    pub gpu_energy_j: f64,
    /// Per-link utilization telemetry (contended runs only; empty — and
    /// omitted from the serialized report — otherwise, so default-mode
    /// traces stay byte-identical).
    pub links: Vec<LinkReport>,
    /// Communication-compression telemetry (`None` unless a wire codec or
    /// gradient sparsifier ran; omitted from serialization so uncompressed
    /// traces stay byte-identical).
    pub compression: Option<CompressionReport>,
    /// Elasticity/fault-recovery telemetry (`None` unless the run executed a
    /// failure plan or wrote checkpoints; omitted from serialization so
    /// failure-free traces stay byte-identical).
    pub recovery: Option<RecoveryReport>,
    /// Virtual-vs-wall-clock calibration (`None` unless the run executed on
    /// a real transport backend via `--exec wallclock`; omitted from
    /// serialization so trace/full reports stay byte-identical).
    pub calibration: Option<CalibrationReport>,
}

impl RunReport {
    /// Mean simulated time per step (over all epochs/workers).
    pub fn mean_step_time(&self) -> f64 {
        let steps: u64 = self.epochs.iter().map(|e| e.steps as u64).sum();
        let time: f64 = self.epochs.iter().map(|e| e.epoch_time).sum();
        if steps == 0 {
            0.0
        } else {
            time / steps as f64
        }
    }

    /// Mean network (fetch) time per step on the critical path.
    pub fn mean_net_time_per_step(&self) -> f64 {
        let steps: u64 = self.epochs.iter().map(|e| e.steps as u64).sum();
        let t: f64 = self.epochs.iter().map(|e| e.phases.fetch).sum();
        if steps == 0 {
            0.0
        } else {
            t / steps as f64
        }
    }

    /// Mean bytes transferred per step.
    pub fn mean_bytes_per_step(&self) -> f64 {
        let steps: u64 = self.epochs.iter().map(|e| e.steps as u64).sum();
        let b: u64 = self.epochs.iter().map(|e| e.comm.bytes).sum();
        if steps == 0 {
            0.0
        } else {
            b as f64 / steps as f64
        }
    }

    /// Total remote feature rows fetched.
    pub fn total_remote_rows(&self) -> u64 {
        self.epochs.iter().map(|e| e.comm.remote_rows).sum()
    }

    /// Remote rows fetched on the critical path (SyncPull misses only —
    /// excludes bulk cache builds). The paper's Fig-5 quantity.
    pub fn sync_remote_rows(&self) -> u64 {
        self.epochs
            .iter()
            .map(|e| e.comm.remote_rows - e.comm.vector_rows)
            .sum()
    }

    /// Aggregate cache hit rate.
    pub fn cache_hit_rate(&self) -> f64 {
        let mut c = CacheStats::default();
        for e in &self.epochs {
            c.merge(&e.cache);
        }
        c.hit_rate()
    }

    /// Per-(worker, epoch) adaptive-cache telemetry, in report order. Empty
    /// for static-cache engines.
    pub fn cache_timeline(&self) -> impl Iterator<Item = (&EpochReport, &CacheReport)> + '_ {
        self.epochs.iter().filter_map(|e| e.cache_plan.as_ref().map(|cp| (e, cp)))
    }

    /// Largest steady-cache capacity any worker ran with (the adaptive
    /// controller's memory envelope); 0 when no engine reported one.
    pub fn peak_n_hot(&self) -> u32 {
        self.cache_timeline().map(|(_, cp)| cp.n_hot).max().unwrap_or(0)
    }

    /// Aggregate hit rate over the final epoch only (the adaptive
    /// controller's steady state, once resizes have settled).
    pub fn final_epoch_hit_rate(&self) -> f64 {
        let last = self.epochs.iter().map(|e| e.epoch).max();
        let mut c = CacheStats::default();
        for e in self.epochs.iter().filter(|e| Some(e.epoch) == last) {
            c.merge(&e.cache);
        }
        c.hit_rate()
    }

    /// Peak device bytes over the run.
    pub fn peak_device_bytes(&self) -> u64 {
        self.epochs.iter().map(|e| e.device_bytes).max().unwrap_or(0)
    }

    /// Peak host bytes over the run.
    pub fn peak_host_bytes(&self) -> u64 {
        self.epochs.iter().map(|e| e.host_bytes).max().unwrap_or(0)
    }

    /// Per-epoch mean loss series (averaged across workers), for Fig 9.
    pub fn loss_curve(&self) -> Vec<(u32, f64)> {
        let mut by_epoch: BTreeMap<u32, (f64, u32)> = BTreeMap::new();
        for e in &self.epochs {
            if e.mean_loss.is_finite() {
                let slot = by_epoch.entry(e.epoch).or_insert((0.0, 0));
                slot.0 += e.mean_loss;
                slot.1 += 1;
            }
        }
        by_epoch
            .into_iter()
            .map(|(e, (s, n))| (e, s / n as f64))
            .collect()
    }

    /// Per-epoch train-accuracy series (averaged across workers), for Fig 9.
    pub fn accuracy_curve(&self) -> Vec<(u32, f64)> {
        let mut by_epoch: BTreeMap<u32, (f64, u32)> = BTreeMap::new();
        for e in &self.epochs {
            if e.train_acc.is_finite() {
                let slot = by_epoch.entry(e.epoch).or_insert((0.0, 0));
                slot.0 += e.train_acc;
                slot.1 += 1;
            }
        }
        by_epoch
            .into_iter()
            .map(|(e, (s, n))| (e, s / n as f64))
            .collect()
    }

    /// Serialize to a [`Value`] tree (for JSON bench artifacts).
    pub fn to_value(&self) -> Value {
        let mut v = Value::table();
        v.set("engine", self.engine.as_str())
            .set("dataset", self.dataset.as_str())
            .set("num_workers", self.num_workers)
            .set("batch_size", self.batch_size)
            .set("total_time", self.total_time)
            .set("setup_time", self.setup_time)
            .set("cpu_energy_j", self.cpu_energy_j)
            .set("gpu_energy_j", self.gpu_energy_j);
        let epochs: Vec<Value> = self.epochs.iter().map(EpochReport::to_value).collect();
        v.set("epochs", epochs);
        if !self.links.is_empty() {
            let links: Vec<Value> = self.links.iter().map(LinkReport::to_value).collect();
            v.set("links", links);
        }
        if let Some(c) = &self.compression {
            v.set("compression", c.to_value());
        }
        if let Some(r) = &self.recovery {
            v.set("recovery", r.to_value());
        }
        if let Some(c) = &self.calibration {
            v.set("calibration", c.to_value());
        }
        v
    }

    /// Serialize to pretty JSON (bench output artifact).
    pub fn to_json(&self) -> String {
        self.to_value().to_json_pretty()
    }

    /// Parse a tree produced by [`Self::to_value`] (the `top --report`
    /// offline path). Optional sections parse back to their absent forms, so
    /// `from_value(to_value(r)) == r` for every report shape.
    pub fn from_value(v: &Value) -> Result<RunReport> {
        let epochs = match v.get("epochs") {
            Some(Value::Arr(items)) => {
                items.iter().map(EpochReport::from_value).collect::<Result<Vec<_>>>()?
            }
            other => anyhow::bail!("key 'epochs': expected array, got {other:?}"),
        };
        let links = match v.get("links") {
            Some(Value::Arr(items)) => {
                items.iter().map(LinkReport::from_value).collect::<Result<Vec<_>>>()?
            }
            Some(other) => anyhow::bail!("key 'links': expected array, got {other:?}"),
            None => Vec::new(),
        };
        Ok(RunReport {
            engine: v.req_str("engine")?.to_string(),
            dataset: v.req_str("dataset")?.to_string(),
            num_workers: v.req_u32("num_workers")?,
            batch_size: v.req_u32("batch_size")?,
            epochs,
            total_time: v.req_f64("total_time")?,
            setup_time: v.req_f64("setup_time")?,
            cpu_energy_j: v.req_f64("cpu_energy_j")?,
            gpu_energy_j: v.req_f64("gpu_energy_j")?,
            links,
            compression: match v.get("compression") {
                Some(c) => Some(CompressionReport::from_value(c)?),
                None => None,
            },
            recovery: match v.get("recovery") {
                Some(r) => Some(RecoveryReport::from_value(r)?),
                None => None,
            },
            calibration: match v.get("calibration") {
                Some(c) => Some(CalibrationReport::from_value(c)?),
                None => None,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_with(epochs: Vec<EpochReport>) -> RunReport {
        RunReport { epochs, ..Default::default() }
    }

    #[test]
    fn hit_rate_edge_cases() {
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
        let c = CacheStats { lookups: 10, hits: 7 };
        assert!((c.hit_rate() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn mean_step_time_weighs_by_steps() {
        let r = report_with(vec![
            EpochReport { steps: 10, epoch_time: 1.0, ..Default::default() },
            EpochReport { steps: 30, epoch_time: 1.0, ..Default::default() },
        ]);
        assert!((r.mean_step_time() - 2.0 / 40.0).abs() < 1e-12);
    }

    #[test]
    fn empty_report_is_zero_not_nan() {
        let r = report_with(vec![]);
        assert_eq!(r.mean_step_time(), 0.0);
        assert_eq!(r.mean_bytes_per_step(), 0.0);
        assert_eq!(r.mean_net_time_per_step(), 0.0);
    }

    #[test]
    fn loss_curve_averages_workers() {
        let mk = |epoch, worker, loss| EpochReport {
            epoch,
            worker,
            mean_loss: loss,
            ..Default::default()
        };
        let r = report_with(vec![mk(0, 0, 2.0), mk(0, 1, 4.0), mk(1, 0, 1.0), mk(1, 1, 1.0)]);
        assert_eq!(r.loss_curve(), vec![(0, 3.0), (1, 1.0)]);
    }

    #[test]
    fn loss_curve_skips_nan_trace_entries() {
        let r = report_with(vec![EpochReport {
            epoch: 0,
            mean_loss: f64::NAN,
            ..Default::default()
        }]);
        assert!(r.loss_curve().is_empty());
    }

    #[test]
    fn run_report_round_trips_minimal_shape() {
        let r = RunReport {
            engine: "rapid".to_string(),
            dataset: "tiny".to_string(),
            num_workers: 2,
            batch_size: 32,
            epochs: vec![EpochReport { epoch: 0, worker: 1, steps: 3, ..Default::default() }],
            total_time: 1.5,
            setup_time: 0.25,
            cpu_energy_j: 10.0,
            gpu_energy_j: 20.0,
            ..Default::default()
        };
        let back = RunReport::from_value(&r.to_value()).unwrap();
        assert_eq!(back, r);
        assert!(back.links.is_empty());
        assert!(back.compression.is_none() && back.recovery.is_none());
    }

    #[test]
    fn run_report_round_trips_every_optional_section() {
        let r = RunReport {
            engine: "quant-pull".to_string(),
            dataset: "tiny".to_string(),
            num_workers: 1,
            batch_size: 16,
            epochs: vec![EpochReport {
                epoch: 0,
                cache_plan: Some(CacheReport {
                    n_hot: 64,
                    hits: 10,
                    misses: 2,
                    hit_rate: 10.0 / 12.0,
                    resize_events: 1,
                }),
                ..Default::default()
            }],
            links: vec![LinkReport {
                link: "host-up:0".to_string(),
                capacity_bytes_per_sec: 1e9,
                busy_sec: 0.5,
                served_bytes: 1e6,
                flows: 7,
                peak_flows: 3,
                peak_backlog_bytes: 4096.0,
            }],
            compression: Some(CompressionReport {
                codec: "int8".to_string(),
                uncompressed_bytes: 4000,
                compressed_bytes: 1100,
                bytes_saved: 2900,
                effective_compression_ratio: 4000.0 / 1100.0,
                quant_mse: 1e-4,
                grad_elems_total: 100,
                grad_elems_sent: 10,
            }),
            recovery: Some(RecoveryReport { events: 2, moved_rows: 5, ..Default::default() }),
            calibration: Some(CalibrationReport {
                backend: "shm-rings".to_string(),
                run_wall_sec: 0.125,
                epochs: vec![CalibrationEpoch {
                    epoch: 0,
                    modeled_net_sec: 0.5,
                    measured_wall_sec: 0.01,
                    measured_bytes: 40_000,
                    rpcs: 8,
                }],
                links: vec![CalibrationLink {
                    link: "0->1".to_string(),
                    modeled_bytes: 40_512,
                    modeled_sec: 0.5,
                    measured_bytes: 40_000,
                    measured_wall_sec: 0.01,
                    rpcs: 8,
                }],
            }),
            ..Default::default()
        };
        let back = RunReport::from_value(&r.to_value()).unwrap();
        assert_eq!(back, r);
        // And through actual JSON bytes (the top --report path).
        let json = r.to_json();
        let back2 = RunReport::from_value(&Value::from_json(&json).unwrap()).unwrap();
        assert_eq!(back2, r);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = CommStats {
            vector_pulls: 1,
            sync_pulls: 2,
            remote_rows: 3,
            vector_rows: 1,
            bytes: 4,
            net_time: 0.5,
        };
        a.merge(&a.clone());
        assert_eq!(a.vector_pulls, 2);
        assert_eq!(a.bytes, 8);
        assert!((a.net_time - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cache_plan_is_omitted_unless_present() {
        // Byte-stability contract: a report without adaptive telemetry must
        // serialize to exactly the pre-CacheReport shape.
        let without = EpochReport { steps: 1, ..Default::default() };
        assert!(!without.to_value().to_json_pretty().contains("cache_plan"));
        let with = EpochReport {
            steps: 1,
            cache_plan: Some(CacheReport {
                n_hot: 512,
                hits: 9,
                misses: 3,
                hit_rate: 0.75,
                resize_events: 2,
            }),
            ..Default::default()
        };
        let json = with.to_value().to_json_pretty();
        assert!(json.contains("cache_plan") && json.contains("resize_events"), "{json}");
    }

    #[test]
    fn compression_is_omitted_unless_present() {
        // Byte-stability contract: an uncompressed run's report must
        // serialize to exactly the pre-CompressionReport shape.
        let without = report_with(vec![EpochReport::default()]);
        assert!(!without.to_json().contains("compression"));
        let with = RunReport {
            compression: Some(CompressionReport {
                codec: "int8".to_string(),
                uncompressed_bytes: 4000,
                compressed_bytes: 1080,
                bytes_saved: 2920,
                effective_compression_ratio: 4000.0 / 1080.0,
                quant_mse: 1e-6,
                grad_elems_total: 100,
                grad_elems_sent: 10,
            }),
            ..Default::default()
        };
        let json = with.to_json();
        assert!(
            json.contains("compression")
                && json.contains("effective_compression_ratio")
                && json.contains("\"codec\""),
            "{json}"
        );
        let v = Value::from_json(&json).unwrap();
        assert_eq!(v, with.to_value());
    }

    #[test]
    fn recovery_is_omitted_unless_present() {
        // Byte-stability contract: a failure-free run's report must
        // serialize to exactly the pre-RecoveryReport shape.
        let without = report_with(vec![EpochReport::default()]);
        assert!(!without.to_json().contains("recovery"));
        let with = RunReport {
            recovery: Some(RecoveryReport {
                events: 3,
                worker_leaves: 1,
                worker_joins: 1,
                link_downs: 0,
                link_ups: 0,
                crash_restarts: 1,
                checkpoints_written: 2,
                moved_rows: 5_000,
                moved_bytes: 2_000_000,
                rerouted_bytes: 0,
                recovery_time: 0.25,
                lost_work_time: 1.5,
            }),
            ..Default::default()
        };
        let json = with.to_json();
        assert!(
            json.contains("recovery")
                && json.contains("lost_work_time")
                && json.contains("moved_bytes"),
            "{json}"
        );
        let v = Value::from_json(&json).unwrap();
        assert_eq!(v, with.to_value());
    }

    #[test]
    fn calibration_is_omitted_unless_present() {
        // Byte-stability contract: a trace/full run's report must serialize
        // to exactly the pre-CalibrationReport shape.
        let without = report_with(vec![EpochReport::default()]);
        assert!(!without.to_json().contains("calibration"));
        let with = RunReport {
            calibration: Some(CalibrationReport {
                backend: "shm-rings".to_string(),
                run_wall_sec: 1.0,
                epochs: vec![CalibrationEpoch { epoch: 1, rpcs: 3, ..Default::default() }],
                links: vec![CalibrationLink { link: "1->0".to_string(), ..Default::default() }],
            }),
            ..Default::default()
        };
        let json = with.to_json();
        assert!(
            json.contains("calibration")
                && json.contains("measured_wall_sec")
                && json.contains("modeled_net_sec")
                && json.contains("\"backend\""),
            "{json}"
        );
        let v = Value::from_json(&json).unwrap();
        assert_eq!(v, with.to_value());
        let back = RunReport::from_value(&v).unwrap();
        assert_eq!(back, with);
    }

    #[test]
    fn epoch_report_value_round_trip() {
        // Checkpoints persist already-reported epochs through to_value /
        // from_value; every field must survive, including the optional
        // adaptive telemetry and NaN trace-mode losses (NaN ↔ JSON null).
        let full = EpochReport {
            epoch: 3,
            worker: 1,
            steps: 17,
            epoch_time: 2.5,
            phases: PhaseTimes { sample: 0.1, fetch: 0.2, assemble: 0.3, compute: 0.4, idle: 0.5 },
            comm: CommStats {
                vector_pulls: 2,
                sync_pulls: 9,
                remote_rows: 1_000,
                vector_rows: 600,
                bytes: 400_000,
                net_time: 0.7,
            },
            cache: CacheStats { lookups: 50, hits: 40 },
            cache_plan: Some(CacheReport {
                n_hot: 256,
                hits: 40,
                misses: 10,
                hit_rate: 0.8,
                resize_events: 1,
            }),
            mean_loss: 1.25,
            train_acc: 0.5,
            device_bytes: 123,
            host_bytes: 456,
        };
        let back =
            EpochReport::from_value(&Value::from_json(&full.to_value().to_json()).unwrap())
                .unwrap();
        assert_eq!(back, full);

        let trace = EpochReport { mean_loss: f64::NAN, train_acc: f64::NAN, ..Default::default() };
        let back =
            EpochReport::from_value(&Value::from_json(&trace.to_value().to_json()).unwrap())
                .unwrap();
        assert!(back.mean_loss.is_nan() && back.train_acc.is_nan());
        assert!(back.cache_plan.is_none());
    }

    #[test]
    fn cache_timeline_and_peaks() {
        let mk = |epoch, n_hot, hits, lookups| EpochReport {
            epoch,
            cache: CacheStats { lookups, hits },
            cache_plan: Some(CacheReport {
                n_hot,
                hits,
                misses: lookups - hits,
                hit_rate: hits as f64 / lookups as f64,
                resize_events: 0,
            }),
            ..Default::default()
        };
        let r = report_with(vec![mk(0, 100, 1, 10), mk(1, 200, 9, 10)]);
        assert_eq!(r.cache_timeline().count(), 2);
        assert_eq!(r.peak_n_hot(), 200);
        assert!((r.final_epoch_hit_rate() - 0.9).abs() < 1e-12);
        assert_eq!(report_with(vec![]).peak_n_hot(), 0);
        let plain = report_with(vec![EpochReport::default()]);
        assert_eq!(plain.cache_timeline().count(), 0);
    }

    #[test]
    fn cache_stats_misses() {
        assert_eq!(CacheStats { lookups: 10, hits: 7 }.misses(), 3);
        assert_eq!(CacheStats::default().misses(), 0);
    }

    #[test]
    fn json_emission_parses_back() {
        let r = report_with(vec![EpochReport { steps: 5, ..Default::default() }]);
        let s = r.to_json();
        let v = Value::from_json(&s).unwrap();
        assert_eq!(v, r.to_value());
        let epochs = v.get("epochs").unwrap();
        match epochs {
            Value::Arr(items) => assert_eq!(items.len(), 1),
            other => panic!("{other:?}"),
        }
    }
}
