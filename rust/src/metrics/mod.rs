//! Instrumentation: counters, phase timers, and per-epoch reports.
//!
//! Every bench and example consumes these structures; they mirror the
//! quantities the paper reports — step time, network fetch time, RPC counts,
//! bytes moved, cache hit rates, memory, and energy.

use crate::util::value::Value;
use std::collections::BTreeMap;

/// Communication counters (monotonic over a run).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommStats {
    /// Vectorized bulk-pull RPCs (cache builds).
    pub vector_pulls: u64,
    /// Synchronous miss-set pulls.
    pub sync_pulls: u64,
    /// Remote feature rows fetched (the paper's `rpc_e` counts rows).
    pub remote_rows: u64,
    /// Subset of `remote_rows` moved by bulk VectorPulls (cache builds);
    /// `remote_rows - vector_rows` = critical-path SyncPull misses (Fig 5).
    pub vector_rows: u64,
    /// Bytes moved over the fabric.
    pub bytes: u64,
    /// Simulated network time charged (seconds).
    pub net_time: f64,
}

impl CommStats {
    /// Accumulate another counter set.
    pub fn merge(&mut self, o: &CommStats) {
        self.vector_pulls += o.vector_pulls;
        self.sync_pulls += o.sync_pulls;
        self.remote_rows += o.remote_rows;
        self.vector_rows += o.vector_rows;
        self.bytes += o.bytes;
        self.net_time += o.net_time;
    }
}

/// Cache statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    pub lookups: u64,
    pub hits: u64,
}

impl CacheStats {
    /// Hit rate in [0,1]; 0 when no lookups.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }

    /// Lookups that missed the cache.
    pub fn misses(&self) -> u64 {
        self.lookups - self.hits
    }

    pub fn merge(&mut self, o: &CacheStats) {
        self.lookups += o.lookups;
        self.hits += o.hits;
    }
}

/// Per-epoch hot-cache controller telemetry, reported by engines whose cache
/// capacity is a live quantity (the `adaptive-cache` strategy). Static-cache
/// engines leave it `None`, and serialization omits it entirely, so existing
/// reports — including the golden trace fixture — stay byte-identical.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheReport {
    /// Steady-cache capacity (`n_hot`) that served this epoch.
    pub n_hot: u32,
    /// Cache hits observed this epoch.
    pub hits: u64,
    /// Cache misses observed this epoch.
    pub misses: u64,
    /// Hit rate in [0,1] for this epoch.
    pub hit_rate: f64,
    /// Cumulative controller resizes applied through this epoch's boundary.
    pub resize_events: u32,
}

impl CacheReport {
    /// Serialize to a [`Value`] table.
    pub fn to_value(&self) -> Value {
        let mut v = Value::table();
        v.set("n_hot", self.n_hot)
            .set("hits", self.hits)
            .set("misses", self.misses)
            .set("hit_rate", self.hit_rate)
            .set("resize_events", self.resize_events);
        v
    }
}

/// Wall/simulated time spent per pipeline phase (seconds).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseTimes {
    /// Mini-batch sampling / schedule streaming.
    pub sample: f64,
    /// Feature fetch waiting on the critical path.
    pub fetch: f64,
    /// Host-side feature assembly / device copy.
    pub assemble: f64,
    /// Model forward/backward/update.
    pub compute: f64,
    /// Trainer idle (waiting on prefetcher that is itself waiting).
    pub idle: f64,
}

impl PhaseTimes {
    /// Total step-attributable time.
    pub fn total(&self) -> f64 {
        self.sample + self.fetch + self.assemble + self.compute + self.idle
    }

    pub fn merge(&mut self, o: &PhaseTimes) {
        self.sample += o.sample;
        self.fetch += o.fetch;
        self.assemble += o.assemble;
        self.compute += o.compute;
        self.idle += o.idle;
    }
}

/// Per-epoch report, one per (worker, epoch).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EpochReport {
    pub epoch: u32,
    pub worker: u32,
    /// Batches executed.
    pub steps: u32,
    /// Simulated epoch wall time `t_e` (seconds).
    pub epoch_time: f64,
    pub phases: PhaseTimes,
    pub comm: CommStats,
    pub cache: CacheStats,
    /// Adaptive-cache controller telemetry (`None` for static-cache engines;
    /// omitted from serialization so their traces stay byte-identical).
    pub cache_plan: Option<CacheReport>,
    /// Mean training loss over the epoch (NaN in trace mode).
    pub mean_loss: f64,
    /// Training accuracy over the epoch's seeds (NaN in trace mode).
    pub train_acc: f64,
    /// Peak device-cache bytes (cache + staged prefetch buffers).
    pub device_bytes: u64,
    /// Peak host bytes attributable to the run (schedule buffers etc.).
    pub host_bytes: u64,
}

impl EpochReport {
    /// Serialize to a [`Value`] table.
    pub fn to_value(&self) -> Value {
        let mut v = Value::table();
        v.set("epoch", self.epoch)
            .set("worker", self.worker)
            .set("steps", self.steps)
            .set("epoch_time", self.epoch_time)
            .set("mean_loss", self.mean_loss)
            .set("train_acc", self.train_acc)
            .set("device_bytes", self.device_bytes)
            .set("host_bytes", self.host_bytes)
            .set("sample_s", self.phases.sample)
            .set("fetch_s", self.phases.fetch)
            .set("assemble_s", self.phases.assemble)
            .set("compute_s", self.phases.compute)
            .set("idle_s", self.phases.idle)
            .set("vector_pulls", self.comm.vector_pulls)
            .set("sync_pulls", self.comm.sync_pulls)
            .set("remote_rows", self.comm.remote_rows)
            .set("vector_rows", self.comm.vector_rows)
            .set("bytes", self.comm.bytes)
            .set("net_time", self.comm.net_time)
            .set("cache_lookups", self.cache.lookups)
            .set("cache_hits", self.cache.hits);
        if let Some(cp) = &self.cache_plan {
            v.set("cache_plan", cp.to_value());
        }
        v
    }
}

/// Per-physical-link utilization telemetry from a contended run
/// (`fabric.contention = true`); mirrors `net::LinkUtilization` with the
/// link identity flattened to its stable label.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LinkReport {
    /// Stable link label (e.g. `host-up:0`, `rack-up:1`, `dfly-global:0>1`).
    pub link: String,
    /// Link capacity (bytes/second).
    pub capacity_bytes_per_sec: f64,
    /// Virtual seconds with at least one transfer in flight.
    pub busy_sec: f64,
    /// Bytes drained through the link.
    pub served_bytes: f64,
    /// Transfers that crossed the link.
    pub flows: u64,
    /// Peak concurrent in-flight transfers (queue depth).
    pub peak_flows: u32,
    /// Peak queued bytes at any instant.
    pub peak_backlog_bytes: f64,
}

impl LinkReport {
    /// Mean utilization in [0,1] over the link's busy time.
    pub fn utilization(&self) -> f64 {
        if self.busy_sec <= 0.0 {
            0.0
        } else {
            self.served_bytes / (self.capacity_bytes_per_sec * self.busy_sec)
        }
    }

    /// Serialize to a [`Value`] table.
    pub fn to_value(&self) -> Value {
        let mut v = Value::table();
        v.set("link", self.link.as_str())
            .set("capacity_bytes_per_sec", self.capacity_bytes_per_sec)
            .set("busy_sec", self.busy_sec)
            .set("served_bytes", self.served_bytes)
            .set("flows", self.flows)
            .set("peak_flows", u64::from(self.peak_flows))
            .set("peak_backlog_bytes", self.peak_backlog_bytes)
            .set("utilization", self.utilization());
        v
    }
}

/// Whole-run communication-compression telemetry. Present only when a wire
/// codec or gradient sparsifier actually ran; omitted from serialization
/// otherwise, so uncompressed reports — including the golden trace fixture —
/// stay byte-identical.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CompressionReport {
    /// Wire codec label (`f16` / `int8`; `none` when only gradients compress).
    pub codec: String,
    /// Raw f32 bytes the compressed remote rows would have moved.
    pub uncompressed_bytes: u64,
    /// Payload bytes actually charged for those rows (block headers included).
    pub compressed_bytes: u64,
    /// `uncompressed_bytes - compressed_bytes` (saturating at 0).
    pub bytes_saved: u64,
    /// `uncompressed_bytes / compressed_bytes`; 1.0 when nothing compressed.
    pub effective_compression_ratio: f64,
    /// Mean squared quantization error per feature element (0 in trace mode,
    /// where rows are never materialized).
    pub quant_mse: f64,
    /// Gradient coordinates produced by backward passes (full mode).
    pub grad_elems_total: u64,
    /// Gradient coordinates applied after sparsification.
    pub grad_elems_sent: u64,
}

impl CompressionReport {
    /// Serialize to a [`Value`] table.
    pub fn to_value(&self) -> Value {
        let mut v = Value::table();
        v.set("codec", self.codec.as_str())
            .set("uncompressed_bytes", self.uncompressed_bytes)
            .set("compressed_bytes", self.compressed_bytes)
            .set("bytes_saved", self.bytes_saved)
            .set("effective_compression_ratio", self.effective_compression_ratio)
            .set("quant_mse", self.quant_mse)
            .set("grad_elems_total", self.grad_elems_total)
            .set("grad_elems_sent", self.grad_elems_sent);
        v
    }
}

/// Whole-run summary aggregated across workers and epochs.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunReport {
    /// Engine display name.
    pub engine: String,
    pub dataset: String,
    pub num_workers: u32,
    pub batch_size: u32,
    pub epochs: Vec<EpochReport>,
    /// End-to-end simulated time (max over workers of their total time).
    pub total_time: f64,
    /// One-time setup cost (RapidGNN precompute + initial cache build),
    /// reported separately from per-epoch training time like the paper.
    pub setup_time: f64,
    /// CPU / GPU energy in joules (from [`crate::energy`]).
    pub cpu_energy_j: f64,
    pub gpu_energy_j: f64,
    /// Per-link utilization telemetry (contended runs only; empty — and
    /// omitted from the serialized report — otherwise, so default-mode
    /// traces stay byte-identical).
    pub links: Vec<LinkReport>,
    /// Communication-compression telemetry (`None` unless a wire codec or
    /// gradient sparsifier ran; omitted from serialization so uncompressed
    /// traces stay byte-identical).
    pub compression: Option<CompressionReport>,
}

impl RunReport {
    /// Mean simulated time per step (over all epochs/workers).
    pub fn mean_step_time(&self) -> f64 {
        let steps: u64 = self.epochs.iter().map(|e| e.steps as u64).sum();
        let time: f64 = self.epochs.iter().map(|e| e.epoch_time).sum();
        if steps == 0 {
            0.0
        } else {
            time / steps as f64
        }
    }

    /// Mean network (fetch) time per step on the critical path.
    pub fn mean_net_time_per_step(&self) -> f64 {
        let steps: u64 = self.epochs.iter().map(|e| e.steps as u64).sum();
        let t: f64 = self.epochs.iter().map(|e| e.phases.fetch).sum();
        if steps == 0 {
            0.0
        } else {
            t / steps as f64
        }
    }

    /// Mean bytes transferred per step.
    pub fn mean_bytes_per_step(&self) -> f64 {
        let steps: u64 = self.epochs.iter().map(|e| e.steps as u64).sum();
        let b: u64 = self.epochs.iter().map(|e| e.comm.bytes).sum();
        if steps == 0 {
            0.0
        } else {
            b as f64 / steps as f64
        }
    }

    /// Total remote feature rows fetched.
    pub fn total_remote_rows(&self) -> u64 {
        self.epochs.iter().map(|e| e.comm.remote_rows).sum()
    }

    /// Remote rows fetched on the critical path (SyncPull misses only —
    /// excludes bulk cache builds). The paper's Fig-5 quantity.
    pub fn sync_remote_rows(&self) -> u64 {
        self.epochs
            .iter()
            .map(|e| e.comm.remote_rows - e.comm.vector_rows)
            .sum()
    }

    /// Aggregate cache hit rate.
    pub fn cache_hit_rate(&self) -> f64 {
        let mut c = CacheStats::default();
        for e in &self.epochs {
            c.merge(&e.cache);
        }
        c.hit_rate()
    }

    /// Per-(worker, epoch) adaptive-cache telemetry, in report order. Empty
    /// for static-cache engines.
    pub fn cache_timeline(&self) -> impl Iterator<Item = (&EpochReport, &CacheReport)> + '_ {
        self.epochs.iter().filter_map(|e| e.cache_plan.as_ref().map(|cp| (e, cp)))
    }

    /// Largest steady-cache capacity any worker ran with (the adaptive
    /// controller's memory envelope); 0 when no engine reported one.
    pub fn peak_n_hot(&self) -> u32 {
        self.cache_timeline().map(|(_, cp)| cp.n_hot).max().unwrap_or(0)
    }

    /// Aggregate hit rate over the final epoch only (the adaptive
    /// controller's steady state, once resizes have settled).
    pub fn final_epoch_hit_rate(&self) -> f64 {
        let last = self.epochs.iter().map(|e| e.epoch).max();
        let mut c = CacheStats::default();
        for e in self.epochs.iter().filter(|e| Some(e.epoch) == last) {
            c.merge(&e.cache);
        }
        c.hit_rate()
    }

    /// Peak device bytes over the run.
    pub fn peak_device_bytes(&self) -> u64 {
        self.epochs.iter().map(|e| e.device_bytes).max().unwrap_or(0)
    }

    /// Peak host bytes over the run.
    pub fn peak_host_bytes(&self) -> u64 {
        self.epochs.iter().map(|e| e.host_bytes).max().unwrap_or(0)
    }

    /// Per-epoch mean loss series (averaged across workers), for Fig 9.
    pub fn loss_curve(&self) -> Vec<(u32, f64)> {
        let mut by_epoch: BTreeMap<u32, (f64, u32)> = BTreeMap::new();
        for e in &self.epochs {
            if e.mean_loss.is_finite() {
                let slot = by_epoch.entry(e.epoch).or_insert((0.0, 0));
                slot.0 += e.mean_loss;
                slot.1 += 1;
            }
        }
        by_epoch
            .into_iter()
            .map(|(e, (s, n))| (e, s / n as f64))
            .collect()
    }

    /// Per-epoch train-accuracy series (averaged across workers), for Fig 9.
    pub fn accuracy_curve(&self) -> Vec<(u32, f64)> {
        let mut by_epoch: BTreeMap<u32, (f64, u32)> = BTreeMap::new();
        for e in &self.epochs {
            if e.train_acc.is_finite() {
                let slot = by_epoch.entry(e.epoch).or_insert((0.0, 0));
                slot.0 += e.train_acc;
                slot.1 += 1;
            }
        }
        by_epoch
            .into_iter()
            .map(|(e, (s, n))| (e, s / n as f64))
            .collect()
    }

    /// Serialize to a [`Value`] tree (for JSON bench artifacts).
    pub fn to_value(&self) -> Value {
        let mut v = Value::table();
        v.set("engine", self.engine.as_str())
            .set("dataset", self.dataset.as_str())
            .set("num_workers", self.num_workers)
            .set("batch_size", self.batch_size)
            .set("total_time", self.total_time)
            .set("setup_time", self.setup_time)
            .set("cpu_energy_j", self.cpu_energy_j)
            .set("gpu_energy_j", self.gpu_energy_j);
        let epochs: Vec<Value> = self.epochs.iter().map(EpochReport::to_value).collect();
        v.set("epochs", epochs);
        if !self.links.is_empty() {
            let links: Vec<Value> = self.links.iter().map(LinkReport::to_value).collect();
            v.set("links", links);
        }
        if let Some(c) = &self.compression {
            v.set("compression", c.to_value());
        }
        v
    }

    /// Serialize to pretty JSON (bench output artifact).
    pub fn to_json(&self) -> String {
        self.to_value().to_json_pretty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_with(epochs: Vec<EpochReport>) -> RunReport {
        RunReport { epochs, ..Default::default() }
    }

    #[test]
    fn hit_rate_edge_cases() {
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
        let c = CacheStats { lookups: 10, hits: 7 };
        assert!((c.hit_rate() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn mean_step_time_weighs_by_steps() {
        let r = report_with(vec![
            EpochReport { steps: 10, epoch_time: 1.0, ..Default::default() },
            EpochReport { steps: 30, epoch_time: 1.0, ..Default::default() },
        ]);
        assert!((r.mean_step_time() - 2.0 / 40.0).abs() < 1e-12);
    }

    #[test]
    fn empty_report_is_zero_not_nan() {
        let r = report_with(vec![]);
        assert_eq!(r.mean_step_time(), 0.0);
        assert_eq!(r.mean_bytes_per_step(), 0.0);
        assert_eq!(r.mean_net_time_per_step(), 0.0);
    }

    #[test]
    fn loss_curve_averages_workers() {
        let mk = |epoch, worker, loss| EpochReport {
            epoch,
            worker,
            mean_loss: loss,
            ..Default::default()
        };
        let r = report_with(vec![mk(0, 0, 2.0), mk(0, 1, 4.0), mk(1, 0, 1.0), mk(1, 1, 1.0)]);
        assert_eq!(r.loss_curve(), vec![(0, 3.0), (1, 1.0)]);
    }

    #[test]
    fn loss_curve_skips_nan_trace_entries() {
        let r = report_with(vec![EpochReport {
            epoch: 0,
            mean_loss: f64::NAN,
            ..Default::default()
        }]);
        assert!(r.loss_curve().is_empty());
    }

    #[test]
    fn merge_accumulates() {
        let mut a = CommStats {
            vector_pulls: 1,
            sync_pulls: 2,
            remote_rows: 3,
            vector_rows: 1,
            bytes: 4,
            net_time: 0.5,
        };
        a.merge(&a.clone());
        assert_eq!(a.vector_pulls, 2);
        assert_eq!(a.bytes, 8);
        assert!((a.net_time - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cache_plan_is_omitted_unless_present() {
        // Byte-stability contract: a report without adaptive telemetry must
        // serialize to exactly the pre-CacheReport shape.
        let without = EpochReport { steps: 1, ..Default::default() };
        assert!(!without.to_value().to_json_pretty().contains("cache_plan"));
        let with = EpochReport {
            steps: 1,
            cache_plan: Some(CacheReport {
                n_hot: 512,
                hits: 9,
                misses: 3,
                hit_rate: 0.75,
                resize_events: 2,
            }),
            ..Default::default()
        };
        let json = with.to_value().to_json_pretty();
        assert!(json.contains("cache_plan") && json.contains("resize_events"), "{json}");
    }

    #[test]
    fn compression_is_omitted_unless_present() {
        // Byte-stability contract: an uncompressed run's report must
        // serialize to exactly the pre-CompressionReport shape.
        let without = report_with(vec![EpochReport::default()]);
        assert!(!without.to_json().contains("compression"));
        let with = RunReport {
            compression: Some(CompressionReport {
                codec: "int8".to_string(),
                uncompressed_bytes: 4000,
                compressed_bytes: 1080,
                bytes_saved: 2920,
                effective_compression_ratio: 4000.0 / 1080.0,
                quant_mse: 1e-6,
                grad_elems_total: 100,
                grad_elems_sent: 10,
            }),
            ..Default::default()
        };
        let json = with.to_json();
        assert!(
            json.contains("compression")
                && json.contains("effective_compression_ratio")
                && json.contains("\"codec\""),
            "{json}"
        );
        let v = Value::from_json(&json).unwrap();
        assert_eq!(v, with.to_value());
    }

    #[test]
    fn cache_timeline_and_peaks() {
        let mk = |epoch, n_hot, hits, lookups| EpochReport {
            epoch,
            cache: CacheStats { lookups, hits },
            cache_plan: Some(CacheReport {
                n_hot,
                hits,
                misses: lookups - hits,
                hit_rate: hits as f64 / lookups as f64,
                resize_events: 0,
            }),
            ..Default::default()
        };
        let r = report_with(vec![mk(0, 100, 1, 10), mk(1, 200, 9, 10)]);
        assert_eq!(r.cache_timeline().count(), 2);
        assert_eq!(r.peak_n_hot(), 200);
        assert!((r.final_epoch_hit_rate() - 0.9).abs() < 1e-12);
        assert_eq!(report_with(vec![]).peak_n_hot(), 0);
        let plain = report_with(vec![EpochReport::default()]);
        assert_eq!(plain.cache_timeline().count(), 0);
    }

    #[test]
    fn cache_stats_misses() {
        assert_eq!(CacheStats { lookups: 10, hits: 7 }.misses(), 3);
        assert_eq!(CacheStats::default().misses(), 0);
    }

    #[test]
    fn json_emission_parses_back() {
        let r = report_with(vec![EpochReport { steps: 5, ..Default::default() }]);
        let s = r.to_json();
        let v = Value::from_json(&s).unwrap();
        assert_eq!(v, r.to_value());
        let epochs = v.get("epochs").unwrap();
        match epochs {
            Value::Arr(items) => assert_eq!(items.len(), 1),
            other => panic!("{other:?}"),
        }
    }
}
