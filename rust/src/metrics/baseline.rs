//! Bench-baseline regression diffing (`rapidgnn bench-diff`).
//!
//! Compares a fresh bench artifact (`bench_results/fig4.json`, `table2.json`)
//! against the baselines committed at the repo root (`BENCH_fig4.json`,
//! `BENCH_table2.json`) cell by cell. A cell's identity is the set of
//! descriptor keys it carries (dataset / engine / batch / ...); every other
//! numeric field is a metric checked against a symmetric relative tolerance
//! band. Baseline cells missing from the fresh results are regressions;
//! fresh cells absent from the baseline are informational (new coverage) and
//! get picked up when the main-branch job refreshes the baselines.

use crate::util::value::Value;
use crate::Result;
use anyhow::bail;

/// Default relative tolerance band. The simulator is deterministic, so this
/// absorbs intentional model retuning smaller than a headline regression,
/// not run-to-run noise.
pub const DEFAULT_TOLERANCE: f64 = 0.15;

/// Keys that identify a cell rather than measure it.
const ID_KEYS: [&str; 9] =
    ["batch", "batch_size", "cell", "codec", "dataset", "engine", "mode", "topology", "workers"];

/// One compared metric.
#[derive(Debug, Clone, PartialEq)]
pub struct DiffEntry {
    /// Table name (`fig4`, `table2`).
    pub table: String,
    /// Cell identity string (`dataset=tiny batch=32`).
    pub cell: String,
    /// Metric field name.
    pub metric: String,
    /// Committed baseline value.
    pub baseline: f64,
    /// Fresh value (NaN when the metric vanished from the fresh cell).
    pub fresh: f64,
    /// Symmetric relative delta `|fresh - baseline| / max(|baseline|, eps)`.
    pub rel: f64,
    /// True when `rel` exceeds the tolerance band.
    pub breach: bool,
}

/// Whole-comparison outcome across one or more tables.
#[derive(Debug, Clone)]
pub struct DiffSummary {
    /// Tolerance band the entries were judged against.
    pub tolerance: f64,
    /// Every compared metric, in deterministic (table, cell, metric) order.
    pub entries: Vec<DiffEntry>,
    /// Baseline cells with no matching fresh cell — always a regression.
    pub missing_cells: Vec<String>,
    /// Fresh cells with no matching baseline cell — informational.
    pub new_cells: Vec<String>,
}

impl DiffSummary {
    /// Empty summary with the given tolerance.
    pub fn new(tolerance: f64) -> DiffSummary {
        DiffSummary {
            tolerance,
            entries: Vec::new(),
            missing_cells: Vec::new(),
            new_cells: Vec::new(),
        }
    }

    /// True when any metric breached or any baseline cell disappeared.
    pub fn breached(&self) -> bool {
        !self.missing_cells.is_empty() || self.entries.iter().any(|e| e.breach)
    }

    /// The breaching entries only.
    pub fn breaches(&self) -> impl Iterator<Item = &DiffEntry> + '_ {
        self.entries.iter().filter(|e| e.breach)
    }

    /// Serialize for the diff artifact.
    pub fn to_value(&self) -> Value {
        let mut v = Value::table();
        v.set("tolerance", self.tolerance)
            .set("breached", self.breached())
            .set(
                "missing_cells",
                self.missing_cells.iter().map(|c| Value::Str(c.clone())).collect::<Vec<_>>(),
            )
            .set(
                "new_cells",
                self.new_cells.iter().map(|c| Value::Str(c.clone())).collect::<Vec<_>>(),
            );
        let entries: Vec<Value> = self
            .entries
            .iter()
            .map(|e| {
                let mut t = Value::table();
                t.set("table", e.table.as_str())
                    .set("cell", e.cell.as_str())
                    .set("metric", e.metric.as_str())
                    .set("baseline", e.baseline)
                    .set("fresh", e.fresh)
                    .set("rel", e.rel)
                    .set("breach", e.breach);
                t
            })
            .collect();
        v.set("entries", entries);
        v
    }
}

/// A cell's identity: its descriptor keys rendered `key=value`, space-joined
/// in the fixed [`ID_KEYS`] order.
fn cell_id(cell: &Value) -> String {
    let mut parts = Vec::new();
    for key in ID_KEYS {
        if let Some(v) = cell.get(key) {
            let rendered = match v {
                Value::Str(s) => s.clone(),
                other => other.to_json(),
            };
            parts.push(format!("{key}={rendered}"));
        }
    }
    if parts.is_empty() {
        "(anonymous cell)".to_string()
    } else {
        parts.join(" ")
    }
}

/// The cell list under a bench artifact root (array of tables, or one table).
fn cells(root: &Value) -> Result<Vec<&Value>> {
    match root {
        Value::Arr(items) => {
            for item in items {
                if !matches!(item, Value::Table(_)) {
                    bail!("bench artifact cell is not a table: {item:?}");
                }
            }
            Ok(items.iter().collect())
        }
        Value::Table(_) => Ok(vec![root]),
        other => bail!("bench artifact root is neither array nor table: {other:?}"),
    }
}

/// Numeric metric fields of a cell (identity keys excluded), in the table's
/// deterministic key order.
fn metric_fields(cell: &Value) -> Vec<(String, f64)> {
    let Value::Table(map) = cell else { return Vec::new() };
    map.iter()
        .filter(|(k, _)| !ID_KEYS.contains(&k.as_str()))
        .filter_map(|(k, v)| match v {
            Value::Int(i) => Some((k.clone(), *i as f64)),
            Value::Float(f) => Some((k.clone(), *f)),
            _ => None,
        })
        .collect()
}

/// Symmetric relative delta; equal values (including NaN == NaN) read 0.
fn relative_delta(baseline: f64, fresh: f64) -> f64 {
    if baseline == fresh || (baseline.is_nan() && fresh.is_nan()) {
        0.0
    } else if fresh.is_nan() || baseline.is_nan() {
        f64::INFINITY
    } else {
        (fresh - baseline).abs() / baseline.abs().max(1e-12)
    }
}

/// Diff one table pair into `summary`. Cells are matched by identity; within
/// a matched pair every baseline metric is compared (metrics that vanished
/// from the fresh cell breach with `fresh = NaN`).
pub fn diff_tables(
    summary: &mut DiffSummary,
    table: &str,
    baseline: &Value,
    fresh: &Value,
) -> Result<()> {
    let base_cells = cells(baseline)?;
    let fresh_cells = cells(fresh)?;
    let fresh_by_id: Vec<(String, &Value)> =
        fresh_cells.iter().map(|c| (cell_id(c), *c)).collect();
    let mut matched: Vec<bool> = vec![false; fresh_by_id.len()];
    for bcell in base_cells {
        let id = cell_id(bcell);
        let found = fresh_by_id.iter().position(|(fid, _)| *fid == id);
        let Some(idx) = found else {
            summary.missing_cells.push(format!("{table}: {id}"));
            continue;
        };
        matched[idx] = true;
        let fcell = fresh_by_id[idx].1;
        for (metric, bval) in metric_fields(bcell) {
            let fval = match fcell.get(&metric) {
                Some(Value::Int(i)) => *i as f64,
                Some(Value::Float(f)) => *f,
                _ => f64::NAN,
            };
            let rel = relative_delta(bval, fval);
            summary.entries.push(DiffEntry {
                table: table.to_string(),
                cell: id.clone(),
                metric,
                baseline: bval,
                fresh: fval,
                rel,
                breach: rel > summary.tolerance,
            });
        }
    }
    for (i, (id, _)) in fresh_by_id.iter().enumerate() {
        if !matched[i] {
            summary.new_cells.push(format!("{table}: {id}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(dataset: &str, batch: i64, metric: &str, v: f64) -> Value {
        let mut c = Value::table();
        c.set("dataset", dataset).set("batch", batch).set(metric, v);
        c
    }

    fn table(cells: Vec<Value>) -> Value {
        Value::Arr(cells)
    }

    #[test]
    fn within_band_passes_and_outside_breaches() {
        let base = table(vec![cell("tiny", 32, "bytes", 100.0)]);
        let ok = table(vec![cell("tiny", 32, "bytes", 110.0)]);
        let bad = table(vec![cell("tiny", 32, "bytes", 130.0)]);

        let mut s = DiffSummary::new(0.15);
        diff_tables(&mut s, "fig4", &base, &ok).unwrap();
        assert!(!s.breached(), "{:?}", s.entries);
        assert_eq!(s.entries.len(), 1);
        assert!((s.entries[0].rel - 0.1).abs() < 1e-12);

        let mut s = DiffSummary::new(0.15);
        diff_tables(&mut s, "fig4", &base, &bad).unwrap();
        assert!(s.breached());
        assert_eq!(s.breaches().count(), 1);
    }

    #[test]
    fn missing_baseline_cell_is_a_regression() {
        let base = table(vec![cell("tiny", 32, "bytes", 100.0), cell("tiny", 64, "bytes", 1.0)]);
        let fresh = table(vec![cell("tiny", 32, "bytes", 100.0)]);
        let mut s = DiffSummary::new(0.15);
        diff_tables(&mut s, "fig4", &base, &fresh).unwrap();
        assert!(s.breached());
        assert_eq!(s.missing_cells, vec!["fig4: batch=64 dataset=tiny"]);
    }

    #[test]
    fn new_fresh_cells_are_informational() {
        let base = table(vec![cell("tiny", 32, "bytes", 100.0)]);
        let fresh = table(vec![cell("tiny", 32, "bytes", 100.0), cell("tiny", 64, "bytes", 1.0)]);
        let mut s = DiffSummary::new(0.15);
        diff_tables(&mut s, "fig4", &base, &fresh).unwrap();
        assert!(!s.breached());
        assert_eq!(s.new_cells, vec!["fig4: batch=64 dataset=tiny"]);
    }

    #[test]
    fn identity_uses_descriptor_keys_not_metrics() {
        // Same descriptors, different metric value: one cell, compared.
        let mut a = Value::table();
        a.set("dataset", "tiny").set("engine", "rapid").set("speedup", 2.0);
        let mut b = Value::table();
        b.set("dataset", "tiny").set("engine", "rapid").set("speedup", 4.0);
        let mut s = DiffSummary::new(0.15);
        diff_tables(&mut s, "table2", &table(vec![a]), &table(vec![b])).unwrap();
        assert_eq!(s.entries.len(), 1);
        assert!(s.entries[0].breach);
        assert_eq!(s.entries[0].cell, "dataset=tiny engine=rapid");
    }

    #[test]
    fn vanished_metric_breaches_with_nan_fresh() {
        let base = table(vec![cell("tiny", 32, "bytes", 100.0)]);
        let mut stripped = Value::table();
        stripped.set("dataset", "tiny").set("batch", 32i64);
        let fresh = table(vec![stripped]);
        let mut s = DiffSummary::new(0.15);
        diff_tables(&mut s, "fig4", &base, &fresh).unwrap();
        assert!(s.breached());
        assert!(s.entries[0].fresh.is_nan());
    }

    #[test]
    fn zero_baseline_and_equal_values_are_stable() {
        let base = table(vec![cell("tiny", 32, "zero", 0.0)]);
        let fresh = table(vec![cell("tiny", 32, "zero", 0.0)]);
        let mut s = DiffSummary::new(0.15);
        diff_tables(&mut s, "fig4", &base, &fresh).unwrap();
        assert!(!s.breached());
        assert_eq!(s.entries[0].rel, 0.0);
    }

    #[test]
    fn summary_serializes_round_trippable_json() {
        let base = table(vec![cell("tiny", 32, "bytes", 100.0)]);
        let fresh = table(vec![cell("tiny", 32, "bytes", 200.0)]);
        let mut s = DiffSummary::new(0.15);
        diff_tables(&mut s, "fig4", &base, &fresh).unwrap();
        let json = s.to_value().to_json_pretty();
        let back = Value::from_json(&json).unwrap();
        assert!(back.req_bool("breached").unwrap());
        assert!((back.req_f64("tolerance").unwrap() - 0.15).abs() < 1e-12);
    }

    #[test]
    fn malformed_roots_error() {
        let mut s = DiffSummary::new(0.15);
        assert!(diff_tables(&mut s, "t", &Value::Int(3), &Value::table()).is_err());
        assert!(
            diff_tables(&mut s, "t", &table(vec![Value::Int(1)]), &Value::table()).is_err()
        );
    }
}
