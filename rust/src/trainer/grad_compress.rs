//! Error-feedback gradient sparsification around the host SAGE backend.
//!
//! Classic EF-SGD (Stich et al.): each step the carried residual is folded
//! into the fresh gradient, only the top-k (or a seeded random-k) fraction of
//! coordinates per parameter group is applied, and the dropped mass becomes
//! the next residual. Selection runs independently per parameter group
//! (`w_self`, `w_nbr`, `bias` of every layer) so no group is starved by
//! another's magnitude scale.
//!
//! Determinism: top-k is total-ordered (|g| desc, index asc) and random-k
//! draws from an [`Rng`] seeded by `base_seed`, the step counter, and the
//! group index — the cluster event loop steps workers in virtual-time order,
//! so the sequence of `step` calls (and hence every mask) is identical across
//! `RAPIDGNN_THREADS` settings.

use super::sage::{SageModel, StepOutput};
use super::tensor::Mat;
use super::{GradStats, TrainStep};
use crate::compress::{keep_count, rand_k_indices, top_k_indices, ErrorFeedback, GradMode};
use crate::sampler::seed::Rng;
use crate::sampler::SampledBatch;
use crate::util::value::Value;
use crate::Result;

/// Residual accumulators for one SAGE layer's three parameter groups.
struct LayerFeedback {
    w_self: ErrorFeedback,
    w_nbr: ErrorFeedback,
    bias: ErrorFeedback,
}

/// [`SageModel`] with error-feedback gradient sparsification between
/// backward and update.
pub struct GradCompressedSage {
    model: SageModel,
    mode: GradMode,
    k: f64,
    seed: u64,
    step: u64,
    feedback: Vec<LayerFeedback>,
    stats: GradStats,
}

impl GradCompressedSage {
    /// Wrap `model`, keeping a `k` fraction of coordinates per group per step.
    pub fn new(model: SageModel, mode: GradMode, k: f64, seed: u64) -> GradCompressedSage {
        let feedback = model
            .layers
            .iter()
            .map(|l| LayerFeedback {
                w_self: ErrorFeedback::new(l.w_self.data.len()),
                w_nbr: ErrorFeedback::new(l.w_nbr.data.len()),
                bias: ErrorFeedback::new(l.bias.len()),
            })
            .collect();
        GradCompressedSage { model, mode, k, seed, step: 0, feedback, stats: GradStats::default() }
    }

    /// The wrapped model (tests compare parameters against a dense run).
    pub fn model(&self) -> &SageModel {
        &self.model
    }

    /// Total squared residual mass currently carried (telemetry / tests).
    pub fn residual_norm_sq(&self) -> f64 {
        self.feedback
            .iter()
            .map(|f| {
                f.w_self.residual_norm_sq() + f.w_nbr.residual_norm_sq() + f.bias.residual_norm_sq()
            })
            .sum()
    }
}

/// Accumulate → select on the accumulated values → retain, counting elements.
fn sparsify(
    ef: &mut ErrorFeedback,
    grad: &mut [f32],
    mode: GradMode,
    k: f64,
    group_seed: u64,
    stats: &mut GradStats,
) {
    ef.accumulate(grad);
    let keep = keep_count(grad.len(), k);
    let idx = match mode {
        GradMode::TopK => top_k_indices(grad, keep),
        GradMode::RandK => rand_k_indices(grad.len(), keep, &mut Rng::new(group_seed)),
    };
    stats.elems_total += grad.len() as u64;
    stats.elems_sent += idx.len() as u64;
    ef.retain(grad, &idx);
}

impl TrainStep for GradCompressedSage {
    fn step(&mut self, x0: &Mat, batch: &SampledBatch, labels: &[u16], lr: f32) -> StepOutput {
        let (out, mut grads) = self.model.forward_backward(x0, batch, labels);
        let step_seed = self.seed ^ self.step.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        for (l, (g, fb)) in grads.iter_mut().zip(self.feedback.iter_mut()).enumerate() {
            let base = step_seed ^ ((l as u64 + 1) << 32);
            let (mode, k) = (self.mode, self.k);
            sparsify(&mut fb.w_self, &mut g.w_self.data, mode, k, base ^ 1, &mut self.stats);
            sparsify(&mut fb.w_nbr, &mut g.w_nbr.data, mode, k, base ^ 2, &mut self.stats);
            sparsify(&mut fb.bias, &mut g.bias, mode, k, base ^ 3, &mut self.stats);
        }
        self.model.apply_grads(&grads, lr);
        self.step += 1;
        out
    }

    fn eval(&mut self, x0: &Mat, batch: &SampledBatch, labels: &[u16]) -> StepOutput {
        self.model.evaluate(x0, batch, labels)
    }

    fn grad_stats(&self) -> Option<GradStats> {
        Some(self.stats)
    }

    fn save_state(&self) -> Option<Value> {
        // Model weights plus everything the sparsifier's trajectory depends
        // on: the step counter (mask seeds derive from it), the per-group
        // residuals, and the cumulative coordinate counters (telemetry).
        let mut v = self.model.export_state();
        v.set("grad_step", self.step)
            .set("grad_elems_total", self.stats.elems_total)
            .set("grad_elems_sent", self.stats.elems_sent);
        for (l, fb) in self.feedback.iter().enumerate() {
            let to_f64 = |r: &[f32]| -> Vec<f64> { r.iter().map(|&x| x as f64).collect() };
            v.set(&format!("ef_w_self_{l}"), &to_f64(fb.w_self.residual())[..])
                .set(&format!("ef_w_nbr_{l}"), &to_f64(fb.w_nbr.residual())[..])
                .set(&format!("ef_bias_{l}"), &to_f64(fb.bias.residual())[..]);
        }
        Some(v)
    }

    fn load_state(&mut self, v: &Value) -> Result<()> {
        self.model.import_state(v)?;
        self.step = v.req_u64("grad_step")?;
        self.stats.elems_total = v.req_u64("grad_elems_total")?;
        self.stats.elems_sent = v.req_u64("grad_elems_sent")?;
        for (l, fb) in self.feedback.iter_mut().enumerate() {
            let restore = |ef: &mut ErrorFeedback, key: String| -> Result<()> {
                let r: Vec<f32> =
                    v.req_f64_array(&key)?.into_iter().map(|x| x as f32).collect();
                anyhow::ensure!(
                    r.len() == ef.residual().len(),
                    "{key}: residual length {} != expected {}",
                    r.len(),
                    ef.residual().len()
                );
                ef.set_residual(&r);
                Ok(())
            };
            restore(&mut fb.w_self, format!("ef_w_self_{l}"))?;
            restore(&mut fb.w_nbr, format!("ef_w_nbr_{l}"))?;
            restore(&mut fb.bias, format!("ef_bias_{l}"))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetPreset};
    use crate::graph::build_dataset;
    use crate::sampler::{sample_blocks, Fanout};

    fn tiny_batch() -> (crate::graph::Dataset, SampledBatch, Mat, Vec<u16>) {
        let ds = build_dataset(&DatasetConfig::preset(DatasetPreset::Tiny, 1.0), true);
        let seeds: Vec<u32> = ds.train_nodes.iter().take(16).copied().collect();
        let batch = sample_blocks(&ds.graph, &seeds, &[Fanout::Sample(4), Fanout::Sample(3)], 9);
        let d = ds.config.feature_dim as usize;
        let mut x0 = Mat::zeros(batch.node_layers[0].len(), d);
        for (i, &v) in batch.node_layers[0].iter().enumerate() {
            x0.row_mut(i).copy_from_slice(ds.feature_row(v));
        }
        let labels: Vec<u16> = batch.seeds().iter().map(|&s| ds.labels[s as usize]).collect();
        (ds, batch, x0, labels)
    }

    fn fresh_model(ds: &crate::graph::Dataset) -> SageModel {
        SageModel::new(ds.config.feature_dim as usize, 8, ds.config.num_classes as usize, 2, 1)
    }

    #[test]
    fn keep_all_is_bit_identical_to_dense_sgd() {
        // k = 1 keeps every coordinate: residuals stay zero and the wrapped
        // model's trajectory is the dense one, bit for bit.
        let (ds, batch, x0, labels) = tiny_batch();
        let mut dense = fresh_model(&ds);
        let mut wrapped = GradCompressedSage::new(fresh_model(&ds), GradMode::TopK, 1.0, 7);
        for _ in 0..5 {
            let a = dense.train_step(&x0, &batch, &labels, 0.1);
            let b = wrapped.step(&x0, &batch, &labels, 0.1);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        }
        for (dl, wl) in dense.layers.iter().zip(&wrapped.model().layers) {
            assert_eq!(dl.w_self.data, wl.w_self.data);
            assert_eq!(dl.w_nbr.data, wl.w_nbr.data);
            assert_eq!(dl.bias, wl.bias);
        }
        assert_eq!(wrapped.residual_norm_sq(), 0.0);
        let s = wrapped.grad_stats().unwrap();
        assert_eq!(s.elems_sent, s.elems_total);
    }

    #[test]
    fn topk_ten_percent_still_trains() {
        let (ds, batch, x0, labels) = tiny_batch();
        let mut wrapped = GradCompressedSage::new(fresh_model(&ds), GradMode::TopK, 0.1, 7);
        let first = wrapped.step(&x0, &batch, &labels, 0.1).loss;
        let mut last = first;
        for _ in 0..40 {
            last = wrapped.step(&x0, &batch, &labels, 0.1).loss;
        }
        assert!(last < first * 0.7, "EF top-k loss {first} -> {last}");
        assert!(wrapped.residual_norm_sq() > 0.0, "dropped mass must be carried");
        let s = wrapped.grad_stats().unwrap();
        assert!(s.elems_sent < s.elems_total, "{s:?}");
        // ~10% kept, padded up by per-group ceil(len·k) and the ≥1 floor.
        let ratio = s.elems_sent as f64 / s.elems_total as f64;
        assert!(ratio > 0.05 && ratio < 0.2, "ratio {ratio}");
    }

    #[test]
    fn randk_is_seed_deterministic() {
        let (ds, batch, x0, labels) = tiny_batch();
        let mut a = GradCompressedSage::new(fresh_model(&ds), GradMode::RandK, 0.2, 42);
        let mut b = GradCompressedSage::new(fresh_model(&ds), GradMode::RandK, 0.2, 42);
        for _ in 0..4 {
            let la = a.step(&x0, &batch, &labels, 0.1).loss;
            let lb = b.step(&x0, &batch, &labels, 0.1).loss;
            assert_eq!(la.to_bits(), lb.to_bits());
        }
        for (al, bl) in a.model().layers.iter().zip(&b.model().layers) {
            assert_eq!(al.w_self.data, bl.w_self.data);
        }
        // A different seed picks different masks (parameters diverge).
        let mut c = GradCompressedSage::new(fresh_model(&ds), GradMode::RandK, 0.2, 43);
        for _ in 0..4 {
            c.step(&x0, &batch, &labels, 0.1);
        }
        assert_ne!(a.model().layers[0].w_self.data, c.model().layers[0].w_self.data);
    }

    #[test]
    fn checkpoint_round_trip_resumes_the_exact_trajectory() {
        // Train A for 3 steps, checkpoint, keep training A for 4 more; B
        // restores the checkpoint into a differently-seeded wrapper and runs
        // the same 4 steps — losses and weights must match bit-exactly
        // (residuals, step counter, and mask seeds all round-trip).
        let (ds, batch, x0, labels) = tiny_batch();
        let mut a = GradCompressedSage::new(fresh_model(&ds), GradMode::RandK, 0.2, 7);
        for _ in 0..3 {
            a.step(&x0, &batch, &labels, 0.1);
        }
        let snap = crate::util::value::Value::from_json(&a.save_state().unwrap().to_json())
            .unwrap();
        let mut b = GradCompressedSage::new(fresh_model(&ds), GradMode::RandK, 0.2, 7);
        b.load_state(&snap).unwrap();
        assert_eq!(b.grad_stats(), a.grad_stats());
        for _ in 0..4 {
            let la = a.step(&x0, &batch, &labels, 0.1).loss;
            let lb = b.step(&x0, &batch, &labels, 0.1).loss;
            assert_eq!(la.to_bits(), lb.to_bits());
        }
        for (al, bl) in a.model().layers.iter().zip(&b.model().layers) {
            assert_eq!(al.w_self.data, bl.w_self.data);
            assert_eq!(al.w_nbr.data, bl.w_nbr.data);
            assert_eq!(al.bias, bl.bias);
        }
        assert_eq!(a.grad_stats(), b.grad_stats());
    }

    #[test]
    fn dense_backend_reports_no_grad_stats() {
        let (ds, _, _, _) = tiny_batch();
        let dense: Box<dyn TrainStep> = Box::new(fresh_model(&ds));
        assert!(dense.grad_stats().is_none());
    }
}
