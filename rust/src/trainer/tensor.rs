//! Minimal f32 matrix kernels for the host-path GraphSAGE trainer.
//!
//! The host path exists to (a) run convergence experiments without the PJRT
//! artifact and (b) cross-check the AOT-compiled JAX model. Kernels are
//! simple blocked loops — fast enough for the ~1 GFLOP/step workloads here;
//! the optimized device path is the Pallas/XLA artifact.

/// Row-major f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// From existing data (length must be rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// Kaiming-ish random init in [-lim, lim], deterministic in `seed`.
    pub fn init(rows: usize, cols: usize, seed: u64) -> Mat {
        let lim = (6.0 / (rows + cols) as f32).sqrt();
        let mut rng = crate::sampler::seed::Rng::new(seed);
        let data = (0..rows * cols).map(|_| (rng.f32() * 2.0 - 1.0) * lim).collect();
        Mat { rows, cols, data }
    }

    /// Row slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable row slice.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// `self @ other` — blocked ikj matmul.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape");
        let mut out = Mat::zeros(self.rows, other.cols);
        matmul_into(self, other, &mut out, false);
        out
    }

    /// `self^T @ other` (used for weight gradients).
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "t_matmul shape");
        let mut out = Mat::zeros(self.cols, other.cols);
        // out[i,j] = Σ_r self[r,i] * other[r,j]
        for r in 0..self.rows {
            let a = self.row(r);
            let b = other.row(r);
            for (i, &ai) in a.iter().enumerate() {
                if ai == 0.0 {
                    continue;
                }
                let o = out.row_mut(i);
                for (j, &bj) in b.iter().enumerate() {
                    o[j] += ai * bj;
                }
            }
        }
        out
    }

    /// `self @ other^T` (used for input gradients).
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_t shape");
        let mut out = Mat::zeros(self.rows, other.rows);
        for r in 0..self.rows {
            let a = self.row(r);
            let o = out.row_mut(r);
            for j in 0..other.rows {
                let b = other.row(j);
                let mut acc = 0.0;
                for k in 0..self.cols {
                    acc += a[k] * b[k];
                }
                o[j] = acc;
            }
        }
        out
    }

    /// In-place `self -= lr * g` (SGD step).
    pub fn sgd(&mut self, g: &Mat, lr: f32) {
        assert_eq!(self.data.len(), g.data.len());
        for (w, &d) in self.data.iter_mut().zip(&g.data) {
            *w -= lr * d;
        }
    }

    /// Element-wise ReLU (new matrix).
    pub fn relu(&self) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| x.max(0.0)).collect(),
        }
    }

    /// Backprop through ReLU: `grad * (pre > 0)` in place on `grad`.
    pub fn relu_backward(grad: &mut Mat, pre: &Mat) {
        for (g, &z) in grad.data.iter_mut().zip(&pre.data) {
            if z <= 0.0 {
                *g = 0.0;
            }
        }
    }

    /// Column sums (bias gradients).
    pub fn col_sum(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.cols];
        for r in 0..self.rows {
            for (o, &x) in out.iter_mut().zip(self.row(r)) {
                *o += x;
            }
        }
        out
    }

    /// Frobenius norm (diagnostics / tests).
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|&x| x * x).sum::<f32>().sqrt()
    }

    /// Gather rows by index: `out[i] = self[idx[i]]`.
    pub fn gather(&self, idx: &[u32]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(r as usize));
        }
        out
    }
}

/// `out (+)= a @ b`; zeroes `out` first unless `accumulate`.
pub fn matmul_into(a: &Mat, b: &Mat, out: &mut Mat, accumulate: bool) {
    assert_eq!(a.cols, b.rows);
    assert_eq!(out.rows, a.rows);
    assert_eq!(out.cols, b.cols);
    if !accumulate {
        out.data.fill(0.0);
    }
    // ikj order: streams through b and out rows — cache-friendly for row-major
    for i in 0..a.rows {
        let arow = a.row(i);
        for (k, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = b.row(k);
            let orow = &mut out.data[i * b.cols..(i + 1) * b.cols];
            for (o, &bkj) in orow.iter_mut().zip(brow) {
                *o += aik * bkj;
            }
        }
    }
}

/// Softmax cross-entropy over logits rows with integer labels.
///
/// Returns `(mean_loss, correct_count, dlogits)` where `dlogits` is already
/// divided by the number of valid rows (mean reduction). Rows with label
/// `u16::MAX` are padding and contribute nothing.
pub fn softmax_xent(logits: &Mat, labels: &[u16]) -> (f64, u32, Mat) {
    assert_eq!(logits.rows, labels.len());
    let valid = labels.iter().filter(|&&y| y != u16::MAX).count().max(1);
    let mut grad = Mat::zeros(logits.rows, logits.cols);
    let mut loss = 0.0f64;
    let mut correct = 0u32;
    for r in 0..logits.rows {
        let y = labels[r];
        if y == u16::MAX {
            continue;
        }
        let row = logits.row(r);
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for &x in row {
            sum += (x - maxv).exp();
        }
        let log_z = maxv + sum.ln();
        loss += (log_z - row[y as usize]) as f64;
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        if argmax == y as usize {
            correct += 1;
        }
        let g = grad.row_mut(r);
        for (j, &x) in row.iter().enumerate() {
            g[j] = ((x - log_z).exp() - if j == y as usize { 1.0 } else { 0.0 })
                / valid as f32;
        }
    }
    (loss / valid as f64, correct, grad)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let i = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn matmul_known_values() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let a = Mat::init(5, 3, 1);
        let b = Mat::init(5, 4, 2);
        let direct = a.t_matmul(&b);
        // explicit a^T
        let mut at = Mat::zeros(3, 5);
        for r in 0..5 {
            for c in 0..3 {
                at.row_mut(c)[r] = a.row(r)[c];
            }
        }
        let expect = at.matmul(&b);
        for (x, y) in direct.data.iter().zip(&expect.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn matmul_t_matches_explicit_transpose() {
        let a = Mat::init(4, 3, 3);
        let b = Mat::init(6, 3, 4);
        let direct = a.matmul_t(&b);
        let mut bt = Mat::zeros(3, 6);
        for r in 0..6 {
            for c in 0..3 {
                bt.row_mut(c)[r] = b.row(r)[c];
            }
        }
        let expect = a.matmul(&bt);
        for (x, y) in direct.data.iter().zip(&expect.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn relu_and_backward() {
        let z = Mat::from_vec(1, 4, vec![-1.0, 0.0, 0.5, 2.0]);
        let h = z.relu();
        assert_eq!(h.data, vec![0.0, 0.0, 0.5, 2.0]);
        let mut g = Mat::from_vec(1, 4, vec![1.0; 4]);
        Mat::relu_backward(&mut g, &z);
        assert_eq!(g.data, vec![0.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn softmax_xent_uniform_logits() {
        let logits = Mat::zeros(2, 4);
        let (loss, _correct, grad) = softmax_xent(&logits, &[0, 1]);
        assert!((loss - (4f64).ln()).abs() < 1e-6);
        // gradient rows sum to zero
        for r in 0..2 {
            let s: f32 = grad.row(r).iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_xent_ignores_padding() {
        let logits = Mat::from_vec(2, 2, vec![5.0, 0.0, 0.0, 5.0]);
        let (loss, correct, grad) = softmax_xent(&logits, &[0, u16::MAX]);
        assert!(loss < 0.1);
        assert_eq!(correct, 1);
        assert!(grad.row(1).iter().all(|&g| g == 0.0));
    }

    #[test]
    fn softmax_gradient_numerically_correct() {
        let mut logits = Mat::init(3, 5, 7);
        let labels = [1u16, 4, 2];
        let (_, _, grad) = softmax_xent(&logits, &labels);
        let eps = 1e-3f32;
        for idx in [0usize, 4, 7, 14] {
            let orig = logits.data[idx];
            logits.data[idx] = orig + eps;
            let (lp, _, _) = softmax_xent(&logits, &labels);
            logits.data[idx] = orig - eps;
            let (lm, _, _) = softmax_xent(&logits, &labels);
            logits.data[idx] = orig;
            let numeric = ((lp - lm) / (2.0 * eps as f64)) as f32;
            assert!(
                (numeric - grad.data[idx]).abs() < 1e-3,
                "idx {idx}: numeric {numeric} analytic {}",
                grad.data[idx]
            );
        }
    }

    #[test]
    fn gather_rows() {
        let m = Mat::from_vec(3, 2, vec![0., 1., 10., 11., 20., 21.]);
        let g = m.gather(&[2, 0]);
        assert_eq!(g.data, vec![20., 21., 0., 1.]);
    }

    #[test]
    fn sgd_updates() {
        let mut w = Mat::from_vec(1, 2, vec![1.0, 2.0]);
        let g = Mat::from_vec(1, 2, vec![0.5, -0.5]);
        w.sgd(&g, 0.1);
        assert_eq!(w.data, vec![0.95, 2.05]);
    }
}
