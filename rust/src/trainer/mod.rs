//! Trainer: batch tensor assembly and the train-step backends.
//!
//! Two backends execute the same 2-layer GraphSAGE step:
//! - [`sage::SageModel`] — pure-rust host reference (always available);
//! - [`crate::runtime::PjrtTrainer`] — the AOT-compiled JAX/Pallas artifact
//!   executed via PJRT (the production path; Python never runs at training
//!   time).
//!
//! Both implement [`TrainStep`] so engines are backend-agnostic, and the
//! integration tests assert they produce matching losses on the same batches.

pub mod grad_compress;
pub mod sage;
pub mod tensor;

pub use grad_compress::GradCompressedSage;
pub use sage::{SageModel, StepOutput};
pub use tensor::Mat;

use crate::graph::Dataset;
use crate::sampler::SampledBatch;
use crate::util::value::Value;
use crate::Result;

/// Gradient-compression telemetry: cumulative coordinate counts before and
/// after sparsification over a backend's lifetime.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GradStats {
    /// Gradient coordinates produced by backward passes.
    pub elems_total: u64,
    /// Coordinates actually applied (the sparse "wire" volume).
    pub elems_sent: u64,
}

/// A train-step backend.
pub trait TrainStep {
    /// Run one SGD step; `x0` is the `[n_input, d]` feature block in
    /// input-node order, `labels` per-seed (u16::MAX = ignore).
    fn step(&mut self, x0: &Mat, batch: &SampledBatch, labels: &[u16], lr: f32) -> StepOutput;

    /// Evaluate without updating.
    fn eval(&mut self, x0: &Mat, batch: &SampledBatch, labels: &[u16]) -> StepOutput;

    /// Gradient-compression telemetry; `None` (the default) for dense
    /// backends.
    fn grad_stats(&self) -> Option<GradStats> {
        None
    }

    /// Serialize the backend's full training state for a checkpoint, or
    /// `None` (the default) when the backend cannot be checkpointed (e.g.
    /// PJRT device state lives outside the host).
    fn save_state(&self) -> Option<Value> {
        None
    }

    /// Restore state produced by [`Self::save_state`]. The default errors:
    /// a backend that returns `Some` from `save_state` must override this.
    fn load_state(&mut self, _v: &Value) -> Result<()> {
        anyhow::bail!("this train-step backend does not support checkpoint restore")
    }
}

impl TrainStep for SageModel {
    fn step(&mut self, x0: &Mat, batch: &SampledBatch, labels: &[u16], lr: f32) -> StepOutput {
        self.train_step(x0, batch, labels, lr)
    }

    fn eval(&mut self, x0: &Mat, batch: &SampledBatch, labels: &[u16]) -> StepOutput {
        self.evaluate(x0, batch, labels)
    }

    fn save_state(&self) -> Option<Value> {
        Some(self.export_state())
    }

    fn load_state(&mut self, v: &Value) -> Result<()> {
        self.import_state(v)
    }
}

/// Wrap a staged feature block (from the prefetcher) as a matrix.
pub fn feature_mat(features: Vec<f32>, num_nodes: usize, feature_dim: usize) -> Mat {
    Mat::from_vec(num_nodes, feature_dim, features)
}

/// Extract per-seed labels for a batch.
pub fn batch_labels(ds: &Dataset, batch: &SampledBatch) -> Vec<u16> {
    batch.seeds().iter().map(|&s| ds.labels[s as usize]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetPreset};
    use crate::graph::build_dataset;
    use crate::sampler::{sample_blocks, Fanout};

    #[test]
    fn feature_mat_shape_checked() {
        let m = feature_mat(vec![0.0; 12], 3, 4);
        assert_eq!(m.rows, 3);
        assert_eq!(m.cols, 4);
    }

    #[test]
    fn batch_labels_match_dataset() {
        let ds = build_dataset(&DatasetConfig::preset(DatasetPreset::Tiny, 1.0), false);
        let seeds: Vec<u32> = ds.train_nodes.iter().take(8).copied().collect();
        let b = sample_blocks(&ds.graph, &seeds, &[Fanout::Sample(3)], 1);
        let labels = batch_labels(&ds, &b);
        for (i, &s) in seeds.iter().enumerate() {
            assert_eq!(labels[i], ds.labels[s as usize]);
        }
    }
}
