//! Host-path GraphSAGE: forward, backward, SGD — the reference model the
//! AOT-compiled JAX/Pallas artifact must agree with.
//!
//! Architecture (paper §2.3 baseline): L layers of
//! `h_dst = σ(W_self · h_self + W_nbr · mean(h_nbrs) + b)` with ReLU between
//! layers and softmax cross-entropy on the seed logits — GraphSAGE with mean
//! aggregation, matching DGL's `SAGEConv(aggregator_type='mean')` up to the
//! self/neighbor weight split.

use super::tensor::{softmax_xent, Mat};
use crate::sampler::khop::{LayerBlock, SampledBatch, NO_NEIGHBOR};
use crate::util::value::Value;
use crate::Result;
use anyhow::ensure;

/// One SAGE layer's parameters.
#[derive(Debug, Clone)]
pub struct SageLayer {
    pub w_self: Mat,
    pub w_nbr: Mat,
    pub bias: Vec<f32>,
}

impl SageLayer {
    fn new(d_in: usize, d_out: usize, seed: u64) -> SageLayer {
        SageLayer {
            w_self: Mat::init(d_in, d_out, seed ^ 0x5e1f),
            w_nbr: Mat::init(d_in, d_out, seed ^ 0xa66e),
            bias: vec![0.0; d_out],
        }
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.w_self.data.len() + self.w_nbr.data.len() + self.bias.len()
    }
}

/// Gradients mirroring [`SageLayer`].
pub struct SageLayerGrad {
    pub w_self: Mat,
    pub w_nbr: Mat,
    pub bias: Vec<f32>,
}

/// The GraphSAGE model.
#[derive(Debug, Clone)]
pub struct SageModel {
    pub layers: Vec<SageLayer>,
    /// Layer output dims: `[hidden, ..., num_classes]`.
    pub dims: Vec<usize>,
}

/// Output of one training/eval step.
#[derive(Debug, Clone, Copy)]
pub struct StepOutput {
    pub loss: f64,
    /// Correctly classified seeds.
    pub correct: u32,
    /// Seeds with labels (denominator for accuracy).
    pub total: u32,
}

impl SageModel {
    /// Build an L-layer model: `feature_dim → hidden (×L-1) → num_classes`.
    pub fn new(
        feature_dim: usize,
        hidden: usize,
        num_classes: usize,
        layers: usize,
        seed: u64,
    ) -> SageModel {
        assert!(layers >= 1);
        let mut dims = vec![feature_dim];
        for _ in 0..layers - 1 {
            dims.push(hidden);
        }
        dims.push(num_classes);
        let layers = (0..layers)
            .map(|l| SageLayer::new(dims[l], dims[l + 1], seed.wrapping_add(l as u64 * 7919)))
            .collect();
        SageModel { layers, dims: dims[1..].to_vec() }
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.layers.iter().map(SageLayer::num_params).sum()
    }

    /// Forward pass only; returns seed logits.
    pub fn forward(&self, x0: &Mat, batch: &SampledBatch) -> Mat {
        let mut h = x0.clone();
        for (l, layer) in self.layers.iter().enumerate() {
            let block = &batch.blocks[l];
            let z = layer_forward(layer, &h, block);
            h = if l + 1 < self.layers.len() {
                z.relu()
            } else {
                z
            };
        }
        h
    }

    /// Evaluate loss/accuracy without updating parameters.
    pub fn evaluate(&self, x0: &Mat, batch: &SampledBatch, labels: &[u16]) -> StepOutput {
        let logits = self.forward(x0, batch);
        let (loss, correct, _) = softmax_xent(&logits, labels);
        StepOutput { loss, correct, total: count_valid(labels) }
    }

    /// One SGD training step on a sampled batch.
    ///
    /// `x0` is the `[n_input, d]` feature block (input-node order), `labels`
    /// the per-seed labels (u16::MAX = padding).
    pub fn train_step(
        &mut self,
        x0: &Mat,
        batch: &SampledBatch,
        labels: &[u16],
        lr: f32,
    ) -> StepOutput {
        let (out, grads) = self.forward_backward(x0, batch, labels);
        self.apply_grads(&grads, lr);
        out
    }

    /// Apply per-layer gradients with plain SGD (`p ← p − lr·g`). Split out
    /// of [`Self::train_step`] so gradient-compressing backends can edit the
    /// gradients between backward and update.
    pub fn apply_grads(&mut self, grads: &[SageLayerGrad], lr: f32) {
        for (layer, g) in self.layers.iter_mut().zip(grads) {
            layer.w_self.sgd(&g.w_self, lr);
            layer.w_nbr.sgd(&g.w_nbr, lr);
            for (b, &gb) in layer.bias.iter_mut().zip(&g.bias) {
                *b -= lr * gb;
            }
        }
    }

    /// Forward + backward; returns step output and per-layer gradients.
    pub fn forward_backward(
        &self,
        x0: &Mat,
        batch: &SampledBatch,
        labels: &[u16],
    ) -> (StepOutput, Vec<SageLayerGrad>) {
        let num_layers = self.layers.len();
        assert_eq!(batch.blocks.len(), num_layers, "batch depth vs model depth");
        assert_eq!(x0.rows, batch.node_layers[0].len(), "feature block rows");
        assert_eq!(labels.len(), batch.seeds().len(), "labels per seed");

        // ---- forward, caching activations ----
        // inputs[l] = activation entering layer l; pre[l] = pre-activation out.
        let mut inputs: Vec<Mat> = Vec::with_capacity(num_layers);
        let mut pres: Vec<Mat> = Vec::with_capacity(num_layers);
        let mut aggs: Vec<Mat> = Vec::with_capacity(num_layers);
        let mut h = x0.clone();
        for (l, layer) in self.layers.iter().enumerate() {
            let block = &batch.blocks[l];
            let agg = aggregate_mean(&h, block);
            let z = layer_forward_with_agg(layer, &h, &agg, block);
            inputs.push(h);
            aggs.push(agg);
            let next = if l + 1 < num_layers {
                z.relu()
            } else {
                z.clone()
            };
            pres.push(z);
            h = next;
        }
        let logits = &pres[num_layers - 1];
        let (loss, correct, dlogits) = softmax_xent(logits, labels);

        // ---- backward ----
        let mut grads: Vec<Option<SageLayerGrad>> = (0..num_layers).map(|_| None).collect();
        let mut dz = dlogits; // grad wrt pre-activation of current layer
        for l in (0..num_layers).rev() {
            let block = &batch.blocks[l];
            let layer = &self.layers[l];
            let x_in = &inputs[l];
            let agg = &aggs[l];
            // weight grads
            let x_self = x_in.gather(&block.self_idx);
            let g = SageLayerGrad {
                w_self: x_self.t_matmul(&dz),
                w_nbr: agg.t_matmul(&dz),
                bias: dz.col_sum(),
            };
            grads[l] = Some(g);
            if l == 0 {
                break;
            }
            // grad wrt layer input (= previous layer's post-ReLU output)
            let mut dx = Mat::zeros(x_in.rows, x_in.cols);
            // self path: dx[self_idx[d]] += dz[d] @ w_self^T
            let dself = dz.matmul_t(&layer.w_self);
            for (d, &si) in block.self_idx.iter().enumerate() {
                let dst = dx.row_mut(si as usize);
                for (o, &v) in dst.iter_mut().zip(dself.row(d)) {
                    *o += v;
                }
            }
            // neighbor path: dagg = dz @ w_nbr^T, scattered as mean
            let dagg = dz.matmul_t(&layer.w_nbr);
            scatter_mean_grad(&dagg, block, &mut dx);
            // through ReLU of the previous layer
            Mat::relu_backward(&mut dx, &pres[l - 1]);
            dz = dx;
        }

        let grads: Vec<SageLayerGrad> = grads.into_iter().map(|g| g.unwrap()).collect();
        (
            StepOutput { loss, correct, total: count_valid(labels) },
            grads,
        )
    }
}

fn count_valid(labels: &[u16]) -> u32 {
    labels.iter().filter(|&&y| y != u16::MAX).count() as u32
}

/// Masked mean aggregation: `agg[d] = mean over valid nbr slots of src rows`.
/// This is the computation the L1 Pallas kernel implements on device.
pub fn aggregate_mean(src: &Mat, block: &LayerBlock) -> Mat {
    let f = block.fanout as usize;
    let mut out = Mat::zeros(block.num_dst as usize, src.cols);
    for d in 0..block.num_dst as usize {
        let slots = &block.nbr_idx[d * f..(d + 1) * f];
        let mut count = 0f32;
        {
            let orow = out.row_mut(d);
            for &ni in slots {
                if ni != NO_NEIGHBOR {
                    count += 1.0;
                    for (o, &x) in orow.iter_mut().zip(src.row(ni as usize)) {
                        *o += x;
                    }
                }
            }
        }
        if count > 0.0 {
            let inv = 1.0 / count;
            for o in out.row_mut(d) {
                *o *= inv;
            }
        }
    }
    out
}

/// Backward of [`aggregate_mean`]: `dx[nbr] += dagg[d] / count(d)`.
fn scatter_mean_grad(dagg: &Mat, block: &LayerBlock, dx: &mut Mat) {
    let f = block.fanout as usize;
    for d in 0..block.num_dst as usize {
        let slots = &block.nbr_idx[d * f..(d + 1) * f];
        let count = slots.iter().filter(|&&ni| ni != NO_NEIGHBOR).count();
        if count == 0 {
            continue;
        }
        let inv = 1.0 / count as f32;
        for &ni in slots {
            if ni != NO_NEIGHBOR {
                let row = dx.row_mut(ni as usize);
                for (o, &g) in row.iter_mut().zip(dagg.row(d)) {
                    *o += g * inv;
                }
            }
        }
    }
}

fn layer_forward(layer: &SageLayer, src: &Mat, block: &LayerBlock) -> Mat {
    let agg = aggregate_mean(src, block);
    layer_forward_with_agg(layer, src, &agg, block)
}

impl SageModel {
    /// Serialize weights for a checkpoint. f32 → f64 is exact and the JSON
    /// float emission in [`crate::util::value`] round-trips finite f64, so
    /// restored weights are bit-identical.
    pub fn export_state(&self) -> Value {
        let mut v = Value::table();
        let dims: Vec<u32> = self.dims.iter().map(|&d| d as u32).collect();
        v.set("dims", &dims[..]);
        for (l, layer) in self.layers.iter().enumerate() {
            let w_self: Vec<f64> = layer.w_self.data.iter().map(|&x| x as f64).collect();
            let w_nbr: Vec<f64> = layer.w_nbr.data.iter().map(|&x| x as f64).collect();
            let bias: Vec<f64> = layer.bias.iter().map(|&x| x as f64).collect();
            v.set(&format!("w_self_{l}"), &w_self[..])
                .set(&format!("w_nbr_{l}"), &w_nbr[..])
                .set(&format!("bias_{l}"), &bias[..]);
        }
        v
    }

    /// Restore weights exported by [`Self::export_state`] into this model
    /// (which must have been constructed with the same shape config).
    pub fn import_state(&mut self, v: &Value) -> Result<()> {
        let dims: Vec<usize> =
            v.req_u32_array("dims")?.into_iter().map(|d| d as usize).collect();
        ensure!(
            dims == self.dims,
            "checkpoint dims {dims:?} do not match model dims {:?}",
            self.dims
        );
        for l in 0..self.layers.len() {
            let copy = |dst: &mut [f32], src: Vec<f64>, what: &str| -> Result<()> {
                ensure!(
                    src.len() == dst.len(),
                    "checkpoint layer {l} {what} has {} elements, model has {}",
                    src.len(),
                    dst.len()
                );
                for (d, s) in dst.iter_mut().zip(src) {
                    *d = s as f32;
                }
                Ok(())
            };
            let layer = &mut self.layers[l];
            copy(&mut layer.w_self.data, v.req_f64_array(&format!("w_self_{l}"))?, "w_self")?;
            copy(&mut layer.w_nbr.data, v.req_f64_array(&format!("w_nbr_{l}"))?, "w_nbr")?;
            copy(&mut layer.bias, v.req_f64_array(&format!("bias_{l}"))?, "bias")?;
        }
        Ok(())
    }
}

fn layer_forward_with_agg(layer: &SageLayer, src: &Mat, agg: &Mat, block: &LayerBlock) -> Mat {
    let x_self = src.gather(&block.self_idx);
    let mut z = x_self.matmul(&layer.w_self);
    let zn = agg.matmul(&layer.w_nbr);
    for (a, &b) in z.data.iter_mut().zip(&zn.data) {
        *a += b;
    }
    for r in 0..z.rows {
        for (x, &b) in z.row_mut(r).iter_mut().zip(&layer.bias) {
            *x += b;
        }
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetPreset};
    use crate::graph::build_dataset;
    use crate::sampler::{sample_blocks, Fanout};

    fn tiny_batch() -> (crate::graph::Dataset, SampledBatch, Mat, Vec<u16>) {
        let ds = build_dataset(&DatasetConfig::preset(DatasetPreset::Tiny, 1.0), true);
        let seeds: Vec<u32> = ds.train_nodes.iter().take(16).copied().collect();
        let batch = sample_blocks(
            &ds.graph,
            &seeds,
            &[Fanout::Sample(4), Fanout::Sample(3)],
            9,
        );
        let d = ds.config.feature_dim as usize;
        let mut x0 = Mat::zeros(batch.node_layers[0].len(), d);
        for (i, &v) in batch.node_layers[0].iter().enumerate() {
            x0.row_mut(i).copy_from_slice(ds.feature_row(v));
        }
        let labels: Vec<u16> = batch.seeds().iter().map(|&s| ds.labels[s as usize]).collect();
        (ds, batch, x0, labels)
    }

    #[test]
    fn aggregate_mean_hand_case() {
        // 3 src rows, 2 dst; dst0 ← rows {0,2}, dst1 ← none
        let src = Mat::from_vec(3, 2, vec![1., 2., 10., 20., 3., 4.]);
        let block = LayerBlock {
            fanout: 2,
            num_dst: 2,
            self_idx: vec![0, 1],
            nbr_idx: vec![0, 2, NO_NEIGHBOR, NO_NEIGHBOR],
        };
        let agg = aggregate_mean(&src, &block);
        assert_eq!(agg.row(0), &[2.0, 3.0]);
        assert_eq!(agg.row(1), &[0.0, 0.0]);
    }

    #[test]
    fn forward_shapes() {
        let (ds, batch, x0, _labels) = tiny_batch();
        let model =
            SageModel::new(ds.config.feature_dim as usize, 8, ds.config.num_classes as usize, 2, 1);
        let logits = model.forward(&x0, &batch);
        assert_eq!(logits.rows, batch.seeds().len());
        assert_eq!(logits.cols, ds.config.num_classes as usize);
    }

    #[test]
    fn loss_decreases_over_steps() {
        let (ds, batch, x0, labels) = tiny_batch();
        let mut model =
            SageModel::new(ds.config.feature_dim as usize, 8, ds.config.num_classes as usize, 2, 1);
        let first = model.train_step(&x0, &batch, &labels, 0.1).loss;
        let mut last = first;
        for _ in 0..30 {
            last = model.train_step(&x0, &batch, &labels, 0.1).loss;
        }
        assert!(last < first * 0.7, "loss {first} -> {last}");
    }

    #[test]
    fn gradients_numerically_correct() {
        // Numerical gradient check across every parameter group of both layers.
        let (ds, batch, x0, labels) = tiny_batch();
        let model =
            SageModel::new(ds.config.feature_dim as usize, 6, ds.config.num_classes as usize, 2, 5);
        let (_, grads) = model.forward_backward(&x0, &batch, &labels);
        let eps = 3e-3f32;
        let check = |get: &dyn Fn(&mut SageModel) -> &mut f32, analytic: f32| {
            let mut m = model.clone();
            *get(&mut m) += eps;
            let lp = m.evaluate(&x0, &batch, &labels).loss;
            let mut m = model.clone();
            *get(&mut m) -= eps;
            let lm = m.evaluate(&x0, &batch, &labels).loss;
            let numeric = ((lp - lm) / (2.0 * eps as f64)) as f32;
            assert!(
                (numeric - analytic).abs() < 2e-2_f32.max(0.05 * analytic.abs()),
                "numeric {numeric} vs analytic {analytic}"
            );
        };
        // spot-check a few coordinates in each group
        for l in 0..2 {
            for idx in [0usize, 3, 11] {
                let a = grads[l].w_self.data[idx];
                check(&|m: &mut SageModel| &mut m.layers[l].w_self.data[idx], a);
                let a = grads[l].w_nbr.data[idx];
                check(&|m: &mut SageModel| &mut m.layers[l].w_nbr.data[idx], a);
            }
            let a = grads[l].bias[1];
            check(&|m: &mut SageModel| &mut m.layers[l].bias[1], a);
        }
    }

    #[test]
    fn padded_labels_do_not_affect_grads() {
        let (ds, batch, x0, mut labels) = tiny_batch();
        let model =
            SageModel::new(ds.config.feature_dim as usize, 6, ds.config.num_classes as usize, 2, 2);
        let (_, g_full) = model.forward_backward(&x0, &batch, &labels);
        // mask half the labels — loss changes but gradient wrt masked rows is 0;
        // quick sanity: gradients differ (denominator change) but stay finite
        for y in labels.iter_mut().skip(8) {
            *y = u16::MAX;
        }
        let (out, g_half) = model.forward_backward(&x0, &batch, &labels);
        assert_eq!(out.total, 8);
        assert!(g_half[0].w_self.norm().is_finite());
        assert!(g_full[0].w_self.norm() != g_half[0].w_self.norm());
    }

    #[test]
    fn three_layer_model_trains() {
        // depth generality: the host path supports arbitrary fanout depth
        let ds = build_dataset(&DatasetConfig::preset(DatasetPreset::Tiny, 1.0), true);
        let seeds: Vec<u32> = ds.train_nodes.iter().take(16).copied().collect();
        let fo = [Fanout::Sample(3), Fanout::Sample(3), Fanout::Sample(3)];
        let batch = sample_blocks(&ds.graph, &seeds, &fo, 4);
        let d = ds.config.feature_dim as usize;
        let mut x0 = Mat::zeros(batch.node_layers[0].len(), d);
        for (i, &v) in batch.node_layers[0].iter().enumerate() {
            x0.row_mut(i).copy_from_slice(ds.feature_row(v));
        }
        let labels: Vec<u16> = batch.seeds().iter().map(|&s| ds.labels[s as usize]).collect();
        let mut model = SageModel::new(d, 8, ds.config.num_classes as usize, 3, 2);
        assert_eq!(model.layers.len(), 3);
        let first = model.train_step(&x0, &batch, &labels, 0.1).loss;
        let mut last = first;
        for _ in 0..25 {
            last = model.train_step(&x0, &batch, &labels, 0.1).loss;
        }
        assert!(last < first, "3-layer loss {first} -> {last}");
    }

    #[test]
    fn param_count_formula() {
        let m = SageModel::new(100, 64, 47, 2, 0);
        let expect = (100 * 64 * 2 + 64) + (64 * 47 * 2 + 47);
        assert_eq!(m.num_params(), expect);
    }

    #[test]
    fn export_import_state_is_bit_exact_through_json() {
        let (ds, batch, x0, labels) = tiny_batch();
        let mut trained = SageModel::new(ds.config.feature_dim as usize, 8, 7, 2, 1);
        for _ in 0..3 {
            trained.train_step(&x0, &batch, &labels, 0.1);
        }
        // serialize → JSON text → parse → restore into a differently-seeded
        // fresh model: every parameter must come back bit-identically.
        let json = trained.export_state().to_json();
        let back = Value::from_json(&json).unwrap();
        let mut restored = SageModel::new(ds.config.feature_dim as usize, 8, 7, 2, 99);
        assert_ne!(restored.layers[0].w_self.data, trained.layers[0].w_self.data);
        restored.import_state(&back).unwrap();
        for (a, b) in trained.layers.iter().zip(&restored.layers) {
            assert_eq!(a.w_self.data, b.w_self.data);
            assert_eq!(a.w_nbr.data, b.w_nbr.data);
            assert_eq!(a.bias, b.bias);
        }
        // and the restored model continues identically
        let la = trained.train_step(&x0, &batch, &labels, 0.1).loss;
        let lb = restored.train_step(&x0, &batch, &labels, 0.1).loss;
        assert_eq!(la.to_bits(), lb.to_bits());
        // shape mismatch is rejected
        let mut wrong = SageModel::new(ds.config.feature_dim as usize, 16, 7, 2, 1);
        assert!(wrong.import_state(&back).is_err());
    }
}
