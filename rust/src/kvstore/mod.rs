//! Distributed KV store for node features (DistDGL-style), sharded by the
//! graph partition, with RPC costs charged to the simulated [`crate::net`]
//! fabric.
//!
//! Two pull primitives mirror the paper:
//! - [`KvStore::vector_pull`] — one bulk, vectorized pull (cache builds;
//!   Algorithm 1 line 4). Fans out to owner shards in parallel.
//! - [`KvStore::sync_pull`] — the miss-set pull on (or near) the critical
//!   path (Algorithm 1 line 14). Same transport, tracked separately.
//!
//! Feature values may or may not be materialized: the trace-mode benches run
//! metadata-only (counts and charges are exact, no row copies), while full
//! runs gather real rows.

use crate::graph::Dataset;
use crate::metrics::CommStats;
use crate::net::NetFabric;
use crate::partition::Partition;
use crate::{NodeId, WorkerId};
use std::sync::Arc;

/// Result of a pull operation.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Pull {
    /// Simulated seconds on the requester's critical path.
    pub time: f64,
    /// Bytes moved over the fabric.
    pub bytes: u64,
    /// Remote feature rows fetched.
    pub remote_rows: u64,
    /// RPCs issued (one per touched remote shard).
    pub rpcs: u64,
}

/// Sharded feature store.
pub struct KvStore {
    part: Arc<Partition>,
    fabric: NetFabric,
    feature_dim: usize,
    /// `rank[v]` = row index of v within its owner's shard.
    rank: Vec<u32>,
    /// Per-partition feature rows (row-major); empty vecs in trace mode.
    shards: Vec<Vec<f32>>,
}

impl KvStore {
    /// Build from a dataset + partition. Copies feature rows into per-shard
    /// storage when the dataset has materialized features.
    pub fn new(ds: &Dataset, part: Arc<Partition>, fabric: NetFabric) -> Self {
        let n = ds.graph.num_nodes() as usize;
        let d = ds.config.feature_dim as usize;
        let mut rank = vec![0u32; n];
        for locals in &part.local_nodes {
            for (i, &v) in locals.iter().enumerate() {
                rank[v as usize] = i as u32;
            }
        }
        let shards: Vec<Vec<f32>> = if ds.has_features() {
            part.local_nodes
                .iter()
                .map(|locals| {
                    let mut rows = Vec::with_capacity(locals.len() * d);
                    for &v in locals {
                        rows.extend_from_slice(ds.feature_row(v));
                    }
                    rows
                })
                .collect()
        } else {
            vec![Vec::new(); part.num_parts as usize]
        };
        KvStore { part, fabric, feature_dim: d, rank, shards }
    }

    /// Feature dimensionality.
    pub fn feature_dim(&self) -> usize {
        self.feature_dim
    }

    /// Whether feature values are materialized.
    pub fn has_values(&self) -> bool {
        self.shards.iter().any(|s| !s.is_empty())
    }

    /// The fabric all pulls are charged against (topology-aware per-link
    /// stats live here — Fig-4/Fig-6 benches and failure-path tests read it).
    pub fn fabric(&self) -> &NetFabric {
        &self.fabric
    }

    /// Copy node `v`'s feature row into `out` (must be materialized).
    #[inline]
    pub fn copy_row(&self, v: NodeId, out: &mut [f32]) {
        let p = self.part.owner_of(v) as usize;
        let r = self.rank[v as usize] as usize;
        let d = self.feature_dim;
        out.copy_from_slice(&self.shards[p][r * d..(r + 1) * d]);
    }

    /// Read-only view of node `v`'s feature row.
    #[inline]
    pub fn row(&self, v: NodeId) -> &[f32] {
        let p = self.part.owner_of(v) as usize;
        let r = self.rank[v as usize] as usize;
        let d = self.feature_dim;
        &self.shards[p][r * d..(r + 1) * d]
    }

    /// Bytes held by shard `p` (Fig-7 host-memory accounting).
    pub fn shard_bytes(&self, p: WorkerId) -> u64 {
        (self.shards[p as usize].len() * 4) as u64
    }

    /// Internal: group `ids` by owner, charge the fabric for the remote
    /// portion, and optionally gather rows (in `ids` order) into `out`.
    /// `epoch` resolves transient speed phases on the charge.
    fn pull_impl(
        &self,
        requester: WorkerId,
        ids: &[NodeId],
        mut out: Option<&mut Vec<f32>>,
        epoch: u32,
    ) -> Pull {
        let row_bytes = (self.feature_dim * 4) as u64;
        // rows per remote owner shard
        let mut per_dst = vec![0u64; self.part.num_parts as usize];
        let mut remote_rows = 0u64;
        for &v in ids {
            let o = self.part.owner_of(v);
            if o != requester {
                per_dst[o as usize] += 1;
                remote_rows += 1;
            }
        }
        if let Some(buf) = out.as_deref_mut() {
            buf.clear();
            buf.reserve(ids.len() * self.feature_dim);
            for &v in ids {
                let p = self.part.owner_of(v) as usize;
                let r = self.rank[v as usize] as usize;
                let d = self.feature_dim;
                buf.extend_from_slice(&self.shards[p][r * d..(r + 1) * d]);
            }
        }
        let dsts: Vec<(WorkerId, u64)> = per_dst
            .iter()
            .enumerate()
            .filter(|&(_, &r)| r > 0)
            .map(|(p, &r)| (p as WorkerId, r))
            .collect();
        let charge = self.fabric.charge_fanout_at(requester, &dsts, row_bytes, epoch);
        Pull {
            time: charge.time,
            bytes: charge.bytes,
            remote_rows,
            rpcs: dsts.len() as u64,
        }
    }

    /// Bulk vectorized pull (cache construction). `ids` should be remote
    /// nodes; local ids cost nothing on the fabric and are gathered free.
    pub fn vector_pull(
        &self,
        requester: WorkerId,
        ids: &[NodeId],
        out: Option<&mut Vec<f32>>,
        stats: &mut CommStats,
    ) -> Pull {
        self.vector_pull_at(requester, ids, out, stats, 0)
    }

    /// Epoch-aware [`Self::vector_pull`]: transient speed phases resolve
    /// against the requester's current training epoch.
    pub fn vector_pull_at(
        &self,
        requester: WorkerId,
        ids: &[NodeId],
        out: Option<&mut Vec<f32>>,
        stats: &mut CommStats,
        epoch: u32,
    ) -> Pull {
        let p = self.pull_impl(requester, ids, out, epoch);
        stats.vector_pulls += p.rpcs;
        stats.remote_rows += p.remote_rows;
        stats.vector_rows += p.remote_rows;
        stats.bytes += p.bytes;
        stats.net_time += p.time;
        p
    }

    /// Miss-set pull (critical-path or prefetcher residual misses).
    pub fn sync_pull(
        &self,
        requester: WorkerId,
        ids: &[NodeId],
        out: Option<&mut Vec<f32>>,
        stats: &mut CommStats,
    ) -> Pull {
        self.sync_pull_at(requester, ids, out, stats, 0)
    }

    /// Epoch-aware [`Self::sync_pull`] (see [`Self::vector_pull_at`]).
    pub fn sync_pull_at(
        &self,
        requester: WorkerId,
        ids: &[NodeId],
        out: Option<&mut Vec<f32>>,
        stats: &mut CommStats,
        epoch: u32,
    ) -> Pull {
        let p = self.pull_impl(requester, ids, out, epoch);
        stats.sync_pulls += p.rpcs;
        stats.remote_rows += p.remote_rows;
        stats.bytes += p.bytes;
        stats.net_time += p.time;
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetPreset, FabricConfig};
    use crate::graph::build_dataset;
    use crate::partition::metis_like;

    fn setup(with_features: bool) -> (Dataset, Arc<Partition>, KvStore) {
        let ds = build_dataset(&DatasetConfig::preset(DatasetPreset::Tiny, 1.0), with_features);
        let part = Arc::new(metis_like(&ds.graph, 2, 0));
        let kv = KvStore::new(&ds, part.clone(), NetFabric::new(FabricConfig::default()));
        (ds, part, kv)
    }

    #[test]
    fn rows_match_dataset() {
        let (ds, _, kv) = setup(true);
        for v in [0u32, 5, 100, 1999] {
            assert_eq!(kv.row(v), ds.feature_row(v));
        }
    }

    #[test]
    fn pull_gathers_in_request_order() {
        let (ds, _, kv) = setup(true);
        let ids = [9u32, 3, 500, 3];
        let mut out = Vec::new();
        let mut stats = CommStats::default();
        kv.vector_pull(0, &ids, Some(&mut out), &mut stats);
        let d = kv.feature_dim();
        for (i, &v) in ids.iter().enumerate() {
            assert_eq!(&out[i * d..(i + 1) * d], ds.feature_row(v));
        }
    }

    #[test]
    fn local_ids_cost_nothing() {
        let (_, part, kv) = setup(false);
        let locals: Vec<u32> = part.local_nodes[0].iter().take(10).copied().collect();
        let mut stats = CommStats::default();
        let p = kv.sync_pull(0, &locals, None, &mut stats);
        assert_eq!(p.remote_rows, 0);
        assert_eq!(p.rpcs, 0);
        assert_eq!(p.time, 0.0);
        assert_eq!(stats.bytes, 0);
    }

    #[test]
    fn remote_ids_are_charged() {
        let (_, part, kv) = setup(false);
        let remotes: Vec<u32> = part.local_nodes[1].iter().take(10).copied().collect();
        let mut stats = CommStats::default();
        let p = kv.sync_pull(0, &remotes, None, &mut stats);
        assert_eq!(p.remote_rows, 10);
        assert_eq!(p.rpcs, 1, "all on one shard → one RPC");
        assert!(p.time > 0.0);
        assert_eq!(stats.sync_pulls, 1);
        assert_eq!(stats.remote_rows, 10);
    }

    #[test]
    fn vector_vs_sync_tracked_separately() {
        let (_, part, kv) = setup(false);
        let remotes: Vec<u32> = part.local_nodes[1].iter().take(5).copied().collect();
        let mut stats = CommStats::default();
        kv.vector_pull(0, &remotes, None, &mut stats);
        kv.sync_pull(0, &remotes, None, &mut stats);
        assert_eq!(stats.vector_pulls, 1);
        assert_eq!(stats.sync_pulls, 1);
        assert_eq!(stats.remote_rows, 10);
    }

    #[test]
    fn one_bulk_pull_beats_per_node_pulls() {
        // The VectorPull advantage the paper leans on: one vectorized RPC
        // amortizes latency over rows.
        let (_, part, kv) = setup(false);
        let remotes: Vec<u32> = part.local_nodes[1].iter().take(100).copied().collect();
        let mut s1 = CommStats::default();
        let bulk = kv.vector_pull(0, &remotes, None, &mut s1);
        let mut s2 = CommStats::default();
        let mut per_node_time = 0.0;
        for &v in &remotes {
            per_node_time += kv.sync_pull(0, &[v], None, &mut s2).time;
        }
        assert!(per_node_time > 10.0 * bulk.time);
    }

    #[test]
    fn trace_mode_has_no_values() {
        let (_, _, kv) = setup(false);
        assert!(!kv.has_values());
    }
}
